#!/usr/bin/env python3
"""Run the compression micro-benches and emit BENCH_compress.json.

Runs `cargo bench --bench micro_compressors` and `--bench micro_collectives`
(release profile, custom harness) with REPRO_BENCH_JSON pointed at temp
files, merges the two reports, and writes `BENCH_compress.json` at the repo
root so the perf trajectory is tracked from this PR onward.

Usage:
    python3 tools/bench_compress.py [--n COORDS] [--out PATH]

The acceptance gates this file evidences (ISSUE 1):
  * >= 4x throughput on pack/unpack vs the scalar reference;
  * a measured speedup on the fused QSGD-MN-4 encode->allreduce->decode
    step vs the seed f32-level path, same machine, same run.
"""

import argparse
import json
import os
import platform
import subprocess
import sys
import tempfile
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUST_DIR = os.path.join(REPO_ROOT, "rust")


def run_bench(name: str, n: int | None) -> dict:
    fd, path = tempfile.mkstemp(prefix=f"repro_{name}_", suffix=".json")
    os.close(fd)
    env = dict(os.environ, REPRO_BENCH_JSON=path)
    if n is not None:
        env["REPRO_BENCH_N"] = str(n)
    try:
        subprocess.run(
            ["cargo", "bench", "--bench", name],
            cwd=RUST_DIR,
            env=env,
            check=True,
        )
        with open(path) as f:
            return json.load(f)
    finally:
        os.unlink(path)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=None, help="coordinates per gradient")
    ap.add_argument(
        "--out",
        default=os.path.join(REPO_ROOT, "BENCH_compress.json"),
        help="output path (default: repo-root BENCH_compress.json)",
    )
    args = ap.parse_args()

    compressors = run_bench("micro_compressors", args.n)
    collectives = run_bench("micro_collectives", args.n)

    speedups = compressors.get("speedups", {})
    gates = {
        "pack_ge_4x": speedups.get("pack_4b", 0.0) >= 4.0
        and speedups.get("pack_8b", 0.0) >= 4.0,
        "unpack_ge_4x": speedups.get("unpack_4b", 0.0) >= 4.0
        and speedups.get("unpack_8b", 0.0) >= 4.0,
        "fused_qsgd_mn_4_faster": speedups.get("fused_qsgd_mn_4", 0.0) > 1.0,
    }

    report = {
        "schema": "repro-bench-compress-v1",
        "generated_unix": int(time.time()),
        "machine": {
            "platform": platform.platform(),
            "cpu_count": os.cpu_count(),
        },
        "speedups": speedups,
        "gates": gates,
        "micro_compressors": compressors,
        "micro_collectives": collectives,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")
    for k, ok in gates.items():
        print(f"  {k}: {'PASS' if ok else 'FAIL'}")
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    sys.exit(main())
