#!/usr/bin/env python3
"""Run the compression micro-benches and emit BENCH_compress.json.

Runs `cargo bench --bench micro_compressors` and `--bench micro_collectives`
(release profile, custom harness) with REPRO_BENCH_JSON pointed at temp
files, merges the two reports, and writes `BENCH_compress.json` at the repo
root so the perf trajectory is tracked from this PR onward. Also runs
`--bench micro_overlap` (the PR 4 bucketed control plane's overlap gate,
-> `BENCH_overlap.json`), `--bench micro_faults` (the PR 6 straggler
scenario: strict-sync vs timeout-into-partial under seeded jitter,
-> `BENCH_faults.json`), and `--bench micro_integrity` (the PR 7
self-healing gates: <= 2% checksum overhead and retransmit-recovery
cheaper than a full-step redo, -> `BENCH_integrity.json`), and
`--bench micro_hierarchy` (the PR 8 two-level collective gate: hier <= flat
simulated comm time on the paper topology at 2/4 bits,
-> `BENCH_hierarchy.json`), and `--bench micro_trace` (the PR 9 flight
recorder gates: armed tracer <= 3% wall overhead, bit-identical output and
ledgers, clean audit, -> `BENCH_trace.json`).

Usage:
    python3 tools/bench_compress.py [--n COORDS] [--out PATH]
        [--out-overlap PATH] [--out-faults PATH] [--out-integrity PATH]
        [--out-hierarchy PATH] [--out-trace PATH]

The acceptance gates this file evidences (ISSUE 1):
  * >= 4x throughput on pack/unpack vs the scalar reference;
  * a measured speedup on the fused QSGD-MN-4 encode->allreduce->decode
    step vs the seed f32-level path, same machine, same run.

Plus the ISSUE 10 SIMD gate (`simd_encode_ge_2x`): when micro_compressors
reports a runtime vector backend (`simd.vector_available`), the vectorized
QSGD level kernel must clear >= 2x GB/s over the pinned scalar fallback
(`speedups.simd_qsgd_encode_int`). On scalar-only machines — or under
REPRO_FORCE_SCALAR — the gate passes vacuously and the report records that
no vector backend was exercised.
"""

import argparse
import json
import os
import platform
import subprocess
import sys
import tempfile
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUST_DIR = os.path.join(REPO_ROOT, "rust")


def run_bench(name: str, n: int | None, required: bool = True) -> tuple[dict, int]:
    """Run one custom-harness bench; returns (report, exit code).

    With required=True a nonzero exit raises (the PR 1 benches must
    complete to produce their speedup report). With required=False the
    report is still salvaged when the bench wrote its JSON before failing
    a hard gate (micro_overlap asserts *after* emitting entries), so a
    gate failure downgrades to a FAIL row instead of a traceback.
    """
    fd, path = tempfile.mkstemp(prefix=f"repro_{name}_", suffix=".json")
    os.close(fd)
    env = dict(os.environ, REPRO_BENCH_JSON=path)
    if n is not None:
        env["REPRO_BENCH_N"] = str(n)
    try:
        proc = subprocess.run(
            ["cargo", "bench", "--bench", name],
            cwd=RUST_DIR,
            env=env,
            check=False,
        )
        if proc.returncode != 0 and required:
            raise subprocess.CalledProcessError(
                proc.returncode, proc.args
            )
        try:
            with open(path) as f:
                return json.load(f), proc.returncode
        except (FileNotFoundError, json.JSONDecodeError):
            return {}, proc.returncode
    finally:
        if os.path.exists(path):
            os.unlink(path)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=None, help="coordinates per gradient")
    ap.add_argument(
        "--out",
        default=os.path.join(REPO_ROOT, "BENCH_compress.json"),
        help="output path (default: repo-root BENCH_compress.json)",
    )
    ap.add_argument(
        "--out-overlap",
        default=os.path.join(REPO_ROOT, "BENCH_overlap.json"),
        help="overlap report path (default: repo-root BENCH_overlap.json)",
    )
    ap.add_argument(
        "--out-faults",
        default=os.path.join(REPO_ROOT, "BENCH_faults.json"),
        help="straggler report path (default: repo-root BENCH_faults.json)",
    )
    ap.add_argument(
        "--out-hierarchy",
        default=os.path.join(REPO_ROOT, "BENCH_hierarchy.json"),
        help="hierarchy report path (default: repo-root BENCH_hierarchy.json)",
    )
    ap.add_argument(
        "--out-integrity",
        default=os.path.join(REPO_ROOT, "BENCH_integrity.json"),
        help="integrity report path (default: repo-root BENCH_integrity.json)",
    )
    ap.add_argument(
        "--out-trace",
        default=os.path.join(REPO_ROOT, "BENCH_trace.json"),
        help="flight-recorder report path (default: repo-root BENCH_trace.json)",
    )
    args = ap.parse_args()

    compressors, _ = run_bench("micro_compressors", args.n)
    collectives, _ = run_bench("micro_collectives", args.n)

    speedups = compressors.get("speedups", {})
    simd_info = compressors.get("simd", {})
    simd_vector = simd_info.get("vector_available", 0.0) == 1.0
    gates = {
        "pack_ge_4x": speedups.get("pack_4b", 0.0) >= 4.0
        and speedups.get("pack_8b", 0.0) >= 4.0,
        "unpack_ge_4x": speedups.get("unpack_4b", 0.0) >= 4.0
        and speedups.get("unpack_8b", 0.0) >= 4.0,
        "fused_qsgd_mn_4_faster": speedups.get("fused_qsgd_mn_4", 0.0) > 1.0,
        # ISSUE 10: vectorized level kernel >= 2x over the scalar fallback;
        # vacuous when no runtime vector backend exists (scalar-only host or
        # REPRO_FORCE_SCALAR) — the bench also asserts this in-process.
        "simd_encode_ge_2x": (not simd_vector)
        or speedups.get("simd_qsgd_encode_int", 0.0) >= 2.0,
    }

    report = {
        "schema": "repro-bench-compress-v1",
        "generated_unix": int(time.time()),
        "machine": {
            "platform": platform.platform(),
            "cpu_count": os.cpu_count(),
        },
        "speedups": speedups,
        "simd": simd_info,
        "gates": gates,
        "micro_compressors": compressors,
        "micro_collectives": collectives,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")

    # Overlap bench LAST and non-required: its hard gate asserts after
    # emitting JSON, so BENCH_compress.json above is always written and a
    # gate failure is salvaged into a FAIL row here instead of a traceback.
    # (micro_overlap sizes itself; forward only an explicit --n override.)
    overlap, overlap_rc = run_bench("micro_overlap", args.n, required=False)

    # overlap gate: bucketed-with-overlap <= monolithic everywhere
    overlap_gate = (
        overlap_rc == 0
        and bool(overlap.get("entries"))
        and all(e.get("gate_pass", 0.0) == 1.0 for e in overlap.get("entries", []))
    )
    overlap_report = {
        "schema": "repro-bench-overlap-v1",
        "generated_unix": report["generated_unix"],
        "machine": report["machine"],
        "gates": {"bucketed_le_monolithic": overlap_gate},
        "micro_overlap": overlap,
    }
    with open(args.out_overlap, "w") as f:
        json.dump(overlap_report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out_overlap}")

    # Straggler bench, same non-required pattern: micro_faults asserts its
    # hard gate after emitting JSON, so a regression shows up as a FAIL row.
    faults, faults_rc = run_bench("micro_faults", args.n, required=False)

    # fault gate: partial == strict at jitter 0, partial < strict at >= 10%
    faults_gate = (
        faults_rc == 0
        and bool(faults.get("entries"))
        and all(e.get("gate_pass", 0.0) == 1.0 for e in faults.get("entries", []))
    )
    faults_report = {
        "schema": "repro-bench-faults-v1",
        "generated_unix": report["generated_unix"],
        "machine": report["machine"],
        "gates": {"partial_beats_strict_under_jitter": faults_gate},
        "micro_faults": faults,
    }
    with open(args.out_faults, "w") as f:
        json.dump(faults_report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out_faults}")

    # Integrity bench, same non-required pattern: micro_integrity asserts
    # its hard gates after emitting JSON. (It sizes itself at n=2^20;
    # forward only an explicit --n override.)
    integrity, integrity_rc = run_bench("micro_integrity", args.n, required=False)

    # integrity gates: <= 2% checksum overhead with bit-equal output, and
    # retransmit recovery cheaper than redoing the whole collective
    integrity_gate = (
        integrity_rc == 0
        and integrity.get("gate_overhead_pass", 0.0) == 1.0
        and integrity.get("gate_recovery_pass", 0.0) == 1.0
    )
    integrity_report = {
        "schema": "repro-bench-integrity-v1",
        "generated_unix": report["generated_unix"],
        "machine": report["machine"],
        "gates": {"checksum_cheap_and_recovery_beats_redo": integrity_gate},
        "micro_integrity": integrity,
    }
    with open(args.out_integrity, "w") as f:
        json.dump(integrity_report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out_integrity}")

    # Hierarchy bench, same non-required pattern: micro_hierarchy asserts
    # its hard gate after emitting JSON. (It sizes itself at n=2^20;
    # forward only an explicit --n override.)
    hierarchy, hierarchy_rc = run_bench("micro_hierarchy", args.n, required=False)

    # hierarchy gate: two-level schedule <= flat ring on simulated comm
    # time at every width, with the per-level hop-bit split intact
    hierarchy_gate = (
        hierarchy_rc == 0
        and bool(hierarchy.get("entries"))
        and all(e.get("gate_pass", 0.0) == 1.0 for e in hierarchy.get("entries", []))
    )
    hierarchy_report = {
        "schema": "repro-bench-hierarchy-v1",
        "generated_unix": report["generated_unix"],
        "machine": report["machine"],
        "gates": {"hier_le_flat_on_paper_topology": hierarchy_gate},
        "micro_hierarchy": hierarchy,
    }
    with open(args.out_hierarchy, "w") as f:
        json.dump(hierarchy_report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out_hierarchy}")

    # Flight-recorder bench, same non-required pattern: micro_trace asserts
    # its hard gates after emitting JSON. (It sizes itself at n=2^20;
    # forward only an explicit --n override.)
    trace, trace_rc = run_bench("micro_trace", args.n, required=False)

    # trace gates: armed recorder adds <= 3% wall time and stays inert
    # (bit-identical output + all twelve ledgers, zero audit violations)
    trace_gate = (
        trace_rc == 0
        and trace.get("gate_overhead_pass", 0.0) == 1.0
        and trace.get("gate_parity_pass", 0.0) == 1.0
    )
    trace_report = {
        "schema": "repro-bench-trace-v1",
        "generated_unix": report["generated_unix"],
        "machine": report["machine"],
        "gates": {"trace_overhead_le_3pct_and_inert": trace_gate},
        "micro_trace": trace,
    }
    with open(args.out_trace, "w") as f:
        json.dump(trace_report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out_trace}")

    gates["bucketed_le_monolithic"] = overlap_gate
    gates["partial_beats_strict_under_jitter"] = faults_gate
    gates["checksum_cheap_and_recovery_beats_redo"] = integrity_gate
    gates["hier_le_flat_on_paper_topology"] = hierarchy_gate
    gates["trace_overhead_le_3pct_and_inert"] = trace_gate
    for k, ok in gates.items():
        print(f"  {k}: {'PASS' if ok else 'FAIL'}")
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    sys.exit(main())
