#!/usr/bin/env python3
"""ASCII plotter for the training curves in results/*.csv.

The paper's figures are loss/accuracy vs epoch line plots; this renders the
same series in the terminal so runs can be compared without matplotlib:

    python tools/plot_results.py results/fig3_4_resnet_lite_*.csv
    python tools/plot_results.py --col loss --smooth 5 results/train_*.csv

Columns available: loss, lr, t_compute, t_encode, t_decode, t_comm_sim,
bits_per_worker (see rust/src/train/mod.rs CSV header).
"""

from __future__ import annotations

import argparse
import csv
import math
import os
import sys

WIDTH = 78
HEIGHT = 22
MARKS = "ox+*#@%&"


def load(path: str, col: str) -> list[float]:
    with open(path) as f:
        reader = csv.DictReader(f)
        return [float(row[col]) for row in reader]


def smooth(ys: list[float], k: int) -> list[float]:
    if k <= 1:
        return ys
    out = []
    for i in range(len(ys)):
        lo = max(0, i - k + 1)
        out.append(sum(ys[lo : i + 1]) / (i + 1 - lo))
    return out


def render(series: dict[str, list[float]], col: str, logy: bool) -> str:
    all_vals = [v for ys in series.values() for v in ys if math.isfinite(v)]
    if not all_vals:
        return "(no finite data)"
    lo, hi = min(all_vals), max(all_vals)
    if logy:
        floor = min(v for v in all_vals if v > 0) if any(v > 0 for v in all_vals) else 1e-9
        f = lambda v: math.log10(max(v, floor))
        lo, hi = f(lo if lo > 0 else floor), f(hi)
    else:
        f = float
    if hi <= lo:
        hi = lo + 1e-9
    max_len = max(len(ys) for ys in series.values())

    grid = [[" "] * WIDTH for _ in range(HEIGHT)]
    for si, (_name, ys) in enumerate(series.items()):
        mark = MARKS[si % len(MARKS)]
        for i, v in enumerate(ys):
            if not math.isfinite(v):
                continue
            x = int(i * (WIDTH - 1) / max(1, max_len - 1))
            y = int((f(v) - lo) / (hi - lo) * (HEIGHT - 1))
            grid[HEIGHT - 1 - y][x] = mark

    top = 10 ** hi if logy else hi
    bot = 10 ** lo if logy else lo
    lines = [f"{col}{' (log)' if logy else ''}   top={top:.4g}  bottom={bot:.4g}"]
    lines += ["|" + "".join(row) + "|" for row in grid]
    lines.append("+" + "-" * WIDTH + "+")
    lines.append(f" step 0 {' ' * (WIDTH - 16)} step {max_len - 1}")
    for si, name in enumerate(series):
        lines.append(f"  {MARKS[si % len(MARKS)]} {name}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--col", default="loss")
    ap.add_argument("--smooth", type=int, default=1, help="trailing-mean window")
    ap.add_argument("--log", action="store_true", help="log-scale y axis")
    args = ap.parse_args()

    series: dict[str, list[float]] = {}
    for path in args.files:
        if not os.path.exists(path):
            print(f"skip missing {path}", file=sys.stderr)
            continue
        name = os.path.basename(path).removesuffix(".csv")
        try:
            series[name] = smooth(load(path, args.col), args.smooth)
        except KeyError:
            print(f"skip {path}: no column '{args.col}'", file=sys.stderr)
    if not series:
        sys.exit("no data")
    print(render(series, args.col, args.log))


if __name__ == "__main__":
    main()
