#!/usr/bin/env python3
"""Render (and optionally validate) a flight-recorder trace.

The Rust side (`repro train ... --trace PATH`, see `rust/src/trace/`) emits
either of two formats, autodetected here:

  * Chrome trace-event JSON (default, any extension but `.jsonl`): an object
    with `traceEvents` + a `reproTotals` footer. Loadable as-is in
    `chrome://tracing` or https://ui.perfetto.dev — this tool prints the
    time/bit breakdown table without a browser.
  * JSON lines (`.jsonl`): one `meta` line, one `step` line per training
    step (flattened SimClock delta + per-category span sums), one `run`
    footer with totals.

Usage:
    python3 tools/trace_report.py results/train.trace.json
    python3 tools/trace_report.py results/train.trace.jsonl
    python3 tools/trace_report.py results/hier.trace.json --check

`--check` re-validates the recorder's structural invariants from the
artifact alone (used by CI on the traced hier+faults run):

  * Chrome: every (pid, tid) track's complete events are monotone and
    non-overlapping; the per-level wire tracks reconcile with the
    `hop_bits_intra` / `hop_bits_inter` / `retrans_bits` run totals; the
    in-run ledger audit reported zero violations.
  * JSONL: per-step `hop_bits_intra + hop_bits_inter == hop_bits_per_worker`,
    per-category span sums match the step deltas, step deltas sum to the
    run footer, zero violations.

Exit status: 0 ok, 1 check failed, 2 bad input. Stdlib only.
"""

import argparse
import json
import sys

CLOCK_KEYS = [
    "comm_s", "compute_s", "encode_s", "decode_s",
    "bits_per_worker", "hop_bits_per_worker", "hop_bits_intra",
    "hop_bits_inter", "hidden_comm_s", "straggler_wait_s",
    "retrans_s", "retrans_bits",
]
TIME_CATS = [
    ("comm_s", "comm"), ("compute_s", "compute"), ("encode_s", "encode"),
    ("decode_s", "decode"), ("straggler_wait_s", "straggler wait"),
    ("retrans_s", "retransmit"),
]


def close(a, b, scale=1.0):
    return abs(a - b) <= 1e-9 * max(abs(a), abs(b), abs(scale), 1e-12)


def load(path):
    """Returns ("chrome", dict) or ("jsonl", list-of-dicts)."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
        if isinstance(doc, dict) and "traceEvents" in doc:
            return "chrome", doc
    except json.JSONDecodeError:
        pass
    lines = []
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        try:
            lines.append(json.loads(line))
        except json.JSONDecodeError as e:
            sys.exit(f"error: {path}:{i + 1}: neither Chrome JSON nor JSONL: {e}")
    if not lines:
        sys.exit(f"error: {path}: empty trace")
    return "jsonl", lines


def totals_of(fmt, doc):
    if fmt == "chrome":
        tot = doc.get("reproTotals")
        if tot is None:
            sys.exit("error: Chrome trace has no reproTotals footer")
        return tot
    runs = [l for l in doc if l.get("type") == "run"]
    if not runs:
        sys.exit("error: JSONL trace has no run footer")
    return runs[-1]


def fmt_bits(b):
    for unit, scale in [("Gbit", 1e9), ("Mbit", 1e6), ("kbit", 1e3)]:
        if abs(b) >= scale:
            return f"{b / scale:.3f} {unit}"
    return f"{b:.0f} bit"


def report(fmt, doc, path):
    tot = totals_of(fmt, doc)
    total_s = (sum(tot[k] for k, _ in TIME_CATS) - tot["hidden_comm_s"])
    print(f"{path}  [{fmt}]  steps={tot['steps']:.0f}  "
          f"violations={tot['violations']:.0f}")
    print()
    print(f"  {'phase':<16} {'seconds':>12} {'share':>7}")
    print("  " + "-" * 37)
    for key, label in TIME_CATS:
        share = tot[key] / total_s if total_s > 0 else 0.0
        print(f"  {label:<16} {tot[key]:>12.6f} {share:>6.1%}")
    print(f"  {'hidden (comm)':<16} {-tot['hidden_comm_s']:>12.6f} "
          f"{(-tot['hidden_comm_s'] / total_s if total_s > 0 else 0.0):>6.1%}")
    print("  " + "-" * 37)
    print(f"  {'critical path':<16} {total_s:>12.6f} {1:>6.1%}")
    ovl = tot["hidden_comm_s"] / tot["comm_s"] if tot["comm_s"] > 0 else 0.0
    print()
    print(f"  payload        {fmt_bits(tot['bits_per_worker'])} per worker")
    print(f"  wire hops      {fmt_bits(tot['hop_bits_per_worker'])} per worker "
          f"(intra {fmt_bits(tot['hop_bits_intra'])}, "
          f"inter {fmt_bits(tot['hop_bits_inter'])})")
    print(f"  retransmitted  {fmt_bits(tot['retrans_bits'])}")
    print(f"  overlap        {ovl:.1%} of comm hidden behind compute")

    if fmt == "chrome":
        attempts = {}
        for e in doc["traceEvents"]:
            if e.get("ph") == "X" and e.get("name") == "retransmit":
                a = int(e["args"]["attempt"])
                attempts[a] = attempts.get(a, 0) + 1
        if attempts:
            ladder = "  ".join(f"attempt {a}: {attempts[a]}"
                               for a in sorted(attempts))
            print(f"  retry ladder   {ladder}")
    else:
        rtx = sum(l.get("retransmits", 0) for l in doc if l.get("type") == "step")
        if rtx:
            print(f"  retransmits    {rtx:.0f} hop segments across the run")


def check_chrome(doc):
    errors = []
    tot = totals_of("chrome", doc)
    if tot["violations"] != 0:
        errors.append(f"ledger audit recorded {tot['violations']:.0f} violations")
    last_end = {}
    wire = {("hop", 0): 0.0, ("checksum", 0): 0.0,
            ("hop", 1): 0.0, ("checksum", 1): 0.0}
    rtx_bits = 0.0
    for e in doc["traceEvents"]:
        if e.get("ph") != "X":
            continue
        key = (e["pid"], e["tid"])
        ts, dur = e["ts"], e["dur"]
        if dur < 0:
            errors.append(f"track {key}: negative duration at ts={ts}")
        prev = last_end.get(key)
        # 1e-3 us of slack: ts values round-trip through decimal text
        if prev is not None and ts + 1e-3 < prev:
            errors.append(
                f"track {key}: event '{e['name']}' at {ts}us overlaps "
                f"previous end {prev}us")
        last_end[key] = ts + dur if prev is None else max(prev, ts + dur)
        if e["pid"] == 1:
            bits = e["args"]["wire_bits"]
            if e["name"] == "retransmit":
                rtx_bits += bits
            elif (e["name"], e["tid"]) in wire:
                wire[(e["name"], e["tid"])] += bits
            else:
                errors.append(f"unexpected wire-track event {e['name']!r}")
    intra = wire[("hop", 0)] + wire[("checksum", 0)]
    inter = wire[("hop", 1)] + wire[("checksum", 1)]
    for got, key in [(intra, "hop_bits_intra"), (inter, "hop_bits_inter"),
                     (rtx_bits, "retrans_bits"),
                     (intra + inter, "hop_bits_per_worker")]:
        if not close(got, tot[key]):
            errors.append(f"wire tracks carry {got:.0f} bits but "
                          f"reproTotals.{key} = {tot[key]:.0f}")
    return errors


def check_jsonl(doc):
    errors = []
    if doc[0].get("type") != "meta":
        errors.append("first line is not a meta record")
    steps = [l for l in doc if l.get("type") == "step"]
    tot = totals_of("jsonl", doc)
    if not steps:
        errors.append("no step records")
    sums = {k: 0.0 for k in CLOCK_KEYS}
    for l in steps:
        sid = l.get("step")
        if l.get("violations", 0) != 0:
            errors.append(f"step {sid}: {l['violations']:.0f} audit violations")
        if not close(l["hop_bits_intra"] + l["hop_bits_inter"],
                     l["hop_bits_per_worker"]):
            errors.append(f"step {sid}: per-level hop bits do not sum")
        for key, cat in [("comm_s", "comm"), ("encode_s", "encode"),
                         ("decode_s", "decode"), ("compute_s", "compute"),
                         ("straggler_wait_s", "straggler_wait"),
                         ("retrans_s", "retrans"),
                         ("hidden_comm_s", "hidden_comm")]:
            if not close(l["span_s"][cat], l[key]):
                errors.append(f"step {sid}: span sum for {cat} "
                              f"({l['span_s'][cat]}) != delta ({l[key]})")
        for k in CLOCK_KEYS:
            sums[k] += l[k]
    for k in CLOCK_KEYS:
        if not close(sums[k], tot[k]):
            errors.append(f"run.{k} = {tot[k]} but steps sum to {sums[k]}")
    if tot.get("violations", 0) != 0:
        errors.append(f"run footer reports {tot['violations']:.0f} violations")
    if tot.get("steps") != len(steps):
        errors.append(f"run footer reports {tot.get('steps')} steps, "
                      f"file has {len(steps)}")
    return errors


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", help="trace file (.json Chrome form or .jsonl)")
    ap.add_argument("--check", action="store_true",
                    help="validate structural invariants; nonzero exit on failure")
    args = ap.parse_args()

    fmt, doc = load(args.trace)
    report(fmt, doc, args.trace)
    if args.check:
        errors = check_chrome(doc) if fmt == "chrome" else check_jsonl(doc)
        print()
        if errors:
            for e in errors:
                print(f"CHECK FAIL: {e}", file=sys.stderr)
            sys.exit(1)
        print(f"check ok: {fmt} trace is internally consistent")


if __name__ == "__main__":
    main()
