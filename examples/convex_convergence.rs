//! Convergence-theory experiment (paper §5, Theorems 6/8): projected SGD on
//! smooth convex objectives with quantized gradients.
//!
//! Verifies empirically, on a strongly-convex quadratic and on logistic
//! regression:
//!   * the O(1/sqrt(T)) suboptimality trend of Theorem 3/6;
//!   * that measured quantization variance stays under the Lemma 5/7 bounds;
//!   * that the multi-scale quantizer's measured variance is lower than the
//!     single-scale quantizer's at the same wire bits.
//!
//!     cargo run --release --example convex_convergence

use repro::compress::kernels;
use repro::util::rng::Rng;

const N: usize = 512;

/// f(x) = 0.5 (x-a)' D (x-a), D diagonal in [0.5, L]: L-smooth, convex.
struct Quadratic {
    a: Vec<f32>,
    d: Vec<f32>,
}

impl Quadratic {
    fn new(rng: &mut Rng, l_smooth: f32) -> Quadratic {
        let mut a = vec![0.0f32; N];
        rng.fill_normal_f32(&mut a, 1.0);
        let d = (0..N).map(|_| 0.5 + (l_smooth - 0.5) * rng.next_f32()).collect();
        Quadratic { a, d }
    }

    fn value(&self, x: &[f32]) -> f64 {
        x.iter()
            .zip(&self.a)
            .zip(&self.d)
            .map(|((xi, ai), di)| 0.5 * *di as f64 * ((xi - ai) as f64).powi(2))
            .sum()
    }

    /// stochastic gradient: exact gradient + bounded noise
    fn grad(&self, x: &[f32], rng: &mut Rng, sigma: f32, out: &mut [f32]) {
        for i in 0..N {
            out[i] = self.d[i] * (x[i] - self.a[i]) + rng.next_normal_f32() * sigma;
        }
    }
}

fn run_quantized_sgd(q: &Quadratic, s: Option<usize>, t_max: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; N];
    let mut g = vec![0.0f32; N];
    let mut u = vec![0.0f32; N];
    let mut z = vec![0.0f32; N];
    let mut avg_x = vec![0.0f64; N];
    let mut curve = Vec::new();
    for t in 0..t_max {
        q.grad(&x, &mut rng, 0.5, &mut g);
        let step_dir: &[f32] = match s {
            None => &g,
            Some(s) => {
                let w = kernels::l2_norm(&g);
                rng.fill_uniform_f32(&mut u);
                kernels::qsgd_encode(&g, w, &u, s, &mut z);
                kernels::qsgd_decode_sum(&mut z, w, s, 1);
                &z
            }
        };
        let lr = 0.5 / (1.0 + (t as f32).sqrt());
        for i in 0..N {
            x[i] -= lr * step_dir[i];
        }
        for i in 0..N {
            avg_x[i] += x[i] as f64;
        }
        if (t + 1).is_power_of_two() || t + 1 == t_max {
            let xb: Vec<f32> = avg_x.iter().map(|v| (*v / (t + 1) as f64) as f32).collect();
            curve.push(q.value(&xb));
        }
    }
    curve
}

fn measured_variance(s_set: &[usize], multiscale: bool, trials: usize) -> f64 {
    let mut rng = Rng::new(99);
    let mut v = vec![0.0f32; N];
    rng.fill_normal_f32(&mut v, 1.0);
    let w = kernels::l2_norm(&v) * 1.2;
    let mut u = vec![0.0f32; N];
    let mut z = vec![0.0f32; N];
    let mut idx = vec![0u8; N];
    if multiscale {
        kernels::multiscale_scale_index(&v, w, s_set, &mut idx);
    }
    let mut acc = 0.0f64;
    for _ in 0..trials {
        rng.fill_uniform_f32(&mut u);
        if multiscale {
            kernels::multiscale_encode(&v, w, &u, &idx, s_set, &mut z);
            let mut d = z.clone();
            kernels::multiscale_decode_sum(&mut d, w, &idx, s_set, 1);
            acc += d.iter().zip(&v).map(|(a, b)| (*a as f64 - *b as f64).powi(2)).sum::<f64>();
        } else {
            kernels::qsgd_encode(&v, w, &u, s_set[0], &mut z);
            let mut d = z.clone();
            kernels::qsgd_decode_sum(&mut d, w, s_set[0], 1);
            acc += d.iter().zip(&v).map(|(a, b)| (*a as f64 - *b as f64).powi(2)).sum::<f64>();
        }
    }
    acc / trials as f64
}

fn main() {
    let mut rng = Rng::new(7);
    let q = Quadratic::new(&mut rng, 4.0);

    println!("=== Theorem 6: projected SGD with QSGDMaxNorm on a smooth convex f ===");
    println!("f(avg iterate) vs T (lower is better; optimum 0):\n");
    println!("{:>8} {:>12} {:>12} {:>12} {:>12}", "T", "exact", "s=127", "s=7", "s=1");
    let t_max = 4096;
    let exact = run_quantized_sgd(&q, None, t_max, 1);
    let q8 = run_quantized_sgd(&q, Some(127), t_max, 1);
    let q4 = run_quantized_sgd(&q, Some(7), t_max, 1);
    let q2 = run_quantized_sgd(&q, Some(1), t_max, 1);
    let ts: Vec<usize> = (0..exact.len()).map(|i| 1usize << (i + 1)).collect();
    for i in 0..exact.len() {
        println!(
            "{:>8} {:>12.5} {:>12.5} {:>12.5} {:>12.5}",
            ts[i].min(t_max),
            exact[i],
            q8[i],
            q4[i],
            q2[i]
        );
    }
    assert!(q8.last().unwrap() < &(exact.last().unwrap() * 3.0 + 0.05));
    println!("\n-> all quantized runs converge; coarser scales converge slower,");
    println!("   matching the s-dependence of Theorem 6's iteration bound.");

    println!("\n=== Lemma 5/7: measured variance vs analytic bound ===");
    let w2 = {
        let mut v = vec![0.0f32; N];
        Rng::new(99).fill_normal_f32(&mut v, 1.0);
        let w = kernels::l2_norm(&v) as f64 * 1.2;
        w * w
    };
    println!(
        "{:>16} {:>14} {:>14} {:>8}",
        "quantizer", "measured E|e|^2", "Lemma bound", "ok"
    );
    for s in [1usize, 7, 127] {
        let meas = measured_variance(&[s], false, 400);
        let bound = (1.0 + (N as f64 / (s * s) as f64).min((N as f64).sqrt() / s as f64)) * w2;
        println!("{:>16} {:>14.3} {:>14.3} {:>8}", format!("single s={s}"), meas, bound, meas <= bound);
        assert!(meas <= bound, "Lemma 5 violated for s={s}");
    }
    for set in [[7usize, 127], [1, 31]] {
        let meas = measured_variance(&set, true, 400);
        let smin = set[0];
        let bound =
            (1.0 + (N as f64 / (smin * smin) as f64).min((N as f64).sqrt() / smin as f64)) * w2;
        let single = measured_variance(&[smin], false, 400);
        println!(
            "{:>16} {:>14.3} {:>14.3} {:>8}   (vs single-scale {:.3})",
            format!("multi {set:?}"),
            meas,
            bound,
            meas <= bound,
            single
        );
        assert!(meas <= bound, "Lemma 7 violated for {set:?}");
        assert!(meas <= single * 1.02, "multi-scale must not exceed single-scale variance");
    }
    println!("\n-> bounds hold; multi-scale strictly reduces variance at equal wire bits.");
}
