//! Distributed CIFAR-like training — the Figures 1/2 workload as a runnable
//! example: trains the computation-intensive (resnet_lite) and
//! communication-intensive (vgg_lite) models with a configurable compression
//! method across 4 simulated workers, logging loss/accuracy curves to
//! `results/`.
//!
//!     cargo run --release --example distributed_cifar -- \
//!         [--model resnet_lite] [--method qsgd-mn-4] [--steps 150] \
//!         [--workers 4] [--lr 0.05] [--compare]
//!
//! `--compare` runs the method against the AllReduce-SGD baseline and
//! PowerSGD rank-2 and prints the head-to-head table.

use repro::cli::Args;
use repro::compress::Method;
use repro::runtime::Artifacts;
use repro::train::{summary_table, Experiment};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--"))?;
    let model = args.get_or("model", "resnet_lite").to_string();
    let method = args.get_or("method", "qsgd-mn-4").to_string();
    let steps: usize = args.parse_or("steps", 150)?;
    let workers: usize = args.parse_or("workers", 4)?;
    let lr: f64 = args.parse_or("lr", 0.05)?;
    let compare = args.flag("compare");
    args.reject_unknown()?;

    let arts = Artifacts::load_default()?;
    let methods = if compare {
        vec![
            Method::parse("allreduce")?,
            Method::parse(&method)?,
            Method::parse("powersgd-2")?,
        ]
    } else {
        vec![Method::parse(&method)?]
    };

    let mut exp = Experiment::new("distributed_cifar", &model, methods);
    exp.steps = steps;
    exp.workers = workers;
    exp.lr0 = lr;

    let results = exp.run(&arts)?;
    let summaries: Vec<_> = results.into_iter().map(|(_, s)| s).collect();
    println!("\n{}", summary_table(&summaries));
    println!("loss curves written to results/distributed_cifar_*.csv");
    Ok(())
}
