//! Distributed CIFAR-like training — the Figures 1/2 workload as a runnable
//! example: trains the computation-intensive (resnet_lite) and
//! communication-intensive (vgg_lite) models with a configurable compression
//! method across 4 simulated workers, logging loss/accuracy curves to
//! `results/`.
//!
//!     cargo run --release --example distributed_cifar -- \
//!         [--model resnet_lite] [--method qsgd-mn-4] [--steps 150] \
//!         [--workers 4] [--lr 0.05] [--compare] [--buckets 8]
//!
//! `--compare` runs the method against the AllReduce-SGD baseline and
//! PowerSGD rank-2 and prints the head-to-head table.
//!
//! For every all-reduce-compatible quantizer (qsgd-mn-*, qsgd-mn-ts-*,
//! grandk-mn-*, grandk-mn-ts-*) the example then re-runs the same training
//! through the bucketed gradient control plane (`--buckets`, default 8,
//! with variance-adaptive precision, plus error feedback on the dense
//! methods) and prints the monolithic-vs-bucketed overlap_frac / wire-bits
//! comparison.

use repro::cli::Args;
use repro::compress::Method;
use repro::control::{BitsPolicy, ControlConfig};
use repro::metrics::render_table;
use repro::runtime::Artifacts;
use repro::train::{summary_table, Experiment};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--"))?;
    let model = args.get_or("model", "resnet_lite").to_string();
    let method = args.get_or("method", "qsgd-mn-4").to_string();
    let steps: usize = args.parse_or("steps", 150)?;
    let workers: usize = args.parse_or("workers", 4)?;
    let lr: f64 = args.parse_or("lr", 0.05)?;
    let buckets: usize = args.parse_or("buckets", 8)?;
    let compare = args.flag("compare");
    args.reject_unknown()?;

    let arts = Artifacts::load_default()?;
    let methods = if compare {
        vec![
            Method::parse("allreduce")?,
            Method::parse(&method)?,
            Method::parse("powersgd-2")?,
        ]
    } else {
        vec![Method::parse(&method)?]
    };

    let mut exp = Experiment::new("distributed_cifar", &model, methods);
    exp.steps = steps;
    exp.workers = workers;
    exp.lr0 = lr;

    let results = exp.run(&arts)?;
    let summaries: Vec<_> = results.into_iter().map(|(_, s)| s).collect();
    println!("\n{}", summary_table(&summaries));

    // bucketed control plane head-to-head: same method, same seed/schedule,
    // but DDP-style layer buckets + variance-adaptive precision + backward/
    // comm overlap (+ error feedback where the domain is dense — a GlobalK
    // residual would live on coordinates the wire never carries).
    let parsed = Method::parse(&method)?;
    let bucketable = matches!(
        parsed,
        Method::Qsgd { .. } | Method::QsgdTs { .. } | Method::RandK { .. } | Method::RandKTs { .. }
    );
    if bucketable {
        let dense = matches!(parsed, Method::Qsgd { .. } | Method::QsgdTs { .. });
        let mut cfg = ControlConfig::new(buckets);
        // auto precision where it can actually adapt; a maximal-span TS set
        // pins the small scale, so fall back to the method's fixed widths
        // (build_plane rejects a headroom-less auto loudly)
        let auto = repro::control::auto_can_adapt(&parsed);
        cfg.bits = if auto { BitsPolicy::Auto } else { BitsPolicy::Fixed(None) };
        cfg.error_feedback = dense;
        let mono_label = parsed.label();
        let mut bexp =
            Experiment::new("distributed_cifar_bucketed", &model, vec![parsed.clone()]);
        bexp.steps = steps;
        bexp.workers = workers;
        bexp.lr0 = lr;
        bexp.control = Some(cfg);
        let bresults = bexp.run(&arts)?;
        let mono = summaries
            .iter()
            .find(|s| s.label == mono_label)
            .expect("monolithic summary");
        let bucketed = &bresults[0].1;
        println!("\n=== monolithic vs bucketed control plane ({model}, M={workers}) ===");
        let rows = vec![
            vec![
                "monolithic".into(),
                mono.label.clone(),
                format!("{:.2}", mono.overlap_frac),
                format!("{:.1}", mono.mean_bits_per_step / 1e3),
                format!("{:.3}", mono.sim_time_s),
                format!("{:.4}", mono.final_loss),
            ],
            vec![
                format!(
                    "bucketed x{buckets} ({}{})",
                    if auto { "auto" } else { "fixed" },
                    if dense { "+EF" } else { "" }
                ),
                bucketed.label.clone(),
                format!("{:.2}", bucketed.overlap_frac),
                format!("{:.1}", bucketed.mean_bits_per_step / 1e3),
                format!("{:.3}", bucketed.sim_time_s),
                format!("{:.4}", bucketed.final_loss),
            ],
        ];
        println!(
            "{}",
            render_table(
                &["plane", "method", "overlap_frac", "kbits/step", "sim_s", "train_loss"],
                &rows
            )
        );
    }

    println!("loss curves written to results/distributed_cifar_*.csv");
    Ok(())
}
