//! Scalability study (paper §1 + §6.6): why all-reduce compatibility is the
//! point. Prints (a) the §6.6 analytical throughput projections for the
//! paper's 32-node × 4-V100 cluster (Figures 11–14) and (b) the
//! all-reduce-vs-all-gather communication-time scaling series.
//!
//!     cargo run --release --example scalability [-- --floor-bits 8]

use repro::cli::Args;
use repro::figures;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--"))?;
    let floor: Option<f64> = args.get("floor-bits").map(|v| v.parse()).transpose()?;
    args.reject_unknown()?;

    println!("{}", figures::fig11_14(floor));
    println!("=== All-reduce vs all-gather scaling (VGG16 gradient, 10 Gbps) ===");
    println!("{}", figures::scalability_table());
    println!(
        "all-reduce communication is O(1) in bandwidth and O(M) only in latency;\n\
         all-gather grows linearly in M — the gap above is the paper's core argument."
    );
    Ok(())
}
