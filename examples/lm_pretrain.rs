//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): data-parallel pretraining of the
//! ~10.8M-parameter decoder-only transformer LM on a synthetic Markov
//! corpus, with the paper's quantizer on the gradient path.
//!
//! Proves all three layers compose on a real training workload: the L2 JAX
//! transformer (AOT-lowered, vmapped over workers) executes through PJRT
//! from the Rust coordinator; per-worker gradients go through the L1-parity
//! QSGDMaxNorm encoder and the simulated collectives; SGD updates the
//! replicated flat parameters. The loss curve is logged to
//! `results/lm_pretrain_*.csv` and should descend from ~ln(256)=5.55 toward
//! the corpus's conditional entropy (printed below).
//!
//!     cargo run --release --example lm_pretrain -- \
//!         [--steps 300] [--workers 4] [--method qsgd-mn-8] [--lr 0.2]

use repro::cli::Args;
use repro::cluster::{run_training, ClusterConfig};
use repro::compress::Method;
use repro::data::MarkovCorpus;
use repro::metrics::CsvWriter;
use repro::runtime::Artifacts;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--"))?;
    let steps: usize = args.parse_or("steps", 300)?;
    let workers: usize = args.parse_or("workers", 4)?;
    let method = Method::parse(args.get_or("method", "qsgd-mn-8"))?;
    let lr: f64 = args.parse_or("lr", 0.2)?;
    let seed: u64 = args.parse_or("seed", 42)?;
    args.reject_unknown()?;

    let arts = Artifacts::load_default()?;
    let model = arts.model("transformer")?;
    let corpus = MarkovCorpus::new(seed ^ 0xDA7A, model.cfg.req("vocab")?.as_usize()?, 8);
    let entropy = corpus.entropy_nats();
    println!(
        "transformer LM: {} params, vocab {}, seq {} | corpus entropy floor {:.3} nats (uniform {:.3})",
        model.param_count,
        model.cfg.req("vocab")?.as_usize()?,
        model.cfg.req("seq")?.as_usize()?,
        entropy,
        (model.cfg.req("vocab")?.as_f64()?).ln(),
    );
    println!("method {}, M={workers}, {steps} steps\n", method.label());

    let mut cfg = ClusterConfig::new("transformer", workers, method);
    cfg.total_steps = steps;
    cfg.lr0 = lr;
    cfg.seed = seed;
    cfg.momentum = 0.9;
    cfg.weight_decay = 1e-4;

    let mut csv = CsvWriter::create(
        std::path::Path::new("results/lm_pretrain_loss.csv"),
        &["step", "loss", "lr", "bits_per_worker"],
    )?;
    let t0 = std::time::Instant::now();
    let (records, summary) = run_training(&arts, cfg, |rec| {
        let _ = csv.row(&[rec.step as f64, rec.loss, rec.lr, rec.bits_per_worker]);
        if rec.step % 10 == 0 {
            println!(
                "step {:>4}  loss {:.4}  ({:.1}s elapsed)",
                rec.step,
                rec.loss,
                t0.elapsed().as_secs_f64()
            );
        }
    })?;

    let first = records.first().unwrap().loss;
    let last = records.last().unwrap().loss;
    println!("\nloss: {first:.4} -> {last:.4} (entropy floor {entropy:.4})");
    println!(
        "eval loss {:.4} | {:.1} min wall | compression: {:.0} kbits/worker/step vs {:.0} dense",
        summary.final_eval_loss,
        summary.wall_time_s / 60.0,
        summary.mean_bits_per_step / 1e3,
        32.0 * summary.steps as f64 * 0.0 + 32.0 * 10_785_792.0 / 1e3,
    );
    println!("curve: results/lm_pretrain_loss.csv");
    anyhow::ensure!(last < first, "loss must decrease over the run");
    Ok(())
}
