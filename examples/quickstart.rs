//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! Loads the AOT artifacts, builds a 4-worker simulated cluster on the MLP
//! model, trains 40 steps with the paper's 8-bit QSGDMaxNorm quantizer, and
//! prints the loss curve + wire savings vs dense all-reduce.
//!
//!     cargo run --release --example quickstart

use repro::cluster::{Cluster, ClusterConfig};
use repro::compress::Method;
use repro::runtime::Artifacts;

fn main() -> anyhow::Result<()> {
    let arts = Artifacts::load_default()?;
    println!("artifacts: {:?}", arts.dir);

    let method = Method::parse("qsgd-mn-8")?;
    let mut cfg = ClusterConfig::new("mlp", 4, method);
    cfg.total_steps = 40;
    cfg.lr0 = 0.02;

    let mut cluster = Cluster::new(&arts, cfg)?;
    println!(
        "model=mlp  params={}  workers=4  method={}",
        cluster.param_count(),
        cluster.aggregator_name()
    );

    for step in 0..40 {
        let rec = cluster.train_step(step)?;
        if step % 5 == 0 || step == 39 {
            println!(
                "step {:>3}  loss {:.4}  bits/worker {:.0} ({}x smaller than fp32)",
                rec.step,
                rec.loss,
                rec.bits_per_worker,
                (32.0 * cluster.param_count() as f64 / rec.bits_per_worker).round()
            );
        }
    }

    let (eval_loss, eval_acc) = cluster.evaluate()?;
    println!("\neval: loss {eval_loss:.4}, accuracy {:.1}%", eval_acc * 100.0);
    println!(
        "simulated time: compute {:.2}s + encode {:.3}s + comm {:.3}s + decode {:.3}s",
        cluster.clock.compute_s, cluster.clock.encode_s, cluster.clock.comm_s, cluster.clock.decode_s
    );
    Ok(())
}
