"""L1 Pallas kernels for QSGDMaxNorm (single-scale) quantization.

The paper's compute hot-spot is elementwise stochastic rounding against a
globally shared max-norm scale, plus the L2-norm reduction that produces the
scale. Both are written as Pallas kernels with an explicit HBM->VMEM block
schedule (DESIGN.md §7):

* ``qsgd_quantize``   — grid over 1-D blocks of ``BLOCK`` lanes; each block
  streams v/u tiles into VMEM, does the rounding on the VPU, writes the
  signed-level tile. No cross-block dependence: the scale ``wnorm`` is a
  prefetched scalar.
* ``l2_norm_partials`` — block-partial sum-of-squares reduction (the Pallas
  analogue of a CUDA warp-reduce + grid-level second pass); the final sqrt
  of the partial sum happens in plain jnp (a trivial [grid]-length vector).
* ``qsgd_dequantize`` — streaming reconstruct of the all-reduced level sum.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); on a real TPU the same BlockSpecs pipeline HBM<->VMEM.
VMEM footprint at BLOCK=8192: 3 live f32 tiles = 96 KiB, far under budget,
leaving headroom for double-buffering (see DESIGN.md §7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8192 f32 lanes = 32 KiB per tile: large enough to amortize the grid loop,
# small enough that in+rand+out triple stays < 100 KiB of VMEM.
BLOCK = 8192


def _pad_to_block(x: jnp.ndarray, block: int) -> jnp.ndarray:
    n = x.shape[0]
    rem = (-n) % block
    if rem == 0:
        return x
    return jnp.pad(x, (0, rem))


# ---------------------------------------------------------------------------
# quantize


def _quantize_kernel(v_ref, w_ref, u_ref, o_ref, *, s: int):
    """One VMEM tile of eq. (6)/(7): signed integer levels."""
    v = v_ref[...]
    u = u_ref[...]
    w = w_ref[0]
    safe_w = jnp.where(w > 0.0, w, jnp.float32(1.0))
    a = jnp.abs(v) / safe_w
    scaled = a * jnp.float32(s)
    l = jnp.floor(scaled)
    p = scaled - l
    level = l + jnp.where(u < p, jnp.float32(1.0), jnp.float32(0.0))
    zeta = jnp.sign(v) * level
    o_ref[...] = jnp.where(w > 0.0, zeta, jnp.zeros_like(zeta))


def qsgd_quantize(
    v: jnp.ndarray, wnorm: jnp.ndarray, u: jnp.ndarray, s: int, block: int = BLOCK
) -> jnp.ndarray:
    """Pallas QSGDMaxNorm encode: f32[n] -> signed levels f32[n].

    ``wnorm`` is the shared max L2 norm (scalar); ``u`` the explicit uniform
    randomness (DESIGN.md §5 determinism contract).
    """
    n = v.shape[0]
    vp = _pad_to_block(v.astype(jnp.float32), block)
    up = _pad_to_block(u.astype(jnp.float32), block)
    w1 = jnp.reshape(jnp.asarray(wnorm, jnp.float32), (1,))
    grid = vp.shape[0] // block
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, s=s),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),  # broadcast scalar tile
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(vp.shape, jnp.float32),
        interpret=True,
    )(vp, w1, up)
    return out[:n]


# ---------------------------------------------------------------------------
# dequantize


def _dequantize_kernel(z_ref, w_ref, o_ref, *, s: int, m: int):
    z = z_ref[...]
    w = w_ref[0]
    o_ref[...] = z * w / jnp.float32(s * m)


def qsgd_dequantize(
    zeta_sum: jnp.ndarray,
    wnorm: jnp.ndarray,
    s: int,
    m: int,
    block: int = BLOCK,
) -> jnp.ndarray:
    """Pallas QSGDMaxNorm decode of an all-reduced level sum (eq. 8, /M)."""
    n = zeta_sum.shape[0]
    zp = _pad_to_block(zeta_sum.astype(jnp.float32), block)
    w1 = jnp.reshape(jnp.asarray(wnorm, jnp.float32), (1,))
    grid = zp.shape[0] // block
    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, s=s, m=m),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(zp.shape, jnp.float32),
        interpret=True,
    )(zp, w1)
    return out[:n]


# ---------------------------------------------------------------------------
# L2 norm (two-pass block reduction)


def _sumsq_kernel(v_ref, o_ref):
    v = v_ref[...]
    o_ref[0] = jnp.sum(v * v)


def l2_norm_partials(v: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """Block-partial sum-of-squares, f32[n] -> f32[grid]."""
    vp = _pad_to_block(v.astype(jnp.float32), block)
    grid = vp.shape[0] // block
    return pl.pallas_call(
        _sumsq_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.float32),
        interpret=True,
    )(vp)


def l2_norm(v: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """Full L2 norm via the Pallas partial reduction + trivial final pass."""
    return jnp.sqrt(jnp.sum(l2_norm_partials(v, block)))
