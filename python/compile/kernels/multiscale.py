"""L1 Pallas kernels for QSGDMaxNormMultiScale quantization (paper §4.2).

Three kernels, matching the three phases of Algorithm 2:

* ``scale_index``       — per-coordinate scale selection (eq. 10): the largest
  scale s in the set with ``s * |v_i| <= ||w|| * min(S)``. The scale set is a
  static tuple (N = 2..4 in the paper), so selection is N fused compares in
  registers — no gather, see DESIGN.md §7.
* ``multiscale_quantize`` — stochastic rounding at the *shared* per-coordinate
  scale (after the min-all-reduce scale sharing happens at L3).
* ``multiscale_dequantize`` — eq. (12): elementwise division by s*.

All stream 1-D VMEM tiles like the single-scale kernel; the scale-index
vector rides along as a second input tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .qsgd import BLOCK, _pad_to_block


def _scale_index_kernel(v_ref, w_ref, o_ref, *, scales: tuple[int, ...]):
    v = v_ref[...]
    w = w_ref[0]
    safe_w = jnp.where(w > 0.0, w, jnp.float32(1.0))
    smin = jnp.float32(min(scales))
    idx = jnp.zeros(v.shape, jnp.float32)
    for j, s in enumerate(sorted(scales)):
        ok = jnp.float32(s) * jnp.abs(v) <= safe_w * smin
        idx = jnp.where(ok, jnp.float32(j), idx)
    o_ref[...] = idx


def scale_index(
    v: jnp.ndarray, wnorm: jnp.ndarray, scales: tuple[int, ...], block: int = BLOCK
) -> jnp.ndarray:
    """Per-coordinate scale index (f32 integer values), eq. (10)."""
    n = v.shape[0]
    vp = _pad_to_block(v.astype(jnp.float32), block)
    w1 = jnp.reshape(jnp.asarray(wnorm, jnp.float32), (1,))
    grid = vp.shape[0] // block
    out = pl.pallas_call(
        functools.partial(_scale_index_kernel, scales=tuple(scales)),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(vp.shape, jnp.float32),
        interpret=True,
    )(vp, w1)
    return out[:n]


def _ms_quantize_kernel(v_ref, w_ref, u_ref, idx_ref, o_ref, *, scales: tuple[int, ...]):
    v = v_ref[...]
    u = u_ref[...]
    idx = idx_ref[...]
    w = w_ref[0]
    safe_w = jnp.where(w > 0.0, w, jnp.float32(1.0))
    a = jnp.abs(v) / safe_w
    srt = sorted(scales)
    s_eff = jnp.zeros(v.shape, jnp.float32)
    for j, s in enumerate(srt):
        s_eff = jnp.where(idx == jnp.float32(j), jnp.float32(s), s_eff)
    scaled = a * s_eff
    l = jnp.floor(scaled)
    p = scaled - l
    level = l + jnp.where(u < p, jnp.float32(1.0), jnp.float32(0.0))
    zeta = jnp.sign(v) * level
    o_ref[...] = jnp.where(w > 0.0, zeta, jnp.zeros_like(zeta))


def multiscale_quantize(
    v: jnp.ndarray,
    wnorm: jnp.ndarray,
    u: jnp.ndarray,
    scale_idx: jnp.ndarray,
    scales: tuple[int, ...],
    block: int = BLOCK,
) -> jnp.ndarray:
    """Pallas multi-scale encode at the shared per-coordinate scale (eq. 9/11)."""
    n = v.shape[0]
    vp = _pad_to_block(v.astype(jnp.float32), block)
    up = _pad_to_block(u.astype(jnp.float32), block)
    ip = _pad_to_block(scale_idx.astype(jnp.float32), block)
    w1 = jnp.reshape(jnp.asarray(wnorm, jnp.float32), (1,))
    grid = vp.shape[0] // block
    out = pl.pallas_call(
        functools.partial(_ms_quantize_kernel, scales=tuple(scales)),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(vp.shape, jnp.float32),
        interpret=True,
    )(vp, w1, up, ip)
    return out[:n]


def _ms_dequantize_kernel(z_ref, w_ref, idx_ref, o_ref, *, scales: tuple[int, ...], m: int):
    z = z_ref[...]
    idx = idx_ref[...]
    w = w_ref[0]
    srt = sorted(scales)
    s_eff = jnp.full(z.shape, jnp.float32(srt[0]))
    for j, s in enumerate(srt):
        s_eff = jnp.where(idx == jnp.float32(j), jnp.float32(s), s_eff)
    o_ref[...] = z * w / (s_eff * jnp.float32(m))


def multiscale_dequantize(
    zeta_sum: jnp.ndarray,
    wnorm: jnp.ndarray,
    scale_idx: jnp.ndarray,
    scales: tuple[int, ...],
    m: int,
    block: int = BLOCK,
) -> jnp.ndarray:
    """Pallas multi-scale decode of an all-reduced level sum (eq. 12, /M)."""
    n = zeta_sum.shape[0]
    zp = _pad_to_block(zeta_sum.astype(jnp.float32), block)
    ip = _pad_to_block(scale_idx.astype(jnp.float32), block)
    w1 = jnp.reshape(jnp.asarray(wnorm, jnp.float32), (1,))
    grid = zp.shape[0] // block
    out = pl.pallas_call(
        functools.partial(_ms_dequantize_kernel, scales=tuple(scales), m=m),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(zp.shape, jnp.float32),
        interpret=True,
    )(zp, w1, ip)
    return out[:n]
