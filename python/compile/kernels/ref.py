"""Pure-jnp oracles for the quantization kernels (L1 correctness ground truth).

Every kernel in this package is validated against these functions by
``python/tests``; the Rust implementations are additionally validated against
the *lowered HLO* of the Pallas kernels, so this file is the root of the
bit-exactness chain described in DESIGN.md §5.

All stochastic rounding consumes an explicit uniform vector ``u`` in
``[0, 1)`` so that every layer (jnp oracle, Pallas kernel, Rust hot path,
PJRT-executed HLO) is a deterministic function of ``(v, wnorm, u)``.

Paper equations ("Quantization for Distributed Optimization"):

*  eq. (6)/(7): single-scale QSGDMaxNorm — for coordinate ``v_i`` with shared
   scale ``s`` and shared max-norm ``||w||``, let ``a = |v_i| / ||w||`` and
   ``l = floor(a * s)``. Then the transmitted integer level is
   ``l + 1{u_i < a*s - l}`` and the encoded coordinate is
   ``sign(v_i) * level``.
*  eq. (9)/(10)/(11): multi-scale — per-coordinate scale ``s*_i`` is the
   largest scale in the set ``S`` with ``s <= (||w|| / |v_i|) * min(S)``;
   rounding then proceeds at ``s*_i``.
*  eq. (8)/(12): reconstruction divides by the scale(s) and multiplies by
   ``||w||``.
"""

from __future__ import annotations

import jax.numpy as jnp


def qsgd_levels(v: jnp.ndarray, wnorm: jnp.ndarray, u: jnp.ndarray, s: int) -> jnp.ndarray:
    """Signed integer levels ``zeta = sign(v) * xi * s`` for QSGDMaxNorm.

    Args:
      v:     gradient vector, f32[n].
      wnorm: shared scalar ``||w||_2 = max_m ||g_m||_2`` (f32 scalar).
      u:     uniform randomness in [0, 1), f32[n].
      s:     number of non-zero quantization levels (static int >= 1).

    Returns:
      f32[n] vector of signed integer levels in ``[-s, s]``. (f32 carrier so
      the same HLO I/O dtype is used everywhere; values are exact integers.)
    """
    v = v.astype(jnp.float32)
    wnorm = jnp.asarray(wnorm, jnp.float32)
    # Guard w == 0 (all-zero gradients everywhere): levels are all zero.
    safe_w = jnp.where(wnorm > 0.0, wnorm, jnp.float32(1.0))
    a = jnp.abs(v) / safe_w  # in [0, 1] since |v_i| <= ||v|| <= ||w||
    scaled = a * jnp.float32(s)
    l = jnp.floor(scaled)
    p = scaled - l
    level = l + jnp.where(u < p, jnp.float32(1.0), jnp.float32(0.0))
    zeta = jnp.sign(v) * level
    return jnp.where(wnorm > 0.0, zeta, jnp.zeros_like(zeta))


def qsgd_dequantize(zeta_sum: jnp.ndarray, wnorm: jnp.ndarray, s: int, m: int) -> jnp.ndarray:
    """Reconstruct the *averaged* gradient from an all-reduced level sum.

    eq. (8) applied to ``(1/M) * sum_m zeta_m``: ``||w|| * zeta / (s * M)``.
    """
    return (
        zeta_sum.astype(jnp.float32)
        * jnp.asarray(wnorm, jnp.float32)
        / jnp.float32(s * m)
    )


def multiscale_scale_index(
    v: jnp.ndarray, wnorm: jnp.ndarray, scales: tuple[int, ...]
) -> jnp.ndarray:
    """Per-coordinate scale index: largest ``s_j <= (||w||/|v_i|) * min(S)``.

    The scale set is sorted ascending; index 0 == ``min(S)`` always
    qualifies because ``|v_i| <= ||w||``. Returned as f32 integer values
    for HLO-dtype uniformity.
    """
    v = v.astype(jnp.float32)
    wnorm = jnp.asarray(wnorm, jnp.float32)
    smin = jnp.float32(min(scales))
    safe_w = jnp.where(wnorm > 0.0, wnorm, jnp.float32(1.0))
    # threshold on s:  s * |v_i| <= ||w|| * smin   (multiplicative form avoids
    # the |v_i| == 0 division special-case; v_i == 0 admits every scale).
    idx = jnp.zeros(v.shape, jnp.float32)
    for j, s in enumerate(sorted(scales)):
        ok = jnp.float32(s) * jnp.abs(v) <= safe_w * smin
        idx = jnp.where(ok, jnp.float32(j), idx)
    return idx


def multiscale_levels(
    v: jnp.ndarray,
    wnorm: jnp.ndarray,
    u: jnp.ndarray,
    scale_idx: jnp.ndarray,
    scales: tuple[int, ...],
) -> jnp.ndarray:
    """Signed levels at the (already shared) per-coordinate scale.

    ``scale_idx`` is the elementwise-min over workers of
    :func:`multiscale_scale_index` (the paper's *scale sharing*), carried as
    f32 integers.
    """
    v = v.astype(jnp.float32)
    wnorm = jnp.asarray(wnorm, jnp.float32)
    safe_w = jnp.where(wnorm > 0.0, wnorm, jnp.float32(1.0))
    a = jnp.abs(v) / safe_w
    srt = sorted(scales)
    s_eff = jnp.zeros(v.shape, jnp.float32)
    for j, s in enumerate(srt):
        s_eff = jnp.where(scale_idx == jnp.float32(j), jnp.float32(s), s_eff)
    scaled = a * s_eff
    l = jnp.floor(scaled)
    p = scaled - l
    level = l + jnp.where(u < p, jnp.float32(1.0), jnp.float32(0.0))
    zeta = jnp.sign(v) * level
    return jnp.where(wnorm > 0.0, zeta, jnp.zeros_like(zeta))


def multiscale_dequantize(
    zeta_sum: jnp.ndarray,
    wnorm: jnp.ndarray,
    scale_idx: jnp.ndarray,
    scales: tuple[int, ...],
    m: int,
) -> jnp.ndarray:
    """eq. (12) on the all-reduced sum: elementwise divide by ``s*`` then /M."""
    srt = sorted(scales)
    s_eff = jnp.full(zeta_sum.shape, jnp.float32(srt[0]))
    for j, s in enumerate(srt):
        s_eff = jnp.where(scale_idx == jnp.float32(j), jnp.float32(s), s_eff)
    return (
        zeta_sum.astype(jnp.float32)
        * jnp.asarray(wnorm, jnp.float32)
        / (s_eff * jnp.float32(m))
    )


def randk_gather(v: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Sparsification front-end: gather the K globally-shared coordinates."""
    return v.astype(jnp.float32)[idx]


def randk_scatter(n: int, idx: jnp.ndarray, dense_k: jnp.ndarray) -> jnp.ndarray:
    """Scatter decoded K values back into an n-vector (rest zeros)."""
    out = jnp.zeros((n,), jnp.float32)
    return out.at[idx].set(dense_k.astype(jnp.float32))


def l2_norm(v: jnp.ndarray) -> jnp.ndarray:
    """Shared-scale prerequisite: the worker-local L2 norm."""
    v = v.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(v * v))
