"""L1 Pallas kernel for the GlobalRandK front-end (paper §4.3/§4.4).

GlobalRandK sparsification picks K coordinates *with a globally shared seed*
(all workers pick the same indices — that is what makes the scheme all-reduce
compatible), gathers them into a dense K-vector, and hands that dense vector
to the QSGDMaxNorm / MultiScale quantizer.

The gather is expressed as a Pallas kernel over K-blocks doing dynamic loads
from the full gradient resident in HBM (``index_map`` keeps the whole source
as one block; per-element ``pl.load`` with an index tile does the gather —
on TPU this maps to VMEM scalar-indexed loads, the analogue of the paper's
``torch.gather``). The scatter-back after decode is the transpose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .qsgd import _pad_to_block

GATHER_BLOCK = 2048


def _gather_kernel(idx_ref, v_ref, o_ref):
    idx = idx_ref[...].astype(jnp.int32)
    o_ref[...] = v_ref[idx]


def randk_gather(v: jnp.ndarray, idx: jnp.ndarray, block: int = GATHER_BLOCK) -> jnp.ndarray:
    """Gather K globally-shared coordinates: f32[n], i32[k] -> f32[k]."""
    k = idx.shape[0]
    ip = _pad_to_block(idx.astype(jnp.int32), block)  # pad with index 0
    grid = ip.shape[0] // block
    out = pl.pallas_call(
        _gather_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(v.shape, lambda i: (0,)),  # full source resident
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(ip.shape, jnp.float32),
        interpret=True,
    )(ip, v.astype(jnp.float32))
    return out[:k]


def randk_scatter(n: int, idx: jnp.ndarray, dense_k: jnp.ndarray) -> jnp.ndarray:
    """Scatter decoded K values into an n-vector of zeros (jnp scatter).

    The scatter is a one-shot `.at[].set()` — XLA lowers it to a single
    scatter HLO; a handwritten Pallas scatter buys nothing on top (it is
    bandwidth-bound and write-once), so we keep the fused XLA op.
    """
    out = jnp.zeros((n,), jnp.float32)
    return out.at[idx.astype(jnp.int32)].set(dense_k.astype(jnp.float32))
