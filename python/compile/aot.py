"""AOT exporter: lower every L2/L1 graph to HLO text + write artifact index.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (behind
the Rust `xla` crate) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts [--fast]

Outputs (all under --out-dir):
  {model}_step_m{M}.hlo.txt   multi-worker gradient step (vmapped over M)
  {model}_eval.hlo.txt        eval step (loss, correct)
  {model}_params.bin          initial flat f32 parameters (little-endian)
  qsgd_quantize_s{S}.hlo.txt  Pallas quantizer parity graphs (n=PARITY_N)
  qsgd_roundtrip.hlo.txt      quantize+dequantize composed
  multiscale_quantize.hlo.txt scale-index + quantize (two outputs)
  l2_norm.hlo.txt             Pallas block-reduction norm
  meta.json                   the artifact index consumed by rust/src/runtime
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .models import REGISTRY, transformer

PARITY_N = 16384
PARITY_SCALES = (7, 127)

# bits-per-coordinate -> number of non-zero levels s (paper: r = ceil(log s)+1,
# i.e. b bits leave b-1 bits for the magnitude level).
BITS_TO_S = {2: 1, 4: 7, 6: 31, 8: 127, 10: 511, 12: 2047}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flops_estimate(lowered) -> float:
    try:
        ca = lowered.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return float(ca.get("flops", 0.0))
    except Exception:
        return 0.0


def _write(out_dir: str, name: str, text: str) -> str:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text) / 1e6:.2f} MB)")
    return name


def export_model(out_dir: str, name: str, cfg: dict, batch: int, workers: list[int], eval_batch: int):
    print(f"[aot] model {name} cfg={cfg}")
    flat, _, segments = model_lib.init_flat(name, cfg)
    p = int(flat.size)
    params_file = f"{name}_params.bin"
    np.asarray(flat, dtype="<f4").tofile(os.path.join(out_dir, params_file))

    entry = {
        "cfg": cfg,
        "param_count": p,
        "params_file": params_file,
        "segments": segments,
        "steps": {},
        "input": "tokens" if name == "transformer" else "image",
        "batch": batch,
    }

    pspec = jax.ShapeDtypeStruct((p,), jnp.float32)
    for m in workers:
        step = model_lib.make_train_step(name, cfg, m)
        if name == "transformer":
            toks = jax.ShapeDtypeStruct((m, batch, cfg["seq"] + 1), jnp.int32)
            lowered = jax.jit(step).lower(pspec, toks)
            inputs = [
                {"kind": "params", "shape": [p], "dtype": "f32"},
                {"kind": "tokens", "shape": [m, batch, cfg["seq"] + 1], "dtype": "i32"},
            ]
        else:
            xs = jax.ShapeDtypeStruct((m, batch, *cfg["input"]), jnp.float32)
            ys = jax.ShapeDtypeStruct((m, batch), jnp.int32)
            lowered = jax.jit(step).lower(pspec, xs, ys)
            inputs = [
                {"kind": "params", "shape": [p], "dtype": "f32"},
                {"kind": "images", "shape": [m, batch, *cfg["input"]], "dtype": "f32"},
                {"kind": "labels", "shape": [m, batch], "dtype": "i32"},
            ]
        fname = _write(out_dir, f"{name}_step_m{m}.hlo.txt", to_hlo_text(lowered))
        entry["steps"][str(m)] = {
            "file": fname,
            "workers": m,
            "batch": batch,
            "inputs": inputs,
            "outputs": [
                {"kind": "loss", "shape": [m], "dtype": "f32"},
                {"kind": "grads", "shape": [m, p], "dtype": "f32"},
            ],
            "flops": flops_estimate(lowered),
        }

    ev = model_lib.make_eval_step(name, cfg)
    if name == "transformer":
        toks = jax.ShapeDtypeStruct((eval_batch, cfg["seq"] + 1), jnp.int32)
        lowered = jax.jit(ev).lower(pspec, toks)
        ev_inputs = [
            {"kind": "params", "shape": [p], "dtype": "f32"},
            {"kind": "tokens", "shape": [eval_batch, cfg["seq"] + 1], "dtype": "i32"},
        ]
    else:
        xs = jax.ShapeDtypeStruct((eval_batch, *cfg["input"]), jnp.float32)
        ys = jax.ShapeDtypeStruct((eval_batch,), jnp.int32)
        lowered = jax.jit(ev).lower(pspec, xs, ys)
        ev_inputs = [
            {"kind": "params", "shape": [p], "dtype": "f32"},
            {"kind": "images", "shape": [eval_batch, *cfg["input"]], "dtype": "f32"},
            {"kind": "labels", "shape": [eval_batch], "dtype": "i32"},
        ]
    fname = _write(out_dir, f"{name}_eval.hlo.txt", to_hlo_text(lowered))
    entry["eval"] = {"file": fname, "batch": eval_batch, "inputs": ev_inputs}
    return entry


def export_kernels(out_dir: str) -> dict:
    print("[aot] parity kernels")
    kernels = {}
    v = jax.ShapeDtypeStruct((PARITY_N,), jnp.float32)
    w = jax.ShapeDtypeStruct((), jnp.float32)
    u = jax.ShapeDtypeStruct((PARITY_N,), jnp.float32)

    for s in sorted(set(BITS_TO_S.values())):
        fn = model_lib.make_qsgd_quantize(PARITY_N, s)
        fname = _write(out_dir, f"qsgd_quantize_s{s}.hlo.txt", to_hlo_text(jax.jit(fn).lower(v, w, u)))
        kernels[f"qsgd_quantize_s{s}"] = {"file": fname, "n": PARITY_N, "s": s}

    fn = model_lib.make_qsgd_roundtrip(PARITY_N, 127, 4)
    fname = _write(out_dir, "qsgd_roundtrip.hlo.txt", to_hlo_text(jax.jit(fn).lower(v, w, u)))
    kernels["qsgd_roundtrip"] = {"file": fname, "n": PARITY_N, "s": 127, "m": 4}

    fn = model_lib.make_multiscale_quantize(PARITY_N, PARITY_SCALES)
    fname = _write(out_dir, "multiscale_quantize.hlo.txt", to_hlo_text(jax.jit(fn).lower(v, w, u)))
    kernels["multiscale_quantize"] = {
        "file": fname,
        "n": PARITY_N,
        "scales": list(PARITY_SCALES),
    }

    fn = model_lib.make_l2_norm(PARITY_N)
    fname = _write(out_dir, "l2_norm.hlo.txt", to_hlo_text(jax.jit(fn).lower(v)))
    kernels["l2_norm"] = {"file": fname, "n": PARITY_N}
    return kernels


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fast", action="store_true", help="mlp + kernels only (CI smoke)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lm-batch", type=int, default=8)
    ap.add_argument("--eval-batch", type=int, default=200)
    ap.add_argument("--workers", type=int, nargs="+", default=[1, 2, 4])
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    meta = {"version": 1, "models": {}, "kernels": {}, "bits_to_s": BITS_TO_S}

    meta["kernels"] = export_kernels(args.out_dir)
    meta["models"]["mlp"] = export_model(
        args.out_dir, "mlp", REGISTRY["mlp"].default_cfg(), args.batch, args.workers, args.eval_batch
    )
    if not args.fast:
        meta["models"]["resnet_lite"] = export_model(
            args.out_dir,
            "resnet_lite",
            REGISTRY["resnet_lite"].default_cfg(),
            args.batch,
            args.workers,
            args.eval_batch,
        )
        meta["models"]["vgg_lite"] = export_model(
            args.out_dir,
            "vgg_lite",
            REGISTRY["vgg_lite"].default_cfg(),
            args.batch,
            args.workers,
            args.eval_batch,
        )
        meta["models"]["transformer"] = export_model(
            args.out_dir,
            "transformer",
            transformer.default_cfg(),
            args.lm_batch,
            [1, 2, 4],
            16,
        )

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[aot] wrote meta.json with {len(meta['models'])} models, {len(meta['kernels'])} kernels")


if __name__ == "__main__":
    main()
