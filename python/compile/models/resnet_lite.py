"""ResNet-lite: the computation-intensive model (ResNet50 stand-in, §2 of DESIGN.md).

A CIFAR-style pre-activation residual network: stem conv, three stages of
residual blocks at widths (16, 32, 64) with stride-2 transitions, global
average pooling, linear head. Deep-and-narrow => high FLOPs-per-parameter,
preserving the paper's computation-intensive vs communication-intensive
contrast against vgg_lite.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def default_cfg():
    return {
        "input": [32, 32, 3],
        "widths": [16, 32, 64],
        "blocks_per_stage": 2,
        "classes": 10,
    }


def _block_init(key, cin, cout):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "gn1": common.group_norm_init(cin),
        "conv1": common.conv_init(k1, 3, 3, cin, cout),
        "gn2": common.group_norm_init(cout),
        "conv2": common.conv_init(k2, 3, 3, cout, cout),
    }
    if cin != cout:
        p["proj"] = common.conv_init(k3, 1, 1, cin, cout)
    return p


def _block_apply(p, x, stride):
    h = jax.nn.relu(common.group_norm(p["gn1"], x))
    h = common.conv(p["conv1"], h, stride=stride)
    h = jax.nn.relu(common.group_norm(p["gn2"], h))
    h = common.conv(p["conv2"], h)
    if "proj" in p:
        x = common.conv(p["proj"], x, stride=stride)
    return x + h


def init(key, cfg):
    widths = cfg["widths"]
    nb = cfg["blocks_per_stage"]
    keys = jax.random.split(key, 2 + len(widths) * nb)
    params = {"stem": common.conv_init(keys[0], 3, 3, cfg["input"][2], widths[0])}
    ki = 1
    cin = widths[0]
    for si, w in enumerate(widths):
        for bi in range(nb):
            params[f"s{si}b{bi}"] = _block_init(keys[ki], cin, w)
            cin = w
            ki += 1
    params["head_gn"] = common.group_norm_init(widths[-1])
    params["head"] = common.dense_init(keys[ki], widths[-1], cfg["classes"])
    return params


def apply(params, x, cfg):
    widths = cfg["widths"]
    nb = cfg["blocks_per_stage"]
    h = common.conv(params["stem"], x)
    for si, _w in enumerate(widths):
        for bi in range(nb):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = _block_apply(params[f"s{si}b{bi}"], h, stride)
    h = jax.nn.relu(common.group_norm(params["head_gn"], h))
    h = common.avg_pool_global(h)
    return common.dense(params["head"], h)
