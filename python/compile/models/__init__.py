"""L2 model zoo (build-time only; lowered to HLO by aot.py).

Each model module exposes:

* ``init(key, cfg) -> params``  — a pytree of f32 arrays.
* ``apply(params, x, cfg) -> logits`` — pure forward pass.
* ``default_cfg() -> dict``     — the configuration used by the paper repro.

Models are pure-functional (no mutable state: GroupNorm instead of BatchNorm)
so that ``jax.grad`` over a flat parameter vector lowers to a single HLO.
"""

from . import mlp, resnet_lite, transformer, vgg_lite  # noqa: F401

REGISTRY = {
    "mlp": mlp,
    "resnet_lite": resnet_lite,
    "vgg_lite": vgg_lite,
    "transformer": transformer,
}
