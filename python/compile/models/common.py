"""Shared layers/initializers for the L2 model zoo."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def he_normal(key, shape, fan_in):
    """He/Kaiming normal initializer (matches the paper's PyTorch defaults)."""
    std = jnp.sqrt(2.0 / fan_in)
    return (jax.random.normal(key, shape) * std).astype(jnp.float32)


def lecun_normal(key, shape, fan_in):
    std = jnp.sqrt(1.0 / fan_in)
    return (jax.random.normal(key, shape) * std).astype(jnp.float32)


def dense_init(key, d_in, d_out):
    kw, _ = jax.random.split(key)
    return {
        "w": he_normal(kw, (d_in, d_out), d_in),
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def dense(p, x):
    return x @ p["w"] + p["b"]


def conv_init(key, kh, kw_, cin, cout):
    k, _ = jax.random.split(key)
    return {
        "w": he_normal(k, (kh, kw_, cin, cout), kh * kw_ * cin),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def conv(p, x, stride=1, padding="SAME"):
    """NHWC conv with HWIO weights."""
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def group_norm_init(c):
    return {"g": jnp.ones((c,), jnp.float32), "b": jnp.zeros((c,), jnp.float32)}


def group_norm(p, x, groups=8, eps=1e-5):
    """Stateless GroupNorm over NHWC (BatchNorm stand-in; see DESIGN.md §2)."""
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = ((xg - mean) ** 2).mean(axis=(1, 2, 4), keepdims=True)
    xn = ((xg - mean) / jnp.sqrt(var + eps)).reshape(n, h, w, c)
    return xn * p["g"] + p["b"]


def layer_norm_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layer_norm(p, x, eps=1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def max_pool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def avg_pool_global(x):
    return x.mean(axis=(1, 2))


def softmax_xent(logits, labels):
    """Mean softmax cross-entropy; labels are int class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def accuracy_count(logits, labels):
    """Number of correct argmax predictions (f32 scalar)."""
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
