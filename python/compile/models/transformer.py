"""Decoder-only transformer LM — the end-to-end training workload.

Used by ``examples/lm_pretrain.rs`` (EXPERIMENTS.md §E2E): data-parallel
pretraining on a synthetic Markov corpus with the paper's quantizers on the
gradient path. Pre-LN GPT-style blocks, learned positional embeddings, tied
output head.

``default_cfg`` is ~10M parameters (CPU-trainable in minutes); ``large_cfg``
is ~100M for parity with the system-prompt scale target (compile-only on
this testbed — documented substitution, DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def default_cfg():
    return {
        "vocab": 256,
        "seq": 128,
        "d_model": 384,
        "heads": 6,
        "layers": 6,
        "d_ff": 1536,
    }


def large_cfg():
    return {
        "vocab": 8192,
        "seq": 256,
        "d_model": 768,
        "heads": 12,
        "layers": 12,
        "d_ff": 3072,
    }


def _block_init(key, cfg):
    d, f = cfg["d_model"], cfg["d_ff"]
    k = jax.random.split(key, 6)
    return {
        "ln1": common.layer_norm_init(d),
        "wqkv": common.lecun_normal(k[0], (d, 3 * d), d),
        "wo": common.lecun_normal(k[1], (d, d), d),
        "ln2": common.layer_norm_init(d),
        "w1": common.lecun_normal(k[2], (d, f), d),
        "b1": jnp.zeros((f,), jnp.float32),
        "w2": common.lecun_normal(k[3], (f, d), f),
        "b2": jnp.zeros((d,), jnp.float32),
    }


def _block_apply(p, x, cfg):
    b, t, d = x.shape
    h = cfg["heads"]
    dh = d // h

    # --- causal self-attention
    xn = common.layer_norm(p["ln1"], x)
    qkv = xn @ p["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    att = jnp.where(mask, att, jnp.float32(-1e30))
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    x = x + y @ p["wo"]

    # --- MLP
    xn = common.layer_norm(p["ln2"], x)
    hdd = jax.nn.gelu(xn @ p["w1"] + p["b1"])
    return x + hdd @ p["w2"] + p["b2"]


def init(key, cfg):
    keys = jax.random.split(key, cfg["layers"] + 3)
    params = {
        "tok_emb": common.lecun_normal(keys[0], (cfg["vocab"], cfg["d_model"]), cfg["d_model"]),
        "pos_emb": common.lecun_normal(keys[1], (cfg["seq"], cfg["d_model"]), cfg["d_model"]),
        "ln_f": common.layer_norm_init(cfg["d_model"]),
    }
    for i in range(cfg["layers"]):
        params[f"blk{i}"] = _block_init(keys[2 + i], cfg)
    return params


def apply(params, x, cfg):
    """x: i32[B, T] token ids -> logits f32[B, T, vocab] (tied head)."""
    t = x.shape[1]
    h = params["tok_emb"][x] + params["pos_emb"][:t]
    for i in range(cfg["layers"]):
        h = _block_apply(params[f"blk{i}"], h, cfg)
    h = common.layer_norm(params["ln_f"], h)
    return h @ params["tok_emb"].T
