"""VGG-lite: the communication-intensive model (VGG16 stand-in, DESIGN.md §2).

Classic VGG topology — conv-conv-pool stacks then wide dense layers. Most of
the parameters live in the dense head, so the parameters-per-FLOP ratio is
high: exactly the regime where the paper shows gradient compression pays off
most (Figs 13/14 vs 11/12).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def default_cfg():
    return {
        "input": [32, 32, 3],
        "stages": [[32, 32], [64, 64], [128, 128]],
        "dense": [256],
        "classes": 10,
    }


def init(key, cfg):
    n_conv = sum(len(s) for s in cfg["stages"])
    keys = jax.random.split(key, n_conv + len(cfg["dense"]) + 1)
    params = {}
    ki = 0
    cin = cfg["input"][2]
    for si, stage in enumerate(cfg["stages"]):
        for ci, cout in enumerate(stage):
            params[f"conv{si}_{ci}"] = common.conv_init(keys[ki], 3, 3, cin, cout)
            params[f"gn{si}_{ci}"] = common.group_norm_init(cout)
            cin = cout
            ki += 1
    hw = cfg["input"][0] // (2 ** len(cfg["stages"]))
    d_in = hw * hw * cfg["stages"][-1][-1]
    for di, d in enumerate(cfg["dense"]):
        params[f"fc{di}"] = common.dense_init(keys[ki], d_in, d)
        d_in = d
        ki += 1
    params["head"] = common.dense_init(keys[ki], d_in, cfg["classes"])
    return params


def apply(params, x, cfg):
    h = x
    for si, stage in enumerate(cfg["stages"]):
        for ci, _cout in enumerate(stage):
            h = common.conv(params[f"conv{si}_{ci}"], h)
            h = jax.nn.relu(common.group_norm(params[f"gn{si}_{ci}"], h))
        h = common.max_pool2(h)
    h = h.reshape(h.shape[0], -1)
    for di, _d in enumerate(cfg["dense"]):
        h = jax.nn.relu(common.dense(params[f"fc{di}"], h))
    return common.dense(params["head"], h)
