"""Plain MLP classifier on flattened 32x32x3 inputs (quickstart model)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def default_cfg():
    return {
        "input": [32, 32, 3],
        "hidden": [512, 256],
        "classes": 10,
    }


def init(key, cfg):
    dims = [int(jnp.prod(jnp.asarray(cfg["input"])))] + list(cfg["hidden"]) + [cfg["classes"]]
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"fc{i}": common.dense_init(k, dims[i], dims[i + 1])
        for i, k in enumerate(keys)
    }


def apply(params, x, cfg):
    h = x.reshape(x.shape[0], -1)
    n_layers = len(cfg["hidden"]) + 1
    for i in range(n_layers):
        h = common.dense(params[f"fc{i}"], h)
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h
