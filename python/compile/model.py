"""L2 glue: flat-parameter train/eval steps over the model zoo.

The Rust coordinator (L3) owns parameters as a single flat ``f32[P]`` buffer;
every exported HLO takes/returns that flat layout. ``ravel_pytree`` defines
the canonical ordering, and ``meta.json`` (written by aot.py) records the
per-tensor segmentation so L3-side compressors that need layer structure
(PowerSGD) can reshape slices without ever importing Python.

Exports
  * ``make_train_step(model, cfg, m)`` — f(params[P], x[M,B,...], y[M,B])
    -> (loss[M], grads[M,P]): the vmapped multi-worker gradient step. The
    per-worker gradients feed the compression + simulated-collective path in
    Rust (DESIGN.md §2 substitution table).
  * ``make_eval_step(model, cfg)``  — f(params[P], x[B,...], y[B])
    -> (loss, correct_count).
  * quantizer wrappers re-exported from kernels (lowered standalone so Rust
    can cross-check its native encoder bit-for-bit against the Pallas HLO).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import multiscale as ms_kernels
from .kernels import qsgd as qsgd_kernels
from .models import REGISTRY
from .models import common

SEED = 42


def init_flat(model_name: str, cfg: dict):
    """Initialize parameters; return (flat f32[P] array, unravel fn, segments).

    ``segments`` is a list of (dotted-name, shape, offset, length) describing
    the flat layout — persisted in meta.json for L3.
    """
    model = REGISTRY[model_name]
    params = model.init(jax.random.PRNGKey(SEED), cfg)
    flat, unravel = ravel_pytree(params)

    segments = []
    offset = 0
    leaves_with_path = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in leaves_with_path:
        name = ".".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        length = int(leaf.size)
        segments.append(
            {"name": name, "shape": list(leaf.shape), "offset": offset, "len": length}
        )
        offset += length
    assert offset == flat.size
    return flat.astype(jnp.float32), unravel, segments


def _loss_classifier(model, cfg, unravel, params_flat, x, y):
    params = unravel(params_flat)
    logits = model.apply(params, x, cfg)
    return common.softmax_xent(logits, y)


def _loss_lm(model, cfg, unravel, params_flat, tokens):
    """tokens: i32[B, T+1]; next-token CE over all T positions."""
    params = unravel(params_flat)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = model.apply(params, inp, cfg)
    return common.softmax_xent(logits, tgt)


def make_train_step(model_name: str, cfg: dict, m: int):
    """Multi-worker gradient step; worker axis is vmapped over the data only."""
    model = REGISTRY[model_name]
    _, unravel, _ = init_flat(model_name, cfg)

    if model_name == "transformer":

        def one(params_flat, tokens):
            loss, grads = jax.value_and_grad(
                lambda p: _loss_lm(model, cfg, unravel, p, tokens)
            )(params_flat)
            return loss, grads

        def step(params_flat, tokens_m):
            return jax.vmap(one, in_axes=(None, 0))(params_flat, tokens_m)

    else:

        def one(params_flat, x, y):
            loss, grads = jax.value_and_grad(
                lambda p: _loss_classifier(model, cfg, unravel, p, x, y)
            )(params_flat)
            return loss, grads

        def step(params_flat, x_m, y_m):
            return jax.vmap(one, in_axes=(None, 0, 0))(params_flat, x_m, y_m)

    return step


def make_eval_step(model_name: str, cfg: dict):
    model = REGISTRY[model_name]
    _, unravel, _ = init_flat(model_name, cfg)

    if model_name == "transformer":

        def step(params_flat, tokens):
            loss = _loss_lm(model, cfg, unravel, params_flat, tokens)
            return (loss, jnp.float32(0.0))

    else:

        def step(params_flat, x, y):
            params = unravel(params_flat)
            logits = model.apply(params, x, cfg)
            return (common.softmax_xent(logits, y), common.accuracy_count(logits, y))

    return step


# ---------------------------------------------------------------------------
# standalone kernel graphs (for the Rust bit-exactness parity artifacts)


def make_qsgd_quantize(n: int, s: int):
    def fn(v, wnorm, u):
        return (qsgd_kernels.qsgd_quantize(v, wnorm, u, s),)

    return fn


def make_qsgd_roundtrip(n: int, s: int, m: int):
    """quantize + dequantize composed — the full L1 hot path in one HLO."""

    def fn(v, wnorm, u):
        z = qsgd_kernels.qsgd_quantize(v, wnorm, u, s)
        return (qsgd_kernels.qsgd_dequantize(z, wnorm, s, m),)

    return fn


def make_multiscale_quantize(n: int, scales: tuple[int, ...]):
    def fn(v, wnorm, u):
        idx = ms_kernels.scale_index(v, wnorm, scales)
        z = ms_kernels.multiscale_quantize(v, wnorm, u, idx, scales)
        return (idx, z)

    return fn


def make_l2_norm(n: int):
    def fn(v):
        return (qsgd_kernels.l2_norm(v),)

    return fn
