"""Artifact-index contract tests: meta.json written by aot.py must satisfy
the invariants the Rust loader (rust/src/runtime/artifacts.rs) relies on.

These run against the real artifacts/ directory when present (make
artifacts); they skip cleanly otherwise so the pytest suite works in a
fresh checkout.
"""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
META = os.path.join(ART, "meta.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(META), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def meta():
    with open(META) as f:
        return json.load(f)


def test_bits_to_s_matches_rust_mapping(meta):
    """BITS_TO_S must be s = 2^(b-1) - 1 — mirrored in compress/kernels.rs."""
    for b, s in meta["bits_to_s"].items():
        assert s == 2 ** (int(b) - 1) - 1


def test_segments_tile_flat_vector(meta):
    for name, m in meta["models"].items():
        off = 0
        for seg in m["segments"]:
            assert seg["offset"] == off, f"{name}: gap before {seg['name']}"
            assert seg["len"] == int(np.prod(seg["shape"])) if seg["shape"] else 1
            off += seg["len"]
        assert off == m["param_count"], name


def test_params_bin_sizes(meta):
    for name, m in meta["models"].items():
        path = os.path.join(ART, m["params_file"])
        assert os.path.getsize(path) == 4 * m["param_count"], name
        params = np.fromfile(path, dtype="<f4")
        assert np.all(np.isfinite(params)), name
        assert np.linalg.norm(params) > 0, name


def test_step_inputs_consistent(meta):
    for name, m in meta["models"].items():
        for mstr, st in m["steps"].items():
            mm = int(mstr)
            assert st["workers"] == mm
            kinds = [i["kind"] for i in st["inputs"]]
            assert kinds[0] == "params"
            assert st["inputs"][0]["shape"] == [m["param_count"]]
            # worker axis leads every data tensor
            for i in st["inputs"][1:]:
                assert i["shape"][0] == mm, f"{name} M={mm}: {i}"
            for o in st["outputs"]:
                assert o["shape"][0] in (mm, mm * m["param_count"]) or o["shape"] == [
                    mm,
                    m["param_count"],
                ]
            assert os.path.exists(os.path.join(ART, st["file"]))


def test_hlo_files_are_parseable_text(meta):
    """HLO text (not proto) is the interchange format — cheap sanity check
    that every artifact really is module text with an entry computation."""
    for name, m in meta["models"].items():
        for st in m["steps"].values():
            head = open(os.path.join(ART, st["file"])).read(200)
            assert head.startswith("HloModule"), f"{name}: {st['file']}"
    for k in meta["kernels"].values():
        head = open(os.path.join(ART, k["file"])).read(200)
        assert head.startswith("HloModule"), k["file"]


def test_kernel_inventory_complete(meta):
    needed = {
        "qsgd_roundtrip",
        "multiscale_quantize",
        "l2_norm",
    } | {f"qsgd_quantize_s{s}" for s in (1, 7, 31, 127, 511, 2047)}
    assert needed <= set(meta["kernels"].keys())
