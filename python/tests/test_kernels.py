"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes, scales and value distributions; every comparison
is exact (the kernels are deterministic functions of (v, wnorm, u))."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import multiscale, qsgd, randk, ref

SCALES_SETS = [(1, 31), (7, 127), (7, 31, 511), (127, 2047)]
S_VALUES = [1, 7, 31, 127, 511, 2047]


def make_inputs(seed, n, spread=1.0):
    rng = np.random.default_rng(seed)
    v = jnp.asarray((rng.normal(size=n) * spread).astype(np.float32))
    u = jnp.asarray(rng.random(n).astype(np.float32))
    w = ref.l2_norm(v) * np.float32(1.0 + rng.random())
    return v, u, w


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=20000),
    s=st.sampled_from(S_VALUES),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_qsgd_quantize_matches_ref(n, s, seed):
    v, u, w = make_inputs(seed, n)
    z_ref = ref.qsgd_levels(v, w, u, s)
    z_pal = qsgd.qsgd_quantize(v, w, u, s)
    np.testing.assert_array_equal(np.asarray(z_ref), np.asarray(z_pal))


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=20000),
    s=st.sampled_from(S_VALUES),
    m=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_qsgd_dequantize_matches_ref(n, s, m, seed):
    v, u, w = make_inputs(seed, n)
    z = ref.qsgd_levels(v, w, u, s)
    d_ref = ref.qsgd_dequantize(z, w, s, m)
    d_pal = qsgd.qsgd_dequantize(z, w, s, m)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_pal), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(min_value=1, max_value=50000), seed=st.integers(min_value=0, max_value=2**31))
def test_l2_norm_matches_ref(n, seed):
    v, _, _ = make_inputs(seed, n)
    np.testing.assert_allclose(float(ref.l2_norm(v)), float(qsgd.l2_norm(v)), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=20000),
    scales=st.sampled_from(SCALES_SETS),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_multiscale_index_and_quantize_match_ref(n, scales, seed):
    v, u, w = make_inputs(seed, n)
    i_ref = ref.multiscale_scale_index(v, w, scales)
    i_pal = multiscale.scale_index(v, w, scales)
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_pal))
    z_ref = ref.multiscale_levels(v, w, u, i_ref, scales)
    z_pal = multiscale.multiscale_quantize(v, w, u, i_pal, scales)
    np.testing.assert_array_equal(np.asarray(z_ref), np.asarray(z_pal))
    d_ref = ref.multiscale_dequantize(z_ref, w, i_ref, scales, 4)
    d_pal = multiscale.multiscale_dequantize(z_pal, w, i_pal, scales, 4)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_pal), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=8, max_value=20000),
    frac=st.floats(min_value=0.01, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_randk_gather_scatter_match_ref(n, frac, seed):
    rng = np.random.default_rng(seed)
    v, _, _ = make_inputs(seed, n)
    k = max(1, int(n * frac))
    idx = jnp.asarray(rng.choice(n, size=k, replace=False).astype(np.int32))
    g_ref = ref.randk_gather(v, idx)
    g_pal = randk.randk_gather(v, idx)
    np.testing.assert_array_equal(np.asarray(g_ref), np.asarray(g_pal))
    s_ref = ref.randk_scatter(n, idx, g_ref)
    s_pal = randk.randk_scatter(n, idx, g_pal)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))


# ---------------------------------------------------------------------------
# analytic invariants of the oracle itself


def test_levels_are_integers_in_range():
    v, u, w = make_inputs(0, 5000)
    for s in S_VALUES:
        z = np.asarray(ref.qsgd_levels(v, w, u, s))
        assert np.all(z == np.round(z))
        assert np.all(np.abs(z) <= s)


def test_zero_norm_encodes_zero():
    v = jnp.zeros(100, jnp.float32)
    u = jnp.full(100, 0.5, jnp.float32)
    z = ref.qsgd_levels(v, jnp.float32(0.0), u, 7)
    assert np.all(np.asarray(z) == 0.0)
    zp = qsgd.qsgd_quantize(v, jnp.float32(0.0), u, 7)
    assert np.all(np.asarray(zp) == 0.0)


def test_unbiasedness_lemma5():
    """Monte-Carlo check of Lemma 5: E[Q_s(v)] = v."""
    rng = np.random.default_rng(1)
    n, s, trials = 64, 7, 4000
    v, _, w = make_inputs(1, n)
    acc = np.zeros(n, np.float64)
    for _ in range(trials):
        u = jnp.asarray(rng.random(n).astype(np.float32))
        z = ref.qsgd_levels(v, w, u, s)
        acc += np.asarray(ref.qsgd_dequantize(z, w, s, 1), np.float64)
    est = acc / trials
    se = 4.0 * float(w) / (s * np.sqrt(trials))
    np.testing.assert_allclose(est, np.asarray(v, np.float64), atol=se)


def test_variance_bound_lemma5():
    """E||Q(v)-v||^2 <= (1 + min(n/s^2, sqrt(n)/s)) ||w||^2."""
    rng = np.random.default_rng(2)
    n, trials = 256, 600
    v, _, w = make_inputs(2, n)
    for s in (1, 7, 31):
        err = 0.0
        for _ in range(trials):
            u = jnp.asarray(rng.random(n).astype(np.float32))
            z = ref.qsgd_levels(v, w, u, s)
            d = np.asarray(ref.qsgd_dequantize(z, w, s, 1), np.float64)
            err += np.sum((d - np.asarray(v, np.float64)) ** 2)
        err /= trials
        bound = (1 + min(n / s**2, np.sqrt(n) / s)) * float(w) ** 2
        assert err <= bound * 1.1, f"s={s}: {err} > {bound}"


def test_multiscale_eq10_constraint():
    """Every selected scale satisfies s* <= (||w||/|v_i|) * smin (eq. 10)."""
    v, _, w = make_inputs(3, 4096)
    scales = (7, 127)
    idx = np.asarray(ref.multiscale_scale_index(v, w, scales), np.int64)
    sel = np.asarray(sorted(scales))[idx]
    va = np.abs(np.asarray(v, np.float64))
    wf = float(w)
    ok = sel * va <= wf * min(scales) * (1 + 1e-6)
    assert np.all(ok)


def test_multiscale_levels_fit_smin_bits():
    """Levels at the shared scale stay <= smin + 1 — the wire-format claim."""
    v, u, w = make_inputs(4, 4096)
    scales = (7, 127)
    idx = ref.multiscale_scale_index(v, w, scales)
    z = np.asarray(ref.multiscale_levels(v, w, u, idx, scales))
    assert np.max(np.abs(z)) <= scales[0] + 1


@pytest.mark.parametrize("block", [256, 1024, 8192])
def test_block_size_invariance(block):
    """The BlockSpec tiling must not change results (padding correctness)."""
    v, u, w = make_inputs(5, 3000)
    z_ref = ref.qsgd_levels(v, w, u, 127)
    z_pal = qsgd.qsgd_quantize(v, w, u, 127, block=block)
    np.testing.assert_array_equal(np.asarray(z_ref), np.asarray(z_pal))
