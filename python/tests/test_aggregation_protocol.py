"""Protocol-level tests of the paper's core invariant, in pure Python:
the compression operators commute with summation (all-reduce compatibility,
DESIGN.md §4), end to end through the jnp oracle — the same property the
Rust side asserts on real model gradients in cluster_equivalence.rs.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def simulate_workers(seed, m, n):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(size=n).astype(np.float32)) for _ in range(m)]


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=6),
    n=st.integers(min_value=1, max_value=4000),
    s=st.sampled_from([1, 7, 127]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_qsgd_commutes_with_aggregation(m, n, s, seed):
    """decode(sum_m(levels_m)) == (1/M) * sum_m decode(levels_m)."""
    rng = np.random.default_rng(seed)
    grads = simulate_workers(seed, m, n)
    wnorm = jnp.float32(max(float(ref.l2_norm(g)) for g in grads))
    levels = []
    for g in grads:
        u = jnp.asarray(rng.random(n).astype(np.float32))
        levels.append(ref.qsgd_levels(g, wnorm, u, s))
    summed = sum(np.asarray(z, np.float64) for z in levels)
    path_a = np.asarray(ref.qsgd_dequantize(jnp.asarray(summed, jnp.float32), wnorm, s, m))
    path_b = np.mean(
        [np.asarray(ref.qsgd_dequantize(z, wnorm, s, 1)) for z in levels], axis=0
    )
    np.testing.assert_allclose(path_a, path_b, rtol=1e-5, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(min_value=2, max_value=5),
    n=st.integers(min_value=1, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_scale_sharing_makes_multiscale_commute(m, n, seed):
    """With the shared (min) scale index, multi-scale sums decode correctly;
    without sharing, workers' levels are at incompatible scales."""
    scales = (7, 127)
    rng = np.random.default_rng(seed)
    grads = simulate_workers(seed, m, n)
    wnorm = jnp.float32(max(float(ref.l2_norm(g)) for g in grads))

    # scale sharing: elementwise min over workers (paper Algorithm 2, line 7)
    per_worker_idx = [ref.multiscale_scale_index(g, wnorm, scales) for g in grads]
    shared_idx = jnp.min(jnp.stack(per_worker_idx), axis=0)

    levels = []
    for g in grads:
        u = jnp.asarray(rng.random(n).astype(np.float32))
        levels.append(ref.multiscale_levels(g, wnorm, u, shared_idx, scales))
    summed = jnp.asarray(sum(np.asarray(z, np.float64) for z in levels), jnp.float32)
    path_a = np.asarray(ref.multiscale_dequantize(summed, wnorm, shared_idx, scales, m))
    path_b = np.mean(
        [
            np.asarray(ref.multiscale_dequantize(z, wnorm, shared_idx, scales, 1))
            for z in levels
        ],
        axis=0,
    )
    np.testing.assert_allclose(path_a, path_b, rtol=1e-5, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=2000),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_shared_max_norm_dominates_every_worker(n, seed):
    grads = simulate_workers(seed, 4, n)
    wnorm = max(float(ref.l2_norm(g)) for g in grads)
    for g in grads:
        assert float(jnp.max(jnp.abs(g))) <= wnorm + 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_multiscale_index_monotone_in_magnitude(seed):
    """Smaller |v_i| must never get a *smaller* scale than larger |v_i|."""
    n = 1000
    scales = (7, 31, 127)
    rng = np.random.default_rng(seed)
    v = jnp.asarray(np.sort(np.abs(rng.normal(size=n))).astype(np.float32))
    w = ref.l2_norm(v) * jnp.float32(1.5)
    idx = np.asarray(ref.multiscale_scale_index(v, w, scales))
    # v ascending in magnitude => idx non-increasing
    assert np.all(np.diff(idx) <= 0 + 1e-9)
