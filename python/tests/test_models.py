"""L2 model-zoo shape/grad tests + flat-layout contract checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib
from compile.models import REGISTRY

CLASSIFIERS = ["mlp", "resnet_lite", "vgg_lite"]


@pytest.mark.parametrize("name", CLASSIFIERS)
def test_forward_shapes(name):
    mod = REGISTRY[name]
    cfg = mod.default_cfg()
    params = mod.init(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((4, *cfg["input"]), jnp.float32)
    logits = mod.apply(params, x, cfg)
    assert logits.shape == (4, cfg["classes"])
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_transformer_forward_shape():
    mod = REGISTRY["transformer"]
    cfg = dict(mod.default_cfg(), layers=2, d_model=64, heads=4, d_ff=128, seq=16)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, 16), jnp.int32)
    logits = mod.apply(params, x, cfg)
    assert logits.shape == (2, 16, cfg["vocab"])


@pytest.mark.parametrize("name", CLASSIFIERS)
def test_flat_segments_cover_params(name):
    cfg = REGISTRY[name].default_cfg()
    flat, _, segments = model_lib.init_flat(name, cfg)
    total = sum(s["len"] for s in segments)
    assert total == flat.size
    # segments are contiguous and ordered
    off = 0
    for s in segments:
        assert s["offset"] == off
        assert s["len"] == int(np.prod(s["shape"])) if s["shape"] else 1
        off += s["len"]


def test_train_step_multiworker_shapes():
    cfg = REGISTRY["mlp"].default_cfg()
    flat, _, _ = model_lib.init_flat("mlp", cfg)
    m, b = 3, 4
    step = model_lib.make_train_step("mlp", cfg, m)
    x = jnp.zeros((m, b, *cfg["input"]), jnp.float32)
    y = jnp.zeros((m, b), jnp.int32)
    loss, grads = step(flat, x, y)
    assert loss.shape == (m,)
    assert grads.shape == (m, flat.size)


def test_identical_shards_give_identical_grads():
    """vmap over the worker axis must not couple workers."""
    cfg = REGISTRY["mlp"].default_cfg()
    flat, _, _ = model_lib.init_flat("mlp", cfg)
    step = model_lib.make_train_step("mlp", cfg, 2)
    rng = np.random.default_rng(0)
    x1 = jnp.asarray(rng.normal(size=(4, *cfg["input"])).astype(np.float32))
    y1 = jnp.asarray(rng.integers(0, 10, size=(4,)).astype(np.int32))
    x = jnp.stack([x1, x1])
    y = jnp.stack([y1, y1])
    loss, grads = step(flat, x, y)
    np.testing.assert_allclose(np.asarray(loss[0]), np.asarray(loss[1]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(grads[0]), np.asarray(grads[1]), rtol=1e-5, atol=1e-7)


def test_grad_direction_decreases_loss():
    cfg = REGISTRY["mlp"].default_cfg()
    flat, _, _ = model_lib.init_flat("mlp", cfg)
    step = model_lib.make_train_step("mlp", cfg, 1)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 8, *cfg["input"])).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=(1, 8)).astype(np.int32))
    loss0, grads = step(flat, x, y)
    flat1 = flat - 0.01 * grads[0]
    loss1, _ = step(flat1, x, y)
    assert float(loss1[0]) < float(loss0[0])


def test_eval_step_counts_correct():
    cfg = REGISTRY["mlp"].default_cfg()
    flat, _, _ = model_lib.init_flat("mlp", cfg)
    ev = model_lib.make_eval_step("mlp", cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(16, *cfg["input"])).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=(16,)).astype(np.int32))
    loss, correct = ev(flat, x, y)
    assert 0.0 <= float(correct) <= 16.0
    assert float(loss) > 0.0


def test_transformer_loss_at_init_near_uniform():
    mod = REGISTRY["transformer"]
    cfg = dict(mod.default_cfg(), layers=2, d_model=64, heads=4, d_ff=128, seq=16)
    # build a matching init via model_lib internals
    import jax.flatten_util as fu

    params = mod.init(jax.random.PRNGKey(model_lib.SEED), cfg)
    flat, unravel = fu.ravel_pytree(params)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg["vocab"], size=(2, 17)).astype(np.int32))
    loss = model_lib._loss_lm(mod, cfg, unravel, flat, toks)
    uniform = np.log(cfg["vocab"])
    assert abs(float(loss) - uniform) < 1.0, f"init loss {loss} far from ln(V)={uniform}"
