//! Distributed-equivalence invariants:
//!  * M workers with the *same* data shard == 1 worker (modulo quantization
//!    noise; exactly for the dense path);
//!  * the all-reduce-compatibility property on real model gradients:
//!    decode(sum(encode_m)) == mean-of-decodes, per DESIGN.md §4;
//!  * wire accounting matches the paper's 32 + d·r formula on real models.

use repro::collectives::StepCtx;
use repro::compress::{kernels, Method};
use repro::netsim::{NetConfig, SimClock};
use repro::runtime::{Artifacts, Runtime, StepFn};
use repro::util::rng::Rng;

fn artifacts() -> Artifacts {
    Artifacts::load_default().expect("run `make artifacts` before cargo test")
}

/// Pull one real multi-worker gradient out of the mlp model.
fn real_grads(m: usize) -> (Vec<Vec<f32>>, usize) {
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    let model = arts.model("mlp").unwrap();
    let step = StepFn::load(&rt, &arts, model, m).unwrap();
    let params = arts.load_params(model).unwrap();
    let b = step.spec.batch;
    let dim = 32 * 32 * 3;
    let mut rng = Rng::new(0xFEED);
    let mut x = vec![0.0f32; m * b * dim];
    rng.fill_normal_f32(&mut x, 1.0);
    let y: Vec<i32> = (0..(m * b) as i32).map(|i| i % 10).collect();
    let out = step.run(&rt, &params, Some(&x), None, Some(&y)).unwrap();
    let p = model.param_count;
    let grads = (0..m).map(|w| out.grads[w * p..(w + 1) * p].to_vec()).collect();
    (grads, p)
}

#[test]
fn same_shard_multiworker_equals_singleworker_dense() {
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    let model = arts.model("mlp").unwrap();
    let params = arts.load_params(model).unwrap();
    let dim = 32 * 32 * 3;

    let step1 = StepFn::load(&rt, &arts, model, 1).unwrap();
    let b = step1.spec.batch;
    let mut rng = Rng::new(0xABCD);
    let mut x1 = vec![0.0f32; b * dim];
    rng.fill_normal_f32(&mut x1, 1.0);
    let y1: Vec<i32> = (0..b as i32).map(|i| i % 10).collect();
    let out1 = step1.run(&rt, &params, Some(&x1), None, Some(&y1)).unwrap();

    // two workers, both with the identical batch
    let step2 = StepFn::load(&rt, &arts, model, 2).unwrap();
    let mut x2 = x1.clone();
    x2.extend_from_slice(&x1);
    let mut y2 = y1.clone();
    y2.extend_from_slice(&y1);
    let out2 = step2.run(&rt, &params, Some(&x2), None, Some(&y2)).unwrap();

    let p = model.param_count;
    assert!((out2.losses[0] - out1.losses[0]).abs() < 1e-5);
    assert!((out2.losses[1] - out1.losses[0]).abs() < 1e-5);
    let err01 = repro::tensor::max_rel_err(&out2.grads[..p], &out1.grads);
    let err11 = repro::tensor::max_rel_err(&out2.grads[p..], &out1.grads);
    assert!(err01 < 1e-3, "worker0 grad must equal single-worker grad: {err01}");
    assert!(err11 < 1e-3, "worker1 grad must equal single-worker grad: {err11}");
}

#[test]
fn allreduce_compatibility_on_real_gradients() {
    // decode(allreduce_sum(levels)) == (1/M)·Σ decode(levels_m): exact,
    // because both sides divide the same integer sum by s·M — we verify the
    // stronger statement that summing levels THEN decoding equals averaging
    // individual decodes, on a real model gradient.
    let m = 4;
    let (grads, n) = real_grads(m);
    let s = kernels::s_for_bits(4);
    let wnorm = grads.iter().map(|g| kernels::l2_norm(g)).fold(0.0f32, f32::max);
    let mut rng = Rng::new(5);

    let mut levels: Vec<Vec<f32>> = Vec::new();
    let mut u = vec![0.0f32; n];
    for g in &grads {
        rng.fill_uniform_f32(&mut u);
        let mut z = vec![0.0f32; n];
        kernels::qsgd_encode(g, wnorm, &u, s, &mut z);
        levels.push(z);
    }

    // path A: sum in compressed domain, decode once
    let mut sum = vec![0.0f32; n];
    for z in &levels {
        repro::tensor::add_assign(&mut sum, z);
    }
    kernels::qsgd_decode_sum(&mut sum, wnorm, s, m);

    // path B: decode each, average
    let mut avg = vec![0.0f32; n];
    for z in &levels {
        let mut d = z.clone();
        kernels::qsgd_decode_sum(&mut d, wnorm, s, 1);
        repro::tensor::add_assign(&mut avg, &d);
    }
    repro::tensor::scale(1.0 / m as f32, &mut avg);

    let err = repro::tensor::max_rel_err(&sum, &avg);
    assert!(err < 1e-6, "compression must commute with aggregation: {err}");
}

#[test]
fn paper_wire_formula_on_real_model() {
    // 32 + d·r bits per worker, on the real mlp gradient dimension
    let m = 2;
    let (grads, n) = real_grads(m);
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    for (spec, expect_bits) in [
        ("qsgd-mn-8", 32.0 + n as f64 * 8.0),
        ("qsgd-mn-4", 32.0 + n as f64 * 4.0),
        ("qsgd-mn-2", 32.0 + n as f64 * 2.0),
        ("qsgd-mn-ts-2-6", 32.0 + n as f64 * 2.0 + n as f64 * 1.0),
        ("allreduce", n as f64 * 32.0),
    ] {
        let method = Method::parse(spec).unwrap();
        let mut agg = method.build(n, &[]).unwrap();
        let net = NetConfig::flat(m, 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut rng = Rng::new(1);
        let out = agg.aggregate(&refs, &mut ctx, &mut rng);
        assert_eq!(out.len(), n);
        assert_eq!(clock.bits_per_worker, expect_bits, "{spec}");
    }
}

#[test]
fn quantized_aggregate_tracks_dense_aggregate() {
    // relative L2 error of the 8-bit aggregate vs the dense mean on a real
    // gradient must be small (quantization noise ~ ||w||/s per coord).
    let m = 4;
    let (grads, n) = real_grads(m);
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let dense = repro::tensor::mean_of(&refs);

    let mut agg = Method::parse("qsgd-mn-8").unwrap().build(n, &[]).unwrap();
    let net = NetConfig::flat(m, 10.0);
    let mut clock = SimClock::default();
    let mut ctx = StepCtx::new(&net, &mut clock);
    let mut rng = Rng::new(2);
    let q = agg.aggregate(&refs, &mut ctx, &mut rng);

    let num: f64 = q
        .iter()
        .zip(&dense)
        .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let den = repro::tensor::norm2(&dense).max(1e-12);
    // Lemma 5 scale: error ||.||2 <= sqrt(min(n/s², √n/s))·||w|| / sqrt(M)
    let wnorm = grads.iter().map(|g| kernels::l2_norm(g)).fold(0.0f32, f32::max) as f64;
    let s = 127.0f64;
    let bound = ((n as f64).sqrt() / s).sqrt() * wnorm / (m as f64).sqrt();
    assert!(
        num <= bound * 2.0,
        "aggregate error {num} exceeds 2x Lemma-5 scale {bound} (dense norm {den})"
    );
}
