//! Cross-layer bit-exactness (DESIGN.md §5): the Rust hot-path quantizer
//! must equal the lowered Pallas kernel executed through PJRT, bit for bit,
//! on the same (v, wnorm, u) inputs.

use repro::compress::kernels;
use repro::runtime::{Artifacts, Input, Output, Runtime};
use repro::util::rng::Rng;

fn artifacts() -> Artifacts {
    Artifacts::load_default().expect("run `make artifacts` before cargo test")
}

fn exec_kernel(
    rt: &Runtime,
    arts: &Artifacts,
    name: &str,
    inputs: &[Input<'_>],
) -> Vec<Vec<f32>> {
    let k = arts.kernel(name).unwrap();
    let exe = rt.load(&arts.path_of(&k.file)).unwrap();
    rt.execute(&exe, inputs)
        .unwrap()
        .into_iter()
        .map(|o| match o {
            Output::F32(v) => v,
            other => panic!("expected f32, got {other:?}"),
        })
        .collect()
}

fn test_vectors(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, f32) {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut v, 1.0);
    // sprinkle exact zeros and large coords — quantizer edge cases
    for i in (0..n).step_by(97) {
        v[i] = 0.0;
    }
    v[1] = repro::tensor::norm_inf(&v) * 2.0;
    let mut u = vec![0.0f32; n];
    rng.fill_uniform_f32(&mut u);
    let wnorm = kernels::l2_norm(&v) * 1.25;
    (v, u, wnorm)
}

#[test]
fn qsgd_quantize_bit_exact_all_scales() {
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    for s in [1usize, 7, 31, 127, 511, 2047] {
        let name = format!("qsgd_quantize_s{s}");
        let k = arts.kernel(&name).unwrap();
        let n = k.n;
        let (v, u, wnorm) = test_vectors(n, 1000 + s as u64);
        let outs = exec_kernel(
            &rt,
            &arts,
            &name,
            &[
                Input::F32(&v, vec![n as i64]),
                Input::F32(std::slice::from_ref(&wnorm), vec![]),
                Input::F32(&u, vec![n as i64]),
            ],
        );
        let hlo_levels = &outs[0];

        let mut rust_levels = vec![0.0f32; n];
        kernels::qsgd_encode(&v, wnorm, &u, s, &mut rust_levels);

        let mismatches: Vec<usize> = (0..n)
            .filter(|&i| rust_levels[i] != hlo_levels[i])
            .take(5)
            .collect();
        assert!(
            mismatches.is_empty(),
            "s={s}: {} mismatches, first at {:?} (rust {:?} vs hlo {:?})",
            (0..n).filter(|&i| rust_levels[i] != hlo_levels[i]).count(),
            mismatches,
            mismatches.iter().map(|&i| rust_levels[i]).collect::<Vec<_>>(),
            mismatches.iter().map(|&i| hlo_levels[i]).collect::<Vec<_>>(),
        );
    }
}

#[test]
fn multiscale_quantize_bit_exact() {
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    let k = arts.kernel("multiscale_quantize").unwrap();
    let n = k.n;
    let scales: Vec<usize> = k
        .extra
        .req("scales")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap())
        .collect();
    let (v, u, wnorm) = test_vectors(n, 77);
    let outs = exec_kernel(
        &rt,
        &arts,
        "multiscale_quantize",
        &[
            Input::F32(&v, vec![n as i64]),
            Input::F32(std::slice::from_ref(&wnorm), vec![]),
            Input::F32(&u, vec![n as i64]),
        ],
    );
    let (hlo_idx, hlo_levels) = (&outs[0], &outs[1]);

    let mut rust_idx = vec![0u8; n];
    kernels::multiscale_scale_index(&v, wnorm, &scales, &mut rust_idx);
    let mut rust_levels = vec![0.0f32; n];
    kernels::multiscale_encode(&v, wnorm, &u, &rust_idx, &scales, &mut rust_levels);

    for i in 0..n {
        assert_eq!(rust_idx[i] as f32, hlo_idx[i], "scale idx mismatch at {i}");
        assert_eq!(rust_levels[i], hlo_levels[i], "level mismatch at {i}");
    }
}

#[test]
fn l2_norm_close_to_pallas_reduction() {
    // The Pallas norm reduces in f32 block partials; the Rust norm uses an
    // f64 accumulator. Equality is within f32 rounding of the partials.
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    let k = arts.kernel("l2_norm").unwrap();
    let n = k.n;
    let (v, _, _) = test_vectors(n, 4242);
    let outs = exec_kernel(&rt, &arts, "l2_norm", &[Input::F32(&v, vec![n as i64])]);
    let hlo = outs[0][0];
    let rust = kernels::l2_norm(&v);
    let rel = ((hlo - rust) / rust).abs();
    assert!(rel < 1e-5, "norm mismatch: hlo={hlo} rust={rust} rel={rel}");
}

#[test]
fn qsgd_roundtrip_decode_matches() {
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    let k = arts.kernel("qsgd_roundtrip").unwrap();
    let (n, s, m) = (
        k.n,
        k.extra.req("s").unwrap().as_usize().unwrap(),
        k.extra.req("m").unwrap().as_usize().unwrap(),
    );
    let (v, u, wnorm) = test_vectors(n, 9);
    let outs = exec_kernel(
        &rt,
        &arts,
        "qsgd_roundtrip",
        &[
            Input::F32(&v, vec![n as i64]),
            Input::F32(std::slice::from_ref(&wnorm), vec![]),
            Input::F32(&u, vec![n as i64]),
        ],
    );
    let hlo = &outs[0];
    let mut rust = vec![0.0f32; n];
    kernels::qsgd_encode(&v, wnorm, &u, s, &mut rust);
    kernels::qsgd_decode_sum(&mut rust, wnorm, s, m);
    for i in 0..n {
        let d = (rust[i] - hlo[i]).abs();
        assert!(
            d <= f32::EPSILON * rust[i].abs().max(1.0),
            "roundtrip mismatch at {i}: {} vs {}",
            rust[i],
            hlo[i]
        );
    }
}
