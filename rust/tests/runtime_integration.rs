//! Integration: artifact loading + PJRT execution of the lowered L2 steps.
//!
//! Requires `make artifacts` (fails with a clear message otherwise).

use repro::runtime::{Artifacts, EvalFn, Runtime, StepFn};

fn artifacts() -> Artifacts {
    Artifacts::load_default().expect("run `make artifacts` before cargo test")
}

#[test]
fn meta_inventory_is_complete() {
    let arts = artifacts();
    assert!(arts.models.contains_key("mlp"));
    for (name, m) in &arts.models {
        assert!(m.param_count > 0, "{name}");
        assert!(!m.segments.is_empty(), "{name}");
        let seg_total: usize = m.segments.iter().map(|s| s.len).sum();
        assert_eq!(seg_total, m.param_count, "{name}: segments must tile the flat vector");
        for spec in m.steps.values() {
            assert!(arts.path_of(&spec.file).exists(), "{name}: missing {}", spec.file);
        }
        assert!(arts.path_of(&m.eval.file).exists());
        assert!(arts.path_of(&m.params_file).exists());
    }
    assert_eq!(arts.s_for_bits(8).unwrap(), 127);
    assert!(arts.s_for_bits(3).is_err());
}

#[test]
fn params_bin_loads_with_finite_values() {
    let arts = artifacts();
    for m in arts.models.values() {
        let p = arts.load_params(m).unwrap();
        assert_eq!(p.len(), m.param_count);
        assert!(p.iter().all(|x| x.is_finite()));
        let norm = repro::tensor::norm2(&p);
        assert!(norm > 0.0, "{}: all-zero init?", m.name);
    }
}

#[test]
fn mlp_step_executes_and_grads_are_finite() {
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    let model = arts.model("mlp").unwrap();
    let step = StepFn::load(&rt, &arts, model, 2).unwrap();
    let params = arts.load_params(model).unwrap();
    let b = step.spec.batch;
    let dim: usize = 32 * 32 * 3;
    let x = vec![0.1f32; 2 * b * dim];
    let y: Vec<i32> = (0..2 * b as i32).map(|i| i % 10).collect();
    let out = step.run(&rt, &params, Some(&x), None, Some(&y)).unwrap();
    assert_eq!(out.losses.len(), 2);
    assert!(out.losses.iter().all(|l| l.is_finite() && *l > 0.0));
    assert_eq!(out.grads.len(), 2 * model.param_count);
    assert!(out.grads.iter().all(|g| g.is_finite()));
    assert!(repro::tensor::norm2(&out.grads) > 1e-6, "gradient must be non-trivial");
}

#[test]
fn eval_step_runs() {
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    let model = arts.model("mlp").unwrap();
    let ev = EvalFn::load(&rt, &arts, model).unwrap();
    let params = arts.load_params(model).unwrap();
    let n = ev.spec.batch;
    let x = vec![0.0f32; n * 32 * 32 * 3];
    let y = vec![0i32; n];
    let (loss, correct) = ev.run(&rt, &params, Some(&x), None, Some(&y)).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=n as f32).contains(&correct));
}

#[test]
fn executable_cache_reuses_compilations() {
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    let model = arts.model("mlp").unwrap();
    let p1 = rt.load(&arts.path_of(&model.eval.file)).unwrap();
    let p2 = rt.load(&arts.path_of(&model.eval.file)).unwrap();
    assert!(std::rc::Rc::ptr_eq(&p1, &p2), "second load must hit the cache");
}

#[test]
fn step_shape_validation_errors() {
    let arts = artifacts();
    let rt = Runtime::new().unwrap();
    let model = arts.model("mlp").unwrap();
    let step = StepFn::load(&rt, &arts, model, 1).unwrap();
    let params = arts.load_params(model).unwrap();
    // missing labels
    let x = vec![0.0f32; step.spec.batch * 32 * 32 * 3];
    assert!(step.run(&rt, &params, Some(&x), None, None).is_err());
    // wrong param length
    assert!(step
        .run(&rt, &params[..10], Some(&x), None, Some(&vec![0; step.spec.batch]))
        .is_err());
    // no lowered step for absurd M
    assert!(StepFn::load(&rt, &arts, model, 999).is_err());
}
