//! Flight-recorder invariants (PR 9 acceptance):
//!
//! * **Inert when off / complete when on** — over the PR 8 parity matrix
//!   (qsgd-mn-4 × {flat ring, hier 4×4, tree} × {strict, partial cohort,
//!   lossy wire}), a traced run's output and all twelve SimClock ledgers
//!   are bit-identical to the untraced run, and every SimClock category's
//!   step delta equals the sum of its spans (re-verified here from the raw
//!   spans, independently of `LedgerAudit`).
//! * **Chrome export** — a traced hierarchical lossy run emits trace-event
//!   JSON that parses back, keeps every track's complete events monotone
//!   and non-overlapping, and whose per-level wire tracks reconcile exactly
//!   with `hop_bits_intra` / `hop_bits_inter` / `retrans_bits`.
//!
//! Like the rest of this tier the tests run without PJRT: they drive the
//! bucketed control plane through `StepCtx` directly.

use repro::collectives::{packed, IntegrityConfig, StepCtx};
use repro::compress::{Aggregator, Method};
use repro::control::{build_plane, ControlConfig};
use repro::netsim::{Algo, FaultPlan, HopFault, LinkLevel, NetConfig, SimClock};
use repro::runtime::{contiguous_segments, Segment};
use repro::trace::{Cat, SpanKind, Tracer};
use repro::util::json::Json;
use repro::util::rng::Rng;

#[derive(Clone, Copy)]
struct Topo {
    name: &'static str,
    m: usize,
    g: usize,
    hier: bool,
    algo: Algo,
}

const TOPOS: [Topo; 3] = [
    Topo { name: "flat-ring", m: 8, g: 1, hier: false, algo: Algo::Ring },
    Topo { name: "hier-4x4", m: 16, g: 4, hier: true, algo: Algo::Ring },
    Topo { name: "tree", m: 8, g: 1, hier: false, algo: Algo::Tree },
];

#[derive(Clone, Copy, PartialEq)]
enum Scenario {
    Strict,
    Partial,
    Lossy,
}

impl Scenario {
    fn name(&self) -> &'static str {
        match self {
            Scenario::Strict => "strict",
            Scenario::Partial => "partial",
            Scenario::Lossy => "lossy",
        }
    }
}

fn net_for(m: usize, g: usize, algo: Algo) -> NetConfig {
    let mut net = NetConfig::flat(m, 10.0);
    net.gpus_per_node = g.max(1);
    net.algo = algo;
    net
}

fn grads_for(m: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut grng = Rng::new(seed);
    (0..m)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            grng.fill_normal_f32(&mut v, 1.0);
            v
        })
        .collect()
}

/// A deterministic step at which the wire plan actually faults at least one
/// hop delivery (so the lossy scenario exercises the retransmit spans).
fn faulting_step(plan: &FaultPlan, topo: &Topo) -> usize {
    let hops = packed::schedule_for_topo(topo.algo, false, 1, topo.hier, topo.g, topo.m)
        .as_dyn()
        .hops(topo.m);
    (0..512)
        .find(|&s| {
            (0..topo.m)
                .any(|w| (0..hops).any(|h| plan.hop_fault(s, w, h, 0) != HopFault::None))
        })
        .expect("a 4% per-hop fault rate must fire within 512 steps")
}

/// Run one aggregate under the scenario; `tracer` arms the flight recorder.
fn run_once(
    topo: &Topo,
    scenario: Scenario,
    grads: &[Vec<f32>],
    n: usize,
    segments: &[Segment],
    plan: &FaultPlan,
    fault_step: usize,
    seed: u64,
    mut tracer: Option<&mut Tracer>,
) -> (Vec<f32>, SimClock) {
    let method = Method::parse("qsgd-mn-4").unwrap();
    let mut plane = build_plane(&method, &ControlConfig::new(3), n, segments).unwrap();
    let mut clock = SimClock::default();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let mut rng = Rng::new(seed ^ 0x51EED);
    let out = match scenario {
        Scenario::Strict | Scenario::Lossy => {
            let net = net_for(topo.m, topo.g, topo.algo);
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.hier = topo.hier;
            if scenario == Scenario::Lossy {
                ctx.integrity = Some(IntegrityConfig::default());
                ctx.wire_faults = Some((plan, fault_step));
            }
            ctx.tracer = tracer.as_deref_mut();
            plane.aggregate(&refs, &mut ctx, &mut rng)
        }
        Scenario::Partial => {
            // worker 2 dropped: the id-keyed partial-cohort seam over a
            // wire rebuilt for the live width
            let live: Vec<usize> = (0..topo.m).filter(|&w| w != 2).collect();
            let slices: Vec<&[f32]> = live.iter().map(|&w| refs[w]).collect();
            let net = net_for(live.len(), topo.g, topo.algo);
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.hier = topo.hier;
            ctx.tracer = tracer.as_deref_mut();
            plane.aggregate_cohort(&slices, &live, &mut ctx, &mut rng)
        }
    };
    if let Some(t) = tracer {
        t.end_step(&clock);
    }
    (out, clock)
}

fn assert_clock_eq(a: &SimClock, b: &SimClock, what: &str) {
    assert_eq!(a.comm_s, b.comm_s, "{what}: comm_s");
    assert_eq!(a.compute_s, b.compute_s, "{what}: compute_s");
    assert_eq!(a.encode_s, b.encode_s, "{what}: encode_s");
    assert_eq!(a.decode_s, b.decode_s, "{what}: decode_s");
    assert_eq!(a.bits_per_worker, b.bits_per_worker, "{what}: bits_per_worker");
    assert_eq!(
        a.hop_bits_per_worker, b.hop_bits_per_worker,
        "{what}: hop_bits_per_worker"
    );
    assert_eq!(a.hop_bits_intra, b.hop_bits_intra, "{what}: hop_bits_intra");
    assert_eq!(a.hop_bits_inter, b.hop_bits_inter, "{what}: hop_bits_inter");
    assert_eq!(a.hidden_comm_s, b.hidden_comm_s, "{what}: hidden_comm_s");
    assert_eq!(a.straggler_wait_s, b.straggler_wait_s, "{what}: straggler_wait_s");
    assert_eq!(a.retrans_s, b.retrans_s, "{what}: retrans_s");
    assert_eq!(a.retrans_bits, b.retrans_bits, "{what}: retrans_bits");
}

/// Independent re-verification of the span accounting, from the raw spans
/// (not through `LedgerAudit`, which already ran inside `end_step`).
fn verify_spans(tracer: &Tracer, clock: &SimClock, what: &str) {
    assert_eq!(tracer.violation_count(), 0, "{what}: audit violations");
    assert_eq!(tracer.steps().len(), 1, "{what}: one recorded step");
    let st = &tracer.steps()[0];
    assert!(st.violations.is_empty(), "{what}: {:?}", st.violations);

    // (1) per-category chains tile [0, delta] exactly.
    for cat in Cat::ALL {
        let want = cat.of(clock);
        let chain: Vec<_> = st
            .spans
            .iter()
            .filter(|sp| sp.cat == cat && !sp.kind.is_instant())
            .collect();
        if chain.is_empty() {
            assert_eq!(want, 0.0, "{what}: {} charged without spans", cat.name());
            continue;
        }
        assert_eq!(chain[0].t0, 0.0, "{what}: {} chain start", cat.name());
        for w in chain.windows(2) {
            assert_eq!(
                w[1].t0,
                w[0].t1,
                "{what}: {} chain gap between {} and {}",
                cat.name(),
                w[0].kind.name(),
                w[1].kind.name()
            );
        }
        assert_eq!(
            chain.last().unwrap().t1,
            want,
            "{what}: {} span-sum != ledger delta",
            cat.name()
        );
    }

    // (2) bit books are exact sums of the spans'.
    let payload: f64 = st.spans.iter().map(|sp| sp.bits).sum();
    assert_eq!(payload, clock.bits_per_worker, "{what}: payload bit book");
    let mut intra = 0.0;
    let mut inter = 0.0;
    let mut rtx = 0.0;
    for sp in &st.spans {
        match sp.kind {
            SpanKind::Hop { level, wire_bits, .. }
            | SpanKind::Checksum { level, wire_bits, .. } => match level {
                LinkLevel::Intra => intra += wire_bits,
                LinkLevel::Inter => inter += wire_bits,
            },
            SpanKind::Retransmit { wire_bits, .. } => rtx += wire_bits,
            _ => {}
        }
    }
    assert_eq!(intra, clock.hop_bits_intra, "{what}: intra wire book");
    assert_eq!(inter, clock.hop_bits_inter, "{what}: inter wire book");
    assert_eq!(
        intra + inter,
        clock.hop_bits_per_worker,
        "{what}: hop wire book"
    );
    assert_eq!(rtx, clock.retrans_bits, "{what}: retransmit wire book");
}

#[test]
fn traced_matches_untraced_and_spans_sum_to_deltas() {
    let n = 1543usize;
    let seg_lens = [600usize, 400, 300, 150, 93];
    let segments = contiguous_segments(&seg_lens);
    let plan = FaultPlan::wire(0x9E7A, 0.02, 0.02);

    for topo in &TOPOS {
        let fault_step = faulting_step(&plan, topo);
        let seed = 0x7ACE + topo.m as u64;
        let grads = grads_for(topo.m, n, seed);
        for scenario in [Scenario::Strict, Scenario::Partial, Scenario::Lossy] {
            let what = format!("{} / {}", topo.name, scenario.name());

            let (out_off, clk_off) = run_once(
                topo, scenario, &grads, n, &segments, &plan, fault_step, seed, None,
            );
            let mut tracer = Tracer::new();
            let (out_on, clk_on) = run_once(
                topo,
                scenario,
                &grads,
                n,
                &segments,
                &plan,
                fault_step,
                seed,
                Some(&mut tracer),
            );

            // inert when on: output and every ledger bit-identical
            assert_eq!(out_on, out_off, "{what}: traced output diverged");
            assert_clock_eq(&clk_on, &clk_off, &what);
            // complete when on: span accounting closes every ledger
            verify_spans(&tracer, &clk_on, &what);
            if scenario == Scenario::Lossy {
                assert!(
                    clk_on.retrans_bits > 0.0,
                    "{what}: lossy scenario must exercise retransmits"
                );
            }
        }
    }
}

#[test]
fn chrome_trace_parses_monotone_and_reconciles_wire_tracks() {
    // A 3-step traced hierarchical lossy run — the acceptance scenario.
    let topo = TOPOS[1];
    assert!(topo.hier);
    let n = 1543usize;
    let seg_lens = [600usize, 400, 300, 150, 93];
    let segments = contiguous_segments(&seg_lens);
    let plan = FaultPlan::wire(0x9E7A, 0.05, 0.05);
    let fault_step = faulting_step(&plan, &topo);
    let seed = 0xC42;
    let grads = grads_for(topo.m, n, seed);
    let method = Method::parse("qsgd-mn-4").unwrap();
    let mut plane = build_plane(&method, &ControlConfig::new(3), n, &segments).unwrap();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let net = net_for(topo.m, topo.g, topo.algo);

    let mut tracer = Tracer::new();
    let mut run_clock = SimClock::default();
    for step in 0..3 {
        let mut clock = SimClock::default();
        tracer.begin_step(step, run_clock.total_s());
        {
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.hier = topo.hier;
            ctx.integrity = Some(IntegrityConfig::default());
            ctx.wire_faults = Some((&plan, fault_step + step));
            ctx.tracer = Some(&mut tracer);
            let mut rng = Rng::new(seed ^ 0x51EED ^ step as u64);
            plane.aggregate(&refs, &mut ctx, &mut rng);
        }
        tracer.end_step(&clock);
        run_clock.accumulate(&clock);
    }
    assert_eq!(tracer.violation_count(), 0);

    let text = tracer.to_chrome(topo.m).to_string();
    let parsed = Json::parse(&text).expect("chrome trace must parse");
    let events = parsed.req("traceEvents").unwrap().as_arr().unwrap();

    let mut last_end: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
    let mut worker_tracks = std::collections::BTreeSet::new();
    let (mut wire_intra, mut wire_inter, mut wire_rtx) = (0.0f64, 0.0f64, 0.0f64);
    for e in events {
        if e.req("ph").unwrap().as_str().unwrap() != "X" {
            continue;
        }
        let pid = e.req("pid").unwrap().as_usize().unwrap();
        let tid = e.req("tid").unwrap().as_usize().unwrap();
        let ts = e.req("ts").unwrap().as_f64().unwrap();
        let dur = e.req("dur").unwrap().as_f64().unwrap();
        assert!(dur >= 0.0);
        let prev = last_end.get(&(pid, tid)).copied().unwrap_or(f64::NEG_INFINITY);
        assert!(
            ts + 1e-3 >= prev,
            "track ({pid},{tid}): event at {ts}us overlaps previous end {prev}us"
        );
        last_end.insert((pid, tid), ts + dur);
        if pid == 0 {
            worker_tracks.insert(tid);
        } else {
            let name = e.req("name").unwrap().as_str().unwrap();
            let bits = e.req("args").unwrap().req("wire_bits").unwrap().as_f64().unwrap();
            match (name, tid) {
                ("hop", 0) | ("checksum", 0) => wire_intra += bits,
                ("hop", 1) | ("checksum", 1) => wire_inter += bits,
                ("retransmit", _) => wire_rtx += bits,
                other => panic!("unexpected wire-track event {other:?}"),
            }
        }
    }
    assert_eq!(worker_tracks.len(), topo.m, "one track per worker");

    // Per-level wire tracks reconcile exactly with the run totals.
    let totals = parsed.req("reproTotals").unwrap();
    let tot = |k: &str| totals.req(k).unwrap().as_f64().unwrap();
    assert_eq!(wire_intra, tot("hop_bits_intra"), "intra wire track");
    assert_eq!(wire_inter, tot("hop_bits_inter"), "inter wire track");
    assert_eq!(wire_rtx, tot("retrans_bits"), "retransmit wire total");
    assert_eq!(wire_intra + wire_inter, tot("hop_bits_per_worker"));
    assert_eq!(tot("violations"), 0.0);
    // the hierarchical schedule genuinely split the books
    assert!(wire_intra > 0.0 && wire_inter > 0.0, "hier run must use both levels");
}

#[test]
fn jsonl_export_reconciles_per_step() {
    let topo = TOPOS[0];
    let n = 777usize;
    let grads = grads_for(topo.m, n, 0xBEA7);
    let method = Method::parse("qsgd-mn-4").unwrap();
    let mut plane = build_plane(&method, &ControlConfig::new(2), n, &[]).unwrap();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let net = net_for(topo.m, topo.g, topo.algo);

    let mut tracer = Tracer::new();
    let mut run_clock = SimClock::default();
    for step in 0..2 {
        let mut clock = SimClock::default();
        tracer.begin_step(step, run_clock.total_s());
        {
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.tracer = Some(&mut tracer);
            let mut rng = Rng::new(0xBEA7 ^ step as u64);
            plane.aggregate(&refs, &mut ctx, &mut rng);
        }
        tracer.end_step(&clock);
        run_clock.accumulate(&clock);
    }

    let dir = std::env::temp_dir().join("repro_trace_invariants");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.trace.jsonl");
    tracer.write_jsonl(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 4, "meta + 2 steps + run footer");
    assert_eq!(lines[0].req("type").unwrap().as_str().unwrap(), "meta");
    let mut sum_comm = 0.0;
    for l in &lines[1..3] {
        assert_eq!(l.req("type").unwrap().as_str().unwrap(), "step");
        assert_eq!(l.req("violations").unwrap().as_f64().unwrap(), 0.0);
        let intra = l.req("hop_bits_intra").unwrap().as_f64().unwrap();
        let inter = l.req("hop_bits_inter").unwrap().as_f64().unwrap();
        let hop = l.req("hop_bits_per_worker").unwrap().as_f64().unwrap();
        assert_eq!(intra + inter, hop, "per-step per-level split");
        // the per-category span sums mirror the flattened delta
        let span_comm =
            l.req("span_s").unwrap().req("comm").unwrap().as_f64().unwrap();
        let comm = l.req("comm_s").unwrap().as_f64().unwrap();
        assert!((span_comm - comm).abs() <= 1e-12 * comm.abs().max(1.0));
        sum_comm += comm;
    }
    let run = &lines[3];
    assert_eq!(run.req("type").unwrap().as_str().unwrap(), "run");
    assert_eq!(run.req("steps").unwrap().as_f64().unwrap(), 2.0);
    let total_comm = run.req("comm_s").unwrap().as_f64().unwrap();
    assert!((total_comm - sum_comm).abs() <= 1e-12 * total_comm.abs().max(1.0));
    std::fs::remove_file(&path).ok();
}
