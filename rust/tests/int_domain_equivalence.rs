//! Integer-domain equivalence (the tentpole acceptance property):
//!
//! For random gradients, worker counts, and bit-widths, the fused
//! encode→pack→ring-allreduce→unpack→decode path — and the production
//! integer-domain aggregators — produce **bit-identical** output to the
//! legacy f32-level pipeline, under every reduction algorithm. Integer sums
//! are exact, so comparisons are `assert_eq`-strict (no tolerance).
//!
//! These tests run without lowered artifacts or a PJRT backend: they
//! exercise L3 (kernels, bitpack, collectives, aggregators) only.

use repro::collectives::{self, StepCtx};
use repro::compress::{fused, kernels, Aggregator, Method};
use repro::netsim::{Algo, NetConfig, SimClock};
use repro::util::quickcheck::{check, ensure};
use repro::util::rng::Rng;

fn random_grads(g: &mut repro::util::quickcheck::Gen, m: usize, n: usize) -> Vec<Vec<f32>> {
    (0..m).map(|_| g.vec_normal(n, 1.0)).collect()
}

fn max_norm(refs: &[&[f32]]) -> f32 {
    refs.iter().map(|v| kernels::l2_norm(v)).fold(0.0f32, f32::max)
}

fn run_aggregator(
    spec: &str,
    n: usize,
    grads: &[Vec<f32>],
    seed: u64,
    algo: Algo,
) -> Vec<f32> {
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let mut agg = Method::parse(spec).unwrap().build(n, &[]).unwrap();
    let mut net = NetConfig::flat(grads.len(), 10.0);
    net.algo = algo;
    let mut clock = SimClock::default();
    let mut ctx = StepCtx::new(&net, &mut clock);
    let mut rng = Rng::new(seed);
    agg.aggregate(&refs, &mut ctx, &mut rng)
}

fn f32_allreduce(bufs: &mut [Vec<f32>], algo: Algo) {
    match algo {
        Algo::Ring => collectives::ring_allreduce_sum(bufs),
        Algo::Tree => collectives::tree_allreduce_sum(bufs),
        Algo::Naive => collectives::naive_allreduce_sum(bufs),
    }
}

/// Legacy f32-level QSGD-MN pipeline, replicated through public APIs.
fn reference_qsgd(grads: &[&[f32]], bits: usize, seed: u64, algo: Algo) -> Vec<f32> {
    let m = grads.len();
    let n = grads[0].len();
    let s = kernels::s_for_bits(bits);
    let wnorm = max_norm(grads);
    let rng = Rng::new(seed);
    let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(m);
    for (w, g) in grads.iter().enumerate() {
        let mut wrng = rng.derive(&[w as u64]);
        let mut uni = vec![0.0f32; n];
        wrng.fill_uniform_f32(&mut uni);
        let mut buf = vec![0.0f32; n];
        kernels::qsgd_encode(g, wnorm, &uni, s, &mut buf);
        bufs.push(buf);
    }
    f32_allreduce(&mut bufs, algo);
    let mut sum = bufs.swap_remove(0);
    kernels::qsgd_decode_sum(&mut sum, wnorm, s, m);
    sum
}

/// Legacy f32-level QSGD-MN-TS pipeline, replicated through public APIs.
fn reference_multiscale(grads: &[&[f32]], scales: &[usize], seed: u64, algo: Algo) -> Vec<f32> {
    let m = grads.len();
    let n = grads[0].len();
    let wnorm = max_norm(grads);
    let rng = Rng::new(seed);

    let mut proposals: Vec<Vec<u8>> = Vec::with_capacity(m);
    for g in grads {
        let mut idx = vec![0u8; n];
        kernels::multiscale_scale_index(g, wnorm, scales, &mut idx);
        proposals.push(idx);
    }
    let shared = collectives::min_allreduce_u8(&proposals);

    let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(m);
    for (w, g) in grads.iter().enumerate() {
        let mut wrng = rng.derive(&[w as u64]);
        let mut uni = vec![0.0f32; n];
        wrng.fill_uniform_f32(&mut uni);
        let mut buf = vec![0.0f32; n];
        kernels::multiscale_encode(g, wnorm, &uni, &shared, scales, &mut buf);
        bufs.push(buf);
    }
    f32_allreduce(&mut bufs, algo);
    let mut sum = bufs.swap_remove(0);
    kernels::multiscale_decode_sum(&mut sum, wnorm, &shared, scales, m);
    sum
}

/// Legacy f32-level GRandK-MN pipeline, replicated through public APIs.
fn reference_grandk(grads: &[&[f32]], bits: usize, k: usize, seed: u64, algo: Algo) -> Vec<f32> {
    let m = grads.len();
    let n = grads[0].len();
    let s = kernels::s_for_bits(bits);
    let root = Rng::new(seed);
    let idx = root.derive(&[0x6B6579]).sample_distinct(n, k);

    let dense: Vec<Vec<f32>> = grads
        .iter()
        .map(|g| idx.iter().map(|&i| g[i]).collect())
        .collect();
    let dense_refs: Vec<&[f32]> = dense.iter().map(|d| d.as_slice()).collect();
    let wnorm = max_norm(&dense_refs);

    let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(m);
    for (w, d) in dense.iter().enumerate() {
        let mut wrng = root.derive(&[w as u64]);
        let mut uni = vec![0.0f32; k];
        wrng.fill_uniform_f32(&mut uni);
        let mut buf = vec![0.0f32; k];
        kernels::qsgd_encode(d, wnorm, &uni, s, &mut buf);
        bufs.push(buf);
    }
    f32_allreduce(&mut bufs, algo);
    let mut sum = bufs.swap_remove(0);
    kernels::qsgd_decode_sum(&mut sum, wnorm, s, m);

    let mut out = vec![0.0f32; n];
    for (j, &i) in idx.iter().enumerate() {
        out[i] = sum[j];
    }
    out
}

fn pick_algo(g: &mut repro::util::quickcheck::Gen) -> Algo {
    *g.pick(&[Algo::Ring, Algo::Tree, Algo::Naive])
}

#[test]
fn prop_qsgd_aggregator_bit_identical_across_algos() {
    check("QSGD-MN int == f32 reference (ring/tree/naive)", 60, |g| {
        let m = g.usize_in(1, 8);
        let bits = *g.pick(&[2usize, 3, 4, 6, 8, 12, 16]);
        let n = g.size_scaled(1, 2500);
        let grads = random_grads(g, m, n);
        let seed = g.rng().next_u64();
        let algo = pick_algo(g);
        let got = run_aggregator(&format!("qsgd-mn-{bits}"), n, &grads, seed, algo);
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let want = reference_qsgd(&refs, bits, seed, algo);
        if got != want {
            let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
            return Err(format!(
                "bits={bits} m={m} n={n} algo={algo:?}: first diff at {bad}: {} vs {}",
                got[bad], want[bad]
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_multiscale_aggregator_bit_identical_across_algos() {
    check("QSGD-MN-TS int == f32 reference", 40, |g| {
        let m = g.usize_in(1, 6);
        let bit_sets: [&[usize]; 3] = [&[2, 6], &[4, 8], &[2, 6, 10]];
        let bits: &[usize] = bit_sets[g.usize_in(0, 2)];
        let n = g.size_scaled(1, 2000);
        let grads = random_grads(g, m, n);
        let seed = g.rng().next_u64();
        let algo = pick_algo(g);
        let spec = format!(
            "qsgd-mn-ts-{}-{}",
            bits[0],
            bits[1] // CLI spec takes two scales; 3-scale set tested below
        );
        let (got, scales) = if bits.len() == 2 {
            let scales: Vec<usize> = bits.iter().map(|&b| kernels::s_for_bits(b)).collect();
            (run_aggregator(&spec, n, &grads, seed, algo), scales)
        } else {
            // build directly for >2 scales
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
            let mut agg = repro::compress::multiscale::QsgdMultiScale::new(bits).unwrap();
            let mut net = NetConfig::flat(m, 10.0);
            net.algo = algo;
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            let mut rng = Rng::new(seed);
            let out = agg.aggregate(&refs, &mut ctx, &mut rng);
            (out, agg.scales.clone())
        };
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let want = reference_multiscale(&refs, &scales, seed, algo);
        if got != want {
            let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
            return Err(format!(
                "bits={bits:?} m={m} n={n} algo={algo:?}: first diff at {bad}: {} vs {}",
                got[bad], want[bad]
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_grandk_aggregator_bit_identical() {
    check("GRandK-MN int == f32 reference", 40, |g| {
        let m = g.usize_in(1, 6);
        let bits = *g.pick(&[2usize, 4, 8]);
        let n = g.size_scaled(32, 3000);
        let k = g.usize_in(1, n / 2);
        let grads = random_grads(g, m, n);
        let seed = g.rng().next_u64();
        let algo = pick_algo(g);
        let got = run_aggregator(&format!("grandk-mn-{bits}-k{k}"), n, &grads, seed, algo);
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let want = reference_grandk(&refs, bits, k, seed, algo);
        if got != want {
            let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
            return Err(format!(
                "bits={bits} m={m} n={n} k={k} algo={algo:?}: diff at {bad}: {} vs {}",
                got[bad], want[bad]
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_fused_wire_path_bit_identical_and_byte_exact() {
    // the full fused chain including the packed wire hop:
    // encode → pack → unpack → int ring-allreduce → decode.
    check("fused wire chain == f32 reference", 50, |g| {
        let m = g.usize_in(1, 8);
        let bits = *g.pick(&[2usize, 3, 4, 5, 6, 8, 12]);
        let n = g.size_scaled(1, 2000);
        let grads = random_grads(g, m, n);
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let wnorm = max_norm(&refs);
        let seed = g.rng().next_u64();
        let rng = Rng::new(seed);
        let s = kernels::s_for_bits(bits);

        let (got, wire_bytes) = if repro::tensor::sum_fits::<i16>(s, m) {
            fused::wire_roundtrip_qsgd::<i16>(&refs, wnorm, bits, &rng)
        } else {
            fused::wire_roundtrip_qsgd::<i32>(&refs, wnorm, bits, &rng)
        };
        let want = reference_qsgd(&refs, bits, seed, Algo::Ring);
        ensure(got == want, "fused wire chain differs from f32 reference")?;
        ensure(
            wire_bytes == (n * bits).div_ceil(8),
            "wire bytes must be byte-exact ceil(n*b/8)",
        )
    });
}

#[test]
fn prop_packed_pipelined_qsgd_bit_identical_across_chunk_counts() {
    // the PR 2 tentpole invariant: the packed-resident chunk-pipelined ring
    // (resident reduce operand = biased Packed words, encode overlapped
    // with the reduce) == the widened-int path == the legacy f32 pipeline,
    // bit for bit, for any chunk plan — including 1 chunk and chunk counts
    // far beyond the pool width.
    check("packed pipelined qsgd == int == f32", 50, |g| {
        let m = g.usize_in(1, 8);
        let bits = *g.pick(&[2usize, 3, 4, 6, 8, 12]);
        let n = g.size_scaled(1, 2500);
        let chunks = *g.pick(&[1usize, 2, 3, 5, 16, 96]);
        let s = kernels::s_for_bits(bits);
        let grads = random_grads(g, m, n);
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let wnorm = max_norm(&refs);
        let seed = g.rng().next_u64();

        let want = reference_qsgd(&refs, bits, seed, Algo::Ring);

        // int path
        let net = NetConfig::flat(m, 10.0);
        let mut clock_int = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock_int);
        let mut s32: Vec<Vec<i32>> = Vec::new();
        let mut uni = Vec::new();
        let mut got_int = vec![0.0f32; n];
        fused::qsgd_step_int(
            &refs, wnorm, s, bits as f64, &mut s32, &mut uni, &mut ctx,
            &Rng::new(seed), &mut got_int,
        );

        // packed-resident pipelined path at a forced chunk count
        let mut clock_packed = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock_packed);
        let mut scratch = fused::PackedScratch::new();
        let mut uni2 = Vec::new();
        let mut got_packed = vec![0.0f32; n];
        fused::qsgd_step_packed(
            &refs, wnorm, s, bits as f64, &mut scratch, &mut uni2, &mut ctx,
            &Rng::new(seed), Some(chunks), &mut got_packed,
        );

        ensure(got_int == want, "int path differs from f32 reference")?;
        if got_packed != want {
            let bad = got_packed.iter().zip(&want).position(|(a, b)| a != b).unwrap();
            return Err(format!(
                "bits={bits} m={m} n={n} chunks={chunks}: packed diff at {bad}: {} vs {}",
                got_packed[bad], want[bad]
            ));
        }
        // nominal ledgers agree across data planes (byte-exact)
        ensure(
            clock_int.bits_per_worker == clock_packed.bits_per_worker,
            "nominal bits ledger must not depend on the data plane",
        )
    });
}

#[test]
fn prop_packed_pipelined_multiscale_bit_identical_across_chunk_counts() {
    check("packed pipelined multiscale == f32", 40, |g| {
        let m = g.usize_in(1, 6);
        let bit_sets: [&[usize]; 3] = [&[2, 6], &[4, 8], &[2, 6, 10]];
        let bits: &[usize] = bit_sets[g.usize_in(0, 2)];
        let n = g.size_scaled(1, 2000);
        let chunks = *g.pick(&[1usize, 3, 8, 64]);
        let scales: Vec<usize> = bits.iter().map(|&b| kernels::s_for_bits(b)).collect();
        let grads = random_grads(g, m, n);
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let wnorm = max_norm(&refs);
        let seed = g.rng().next_u64();

        let want = reference_multiscale(&refs, &scales, seed, Algo::Ring);

        // shared scale indices exactly as the aggregator derives them
        let table = kernels::ScaleTable::new(&scales);
        let mut proposals: Vec<Vec<u8>> = Vec::with_capacity(m);
        for g2 in &refs {
            let mut idx = vec![0u8; n];
            kernels::multiscale_scale_index_t(g2, wnorm, &table, &mut idx);
            proposals.push(idx);
        }
        let shared = collectives::min_allreduce_u8(&proposals);

        let net = NetConfig::flat(m, 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut scratch = fused::PackedScratch::new();
        let mut uni = Vec::new();
        let mut got = vec![0.0f32; n];
        fused::multiscale_step_packed(
            &refs,
            wnorm,
            &table,
            &shared,
            kernels::bits_for_s(scales[0]),
            &mut scratch,
            &mut uni,
            &mut ctx,
            &Rng::new(seed),
            Some(chunks),
            &mut got,
        );
        if got != want {
            let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
            return Err(format!(
                "bits={bits:?} m={m} n={n} chunks={chunks}: diff at {bad}: {} vs {}",
                got[bad], want[bad]
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_packed_resident_ring_in_aggregators_across_schemes() {
    // aggregator-level: with the ring schedule (the production default) the
    // aggregators now run the packed-resident pipelined plane — they must
    // stay bit-identical to the legacy f32 references. Covers QSGD-MN,
    // QSGD-MN-TS, and GRandK-MN in one sweep.
    check("aggregators on packed plane == f32 references", 40, |g| {
        let m = g.usize_in(1, 6);
        let n = g.size_scaled(32, 2000);
        let seed = g.rng().next_u64();
        let grads = random_grads(g, m, n);
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();

        let bits = *g.pick(&[2usize, 4, 8]);
        let got = run_aggregator(&format!("qsgd-mn-{bits}"), n, &grads, seed, Algo::Ring);
        ensure(
            got == reference_qsgd(&refs, bits, seed, Algo::Ring),
            "qsgd-mn packed plane differs",
        )?;

        let scales: Vec<usize> = [2usize, 6].iter().map(|&b| kernels::s_for_bits(b)).collect();
        let got = run_aggregator("qsgd-mn-ts-2-6", n, &grads, seed, Algo::Ring);
        ensure(
            got == reference_multiscale(&refs, &scales, seed, Algo::Ring),
            "qsgd-mn-ts packed plane differs",
        )?;

        let k = g.usize_in(1, n / 2);
        let got = run_aggregator(&format!("grandk-mn-{bits}-k{k}"), n, &grads, seed, Algo::Ring);
        ensure(
            got == reference_grandk(&refs, bits, k, seed, Algo::Ring),
            "grandk packed plane differs",
        )
    });
}

#[test]
fn packed_plane_schedule_matrix_bit_identical_with_ledger_parity() {
    // PR 3 acceptance matrix: the schedule-generic packed plane — fixed
    // ring, width-growing ring, tree, naive — is bit-identical to the int
    // plane and the legacy f32 plane across bits (2/4/8) x workers
    // (2/4/16/64) x chunk plans, with (a) the nominal bits ledger identical
    // across every plane and schedule, (b) comm_s equal to the analytic
    // per-schedule hop formula, and (c) the growing ring never charging
    // more hop bits than the fixed ring.
    use repro::collectives::{packed, PackedSchedule, RingFixed, RingGrowing};
    use repro::compress::bitpack;
    use repro::netsim::RingWidth;
    let n = 97usize;
    for &bits in &[2usize, 4, 8] {
        for &m in &[2usize, 4, 16, 64] {
            let s = kernels::s_for_bits(bits);
            let rbits = bitpack::packed_sum_bits(s, m);
            let seed = (bits * 1000 + m) as u64;
            let mut grng = Rng::new(seed);
            let grads: Vec<Vec<f32>> = (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    grng.fill_normal_f32(&mut v, 1.0);
                    v
                })
                .collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
            let wnorm = max_norm(&refs);
            let net = NetConfig::flat(m, 10.0);

            // int plane: the ledger baseline
            let mut clock_int = SimClock::default();
            let mut got_int = vec![0.0f32; n];
            {
                let mut ctx = StepCtx::new(&net, &mut clock_int);
                let mut s32: Vec<Vec<i32>> = Vec::new();
                let mut uni = Vec::new();
                fused::qsgd_step_int(
                    &refs, wnorm, s, bits as f64, &mut s32, &mut uni, &mut ctx,
                    &Rng::new(seed), &mut got_int,
                );
            }

            let mut hop_bits_fixed = None;
            for algo in [Algo::Ring, Algo::Tree, Algo::Naive] {
                let want = reference_qsgd(&refs, bits, seed, algo);
                assert_eq!(got_int, want, "int plane vs f32 (bits={bits} m={m} algo={algo:?})");
                let widths: &[RingWidth] = if algo == Algo::Ring {
                    &[RingWidth::Fixed, RingWidth::Growing]
                } else {
                    &[RingWidth::Auto]
                };
                for &width in widths {
                    for &chunks in &[1usize, 3, 16] {
                        let mut net_a = net.clone();
                        net_a.algo = algo;
                        let mut clock = SimClock::default();
                        let mut ctx = StepCtx::new(&net_a, &mut clock);
                        ctx.ring_width = width;
                        let mut scratch = fused::PackedScratch::new();
                        let mut uni = Vec::new();
                        let mut got = vec![0.0f32; n];
                        fused::qsgd_step_packed(
                            &refs, wnorm, s, bits as f64, &mut scratch, &mut uni, &mut ctx,
                            &Rng::new(seed), Some(chunks), &mut got,
                        );
                        assert_eq!(
                            got, want,
                            "packed {algo:?}/{width:?} differs (bits={bits} m={m} chunks={chunks})"
                        );
                        // (a) nominal ledger identical across planes/schedules
                        assert_eq!(
                            clock.bits_per_worker, clock_int.bits_per_worker,
                            "nominal ledger (bits={bits} m={m} algo={algo:?})"
                        );
                        // (b) comm_s equals the analytic per-schedule formula
                        let sched = match (algo, width) {
                            (Algo::Ring, RingWidth::Growing) => {
                                PackedSchedule::RingGrowing(RingGrowing { lmax: s })
                            }
                            (Algo::Ring, _) => PackedSchedule::RingFixed(RingFixed),
                            (Algo::Tree, _) => PackedSchedule::Tree(packed::TreeReduce),
                            (Algo::Naive, _) => PackedSchedule::Naive(packed::NaiveReduce),
                        };
                        assert_eq!(
                            clock.comm_s,
                            packed::analytic_comm_s(sched.as_dyn(), &net_a, n, rbits),
                            "comm_s analytic (bits={bits} m={m} algo={algo:?} {width:?})"
                        );
                        if algo == Algo::Ring && chunks == 1 {
                            match width {
                                RingWidth::Fixed => hop_bits_fixed = Some(clock.hop_bits_per_worker),
                                RingWidth::Growing => {
                                    // (c) growing never ships more hop bits
                                    let fixed = hop_bits_fixed.expect("fixed ran first");
                                    assert!(
                                        clock.hop_bits_per_worker <= fixed,
                                        "growing hop bits {} > fixed {} (bits={bits} m={m})",
                                        clock.hop_bits_per_worker,
                                        fixed
                                    );
                                }
                                RingWidth::Auto => {}
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn aggregators_bit_identical_across_schedules_up_to_64_workers() {
    // all three schemes through the schedule-generic packed plane at the
    // worker counts the acceptance matrix names, pinned to the f32
    // references per schedule.
    let n = 160usize;
    let k = 40usize;
    for &m in &[2usize, 4, 16, 64] {
        let seed = 7_000 + m as u64;
        let mut grng = Rng::new(seed);
        let grads: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                grng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        for algo in [Algo::Ring, Algo::Tree, Algo::Naive] {
            let got = run_aggregator("qsgd-mn-4", n, &grads, seed, algo);
            assert_eq!(
                got,
                reference_qsgd(&refs, 4, seed, algo),
                "qsgd-mn-4 m={m} algo={algo:?}"
            );
            let scales: Vec<usize> = [2usize, 6].iter().map(|&b| kernels::s_for_bits(b)).collect();
            let got = run_aggregator("qsgd-mn-ts-2-6", n, &grads, seed, algo);
            assert_eq!(
                got,
                reference_multiscale(&refs, &scales, seed, algo),
                "qsgd-mn-ts m={m} algo={algo:?}"
            );
            let got = run_aggregator(&format!("grandk-mn-4-k{k}"), n, &grads, seed, algo);
            assert_eq!(
                got,
                reference_grandk(&refs, 4, k, seed, algo),
                "grandk-mn m={m} algo={algo:?}"
            );
        }
    }
}

#[test]
fn prop_growing_ring_multiscale_bit_identical() {
    // the width-growing wire also pins bit-identical on the multi-scale
    // scheme (levels bounded by s_min + 1, a different lmax than qsgd's s).
    check("growing ring multiscale == f32", 30, |g| {
        let m = g.usize_in(2, 8);
        let n = g.size_scaled(1, 1500);
        let chunks = *g.pick(&[1usize, 4, 32]);
        let scales: Vec<usize> = [2usize, 6].iter().map(|&b| kernels::s_for_bits(b)).collect();
        let grads = random_grads(g, m, n);
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let wnorm = max_norm(&refs);
        let seed = g.rng().next_u64();
        let want = reference_multiscale(&refs, &scales, seed, Algo::Ring);

        let table = kernels::ScaleTable::new(&scales);
        let mut proposals: Vec<Vec<u8>> = Vec::with_capacity(m);
        for g2 in &refs {
            let mut idx = vec![0u8; n];
            kernels::multiscale_scale_index_t(g2, wnorm, &table, &mut idx);
            proposals.push(idx);
        }
        let shared = collectives::min_allreduce_u8(&proposals);

        let net = NetConfig::flat(m, 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.ring_width = repro::netsim::RingWidth::Growing;
        let mut scratch = fused::PackedScratch::new();
        let mut uni = Vec::new();
        let mut got = vec![0.0f32; n];
        fused::multiscale_step_packed(
            &refs,
            wnorm,
            &table,
            &shared,
            kernels::bits_for_s(scales[0]),
            &mut scratch,
            &mut uni,
            &mut ctx,
            &Rng::new(seed),
            Some(chunks),
            &mut got,
        );
        ensure(got == want, "growing multiscale differs from f32 reference")
    });
}

// ---------------------------------------------------------------------------
// PR 4: bucketed control plane
// ---------------------------------------------------------------------------

use repro::runtime::contiguous_segments;

#[test]
fn bucketed_fixed_bits_bit_identical_to_monolithic_packed_matrix() {
    // PR 4 acceptance matrix: the bucketed control plane with FixedBits is
    // bit-identical to the monolithic packed path — which is itself pinned
    // to the f32 reference — for bucket plans {1, 3, segments, ragged-last}
    // x schedules {ring fixed, ring growing, tree} x workers {4, 16}. The
    // plane draws the monolithic uniform stream per worker and shares the
    // global max norm, so every bucket reproduces the monolithic numbers.
    use repro::control::{ControlConfig, GradientControlPlane};
    use repro::netsim::RingWidth;

    // intentionally odd length; the targets below yield plans of
    // {1, 3 (ragged-last 68), 4, 6 (= one per segment)} buckets
    let n = 1003usize;
    let seg_lens = [334usize, 167, 167, 167, 100, 68];
    let segments = contiguous_segments(&seg_lens);
    let bits = 4usize;

    for &m in &[4usize, 16] {
        let seed = 0xB0CE + m as u64;
        let mut grng = Rng::new(seed);
        let grads: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                grng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();

        for (algo, width) in [
            (Algo::Ring, RingWidth::Fixed),
            (Algo::Ring, RingWidth::Growing),
            (Algo::Tree, RingWidth::Auto),
        ] {
            // monolithic packed path (the PR 3 pinned path)
            let want = {
                let mut agg = Method::parse(&format!("qsgd-mn-{bits}"))
                    .unwrap()
                    .build(n, &segments)
                    .unwrap();
                let mut net = NetConfig::flat(m, 10.0);
                net.algo = algo;
                let mut clock = SimClock::default();
                let mut ctx = StepCtx::new(&net, &mut clock);
                ctx.ring_width = width;
                let mut rng = Rng::new(seed);
                (agg.aggregate(&refs, &mut ctx, &mut rng), clock.bits_per_worker)
            };
            // targets resolve to {1, 3, 4, 6}-bucket plans (greedy grouping
            // can merge below the target; 15 forces one bucket per segment)
            let mut seen = Vec::new();
            for &target in &[1usize, 3, 6, 15] {
                let cfg = ControlConfig::new(target);
                let mut plane =
                    GradientControlPlane::new(cfg, bits, n, &segments).unwrap();
                let nb = plane.plan.len();
                seen.push(nb);
                let mut net = NetConfig::flat(m, 10.0);
                net.algo = algo;
                let mut clock = SimClock::default();
                let got = {
                    let mut ctx = StepCtx::new(&net, &mut clock);
                    ctx.ring_width = width;
                    let mut rng = Rng::new(seed);
                    plane.aggregate(&refs, &mut ctx, &mut rng)
                };
                assert_eq!(
                    got.len(),
                    want.0.len(),
                    "m={m} algo={algo:?} buckets={nb}"
                );
                if got != want.0 {
                    let bad = got.iter().zip(&want.0).position(|(a, b)| a != b).unwrap();
                    panic!(
                        "m={m} algo={algo:?} {width:?} buckets={nb}: first diff at {bad}: {} vs {}",
                        got[bad], want.0[bad]
                    );
                }
                // byte-exact ledger: 32 norm bits + per-bucket byte ceilings
                let payload: f64 = plane
                    .plan
                    .buckets
                    .iter()
                    .map(|b| (8 * repro::compress::bitpack::wire_bytes_for(b.len(), bits as u32)) as f64)
                    .sum();
                assert_eq!(clock.bits_per_worker, 32.0 + payload);
                assert_eq!(plane.last_payload_bits(), payload);
                // the single-bucket plan is ledger-identical to monolithic
                if nb == 1 {
                    assert_eq!(clock.bits_per_worker, want.1);
                }
            }
            assert_eq!(seen, vec![1, 3, 4, 6], "bucket-plan matrix shape");
        }
    }
}

#[test]
fn bucketed_charging_regression_no_double_byte_ceiling() {
    // satellite bugfix pin: with ragged buckets at 2 bits the sum of
    // per-bucket byte ceilings (the correct charge) differs from both the
    // whole-gradient ceiling (a re-derivation) and from ceil-of-sum applied
    // twice; the ledger must equal the closed form exactly.
    use repro::compress::bitpack;
    use repro::control::{BitsPolicy, ControlConfig, GradientControlPlane};

    let seg_lens = [33usize, 33, 31];
    let n: usize = seg_lens.iter().sum();
    let segments = contiguous_segments(&seg_lens);
    let m = 4usize;
    let mut grng = Rng::new(0xD1CE);
    let grads: Vec<Vec<f32>> = (0..m)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            grng.fill_normal_f32(&mut v, 1.0);
            v
        })
        .collect();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();

    let mut cfg = ControlConfig::new(3);
    cfg.bits = BitsPolicy::Fixed(Some(2));
    let mut plane = GradientControlPlane::new(cfg, 4, n, &segments).unwrap();
    assert_eq!(plane.plan.len(), 3);

    let net = NetConfig::flat(m, 10.0);
    let mut clock = SimClock::default();
    {
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut rng = Rng::new(1);
        plane.aggregate(&refs, &mut ctx, &mut rng);
    }
    let closed: f64 = seg_lens
        .iter()
        .map(|&l| (8 * bitpack::wire_bytes_for(l, 2)) as f64)
        .sum();
    assert_eq!(closed, 208.0); // 9 + 9 + 8 bytes
    let whole = (8 * bitpack::wire_bytes_for(n, 2)) as f64;
    assert_eq!(whole, 200.0); // ceil(194/8) = 25 bytes — NOT what we charge
    assert_eq!(clock.bits_per_worker, 32.0 + closed);
    assert_ne!(clock.bits_per_worker, 32.0 + whole);
}

// ---------------------------------------------------------------------------
// PR 5: bucket-generic control plane — multi-scale and GRandK parity matrix
// ---------------------------------------------------------------------------

#[test]
fn bucketed_multiscale_and_grandk_bit_identical_to_monolithic_matrix() {
    // PR 5 acceptance matrix: the aggregator-generic control plane is
    // bit-identical to the monolithic packed path — itself pinned to the
    // f32 references above — for methods {qsgd-mn-ts, grandk-mn,
    // grandk-mn-ts} x bucket plans {1, 3, ragged 4, segment-derived 6} x
    // schedules {ring fixed, ring growing, tree} x workers {4, 16}, with
    // byte-exact per-bucket ledgers: per bucket the wire carries
    // 8*ceil(len_b*payload/8) level bits plus (multi-scale only)
    // 8*ceil(len_b*index/8) scale-share bits, where len_b is the bucket
    // length for dense methods and the ragged K_b split of the sorted
    // global draw for GRandK — summed, plus the 32-bit global norm share.
    use repro::compress::bitpack;
    use repro::control::{build_plane, ControlConfig};
    use repro::netsim::RingWidth;

    let n = 1003usize;
    let seg_lens = [334usize, 167, 167, 167, 100, 68];
    let segments = contiguous_segments(&seg_lens);
    let k = 256usize;

    struct Case {
        spec: String,
        payload_bits: u32,
        /// scale-share bits per coordinate (0 = single-scale: no share)
        index_bits: u32,
        grandk: bool,
    }
    let cases = [
        Case { spec: "qsgd-mn-ts-2-6".into(), payload_bits: 2, index_bits: 1, grandk: false },
        Case { spec: format!("grandk-mn-4-k{k}"), payload_bits: 4, index_bits: 0, grandk: true },
        Case {
            spec: format!("grandk-mn-ts-4-8-k{k}"),
            payload_bits: 4,
            index_bits: 1,
            grandk: true,
        },
    ];

    for case in &cases {
        let method = Method::parse(&case.spec).unwrap();
        for &m in &[4usize, 16] {
            let seed = 0xB0CE5 + m as u64;
            let mut grng = Rng::new(seed);
            let grads: Vec<Vec<f32>> = (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    grng.fill_normal_f32(&mut v, 1.0);
                    v
                })
                .collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();

            // the per-bucket encoded lengths the ledger must be charged at:
            // bucket lengths (dense), or the ragged split of the sorted
            // global K-draw (re-derived here exactly as the plane draws it)
            let drawn: Option<Vec<usize>> = case.grandk.then(|| {
                Rng::new(seed ^ 0x51EED)
                    .derive(&[0x6B6579])
                    .sample_distinct(n, k)
            });

            for (algo, width) in [
                (Algo::Ring, RingWidth::Fixed),
                (Algo::Ring, RingWidth::Growing),
                (Algo::Tree, RingWidth::Auto),
            ] {
                // monolithic packed path (the pinned reference plane)
                let (want, want_bits) = {
                    let mut agg = method.build(n, &segments).unwrap();
                    let mut net = NetConfig::flat(m, 10.0);
                    net.algo = algo;
                    let mut clock = SimClock::default();
                    let mut ctx = StepCtx::new(&net, &mut clock);
                    ctx.ring_width = width;
                    let mut rng = Rng::new(seed ^ 0x51EED);
                    (agg.aggregate(&refs, &mut ctx, &mut rng), clock.bits_per_worker)
                };

                let mut seen = Vec::new();
                for &target in &[1usize, 3, 6, 15] {
                    let cfg = ControlConfig::new(target);
                    let mut plane = build_plane(&method, &cfg, n, &segments).unwrap();
                    let nb = plane.plan.len();
                    seen.push(nb);
                    let mut net = NetConfig::flat(m, 10.0);
                    net.algo = algo;
                    let mut clock = SimClock::default();
                    let got = {
                        let mut ctx = StepCtx::new(&net, &mut clock);
                        ctx.ring_width = width;
                        let mut rng = Rng::new(seed ^ 0x51EED);
                        plane.aggregate(&refs, &mut ctx, &mut rng)
                    };
                    if got != want {
                        let bad =
                            got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                        panic!(
                            "{} m={m} algo={algo:?} {width:?} buckets={nb}: first diff \
                             at {bad}: {} vs {}",
                            case.spec, got[bad], want[bad]
                        );
                    }

                    // per-bucket encoded lengths: independent re-derivation
                    let lens: Vec<usize> = match &drawn {
                        None => plane.plan.buckets.iter().map(|b| b.len()).collect(),
                        Some(idx) => plane
                            .plan
                            .buckets
                            .iter()
                            .map(|b| {
                                idx.partition_point(|&i| i < b.hi)
                                    - idx.partition_point(|&i| i < b.lo)
                            })
                            .collect(),
                    };
                    assert_eq!(
                        plane.last_bucket_lens(),
                        &lens[..],
                        "{} m={m} buckets={nb}: routed lens",
                        case.spec
                    );
                    if case.grandk {
                        assert_eq!(lens.iter().sum::<usize>(), k, "ragged split covers K");
                    }

                    // byte-exact per-bucket ledger: levels + scale share,
                    // each byte-ceiled per bucket, plus the 32-bit norm
                    let payload: f64 = lens
                        .iter()
                        .map(|&l| {
                            let mut bytes = bitpack::wire_bytes_for(l, case.payload_bits);
                            if case.index_bits > 0 {
                                bytes += bitpack::wire_bytes_for(l, case.index_bits);
                            }
                            (8 * bytes) as f64
                        })
                        .sum();
                    assert_eq!(
                        plane.last_payload_bits(),
                        payload,
                        "{} m={m} algo={algo:?} buckets={nb}: payload ledger",
                        case.spec
                    );
                    assert_eq!(
                        clock.bits_per_worker,
                        32.0 + payload,
                        "{} m={m} algo={algo:?} buckets={nb}: bits ledger",
                        case.spec
                    );
                    // a single bucket reproduces the monolithic ledger too
                    if nb == 1 {
                        assert_eq!(clock.bits_per_worker, want_bits, "{}", case.spec);
                    }
                }
                assert_eq!(seen, vec![1, 3, 4, 6], "bucket-plan matrix shape");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PR 8: hierarchical two-level schedule — parity matrix vs the flat planes
// ---------------------------------------------------------------------------

#[test]
fn hierarchical_vs_flat_parity_matrix() {
    // PR 8 acceptance matrix: with `ctx.hier` on, the two-level schedule is
    // payload-bit-identical to the flat packed plane — itself pinned to the
    // f32 references above — for methods {qsgd-mn-4, qsgd-mn-ts-2-6,
    // grandk-mn-4-k256} x topologies {1x4, 4x4, 32x4} x bucket plans
    // {1, 3, ragged-last 4}, with (a) the nominal bits ledger identical
    // across schedules, (b) per-level hop-bits ledgers exactly equal to the
    // hand-written closed forms (4(g-1) intra island segments + 2(nodes-1)
    // inter leader segments per bucket, all at the resident width), and
    // (c) the comm_s delta between the hier and flat runs equal to the
    // closed-form schedule difference (everything else on the wire — norm
    // and scale shares — is schedule-invariant).
    use repro::compress::bitpack;
    use repro::control::{build_plane, ControlConfig};
    use repro::netsim::{LinkLevel, RingWidth};

    // hand-written closed form of ONE fixed-width packed reduce of `l`
    // encoded coords at resident width `rbits` under the schedule the
    // topology resolves: (intra_bits, inter_bits, comm_s). Independent of
    // the PackedReduce hop model on purpose.
    fn closed_form(net: &NetConfig, hier: bool, l: usize, rbits: u32) -> (f64, f64, f64) {
        let m = net.workers;
        if m <= 1 || l == 0 {
            return (0.0, 0.0, 0.0);
        }
        let g = net.gpus_per_node.clamp(1, m);
        let nodes = m.div_ceil(g);
        if hier && g > 1 && nodes > 1 {
            let iseg = bitpack::wire_bytes_for(l.div_ceil(g), rbits) as f64;
            let lseg = bitpack::wire_bytes_for(l.div_ceil(nodes), rbits) as f64;
            let ih = 4.0 * (g - 1) as f64;
            let eh = 2.0 * (nodes - 1) as f64;
            (
                ih * iseg * 8.0,
                eh * lseg * 8.0,
                ih * net.hop_s_on(LinkLevel::Intra, iseg)
                    + eh * net.hop_s_on(LinkLevel::Inter, lseg),
            )
        } else {
            // flat fixed ring on the bottleneck link (also what the hier
            // resolution degenerates to on a single island)
            let seg = bitpack::wire_bytes_for(l.div_ceil(m), rbits) as f64;
            let h = 2.0 * (m - 1) as f64;
            let level = net.bottleneck_level();
            let comm = h * net.hop_s_on(level, seg);
            match level {
                LinkLevel::Intra => (h * seg * 8.0, 0.0, comm),
                LinkLevel::Inter => (0.0, h * seg * 8.0, comm),
            }
        }
    }

    let n = 1003usize;
    let seg_lens = [334usize, 167, 167, 167, 100, 68];
    let segments = contiguous_segments(&seg_lens);
    let k = 256usize;

    struct Case {
        spec: String,
        /// per-contribution level bound (drives the resident width)
        lmax: usize,
        grandk: bool,
    }
    let cases = [
        Case { spec: "qsgd-mn-4".into(), lmax: kernels::s_for_bits(4), grandk: false },
        Case {
            spec: "qsgd-mn-ts-2-6".into(),
            // eq. (10): multi-scale levels are bounded by s_min + 1
            lmax: kernels::s_for_bits(2) + 1,
            grandk: false,
        },
        Case { spec: format!("grandk-mn-4-k{k}"), lmax: kernels::s_for_bits(4), grandk: true },
    ];

    for case in &cases {
        let method = Method::parse(&case.spec).unwrap();
        for &(nodes, g) in &[(1usize, 4usize), (4, 4), (32, 4)] {
            let m = nodes * g;
            let rbits = bitpack::packed_sum_bits(case.lmax, m);
            let mut net = NetConfig::flat(m, 10.0);
            net.gpus_per_node = g;
            assert_eq!(net.nodes(), nodes);
            let seed = 0x41E8 + (m * 31) as u64;
            let mut grng = Rng::new(seed);
            let grads: Vec<Vec<f32>> = (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    grng.fill_normal_f32(&mut v, 1.0);
                    v
                })
                .collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();

            // the monolithic flat aggregate: the pinned payload reference
            let want = {
                let mut agg = method.build(n, &segments).unwrap();
                let mut clock = SimClock::default();
                let mut ctx = StepCtx::new(&net, &mut clock);
                ctx.ring_width = RingWidth::Fixed;
                let mut rng = Rng::new(seed ^ 0x51EED);
                agg.aggregate(&refs, &mut ctx, &mut rng)
            };
            // the ragged K-split the grandk ledger is charged at
            let drawn: Option<Vec<usize>> = case.grandk.then(|| {
                Rng::new(seed ^ 0x51EED).derive(&[0x6B6579]).sample_distinct(n, k)
            });

            let mut seen = Vec::new();
            for &target in &[1usize, 3, 6] {
                let run = |hier: bool| {
                    let cfg = ControlConfig::new(target);
                    let mut plane = build_plane(&method, &cfg, n, &segments).unwrap();
                    let nb = plane.plan.len();
                    let mut clock = SimClock::default();
                    let got = {
                        let mut ctx = StepCtx::new(&net, &mut clock);
                        ctx.ring_width = RingWidth::Fixed;
                        ctx.hier = hier;
                        let mut rng = Rng::new(seed ^ 0x51EED);
                        plane.aggregate(&refs, &mut ctx, &mut rng)
                    };
                    let lens: Vec<usize> = match &drawn {
                        None => plane.plan.buckets.iter().map(|b| b.len()).collect(),
                        Some(idx) => plane
                            .plan
                            .buckets
                            .iter()
                            .map(|b| {
                                idx.partition_point(|&i| i < b.hi)
                                    - idx.partition_point(|&i| i < b.lo)
                            })
                            .collect(),
                    };
                    (got, clock, nb, lens)
                };
                let (flat_out, flat_clock, nb, lens) = run(false);
                let (hier_out, hier_clock, nb_h, lens_h) = run(true);
                assert_eq!(nb, nb_h);
                assert_eq!(lens, lens_h);
                seen.push(nb);

                // (payload) bit-identical across schedules and to the
                // monolithic flat reference
                if flat_out != want || hier_out != want {
                    let out = if flat_out != want { &flat_out } else { &hier_out };
                    let bad = out.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                    panic!(
                        "{} {nodes}x{g} buckets={nb}: payload diff at {bad}: {} vs {}",
                        case.spec, out[bad], want[bad]
                    );
                }

                // (a) nominal ledger is schedule-invariant
                assert_eq!(
                    flat_clock.bits_per_worker, hier_clock.bits_per_worker,
                    "{} {nodes}x{g} buckets={nb}: nominal ledger",
                    case.spec
                );

                // (b) per-level hop-bits ledgers: exact closed forms
                let (mut fi, mut fe, mut fc) = (0.0, 0.0, 0.0);
                let (mut hi, mut he, mut hc) = (0.0, 0.0, 0.0);
                for &l in &lens {
                    let (a, b, c) = closed_form(&net, false, l, rbits);
                    fi += a;
                    fe += b;
                    fc += c;
                    let (a, b, c) = closed_form(&net, true, l, rbits);
                    hi += a;
                    he += b;
                    hc += c;
                }
                for (clock, want_i, want_e, label) in [
                    (&flat_clock, fi, fe, "flat"),
                    (&hier_clock, hi, he, "hier"),
                ] {
                    assert_eq!(
                        clock.hop_bits_intra, want_i,
                        "{} {nodes}x{g} buckets={nb}: {label} intra hop bits",
                        case.spec
                    );
                    assert_eq!(
                        clock.hop_bits_inter, want_e,
                        "{} {nodes}x{g} buckets={nb}: {label} inter hop bits",
                        case.spec
                    );
                    assert_eq!(
                        clock.hop_bits_intra + clock.hop_bits_inter,
                        clock.hop_bits_per_worker,
                        "{} {nodes}x{g} buckets={nb}: {label} level split invariant",
                        case.spec
                    );
                }
                if nodes > 1 {
                    assert!(hier_clock.hop_bits_intra > 0.0, "hier must use NVLink");
                    assert_eq!(flat_clock.hop_bits_intra, 0.0, "flat is all-Ethernet");
                }

                // (c) comm_s: the runs differ by exactly the closed-form
                // schedule difference (norm/scale shares are identical)
                let got_delta = hier_clock.comm_s - flat_clock.comm_s;
                let want_delta = hc - fc;
                assert!(
                    (got_delta - want_delta).abs()
                        <= 1e-12 * (flat_clock.comm_s + hier_clock.comm_s),
                    "{} {nodes}x{g} buckets={nb}: comm delta {got_delta} vs closed {want_delta}",
                    case.spec
                );
                if nodes > 1 {
                    assert!(
                        hier_clock.comm_s < flat_clock.comm_s,
                        "{} {nodes}x{g}: hier must beat flat on simulated time",
                        case.spec
                    );
                }
            }
            assert_eq!(seen, vec![1, 3, 4], "bucket-plan matrix shape");
        }
    }
}

#[test]
fn int_reducers_agree_exactly_on_quantizer_output() {
    // ring/tree/naive integer reducers on real quantizer levels: exact
    // agreement, every rank, both widths.
    let mut rng = Rng::new(42);
    for &(m, bits, n) in &[(4usize, 4usize, 1000usize), (7, 8, 517), (3, 12, 64)] {
        let s = kernels::s_for_bits(bits);
        let grads: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let wnorm = max_norm(&refs);
        let mut levels: Vec<Vec<i32>> = Vec::new();
        let mut uniform: Vec<Vec<f32>> = Vec::new();
        fused::encode_qsgd_into(&refs, wnorm, s, &mut levels, &mut uniform, &Rng::new(7));

        let mut ring = levels.clone();
        let mut tree = levels.clone();
        let mut naive = levels.clone();
        collectives::ring_allreduce_sum_i32(&mut ring);
        collectives::tree_allreduce_sum_t(&mut tree);
        collectives::naive_allreduce_sum_t(&mut naive);
        for r in 0..m {
            assert_eq!(ring[r], naive[0], "ring rank {r} (m={m} bits={bits})");
            assert_eq!(tree[r], naive[0], "tree rank {r} (m={m} bits={bits})");
        }
        // i16 width agrees after widening
        let as16: Vec<Vec<i16>> = levels
            .iter()
            .map(|b| b.iter().map(|&x| x as i16).collect())
            .collect();
        let mut ring16 = as16;
        collectives::ring_allreduce_sum_i16(&mut ring16);
        for r in 0..m {
            let widened: Vec<i32> = ring16[r].iter().map(|&x| x as i32).collect();
            assert_eq!(widened, naive[0], "i16 ring rank {r}");
        }
    }
}

/// Id-keyed variant of [`reference_qsgd`]: slot `i` draws the uniform
/// stream of ORIGINAL worker id `ids[i]`, the norm is taken over the given
/// (surviving) gradients only, and the decode divides by the live count.
/// With identity ids this is exactly `reference_qsgd`.
fn reference_qsgd_ids(
    grads: &[&[f32]],
    ids: &[usize],
    bits: usize,
    seed: u64,
    algo: Algo,
) -> Vec<f32> {
    let m = grads.len();
    let n = grads[0].len();
    let s = kernels::s_for_bits(bits);
    let wnorm = max_norm(grads);
    let rng = Rng::new(seed);
    let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(m);
    for (g, &w) in grads.iter().zip(ids) {
        let mut wrng = rng.derive(&[w as u64]);
        let mut uni = vec![0.0f32; n];
        wrng.fill_uniform_f32(&mut uni);
        let mut buf = vec![0.0f32; n];
        kernels::qsgd_encode(g, wnorm, &uni, s, &mut buf);
        bufs.push(buf);
    }
    f32_allreduce(&mut bufs, algo);
    let mut sum = bufs.swap_remove(0);
    kernels::qsgd_decode_sum(&mut sum, wnorm, s, m);
    sum
}

/// Id-keyed variant of [`reference_multiscale`]: the scale-share min
/// all-reduce runs over the survivors only; uniforms keyed by original id.
fn reference_multiscale_ids(
    grads: &[&[f32]],
    ids: &[usize],
    scales: &[usize],
    seed: u64,
    algo: Algo,
) -> Vec<f32> {
    let m = grads.len();
    let n = grads[0].len();
    let wnorm = max_norm(grads);
    let rng = Rng::new(seed);

    let mut proposals: Vec<Vec<u8>> = Vec::with_capacity(m);
    for g in grads {
        let mut idx = vec![0u8; n];
        kernels::multiscale_scale_index(g, wnorm, scales, &mut idx);
        proposals.push(idx);
    }
    let shared = collectives::min_allreduce_u8(&proposals);

    let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(m);
    for (g, &w) in grads.iter().zip(ids) {
        let mut wrng = rng.derive(&[w as u64]);
        let mut uni = vec![0.0f32; n];
        wrng.fill_uniform_f32(&mut uni);
        let mut buf = vec![0.0f32; n];
        kernels::multiscale_encode(g, wnorm, &uni, &shared, scales, &mut buf);
        bufs.push(buf);
    }
    f32_allreduce(&mut bufs, algo);
    let mut sum = bufs.swap_remove(0);
    kernels::multiscale_decode_sum(&mut sum, wnorm, &shared, scales, m);
    sum
}

#[test]
fn none_fault_plane_strict_cohort_is_bit_identical_across_the_matrix() {
    // PR 6 acceptance, half 1: FaultPlan::none() + strict sync is a
    // bit-level no-op. For every bucketable method x bucket plan x
    // schedule x worker count, driving the control plane through the
    // cohort seam — identity ids from the elastic planner, per-step wire
    // from `net_for_step`, id-masked uniform fill — reproduces plain
    // `aggregate` exactly: output, bits ledger, and simulated clocks.
    use repro::control::{build_plane, ControlConfig, ElasticCohort, ElasticConfig};
    use repro::netsim::{FaultPlan, RingWidth};

    let n = 771usize;
    let seg_lens = [257usize, 200, 150, 100, 64];
    let segments = contiguous_segments(&seg_lens);
    let specs =
        ["qsgd-mn-4", "qsgd-mn-ts-2-6", "grandk-mn-4-k192", "grandk-mn-ts-4-8-k192"];

    for spec in specs {
        let method = Method::parse(spec).unwrap();
        for &m in &[4usize, 16] {
            let seed = 0xFA_0CE5 + m as u64;
            let mut grng = Rng::new(seed);
            let grads: Vec<Vec<f32>> = (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    grng.fill_normal_f32(&mut v, 1.0);
                    v
                })
                .collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();

            // the elastic planner under a none plan: full identity cohort,
            // synchronizing, zero straggler wait, window == base
            let faults = FaultPlan::none();
            let mut cohort = ElasticCohort::new(ElasticConfig::strict(), m).unwrap();
            let plan = cohort.plan_step(0, 1.0);
            assert_eq!(plan.live, (0..m).collect::<Vec<_>>(), "{spec} m={m}: live");
            assert!(plan.sync, "{spec} m={m}: strict step must sync");
            assert_eq!(plan.straggler_wait_s, 0.0, "{spec} m={m}: no jitter, no wait");
            assert_eq!(plan.compute_window_s, 1.0, "{spec} m={m}: window folds to base");

            for (algo, width) in [
                (Algo::Ring, RingWidth::Fixed),
                (Algo::Ring, RingWidth::Growing),
                (Algo::Tree, RingWidth::Auto),
            ] {
                for &target in &[1usize, 3, 6] {
                    let cfg = ControlConfig::new(target);

                    let mut want_clock = SimClock::default();
                    let want = {
                        let mut plane = build_plane(&method, &cfg, n, &segments).unwrap();
                        let mut net = NetConfig::flat(m, 10.0);
                        net.algo = algo;
                        let mut ctx = StepCtx::new(&net, &mut want_clock);
                        ctx.ring_width = width;
                        let mut rng = Rng::new(seed ^ 0x51EED);
                        plane.aggregate(&refs, &mut ctx, &mut rng)
                    };

                    let mut got_clock = SimClock::default();
                    let got = {
                        let mut plane = build_plane(&method, &cfg, n, &segments).unwrap();
                        let mut base = NetConfig::flat(m, 10.0);
                        base.algo = algo;
                        let step_net = faults.net_for_step(&base, 0, plan.live.len());
                        let mut ctx = StepCtx::new(&step_net, &mut got_clock);
                        ctx.ring_width = width;
                        let mut rng = Rng::new(seed ^ 0x51EED);
                        plane.aggregate_cohort(&refs, &plan.live, &mut ctx, &mut rng)
                    };

                    if got != want {
                        let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                        panic!(
                            "{spec} m={m} algo={algo:?} {width:?} target={target}: \
                             cohort seam diverged at {bad}: {} vs {}",
                            got[bad], want[bad]
                        );
                    }
                    assert_eq!(
                        got_clock.bits_per_worker, want_clock.bits_per_worker,
                        "{spec} m={m} algo={algo:?} target={target}: bits ledger"
                    );
                    assert_eq!(
                        got_clock.comm_s, want_clock.comm_s,
                        "{spec} m={m} algo={algo:?} target={target}: comm clock"
                    );
                    assert_eq!(
                        got_clock.hidden_comm_s, want_clock.hidden_comm_s,
                        "{spec} m={m} algo={algo:?} target={target}: hidden comm"
                    );
                }
            }
        }
    }
}

#[test]
fn drop_then_rejoin_cohort_matches_independent_fixed_m_references() {
    // PR 6 acceptance, half 2: under `leave=2@1,join=2@4` at M=4 the
    // plane's partial steps are bit-identical to an independently
    // constructed fixed-M run over the survivors — the same f32 reference
    // pipeline pinned above, with uniform streams keyed by ORIGINAL
    // worker id, the shared norm taken over survivors only, and the
    // decode renormalized by live M=3 — and the step after the rejoin
    // matches the plain full-M reference again.
    use repro::control::{build_plane, ControlConfig, ElasticCohort, ElasticConfig};
    use repro::netsim::FaultPlan;

    let n = 501usize;
    let m = 4usize;

    let ts_scales: Vec<usize> = [2usize, 6].iter().map(|&b| kernels::s_for_bits(b)).collect();
    for (spec, scales) in [("qsgd-mn-4", None), ("qsgd-mn-ts-2-6", Some(ts_scales))] {
        let method = Method::parse(spec).unwrap();
        let mut plane = build_plane(&method, &ControlConfig::new(3), n, &[]).unwrap();

        let mut ec = ElasticConfig::strict();
        ec.faults = FaultPlan::parse("leave=2@1,join=2@4").unwrap();
        let mut cohort = ElasticCohort::new(ec, m).unwrap();

        let mut grng = Rng::new(0xE1A5).derive(&[0x67]);
        for step in 0..6usize {
            let grads: Vec<Vec<f32>> = (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    grng.fill_normal_f32(&mut v, 1.0);
                    v
                })
                .collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();

            let plan = cohort.plan_step(step, 1.0);
            let expect_live: Vec<usize> =
                if (1..4).contains(&step) { vec![0, 1, 3] } else { vec![0, 1, 2, 3] };
            assert_eq!(plan.live, expect_live, "{spec} step {step}: cohort");
            assert_eq!(
                plan.rejoined,
                if step == 4 { vec![2usize] } else { vec![] },
                "{spec} step {step}: rejoin bookkeeping"
            );

            let sub: Vec<&[f32]> = plan.live.iter().map(|&w| refs[w]).collect();
            let mut net = NetConfig::flat(plan.live.len(), 10.0);
            net.algo = Algo::Ring;
            let mut clock = SimClock::default();
            let step_seed = 0xE1A5 ^ step as u64;
            let got = {
                let mut ctx = StepCtx::new(&net, &mut clock);
                let mut rng = Rng::new(step_seed);
                plane.aggregate_cohort(&sub, &plan.live, &mut ctx, &mut rng)
            };

            let want = match &scales {
                None => reference_qsgd_ids(&sub, &plan.live, 4, step_seed, Algo::Ring),
                Some(sc) => {
                    reference_multiscale_ids(&sub, &plan.live, sc, step_seed, Algo::Ring)
                }
            };
            if got != want {
                let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                panic!(
                    "{spec} step {step} (live {:?}): first diff at {bad}: {} vs {}",
                    plan.live, got[bad], want[bad]
                );
            }
            // the rejoined (and final) full-cohort steps equal the plain
            // positional reference too — the id-keyed seam leaves no residue
            if step >= 4 {
                let full = match &scales {
                    None => reference_qsgd(&refs, 4, step_seed, Algo::Ring),
                    Some(sc) => reference_multiscale(&refs, sc, step_seed, Algo::Ring),
                };
                assert_eq!(got, full, "{spec} step {step}: full-M reference");
            }
            cohort.commit(&plan);
        }
    }
}

/// PR 9 pin: the flight recorder is structurally off by default —
/// `StepCtx::new` leaves `tracer == None`, so every other test in this file
/// (and every pre-PR-9 caller) runs the exact pre-recorder hot path — and
/// arming it perturbs neither the integer-domain output nor any of the
/// twelve simulated ledgers, bit for bit.
#[test]
fn flight_recorder_default_off_and_armed_runs_bit_identical() {
    use repro::control::{build_plane, ControlConfig};

    let m = 8usize;
    let n = 1201usize;
    let mut grng = Rng::new(0x7F1A);
    let grads: Vec<Vec<f32>> = (0..m)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            grng.fill_normal_f32(&mut v, 1.0);
            v
        })
        .collect();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let net = NetConfig::flat(m, 10.0);
    let method = Method::parse("qsgd-mn-ts-2-6").unwrap();

    let run = |tracer: Option<&mut repro::trace::Tracer>| -> (Vec<f32>, SimClock) {
        let mut plane = build_plane(&method, &ControlConfig::new(3), n, &[]).unwrap();
        let mut clock = SimClock::default();
        let out = {
            let mut ctx = StepCtx::new(&net, &mut clock);
            assert!(ctx.tracer.is_none(), "StepCtx must construct trace-off");
            ctx.tracer = tracer;
            let mut rng = Rng::new(0x7F1A ^ 0x51EED);
            plane.aggregate(&refs, &mut ctx, &mut rng)
        };
        (out, clock)
    };

    let (out_off, clk_off) = run(None);
    let mut tracer = repro::trace::Tracer::new();
    let (out_on, clk_on) = run(Some(&mut tracer));
    tracer.end_step(&clk_on);

    assert_eq!(out_on, out_off, "armed recorder changed the output");
    assert_eq!(clk_on.comm_s, clk_off.comm_s);
    assert_eq!(clk_on.compute_s, clk_off.compute_s);
    assert_eq!(clk_on.encode_s, clk_off.encode_s);
    assert_eq!(clk_on.decode_s, clk_off.decode_s);
    assert_eq!(clk_on.bits_per_worker, clk_off.bits_per_worker);
    assert_eq!(clk_on.hop_bits_per_worker, clk_off.hop_bits_per_worker);
    assert_eq!(clk_on.hop_bits_intra, clk_off.hop_bits_intra);
    assert_eq!(clk_on.hop_bits_inter, clk_off.hop_bits_inter);
    assert_eq!(clk_on.hidden_comm_s, clk_off.hidden_comm_s);
    assert_eq!(clk_on.straggler_wait_s, clk_off.straggler_wait_s);
    assert_eq!(clk_on.retrans_s, clk_off.retrans_s);
    assert_eq!(clk_on.retrans_bits, clk_off.retrans_bits);
    assert_eq!(tracer.violation_count(), 0, "armed run must audit clean");
}

// ---------------------------------------------------------------------------
// PR 10: runtime SIMD dispatch — backend differential matrix.
//
// The unit tests in `util::simd`, `kernels`, and `bitpack` pin each kernel
// against its scalar oracle in isolation. The tests below pin the *composed*
// packed stages — encode_int → biased pack → segmented ring add → unpack —
// stage by stage, across every backend `simd::available()` reports, over a
// scheme × bits × workers matrix. Every intermediate artifact (integer
// levels, resident words, reduced words, unpacked codes) must be
// bit-identical between the vector backend and the pinned scalar fallback.
// (The forced-scalar CI job reruns this whole file with REPRO_FORCE_SCALAR
// set, so the production `simd::active()` entries are exercised both ways.)
// ---------------------------------------------------------------------------

use repro::compress::bitpack;
use repro::util::simd::{self, Backend};

/// Run the composed QSGD packed stages on one backend; return every
/// intermediate artifact for cross-backend comparison.
fn packed_stages_qsgd(
    bk: Backend,
    grads: &[Vec<f32>],
    bits_q: usize,
    seed: u64,
) -> (Vec<Vec<i32>>, Vec<Vec<u64>>, Vec<u64>, Vec<u64>) {
    let m = grads.len();
    let n = grads[0].len();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let wnorm = max_norm(&refs);
    let s = kernels::s_for_bits(bits_q);
    let rbits = bitpack::packed_sum_bits(s, m);
    let bias = s as i64;
    let root = Rng::new(seed);

    let mut levels: Vec<Vec<i32>> = Vec::with_capacity(m);
    let mut packs: Vec<Vec<u64>> = Vec::with_capacity(m);
    for (w, g) in grads.iter().enumerate() {
        let mut wrng = root.derive(&[w as u64]);
        let mut uni = vec![0.0f32; n];
        wrng.fill_uniform_f32(&mut uni);
        let mut lv = vec![0i32; n];
        kernels::qsgd_encode_int_backend(bk, g, wnorm, &uni, s, &mut lv);
        let mut words = vec![0u64; bitpack::words_for(n, rbits)];
        bitpack::pack_biased_i32_at_backend(bk, &lv, bias, rbits, &mut words, 0);
        levels.push(lv);
        packs.push(words);
    }
    // segmented adds (mimicking ring reduce-scatter partition boundaries)
    // so the masked first/last words and the SIMD middle all get exercised
    let mut acc = packs[0].clone();
    let seg = n / m;
    for src in &packs[1..] {
        for part in 0..m {
            let lo = part * seg;
            let hi = if part + 1 == m { n } else { (part + 1) * seg };
            bitpack::add_packed_codes_backend(bk, &mut acc, src, rbits, lo, hi);
        }
    }
    let mut codes = vec![0u64; n];
    bitpack::unpack_codes_at_backend(bk, &acc, rbits, 0, &mut codes);
    (levels, packs, acc, codes)
}

#[test]
fn simd_backend_matrix_qsgd_stages_bit_identical_to_scalar() {
    let backends = simd::available();
    for &bits_q in &[2usize, 3, 4, 6, 8] {
        for &m in &[2usize, 5] {
            let n = 1023usize;
            let mut grng = Rng::new(0x51D0 ^ ((bits_q as u64) << 8) ^ m as u64);
            let grads: Vec<Vec<f32>> = (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    grng.fill_normal_f32(&mut v, 1.0);
                    v
                })
                .collect();
            let seed = 0xAB5EED ^ bits_q as u64;
            let want = packed_stages_qsgd(Backend::Scalar, &grads, bits_q, seed);
            for &bk in &backends {
                let got = packed_stages_qsgd(bk, &grads, bits_q, seed);
                assert_eq!(got.0, want.0, "{} b{bits_q} m{m}: integer levels", bk.label());
                assert_eq!(got.1, want.1, "{} b{bits_q} m{m}: packed words", bk.label());
                assert_eq!(got.2, want.2, "{} b{bits_q} m{m}: reduced words", bk.label());
                assert_eq!(got.3, want.3, "{} b{bits_q} m{m}: unpacked codes", bk.label());
            }
        }
    }
}

/// Multi-scale analog: scale-index proposal → min-share → encode_int →
/// biased pack → segmented add → unpack, per backend.
fn packed_stages_multiscale(
    bk: Backend,
    grads: &[Vec<f32>],
    scales: &[usize],
    seed: u64,
) -> (Vec<u8>, Vec<Vec<i32>>, Vec<u64>, Vec<u64>) {
    let m = grads.len();
    let n = grads[0].len();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let wnorm = max_norm(&refs);
    let table = kernels::ScaleTable::new(scales);
    let smax = *scales.iter().max().unwrap();
    let rbits = bitpack::packed_sum_bits(smax, m);
    let bias = smax as i64;
    let root = Rng::new(seed);

    let mut proposals: Vec<Vec<u8>> = Vec::with_capacity(m);
    for g in grads {
        let mut prop = vec![0u8; n];
        kernels::multiscale_scale_index_t_backend(bk, g, wnorm, &table, &mut prop);
        proposals.push(prop);
    }
    let shared = collectives::min_allreduce_u8(&proposals);

    let mut levels: Vec<Vec<i32>> = Vec::with_capacity(m);
    let mut acc = vec![0u64; bitpack::words_for(n, rbits)];
    let seg = n / m + 1;
    for (w, g) in grads.iter().enumerate() {
        let mut wrng = root.derive(&[w as u64]);
        let mut uni = vec![0.0f32; n];
        wrng.fill_uniform_f32(&mut uni);
        let mut lv = vec![0i32; n];
        kernels::multiscale_encode_int_backend(bk, g, wnorm, &uni, &shared, &table, &mut lv);
        let mut words = vec![0u64; bitpack::words_for(n, rbits)];
        bitpack::pack_biased_i32_at_backend(bk, &lv, bias, rbits, &mut words, 0);
        for lo in (0..n).step_by(seg) {
            let hi = (lo + seg).min(n);
            bitpack::add_packed_codes_backend(bk, &mut acc, &words, rbits, lo, hi);
        }
        levels.push(lv);
    }
    let mut codes = vec![0u64; n];
    bitpack::unpack_codes_at_with_backend(bk, &acc, rbits, 0, n, |i, c| codes[i] = c);
    (shared, levels, acc, codes)
}

#[test]
fn simd_backend_matrix_multiscale_stages_bit_identical_to_scalar() {
    let backends = simd::available();
    let cases: [&[usize]; 3] = [&[2, 6], &[3, 7, 15], &[2, 4, 8, 12]];
    for scale_bits in cases {
        let scales: Vec<usize> = scale_bits.iter().map(|&b| kernels::s_for_bits(b)).collect();
        for &m in &[2usize, 4] {
            let n = 997usize; // prime: every tail/boundary shape shows up
            let mut grng = Rng::new(0x7515 ^ ((scale_bits.len() as u64) << 12) ^ m as u64);
            let grads: Vec<Vec<f32>> = (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    grng.fill_normal_f32(&mut v, 1.0);
                    v
                })
                .collect();
            let seed = 0xC0DE ^ m as u64;
            let want = packed_stages_multiscale(Backend::Scalar, &grads, &scales, seed);
            for &bk in &backends {
                let got = packed_stages_multiscale(bk, &grads, &scales, seed);
                assert_eq!(got.0, want.0, "{} m{m}: shared scale indices", bk.label());
                assert_eq!(got.1, want.1, "{} m{m}: integer levels", bk.label());
                assert_eq!(got.2, want.2, "{} m{m}: reduced words", bk.label());
                assert_eq!(got.3, want.3, "{} m{m}: unpacked codes", bk.label());
            }
        }
    }
}

/// Satellite 2, end to end through the control plane: a scale-share index
/// poisoned on the wire must panic at the error-feedback residual boundary
/// instead of dividing by the table's 0.0 padding lane. The worker task's
/// message is laundered by the thread pool, so the observable panic is the
/// pool's re-raise (the direct decode boundary messages are pinned by the
/// `kernels`/`fused` unit tests).
#[test]
#[should_panic(expected = "ThreadPool task panicked")]
fn poisoned_wire_share_panics_at_the_residual_boundary() {
    use repro::control::ErrorFeedback;
    let n = 32usize;
    let grads = vec![vec![0.5f32; n], vec![-0.25f32; n]];
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let uni = vec![vec![0.5f32; n]; 2];
    let table = kernels::ScaleTable::new(&[3, 15]);
    let mut shared = vec![0u8; n];
    shared[13] = 9; // poisoned: the table only has 2 scales
    let mut ef = ErrorFeedback::new();
    let mut corrected = Vec::new();
    ef.apply(&refs, &mut corrected);
    ef.absorb_bucket_multiscale(&corrected, &uni, 0, n, 1.0, &table, &shared);
}
