//! Self-healing data plane (PR 7) — integration matrix.
//!
//! Three layers under test, without artifacts or a PJRT backend:
//!
//! 1. **Parity both ways.** Integrity ON over a clean wire changes the
//!    ledgers by the closed-form checksum charge and nothing else — the
//!    aggregated output is bit-identical. Integrity OFF under a corrupting
//!    fault plan is a strict no-op: a trusting wire delivers the payload
//!    regardless, so outputs *and* every clock match the fault-free run.
//! 2. **Healing.** A faulty wire under integrity retransmits and converges
//!    to the clean run bit-for-bit; `retrans_bits`/`retrans_s` carry the
//!    closed-form ladder rebuilt here from the public hop ledger and the
//!    same pure per-attempt draws.
//! 3. **Escalation.** A peer that exhausts every retry is dropped through
//!    [`ElasticCohort::drop_unreachable`] into the PR 6 partial-cohort
//!    path, and the survivors' aggregate equals the independent id-keyed
//!    fixed-M f32 reference; below quorum the step degrades to local.

use repro::collectives::{self, packed, IntegrityConfig, StepCtx, CHECKSUM_BYTES};
use repro::compress::{kernels, Aggregator, Method};
use repro::control::{build_plane, ControlConfig, ElasticCohort, ElasticConfig};
use repro::netsim::{Algo, FaultPlan, HopFault, NetConfig, RingWidth, SimClock};
use repro::util::rng::Rng;

fn make_grads(seed: u64, m: usize, n: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal_f32(&mut v, 1.0);
            v
        })
        .collect()
}

/// One monolithic aggregate with the integrity/fault seams armed as given.
fn run_mono(
    spec: &str,
    grads: &[Vec<f32>],
    seed: u64,
    algo: Algo,
    width: RingWidth,
    integrity: Option<IntegrityConfig>,
    faults: Option<(&FaultPlan, usize)>,
) -> (Vec<f32>, SimClock) {
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let n = refs[0].len();
    let mut agg = Method::parse(spec).unwrap().build(n, &[]).unwrap();
    let mut net = NetConfig::flat(grads.len(), 10.0);
    net.algo = algo;
    let mut clock = SimClock::default();
    let out = {
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.ring_width = width;
        ctx.integrity = integrity;
        ctx.wire_faults = faults;
        let mut rng = Rng::new(seed);
        agg.aggregate(&refs, &mut ctx, &mut rng)
    };
    (out, clock)
}

#[test]
fn clean_wire_integrity_is_output_parity_plus_closed_form_checksum() {
    // Integrity over a clean wire: same bits on the data plane, exactly
    // 64*hops extra on both bit ledgers (8 checksum bytes per hop
    // segment), a bandwidth-only comm increment, zero retransmission.
    let m = 4usize;
    let n = 513usize;
    let grads = make_grads(0x5EA1, m, n);
    let icfg = IntegrityConfig::default();
    let clean = FaultPlan::wire(0x11, 0.0, 0.0);
    for spec in ["qsgd-mn-4", "qsgd-mn-ts-2-6"] {
        for (algo, width) in [
            (Algo::Ring, RingWidth::Fixed),
            (Algo::Ring, RingWidth::Growing),
            (Algo::Tree, RingWidth::Auto),
        ] {
            let (out_off, clk_off) =
                run_mono(spec, &grads, 0x7E57, algo, width, None, None);
            let (out_on, clk_on) =
                run_mono(spec, &grads, 0x7E57, algo, width, Some(icfg), None);
            assert_eq!(out_on, out_off, "{spec} {algo:?} {width:?}: output parity");
            let hops = packed::schedule_for(algo, false, 1).as_dyn().hops(m);
            let want = (8 * CHECKSUM_BYTES * hops) as f64;
            assert_eq!(
                clk_on.bits_per_worker - clk_off.bits_per_worker,
                want,
                "{spec} {algo:?} {width:?}: nominal ledger delta"
            );
            assert_eq!(
                clk_on.hop_bits_per_worker - clk_off.hop_bits_per_worker,
                want,
                "{spec} {algo:?} {width:?}: hop ledger delta"
            );
            assert!(
                clk_on.comm_s > clk_off.comm_s,
                "{spec} {algo:?} {width:?}: checksum bytes must cost wire time"
            );
            assert_eq!(clk_on.retrans_s, 0.0, "clean wire never retransmits");
            assert_eq!(clk_on.retrans_bits, 0.0, "clean wire never retransmits");

            // a loss=0,flip=0 fault plan armed alongside integrity is the
            // same clean run bit for bit (the documented PR 6 parity knob)
            let (out_armed, clk_armed) = run_mono(
                spec,
                &grads,
                0x7E57,
                algo,
                width,
                Some(icfg),
                Some((&clean, 9)),
            );
            assert_eq!(out_armed, out_on, "{spec} {algo:?}: zero-rate plan output");
            assert_eq!(clk_armed.comm_s, clk_on.comm_s, "{spec}: zero-rate plan comm");
            assert_eq!(clk_armed.retrans_bits, 0.0, "{spec}: zero-rate plan retrans");
        }
    }
}

#[test]
fn integrity_off_ignores_the_corrupting_wire_entirely() {
    // The corruption matrix, integrity OFF: the simulated wire is
    // trusting, so loss/flip draws change nothing — outputs and every
    // deterministic clock field are bit-identical to the fault-free run.
    let m = 4usize;
    let n = 384usize;
    let grads = make_grads(0xC0FF, m, n);
    let plan = FaultPlan::wire(0xABCD, 0.2, 0.1);
    for spec in ["qsgd-mn-4", "qsgd-mn-ts-2-6"] {
        for (algo, width) in [
            (Algo::Ring, RingWidth::Fixed),
            (Algo::Ring, RingWidth::Growing),
            (Algo::Tree, RingWidth::Auto),
        ] {
            let (out_base, clk_base) =
                run_mono(spec, &grads, 0xBEEF, algo, width, None, None);
            let (out_faulty, clk_faulty) =
                run_mono(spec, &grads, 0xBEEF, algo, width, None, Some((&plan, 5)));
            assert_eq!(out_faulty, out_base, "{spec} {algo:?} {width:?}: output");
            assert_eq!(clk_faulty.comm_s, clk_base.comm_s, "{spec} {algo:?}: comm");
            assert_eq!(
                clk_faulty.bits_per_worker, clk_base.bits_per_worker,
                "{spec} {algo:?}: bits"
            );
            assert_eq!(
                clk_faulty.hop_bits_per_worker, clk_base.hop_bits_per_worker,
                "{spec} {algo:?}: hop bits"
            );
            assert_eq!(clk_faulty.retrans_s, 0.0, "{spec} {algo:?}: no retrans charge");
            assert_eq!(clk_faulty.retrans_bits, 0.0, "{spec} {algo:?}: no retrans bits");
        }
    }
}

#[test]
fn faulty_wire_under_integrity_heals_bit_identically_at_the_ladder_price() {
    // Healing: corrupted/lost hops retransmit until a clean copy lands, so
    // the aggregate equals the clean-wire run bit for bit, and the whole
    // price shows up on retrans_s/retrans_bits — rebuilt here closed-form
    // from the public hop ledger (RingFixed ships the same segment every
    // hop) and the same pure per-attempt draws the charger replays.
    let m = 4usize;
    let n = 420usize;
    let grads = make_grads(0xFEED, m, n);
    let icfg = IntegrityConfig::default();
    let plan = FaultPlan::wire(0xF00D, 0.1, 0.15);
    let hops = packed::schedule_for(Algo::Ring, false, 1).as_dyn().hops(m);

    // find a step whose draws actually fail somewhere (pure queries — the
    // same stream the charger consumes), so the assertion below has teeth
    let step = (0..64)
        .find(|&s| {
            (0..m).any(|w| {
                (0..hops).any(|h| plan.hop_fault(s, w, h, 0) != HopFault::None)
            })
        })
        .expect("a 25% per-hop fault rate must fire within 64 steps");

    let (out_clean, clk_clean) =
        run_mono("qsgd-mn-4", &grads, 0xD1CE, Algo::Ring, RingWidth::Fixed, Some(icfg), None);
    let (out_faulty, clk_faulty) = run_mono(
        "qsgd-mn-4",
        &grads,
        0xD1CE,
        Algo::Ring,
        RingWidth::Fixed,
        Some(icfg),
        Some((&plan, step)),
    );
    assert_eq!(out_faulty, out_clean, "healed run must be bit-identical");
    assert_eq!(clk_faulty.comm_s, clk_clean.comm_s, "first-copy wire time unchanged");
    assert_eq!(
        clk_faulty.bits_per_worker, clk_clean.bits_per_worker,
        "nominal ledger unchanged (retransmits are booked separately)"
    );

    // closed form: seg bytes from the integrity-off hop ledger + checksum
    let (_, clk_off) =
        run_mono("qsgd-mn-4", &grads, 0xD1CE, Algo::Ring, RingWidth::Fixed, None, None);
    let seg_bytes = clk_off.hop_bits_per_worker / hops as f64 / 8.0 + CHECKSUM_BYTES as f64;
    let net = NetConfig::flat(m, 10.0);
    let mut want_bits = 0.0;
    let mut want_s = 0.0;
    for h in 0..hops {
        for w in 0..m {
            let mut failed = 0u32;
            while failed <= icfg.max_retries
                && plan.hop_fault(step, w, h, failed) != HopFault::None
            {
                failed += 1;
            }
            let sent = failed.min(icfg.max_retries);
            if sent > 0 {
                want_bits += sent as f64 * 8.0 * seg_bytes;
                want_s += icfg.backoff_base_s * (2f64.powi(sent as i32) - 1.0)
                    + sent as f64 * net.hop_s(seg_bytes);
            }
        }
    }
    assert!(want_bits > 0.0, "the chosen step must have failing draws");
    assert_eq!(clk_faulty.retrans_bits, want_bits, "closed-form retrans bits");
    assert_eq!(clk_faulty.retrans_s, want_s, "closed-form retrans time");
}

/// Id-keyed f32 QSGD-MN reference (the PR 6 fixed-M pipeline): slot `i`
/// draws the uniform stream of ORIGINAL worker id `ids[i]`, the shared
/// norm is over the survivors only, the decode divides by the live count.
fn reference_qsgd_ids(grads: &[&[f32]], ids: &[usize], bits: usize, seed: u64) -> Vec<f32> {
    let m = grads.len();
    let n = grads[0].len();
    let s = kernels::s_for_bits(bits);
    let wnorm = grads.iter().map(|v| kernels::l2_norm(v)).fold(0.0f32, f32::max);
    let rng = Rng::new(seed);
    let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(m);
    for (g, &w) in grads.iter().zip(ids) {
        let mut wrng = rng.derive(&[w as u64]);
        let mut uni = vec![0.0f32; n];
        wrng.fill_uniform_f32(&mut uni);
        let mut buf = vec![0.0f32; n];
        kernels::qsgd_encode(g, wnorm, &uni, s, &mut buf);
        bufs.push(buf);
    }
    collectives::ring_allreduce_sum(&mut bufs);
    let mut sum = bufs.swap_remove(0);
    kernels::qsgd_decode_sum(&mut sum, wnorm, s, m);
    sum
}

#[test]
fn retry_exhaustion_escalates_into_the_id_keyed_partial_cohort() {
    // With zero retries, any hop whose first copy fails makes its peer
    // unreachable for the step. The cluster's escalation predicate finds
    // those peers from the same pure draws, `drop_unreachable` folds them
    // out, and the survivors' aggregate equals the independent id-keyed
    // fixed-M reference — the PR 6 degradation, reached through the PR 7
    // integrity path.
    let m = 4usize;
    let n = 501usize;
    let grads = make_grads(0xDEAD, m, n);
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let icfg = IntegrityConfig { max_retries: 0, ..IntegrityConfig::default() };
    let faults = FaultPlan::wire(0x57A9, 0.08, 0.0);
    let hops = packed::schedule_for(Algo::Ring, false, 1).as_dyn().hops(m);

    let ec = ElasticConfig {
        policy: repro::control::CohortPolicy::StrictSync,
        quorum: 1,
        faults: faults.clone(),
    };
    let mut cohort = ElasticCohort::new(ec, m).unwrap();
    let mut exercised = false;
    for step in 0..40usize {
        let mut plan = cohort.plan_step(step, 1.0);
        let dead = faults.unreachable_peers(step, &plan.live, hops, icfg.max_retries);
        cohort.drop_unreachable(&mut plan, &dead);
        if plan.sync && !dead.is_empty() {
            // a proper partial cohort: aggregate the survivors
            assert!(plan.live.len() < m, "someone was dropped");
            exercised = true;
            let sub: Vec<&[f32]> = plan.live.iter().map(|&w| refs[w]).collect();
            let mut plane =
                build_plane(&Method::parse("qsgd-mn-4").unwrap(), &ControlConfig::new(1), n, &[])
                    .unwrap();
            let mut net = NetConfig::flat(plan.live.len(), 10.0);
            net.algo = Algo::Ring;
            let mut clock = SimClock::default();
            let step_seed = 0xDEAD ^ step as u64;
            let got = {
                let mut ctx = StepCtx::new(&net, &mut clock);
                ctx.integrity = Some(icfg);
                ctx.wire_faults = Some((&faults, step));
                let mut rng = Rng::new(step_seed);
                plane.aggregate_cohort(&sub, &plan.live, &mut ctx, &mut rng)
            };
            let want = reference_qsgd_ids(&sub, &plan.live, 4, step_seed);
            assert_eq!(got, want, "step {step} (live {:?}): id-keyed reference", plan.live);
        }
        cohort.commit(&plan);
        if exercised {
            break;
        }
    }
    assert!(exercised, "no step produced a proper partial cohort in 40 tries");

    // total loss: every peer exhausts its retries; below quorum the step
    // degrades to a local one over the full membership — no empty collective
    let total = FaultPlan::wire(0x57A9, 1.0, 0.0);
    let ec = ElasticConfig {
        policy: repro::control::CohortPolicy::StrictSync,
        quorum: 1,
        faults: total.clone(),
    };
    let mut cohort = ElasticCohort::new(ec, m).unwrap();
    let mut plan = cohort.plan_step(0, 1.0);
    let dead = total.unreachable_peers(0, &plan.live, hops, 0);
    assert_eq!(dead, vec![0, 1, 2, 3], "loss=1.0 kills every delivery");
    cohort.drop_unreachable(&mut plan, &dead);
    assert!(!plan.sync, "empty cohort cannot synchronize");
    assert_eq!(plan.live, vec![0, 1, 2, 3], "local step over the membership");
}
