//! End-to-end convergence through the full three-layer stack: PJRT compute,
//! Rust compression + simulated collectives, SGD update.

use repro::cluster::{run_training, ClusterConfig};
use repro::compress::Method;
use repro::runtime::Artifacts;

fn artifacts() -> Artifacts {
    Artifacts::load_default().expect("run `make artifacts` before cargo test")
}

fn final_loss(model: &str, method: &str, steps: usize, workers: usize, seed: u64) -> (f64, f64) {
    final_loss_lr(model, method, steps, workers, seed, 0.05)
}

fn final_loss_lr(
    model: &str,
    method: &str,
    steps: usize,
    workers: usize,
    seed: u64,
    lr0: f64,
) -> (f64, f64) {
    let arts = artifacts();
    let mut cfg = ClusterConfig::new(model, workers, Method::parse(method).unwrap());
    cfg.total_steps = steps;
    cfg.seed = seed;
    cfg.lr0 = lr0;
    let (records, summary) = run_training(&arts, cfg, |_| {}).unwrap();
    let first = records.first().unwrap().loss;
    let _ = summary;
    (first, records.last().unwrap().loss)
}

#[test]
fn dense_baseline_learns() {
    let (first, last) = final_loss("mlp", "allreduce", 25, 2, 7);
    assert!(first > 2.0, "init loss should be ~ln(10): {first}");
    assert!(last < first * 0.6, "loss must drop: {first} -> {last}");
}

#[test]
fn qsgd8_matches_dense_closely() {
    // Fig 1/2 claim: 8-bit QSGD-MN trains as well as AllReduce-SGD.
    let (_, dense) = final_loss("mlp", "allreduce", 25, 2, 7);
    let (_, q8) = final_loss("mlp", "qsgd-mn-8", 25, 2, 7);
    assert!(
        (q8 - dense).abs() < 0.25 * dense.max(0.1) + 0.05,
        "8-bit should track dense: {q8} vs {dense}"
    );
}

#[test]
fn all_paper_methods_reduce_loss() {
    // lr 0.02: the aggressive quantizers on the 1.7M-param MLP need a
    // smaller step (Lemma 5 variance scales with sqrt(n)/s — the same
    // mechanism behind the paper's 2-bit transient, Figs 3/4).
    for method in [
        "qsgd-mn-4",
        "qsgd-mn-ts-4-8",
        "grandk-mn-8",
        "grandk-mn-ts-8-12",
        "powersgd-1",
        "terngrad",
        "topk",
    ] {
        let (first, last) = final_loss_lr("mlp", method, 25, 2, 7, 0.02);
        assert!(
            last < first,
            "{method}: loss must decrease ({first} -> {last})"
        );
    }
}

#[test]
fn runs_are_reproducible() {
    let (_, a) = final_loss("mlp", "qsgd-mn-4", 10, 2, 99);
    let (_, b) = final_loss("mlp", "qsgd-mn-4", 10, 2, 99);
    assert_eq!(a, b, "same seed must give identical runs");
    let (_, c) = final_loss("mlp", "qsgd-mn-4", 10, 2, 100);
    assert_ne!(a, c, "different seed must change the trajectory");
}

#[test]
fn wire_floor_increases_bits_not_loss() {
    let arts = artifacts();
    let mut cfg = ClusterConfig::new("mlp", 2, Method::parse("qsgd-mn-2").unwrap());
    cfg.total_steps = 6;
    let (rec_free, _) = run_training(&arts, cfg.clone(), |_| {}).unwrap();
    cfg.wire_floor_bits = Some(8.0);
    let (rec_floor, _) = run_training(&arts, cfg, |_| {}).unwrap();
    // identical numerics (floor only affects the wire ledger)
    for (a, b) in rec_free.iter().zip(&rec_floor) {
        assert_eq!(a.loss, b.loss, "wire floor must not change numerics");
        assert!(b.bits_per_worker > a.bits_per_worker, "floor must charge more bits");
    }
}
