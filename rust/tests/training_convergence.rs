//! End-to-end convergence through the full three-layer stack: PJRT compute,
//! Rust compression + simulated collectives, SGD update.

use repro::cluster::{run_training, ClusterConfig};
use repro::compress::Method;
use repro::runtime::Artifacts;

fn artifacts() -> Artifacts {
    Artifacts::load_default().expect("run `make artifacts` before cargo test")
}

fn final_loss(model: &str, method: &str, steps: usize, workers: usize, seed: u64) -> (f64, f64) {
    final_loss_lr(model, method, steps, workers, seed, 0.05)
}

fn final_loss_lr(
    model: &str,
    method: &str,
    steps: usize,
    workers: usize,
    seed: u64,
    lr0: f64,
) -> (f64, f64) {
    let arts = artifacts();
    let mut cfg = ClusterConfig::new(model, workers, Method::parse(method).unwrap());
    cfg.total_steps = steps;
    cfg.seed = seed;
    cfg.lr0 = lr0;
    let (records, summary) = run_training(&arts, cfg, |_| {}).unwrap();
    let first = records.first().unwrap().loss;
    let _ = summary;
    (first, records.last().unwrap().loss)
}

#[test]
fn dense_baseline_learns() {
    let (first, last) = final_loss("mlp", "allreduce", 25, 2, 7);
    assert!(first > 2.0, "init loss should be ~ln(10): {first}");
    assert!(last < first * 0.6, "loss must drop: {first} -> {last}");
}

#[test]
fn qsgd8_matches_dense_closely() {
    // Fig 1/2 claim: 8-bit QSGD-MN trains as well as AllReduce-SGD.
    let (_, dense) = final_loss("mlp", "allreduce", 25, 2, 7);
    let (_, q8) = final_loss("mlp", "qsgd-mn-8", 25, 2, 7);
    assert!(
        (q8 - dense).abs() < 0.25 * dense.max(0.1) + 0.05,
        "8-bit should track dense: {q8} vs {dense}"
    );
}

#[test]
fn all_paper_methods_reduce_loss() {
    // lr 0.02: the aggressive quantizers on the 1.7M-param MLP need a
    // smaller step (Lemma 5 variance scales with sqrt(n)/s — the same
    // mechanism behind the paper's 2-bit transient, Figs 3/4).
    for method in [
        "qsgd-mn-4",
        "qsgd-mn-ts-4-8",
        "grandk-mn-8",
        "grandk-mn-ts-8-12",
        "powersgd-1",
        "terngrad",
        "topk",
    ] {
        let (first, last) = final_loss_lr("mlp", method, 25, 2, 7, 0.02);
        assert!(
            last < first,
            "{method}: loss must decrease ({first} -> {last})"
        );
    }
}

#[test]
fn runs_are_reproducible() {
    let (_, a) = final_loss("mlp", "qsgd-mn-4", 10, 2, 99);
    let (_, b) = final_loss("mlp", "qsgd-mn-4", 10, 2, 99);
    assert_eq!(a, b, "same seed must give identical runs");
    let (_, c) = final_loss("mlp", "qsgd-mn-4", 10, 2, 100);
    assert_ne!(a, c, "different seed must change the trajectory");
}

#[test]
fn wire_floor_increases_bits_not_loss() {
    let arts = artifacts();
    let mut cfg = ClusterConfig::new("mlp", 2, Method::parse("qsgd-mn-2").unwrap());
    cfg.total_steps = 6;
    let (rec_free, _) = run_training(&arts, cfg.clone(), |_| {}).unwrap();
    cfg.wire_floor_bits = Some(8.0);
    let (rec_floor, _) = run_training(&arts, cfg, |_| {}).unwrap();
    // identical numerics (floor only affects the wire ledger)
    for (a, b) in rec_free.iter().zip(&rec_floor) {
        assert_eq!(a.loss, b.loss, "wire floor must not change numerics");
        assert!(b.bits_per_worker > a.bits_per_worker, "floor must charge more bits");
    }
}

// ---------------------------------------------------------------------------
// PR 6: elastic cohort under faults
// ---------------------------------------------------------------------------

use repro::control::{CohortPolicy, ControlConfig, ElasticConfig};
use repro::netsim::FaultPlan;

fn elastic_cfg(
    workers: usize,
    policy: CohortPolicy,
    faults: FaultPlan,
) -> ClusterConfig {
    let mut cfg = ClusterConfig::new("mlp", workers, Method::parse("qsgd-mn-4").unwrap());
    cfg.total_steps = 24;
    cfg.seed = 7;
    cfg.lr0 = 0.02;
    // deterministic compute profile: the straggler model times jitter off
    // this base instead of the (noisy) measured wall time
    cfg.sim_compute_s = Some(0.01);
    cfg.control = Some(ControlConfig::new(2));
    cfg.elastic = Some(ElasticConfig { policy, quorum: 1, faults });
    cfg
}

#[test]
fn periodic_sync_bounds_staleness_and_pays_wire_bits_only_on_sync_steps() {
    // periodic-sync degradation: workers accumulate locally and average
    // every `period` steps. Staleness entering any step is bounded by
    // period-1, the wire is silent between syncs, and the run still learns
    // off the accumulated (mean-of-means) gradient.
    let arts = artifacts();
    let period = 3usize;
    let cfg = elastic_cfg(2, CohortPolicy::PeriodicSync { period }, FaultPlan::none());
    let (records, summary) = run_training(&arts, cfg, |_| {}).unwrap();

    for rec in &records {
        assert!(
            rec.staleness <= period - 1,
            "step {}: staleness {} exceeds period-1={}",
            rec.step,
            rec.staleness,
            period - 1
        );
        if (rec.step + 1) % period == 0 {
            assert!(rec.bits_per_worker > 0.0, "step {}: sync must pay wire bits", rec.step);
            assert!(rec.t_comm_sim > 0.0, "step {}: sync must spend comm time", rec.step);
        } else {
            assert_eq!(rec.bits_per_worker, 0.0, "step {}: local step paid bits", rec.step);
            assert_eq!(rec.t_comm_sim, 0.0, "step {}: local step spent comm", rec.step);
        }
        assert_eq!(rec.live_workers, 2, "no membership events in this plan");
    }
    // the bound is tight: staleness actually reaches period-1
    assert!(
        records.iter().any(|r| r.staleness == period - 1),
        "staleness never reached the period-1 bound"
    );
    let first = records.first().unwrap().loss;
    let last = records.last().unwrap().loss;
    assert!(last < first, "periodic-sync run must still learn: {first} -> {last}");
    assert_eq!(summary.t_straggler_wait, 0.0, "no jitter, no waiting");
}

#[test]
fn timeout_into_partial_beats_strict_sync_under_jitter() {
    // PR 6 acceptance: under seeded step-time jitter, cutting stragglers
    // off at the deadline and renormalizing for the live cohort is faster
    // than waiting for the slowest worker — on the deterministic simulated
    // components (compute + comm + straggler wait; encode/decode are
    // wall-measured and excluded from cross-run comparisons). At zero
    // jitter the timeout arm never fires and the two policies agree.
    let arts = artifacts();
    let run = |policy: CohortPolicy, jitter: f64| {
        let cfg = elastic_cfg(4, policy, FaultPlan::jittered(0xFA01, jitter));
        run_training(&arts, cfg, |_| {}).unwrap()
    };
    let partial = || CohortPolicy::TimeoutPartial { timeout_frac: 0.1 };
    let det = |s: &repro::metrics::RunSummary| s.t_compute + s.t_comm_sim + s.t_straggler_wait;

    let (_, s0) = run(CohortPolicy::StrictSync, 0.0);
    let (_, p0) = run(partial(), 0.0);
    assert_eq!(s0.t_straggler_wait, 0.0, "no jitter, strict never waits");
    assert_eq!(p0.t_straggler_wait, 0.0, "no jitter, no deadline fires");
    assert_eq!(s0.t_comm_sim, p0.t_comm_sim, "full cohort both ways at zero jitter");

    for jitter in [0.1, 0.5] {
        let (rs, s) = run(CohortPolicy::StrictSync, jitter);
        let (rp, p) = run(partial(), jitter);
        assert!(s.t_straggler_wait > 0.0, "jitter {jitter}: strict must wait");
        assert!(
            p.t_straggler_wait < s.t_straggler_wait,
            "jitter {jitter}: deadline cap must shed wait ({} vs {})",
            p.t_straggler_wait,
            s.t_straggler_wait
        );
        assert!(
            p.t_comm_sim <= s.t_comm_sim,
            "jitter {jitter}: a smaller cohort never pays more wire time"
        );
        assert!(
            det(&p) < det(&s),
            "jitter {jitter}: partial must beat strict on simulated time ({} vs {})",
            det(&p),
            det(&s)
        );
        // the cap actually bit: some steps synced with a reduced cohort
        assert!(
            rp.iter().any(|r| r.live_workers < 4),
            "jitter {jitter}: no straggler was ever dropped"
        );
        assert!(rs.iter().all(|r| r.live_workers == 4), "strict never drops");
        // both policies still learn
        assert!(rs.last().unwrap().loss < rs.first().unwrap().loss, "strict learns");
        assert!(rp.last().unwrap().loss < rp.first().unwrap().loss, "partial learns");
    }
}

// ---------------------------------------------------------------------------
// PR 7: self-healing data plane
// ---------------------------------------------------------------------------

use repro::collectives::IntegrityConfig;
use repro::control::AnomalyPolicy;
use repro::netsim::HopFault;

#[test]
fn poisoned_step_under_skip_never_reaches_the_wire() {
    // `poison=1@3` plants NaN/Inf in worker 1's step-3 gradient; the
    // default skip policy drops the round before a single level is drawn:
    // compute is charged, the wire and the optimizer see nothing, and the
    // run ledger counts exactly one skipped step.
    let arts = artifacts();
    let cfg = elastic_cfg(
        2,
        CohortPolicy::StrictSync,
        FaultPlan::parse("poison=1@3").unwrap(),
    );
    let (records, summary) = run_training(&arts, cfg, |_| {}).unwrap();
    let rec = &records[3];
    assert!(rec.skipped, "the poisoned step must be skipped");
    assert_eq!(rec.bits_per_worker, 0.0, "nothing reached the wire");
    assert_eq!(rec.t_comm_sim, 0.0, "no comm time for a skipped step");
    assert_eq!(rec.t_encode, 0.0, "no encode for a skipped step");
    assert!(rec.t_compute > 0.0, "compute still happened (and is charged)");
    assert_eq!(summary.skipped_steps, 1, "exactly one skip in the summary");
    assert_eq!(records.iter().filter(|r| r.skipped).count(), 1);
    assert!(
        records.last().unwrap().loss < records.first().unwrap().loss,
        "one dropped round must not stop learning"
    );
}

#[test]
fn poisoned_step_under_abort_fails_loudly() {
    let arts = artifacts();
    let mut cfg = elastic_cfg(
        2,
        CohortPolicy::StrictSync,
        FaultPlan::parse("poison=0@2").unwrap(),
    );
    cfg.on_anomaly = AnomalyPolicy::Abort;
    let err = run_training(&arts, cfg, |_| {}).unwrap_err().to_string();
    assert!(
        err.contains("non-finite gradient at step 2"),
        "abort must name the step: {err}"
    );
}

#[test]
fn poisoned_step_under_clip_sanitizes_and_continues() {
    let arts = artifacts();
    let mut cfg = elastic_cfg(
        2,
        CohortPolicy::StrictSync,
        FaultPlan::parse("poison=1@3").unwrap(),
    );
    cfg.on_anomaly = AnomalyPolicy::Clip(1.0);
    let (records, summary) = run_training(&arts, cfg, |_| {}).unwrap();
    assert_eq!(summary.skipped_steps, 0, "clip repairs instead of dropping");
    assert!(records.iter().all(|r| !r.skipped));
    assert!(records[3].bits_per_worker > 0.0, "the clipped step still syncs");
    assert!(records.iter().all(|r| r.loss.is_finite()), "numerics stay finite");
    assert!(records.last().unwrap().loss < records.first().unwrap().loss);
}

#[test]
fn integrity_checksums_ride_along_without_touching_the_numerics() {
    // integrity on, clean wire: every step's loss is bit-identical, the
    // wire ledger grows by the checksum charge, nothing retransmits
    let arts = artifacts();
    let base = elastic_cfg(2, CohortPolicy::StrictSync, FaultPlan::none());
    let (rec_off, _) = run_training(&arts, base.clone(), |_| {}).unwrap();
    let mut on = base;
    on.integrity = Some(IntegrityConfig::default());
    let (rec_on, sum_on) = run_training(&arts, on, |_| {}).unwrap();
    for (a, b) in rec_off.iter().zip(&rec_on) {
        assert_eq!(a.loss, b.loss, "step {}: checksum must not change numerics", a.step);
        assert!(
            b.bits_per_worker > a.bits_per_worker,
            "step {}: checksum bytes must be charged",
            a.step
        );
        assert_eq!(b.retrans_bits, 0.0, "clean wire never retransmits");
    }
    assert_eq!(sum_on.t_retrans, 0.0);
    assert_eq!(sum_on.skipped_steps, 0);
}

#[test]
fn lossy_wire_with_integrity_heals_and_books_recovery_time() {
    // corrupting wire + integrity: as long as no peer exhausts its retries
    // the run is bit-identical to the clean-wire integrity run, and the
    // whole recovery price lands in retrans_s/retrans_bits. Whether any
    // retransmit (or escalation) happens at all is decided here from the
    // same pure draws the cluster replays, so every branch is asserted
    // deterministically.
    let arts = artifacts();
    let faults = FaultPlan::parse("loss=0.05,flip=0.02,seed=9").unwrap();
    let icfg = IntegrityConfig::default();
    let steps = 24usize;
    let hops = 2 * (2 - 1); // RingFixed at M=2, the cluster's predicate shape
    let any_fail = (0..steps).any(|s| {
        (0..2).any(|w| (0..hops).any(|h| faults.hop_fault(s, w, h, 0) != HopFault::None))
    });
    let any_dead = (0..steps)
        .any(|s| !faults.unreachable_peers(s, &[0, 1], hops, icfg.max_retries).is_empty());

    let mut clean = elastic_cfg(2, CohortPolicy::StrictSync, FaultPlan::none());
    clean.integrity = Some(icfg);
    let (rec_clean, _) = run_training(&arts, clean, |_| {}).unwrap();
    let mut lossy = elastic_cfg(2, CohortPolicy::StrictSync, faults);
    lossy.integrity = Some(icfg);
    let (rec_lossy, summary) = run_training(&arts, lossy, |_| {}).unwrap();

    assert_eq!(
        summary.t_retrans > 0.0,
        any_fail || any_dead,
        "recovery time books exactly when a draw fails"
    );
    if any_dead {
        assert!(
            rec_lossy.iter().any(|r| r.live_workers < 2),
            "an exhausted peer must be dropped into the partial cohort"
        );
    } else {
        for (a, b) in rec_clean.iter().zip(&rec_lossy) {
            assert_eq!(a.loss, b.loss, "step {}: healing must not change numerics", a.step);
            assert_eq!(
                a.bits_per_worker, b.bits_per_worker,
                "step {}: the nominal ledger ignores retransmits",
                a.step
            );
        }
    }
}
