//! Paper-property tier (deterministic CI gate): the statistical contracts
//! that make naive summation of quantized gradients sound — unbiasedness
//! (E[Q(x)] = x, the paper's Lemma 5 first moment) and the Lemma-5 variance
//! bound — checked **end-to-end through the packed aggregate path** (encode
//! → biased pack → schedule-generic packed all-reduce → decode), not just
//! the scalar kernels, for QSGD-MN, QSGD-MN-TS (multi-scale), GRandK-MN,
//! and GRandK-MN-TS.
//!
//! Every test uses fixed seeds and CLT-derived tolerances (>= 4 standard
//! errors), so pass/fail is deterministic: a failure means a real contract
//! regression, not sampling noise. Horváth et al. (2019) motivate gating
//! exactly these moments — a biased or variance-inflated compressor still
//! "trains" but silently loses the convergence guarantees.

use repro::collectives::StepCtx;
use repro::compress::multiscale::QsgdMultiScale;
use repro::compress::qsgd_maxnorm::QsgdMaxNorm;
use repro::compress::randk::{GlobalRandK, GlobalRandKMultiScale};
use repro::compress::{kernels, Aggregator};
use repro::netsim::{Algo, NetConfig, RingWidth, SimClock};
use repro::util::rng::Rng;

/// One aggregate step on the packed plane with the given schedule + width.
fn run_step(
    agg: &mut dyn Aggregator,
    grads: &[Vec<f32>],
    seed: u64,
    algo: Algo,
    width: RingWidth,
) -> Vec<f32> {
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let mut net = NetConfig::flat(grads.len(), 10.0);
    net.algo = algo;
    let mut clock = SimClock::default();
    let mut ctx = StepCtx::new(&net, &mut clock);
    ctx.ring_width = width;
    let mut rng = Rng::new(seed);
    agg.aggregate(&refs, &mut ctx, &mut rng)
}

fn fixed_grads(seed: u64, m: usize, n: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal_f32(&mut v, 1.0);
            v
        })
        .collect()
}

fn mean_of(grads: &[Vec<f32>]) -> Vec<f32> {
    let n = grads[0].len();
    let m = grads.len() as f64;
    (0..n)
        .map(|i| (grads.iter().map(|g| g[i] as f64).sum::<f64>() / m) as f32)
        .collect()
}

fn max_norm(grads: &[Vec<f32>]) -> f32 {
    grads.iter().map(|g| kernels::l2_norm(g)).fold(0.0f32, f32::max)
}

/// Monte-Carlo mean of the aggregate over `trials` fixed-seed steps, checked
/// coordinate-wise against `want` within 5 standard errors of the per-step
/// estimator spread bound `per_step_sd`.
#[allow(clippy::too_many_arguments)]
fn assert_unbiased(
    agg: &mut dyn Aggregator,
    grads: &[Vec<f32>],
    want: &[f32],
    per_step_sd: f64,
    trials: usize,
    seed0: u64,
    algo: Algo,
    width: RingWidth,
    label: &str,
) {
    let n = want.len();
    let mut acc = vec![0.0f64; n];
    for t in 0..trials {
        let out = run_step(agg, grads, seed0 + t as u64, algo, width);
        for i in 0..n {
            acc[i] += out[i] as f64;
        }
    }
    let tol = (5.0 * per_step_sd / (trials as f64).sqrt()).max(1e-6);
    for i in 0..n {
        let est = acc[i] / trials as f64;
        assert!(
            (est - want[i] as f64).abs() <= tol,
            "{label}: E[out[{i}]] = {est} vs {} (tol {tol}, algo {algo:?})",
            want[i]
        );
    }
}

// ---------------------------------------------------------------------------
// Unbiasedness: E[aggregate] = mean gradient, through the packed plane
// ---------------------------------------------------------------------------

#[test]
fn qsgd_mn_unbiased_through_packed_plane_all_schedules() {
    let (m, n) = (3usize, 96usize);
    let grads = fixed_grads(0xA11CE, m, n);
    let want = mean_of(&grads);
    let wmax = max_norm(&grads) as f64;
    let s = kernels::s_for_bits(4) as f64;
    // per-coordinate estimator sd bound: quantization grid w/s, averaged
    // over m independent workers
    let sd = wmax / (s * (m as f64).sqrt());
    // the contract must hold on every schedule of the packed plane — the
    // schedule only changes reduction order of an exact integer sum
    for (algo, width, seed) in [
        (Algo::Ring, RingWidth::Fixed, 10_000u64),
        (Algo::Ring, RingWidth::Growing, 20_000),
        (Algo::Tree, RingWidth::Auto, 30_000),
        (Algo::Naive, RingWidth::Auto, 40_000),
    ] {
        let mut agg = QsgdMaxNorm::new(4).unwrap();
        assert_unbiased(
            &mut agg, &grads, &want, sd, 1200, seed, algo, width, "QSGD-MN-4",
        );
    }
}

#[test]
fn qsgd_mn_ts_unbiased_through_packed_plane() {
    let (m, n) = (3usize, 96usize);
    let grads = fixed_grads(0xB0B, m, n);
    let want = mean_of(&grads);
    let wmax = max_norm(&grads) as f64;
    // worst case: every coordinate at the small scale s_min = s(2 bits) = 1
    let sd = wmax / (1.0 * (m as f64).sqrt());
    let mut agg = QsgdMultiScale::new(&[2, 6]).unwrap();
    assert_unbiased(
        &mut agg,
        &grads,
        &want,
        sd,
        2500,
        50_000,
        Algo::Ring,
        RingWidth::Auto,
        "QSGD-MN-TS-(2,6)",
    );
}

#[test]
fn grandk_unbiased_through_packed_plane() {
    // the n/K-rescaled estimator is the unbiased variant (DESIGN.md §2)
    let (m, n, k) = (2usize, 64usize, 16usize);
    let grads = fixed_grads(0xCAFE, m, n);
    let want = mean_of(&grads);
    let gmax = grads
        .iter()
        .flat_map(|v| v.iter())
        .fold(0.0f32, |a, b| a.max(b.abs())) as f64;
    // dominant spread: the n/K-rescaled Bernoulli coordinate selection
    let sd = gmax * n as f64 / k as f64;
    let mut agg = GlobalRandK::new(8, k, n).unwrap();
    agg.rescale = true;
    assert_unbiased(
        &mut agg,
        &grads,
        &want,
        sd,
        8000,
        70_000,
        Algo::Ring,
        RingWidth::Auto,
        "GRandK-MN-8 (rescaled)",
    );
}

#[test]
fn grandk_ts_unbiased_through_packed_plane() {
    let (m, n, k) = (2usize, 64usize, 16usize);
    let grads = fixed_grads(0xD00D, m, n);
    let want = mean_of(&grads);
    let gmax = grads
        .iter()
        .flat_map(|v| v.iter())
        .fold(0.0f32, |a, b| a.max(b.abs())) as f64;
    let sd = gmax * n as f64 / k as f64;
    let mut agg = GlobalRandKMultiScale::new(&[4, 8], k, n).unwrap();
    agg.rescale = true;
    assert_unbiased(
        &mut agg,
        &grads,
        &want,
        sd,
        8000,
        90_000,
        Algo::Ring,
        RingWidth::Auto,
        "GRandK-MN-TS-(4,8) (rescaled)",
    );
}

// ---------------------------------------------------------------------------
// Lemma-5 variance bound: E||aggregate - v||^2 <= min(n/s^2, sqrt(n)/s)
//                         * ||w||^2 / M, through the packed plane
// ---------------------------------------------------------------------------

/// Mean squared aggregate error over fixed-seed trials with identical
/// per-worker gradients `v` (so wnorm = ||v|| and E[out] = v exactly).
fn mean_sq_error(
    agg: &mut dyn Aggregator,
    v: &[f32],
    m: usize,
    trials: usize,
    seed0: u64,
) -> f64 {
    let grads: Vec<Vec<f32>> = (0..m).map(|_| v.to_vec()).collect();
    let mut acc = 0.0f64;
    for t in 0..trials {
        let out = run_step(agg, &grads, seed0 + t as u64, Algo::Ring, RingWidth::Auto);
        acc += out
            .iter()
            .zip(v)
            .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
            .sum::<f64>();
    }
    acc / trials as f64
}

#[test]
fn qsgd_mn_variance_bound_lemma5_through_packed_plane() {
    let n = 256usize;
    let mut rng = Rng::new(0x5EED);
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut v, 1.0);
    let w = kernels::l2_norm(&v) as f64;
    for (bits, m, seed) in [(2usize, 2usize, 1000u64), (4, 4, 2000), (8, 2, 3000)] {
        let s = kernels::s_for_bits(bits) as f64;
        let nn = n as f64;
        // Lemma 5 over the m-way average of independent quantizations,
        // with 10% slack over the CLT spread of the 400-trial estimate
        let bound = (nn / (s * s)).min(nn.sqrt() / s) * w * w / m as f64;
        let mut agg = QsgdMaxNorm::new(bits).unwrap();
        let got = mean_sq_error(&mut agg, &v, m, 400, seed);
        assert!(
            got <= bound * 1.1,
            "QSGD-MN-{bits} x{m}: E||err||^2 = {got} exceeds Lemma-5 bound {bound}"
        );
    }
}

#[test]
fn qsgd_mn_ts_variance_no_worse_than_smin_bound_through_packed_plane() {
    // the multi-scale scheme refines coordinates *below* the small scale's
    // grid, so its end-to-end variance obeys the single-scale Lemma-5 bound
    // at s_min — at the same wire bits (the scheme's raison d'être).
    let n = 256usize;
    let mut rng = Rng::new(0xFEED);
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut v, 1.0);
    let w = kernels::l2_norm(&v) as f64;
    let m = 2usize;
    let smin = kernels::s_for_bits(2) as f64; // scale set (2, 6) -> s_min = 1
    let nn = n as f64;
    let bound = (nn / (smin * smin)).min(nn.sqrt() / smin) * w * w / m as f64;
    let mut agg = QsgdMultiScale::new(&[2, 6]).unwrap();
    let got = mean_sq_error(&mut agg, &v, m, 400, 4000);
    assert!(
        got <= bound * 1.1,
        "QSGD-MN-TS-(2,6): E||err||^2 = {got} exceeds s_min Lemma-5 bound {bound}"
    );
}

// ---------------------------------------------------------------------------
// PR 4: bucketed control plane — per-bucket unbiasedness and EF boundedness
// ---------------------------------------------------------------------------

use repro::runtime::contiguous_segments;

#[test]
fn bucketed_variance_adaptive_unbiased_per_bucket() {
    // unbiasedness survives the bucketed plane with VarianceAdaptive
    // precision (EF off): every bucket is an independent QSGD-MN quantizer
    // against the shared norm, and E[Q_s(x)] = x holds for ANY s — so the
    // adaptive width choice (which varies per bucket and warms an EMA
    // across trials) cannot bias the aggregate.
    use repro::control::{BitsPolicy, ControlConfig, GradientControlPlane};

    let (m, n) = (3usize, 96usize);
    let seg_lens = [32usize, 32, 32];
    let grads = fixed_grads(0xB0C4E7, m, n);
    let want = mean_of(&grads);
    let wmax = max_norm(&grads) as f64;
    // worst-case estimator sd: the adaptive floor is 2 bits (s = 1)
    let sd = wmax / (1.0 * (m as f64).sqrt());
    let mut cfg = ControlConfig::new(3);
    cfg.bits = BitsPolicy::Auto;
    let mut plane = GradientControlPlane::new(cfg, 4, n, &contiguous_segments(&seg_lens)).unwrap();
    assert_unbiased(
        &mut plane,
        &grads,
        &want,
        sd,
        2500,
        110_000,
        Algo::Ring,
        RingWidth::Auto,
        "bucketed QSGD-MN auto",
    );
}

#[test]
fn bucketed_error_feedback_residual_stays_bounded_200_steps() {
    // with EF on, the per-worker residual e <- x - Q(x) must stay bounded
    // across 200 fixed-seed steps: the adaptive controller keeps the
    // quantization variance under 10% of the (residual-inflated) gradient
    // moment, so the EF recursion contracts instead of accumulating.
    use repro::control::{BitsPolicy, ControlConfig, GradientControlPlane};

    let (m, n) = (3usize, 192usize);
    let seg_lens = [64usize, 64, 64];
    let mut cfg = ControlConfig::new(3);
    cfg.bits = BitsPolicy::Auto;
    cfg.error_feedback = true;
    let mut plane = GradientControlPlane::new(cfg, 8, n, &contiguous_segments(&seg_lens)).unwrap();

    let mut max_grad_norm = 0.0f64;
    let mut max_resid = 0.0f64;
    for step in 0..200u64 {
        let grads = fixed_grads(0xEF00 + step, m, n);
        max_grad_norm = max_grad_norm
            .max(grads.iter().map(|g| kernels::l2_norm(g) as f64).fold(0.0, f64::max));
        let out = run_step(&mut plane, &grads, 0x5EED0 + step, Algo::Ring, RingWidth::Auto);
        assert!(out.iter().all(|x| x.is_finite()), "step {step} non-finite");
        max_resid = max_resid.max(plane.max_residual_norm());
        // the live bound: the residual never exceeds a small multiple of
        // the largest gradient seen — no drift, no blow-up
        assert!(
            plane.max_residual_norm() <= 2.0 * max_grad_norm,
            "step {step}: residual {} exceeds 2x max grad norm {}",
            plane.max_residual_norm(),
            max_grad_norm
        );
    }
    assert!(max_resid > 0.0, "EF must actually accumulate a residual");
}

// ---------------------------------------------------------------------------
// PR 5: bucket-generic control plane — multi-scale and GRandK moments
// ---------------------------------------------------------------------------

#[test]
fn bucketed_multiscale_variance_adaptive_unbiased_per_bucket() {
    // the multi-scale plane under VarianceAdaptive (per-bucket scale pairs
    // shifted against the Lemma-5/6 target at s_min, EF off) must stay
    // unbiased: every bucket is an independent multi-scale quantizer
    // against the shared norm with its own elementwise-min scale share,
    // and E[Q_s*(x)] = x holds for ANY shared scale choice — so neither
    // the adaptive pair choice (which warms an EMA across trials) nor the
    // per-bucket share derivation can bias the aggregate.
    use repro::control::{BitsPolicy, ControlConfig, GradientControlPlane};

    let (m, n) = (3usize, 96usize);
    let seg_lens = [32usize, 32, 32];
    let grads = fixed_grads(0xB0C4E8, m, n);
    let want = mean_of(&grads);
    let wmax = max_norm(&grads) as f64;
    // worst-case estimator sd: the adaptive floor is 2 bits -> s_min = 1
    let sd = wmax / (1.0 * (m as f64).sqrt());
    let mut cfg = ControlConfig::new(3);
    cfg.bits = BitsPolicy::Auto;
    let mut plane = GradientControlPlane::new_multiscale(
        cfg,
        &[2, 6],
        n,
        &contiguous_segments(&seg_lens),
    )
    .unwrap();
    assert_unbiased(
        &mut plane,
        &grads,
        &want,
        sd,
        2500,
        130_000,
        Algo::Ring,
        RingWidth::Auto,
        "bucketed QSGD-MN-TS-(2,6) auto",
    );
}

#[test]
fn bucketed_grandk_variance_adaptive_unbiased_per_bucket() {
    // the n/K-rescaled GRandK estimator stays unbiased through the bucketed
    // plane: the ragged routing of the sorted global draw is deterministic
    // given the draw, each bucket quantizes its gathered slice unbiasedly
    // at whatever width the controller picks, and the scatter applies the
    // same n/K rescale as the monolithic estimator.
    use repro::control::{BitsPolicy, ControlConfig, GradientControlPlane};

    let (m, n, k) = (2usize, 64usize, 16usize);
    let seg_lens = [16usize, 16, 16, 16];
    let grads = fixed_grads(0xBADC0DE, m, n);
    let want = mean_of(&grads);
    let gmax = grads
        .iter()
        .flat_map(|v| v.iter())
        .fold(0.0f32, |a, b| a.max(b.abs())) as f64;
    // dominant spread: the n/K-rescaled Bernoulli coordinate selection
    let sd = gmax * n as f64 / k as f64;
    let mut cfg = ControlConfig::new(4);
    cfg.bits = BitsPolicy::Auto;
    let mut plane =
        GradientControlPlane::new_randk(cfg, 8, k, n, &contiguous_segments(&seg_lens)).unwrap();
    plane.set_rescale(true);
    assert_unbiased(
        &mut plane,
        &grads,
        &want,
        sd,
        8000,
        150_000,
        Algo::Ring,
        RingWidth::Auto,
        "bucketed GRandK-MN-8 auto (rescaled)",
    );
}

#[test]
fn bucketed_multiscale_error_feedback_residual_stays_bounded_200_steps() {
    // EF on the multi-scale path: the residual recompute runs the same
    // multi-scale encode (per-coordinate shared scales) the data plane
    // consumed, so e is exactly what the wire dropped; with the adaptive
    // controller targeting the Lemma-5/6 budget at s_min, the recursion
    // contracts instead of accumulating — bounded over 200 fixed-seed
    // steps, same live bound as the single-scale PR 4 pin.
    use repro::control::{BitsPolicy, ControlConfig, GradientControlPlane};

    let (m, n) = (3usize, 192usize);
    let seg_lens = [64usize, 64, 64];
    let mut cfg = ControlConfig::new(3);
    cfg.bits = BitsPolicy::Auto;
    cfg.error_feedback = true;
    let mut plane = GradientControlPlane::new_multiscale(
        cfg,
        &[2, 6],
        n,
        &contiguous_segments(&seg_lens),
    )
    .unwrap();

    let mut max_grad_norm = 0.0f64;
    let mut max_resid = 0.0f64;
    for step in 0..200u64 {
        let grads = fixed_grads(0xEF05 + step, m, n);
        max_grad_norm = max_grad_norm
            .max(grads.iter().map(|g| kernels::l2_norm(g) as f64).fold(0.0, f64::max));
        let out = run_step(&mut plane, &grads, 0x5EED5 + step, Algo::Ring, RingWidth::Auto);
        assert!(out.iter().all(|x| x.is_finite()), "step {step} non-finite");
        max_resid = max_resid.max(plane.max_residual_norm());
        assert!(
            plane.max_residual_norm() <= 2.0 * max_grad_norm,
            "step {step}: residual {} exceeds 2x max grad norm {}",
            plane.max_residual_norm(),
            max_grad_norm
        );
    }
    assert!(max_resid > 0.0, "EF must actually accumulate a residual");
}

#[test]
fn grandk_variance_bound_through_packed_plane() {
    // GRandK without rescale is the K/n-shrunk estimator: its error against
    // the *full* gradient decomposes into the dropped mass (deterministic
    // given the draw) plus quantization noise on the kept coordinates; the
    // quantization part obeys Lemma 5 on the K-subvector. Gate the total
    // against ||v||^2 + the K-subvector Lemma-5 bound — a regression here
    // means the packed path corrupted either part.
    let (n, k, m) = (256usize, 64usize, 2usize);
    let mut rng = Rng::new(0xF00D);
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut v, 1.0);
    let vnorm2 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>();
    let s = kernels::s_for_bits(4) as f64;
    let kk = k as f64;
    // kept-mass quantization bound at the subvector norm <= ||v||
    let qbound = (kk / (s * s)).min(kk.sqrt() / s) * vnorm2 / m as f64;
    let mut agg = GlobalRandK::new(4, k, n).unwrap();
    let got = mean_sq_error(&mut agg, &v, m, 300, 5000);
    assert!(
        got <= vnorm2 + qbound * 1.1,
        "GRandK-MN-4: E||err||^2 = {got} exceeds dropped-mass + Lemma-5 bound {}",
        vnorm2 + qbound * 1.1
    );
}

// ---------------------------------------------------------------------------
// PR 6: elastic cohort — churn-step unbiasedness over the LIVE mean
// ---------------------------------------------------------------------------

/// One partial-cohort step through the plane: survivors' slices over a wire
/// sized to the live count, uniform streams keyed by ORIGINAL worker id.
fn run_cohort_step(
    agg: &mut dyn Aggregator,
    grads: &[Vec<f32>],
    live: &[usize],
    seed: u64,
) -> Vec<f32> {
    let sub: Vec<&[f32]> = live.iter().map(|&w| grads[w].as_slice()).collect();
    let mut net = NetConfig::flat(live.len(), 10.0);
    net.algo = Algo::Ring;
    let mut clock = SimClock::default();
    let mut ctx = StepCtx::new(&net, &mut clock);
    ctx.ring_width = RingWidth::Auto;
    let mut rng = Rng::new(seed);
    agg.aggregate_cohort(&sub, live, &mut ctx, &mut rng)
}

/// Monte-Carlo mean of the cohort aggregate against the LIVE workers' mean,
/// same 5-standard-error gate as [`assert_unbiased`].
#[allow(clippy::too_many_arguments)]
fn assert_unbiased_cohort(
    agg: &mut dyn Aggregator,
    grads: &[Vec<f32>],
    live: &[usize],
    want: &[f32],
    per_step_sd: f64,
    trials: usize,
    seed0: u64,
    label: &str,
) {
    let n = want.len();
    let mut acc = vec![0.0f64; n];
    for t in 0..trials {
        let out = run_cohort_step(agg, grads, live, seed0 + t as u64);
        for i in 0..n {
            acc[i] += out[i] as f64;
        }
    }
    let tol = (5.0 * per_step_sd / (trials as f64).sqrt()).max(1e-6);
    for i in 0..n {
        let est = acc[i] / trials as f64;
        assert!(
            (est - want[i] as f64).abs() <= tol,
            "{label}: E[out[{i}]] = {est} vs {} (tol {tol})",
            want[i]
        );
    }
}

#[test]
fn elastic_partial_cohort_unbiased_over_the_live_mean_all_bucketable_methods() {
    // PR 6: the renormalized partial all-reduce is an unbiased estimator of
    // the LIVE workers' mean, for every bucketable method. With survivors
    // {0, 1, 3} of M=4, the id-keyed uniform streams and the live-M decode
    // fold must leave E[aggregate_cohort] = mean over the survivors — the
    // dropped worker contributes neither mass nor norm.
    use repro::control::{ControlConfig, GradientControlPlane};
    use repro::runtime::contiguous_segments as segs_of;

    let (m, n, k) = (4usize, 64usize, 16usize);
    let live = [0usize, 1, 3];
    let lm = live.len() as f64;
    let grads = fixed_grads(0xC4A93, m, n);
    let live_grads: Vec<Vec<f32>> = live.iter().map(|&w| grads[w].clone()).collect();
    let want = mean_of(&live_grads);
    let wmax = max_norm(&live_grads) as f64;
    let gmax = live_grads
        .iter()
        .flat_map(|v| v.iter())
        .fold(0.0f32, |a, b| a.max(b.abs())) as f64;
    // dominant GRandK spread: the n/K-rescaled Bernoulli selection
    let sparse_sd = gmax * n as f64 / k as f64;
    let segs = segs_of(&[16usize, 16, 16, 16]);

    let mut single = GradientControlPlane::new(ControlConfig::new(3), 4, n, &segs).unwrap();
    let s4 = kernels::s_for_bits(4) as f64;
    assert_unbiased_cohort(
        &mut single,
        &grads,
        &live,
        &want,
        wmax / (s4 * lm.sqrt()),
        1500,
        210_000,
        "cohort QSGD-MN-4",
    );

    let mut multi =
        GradientControlPlane::new_multiscale(ControlConfig::new(3), &[2, 6], n, &segs).unwrap();
    // worst case: every coordinate at the small scale s_min = s(2 bits) = 1
    assert_unbiased_cohort(
        &mut multi,
        &grads,
        &live,
        &want,
        wmax / (1.0 * lm.sqrt()),
        2500,
        230_000,
        "cohort QSGD-MN-TS-(2,6)",
    );

    let mut sparse =
        GradientControlPlane::new_randk(ControlConfig::new(3), 8, k, n, &segs).unwrap();
    sparse.set_rescale(true);
    assert_unbiased_cohort(
        &mut sparse,
        &grads,
        &live,
        &want,
        sparse_sd,
        8000,
        250_000,
        "cohort GRandK-MN-8 (rescaled)",
    );

    let mut sparse_ts =
        GradientControlPlane::new_randk_ts(ControlConfig::new(3), &[4, 8], k, n, &segs).unwrap();
    sparse_ts.set_rescale(true);
    assert_unbiased_cohort(
        &mut sparse_ts,
        &grads,
        &live,
        &want,
        sparse_sd,
        8000,
        270_000,
        "cohort GRandK-MN-TS-(4,8) (rescaled)",
    );
}
