//! Straggler smoke bench (PR 6, CI-gated): strict-sync vs timeout-into-
//! partial *simulated* step time under seeded per-worker jitter, 4-bit
//! QSGD-MN through the bucketed plane at 8 workers over 10 Gbps flat
//! Ethernet, §6.6 ResNet50 compute profile.
//!
//! Strict sync waits for the slowest worker every step; the timeout policy
//! cuts stragglers off at `base · (1 + frac)` and renormalizes the partial
//! all-reduce for the live cohort. Hard gates, all deterministic (the step
//! times are analytic — α–β wire model plus the seeded jitter stream):
//!   * jitter 0:      partial == strict bit-for-bit (the deadline never
//!                    fires, both run the identity cohort)
//!   * jitter >= 10%: partial < strict on total simulated time
//!
//! Set `REPRO_BENCH_JSON=<path>` to emit the numbers as JSON (consumed by
//! `tools/bench_compress.py` -> `BENCH_faults.json`).

use repro::collectives::StepCtx;
use repro::compress::Aggregator;
use repro::control::{CohortPolicy, ControlConfig, ElasticCohort, ElasticConfig, GradientControlPlane};
use repro::netsim::{FaultPlan, NetConfig, SimClock};
use repro::perfmodel::{self, ModelProfile};
use repro::util::json::{arr, num, obj, s as js, Json};
use repro::util::rng::Rng;

struct PolicyRun {
    /// Sum over steps of `compute_window + comm - hidden` (analytic).
    total_sim_s: f64,
    total_wait_s: f64,
    min_live: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_policy(
    policy: CohortPolicy,
    jitter: f64,
    grads: &[Vec<f32>],
    n: usize,
    buckets: usize,
    bits: usize,
    gbps: f64,
    steps: usize,
) -> PolicyRun {
    let m = grads.len();
    let segments = {
        let lens: Vec<usize> =
            (0..16).map(|i| (i + 1) * n / 16 - i * n / 16).collect();
        repro::runtime::contiguous_segments(&lens)
    };
    let cfg = ElasticConfig {
        policy,
        quorum: 1,
        faults: FaultPlan::jittered(0xFA57, jitter),
    };
    let mut cohort = ElasticCohort::new(cfg, m).expect("cohort");
    let mut plane = GradientControlPlane::new(ControlConfig::new(buckets), bits, n, &segments)
        .expect("control plane");
    let base = ModelProfile::resnet50().compute_s;
    let net = NetConfig::flat(m, gbps);
    let root = Rng::new(0xBE7C);

    let mut run = PolicyRun { total_sim_s: 0.0, total_wait_s: 0.0, min_live: m };
    for step in 0..steps {
        let plan = cohort.plan_step(step, base);
        run.min_live = run.min_live.min(plan.live.len());
        run.total_wait_s += plan.straggler_wait_s;
        if plan.sync {
            let step_net = cohort.faults().net_for_step(&net, step, plan.live.len());
            let mut clock = SimClock::default();
            {
                let mut ctx = StepCtx::new(&step_net, &mut clock);
                ctx.backward_s = Some(plan.compute_window_s * perfmodel::BACKWARD_FRAC);
                let slices: Vec<&[f32]> =
                    plan.live.iter().map(|&w| grads[w].as_slice()).collect();
                let mut rng = root.derive(&[step as u64]);
                let out = plane.aggregate_cohort(&slices, &plan.live, &mut ctx, &mut rng);
                std::hint::black_box(&out);
            }
            run.total_sim_s += plan.compute_window_s + clock.comm_s - clock.hidden_comm_s;
        } else {
            // quorum failure: a local accumulation step, compute only
            run.total_sim_s += plan.compute_window_s;
        }
        cohort.commit(&plan);
    }
    run
}

fn main() {
    let n: usize = std::env::var("REPRO_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 18);
    let (m, bits, buckets, gbps, steps) = (8usize, 4usize, 8usize, 10.0, 40usize);
    let timeout_frac = 0.1;

    let mut rng = Rng::new(0x57A6);
    let grads: Vec<Vec<f32>> = (0..m)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal_f32(&mut v, 1.0);
            v
        })
        .collect();

    println!(
        "=== strict vs timeout-partial simulated step time (n={n}, M={m}, {bits}-bit, \
         {buckets} buckets, {gbps} Gbps, {steps} steps, timeout {timeout_frac}) ==="
    );
    println!(
        "{:>8} {:>14} {:>14} {:>12} {:>12} {:>9} {:>8}",
        "jitter", "strict (s)", "partial (s)", "s wait (s)", "p wait (s)", "min live", "gate"
    );

    let mut entries: Vec<Json> = Vec::new();
    let mut all_pass = true;
    for jitter in [0.0f64, 0.1, 0.5] {
        let strict = run_policy(
            CohortPolicy::StrictSync, jitter, &grads, n, buckets, bits, gbps, steps,
        );
        let partial = run_policy(
            CohortPolicy::TimeoutPartial { timeout_frac },
            jitter, &grads, n, buckets, bits, gbps, steps,
        );
        let pass = if jitter == 0.0 {
            // the deadline never fires: identical cohorts, identical clocks
            partial.total_sim_s == strict.total_sim_s && partial.min_live == m
        } else {
            partial.total_sim_s < strict.total_sim_s
        };
        all_pass &= pass;
        println!(
            "{:>8.2} {:>14.4} {:>14.4} {:>12.4} {:>12.4} {:>9} {:>8}",
            jitter,
            strict.total_sim_s,
            partial.total_sim_s,
            strict.total_wait_s,
            partial.total_wait_s,
            partial.min_live,
            if pass { "ok" } else { "FAIL" }
        );
        entries.push(obj(vec![
            ("jitter", num(jitter)),
            ("strict_sim_s", num(strict.total_sim_s)),
            ("partial_sim_s", num(partial.total_sim_s)),
            ("strict_wait_s", num(strict.total_wait_s)),
            ("partial_wait_s", num(partial.total_wait_s)),
            ("partial_min_live", num(partial.min_live as f64)),
            ("gate_pass", num(pass as u8 as f64)),
        ]));
    }

    if let Ok(path) = std::env::var("REPRO_BENCH_JSON") {
        let json = obj(vec![
            ("schema", js("repro-micro-faults-v1")),
            ("n", num(n as f64)),
            ("workers", num(m as f64)),
            ("bits", num(bits as f64)),
            ("buckets", num(buckets as f64)),
            ("net_gbps", num(gbps)),
            ("steps", num(steps as f64)),
            ("timeout_frac", num(timeout_frac)),
            ("entries", arr(entries)),
        ]);
        std::fs::write(&path, json.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }

    assert!(
        all_pass,
        "fault gate failed: partial must equal strict at zero jitter and beat it at >= 10%"
    );
    println!("\nfault gate: partial == strict at jitter 0, partial < strict at 10% and 50%");
}
