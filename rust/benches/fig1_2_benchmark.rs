//! Figures 1 & 2: loss/accuracy benchmarking of all methods vs
//! AllReduce-SGD and PowerSGD (rank 1/2), on the computation-intensive and
//! communication-intensive models.
//!
//! Paper claims reproduced: the MaxNorm quantizers track the fp32 baseline;
//! every method outperforms PowerSGD; the two-scale variant edges out the
//! single-scale one late in training.

mod common;

fn main() -> anyhow::Result<()> {
    common::run_figure_bench(
        "fig1_2",
        &[
            "allreduce",
            "qsgd-mn-8",
            "qsgd-mn-ts-8-12",
            "grandk-mn-8",
            "grandk-mn-ts-8-12",
            "powersgd-1",
            "powersgd-2",
        ],
    )
}
