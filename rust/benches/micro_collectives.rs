//! Micro-bench: the collective data plane (ring vs tree vs naive) and the
//! simulated-time model across worker counts — the O(log M) vs O(M) story.

mod common;

use repro::collectives::{naive_allreduce_sum, ring_allreduce_sum, tree_allreduce_sum};
use repro::netsim::NetConfig;
use repro::util::rng::Rng;

fn main() {
    let n: usize = std::env::var("REPRO_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2_000_000);

    println!("=== in-memory allreduce data plane, n={n} f32 ===");
    println!("{:>8} {:>12} {:>12} {:>12}", "workers", "ring ms", "tree ms", "naive ms");
    for m in [2usize, 4, 8, 16] {
        let mut rng = Rng::new(m as u64);
        let make = |rng: &mut Rng| -> Vec<Vec<f32>> {
            (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    rng.fill_normal_f32(&mut v, 1.0);
                    v
                })
                .collect()
        };
        let base = make(&mut rng);
        let t_ring = common::time_median(3, || {
            let mut b = base.clone();
            ring_allreduce_sum(&mut b);
            std::hint::black_box(&b);
        });
        let t_tree = common::time_median(3, || {
            let mut b = base.clone();
            tree_allreduce_sum(&mut b);
            std::hint::black_box(&b);
        });
        let t_naive = common::time_median(3, || {
            let mut b = base.clone();
            naive_allreduce_sum(&mut b);
            std::hint::black_box(&b);
        });
        println!(
            "{:>8} {:>12.1} {:>12.1} {:>12.1}",
            m,
            t_ring * 1e3,
            t_tree * 1e3,
            t_naive * 1e3
        );
    }

    println!("\n=== simulated wire time (VGG16 8-bit payload, 10 Gbps flat) ===");
    println!("{:>8} {:>16} {:>16} {:>10}", "workers", "allreduce (s)", "allgather (s)", "ratio");
    let bytes = 14_728_266.0;
    for m in [4usize, 8, 16, 32, 64, 128, 256] {
        let net = NetConfig::flat(m, 10.0);
        let ar = net.allreduce_s(bytes);
        let ag = net.allgather_s(bytes);
        println!("{:>8} {:>16.4} {:>16.4} {:>10.1}", m, ar, ag, ag / ar);
    }
}
