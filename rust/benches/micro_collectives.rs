//! Micro-bench: the collective data plane (ring vs tree vs naive) across
//! element widths — f32 gradients vs the widened i16/i32 level buffers of
//! the integer-domain hot path — and the simulated-time model across worker
//! counts (the O(log M) vs O(M) story). GB/s is over the per-rank payload.
//!
//! Set `REPRO_BENCH_JSON=<path>` to also emit the numbers as JSON
//! (consumed by `tools/bench_compress.py` -> `BENCH_compress.json`).

mod common;

use repro::collectives::{
    allreduce_sum_packed_sched, naive_allreduce_sum_t, ring_allreduce_sum_packed,
    ring_allreduce_sum_t, ring_allreduce_sum_t_counted, tree_allreduce_sum_t, PlaneTraffic,
    RingFixed, RingGrowing, RingTraffic,
};
use repro::compress::bitpack::{pack_biased_int, packed_sum_bits, Packed};
use repro::compress::kernels::s_for_bits;
use repro::netsim::NetConfig;
use repro::util::json::{arr, num, obj, s as js, Json};
use repro::util::rng::Rng;

fn bench_width<T: repro::tensor::LevelInt>(
    n: usize,
    m: usize,
    rng: &mut Rng,
    entries: &mut Vec<Json>,
) -> (f64, f64, f64) {
    // quantizer-level-ranged random ints (|x| <= 127) so i16 sums stay safe
    let base: Vec<Vec<T>> = (0..m)
        .map(|_| {
            (0..n)
                .map(|_| T::from_level(rng.next_below(255) as f32 - 127.0))
                .collect()
        })
        .collect();
    let bytes = (n * std::mem::size_of::<T>()) as f64 / 1e9;
    let t_ring = common::time_median(3, || {
        let mut b = base.clone();
        ring_allreduce_sum_t(&mut b);
        std::hint::black_box(&b);
    });
    let t_tree = common::time_median(3, || {
        let mut b = base.clone();
        tree_allreduce_sum_t(&mut b);
        std::hint::black_box(&b);
    });
    let t_naive = common::time_median(3, || {
        let mut b = base.clone();
        naive_allreduce_sum_t(&mut b);
        std::hint::black_box(&b);
    });
    for (algo, t) in [("ring", t_ring), ("tree", t_tree), ("naive", t_naive)] {
        entries.push(obj(vec![
            ("width", js(T::TAG)),
            ("workers", num(m as f64)),
            ("algo", js(algo)),
            ("ms", num(t * 1e3)),
            ("gbps", num(bytes / t)),
        ]));
    }
    (t_ring, t_tree, t_naive)
}

fn main() {
    let n: usize = std::env::var("REPRO_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2_000_000);

    let mut entries: Vec<Json> = Vec::new();

    println!("=== in-memory allreduce data plane, n={n} f32 ===");
    println!("{:>8} {:>12} {:>12} {:>12}", "workers", "ring ms", "tree ms", "naive ms");
    for m in [2usize, 4, 8, 16] {
        let mut rng = Rng::new(m as u64);
        let base: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect();
        let bytes = (n * 4) as f64 / 1e9;
        let t_ring = common::time_median(3, || {
            let mut b = base.clone();
            ring_allreduce_sum_t(&mut b);
            std::hint::black_box(&b);
        });
        let t_tree = common::time_median(3, || {
            let mut b = base.clone();
            tree_allreduce_sum_t(&mut b);
            std::hint::black_box(&b);
        });
        let t_naive = common::time_median(3, || {
            let mut b = base.clone();
            naive_allreduce_sum_t(&mut b);
            std::hint::black_box(&b);
        });
        println!(
            "{:>8} {:>12.1} {:>12.1} {:>12.1}",
            m,
            t_ring * 1e3,
            t_tree * 1e3,
            t_naive * 1e3
        );
        for (algo, t) in [("ring", t_ring), ("tree", t_tree), ("naive", t_naive)] {
            entries.push(obj(vec![
                ("width", js("f32")),
                ("workers", num(m as f64)),
                ("algo", js(algo)),
                ("ms", num(t * 1e3)),
                ("gbps", num(bytes / t)),
            ]));
        }
    }

    println!("\n=== integer-domain allreduce: f32 vs i16 vs i32 level buffers, ring ===");
    println!("{:>8} {:>12} {:>12} {:>12}", "workers", "f32 ms", "i16 ms", "i32 ms");
    for m in [2usize, 4, 8, 16] {
        let mut rng = Rng::new(100 + m as u64);
        let base32f: Vec<Vec<f32>> = (0..m)
            .map(|_| (0..n).map(|_| rng.next_below(255) as f32 - 127.0).collect())
            .collect();
        let t_f32 = common::time_median(3, || {
            let mut b = base32f.clone();
            ring_allreduce_sum_t(&mut b);
            std::hint::black_box(&b);
        });
        let (t_i16, _, _) = bench_width::<i16>(n, m, &mut rng, &mut entries);
        let (t_i32, _, _) = bench_width::<i32>(n, m, &mut rng, &mut entries);
        println!(
            "{:>8} {:>12.1} {:>12.1} {:>12.1}",
            m,
            t_f32 * 1e3,
            t_i16 * 1e3,
            t_i32 * 1e3
        );
    }

    // ---- packed-resident vs i16-resident ring (the PR 2 tentpole) ------
    // The acceptance gate: with the resident reduce operand being Packed
    // biased codes, the data plane's bytes-moved must be at most
    // (bits/16 + eps) of the i16 plane's, eps = 0.20 covering the resident
    // width's log2(workers) headroom for partial sums.
    let np = (n / 4).max(64);
    println!("\n=== packed-resident vs i16-resident ring, n={np} ===");
    println!(
        "{:>5} {:>8} {:>6} {:>10} {:>10} {:>12} {:>12} {:>7}",
        "bits", "workers", "rbits", "i16 ms", "packed ms", "i16 MB", "packed MB", "ratio"
    );
    for bits in [2usize, 4, 8] {
        let s = s_for_bits(bits);
        for m in [4usize, 16, 64] {
            let rbits = packed_sum_bits(s, m);
            let mut rng = Rng::new((1000 * bits + m) as u64);
            let levels: Vec<Vec<i32>> = (0..m)
                .map(|_| {
                    (0..np)
                        .map(|_| rng.next_below(2 * s as u64 + 1) as i32 - s as i32)
                        .collect()
                })
                .collect();

            // i16-resident plane (the PR 1 data plane) + its bytes counter
            let base16: Vec<Vec<i16>> = levels
                .iter()
                .map(|l| l.iter().map(|&x| x as i16).collect())
                .collect();
            let mut i16_bytes = 0.0f64;
            {
                let mut b = base16.clone();
                ring_allreduce_sum_t_counted(&mut b, &mut i16_bytes);
            }
            let t_i16 = common::time_median(3, || {
                let mut b = base16.clone();
                ring_allreduce_sum_t(&mut b);
                std::hint::black_box(&b);
            });

            // packed-resident plane: biased codes at the carry-safe width
            let base_packed: Vec<Packed> = levels
                .iter()
                .map(|l| pack_biased_int(l, s as i64, rbits))
                .collect();
            let mut traffic = RingTraffic::default();
            {
                let mut b = base_packed.clone();
                ring_allreduce_sum_packed(&mut b, &mut traffic);
            }
            let t_packed = common::time_median(3, || {
                let mut b = base_packed.clone();
                let mut t = RingTraffic::default();
                ring_allreduce_sum_packed(&mut b, &mut t);
                std::hint::black_box(&b);
            });

            let ratio = traffic.bytes_moved / i16_bytes;
            let gate = bits as f64 / 16.0 + 0.20;
            println!(
                "{:>5} {:>8} {:>6} {:>10.1} {:>10.1} {:>12.1} {:>12.1} {:>7.3}",
                bits,
                m,
                rbits,
                t_i16 * 1e3,
                t_packed * 1e3,
                i16_bytes / 1e6,
                traffic.bytes_moved / 1e6,
                ratio
            );
            assert!(
                ratio <= gate,
                "packed-resident traffic ratio {ratio:.3} exceeds bits/16 + 0.20 = {gate:.3} \
                 (bits={bits}, m={m}, rbits={rbits})"
            );
            for (width, t, bytes) in [
                ("i16", t_i16, i16_bytes),
                ("packed", t_packed, traffic.bytes_moved),
            ] {
                entries.push(obj(vec![
                    ("width", js(width)),
                    ("payload_bits", num(bits as f64)),
                    ("resident_bits", num(if width == "packed" { rbits as f64 } else { 16.0 })),
                    ("workers", num(m as f64)),
                    ("algo", js("ring")),
                    ("ms", num(t * 1e3)),
                    ("bytes_moved", num(bytes)),
                    ("traffic_ratio_vs_i16", num(ratio)),
                ]));
            }
        }
    }

    // ---- growing-width vs fixed-width packed ring (the PR 3 tentpole) --
    // The acceptance gate: the width-growing pack-per-hop ring may NEVER
    // ship more wire bits than the fixed-width ring (each reduce-scatter
    // hop rides bitlen(2k*lmax) <= bitlen(2M*lmax)). The bench also records
    // where the analytic time selector flips (see DESIGN.md §Performance:
    // growing wins on slow wires, fixed when the link outruns the
    // re-packer).
    let ng = 16_384usize.min(n);
    println!("\n=== growing-width vs fixed-width packed ring, n={ng} ===");
    println!(
        "{:>5} {:>8} {:>6} {:>10} {:>10} {:>12} {:>12} {:>7} {:>10}",
        "bits", "workers", "rbits", "fixed ms", "grow ms", "fixed Mb", "grow Mb", "ratio", "sel@10G"
    );
    for bits in [2usize, 4] {
        let s = s_for_bits(bits);
        for m in [64usize, 256, 1024] {
            let rbits = packed_sum_bits(s, m);
            let mut rng = Rng::new((7000 * bits + m) as u64);
            let levels: Vec<Vec<i32>> = (0..m)
                .map(|_| {
                    (0..ng)
                        .map(|_| rng.next_below(2 * s as u64 + 1) as i32 - s as i32)
                        .collect()
                })
                .collect();
            let base: Vec<Packed> = levels
                .iter()
                .map(|l| pack_biased_int(l, s as i64, rbits))
                .collect();

            let mut t_fixed_traffic = PlaneTraffic::default();
            {
                let mut b = base.clone();
                allreduce_sum_packed_sched(&RingFixed, &mut b, &mut t_fixed_traffic);
            }
            let t_fixed = common::time_median(3, || {
                let mut b = base.clone();
                let mut t = PlaneTraffic::default();
                allreduce_sum_packed_sched(&RingFixed, &mut b, &mut t);
                std::hint::black_box(&b);
            });

            let grow = RingGrowing { lmax: s };
            let mut t_grow_traffic = PlaneTraffic::default();
            {
                let mut b = base.clone();
                allreduce_sum_packed_sched(&grow, &mut b, &mut t_grow_traffic);
            }
            let t_grow = common::time_median(3, || {
                let mut b = base.clone();
                let mut t = PlaneTraffic::default();
                allreduce_sum_packed_sched(&grow, &mut b, &mut t);
                std::hint::black_box(&b);
            });

            let ratio = t_grow_traffic.wire_bits / t_fixed_traffic.wire_bits;
            let sel = NetConfig::flat(m, 10.0).growing_ring_wins(s, m, ng);
            println!(
                "{:>5} {:>8} {:>6} {:>10.1} {:>10.1} {:>12.2} {:>12.2} {:>7.3} {:>10}",
                bits,
                m,
                rbits,
                t_fixed * 1e3,
                t_grow * 1e3,
                t_fixed_traffic.wire_bits / 1e6,
                t_grow_traffic.wire_bits / 1e6,
                ratio,
                if sel { "growing" } else { "fixed" }
            );
            assert!(
                t_grow_traffic.wire_bits <= t_fixed_traffic.wire_bits,
                "growing ring shipped MORE wire bits than fixed \
                 ({} vs {}, bits={bits}, m={m})",
                t_grow_traffic.wire_bits,
                t_fixed_traffic.wire_bits
            );
            for (sched, t, traffic) in [
                ("ring-fixed", t_fixed, t_fixed_traffic),
                ("ring-growing", t_grow, t_grow_traffic),
            ] {
                entries.push(obj(vec![
                    ("width", js("packed")),
                    ("schedule", js(sched)),
                    ("payload_bits", num(bits as f64)),
                    ("resident_bits", num(rbits as f64)),
                    ("workers", num(m as f64)),
                    ("ms", num(t * 1e3)),
                    ("wire_bits", num(traffic.wire_bits)),
                    (
                        "wire_ratio_vs_fixed",
                        num(traffic.wire_bits / t_fixed_traffic.wire_bits),
                    ),
                ]));
            }
        }
    }

    println!("\n=== simulated wire time (VGG16 8-bit payload, 10 Gbps flat) ===");
    println!("{:>8} {:>16} {:>16} {:>10}", "workers", "allreduce (s)", "allgather (s)", "ratio");
    let bytes = 14_728_266.0;
    for m in [4usize, 8, 16, 32, 64, 128, 256] {
        let net = NetConfig::flat(m, 10.0);
        let ar = net.allreduce_s(bytes);
        let ag = net.allgather_s(bytes);
        println!("{:>8} {:>16.4} {:>16.4} {:>10.1}", m, ar, ag, ag / ar);
    }

    if let Ok(path) = std::env::var("REPRO_BENCH_JSON") {
        let json = obj(vec![
            ("schema", js("repro-micro-collectives-v1")),
            ("n", num(n as f64)),
            ("entries", arr(entries)),
        ]);
        std::fs::write(&path, json.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }
}
