//! Figures 9 & 10: GlobalRandKMaxNormMultiScale two-scale sweep. Paper
//! claims mirror Figs 5/6: precision-resilient, strong early, lags late.

mod common;

fn main() -> anyhow::Result<()> {
    common::run_figure_bench(
        "fig9_10",
        &[
            "allreduce",
            "grandk-mn-ts-8-12",
            "grandk-mn-ts-6-10",
            "grandk-mn-ts-4-8",
            "grandk-mn-ts-2-6",
        ],
    )
}
