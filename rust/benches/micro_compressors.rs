//! Micro-bench: encoder/decoder throughput of every compressor on a
//! gradient-sized vector, plus the old-vs-new comparisons for this repo's
//! integer-domain rewrite: scalar-reference vs word-level bitpack, f32-level
//! vs fused integer QSGD-MN-4 aggregation. Reports GB/s over the input
//! gradient bytes.
//!
//! Set `REPRO_BENCH_JSON=<path>` to also emit the numbers as JSON
//! (consumed by `tools/bench_compress.py` -> `BENCH_compress.json`).

mod common;

use repro::collectives::StepCtx;
use repro::compress::{bitpack, fused, kernels, Method};
use repro::netsim::{NetConfig, SimClock};
use repro::util::json::{arr, num, obj, s as js, Json};
use repro::util::rng::Rng;
use repro::util::simd::{self, Backend};

struct Report {
    entries: Vec<(String, f64, f64)>, // (name, ms, GB/s)
}

impl Report {
    fn push(&mut self, name: &str, t_s: f64, gbytes: f64) {
        println!("{:>34} {:>9.2} ms {:>8.2} GB/s", name, t_s * 1e3, gbytes / t_s);
        self.entries.push((name.to_string(), t_s * 1e3, gbytes / t_s));
    }

    fn gbps(&self, name: &str) -> f64 {
        self.entries.iter().find(|(n, _, _)| n == name).map(|(_, _, g)| *g).unwrap_or(0.0)
    }

    fn to_json(&self) -> Json {
        arr(self
            .entries
            .iter()
            .map(|(n, ms, g)| {
                obj(vec![("name", js(n)), ("ms", num(*ms)), ("gbps", num(*g))])
            })
            .collect())
    }
}

fn main() {
    let n: usize = std::env::var("REPRO_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4_000_000);
    let m = 4;
    let mut rng = Rng::new(1);
    let grads: Vec<Vec<f32>> = (0..m)
        .map(|_| {
            let mut g = vec![0.0f32; n];
            rng.fill_normal_f32(&mut g, 1.0);
            g
        })
        .collect();
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let gbytes = (m * n * 4) as f64 / 1e9;
    let vb = (n * 4) as f64 / 1e9;
    let mut report = Report { entries: Vec::new() };

    println!("=== aggregate() wall time, n={n} coords x M={m} workers ({gbytes:.2} GB of gradients) ===");
    println!("{:>22} {:>10} {:>10} {:>12}", "method", "ms", "GB/s", "wire bits/c");
    let mut agg_entries: Vec<Json> = Vec::new();
    for spec in [
        "allreduce",
        "qsgd-mn-2",
        "qsgd-mn-4",
        "qsgd-mn-8",
        "qsgd-mn-ts-2-6",
        "qsgd-mn-ts-8-12",
        "grandk-mn-8",
        "grandk-mn-ts-8-12",
        "terngrad",
        "signsgd",
        "topk",
        "powersgd-2",
    ] {
        let method = Method::parse(spec).unwrap();
        let mut agg = method.build(n, &[]).unwrap();
        let net = NetConfig::flat(m, 10.0);
        let t = common::time_median(3, || {
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            let mut r = Rng::new(7);
            let out = agg.aggregate(&refs, &mut ctx, &mut r);
            std::hint::black_box(&out);
        });
        println!(
            "{:>22} {:>10.1} {:>10.2} {:>12.2}",
            agg.name(),
            t * 1e3,
            gbytes / t,
            agg.nominal_bits()
        );
        agg_entries.push(obj(vec![
            ("name", js(&agg.name())),
            ("ms", num(t * 1e3)),
            ("gbps", num(gbytes / t)),
            ("wire_bits_per_coord", num(agg.nominal_bits())),
        ]));
    }

    // raw kernel rates (single worker, the innermost loops)
    println!("\n=== raw kernel rates, n={n} (GB/s over {vb:.2} GB input) ===");
    let v = &grads[0];
    let mut u = vec![0.0f32; n];
    Rng::new(3).fill_uniform_f32(&mut u);
    let w = kernels::l2_norm(v);
    let mut z = vec![0.0f32; n];
    let mut z16 = vec![0i16; n];

    let t = common::time_median(5, || kernels::qsgd_encode(v, w, &u, 127, &mut z));
    report.push("qsgd_encode(f32 levels)", t, vb);

    let t = common::time_median(5, || kernels::qsgd_encode_int::<i16>(v, w, &u, 127, &mut z16));
    report.push("qsgd_encode_int(i16 levels)", t, vb);

    let t = common::time_median(5, || {
        let mut d = z.clone();
        kernels::qsgd_decode_sum(&mut d, w, 127, m);
        std::hint::black_box(&d);
    });
    report.push("qsgd_decode(+clone)", t, vb);

    let t = common::time_median(5, || {
        std::hint::black_box(kernels::l2_norm(v));
    });
    report.push("l2_norm", t, vb);

    let mut idx = vec![0u8; n];
    let scales = [7usize, 127];
    let t = common::time_median(5, || kernels::multiscale_scale_index(v, w, &scales, &mut idx));
    report.push("multiscale_scale_index", t, vb);

    let t = common::time_median(5, || {
        kernels::multiscale_encode(v, w, &u, &idx, &scales, &mut z)
    });
    report.push("multiscale_encode", t, vb);

    // bit-packing old vs new (the substrate the paper said was too slow in
    // Python; the word-level rewrite is this PR's >=4x target)
    println!("\n=== bitpack: scalar reference vs word-level, n={n} ===");
    for bits in [4u32, 8] {
        let s_q = kernels::s_for_bits(bits as usize);
        kernels::qsgd_encode(v, w, &u, s_q, &mut z);

        let t = common::time_median(5, || {
            std::hint::black_box(bitpack::pack_scalar_reference(&z, bits));
        });
        report.push(&format!("pack_ref({bits}b)"), t, vb);

        let t = common::time_median(5, || {
            std::hint::black_box(bitpack::pack(&z, bits));
        });
        report.push(&format!("pack({bits}b)"), t, vb);

        kernels::qsgd_encode_int::<i16>(v, w, &u, s_q, &mut z16);
        let mut words = Vec::new();
        let t = common::time_median(5, || {
            bitpack::pack_int_into(&z16, bits, &mut words);
            std::hint::black_box(&words);
        });
        report.push(&format!("pack_int({bits}b,i16)"), t, vb);

        let packed = bitpack::pack(&z, bits);
        let t = common::time_median(5, || {
            std::hint::black_box(bitpack::unpack_scalar_reference(&packed));
        });
        report.push(&format!("unpack_ref({bits}b)"), t, vb);

        let t = common::time_median(5, || {
            std::hint::black_box(bitpack::unpack(&packed));
        });
        report.push(&format!("unpack({bits}b)"), t, vb);

        let t = common::time_median(5, || {
            bitpack::unpack_int_into(&packed, &mut z16);
            std::hint::black_box(&z16);
        });
        report.push(&format!("unpack_int({bits}b,i16)"), t, vb);
    }

    // fused QSGD-MN-4 step: legacy f32-level pipeline vs integer domain
    println!("\n=== fused QSGD-MN-4 encode->allreduce->decode, old vs new ===");
    let wnorm = refs.iter().map(|g| kernels::l2_norm(g)).fold(0.0f32, f32::max);
    let s4 = kernels::s_for_bits(4);
    let step_rng = Rng::new(11);

    let t_old = common::time_median(3, || {
        let out = fused::reference_qsgd_aggregate(&refs, wnorm, s4, &step_rng);
        std::hint::black_box(&out);
    });
    report.push("fused_qsgd4_f32_reference", t_old, gbytes);

    let t_new = common::time_median(3, || {
        let (out, _) = fused::wire_roundtrip_qsgd::<i16>(&refs, wnorm, 4, &step_rng);
        std::hint::black_box(&out);
    });
    report.push("fused_qsgd4_int_wire", t_new, gbytes);

    // SIMD dispatch vs pinned scalar fallback (the PR 10 tentpole): the same
    // backend-explicit entries the differential tests pin bit-identical,
    // timed per available backend. The packed-add runs at 32-bit fields so
    // the repeated timing iterations stay carry-safe (fields accumulate
    // across reps; 32-bit headroom covers millions of iterations).
    println!("\n=== SIMD dispatch vs scalar fallback ===");
    let backends = simd::available();
    let vector_bk = backends.iter().copied().find(|&b| b != Backend::Scalar);
    println!(
        "active backend: {} (available: {})",
        simd::active().label(),
        backends.iter().map(|b| b.label()).collect::<Vec<_>>().join(",")
    );
    let rbits = bitpack::packed_sum_bits(s4, m);
    let bias = s4 as i64;
    let mut lv = vec![0i32; n];
    for &bk in &backends {
        let lbl = bk.label();
        let t = common::time_median(5, || {
            kernels::qsgd_encode_int_backend::<i32>(bk, v, w, &u, s4, &mut lv);
            std::hint::black_box(&lv);
        });
        report.push(&format!("qsgd_encode_int[{lbl}]"), t, vb);

        let mut words = vec![0u64; bitpack::words_for(n, rbits)];
        let t = common::time_median(5, || {
            bitpack::pack_biased_i32_at_backend(bk, &lv, bias, rbits, &mut words, 0);
            std::hint::black_box(&words);
        });
        report.push(&format!("pack_biased[{lbl}]"), t, vb);

        let mut codes = vec![0u64; n];
        let t = common::time_median(5, || {
            bitpack::unpack_codes_at_backend(bk, &words, rbits, 0, &mut codes);
            std::hint::black_box(&codes);
        });
        report.push(&format!("unpack_fields[{lbl}]"), t, vb);

        let mut wide = vec![0u64; bitpack::words_for(n, 32)];
        bitpack::pack_biased_i32_at_backend(bk, &lv, bias, 32, &mut wide, 0);
        let src = wide.clone();
        let mut dst = wide;
        let t = common::time_median(5, || {
            bitpack::add_packed_codes_backend(bk, &mut dst, &src, 32, 1, n - 1);
            std::hint::black_box(&dst);
        });
        report.push(&format!("packed_add[{lbl}]"), t, vb);
    }
    let mut simd_speedups: Vec<(String, f64)> = Vec::new();
    if let Some(vbk) = vector_bk {
        let vl = vbk.label();
        for key in ["qsgd_encode_int", "pack_biased", "unpack_fields", "packed_add"] {
            let x = report.gbps(&format!("{key}[{vl}]")) / report.gbps(&format!("{key}[scalar]"));
            simd_speedups.push((format!("simd_{key}"), x));
        }
        // tentpole gate: the vectorized level kernel must clear 2x over the
        // pinned scalar loop (the bit-plane kernels are gated by
        // tools/bench_compress.py, which knows which ones this backend
        // implements). REPRO_BENCH_NO_SIMD_GATE=1 skips on odd hardware.
        let enc = simd_speedups[0].1;
        if std::env::var("REPRO_BENCH_NO_SIMD_GATE").is_err() {
            assert!(
                enc >= 2.0,
                "SIMD gate: qsgd_encode_int[{vl}] only {enc:.2}x over scalar (need >= 2x)"
            );
        }
    }

    let speedups = vec![
        ("pack_4b", report.gbps("pack(4b)") / report.gbps("pack_ref(4b)")),
        ("unpack_4b", report.gbps("unpack(4b)") / report.gbps("unpack_ref(4b)")),
        ("pack_int_4b", report.gbps("pack_int(4b,i16)") / report.gbps("pack_ref(4b)")),
        (
            "unpack_int_4b",
            report.gbps("unpack_int(4b,i16)") / report.gbps("unpack_ref(4b)"),
        ),
        ("pack_8b", report.gbps("pack(8b)") / report.gbps("pack_ref(8b)")),
        ("unpack_8b", report.gbps("unpack(8b)") / report.gbps("unpack_ref(8b)")),
        ("fused_qsgd_mn_4", t_old / t_new),
    ];
    println!("\n=== speedups (new / old) ===");
    for (name, x) in &speedups {
        println!("{name:>20}: {x:.2}x");
    }
    for (name, x) in &simd_speedups {
        println!("{name:>24}: {x:.2}x (vector / scalar)");
    }

    if let Ok(path) = std::env::var("REPRO_BENCH_JSON") {
        let json = obj(vec![
            ("schema", js("repro-micro-compressors-v1")),
            ("n", num(n as f64)),
            ("workers", num(m as f64)),
            ("aggregate", arr(agg_entries)),
            ("kernels", report.to_json()),
            (
                "speedups",
                obj(speedups
                    .iter()
                    .map(|(k, v)| (*k, num(*v)))
                    .chain(simd_speedups.iter().map(|(k, v)| (k.as_str(), num(*v))))
                    .collect()),
            ),
            (
                "simd",
                obj(vec![
                    ("active", js(simd::active().label())),
                    (
                        "available",
                        arr(backends.iter().map(|b| js(b.label())).collect()),
                    ),
                    (
                        "vector_available",
                        num(if vector_bk.is_some() { 1.0 } else { 0.0 }),
                    ),
                ]),
            ),
        ]);
        std::fs::write(&path, json.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }
}
