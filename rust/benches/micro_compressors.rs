//! Micro-bench: encoder/decoder throughput of every compressor on a
//! gradient-sized vector — the L3 hot-path numbers behind EXPERIMENTS.md
//! §Perf. Reports GB/s over the input gradient bytes.

mod common;

use repro::collectives::StepCtx;
use repro::compress::{bitpack, kernels, Method};
use repro::netsim::{NetConfig, SimClock};
use repro::util::rng::Rng;

fn main() {
    let n: usize = std::env::var("REPRO_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4_000_000);
    let m = 4;
    let mut rng = Rng::new(1);
    let grads: Vec<Vec<f32>> = (0..m)
        .map(|_| {
            let mut g = vec![0.0f32; n];
            rng.fill_normal_f32(&mut g, 1.0);
            g
        })
        .collect();
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let gbytes = (m * n * 4) as f64 / 1e9;

    println!("=== aggregate() wall time, n={n} coords x M={m} workers ({gbytes:.2} GB of gradients) ===");
    println!("{:>22} {:>10} {:>10} {:>12}", "method", "ms", "GB/s", "wire bits/c");
    for spec in [
        "allreduce",
        "qsgd-mn-2",
        "qsgd-mn-4",
        "qsgd-mn-8",
        "qsgd-mn-ts-2-6",
        "qsgd-mn-ts-8-12",
        "grandk-mn-8",
        "grandk-mn-ts-8-12",
        "terngrad",
        "signsgd",
        "topk",
        "powersgd-2",
    ] {
        let method = Method::parse(spec).unwrap();
        let mut agg = method.build(n, &[]).unwrap();
        let net = NetConfig::flat(m, 10.0);
        let t = common::time_median(3, || {
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            let mut r = Rng::new(7);
            let out = agg.aggregate(&refs, &mut ctx, &mut r);
            std::hint::black_box(&out);
        });
        println!(
            "{:>22} {:>10.1} {:>10.2} {:>12.2}",
            agg.name(),
            t * 1e3,
            gbytes / t,
            agg.nominal_bits()
        );
    }

    // raw kernel rates (single worker, the innermost loops)
    println!("\n=== raw kernel rates, n={n} ===");
    let v = &grads[0];
    let mut u = vec![0.0f32; n];
    Rng::new(3).fill_uniform_f32(&mut u);
    let w = kernels::l2_norm(v);
    let mut z = vec![0.0f32; n];
    let vb = (n * 4) as f64 / 1e9;

    let t = common::time_median(5, || kernels::qsgd_encode(v, w, &u, 127, &mut z));
    println!("qsgd_encode            {:>8.1} ms  {:>6.2} GB/s", t * 1e3, vb / t);

    let t = common::time_median(5, || {
        let mut d = z.clone();
        kernels::qsgd_decode_sum(&mut d, w, 127, m);
        std::hint::black_box(&d);
    });
    println!("qsgd_decode(+clone)    {:>8.1} ms  {:>6.2} GB/s", t * 1e3, vb / t);

    let t = common::time_median(5, || {
        std::hint::black_box(kernels::l2_norm(v));
    });
    println!("l2_norm                {:>8.1} ms  {:>6.2} GB/s", t * 1e3, vb / t);

    let mut idx = vec![0u8; n];
    let scales = [7usize, 127];
    let t = common::time_median(5, || {
        kernels::multiscale_scale_index(v, w, &scales, &mut idx)
    });
    println!("multiscale_scale_index {:>8.1} ms  {:>6.2} GB/s", t * 1e3, vb / t);

    let t = common::time_median(5, || {
        kernels::multiscale_encode(v, w, &u, &idx, &scales, &mut z)
    });
    println!("multiscale_encode      {:>8.1} ms  {:>6.2} GB/s", t * 1e3, vb / t);

    // bit-packing (the substrate the paper said was too slow in Python)
    kernels::qsgd_encode(v, w, &u, 127, &mut z);
    let t = common::time_median(5, || {
        std::hint::black_box(bitpack::pack(&z, 8));
    });
    println!("bitpack::pack(8b)      {:>8.1} ms  {:>6.2} GB/s", t * 1e3, vb / t);
    let packed = bitpack::pack(&z, 8);
    let t = common::time_median(5, || {
        std::hint::black_box(bitpack::unpack(&packed));
    });
    println!("bitpack::unpack(8b)    {:>8.1} ms  {:>6.2} GB/s", t * 1e3, vb / t);
}
