//! Shared helpers for the custom-harness benches (no criterion in the
//! vendored set — timing is manual: warmup + median-of-k).
//!
//! Environment knobs for CI budgets:
//!   REPRO_BENCH_STEPS   training steps per figure bench (default 20)
//!   REPRO_BENCH_MODELS  comma list of models (default "resnet_lite")
//!   REPRO_BENCH_WORKERS simulated workers (default 4)

#![allow(dead_code)]

use repro::compress::Method;
use repro::runtime::Artifacts;
use repro::train::{summary_table, write_summaries, Experiment};

pub fn bench_steps() -> usize {
    std::env::var("REPRO_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(20)
}

pub fn bench_models() -> Vec<String> {
    std::env::var("REPRO_BENCH_MODELS")
        .unwrap_or_else(|_| "resnet_lite".into())
        .split(',')
        .map(|s| s.trim().to_string())
        .collect()
}

pub fn bench_workers() -> usize {
    std::env::var("REPRO_BENCH_WORKERS").ok().and_then(|v| v.parse().ok()).unwrap_or(4)
}

/// Run one figure's method sweep and print the paper-style table.
pub fn run_figure_bench(fig: &str, method_specs: &[&str]) -> anyhow::Result<()> {
    let arts = Artifacts::load_default()?;
    let methods: Vec<Method> =
        method_specs.iter().map(|s| Method::parse(s).unwrap()).collect();
    for model in bench_models() {
        let mut exp = Experiment::new(&format!("{fig}_{model}"), &model, methods.clone());
        exp.steps = bench_steps();
        exp.workers = bench_workers();
        exp.out_dir = "results".into();
        exp.quiet = true;
        let t0 = std::time::Instant::now();
        let results = exp.run(&arts)?;
        let summaries: Vec<_> = results.into_iter().map(|(_, s)| s).collect();
        println!(
            "\n=== {fig} / {model} (M={}, {} steps, {:.1}s wall) ===",
            exp.workers,
            exp.steps,
            t0.elapsed().as_secs_f64()
        );
        println!("{}", summary_table(&summaries));
        write_summaries(std::path::Path::new("results"), &format!("{fig}_{model}"), &summaries)?;
    }
    Ok(())
}

/// Median wall time of `k` runs of `f` after one warmup (seconds).
pub fn time_median<F: FnMut()>(k: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..k)
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[k / 2]
}
