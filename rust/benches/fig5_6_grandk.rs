//! Figures 5 & 6: GlobalRandKMaxNorm precision sweep {8, 4, 2}. Paper
//! claims: performance is resilient to the precision (a tiny random subset
//! is communicated), initially competitive, worse than dense methods late.

mod common;

fn main() -> anyhow::Result<()> {
    common::run_figure_bench(
        "fig5_6",
        &["allreduce", "grandk-mn-8", "grandk-mn-4", "grandk-mn-2"],
    )
}
