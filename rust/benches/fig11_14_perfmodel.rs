//! Figures 11-14: the §6.6 analytical throughput projections, with and
//! without the 8-bit wire floor (the paper's framework constraint).

fn main() {
    println!("{}", repro::figures::fig11_14(None));
    println!("\n############ with the paper's 8-bit tensor floor ############");
    println!("{}", repro::figures::fig11_14(Some(8.0)));
    println!("{}", repro::figures::scalability_table());
}
