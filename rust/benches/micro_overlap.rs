//! Overlap smoke bench (PR 4, CI-gated): bucketed-vs-monolithic *simulated*
//! step time at 4/16/64 workers, 4-bit QSGD-MN over 10 Gbps flat Ethernet,
//! with the backward window of the §6.6 ResNet50 profile.
//!
//! The monolithic path starts its single collective after the full backward
//! and exposes every comm second; the bucketed control plane releases
//! buckets in backward order and hides all but the final bucket's tail.
//! Hard gate: `bucketed-with-overlap step time <= monolithic step time` at
//! every worker count (the times are analytic — the α–β model — so the
//! gate is deterministic, not noise-sensitive).
//!
//! Set `REPRO_BENCH_JSON=<path>` to emit the numbers as JSON (consumed by
//! `tools/bench_compress.py` -> `BENCH_overlap.json`).

use repro::collectives::StepCtx;
use repro::compress::qsgd_maxnorm::QsgdMaxNorm;
use repro::compress::Aggregator;
use repro::control::{ControlConfig, GradientControlPlane};
use repro::netsim::{NetConfig, SimClock};
use repro::perfmodel::{self, ModelProfile};
use repro::runtime::Segment;
use repro::util::json::{arr, num, obj, s as js, Json};
use repro::util::rng::Rng;

fn make_segments(n: usize, count: usize) -> Vec<Segment> {
    let lens: Vec<usize> = (0..count).map(|i| (i + 1) * n / count - i * n / count).collect();
    repro::runtime::contiguous_segments(&lens)
}

fn run_once(
    agg: &mut dyn Aggregator,
    grads: &[Vec<f32>],
    backward_s: f64,
    gbps: f64,
) -> SimClock {
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let net = NetConfig::flat(grads.len(), gbps);
    let mut clock = SimClock::default();
    {
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.backward_s = Some(backward_s);
        let mut rng = Rng::new(0x0E7A);
        let out = agg.aggregate(&refs, &mut ctx, &mut rng);
        std::hint::black_box(&out);
    }
    clock
}

fn main() {
    let n: usize = std::env::var("REPRO_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 20);
    let bits = 4usize;
    let buckets = 8usize;
    let gbps = 10.0;
    let backward_s = ModelProfile::resnet50().compute_s * perfmodel::BACKWARD_FRAC;
    let segments = make_segments(n, 16);

    println!(
        "=== bucketed-vs-monolithic simulated step (n={n}, {bits}-bit, {buckets} buckets, \
         {gbps} Gbps, backward {backward_s:.3}s) ==="
    );
    println!(
        "{:>8} {:>14} {:>14} {:>12} {:>10} {:>8}",
        "workers", "mono step (s)", "bucket step (s)", "hidden (ms)", "ovl frac", "gate"
    );

    let mut entries: Vec<Json> = Vec::new();
    let mut all_pass = true;
    for m in [4usize, 16, 64] {
        let mut rng = Rng::new(m as u64);
        let grads: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect();

        let mut mono = QsgdMaxNorm::new(bits).expect("mono aggregator");
        let clock_mono = run_once(&mut mono, &grads, backward_s, gbps);
        // the monolithic path hides nothing: full backward, then the wire
        assert_eq!(clock_mono.hidden_comm_s, 0.0);
        let mono_step = backward_s + clock_mono.comm_s;

        let cfg = ControlConfig::new(buckets);
        let mut plane =
            GradientControlPlane::new(cfg, bits, n, &segments).expect("control plane");
        let clock_b = run_once(&mut plane, &grads, backward_s, gbps);
        let buck_step = backward_s + clock_b.comm_s - clock_b.hidden_comm_s;
        let report = plane.last_overlap();

        let pass = buck_step <= mono_step && report.overlap_frac > 0.0;
        all_pass &= pass;
        println!(
            "{:>8} {:>14.6} {:>14.6} {:>12.3} {:>10.3} {:>8}",
            m,
            mono_step,
            buck_step,
            clock_b.hidden_comm_s * 1e3,
            report.overlap_frac,
            if pass { "ok" } else { "FAIL" }
        );
        entries.push(obj(vec![
            ("workers", num(m as f64)),
            ("mono_step_s", num(mono_step)),
            ("bucketed_step_s", num(buck_step)),
            ("hidden_comm_s", num(clock_b.hidden_comm_s)),
            ("overlap_frac", num(report.overlap_frac)),
            ("gate_pass", num(pass as u8 as f64)),
        ]));
    }

    if let Ok(path) = std::env::var("REPRO_BENCH_JSON") {
        let json = obj(vec![
            ("schema", js("repro-micro-overlap-v1")),
            ("n", num(n as f64)),
            ("bits", num(bits as f64)),
            ("buckets", num(buckets as f64)),
            ("net_gbps", num(gbps)),
            ("backward_s", num(backward_s)),
            ("entries", arr(entries)),
        ]);
        std::fs::write(&path, json.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }

    // the CI gate: bucketed-with-overlap never slower than monolithic
    assert!(all_pass, "overlap gate failed: bucketed step slower than monolithic");
    println!("\noverlap gate: bucketed-with-overlap <= monolithic at every worker count");
}
