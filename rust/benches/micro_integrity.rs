//! Integrity smoke bench (PR 7, CI-gated): what hop-segment checksums and
//! retransmit-based healing cost on the packed plane — 4-bit QSGD-MN, one
//! bucket, 8 workers, 10 Gbps flat Ethernet, n = 2^20 coordinates.
//!
//! Hard gates, all deterministic (the wire model is analytic and the fault
//! draws are pure functions of `(seed, step, worker, hop, attempt)`):
//!   * checksum overhead: integrity ON over a clean wire adds <= 2% to the
//!     wire ledger at 4 bits, with the aggregate bit-identical to OFF;
//!   * recovery beats redo: healing a corrupted step (backoff + resent hop
//!     segments) costs less simulated time than re-running the whole
//!     collective — the naive alternative to hop-level retransmission.
//!
//! Set `REPRO_BENCH_JSON=<path>` to emit the numbers as JSON (consumed by
//! `tools/bench_compress.py` -> `BENCH_integrity.json`).

use repro::collectives::{packed, IntegrityConfig, StepCtx};
use repro::compress::Aggregator;
use repro::control::{ControlConfig, GradientControlPlane};
use repro::netsim::{Algo, FaultPlan, HopFault, NetConfig, SimClock};
use repro::util::json::{num, obj, s as js, Json};
use repro::util::rng::Rng;

fn run_once(
    grads: &[Vec<f32>],
    n: usize,
    buckets: usize,
    bits: usize,
    gbps: f64,
    integrity: Option<IntegrityConfig>,
    faults: Option<(&FaultPlan, usize)>,
) -> (Vec<f32>, SimClock) {
    let m = grads.len();
    let plane = GradientControlPlane::new(ControlConfig::new(buckets), bits, n, &[]);
    let mut plane = plane.expect("control plane");
    let net = NetConfig::flat(m, gbps);
    let mut clock = SimClock::default();
    let out = {
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.integrity = integrity;
        ctx.wire_faults = faults;
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let mut rng = Rng::new(0x1D3A);
        plane.aggregate(&refs, &mut ctx, &mut rng)
    };
    (out, clock)
}

fn main() {
    let n: usize = std::env::var("REPRO_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 20);
    let (m, bits, buckets, gbps) = (8usize, 4usize, 1usize, 10.0);
    let icfg = IntegrityConfig::default();

    let mut rng = Rng::new(0x16B1);
    let grads: Vec<Vec<f32>> = (0..m)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal_f32(&mut v, 1.0);
            v
        })
        .collect();

    println!(
        "=== hop-segment integrity overhead + recovery (n={n}, M={m}, {bits}-bit, \
         {buckets} bucket, {gbps} Gbps, retries={}, backoff={}s) ===",
        icfg.max_retries, icfg.backoff_base_s
    );

    // --- gate 1: checksum overhead over a clean wire
    let (out_off, clk_off) = run_once(&grads, n, buckets, bits, gbps, None, None);
    let (out_on, clk_on) = run_once(&grads, n, buckets, bits, gbps, Some(icfg), None);
    let overhead = (clk_on.bits_per_worker - clk_off.bits_per_worker) / clk_off.bits_per_worker;
    let parity = out_on == out_off;
    let gate_overhead = parity && overhead <= 0.02 && clk_on.retrans_bits == 0.0;
    println!(
        "checksum: {:>12.0} -> {:>12.0} bits/worker  (+{:.4}%)  output {}  gate {}",
        clk_off.bits_per_worker,
        clk_on.bits_per_worker,
        overhead * 100.0,
        if parity { "bit-equal" } else { "DIVERGED" },
        if gate_overhead { "ok" } else { "FAIL" }
    );

    // --- gate 2: healing a corrupted step vs redoing the whole collective
    let plan = FaultPlan::wire(0x9E7A, 0.02, 0.02);
    let hops = packed::schedule_for(Algo::Ring, false, 1).as_dyn().hops(m);
    let step = (0..256)
        .find(|&s| {
            (0..m).any(|w| (0..hops).any(|h| plan.hop_fault(s, w, h, 0) != HopFault::None))
        })
        .expect("a 4% per-hop fault rate must fire within 256 steps");
    let (out_faulty, clk_faulty) =
        run_once(&grads, n, buckets, bits, gbps, Some(icfg), Some((&plan, step)));
    let healed = out_faulty == out_on;
    let recovery_s = clk_faulty.retrans_s;
    let redo_s = clk_faulty.comm_s; // price of re-running the collective
    let gate_recovery = healed && recovery_s > 0.0 && recovery_s < redo_s;
    println!(
        "recovery: step {step}: {:.6}s retransmit vs {:.6}s full redo  \
         ({:.0} bits resent)  output {}  gate {}",
        recovery_s,
        redo_s,
        clk_faulty.retrans_bits,
        if healed { "healed" } else { "DIVERGED" },
        if gate_recovery { "ok" } else { "FAIL" }
    );

    if let Ok(path) = std::env::var("REPRO_BENCH_JSON") {
        let json = obj(vec![
            ("schema", js("repro-micro-integrity-v1")),
            ("n", num(n as f64)),
            ("workers", num(m as f64)),
            ("bits", num(bits as f64)),
            ("buckets", num(buckets as f64)),
            ("net_gbps", num(gbps)),
            ("max_retries", num(icfg.max_retries as f64)),
            ("backoff_base_s", num(icfg.backoff_base_s)),
            ("bits_per_worker_off", num(clk_off.bits_per_worker)),
            ("bits_per_worker_on", num(clk_on.bits_per_worker)),
            ("checksum_overhead_frac", num(overhead)),
            ("fault_step", num(step as f64)),
            ("retrans_s", num(recovery_s)),
            ("redo_comm_s", num(redo_s)),
            ("retrans_bits", num(clk_faulty.retrans_bits)),
            ("gate_overhead_pass", num(gate_overhead as u8 as f64)),
            ("gate_recovery_pass", num(gate_recovery as u8 as f64)),
        ]);
        std::fs::write(&path, json.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }

    assert!(
        gate_overhead,
        "integrity gate failed: checksums must cost <= 2% wire bits and keep the \
         aggregate bit-identical"
    );
    assert!(
        gate_recovery,
        "integrity gate failed: hop-level retransmission must heal bit-identically \
         and beat a full-step redo"
    );
    println!("\nintegrity gate: <= 2% checksum overhead, recovery < full redo, bit-equal output");
}
