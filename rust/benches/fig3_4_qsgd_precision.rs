//! Figures 3 & 4: QSGDMaxNorm precision sweep {8, 4, 2} bits vs the fp32
//! baseline. Paper claims: 8/4-bit match AllReduce-SGD; 2-bit quantizes too
//! aggressively and shows a pronounced loss gap (worse on the
//! communication-intensive model).

mod common;

fn main() -> anyhow::Result<()> {
    common::run_figure_bench(
        "fig3_4",
        &["allreduce", "qsgd-mn-8", "qsgd-mn-4", "qsgd-mn-2"],
    )
}
