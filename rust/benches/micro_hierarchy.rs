//! Hierarchical collective gate (PR 8, CI-gated): flat packed ring vs the
//! two-level island schedule on the paper topology (128 workers = 32 nodes
//! x 4 NVLink GPUs, 10 Gbps inter-node Ethernet), *simulated* comm time
//! from the alpha-beta wire model at 2- and 4-bit QSGD-MN widths.
//!
//! The charge path is exactly the fused step's seam
//! (`StepCtx::packed_schedule` -> `charge_packed`), so the numbers here are
//! the ones a training step books; the payload itself is schedule-invariant
//! (pinned bit-for-bit by `hierarchical_vs_flat_parity_matrix`). Hard
//! gates, all deterministic:
//!   * hier comm_s <= flat comm_s at every width (the NVLink islands
//!     absorb 4x the ring hops at ~25x the bandwidth and 1/25 the alpha);
//!   * flat books zero intra-level hop bits, hier books both levels and
//!     the per-level split sums to the hop ledger.
//!
//! Set `REPRO_BENCH_JSON=<path>` to emit the numbers as JSON (consumed by
//! `tools/bench_compress.py` -> `BENCH_hierarchy.json`).

use repro::collectives::StepCtx;
use repro::compress::{bitpack, kernels};
use repro::netsim::{NetConfig, SimClock};
use repro::util::json::{arr, num, obj, s as js, Json};

struct Charge {
    comm_s: f64,
    hop_bits: f64,
    intra_bits: f64,
    inter_bits: f64,
    sched: &'static str,
}

/// One charge-only collective through the fused seam: resolve the schedule
/// for (`hier`, topology), book it on a fresh clock, return the ledgers.
fn charge(net: &NetConfig, hier: bool, lmax: usize, wire_bits: f64, n: usize) -> Charge {
    let m = net.workers;
    let rbits = bitpack::packed_sum_bits(lmax, m);
    let mut clock = SimClock::default();
    let sched_name;
    {
        let mut ctx = StepCtx::new(net, &mut clock);
        ctx.hier = hier;
        let sched = ctx.packed_schedule(lmax, m, n);
        sched_name = sched.as_dyn().name();
        ctx.charge_packed(sched.as_dyn(), n, rbits, wire_bits);
    }
    Charge {
        comm_s: clock.comm_s,
        hop_bits: clock.hop_bits_per_worker,
        intra_bits: clock.hop_bits_intra,
        inter_bits: clock.hop_bits_inter,
        sched: sched_name,
    }
}

fn main() {
    let n: usize = std::env::var("REPRO_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 20);
    let gbps = 10.0;
    let net = NetConfig::paper_cluster(gbps);
    let (m, g) = (net.workers, net.gpus_per_node);
    let nodes = net.nodes();

    println!(
        "=== flat vs hierarchical simulated comm time (n={n}, M={m} = {nodes} nodes x {g} GPUs, \
         {gbps} Gbps inter, QSGD-MN) ==="
    );
    println!(
        "{:>5} {:>12} {:>12} {:>8} {:>12} {:>14} {:>14} {:>8}",
        "bits", "flat (ms)", "hier (ms)", "speedup", "hier sched", "intra (Mbit)", "inter (Mbit)", "gate"
    );

    let mut entries: Vec<Json> = Vec::new();
    let mut all_pass = true;
    for bits in [2usize, 4] {
        let lmax = kernels::s_for_bits(bits);
        let flat = charge(&net, false, lmax, bits as f64, n);
        let hier = charge(&net, true, lmax, bits as f64, n);
        let split_ok = flat.intra_bits == 0.0
            && hier.intra_bits > 0.0
            && hier.inter_bits > 0.0
            && hier.intra_bits + hier.inter_bits == hier.hop_bits;
        let pass = hier.comm_s <= flat.comm_s && split_ok;
        all_pass &= pass;
        println!(
            "{:>5} {:>12.4} {:>12.4} {:>8.2} {:>12} {:>14.3} {:>14.3} {:>8}",
            bits,
            flat.comm_s * 1e3,
            hier.comm_s * 1e3,
            flat.comm_s / hier.comm_s,
            hier.sched,
            hier.intra_bits / 1e6,
            hier.inter_bits / 1e6,
            if pass { "ok" } else { "FAIL" }
        );
        entries.push(obj(vec![
            ("bits", num(bits as f64)),
            ("lmax", num(lmax as f64)),
            ("flat_sched", js(flat.sched)),
            ("hier_sched", js(hier.sched)),
            ("flat_comm_s", num(flat.comm_s)),
            ("hier_comm_s", num(hier.comm_s)),
            ("speedup", num(flat.comm_s / hier.comm_s)),
            ("flat_inter_bits", num(flat.inter_bits)),
            ("hier_intra_bits", num(hier.intra_bits)),
            ("hier_inter_bits", num(hier.inter_bits)),
            ("gate_pass", num(pass as u8 as f64)),
        ]));
    }

    if let Ok(path) = std::env::var("REPRO_BENCH_JSON") {
        let json = obj(vec![
            ("schema", js("repro-micro-hierarchy-v1")),
            ("n", num(n as f64)),
            ("workers", num(m as f64)),
            ("gpus_per_node", num(g as f64)),
            ("nodes", num(nodes as f64)),
            ("net_gbps", num(gbps)),
            ("entries", arr(entries)),
        ]);
        std::fs::write(&path, json.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }

    assert!(
        all_pass,
        "hierarchy gate failed: the two-level schedule must not be slower than \
         the flat ring on the paper topology (and must book both link levels)"
    );
    println!("\nhierarchy gate: hier <= flat simulated comm time at 2 and 4 bits");
}
