//! Figures 7 & 8: QSGDMaxNormMultiScale two-scale sweep
//! {(8,12),(6,10),(4,8),(2,6)}. Paper claim: the 2-bit scheme, which failed
//! in the single-scale sweep (Figs 3/4), performs on par with AllReduce-SGD
//! once the second scale is available.

mod common;

fn main() -> anyhow::Result<()> {
    common::run_figure_bench(
        "fig7_8",
        &[
            "allreduce",
            "qsgd-mn-ts-8-12",
            "qsgd-mn-ts-6-10",
            "qsgd-mn-ts-4-8",
            "qsgd-mn-ts-2-6",
        ],
    )
}
