//! Flight-recorder smoke bench (PR 9, CI-gated): what arming the step
//! tracer costs on the packed plane — 4-bit QSGD-MN, 4 buckets, 8 workers,
//! 10 Gbps flat Ethernet, n = 2^20 coordinates.
//!
//! Hard gates:
//!   * zero-cost-when-on (approximately): the armed recorder adds <= 3%
//!     wall time to a full aggregate step (min of 5 trials per arm);
//!   * inert: the armed aggregate is bit-identical to trace-off — output
//!     and all twelve SimClock ledgers — with a clean audit.
//!
//! Set `REPRO_BENCH_JSON=<path>` to emit the numbers as JSON (consumed by
//! `tools/bench_compress.py` -> `BENCH_trace.json`). Set
//! `REPRO_TRACE_OUT=<path>` to additionally record a small traced
//! hierarchical 4x4 run over a lossy checksummed wire and export it as
//! Chrome trace-event JSON — CI validates that artifact with
//! `tools/trace_report.py --check` and uploads it.

use repro::collectives::{packed, IntegrityConfig, StepCtx};
use repro::compress::Aggregator;
use repro::control::{ControlConfig, GradientControlPlane};
use repro::netsim::{Algo, FaultPlan, HopFault, NetConfig, SimClock};
use repro::trace::Tracer;
use repro::util::json::{num, obj, s as js};
use repro::util::rng::Rng;

fn run_once(
    grads: &[Vec<f32>],
    n: usize,
    buckets: usize,
    bits: usize,
    gbps: f64,
    mut tracer: Option<&mut Tracer>,
) -> (Vec<f32>, SimClock, f64) {
    let m = grads.len();
    let plane = GradientControlPlane::new(ControlConfig::new(buckets), bits, n, &[]);
    let mut plane = plane.expect("control plane");
    let net = NetConfig::flat(m, gbps);
    let mut clock = SimClock::default();
    let t = std::time::Instant::now();
    let out = {
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.tracer = tracer.as_deref_mut();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let mut rng = Rng::new(0x1D3A);
        plane.aggregate(&refs, &mut ctx, &mut rng)
    };
    let wall = t.elapsed().as_secs_f64();
    if let Some(t) = tracer {
        t.end_step(&clock);
    }
    (out, clock, wall)
}

fn clocks_equal(a: &SimClock, b: &SimClock) -> bool {
    a.comm_s == b.comm_s
        && a.compute_s == b.compute_s
        && a.encode_s == b.encode_s
        && a.decode_s == b.decode_s
        && a.bits_per_worker == b.bits_per_worker
        && a.hop_bits_per_worker == b.hop_bits_per_worker
        && a.hop_bits_intra == b.hop_bits_intra
        && a.hop_bits_inter == b.hop_bits_inter
        && a.hidden_comm_s == b.hidden_comm_s
        && a.straggler_wait_s == b.straggler_wait_s
        && a.retrans_s == b.retrans_s
        && a.retrans_bits == b.retrans_bits
}

/// The CI artifact: a 6-step traced hierarchical 4x4 run over a lossy
/// checksummed wire, exported as Chrome trace-event JSON.
fn record_hier_faults_trace(path: &str) {
    let (m, g, n, bits, buckets, gbps) = (16usize, 4usize, 1usize << 14, 4usize, 3usize, 10.0);
    let mut grng = Rng::new(0x7A11);
    let grads: Vec<Vec<f32>> = (0..m)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            grng.fill_normal_f32(&mut v, 1.0);
            v
        })
        .collect();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    let mut net = NetConfig::flat(m, gbps);
    net.gpus_per_node = g;
    let plan = FaultPlan::wire(0x9E7A, 0.05, 0.05);
    let hops = packed::schedule_for_topo(Algo::Ring, false, 1, true, g, m).as_dyn().hops(m);
    let fault_step = (0..512)
        .find(|&s| {
            (0..m).any(|w| (0..hops).any(|h| plan.hop_fault(s, w, h, 0) != HopFault::None))
        })
        .expect("a lossy wire must fault within 512 steps");

    let mut plane =
        GradientControlPlane::new(ControlConfig::new(buckets), bits, n, &[]).expect("plane");
    let mut tracer = Tracer::new();
    let mut run_clock = SimClock::default();
    for step in 0..6usize {
        let mut clock = SimClock::default();
        tracer.begin_step(step, run_clock.total_s());
        {
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.hier = true;
            ctx.integrity = Some(IntegrityConfig::default());
            ctx.wire_faults = Some((&plan, fault_step + step));
            ctx.tracer = Some(&mut tracer);
            let mut rng = Rng::new(0x7A11 ^ step as u64);
            plane.aggregate(&refs, &mut ctx, &mut rng);
        }
        tracer.end_step(&clock);
        run_clock.accumulate(&clock);
    }
    tracer.write_chrome(std::path::Path::new(path), m).expect("writing trace artifact");
    println!(
        "trace artifact: 6-step hier 4x4 lossy run -> {path}  \
         ({:.0} hop bits intra / {:.0} inter, {:.0} retransmitted, {} violations)",
        run_clock.hop_bits_intra,
        run_clock.hop_bits_inter,
        run_clock.retrans_bits,
        tracer.violation_count()
    );
    assert_eq!(tracer.violation_count(), 0, "traced artifact run must audit clean");
}

fn main() {
    let n: usize = std::env::var("REPRO_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 20);
    let (m, bits, buckets, gbps) = (8usize, 4usize, 4usize, 10.0);
    const TRIALS: usize = 5;

    let mut rng = Rng::new(0x16B1);
    let grads: Vec<Vec<f32>> = (0..m)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal_f32(&mut v, 1.0);
            v
        })
        .collect();

    println!(
        "=== flight-recorder overhead (n={n}, M={m}, {bits}-bit, {buckets} buckets, \
         {gbps} Gbps, min of {TRIALS}) ==="
    );

    // min-of-TRIALS wall per arm; outputs/clocks are deterministic so the
    // parity checks use the last trial of each arm.
    let mut wall_off = f64::INFINITY;
    let mut wall_on = f64::INFINITY;
    let mut off = None;
    let mut on = None;
    let mut violations = 0usize;
    for _ in 0..TRIALS {
        let (o, c, w) = run_once(&grads, n, buckets, bits, gbps, None);
        wall_off = wall_off.min(w);
        off = Some((o, c));
        let mut tracer = Tracer::new();
        let (o, c, w) = run_once(&grads, n, buckets, bits, gbps, Some(&mut tracer));
        wall_on = wall_on.min(w);
        violations = tracer.violation_count();
        on = Some((o, c));
    }
    let (out_off, clk_off) = off.unwrap();
    let (out_on, clk_on) = on.unwrap();

    let overhead = (wall_on - wall_off) / wall_off;
    let gate_overhead = overhead <= 0.03;
    let gate_parity = out_on == out_off && clocks_equal(&clk_on, &clk_off) && violations == 0;
    println!(
        "wall: {:.6}s off -> {:.6}s on  ({:+.3}% overhead)  gate {}",
        wall_off,
        wall_on,
        overhead * 100.0,
        if gate_overhead { "ok" } else { "FAIL" }
    );
    println!(
        "parity: output {}  ledgers {}  violations {}  gate {}",
        if out_on == out_off { "bit-equal" } else { "DIVERGED" },
        if clocks_equal(&clk_on, &clk_off) { "bit-equal" } else { "DIVERGED" },
        violations,
        if gate_parity { "ok" } else { "FAIL" }
    );

    if let Ok(path) = std::env::var("REPRO_TRACE_OUT") {
        record_hier_faults_trace(&path);
    }

    if let Ok(path) = std::env::var("REPRO_BENCH_JSON") {
        let json = obj(vec![
            ("schema", js("repro-micro-trace-v1")),
            ("n", num(n as f64)),
            ("workers", num(m as f64)),
            ("bits", num(bits as f64)),
            ("buckets", num(buckets as f64)),
            ("net_gbps", num(gbps)),
            ("trials", num(TRIALS as f64)),
            ("wall_off_s", num(wall_off)),
            ("wall_on_s", num(wall_on)),
            ("overhead_frac", num(overhead)),
            ("violations", num(violations as f64)),
            ("gate_overhead_pass", num(gate_overhead as u8 as f64)),
            ("gate_parity_pass", num(gate_parity as u8 as f64)),
        ]);
        std::fs::write(&path, json.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }

    assert!(
        gate_parity,
        "trace gate failed: the armed recorder must be inert — bit-identical \
         output and ledgers, zero audit violations"
    );
    assert!(
        gate_overhead,
        "trace gate failed: the armed recorder must add <= 3% wall time \
         (measured +{:.3}%)",
        overhead * 100.0
    );
    println!("\ntrace gate: <= 3% wall overhead, bit-equal output + ledgers, clean audit");
}
