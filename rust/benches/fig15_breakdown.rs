//! Figure 15: per-phase time breakdown (compute / encode / comm / decode)
//! for every method, from an instrumented run on the simulated 4-worker
//! cluster. Paper claims: training-time differences come from communication
//! time; two-scale methods pay two all-reduce rounds; PowerSGD codec time
//! grows with parameter count.

mod common;

fn main() -> anyhow::Result<()> {
    let arts = repro::runtime::Artifacts::load_default()?;
    let mut opts = repro::figures::FigureOpts::default();
    opts.steps = common::bench_steps().min(40);
    opts.workers = common::bench_workers();
    opts.models = common::bench_models();
    opts.quiet = true;
    println!("{}", repro::figures::fig15(&arts, &opts)?);
    Ok(())
}
