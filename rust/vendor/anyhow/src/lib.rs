//! Vendored API-compatible subset of `anyhow` (the build image has no
//! crates.io registry, so this workspace path crate stands in for it).
//!
//! Supported surface — exactly what this repo uses:
//! `Result<T>`, `Error`, `anyhow!`, `bail!`, `ensure!`, and the `Context`
//! extension trait (`.context(..)` / `.with_context(..)`) on `Result` and
//! `Option`. Error values carry a single formatted message with contexts
//! prepended, which is all the callers ever display.

use std::fmt;

/// Drop-in for `anyhow::Error`: an opaque, formatted error message.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket `From` coherent
// alongside core's reflexive `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($tt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ctx(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("parsing int")?;
        ensure!(v >= 0, "negative: {v}");
        Ok(v)
    }

    #[test]
    fn error_paths_format() {
        assert_eq!(parse_ctx("42").unwrap(), 42);
        let e = parse_ctx("nope").unwrap_err();
        assert!(e.to_string().starts_with("parsing int: "), "{e}");
        let e = parse_ctx("-3").unwrap_err();
        assert_eq!(e.to_string(), "negative: -3");
    }

    #[test]
    fn option_context_and_bail() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.context("missing")?;
            if v == 9 {
                bail!("nine is right out: {}", v);
            }
            Ok(v)
        }
        assert_eq!(f(Some(1)).unwrap(), 1);
        assert_eq!(f(None).unwrap_err().to_string(), "missing");
        assert_eq!(f(Some(9)).unwrap_err().to_string(), "nine is right out: 9");
    }
}
