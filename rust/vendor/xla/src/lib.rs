//! Stub of the `xla` PJRT bindings.
//!
//! The build image does not carry the native PJRT runtime, so this crate
//! provides the exact API surface `repro::runtime` consumes with every
//! entry point failing at [`PjRtClient::cpu`]. The L3 simulator, compressors
//! and collectives (the bulk of the repo, and all unit tests) are fully
//! functional without it; integration tests that execute lowered HLO report
//! the same "no PJRT backend" failure the seed image did. Swap this path
//! crate for the real bindings in `rust/Cargo.toml` to light up L1/L2.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }

    fn unavailable() -> Error {
        Error::new(
            "PJRT backend unavailable: repro was built with the stub `xla` crate \
             (no native runtime in this image)",
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the bridge decodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
}

/// Marker for scalar types storable in a [`Literal`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor value. The stub never holds real device data; it exists
/// so `runtime::Input::to_literal` type-checks.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape<D: AsRef<[i64]>>(&self, _dims: D) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (stub: parse always fails).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error::new(&format!(
            "cannot parse HLO text {:?}: PJRT backend unavailable (stub xla crate)",
            path.as_ref()
        )))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer handle returned by `execute`.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The single gate: everything downstream of a failed client construction
    /// is unreachable, so the other stub methods only need to type-check.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }
}
