//! Step flight recorder: typed spans over the simulated charge sites, a
//! self-auditing ledger registry, and Chrome-trace/JSON-lines export (PR 9).
//!
//! The simulator books time and wire bits into the twelve [`SimClock`]
//! ledgers from charge sites scattered through `collectives`, `control`, and
//! `cluster`. This module records *why*: every charge emits a [`Span`] whose
//! `[t0, t1]` endpoints are **snapshots of the charged ledger itself**, taken
//! immediately before and after the increment. That construction is the
//! accounting rule everything here leans on:
//!
//! * per category, spans chain exactly — the first span starts at the
//!   step-local zero, each span starts where the previous one ended, and the
//!   last span ends at the step's ledger delta. No floating-point summation
//!   is re-done, so the check is *bit-exact*, not epsilon-close;
//! * the payload/wire bit books are integral f64 well below 2^53, so their
//!   span sums are exact too.
//!
//! [`LedgerAudit::check`] enforces those invariants per step (plus the
//! documented `hop_bits_intra + hop_bits_inter == hop_bits_per_worker` and
//! `hidden_comm_s <= comm_s`), failing loudly under `debug_assertions` and
//! counting violations in release.
//!
//! Tracing is zero-cost when off: the [`Tracer`] hangs off
//! [`crate::collectives::StepCtx`] as an `Option` that defaults to `None`,
//! and every instrumentation site only *reads* clock fields that the charge
//! just wrote — it never adds, reorders, or conditions a charge. Trace-on
//! output is therefore bit-identical to trace-off (pinned in
//! `tests/trace_invariants.rs`).
//!
//! Export: [`Tracer::write_chrome`] emits Chrome trace-event JSON loadable in
//! `chrome://tracing` / <https://ui.perfetto.dev> (one track per worker plus
//! a wire track per link level); [`Tracer::write_jsonl`] emits a compact
//! per-step JSON-lines file. `tools/trace_report.py` renders a breakdown
//! table from either.

use crate::netsim::{LinkLevel, SimClock};
use crate::util::json::{arr, num, obj, s, Json};
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// The SimClock *time* categories a span can charge against. Each span
/// belongs to exactly one category; per step, the spans of a category must
/// tile `[0, delta.category]` exactly (see [`LedgerAudit::check`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Cat {
    Comm,
    Encode,
    Decode,
    Compute,
    StragglerWait,
    Retrans,
    HiddenComm,
}

impl Cat {
    pub const ALL: [Cat; 7] = [
        Cat::Comm,
        Cat::Encode,
        Cat::Decode,
        Cat::Compute,
        Cat::StragglerWait,
        Cat::Retrans,
        Cat::HiddenComm,
    ];

    /// Read this category's accumulator out of a clock (or clock delta).
    pub fn of(&self, c: &SimClock) -> f64 {
        match self {
            Cat::Comm => c.comm_s,
            Cat::Encode => c.encode_s,
            Cat::Decode => c.decode_s,
            Cat::Compute => c.compute_s,
            Cat::StragglerWait => c.straggler_wait_s,
            Cat::Retrans => c.retrans_s,
            Cat::HiddenComm => c.hidden_comm_s,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Cat::Comm => "comm",
            Cat::Encode => "encode",
            Cat::Decode => "decode",
            Cat::Compute => "compute",
            Cat::StragglerWait => "straggler_wait",
            Cat::Retrans => "retrans",
            Cat::HiddenComm => "hidden_comm",
        }
    }
}

/// What a span *was* — the typed payload behind the category accounting.
/// Instants (`Pack`, `GuardSkip`) carry bookkeeping without duration;
/// everything else is a complete event on its category's timeline.
#[derive(Clone, Debug)]
pub enum SpanKind {
    /// Simulated backward pass (cluster profile, charged on the run clock).
    Compute,
    /// Encoder time for one bucket (`None` = unbucketed/monolithic path).
    Encode { bucket: Option<usize> },
    /// Decoder time for one bucket.
    Decode { bucket: Option<usize> },
    /// Payload-bit booking instant at the head of a packed collective: the
    /// paper's `32 + d·r` accounting lands here, before any hop ships.
    Pack { bucket: Option<usize>, payload_bits: f64 },
    /// One synchronous hop of a packed schedule, with its wire-level split.
    Hop { schedule: &'static str, level: LinkLevel, hop_idx: usize, wire_bits: f64 },
    /// Per-hop checksum trailer shipped by the integrity layer (PR 7).
    Checksum { level: LinkLevel, hop_idx: usize, wire_bits: f64 },
    /// Backoff + re-shipped segment after a failed checksummed hop.
    Retransmit { attempt: u32, worker: usize, hop_idx: usize, level: LinkLevel, wire_bits: f64 },
    /// An unpacked (f32-level) collective charged through the uniform
    /// allreduce model — no per-hop wire ledger to partition.
    Collective { schedule: &'static str },
    /// All-gather (the O(M) baseline paths).
    Allgather,
    /// 32-bit norm/max scalar share (the multi-scale `32` in `32 + d·r`).
    NormShare { bucket: Option<usize> },
    /// Per-bucket u8 scale-index min-reduce (multi-scale agreement).
    ScaleShareReduce { bucket: Option<usize> },
    /// Elastic barrier: waiting out the slowest surviving worker.
    StragglerWait,
    /// Rejoining worker replaying the reference state (elastic cohort).
    CatchUp,
    /// Retry-exhaustion escalation charge (detection-timeout ladder).
    Escalation,
    /// Overlap-scheduler verdict instant: how much comm hid behind backward.
    Overlap { hidden_s: f64, exposed_s: f64 },
    /// Anomaly guard skipped the update for this step.
    GuardSkip,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Compute => "compute",
            SpanKind::Encode { .. } => "encode",
            SpanKind::Decode { .. } => "decode",
            SpanKind::Pack { .. } => "pack",
            SpanKind::Hop { .. } => "hop",
            SpanKind::Checksum { .. } => "checksum",
            SpanKind::Retransmit { .. } => "retransmit",
            SpanKind::Collective { .. } => "collective",
            SpanKind::Allgather => "allgather",
            SpanKind::NormShare { .. } => "norm_share",
            SpanKind::ScaleShareReduce { .. } => "scale_share_reduce",
            SpanKind::StragglerWait => "straggler_wait",
            SpanKind::CatchUp => "catch_up",
            SpanKind::Escalation => "escalation",
            SpanKind::Overlap { .. } => "overlap",
            SpanKind::GuardSkip => "guard_skip",
        }
    }

    /// Instants carry no duration and stand outside the category chains.
    /// (`Overlap` is *not* an instant: it is the [`Cat::HiddenComm`]
    /// chain's sole span, covering the step's hidden-comm delta.)
    pub fn is_instant(&self) -> bool {
        matches!(self, SpanKind::Pack { .. } | SpanKind::GuardSkip)
    }

    /// Wire-track attribution: (level, wire bits shipped on that level).
    pub fn wire(&self) -> Option<(LinkLevel, f64)> {
        match self {
            SpanKind::Hop { level, wire_bits, .. }
            | SpanKind::Checksum { level, wire_bits, .. }
            | SpanKind::Retransmit { level, wire_bits, .. } => Some((*level, *wire_bits)),
            _ => None,
        }
    }
}

/// One recorded event. `t0`/`t1` are step-local snapshots of the `cat`
/// accumulator (instants have `t0 == t1` by construction); `bits` is the
/// `bits_per_worker` increment attributed to this span (0 for spans that
/// book no payload bits — hop wire bits live in the kind, not here).
#[derive(Clone, Debug)]
pub struct Span {
    pub cat: Cat,
    pub kind: SpanKind,
    pub t0: f64,
    pub t1: f64,
    pub bits: f64,
}

impl Span {
    pub fn new(cat: Cat, kind: SpanKind, t0: f64, t1: f64, bits: f64) -> Span {
        Span { cat, kind, t0, t1, bits }
    }
}

/// One completed step: its spans, the audited ledger delta, and any
/// invariant violations [`LedgerAudit::check`] found.
#[derive(Clone, Debug)]
pub struct StepTrace {
    pub step: usize,
    /// Run-clock `total_s()` at step start — the Chrome-track time base.
    pub base_s: f64,
    pub spans: Vec<Span>,
    pub delta: SimClock,
    pub violations: Vec<String>,
}

/// The ledger registry: per-step invariant enforcement over (delta, spans).
pub struct LedgerAudit;

impl LedgerAudit {
    /// Check every documented invariant; returns human-readable violations.
    ///
    /// Time chains and bit books are checked with **exact** equality — the
    /// span endpoints are snapshots of the very accumulator the delta was
    /// diffed from, and all bit counts are integral f64 below 2^53, so any
    /// inequality is a real accounting bug, not float noise. The one
    /// epsilon: `hidden <= comm`, where the two sides come from different
    /// accumulators.
    pub fn check(delta: &SimClock, spans: &[Span]) -> Vec<String> {
        let mut v = Vec::new();

        // (1) per-category chain: spans tile [0, delta.cat] exactly.
        for cat in Cat::ALL {
            let want = cat.of(delta);
            let chain: Vec<&Span> =
                spans.iter().filter(|sp| sp.cat == cat && !sp.kind.is_instant()).collect();
            if chain.is_empty() {
                if want != 0.0 {
                    v.push(format!(
                        "{}: delta {want:e} but no spans charged it",
                        cat.name()
                    ));
                }
                continue;
            }
            if chain[0].t0 != 0.0 {
                v.push(format!(
                    "{}: first span ({}) starts at {:e}, not 0",
                    cat.name(),
                    chain[0].kind.name(),
                    chain[0].t0
                ));
            }
            for w in chain.windows(2) {
                if w[1].t0 != w[0].t1 {
                    v.push(format!(
                        "{}: gap between {} (ends {:e}) and {} (starts {:e})",
                        cat.name(),
                        w[0].kind.name(),
                        w[0].t1,
                        w[1].kind.name(),
                        w[1].t0
                    ));
                }
            }
            for sp in &chain {
                if sp.t1 < sp.t0 {
                    v.push(format!(
                        "{}: negative-width span {} [{:e}, {:e}]",
                        cat.name(),
                        sp.kind.name(),
                        sp.t0,
                        sp.t1
                    ));
                }
            }
            let end = chain.last().unwrap().t1;
            if end != want {
                v.push(format!(
                    "{}: spans end at {end:e} but ledger delta is {want:e}",
                    cat.name()
                ));
            }
        }

        // (2) bit books — exact (integral f64 sums).
        let payload: f64 = spans.iter().map(|sp| sp.bits).sum();
        if payload != delta.bits_per_worker {
            v.push(format!(
                "bits_per_worker: spans book {payload} but ledger delta is {}",
                delta.bits_per_worker
            ));
        }
        let mut wire_intra = 0.0;
        let mut wire_inter = 0.0;
        let mut retrans_bits = 0.0;
        for sp in spans {
            match sp.kind {
                SpanKind::Hop { level, wire_bits, .. }
                | SpanKind::Checksum { level, wire_bits, .. } => match level {
                    LinkLevel::Intra => wire_intra += wire_bits,
                    LinkLevel::Inter => wire_inter += wire_bits,
                },
                SpanKind::Retransmit { wire_bits, .. } => retrans_bits += wire_bits,
                _ => {}
            }
        }
        if wire_intra != delta.hop_bits_intra {
            v.push(format!(
                "hop_bits_intra: spans ship {wire_intra} but ledger delta is {}",
                delta.hop_bits_intra
            ));
        }
        if wire_inter != delta.hop_bits_inter {
            v.push(format!(
                "hop_bits_inter: spans ship {wire_inter} but ledger delta is {}",
                delta.hop_bits_inter
            ));
        }
        if wire_intra + wire_inter != delta.hop_bits_per_worker {
            v.push(format!(
                "hop_bits_per_worker: spans ship {} but ledger delta is {}",
                wire_intra + wire_inter,
                delta.hop_bits_per_worker
            ));
        }
        if retrans_bits != delta.retrans_bits {
            v.push(format!(
                "retrans_bits: spans ship {retrans_bits} but ledger delta is {}",
                delta.retrans_bits
            ));
        }

        // (3) ledger-internal invariants.
        if delta.hop_bits_intra + delta.hop_bits_inter != delta.hop_bits_per_worker {
            v.push(format!(
                "ledger: hop_bits_intra {} + hop_bits_inter {} != hop_bits_per_worker {}",
                delta.hop_bits_intra, delta.hop_bits_inter, delta.hop_bits_per_worker
            ));
        }
        let eps = 1e-9 * delta.comm_s.abs().max(1e-12);
        if delta.hidden_comm_s > delta.comm_s + eps {
            v.push(format!(
                "ledger: hidden_comm_s {:e} > comm_s {:e}",
                delta.hidden_comm_s, delta.comm_s
            ));
        }
        v
    }
}

/// The step flight recorder. Owned by the driver (`Cluster` or a test) and
/// lent to [`crate::collectives::StepCtx`] for the duration of a step.
#[derive(Default)]
pub struct Tracer {
    steps: Vec<StepTrace>,
    /// (step index, run-clock base, spans so far) of the open step.
    cur: Option<(usize, f64, Vec<Span>)>,
    cur_bucket: Option<usize>,
    violations: usize,
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer::default()
    }

    /// Open a step at `base_s` seconds of run-clock critical path.
    pub fn begin_step(&mut self, step: usize, base_s: f64) {
        debug_assert!(self.cur.is_none(), "begin_step with a step already open");
        self.cur = Some((step, base_s, Vec::new()));
    }

    /// Record a span into the open step (lazily opening step `len()` at
    /// base 0 so bare `StepCtx` call sites in tests just work).
    pub fn push(&mut self, span: Span) {
        if self.cur.is_none() {
            self.cur = Some((self.steps.len(), 0.0, Vec::new()));
        }
        self.cur.as_mut().unwrap().2.push(span);
    }

    /// The control plane marks which bucket the inner collectives serve so
    /// Encode/Decode/NormShare/Pack spans can carry it without plumbing.
    pub fn set_bucket(&mut self, bucket: Option<usize>) {
        self.cur_bucket = bucket;
    }

    pub fn bucket(&self) -> Option<usize> {
        self.cur_bucket
    }

    /// Close the open step against its audited ledger delta. Loud under
    /// `debug_assertions` (tests), counted in release.
    pub fn end_step(&mut self, delta: &SimClock) {
        let (step, base_s, spans) =
            self.cur.take().unwrap_or((self.steps.len(), 0.0, Vec::new()));
        let violations = LedgerAudit::check(delta, &spans);
        debug_assert!(
            violations.is_empty(),
            "ledger audit failed at step {step}: {violations:#?}"
        );
        self.violations += violations.len();
        self.steps.push(StepTrace { step, base_s, spans, delta: delta.clone(), violations });
    }

    pub fn steps(&self) -> &[StepTrace] {
        &self.steps
    }

    pub fn violation_count(&self) -> usize {
        self.violations
    }

    /// Run totals: fold of all audited step deltas.
    pub fn totals(&self) -> SimClock {
        let mut t = SimClock::default();
        for st in &self.steps {
            t.accumulate(&st.delta);
        }
        t
    }

    /// Chrome trace-event JSON (the `{"traceEvents": [...]}` object form),
    /// loadable in `chrome://tracing` and <https://ui.perfetto.dev>.
    ///
    /// Track layout: pid 0 = "workers", one thread per simulated worker
    /// (the simulated collectives are symmetric, so every worker track
    /// shows the same span sequence); pid 1 = "wire", thread 0 the intra
    /// (NVLink island) level and thread 1 the inter (Ethernet) level, where
    /// Hop/Checksum/Retransmit spans are emitted once with their wire bits.
    ///
    /// Events on one track are monotone and non-overlapping by
    /// construction: each (pid, tid) keeps a cursor that starts at the
    /// step's run-clock base (never rewinding — overlap-hidden comm can
    /// make a step's span sum exceed its critical-path delta) and advances
    /// by each complete event's duration.
    pub fn to_chrome(&self, workers: usize) -> Json {
        let workers = workers.max(1);
        let mut events: Vec<Json> = Vec::new();
        // Metadata: process/thread names.
        events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("process_name")),
            ("pid", num(0.0)),
            ("args", obj(vec![("name", s("workers"))])),
        ]));
        for w in 0..workers {
            events.push(obj(vec![
                ("ph", s("M")),
                ("name", s("thread_name")),
                ("pid", num(0.0)),
                ("tid", num(w as f64)),
                ("args", obj(vec![("name", s(&format!("worker {w}")))])),
            ]));
        }
        events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("process_name")),
            ("pid", num(1.0)),
            ("args", obj(vec![("name", s("wire"))])),
        ]));
        for (tid, name) in [(0usize, "wire:intra"), (1usize, "wire:inter")] {
            events.push(obj(vec![
                ("ph", s("M")),
                ("name", s("thread_name")),
                ("pid", num(1.0)),
                ("tid", num(tid as f64)),
                ("args", obj(vec![("name", s(name))])),
            ]));
        }

        // Per-(pid, tid) cursors, continuous across steps.
        let mut cursors: BTreeMap<(usize, usize), f64> = BTreeMap::new();
        for st in &self.steps {
            for (_, cur) in cursors.iter_mut() {
                *cur = cur.max(st.base_s);
            }
            for sp in &st.spans {
                let dur = (sp.t1 - sp.t0).max(0.0);
                let mut args: Vec<(&str, Json)> = vec![
                    ("step", num(st.step as f64)),
                    ("cat", s(sp.cat.name())),
                ];
                match &sp.kind {
                    SpanKind::Encode { bucket }
                    | SpanKind::Decode { bucket }
                    | SpanKind::NormShare { bucket }
                    | SpanKind::ScaleShareReduce { bucket } => {
                        if let Some(b) = bucket {
                            args.push(("bucket", num(*b as f64)));
                        }
                    }
                    SpanKind::Pack { bucket, payload_bits } => {
                        if let Some(b) = bucket {
                            args.push(("bucket", num(*b as f64)));
                        }
                        args.push(("payload_bits", num(*payload_bits)));
                    }
                    SpanKind::Hop { schedule, level, hop_idx, wire_bits } => {
                        args.push(("schedule", s(schedule)));
                        args.push(("level", s(level_name(*level))));
                        args.push(("hop_idx", num(*hop_idx as f64)));
                        args.push(("wire_bits", num(*wire_bits)));
                    }
                    SpanKind::Checksum { level, hop_idx, wire_bits } => {
                        args.push(("level", s(level_name(*level))));
                        args.push(("hop_idx", num(*hop_idx as f64)));
                        args.push(("wire_bits", num(*wire_bits)));
                    }
                    SpanKind::Retransmit { attempt, worker, hop_idx, level, wire_bits } => {
                        args.push(("attempt", num(*attempt as f64)));
                        args.push(("worker", num(*worker as f64)));
                        args.push(("hop_idx", num(*hop_idx as f64)));
                        args.push(("level", s(level_name(*level))));
                        args.push(("wire_bits", num(*wire_bits)));
                    }
                    SpanKind::Collective { schedule } => {
                        args.push(("schedule", s(schedule)));
                    }
                    SpanKind::Overlap { hidden_s, exposed_s } => {
                        args.push(("hidden_s", num(*hidden_s)));
                        args.push(("exposed_s", num(*exposed_s)));
                    }
                    _ => {}
                }
                let args = obj(args);

                // Overlap renders as an instant: hidden comm ran *under*
                // the compute/comm spans already on the worker tracks, so
                // giving it track width would double-book the timeline.
                if sp.kind.is_instant() || matches!(sp.kind, SpanKind::Overlap { .. }) {
                    let cur = *cursors.entry((0, 0)).or_insert(st.base_s);
                    events.push(obj(vec![
                        ("ph", s("i")),
                        ("s", s("p")),
                        ("pid", num(0.0)),
                        ("tid", num(0.0)),
                        ("ts", num(cur * 1e6)),
                        ("name", s(sp.kind.name())),
                        ("cat", s(sp.cat.name())),
                        ("args", args),
                    ]));
                    continue;
                }

                // Worker tracks: symmetric simulated collectives — emit on
                // every worker thread at that thread's cursor.
                for w in 0..workers {
                    let cur = cursors.entry((0, w)).or_insert(st.base_s);
                    events.push(obj(vec![
                        ("ph", s("X")),
                        ("pid", num(0.0)),
                        ("tid", num(w as f64)),
                        ("ts", num(*cur * 1e6)),
                        ("dur", num(dur * 1e6)),
                        ("name", s(sp.kind.name())),
                        ("cat", s(sp.cat.name())),
                        ("args", args.clone()),
                    ]));
                    *cur += dur;
                }
                // Wire tracks: one emission per wire-bearing span.
                if let Some((level, _)) = sp.kind.wire() {
                    let tid = match level {
                        LinkLevel::Intra => 0usize,
                        LinkLevel::Inter => 1usize,
                    };
                    let cur = cursors.entry((1, tid)).or_insert(st.base_s);
                    events.push(obj(vec![
                        ("ph", s("X")),
                        ("pid", num(1.0)),
                        ("tid", num(tid as f64)),
                        ("ts", num(*cur * 1e6)),
                        ("dur", num(dur * 1e6)),
                        ("name", s(sp.kind.name())),
                        ("cat", s(sp.cat.name())),
                        ("args", args),
                    ]));
                    *cur += dur;
                }
            }
        }

        let totals = self.totals();
        obj(vec![
            ("traceEvents", arr(events)),
            ("displayTimeUnit", s("ms")),
            ("reproTotals", clock_json(&totals, self.steps.len(), self.violations)),
        ])
    }

    /// Write the Chrome trace-event JSON to `path`.
    pub fn write_chrome(&self, path: &Path, workers: usize) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut text = self.to_chrome(workers).to_string();
        text.push('\n');
        std::fs::write(path, text)?;
        Ok(())
    }

    /// Write the compact per-step JSON-lines form: one `meta` line, one
    /// `step` line per step (flattened delta + per-category span sums), one
    /// `run` footer with totals.
    pub fn write_jsonl(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut out = String::new();
        out.push_str(
            &obj(vec![("type", s("meta")), ("schema", s("repro-trace-jsonl-v1"))]).to_string(),
        );
        out.push('\n');
        for st in &self.steps {
            let mut span_s: Vec<(&str, Json)> = Vec::new();
            for cat in Cat::ALL {
                let sum: f64 = st
                    .spans
                    .iter()
                    .filter(|sp| sp.cat == cat && !sp.kind.is_instant())
                    .map(|sp| sp.t1 - sp.t0)
                    .sum();
                span_s.push((cat.name(), num(sum)));
            }
            let mut by_bucket: BTreeMap<String, f64> = BTreeMap::new();
            let mut retransmits = 0usize;
            for sp in &st.spans {
                match &sp.kind {
                    SpanKind::Pack { bucket, payload_bits } => {
                        let key = match bucket {
                            Some(b) => format!("{b}"),
                            None => "none".to_string(),
                        };
                        *by_bucket.entry(key).or_insert(0.0) += payload_bits;
                    }
                    SpanKind::Retransmit { .. } => retransmits += 1,
                    _ => {}
                }
            }
            let bucket_obj = Json::Obj(
                by_bucket.into_iter().map(|(k, v)| (k, num(v))).collect::<BTreeMap<_, _>>(),
            );
            let mut fields: Vec<(&str, Json)> = vec![
                ("type", s("step")),
                ("step", num(st.step as f64)),
                ("base_s", num(st.base_s)),
                ("spans", num(st.spans.len() as f64)),
            ];
            fields.extend(clock_fields(&st.delta));
            fields.push(("span_s", obj(span_s)));
            fields.push(("payload_bits_by_bucket", bucket_obj));
            fields.push(("retransmits", num(retransmits as f64)));
            fields.push(("violations", num(st.violations.len() as f64)));
            out.push_str(&obj(fields).to_string());
            out.push('\n');
        }
        let totals = self.totals();
        out.push_str(&clock_json_typed("run", &totals, self.steps.len(), self.violations));
        out.push('\n');
        std::fs::write(path, out)?;
        Ok(())
    }
}

fn level_name(level: LinkLevel) -> &'static str {
    match level {
        LinkLevel::Intra => "intra",
        LinkLevel::Inter => "inter",
    }
}

fn clock_fields(c: &SimClock) -> Vec<(&'static str, Json)> {
    vec![
        ("comm_s", num(c.comm_s)),
        ("compute_s", num(c.compute_s)),
        ("encode_s", num(c.encode_s)),
        ("decode_s", num(c.decode_s)),
        ("bits_per_worker", num(c.bits_per_worker)),
        ("hop_bits_per_worker", num(c.hop_bits_per_worker)),
        ("hop_bits_intra", num(c.hop_bits_intra)),
        ("hop_bits_inter", num(c.hop_bits_inter)),
        ("hidden_comm_s", num(c.hidden_comm_s)),
        ("straggler_wait_s", num(c.straggler_wait_s)),
        ("retrans_s", num(c.retrans_s)),
        ("retrans_bits", num(c.retrans_bits)),
    ]
}

fn clock_json(c: &SimClock, steps: usize, violations: usize) -> Json {
    let mut fields = clock_fields(c);
    fields.push(("steps", num(steps as f64)));
    fields.push(("violations", num(violations as f64)));
    obj(fields)
}

fn clock_json_typed(ty: &str, c: &SimClock, steps: usize, violations: usize) -> String {
    let mut fields: Vec<(&str, Json)> = vec![("type", s(ty))];
    fields.extend(clock_fields(c));
    fields.push(("steps", num(steps as f64)));
    fields.push(("violations", num(violations as f64)));
    obj(fields).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fabricated but fully consistent step: compute, encode, a packed
    /// collective (pack instant + two hops), a checksum, a decode.
    fn consistent_step() -> (SimClock, Vec<Span>) {
        let mut d = SimClock::default();
        d.compute_s = 2.0;
        d.encode_s = 0.25;
        d.decode_s = 0.125;
        d.comm_s = 1.0;
        d.bits_per_worker = 4096.0;
        d.hop_bits_per_worker = 6144.0;
        d.hop_bits_intra = 4096.0;
        d.hop_bits_inter = 2048.0;
        let spans = vec![
            Span::new(Cat::Compute, SpanKind::Compute, 0.0, 2.0, 0.0),
            Span::new(Cat::Encode, SpanKind::Encode { bucket: Some(0) }, 0.0, 0.25, 0.0),
            Span::new(
                Cat::Comm,
                SpanKind::Pack { bucket: Some(0), payload_bits: 4096.0 },
                0.0,
                0.0,
                4096.0,
            ),
            Span::new(
                Cat::Comm,
                SpanKind::Hop {
                    schedule: "ring",
                    level: LinkLevel::Intra,
                    hop_idx: 0,
                    wire_bits: 4032.0,
                },
                0.0,
                0.5,
                0.0,
            ),
            Span::new(
                Cat::Comm,
                SpanKind::Hop {
                    schedule: "ring",
                    level: LinkLevel::Inter,
                    hop_idx: 1,
                    wire_bits: 1984.0,
                },
                0.5,
                0.9,
                0.0,
            ),
            Span::new(
                Cat::Comm,
                SpanKind::Checksum { level: LinkLevel::Intra, hop_idx: 0, wire_bits: 64.0 },
                0.9,
                0.95,
                0.0,
            ),
            Span::new(
                Cat::Comm,
                SpanKind::Checksum { level: LinkLevel::Inter, hop_idx: 1, wire_bits: 64.0 },
                0.95,
                1.0,
                0.0,
            ),
            Span::new(Cat::Decode, SpanKind::Decode { bucket: Some(0) }, 0.0, 0.125, 0.0),
        ];
        (d, spans)
    }

    #[test]
    fn audit_passes_on_consistent_step() {
        let (d, spans) = consistent_step();
        let v = LedgerAudit::check(&d, &spans);
        assert!(v.is_empty(), "unexpected violations: {v:#?}");
    }

    #[test]
    fn audit_flags_chain_gap_and_sum_mismatch() {
        let (d, mut spans) = consistent_step();
        // Open a gap in the comm chain.
        spans[4].t0 = 0.6;
        let v = LedgerAudit::check(&d, &spans);
        assert!(
            v.iter().any(|m| m.contains("gap")),
            "gap not flagged: {v:#?}"
        );

        let (mut d, spans) = consistent_step();
        // Ledger says more comm than the spans account for.
        d.comm_s = 1.5;
        let v = LedgerAudit::check(&d, &spans);
        assert!(
            v.iter().any(|m| m.starts_with("comm:")),
            "comm end mismatch not flagged: {v:#?}"
        );
    }

    #[test]
    fn audit_flags_bit_book_mismatches() {
        let (mut d, spans) = consistent_step();
        d.bits_per_worker += 1.0;
        d.hop_bits_intra += 64.0; // also breaks intra+inter==hop sum
        let v = LedgerAudit::check(&d, &spans);
        assert!(v.iter().any(|m| m.contains("bits_per_worker")), "{v:#?}");
        assert!(v.iter().any(|m| m.contains("hop_bits_intra")), "{v:#?}");
        assert!(
            v.iter().any(|m| m.starts_with("ledger: hop_bits_intra")),
            "{v:#?}"
        );
    }

    #[test]
    fn audit_flags_uncharged_category() {
        let (mut d, spans) = consistent_step();
        d.retrans_s = 0.5;
        let v = LedgerAudit::check(&d, &spans);
        assert!(
            v.iter().any(|m| m.starts_with("retrans:")),
            "uncharged retrans not flagged: {v:#?}"
        );
    }

    #[test]
    fn audit_flags_hidden_exceeding_comm() {
        let (mut d, mut spans) = consistent_step();
        d.hidden_comm_s = 2.0;
        // Keep the hidden-comm chain consistent so only the ledger invariant fires.
        spans.push(Span::new(
            Cat::HiddenComm,
            SpanKind::Overlap { hidden_s: 2.0, exposed_s: 0.0 },
            0.0,
            2.0,
            0.0,
        ));
        let v = LedgerAudit::check(&d, &spans);
        assert!(
            v.iter().any(|m| m.contains("hidden_comm_s") && m.contains("> comm_s")),
            "{v:#?}"
        );
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "ledger audit failed")]
    fn end_step_is_loud_in_debug() {
        let (mut d, spans) = consistent_step();
        d.comm_s += 1.0;
        let mut t = Tracer::new();
        t.begin_step(0, 0.0);
        for sp in spans {
            t.push(sp);
        }
        t.end_step(&d);
    }

    #[test]
    fn chrome_export_parses_and_is_monotone_per_track() {
        let mut t = Tracer::new();
        let (d, spans) = consistent_step();
        let mut base = 0.0;
        for step in 0..3 {
            t.begin_step(step, base);
            for sp in spans.clone() {
                t.push(sp);
            }
            t.end_step(&d);
            base += d.total_s();
        }
        assert_eq!(t.violation_count(), 0);
        let text = t.to_chrome(4).to_string();
        let parsed = Json::parse(&text).expect("chrome JSON must parse");
        let events = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        let mut last_end: BTreeMap<(usize, usize), f64> = BTreeMap::new();
        let mut workers_seen = std::collections::BTreeSet::new();
        for e in events {
            let ph = e.req("ph").unwrap().as_str().unwrap();
            if ph != "X" {
                continue;
            }
            let pid = e.req("pid").unwrap().as_usize().unwrap();
            let tid = e.req("tid").unwrap().as_usize().unwrap();
            if pid == 0 {
                workers_seen.insert(tid);
            }
            let ts = e.req("ts").unwrap().as_f64().unwrap();
            let dur = e.req("dur").unwrap().as_f64().unwrap();
            let prev = last_end.get(&(pid, tid)).copied().unwrap_or(f64::NEG_INFINITY);
            assert!(
                ts + 1e-6 >= prev,
                "track ({pid},{tid}): event at {ts} overlaps previous end {prev}"
            );
            last_end.insert((pid, tid), ts + dur);
        }
        assert_eq!(workers_seen.len(), 4, "one track per worker");
        let totals = parsed.req("reproTotals").unwrap();
        assert_eq!(totals.req("steps").unwrap().as_usize().unwrap(), 3);
        assert_eq!(totals.req("violations").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn jsonl_export_roundtrips() {
        let dir = std::env::temp_dir().join("repro_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("step.trace.jsonl");
        let mut t = Tracer::new();
        let (d, spans) = consistent_step();
        t.begin_step(0, 0.0);
        for sp in spans {
            t.push(sp);
        }
        t.end_step(&d);
        t.write_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "meta + 1 step + run footer");
        let meta = Json::parse(lines[0]).unwrap();
        assert_eq!(meta.req("type").unwrap().as_str().unwrap(), "meta");
        let step = Json::parse(lines[1]).unwrap();
        assert_eq!(step.req("type").unwrap().as_str().unwrap(), "step");
        assert_eq!(step.req("comm_s").unwrap().as_f64().unwrap(), d.comm_s);
        let run = Json::parse(lines[2]).unwrap();
        assert_eq!(run.req("type").unwrap().as_str().unwrap(), "run");
        assert_eq!(
            run.req("bits_per_worker").unwrap().as_f64().unwrap(),
            d.bits_per_worker
        );
        std::fs::remove_file(&path).ok();
    }
}
