//! The simulated data-parallel cluster: M logical workers, one coordinator.
//!
//! Per step (Algorithm 1/2 shape):
//! 1. **compute** — all workers' forward/backward in ONE PJRT call: the L2
//!    step function is vmapped over the worker axis, so XLA parallelizes
//!    the per-worker compute internally (DESIGN.md §2);
//! 2. **aggregate** — the configured [`Aggregator`] compresses per-worker
//!    gradient slices and runs its collective protocol through [`StepCtx`],
//!    charging the simulated wire;
//! 3. **update** — shared SGD step on the replicated parameters.
//!
//! Every source of randomness derives from (run seed, step, purpose), so a
//! run is exactly reproducible.

use anyhow::{bail, Context, Result};

use crate::collectives::{IntegrityConfig, StepCtx};
use crate::compress::{Aggregator, Method};
use crate::control::{
    self, guard, AnomalyPolicy, CohortPolicy, ControlConfig, ElasticCohort, ElasticConfig,
};
use crate::data::{CifarLike, MarkovCorpus};
use crate::metrics::StepRecord;
use crate::netsim::{NetConfig, SimClock};
use crate::optim::{LrSchedule, Sgd};
use crate::runtime::{Artifacts, EvalFn, ModelArtifacts, Runtime, StepFn};
use crate::util::rng::Rng;

/// Configuration for one training run.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub model: String,
    pub workers: usize,
    pub method: Method,
    pub seed: u64,
    pub lr0: f64,
    pub total_steps: usize,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Ethernet bandwidth for the simulated wire (Gbps)
    pub net_gbps: f64,
    /// GPUs per NVLink island (CLI `--topology NxG`): the simulated wire's
    /// island structure. 1 (the default) is the flat single-GPU-per-node
    /// topology of PRs 1-7 — bit-identical charges everywhere.
    pub gpus_per_node: usize,
    /// hierarchical two-level packed schedule (CLI `--schedule hier`):
    /// full-width island all-reduce over NVLink, compressed leader ring
    /// across nodes (PR 8). Payload is bit-identical to the flat schedule;
    /// only timing and the per-level wire ledgers differ. No effect unless
    /// the topology genuinely spans >1 island of >1 GPU.
    pub hier_schedule: bool,
    /// simulate the paper's >=8-bit tensor constraint
    pub wire_floor_bits: Option<f64>,
    /// per-GPU compute time override for the sim clock (s/step); when None,
    /// measured PJRT wall time is used
    pub sim_compute_s: Option<f64>,
    /// bucketed gradient control plane (CLI `--buckets`/`--bits`/
    /// `--error-feedback`); `None` runs the monolithic aggregator
    pub control: Option<ControlConfig>,
    /// elastic-cohort policy + fault schedule (CLI `--faults`/
    /// `--cohort-policy`/`--quorum`); `None` runs the fixed synchronous
    /// cohort of PRs 1-5. Requires the control plane (the monolithic
    /// aggregators are not cohort-aware).
    pub elastic: Option<ElasticConfig>,
    /// hop-segment integrity on the packed plane (CLI `--integrity`/
    /// `--retries`/`--backoff-s`): checksum every hop segment, retransmit
    /// corrupted/lost hops with bounded backoff, and escalate peers that
    /// exhaust their retries into the elastic partial-cohort path. `None`
    /// trusts the wire — every pre-PR 7 path stays bit-identical.
    pub integrity: Option<IntegrityConfig>,
    /// what a non-finite local gradient does to the step (CLI
    /// `--on-anomaly skip|clip:C|abort`); the pre-encode scan itself runs
    /// on every step and is a pure read on clean cohorts
    pub on_anomaly: AnomalyPolicy,
    /// step flight recorder output (CLI `--trace PATH`, PR 9): `Some` arms
    /// a [`crate::trace::Tracer`] over every step and writes the trace when
    /// the run finishes — `.jsonl` extension selects the compact per-step
    /// JSON-lines form, anything else the Chrome trace-event JSON. `None`
    /// (the default) records nothing and every charge path stays
    /// bit-identical to the untraced plane.
    pub trace: Option<std::path::PathBuf>,
}

impl ClusterConfig {
    pub fn new(model: &str, workers: usize, method: Method) -> ClusterConfig {
        ClusterConfig {
            model: model.to_string(),
            workers,
            method,
            seed: 42,
            lr0: 0.05,
            total_steps: 200,
            momentum: 0.9,
            weight_decay: 5e-4,
            net_gbps: 10.0,
            gpus_per_node: 1,
            hier_schedule: false,
            wire_floor_bits: None,
            sim_compute_s: None,
            control: None,
            elastic: None,
            integrity: None,
            on_anomaly: AnomalyPolicy::Skip,
            trace: None,
        }
    }
}

enum Dataset {
    Images(CifarLike),
    Tokens(MarkovCorpus),
}

/// A live training cluster.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub params: Vec<f32>,
    pub clock: SimClock,
    rt: Runtime,
    step_fn: StepFn,
    eval_fn: EvalFn,
    agg: Box<dyn Aggregator>,
    opt: Sgd,
    sched: LrSchedule,
    net: NetConfig,
    data: Dataset,
    model_meta: ModelArtifacts,
    seq_len: usize,
    root_rng: Rng,
    /// elastic membership/staleness state (None = fixed synchronous cohort)
    elastic: Option<ElasticCohort>,
    /// step flight recorder (None = untraced, the zero-cost default)
    tracer: Option<crate::trace::Tracer>,
    /// scratch for eval batches
    eval_cache: Option<EvalBatch>,
}

struct EvalBatch {
    x_f32: Vec<f32>,
    x_i32: Vec<i32>,
    y_i32: Vec<i32>,
}

impl Cluster {
    pub fn new(arts: &Artifacts, cfg: ClusterConfig) -> Result<Cluster> {
        let rt = Runtime::new()?;
        let model = arts.model(&cfg.model)?.clone();
        let step_fn = StepFn::load(&rt, arts, &model, cfg.workers)?;
        let eval_fn = EvalFn::load(&rt, arts, &model)?;
        let params = arts.load_params(&model)?;
        let agg: Box<dyn Aggregator> = match &cfg.control {
            Some(cc) => Box::new(control::build_plane(
                &cfg.method,
                cc,
                model.param_count,
                &model.segments,
            )?),
            None => cfg.method.build(model.param_count, &model.segments)?,
        };
        let elastic = match &cfg.elastic {
            Some(ec) => {
                if cfg.control.is_none() {
                    bail!(
                        "--cohort-policy/--faults need the bucketed control plane \
                         (pass --buckets N; the monolithic aggregators are not \
                         cohort-aware)"
                    );
                }
                // error-feedback residual memory is positional: it is only
                // sound while the cohort is full and stable
                if cfg.control.as_ref().is_some_and(|cc| cc.error_feedback)
                    && (ec.policy != CohortPolicy::StrictSync || !ec.faults.events.is_empty())
                {
                    bail!(
                        "error feedback needs a stable full cohort: use \
                         --cohort-policy strict and a fault plan without \
                         join/leave events"
                    );
                }
                Some(ElasticCohort::new(ec.clone(), cfg.workers)?)
            }
            None => None,
        };
        let opt = Sgd::new(model.param_count, cfg.momentum, cfg.weight_decay);
        let sched = LrSchedule::paper(cfg.lr0, cfg.total_steps);
        let mut net = NetConfig::flat(cfg.workers, cfg.net_gbps);
        // the island structure rides on the net: every clone the elastic
        // path takes (net_for_step) carries it, so a leaving worker shrinks
        // its island — the leader ring only loses a node when one empties
        net.gpus_per_node = cfg.gpus_per_node.max(1);

        let (data, seq_len) = match model.input_kind.as_str() {
            "image" => (Dataset::Images(CifarLike::new(cfg.seed ^ 0xDA7A)), 0),
            "tokens" => {
                let vocab = model.cfg.req("vocab")?.as_usize()?;
                let seq = model.cfg.req("seq")?.as_usize()?;
                (Dataset::Tokens(MarkovCorpus::new(cfg.seed ^ 0xDA7A, vocab, 8)), seq + 1)
            }
            other => bail!("unknown input kind '{other}'"),
        };

        let root_rng = Rng::new(cfg.seed);
        let tracer = cfg.trace.is_some().then(crate::trace::Tracer::new);
        Ok(Cluster {
            cfg,
            params,
            clock: SimClock::default(),
            rt,
            step_fn,
            eval_fn,
            agg,
            opt,
            sched,
            net,
            data,
            model_meta: model,
            seq_len,
            root_rng,
            elastic,
            tracer,
            eval_cache: None,
        })
    }

    pub fn param_count(&self) -> usize {
        self.model_meta.param_count
    }

    pub fn aggregator_name(&self) -> String {
        self.agg.name()
    }

    /// Execute one training step; returns the step record.
    pub fn train_step(&mut self, step: usize) -> Result<StepRecord> {
        let m = self.cfg.workers;
        let batch = self.step_fn.spec.batch;
        let p = self.param_count();

        // ---- data for all workers
        let (x_f32, x_i32, y_i32) = match &self.data {
            Dataset::Images(d) => {
                let dim = d.dim();
                let mut xs = Vec::with_capacity(m * batch * dim);
                let mut ys = Vec::with_capacity(m * batch);
                for w in 0..m {
                    let (x, y) = d.train_batch(m, w, step as u64, batch);
                    xs.extend_from_slice(&x);
                    ys.extend_from_slice(&y);
                }
                (Some(xs), None, Some(ys))
            }
            Dataset::Tokens(c) => {
                let mut toks = Vec::with_capacity(m * batch * self.seq_len);
                for w in 0..m {
                    toks.extend(c.train_batch(m, w, step as u64, batch, self.seq_len));
                }
                (None, Some(toks), None)
            }
        };

        // ---- 1. compute (single vmapped PJRT call)
        let t0 = std::time::Instant::now();
        let mut out = self.step_fn.run(
            &self.rt,
            &self.params,
            x_f32.as_deref(),
            x_i32.as_deref(),
            y_i32.as_deref(),
        )?;
        let wall_compute = t0.elapsed().as_secs_f64();
        // simulated per-step compute: explicit profile or measured wall / 1
        // (the vmapped call computes all M workers; per-worker parallel time
        // is wall/M only if cores were dedicated — we charge the configured
        // profile when provided, else the measured wall time as-is).
        let sim_compute = self.cfg.sim_compute_s.unwrap_or(wall_compute);
        if let Some(t) = self.tracer.as_mut() {
            t.begin_step(step, self.clock.total_s());
            t.push(crate::trace::Span::new(
                crate::trace::Cat::Compute,
                crate::trace::SpanKind::Compute,
                0.0,
                sim_compute,
                0.0,
            ));
        }
        self.clock.compute_s += sim_compute;

        // ---- 1b. deterministic gradient poison (`--faults poison=W@S`):
        // applied to the raw local gradients before the pre-encode scan,
        // exactly where a real fp16 overflow or DMA corruption would land
        if let Some(ec) = &self.cfg.elastic {
            for w in 0..m {
                if ec.faults.poisoned(step, w) && p > 0 {
                    let g = &mut out.grads[w * p..(w + 1) * p];
                    g[0] = f32::NAN;
                    if p > 1 {
                        g[p / 2] = f32::INFINITY;
                    }
                }
            }
        }

        // ---- 1c. pre-encode anomaly guard: a clean scan is a pure read
        // (bit-identical on every existing path); a dirty one is gated by
        // --on-anomaly before a single level is drawn or bit charged.
        {
            let view: Vec<&[f32]> = (0..m).map(|w| &out.grads[w * p..(w + 1) * p]).collect();
            if let Some(hit) = guard::scan(&view) {
                match self.cfg.on_anomaly {
                    AnomalyPolicy::Abort => bail!(
                        "non-finite gradient at step {step}: worker {} index {} = {}",
                        hit.worker,
                        hit.index,
                        hit.value
                    ),
                    AnomalyPolicy::Skip => {
                        // drop the whole round: compute happened (and stays
                        // charged), but nothing reaches the encoder, the
                        // wire, or the optimizer, and the elastic cohort is
                        // not planned — the step simply never synchronized
                        let loss =
                            out.losses.iter().map(|l| *l as f64).sum::<f64>() / m as f64;
                        if let Some(t) = self.tracer.as_mut() {
                            t.push(crate::trace::Span::new(
                                crate::trace::Cat::Compute,
                                crate::trace::SpanKind::GuardSkip,
                                sim_compute,
                                sim_compute,
                                0.0,
                            ));
                            let delta =
                                SimClock { compute_s: sim_compute, ..SimClock::default() };
                            t.end_step(&delta);
                        }
                        return Ok(StepRecord {
                            step,
                            loss,
                            lr: self.sched.at(step),
                            t_compute: sim_compute,
                            t_encode: 0.0,
                            t_decode: 0.0,
                            t_comm_sim: 0.0,
                            bits_per_worker: 0.0,
                            overlap_frac: 0.0,
                            live_workers: m,
                            straggler_wait_s: 0.0,
                            staleness: 0,
                            retrans_bits: 0.0,
                            retrans_s: 0.0,
                            skipped: true,
                        });
                    }
                    AnomalyPolicy::Clip(c) => {
                        // sanitize ONLY the offending workers: clean peers'
                        // gradients must stay bit-identical
                        for w in 0..m {
                            let g = &mut out.grads[w * p..(w + 1) * p];
                            if g.iter().any(|x| !x.is_finite()) {
                                guard::sanitize_clip(g, c);
                            }
                        }
                    }
                }
            }
        }

        // ---- 2. aggregate
        let grads: Vec<&[f32]> = (0..m).map(|w| &out.grads[w * p..(w + 1) * p]).collect();
        let mut step_clock = SimClock::default();
        let mut step_rng = self.root_rng.derive(&[0x5354, step as u64]);
        let (agg_grad, live_workers, staleness, straggler_wait_s) = match self.elastic.as_mut()
        {
            None => {
                let mut ctx = StepCtx::new(&self.net, &mut step_clock);
                ctx.wire_floor_bits = self.cfg.wire_floor_bits;
                ctx.hier = self.cfg.hier_schedule;
                // checksum accounting works on the fixed cohort too; with
                // no fault plan there is nothing to retransmit
                ctx.integrity = self.cfg.integrity;
                // the backward window of this step — the compute the
                // bucketed control plane's overlap scheduler may hide
                // communication behind
                ctx.backward_s = Some(sim_compute * crate::perfmodel::BACKWARD_FRAC);
                ctx.tracer = self.tracer.as_mut();
                (Some(self.agg.aggregate(&grads, &mut ctx, &mut step_rng)), m, 0, 0.0)
            }
            Some(cohort) => {
                // the policy resolves membership events, times the cohort
                // under the fault plan, and decides who synchronizes; the
                // wire re-derives for the live cohort (ring/tree hops and
                // the packed resident width follow net.workers)
                let mut plan = cohort.plan_step(step, sim_compute);
                let faults = cohort.faults().clone();
                // PR 7 escalation: a peer whose hop deliveries exhaust every
                // integrity retry is unreachable THIS step. Decide that now,
                // from the same pure draws the charging walk replays, drop
                // the peer into the PR 6 partial-cohort path (live-M
                // renormalization for free), and charge the full detection
                // ladder — R+1 sends' worth of backoff — per dead peer.
                let mut escalation_s = 0.0;
                if let Some(icfg) = self.cfg.integrity {
                    if plan.sync && (faults.loss > 0.0 || faults.flip > 0.0) {
                        // the live cohort's schedule shape decides how many
                        // hop deliveries a peer owes (topology-aware: the
                        // hier schedule has a different hop count)
                        let hops = crate::collectives::packed::schedule_for_topo(
                            self.net.algo,
                            false,
                            1,
                            self.cfg.hier_schedule,
                            self.net.gpus_per_node,
                            plan.live.len().max(1),
                        )
                        .as_dyn()
                        .hops(plan.live.len().max(1));
                        let dead = faults.unreachable_peers(
                            step,
                            &plan.live,
                            hops,
                            icfg.max_retries,
                        );
                        if !dead.is_empty() {
                            cohort.drop_unreachable(&mut plan, &dead);
                            escalation_s += dead.len() as f64
                                * icfg.backoff_base_s
                                * (2f64.powi(icfg.max_retries as i32 + 1) - 1.0);
                        }
                    }
                }
                let live_m = plan.live.len();
                let step_net = faults.net_for_step(&self.net, step, live_m.max(1));
                let mut ctx = StepCtx::new(&step_net, &mut step_clock);
                ctx.wire_floor_bits = self.cfg.wire_floor_bits;
                ctx.hier = self.cfg.hier_schedule;
                ctx.integrity = self.cfg.integrity;
                ctx.wire_faults = Some((&faults, step));
                ctx.tracer = self.tracer.as_mut();
                // the += stays unconditional (bit-identical to the untraced
                // plane); only the span is gated on a real charge
                let r0 = ctx.clock.retrans_s;
                ctx.clock.retrans_s += escalation_s;
                if escalation_s > 0.0 {
                    if let Some(t) = ctx.tracer.as_deref_mut() {
                        t.push(crate::trace::Span::new(
                            crate::trace::Cat::Retrans,
                            crate::trace::SpanKind::Escalation,
                            r0,
                            ctx.clock.retrans_s,
                            0.0,
                        ));
                    }
                }
                if !plan.rejoined.is_empty() {
                    // one tree broadcast of the fp32 parameters serves
                    // every rejoiner; time-only — the bits ledgers stay
                    // gradient-payload accounting
                    let cu0 = ctx.clock.comm_s;
                    ctx.clock.comm_s += cohort.catch_up_s(&step_net, p);
                    if let Some(t) = ctx.tracer.as_deref_mut() {
                        t.push(crate::trace::Span::new(
                            crate::trace::Cat::Comm,
                            crate::trace::SpanKind::CatchUp,
                            cu0,
                            ctx.clock.comm_s,
                            0.0,
                        ));
                    }
                }
                let agg_grad = if plan.sync {
                    // the overlap scheduler's cover is the SURVIVING
                    // cohort's backward window — a dropped straggler's
                    // compute is not schedulable cover (satellite-1 fix)
                    ctx.backward_s =
                        Some(plan.compute_window_s * crate::perfmodel::BACKWARD_FRAC);
                    let full = live_m == m;
                    match cohort.contributions(&plan, &grads) {
                        Some(slices) => Some(self.agg.aggregate_cohort(
                            &slices,
                            &plan.live,
                            &mut ctx,
                            &mut step_rng,
                        )),
                        None if full => {
                            // full identity cohort, nothing pending: the
                            // pre-elastic call, bit for bit
                            Some(self.agg.aggregate(&grads, &mut ctx, &mut step_rng))
                        }
                        None => {
                            let slices: Vec<&[f32]> =
                                plan.live.iter().map(|&w| grads[w]).collect();
                            Some(self.agg.aggregate_cohort(
                                &slices,
                                &plan.live,
                                &mut ctx,
                                &mut step_rng,
                            ))
                        }
                    }
                } else {
                    cohort.accumulate(&plan, &grads);
                    None
                };
                let staleness = cohort.commit(&plan);
                (agg_grad, live_m, staleness, plan.straggler_wait_s)
            }
        };
        self.clock.straggler_wait_s += straggler_wait_s;

        // ---- 3. update (skipped on non-synchronizing elastic steps: those
        // gradients are accumulating locally toward the next sync)
        let lr = self.sched.at(step);
        if let Some(agg_grad) = &agg_grad {
            self.opt.step(&mut self.params, agg_grad, lr as f32);
        }

        // ---- close the flight-recorder step against the audited delta:
        // compute and straggler wait were charged on the run clock directly,
        // so the step delta is the step ctx's clock plus those two fields.
        if let Some(t) = self.tracer.as_mut() {
            if straggler_wait_s > 0.0 {
                t.push(crate::trace::Span::new(
                    crate::trace::Cat::StragglerWait,
                    crate::trace::SpanKind::StragglerWait,
                    0.0,
                    straggler_wait_s,
                    0.0,
                ));
            }
            let mut delta = step_clock.clone();
            delta.compute_s = sim_compute;
            delta.straggler_wait_s = straggler_wait_s;
            t.end_step(&delta);
        }
        // step_clock.compute_s / straggler_wait_s are always 0 here (both
        // charged on the run clock above), so the field-wise accumulate is
        // bit-identical to the per-field adds it replaces.
        self.clock.accumulate(&step_clock);

        let loss = out.losses.iter().map(|l| *l as f64).sum::<f64>() / m as f64;
        Ok(StepRecord {
            step,
            loss,
            lr,
            t_compute: sim_compute,
            t_encode: step_clock.encode_s,
            t_decode: step_clock.decode_s,
            t_comm_sim: step_clock.comm_s,
            bits_per_worker: step_clock.bits_per_worker,
            overlap_frac: step_clock.overlap_frac(),
            live_workers,
            straggler_wait_s,
            staleness,
            retrans_bits: step_clock.retrans_bits,
            retrans_s: step_clock.retrans_s,
            skipped: false,
        })
    }

    /// Evaluate on the fixed held-out batch: (loss, accuracy in [0,1]).
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let spec = &self.eval_fn.spec;
        if self.eval_cache.is_none() {
            let cache = match &self.data {
                Dataset::Images(d) => {
                    let (x, y) = d.eval_batch(spec.batch);
                    EvalBatch { x_f32: x, x_i32: Vec::new(), y_i32: y }
                }
                Dataset::Tokens(c) => EvalBatch {
                    x_f32: Vec::new(),
                    x_i32: c.eval_batch(spec.batch, self.seq_len),
                    y_i32: Vec::new(),
                },
            };
            self.eval_cache = Some(cache);
        }
        let cache = self.eval_cache.as_ref().unwrap();
        let (loss, correct) = self.eval_fn.run(
            &self.rt,
            &self.params,
            if cache.x_f32.is_empty() { None } else { Some(&cache.x_f32) },
            if cache.x_i32.is_empty() { None } else { Some(&cache.x_i32) },
            if cache.y_i32.is_empty() { None } else { Some(&cache.y_i32) },
        )?;
        let acc = correct as f64 / self.eval_fn.spec.batch as f64;
        Ok((loss as f64, acc))
    }

    /// PJRT compute-time stats from the runtime (perf accounting).
    pub fn exec_stats(&self) -> (f64, u64) {
        self.rt.exec_stats()
    }

    /// The flight recorder, when armed (`cfg.trace`).
    pub fn tracer(&self) -> Option<&crate::trace::Tracer> {
        self.tracer.as_ref()
    }

    /// Write the recorded trace to `path`: `.jsonl` selects the compact
    /// per-step JSON-lines form, anything else the Chrome trace-event JSON
    /// (`chrome://tracing` / Perfetto). Errors if the run was untraced.
    pub fn write_trace(&self, path: &std::path::Path) -> Result<()> {
        let Some(t) = self.tracer.as_ref() else {
            bail!("no trace recorded: the cluster was built without cfg.trace");
        };
        if path.extension().and_then(|e| e.to_str()) == Some("jsonl") {
            t.write_jsonl(path)
        } else {
            t.write_chrome(path, self.cfg.workers)
        }
    }
}

/// Convenience: load artifacts once and run a full configured training run,
/// returning the per-step records and final eval.
pub fn run_training(
    arts: &Artifacts,
    cfg: ClusterConfig,
    mut on_step: impl FnMut(&StepRecord),
) -> Result<(Vec<StepRecord>, crate::metrics::RunSummary)> {
    let label_method = cfg.method.label();
    let total = cfg.total_steps;
    let model = cfg.model.clone();
    let workers = cfg.workers;
    let mut cluster = Cluster::new(arts, cfg).context("building cluster")?;
    let wall = std::time::Instant::now();
    let mut records = Vec::with_capacity(total);
    for step in 0..total {
        let rec = cluster.train_step(step)?;
        on_step(&rec);
        records.push(rec);
    }
    let (eval_loss, eval_acc) = cluster.evaluate()?;
    if let Some(path) = cluster.cfg.trace.clone() {
        cluster.write_trace(&path).context("writing trace")?;
    }
    let clock = cluster.clock.clone();
    let summary = crate::metrics::RunSummary {
        label: label_method,
        model,
        workers,
        steps: total,
        final_loss: records.last().map(|r| r.loss).unwrap_or(f64::NAN),
        final_eval_loss: eval_loss,
        final_eval_acc: eval_acc,
        mean_bits_per_step: clock.bits_per_worker / total.max(1) as f64,
        overlap_frac: clock.overlap_frac(),
        sim_time_s: clock.total_s(),
        wall_time_s: wall.elapsed().as_secs_f64(),
        t_compute: clock.compute_s,
        t_encode: clock.encode_s,
        t_decode: clock.decode_s,
        t_comm_sim: clock.comm_s,
        t_straggler_wait: clock.straggler_wait_s,
        t_retrans: clock.retrans_s,
        retrans_bits: clock.retrans_bits,
        skipped_steps: records.iter().filter(|r| r.skipped).count(),
    };
    Ok((records, summary))
}
