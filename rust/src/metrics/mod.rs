//! Run metrics: CSV series + JSON run summaries.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

/// Append-oriented CSV writer with a fixed header.
pub struct CsvWriter {
    file: std::fs::File,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file =
            std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        anyhow::ensure!(values.len() == self.cols, "row arity mismatch");
        let line = values
            .iter()
            .map(|v| {
                if v.fract() == 0.0 {
                    // integral values print as exact integers: i64 text for
                    // the common range, `{:.0}` (exact for any f64) beyond
                    // it — long-run cumulative bit counters pass 1e15 and
                    // must not fall into the rounded `{:.6}` branch.
                    // (inf/NaN have NaN fract(), so they keep `{:.6}`.)
                    if v.abs() < 1e15 {
                        format!("{}", *v as i64)
                    } else {
                        format!("{v:.0}")
                    }
                } else {
                    format!("{v:.6}")
                }
            })
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.file, "{line}")?;
        Ok(())
    }
}

/// One training step's record.
#[derive(Clone, Debug, Default)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
    /// wall-clock seconds in PJRT compute
    pub t_compute: f64,
    pub t_encode: f64,
    pub t_decode: f64,
    /// *simulated* communication seconds (netsim)
    pub t_comm_sim: f64,
    pub bits_per_worker: f64,
    /// fraction of `t_comm_sim` the bucketed control plane hid behind
    /// backward compute (0 on the monolithic path)
    pub overlap_frac: f64,
    /// workers participating in this step's collective (the full M on the
    /// fixed synchronous path and on non-sync elastic steps, where it is
    /// the membership computing locally)
    pub live_workers: usize,
    /// simulated seconds the synchronizing cohort waited on coordination
    /// beyond the profile compute time (0 off the elastic path)
    pub straggler_wait_s: f64,
    /// age of the oldest gradient folded into this step's update (0 on
    /// the fixed synchronous path; bounded by period-1 under periodic
    /// sync)
    pub staleness: usize,
    /// cohort-total wire bits retransmitted this step after checksum
    /// mismatches / losses (0 with integrity off or a clean wire)
    pub retrans_bits: f64,
    /// simulated recovery seconds this step: exponential backoff plus the
    /// retransmitted hop time, plus the detection-timeout ladder for peers
    /// that exhausted every retry
    pub retrans_s: f64,
    /// true iff the pre-encode anomaly guard dropped this step under
    /// `--on-anomaly skip` — compute is charged, nothing reached the wire
    pub skipped: bool,
}

/// Whole-run summary, serializable for EXPERIMENTS.md extraction.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub label: String,
    pub model: String,
    pub workers: usize,
    pub steps: usize,
    pub final_loss: f64,
    pub final_eval_loss: f64,
    pub final_eval_acc: f64,
    pub mean_bits_per_step: f64,
    /// run-level fraction of simulated comm hidden behind compute
    pub overlap_frac: f64,
    pub sim_time_s: f64,
    pub wall_time_s: f64,
    pub t_compute: f64,
    pub t_encode: f64,
    pub t_decode: f64,
    pub t_comm_sim: f64,
    /// run-level simulated straggler wait (0 off the elastic path)
    pub t_straggler_wait: f64,
    /// run-level simulated recovery time (backoff + retransmitted hops +
    /// detection ladders; 0 with integrity off)
    pub t_retrans: f64,
    /// run-level cohort-total retransmitted wire bits
    pub retrans_bits: f64,
    /// steps dropped by the anomaly guard under `--on-anomaly skip`
    pub skipped_steps: usize,
}

impl RunSummary {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("label", s(&self.label)),
            ("model", s(&self.model)),
            ("workers", num(self.workers as f64)),
            ("steps", num(self.steps as f64)),
            ("final_loss", num(self.final_loss)),
            ("final_eval_loss", num(self.final_eval_loss)),
            ("final_eval_acc", num(self.final_eval_acc)),
            ("mean_bits_per_step", num(self.mean_bits_per_step)),
            ("overlap_frac", num(self.overlap_frac)),
            ("retrans_bits", num(self.retrans_bits)),
            ("skipped_steps", num(self.skipped_steps as f64)),
            ("sim_time_s", num(self.sim_time_s)),
            ("wall_time_s", num(self.wall_time_s)),
            (
                "time_breakdown",
                obj(vec![
                    ("compute", num(self.t_compute)),
                    ("encode", num(self.t_encode)),
                    ("decode", num(self.t_decode)),
                    ("comm_sim", num(self.t_comm_sim)),
                    ("straggler_wait", num(self.t_straggler_wait)),
                    ("retrans", num(self.t_retrans)),
                ]),
            ),
        ])
    }
}

/// Write a list of summaries as a JSON report.
pub fn write_report(path: &Path, summaries: &[RunSummary]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let j = arr(summaries.iter().map(|r| r.to_json()).collect());
    std::fs::write(path, j.to_string())?;
    Ok(())
}

/// Render an aligned plain-text table (for bench/figure stdout).
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = header.iter().map(|h| h.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("repro_metrics_test");
        let path = dir.join("x.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&[1.0, 2.5]).unwrap();
        w.row(&[3.0, 4.0]).unwrap();
        assert!(w.row(&[1.0]).is_err());
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,2.500000\n"));
    }

    #[test]
    fn csv_big_integral_counters_format_exactly() {
        let dir = std::env::temp_dir().join("repro_metrics_test");
        let path = dir.join("big.csv");
        let mut w = CsvWriter::create(&path, &["bits", "edge", "frac"]).unwrap();
        // 2^53: exactly representable, above the old 1e15 i64-text cutoff —
        // the regression printed 9007199254740992.000000-style rounded text.
        w.row(&[9_007_199_254_740_992.0, 1e15, 2.5]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text.lines().nth(1).unwrap(),
            "9007199254740992,1000000000000000,2.500000"
        );
    }

    #[test]
    fn summary_json_parses_back() {
        let r = RunSummary {
            label: "QSGD-MN-8".into(),
            steps: 10,
            retrans_bits: 512.0,
            skipped_steps: 2,
            t_retrans: 0.25,
            ..Default::default()
        };
        let j = r.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.req("label").unwrap().as_str().unwrap(), "QSGD-MN-8");
        assert_eq!(parsed.req("steps").unwrap().as_usize().unwrap(), 10);
        assert_eq!(parsed.req("retrans_bits").unwrap().as_usize().unwrap(), 512);
        assert_eq!(parsed.req("skipped_steps").unwrap().as_usize().unwrap(), 2);
        let tb = parsed.req("time_breakdown").unwrap();
        assert!(tb.req("retrans").is_ok());
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["name", "val"],
            &[vec!["x".into(), "1".into()], vec!["long-name".into(), "2".into()]],
        );
        assert!(t.contains("long-name"));
        assert_eq!(t.lines().count(), 4);
    }
}
