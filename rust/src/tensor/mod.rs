//! Flat f32 vector math used across the coordinator hot path.
//!
//! Parameters, gradients and compressed payload buffers all live as flat
//! `Vec<f32>` (DESIGN.md: the L2 step functions take/return the same flat
//! layout). These kernels are written to autovectorize; the perf pass
//! (EXPERIMENTS.md §Perf) confirms they run at memory bandwidth.

/// Widened integer level buffers for the compressed-domain hot path.
///
/// Quantizer levels are exact small integers; carrying them as `f32` (the
/// pre-integer-domain pipeline) moves 32 bits per coordinate through memory
/// for a nominally 2–16-bit wire format. A `LevelInt` buffer is the widened
/// accumulator the all-reduce sums into: the width is chosen so that
/// `workers * s` cannot overflow (`DESIGN.md` §Performance, the widening
/// rule `bits × workers → accumulator width`). `i16` halves the memory
/// traffic of the old `f32` path; `i32` is the fallback for extreme
/// `bits × workers` products.
pub trait LevelInt:
    Copy
    + Default
    + Send
    + Sync
    + PartialEq
    + std::fmt::Debug
    + std::ops::AddAssign
    + 'static
{
    /// Largest magnitude the accumulator can hold.
    const MAX_MAG: i64;
    /// Short type tag for bench/report labels ("i16", "i32", ...).
    const TAG: &'static str;

    /// Cast an exact-integer f32 quantizer level. Debug-asserts the value
    /// is integral and in range — quantizer level bounds guarantee it.
    fn from_level(level: f32) -> Self;
    fn to_f32(self) -> f32;
    fn to_i64(self) -> i64;
}

macro_rules! impl_level_int {
    ($t:ty, $tag:literal) => {
        impl LevelInt for $t {
            const MAX_MAG: i64 = <$t>::MAX as i64;
            const TAG: &'static str = $tag;

            #[inline(always)]
            fn from_level(level: f32) -> Self {
                debug_assert_eq!(level.fract(), 0.0, "non-integer level {level}");
                debug_assert!(
                    (level.abs() as i64) <= Self::MAX_MAG,
                    "level {level} overflows {}",
                    Self::TAG
                );
                level as $t
            }

            #[inline(always)]
            fn to_f32(self) -> f32 {
                self as f32
            }

            #[inline(always)]
            fn to_i64(self) -> i64 {
                self as i64
            }
        }
    };
}

impl_level_int!(i8, "i8");
impl_level_int!(i16, "i16");
impl_level_int!(i32, "i32");

/// The widening rule, reusable anywhere a buffer width is chosen: can an
/// all-reduce over `workers` buffers of levels bounded by `s` accumulate in
/// `T` without overflow?
pub fn sum_fits<T: LevelInt>(s: usize, workers: usize) -> bool {
    (workers as i64).saturating_mul(s as i64) <= T::MAX_MAG
}

/// y += a * x
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = a * x + b * y
pub fn axpby(a: f32, x: &[f32], b: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * xi + b * *yi;
    }
}

pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // f64 accumulator: gradients have ~1e7 coordinates, f32 accumulation
    // loses ~3 digits there.
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

pub fn norm2_sq(x: &[f32]) -> f64 {
    x.iter().map(|a| *a as f64 * *a as f64).sum()
}

pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

/// f32 L2 norm matching the L1/L2 layers' f32 accumulation order closely
/// enough for parity tests (they accumulate in f32 pairwise; we use f64 and
/// round — within 1 ulp of pairwise-f32 for gradient-scale inputs).
pub fn norm2_f32(x: &[f32]) -> f32 {
    norm2(x) as f32
}

pub fn norm1(x: &[f32]) -> f64 {
    x.iter().map(|a| a.abs() as f64).sum()
}

pub fn norm_inf(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, a| m.max(a.abs()))
}

pub fn add_assign(x: &mut [f32], y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter_mut().zip(y) {
        *xi += yi;
    }
}

pub fn sub_assign(x: &mut [f32], y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter_mut().zip(y) {
        *xi -= yi;
    }
}

pub fn mean(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|a| *a as f64).sum::<f64>() / x.len() as f64
}

/// Elementwise mean of several equal-length vectors.
pub fn mean_of(vs: &[&[f32]]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let n = vs[0].len();
    let mut out = vec![0.0f32; n];
    for v in vs {
        add_assign(&mut out, v);
    }
    scale(1.0 / vs.len() as f32, &mut out);
    out
}

pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in x.iter().enumerate() {
        if *v > x[best] {
            best = i;
        }
    }
    best
}

/// Indices of the K largest |x_i| (unordered), via partial selection.
/// O(n log k) with a min-heap keyed on magnitude.
pub fn top_k_abs_indices(x: &[f32], k: usize) -> Vec<usize> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct Mag(f32, usize);
    impl Eq for Mag {}
    impl PartialOrd for Mag {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Mag {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            self.0.total_cmp(&o.0).then(self.1.cmp(&o.1))
        }
    }

    let k = k.min(x.len());
    if k == 0 {
        return Vec::new();
    }
    let mut heap: BinaryHeap<Reverse<Mag>> = BinaryHeap::with_capacity(k + 1);
    for (i, v) in x.iter().enumerate() {
        let m = v.abs();
        if heap.len() < k {
            heap.push(Reverse(Mag(m, i)));
        } else if m > heap.peek().unwrap().0 .0 {
            heap.pop();
            heap.push(Reverse(Mag(m, i)));
        }
    }
    let mut idx: Vec<usize> = heap.into_iter().map(|Reverse(Mag(_, i))| i).collect();
    idx.sort_unstable();
    idx
}

/// Max |relative error| between two vectors (0-safe).
pub fn max_rel_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let scale = 1.0f64.max(x.abs() as f64).max(y.abs() as f64);
            (*x as f64 - *y as f64).abs() / scale
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, ensure, ensure_close};

    #[test]
    fn level_int_widening_rule_and_casts() {
        // every width round-trips exact integer levels losslessly
        for lv in [-127.0f32, -1.0, 0.0, 1.0, 127.0] {
            assert_eq!(i8::from_level(lv).to_f32(), lv);
            assert_eq!(i16::from_level(lv).to_f32(), lv);
            assert_eq!(i32::from_level(lv).to_f32(), lv);
            assert_eq!(i8::from_level(lv).to_i64(), lv as i64);
        }
        // the widening rule: workers * s must fit the accumulator
        assert!(sum_fits::<i8>(7, 18)); // 4-bit levels, 18 workers: 126
        assert!(!sum_fits::<i8>(7, 19)); // 133 > i8::MAX
        assert!(sum_fits::<i16>(2047, 16)); // 12-bit, 16 workers: 32752
        assert!(!sum_fits::<i16>(2047, 17));
        assert!(sum_fits::<i32>(32767, 4096)); // 16-bit at MAX_WORKERS
        assert_eq!(i16::TAG, "i16");
    }

    #[test]
    fn axpy_and_dot_basics() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((norm2(&x) - 14f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn topk_small_exact() {
        let x = vec![0.1, -5.0, 3.0, 0.0, -2.0];
        assert_eq!(top_k_abs_indices(&x, 2), vec![1, 2]);
        assert_eq!(top_k_abs_indices(&x, 0), Vec::<usize>::new());
        assert_eq!(top_k_abs_indices(&x, 99).len(), 5);
    }

    #[test]
    fn prop_topk_matches_full_sort() {
        check("topk == sort-based selection", 100, |g| {
            let n = g.size_scaled(1, 2000);
            let k = g.usize_in(0, n);
            let v = g.vec_normal(n, 1.0);
            let fast = top_k_abs_indices(&v, k);
            let mut all: Vec<usize> = (0..n).collect();
            all.sort_by(|&a, &b| v[b].abs().total_cmp(&v[a].abs()).then(a.cmp(&b)));
            let mut slow: Vec<usize> = all[..k].to_vec();
            slow.sort_unstable();
            // ties can legitimately differ in index choice; compare magnitudes
            let mag = |idx: &[usize]| -> f64 { idx.iter().map(|&i| v[i].abs() as f64).sum() };
            ensure_close(mag(&fast), mag(&slow), 1e-9, "selected magnitude mass")
        });
    }

    #[test]
    fn prop_mean_of_matches_manual() {
        check("mean_of", 50, |g| {
            let n = g.size_scaled(1, 512);
            let a = g.vec_normal(n, 2.0);
            let b = g.vec_normal(n, 2.0);
            let m = mean_of(&[&a, &b]);
            for i in 0..n {
                let want = (a[i] + b[i]) / 2.0;
                if (m[i] - want).abs() > 1e-6 {
                    return Err(format!("idx {i}: {} vs {want}", m[i]));
                }
            }
            ensure(true, "")
        });
    }

    #[test]
    fn norms_on_adversarial() {
        check("norm relations", 100, |g| {
            let n = g.size_scaled(1, 1024);
            let v = g.vec_adversarial(n);
            let n2 = norm2(&v);
            let n1 = norm1(&v);
            let ninf = norm_inf(&v) as f64;
            ensure(n2 <= n1 * (1.0 + 1e-9) || n1 == 0.0, "||v||2 <= ||v||1")?;
            ensure(
                ninf <= n2 * (1.0 + 1e-6) + 1e-30,
                "||v||inf <= ||v||2",
            )
        });
    }
}
