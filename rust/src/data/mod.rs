//! Synthetic datasets (DESIGN.md §2 substitution for CIFAR10 + a Markov
//! corpus for the LM workload). Everything is generated deterministically
//! from (seed, split, index) so any worker can materialize its shard
//! without a data service.

use crate::util::rng::Rng;

/// CIFAR10-like synthetic classification set: 32×32×3 images, 10 classes.
///
/// Each class has a smooth prototype (low-frequency random field upsampled
/// 4×4 -> 32×32) plus per-sample smooth distortion and pixel noise. The
/// Bayes error is controlled by `noise`; at the default the task is
/// learnable to >90% by a small CNN but not linearly trivial.
pub struct CifarLike {
    pub classes: usize,
    pub height: usize,
    pub width: usize,
    pub chans: usize,
    pub noise: f32,
    prototypes: Vec<Vec<f32>>,
    seed: u64,
}

fn upsample_bilinear(grid: &[f32], gh: usize, gw: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    // grid: [gh][gw][c] -> out: [h][w][c]
    let mut out = vec![0.0f32; h * w * c];
    for y in 0..h {
        let fy = y as f32 * (gh - 1) as f32 / (h - 1) as f32;
        let y0 = fy.floor() as usize;
        let y1 = (y0 + 1).min(gh - 1);
        let ty = fy - y0 as f32;
        for x in 0..w {
            let fx = x as f32 * (gw - 1) as f32 / (w - 1) as f32;
            let x0 = fx.floor() as usize;
            let x1 = (x0 + 1).min(gw - 1);
            let tx = fx - x0 as f32;
            for ch in 0..c {
                let g = |yy: usize, xx: usize| grid[(yy * gw + xx) * c + ch];
                let top = g(y0, x0) * (1.0 - tx) + g(y0, x1) * tx;
                let bot = g(y1, x0) * (1.0 - tx) + g(y1, x1) * tx;
                out[(y * w + x) * c + ch] = top * (1.0 - ty) + bot * ty;
            }
        }
    }
    out
}

impl CifarLike {
    pub fn new(seed: u64) -> CifarLike {
        CifarLike::with_geometry(seed, 10, 32, 32, 3, 1.4)
    }

    pub fn with_geometry(
        seed: u64,
        classes: usize,
        height: usize,
        width: usize,
        chans: usize,
        noise: f32,
    ) -> CifarLike {
        let root = Rng::new(seed);
        let (gh, gw) = (4usize, 4usize);
        let prototypes = (0..classes)
            .map(|cl| {
                let mut r = root.derive(&[0x70726F74, cl as u64]);
                let mut grid = vec![0.0f32; gh * gw * chans];
                r.fill_normal_f32(&mut grid, 1.0);
                upsample_bilinear(&grid, gh, gw, height, width, chans)
            })
            .collect();
        CifarLike { classes, height, width, chans, noise, prototypes, seed }
    }

    pub fn dim(&self) -> usize {
        self.height * self.width * self.chans
    }

    /// Deterministic single example for (split, index).
    pub fn example(&self, split: u64, index: u64) -> (Vec<f32>, i32) {
        let root = Rng::new(self.seed);
        let mut r = root.derive(&[0x657861, split, index]);
        let label = r.next_below(self.classes as u64) as usize;
        let mut img = self.prototypes[label].clone();
        // smooth per-sample distortion
        let (gh, gw) = (4usize, 4usize);
        let mut grid = vec![0.0f32; gh * gw * self.chans];
        r.fill_normal_f32(&mut grid, self.noise);
        let smooth = upsample_bilinear(&grid, gh, gw, self.height, self.width, self.chans);
        for (p, s) in img.iter_mut().zip(&smooth) {
            *p += s;
        }
        // pixel noise
        for p in img.iter_mut() {
            *p += r.next_normal_f32() * self.noise * 0.5;
        }
        (img, label as i32)
    }

    /// Batch for worker `worker` at step `step` (weak scaling: each worker
    /// draws its own `batch` fresh examples; shard-disjoint by index).
    pub fn train_batch(
        &self,
        workers: usize,
        worker: usize,
        step: u64,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(batch * self.dim());
        let mut ys = Vec::with_capacity(batch);
        for b in 0..batch {
            let index = step * (workers * batch) as u64 + (worker * batch + b) as u64;
            let (img, y) = self.example(0, index);
            xs.extend_from_slice(&img);
            ys.push(y);
        }
        (xs, ys)
    }

    /// Fixed held-out evaluation batch (split 1).
    pub fn eval_batch(&self, n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(n * self.dim());
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let (img, y) = self.example(1, i as u64);
            xs.extend_from_slice(&img);
            ys.push(y);
        }
        (xs, ys)
    }
}

/// Order-1 Markov chain over `vocab` tokens with `branch` successors per
/// state — the synthetic corpus for the LM end-to-end run. The chain's
/// conditional entropy (≈ log2(branch) bits, modulated by random weights)
/// gives a concrete loss floor the training curve should approach.
pub struct MarkovCorpus {
    pub vocab: usize,
    pub branch: usize,
    succ: Vec<u32>,
    /// cumulative probabilities per state, `branch` per state
    cum: Vec<f32>,
    seed: u64,
}

impl MarkovCorpus {
    pub fn new(seed: u64, vocab: usize, branch: usize) -> MarkovCorpus {
        let root = Rng::new(seed);
        let mut succ = vec![0u32; vocab * branch];
        let mut cum = vec![0.0f32; vocab * branch];
        for t in 0..vocab {
            let mut r = root.derive(&[0x6D6B76, t as u64]);
            let mut weights = vec![0.0f32; branch];
            let mut total = 0.0f32;
            for j in 0..branch {
                succ[t * branch + j] = r.next_below(vocab as u64) as u32;
                let w = 0.2 + r.next_f32();
                weights[j] = w;
                total += w;
            }
            let mut acc = 0.0f32;
            for j in 0..branch {
                acc += weights[j] / total;
                cum[t * branch + j] = acc;
            }
            cum[t * branch + branch - 1] = 1.0;
        }
        MarkovCorpus { vocab, branch, succ, cum, seed }
    }

    fn next_token(&self, t: usize, u: f32) -> usize {
        let base = t * self.branch;
        for j in 0..self.branch {
            if u < self.cum[base + j] {
                return self.succ[base + j] as usize;
            }
        }
        self.succ[base + self.branch - 1] as usize
    }

    /// One sequence of `len` tokens for (split, index).
    pub fn sequence(&self, split: u64, index: u64, len: usize) -> Vec<i32> {
        let root = Rng::new(self.seed);
        let mut r = root.derive(&[0x736571, split, index]);
        let mut t = r.next_below(self.vocab as u64) as usize;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(t as i32);
            t = self.next_token(t, r.next_f32());
        }
        out
    }

    /// [workers × batch × len] token block for a step (flattened row-major).
    pub fn train_batch(
        &self,
        workers: usize,
        worker: usize,
        step: u64,
        batch: usize,
        len: usize,
    ) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * len);
        for b in 0..batch {
            let index = step * (workers * batch) as u64 + (worker * batch + b) as u64;
            out.extend(self.sequence(0, index, len));
        }
        out
    }

    pub fn eval_batch(&self, n: usize, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(n * len);
        for i in 0..n {
            out.extend(self.sequence(1, i as u64, len));
        }
        out
    }

    /// The per-token conditional entropy in nats — the loss floor for a
    /// perfect model of the chain.
    pub fn entropy_nats(&self) -> f64 {
        let mut h_total = 0.0f64;
        for t in 0..self.vocab {
            let base = t * self.branch;
            let mut prev = 0.0f32;
            // successor tokens may repeat; accumulate true distribution
            let mut probs = std::collections::HashMap::new();
            for j in 0..self.branch {
                let p = self.cum[base + j] - prev;
                prev = self.cum[base + j];
                *probs.entry(self.succ[base + j]).or_insert(0.0f64) += p as f64;
            }
            let h: f64 = probs.values().filter(|p| **p > 0.0).map(|p| -p * p.ln()).sum();
            h_total += h;
        }
        // stationary distribution approximated as uniform (symmetric construction)
        h_total / self.vocab as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, ensure};

    #[test]
    fn cifar_deterministic_and_label_in_range() {
        let d = CifarLike::new(7);
        let (x1, y1) = d.example(0, 42);
        let (x2, y2) = d.example(0, 42);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        assert!((0..10).contains(&y1));
        assert_eq!(x1.len(), 32 * 32 * 3);
    }

    #[test]
    fn cifar_shards_disjoint_across_workers() {
        let d = CifarLike::new(7);
        let (a, _) = d.train_batch(4, 0, 3, 8);
        let (b, _) = d.train_batch(4, 1, 3, 8);
        assert_ne!(a, b);
    }

    #[test]
    fn cifar_classes_are_separable() {
        // nearest-prototype classification on clean-ish samples beats chance
        let d = CifarLike::new(7);
        let mut correct = 0;
        let total = 200;
        for i in 0..total {
            let (x, y) = d.example(2, i as u64);
            let mut best = (f32::INFINITY, 0usize);
            for (c, p) in d.prototypes.iter().enumerate() {
                let dist: f32 = x.iter().zip(p).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == y as usize {
                correct += 1;
            }
        }
        assert!(
            correct > total / 2,
            "nearest-prototype should beat chance strongly: {correct}/{total}"
        );
    }

    #[test]
    fn prop_markov_tokens_in_vocab() {
        check("markov tokens in range", 30, |g| {
            let vocab = g.usize_in(4, 300);
            let corpus = MarkovCorpus::new(g.rng().next_u64(), vocab, 8.min(vocab));
            let seq = corpus.sequence(0, g.rng().next_u64(), 64);
            for &t in &seq {
                ensure((0..vocab as i32).contains(&t), &format!("token {t}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn markov_transitions_follow_table() {
        let c = MarkovCorpus::new(3, 64, 4);
        let seq = c.sequence(0, 9, 200);
        for w in seq.windows(2) {
            let t = w[0] as usize;
            let next = w[1] as u32;
            let ok = (0..c.branch).any(|j| c.succ[t * c.branch + j] == next);
            assert!(ok, "transition {t}->{next} not in table");
        }
    }

    #[test]
    fn markov_entropy_positive_below_uniform() {
        let c = MarkovCorpus::new(3, 256, 8);
        let h = c.entropy_nats();
        assert!(h > 0.5, "entropy {h}");
        assert!(h < (256f64).ln(), "entropy {h} below uniform bound");
    }
}
