//! # repro — Unbiased Single-/Multi-scale Quantizers for Distributed Optimization
//!
//! A three-layer Rust + JAX + Pallas reproduction of the paper's system:
//! all-reduce-compatible gradient compression (QSGDMaxNorm, its multi-scale
//! extension, and sparsified GlobalRandK variants) inside a simulated
//! data-parallel training cluster whose model compute is AOT-compiled JAX
//! executed through PJRT. See DESIGN.md for the full inventory and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * [`runtime`] — PJRT bridge to the build-time-lowered HLO artifacts
//! * [`compress`] — the paper's contribution + every baseline
//! * [`control`] — bucketed gradient control plane, generic over the whole
//!   all-reduce-compatible quantizer family (per-layer buckets, adaptive
//!   precision, error feedback, backward/comm overlap)
//! * [`collectives`] / [`netsim`] / [`cluster`] — the distributed substrate
//! * [`optim`] / [`data`] / [`train`] — the training framework around it
//! * [`perfmodel`] — the §6.6 analytical throughput model
//! * [`figures`] — regenerates every figure in the paper
//! * [`trace`] — step flight recorder + self-auditing ledger registry

pub mod cli;
pub mod cluster;
pub mod collectives;
pub mod compress;
pub mod control;
pub mod data;
pub mod figures;
pub mod metrics;
pub mod netsim;
pub mod optim;
pub mod perfmodel;
pub mod runtime;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod util;
