//! Packed-resident ring all-reduce: the compressed collective whose
//! *resident* reduce operand is [`Packed`] words, not widened `i16`/`i32`
//! level buffers.
//!
//! The PR 1 data plane reduced widened integer buffers and only measured the
//! packed wire format on the side — the memory it moved did not match the
//! wire bytes it charged (the paper-vs-deployed gap ScaleCom documents).
//! Here every hop of the ring schedule ships a segment of packed codes:
//!
//! * codes are **biased** (`code = level + lmax`, all non-negative), so a
//!   hop's reduce is a field-wise *add* of two packed segments and biases
//!   accumulate linearly with the contribution count;
//! * the resident width ([`bitpack::packed_sum_bits`]) gives every field
//!   headroom for the full `m`-worker sum — the **carry-safety condition**:
//!   no per-field sum can overflow its field, so one big-integer
//!   add-with-carry per segment ([`bitpack::add_packed_codes`]) is exact
//!   field-wise addition, with zero unpack/repack work per hop;
//! * a pack-per-hop **reference** schedule (unpack → add → repack through
//!   the offset kernels) pins the fast path bit-identical.
//!
//! Memory traffic per hop is `segment_codes * resident_bits / 8` bytes —
//! tracked by [`RingTraffic`] so the bench can verify the packed-resident
//! plane moves ~`bits/16` of the i16 plane's bytes.

use crate::compress::bitpack::{self, Packed};

/// Bytes-moved ledger for a data-plane collective: counts the packed-buffer
/// bytes read and written by reduce/copy segments (field bits, not word
/// slack), plus the per-step wire payload for hop-accurate charging.
#[derive(Clone, Copy, Debug, Default)]
pub struct RingTraffic {
    /// total packed bytes read + written by the data plane
    pub bytes_moved: f64,
    /// ring steps executed (reduce-scatter + all-gather)
    pub steps: usize,
}

impl RingTraffic {
    #[inline]
    fn seg(&mut self, codes: usize, bits: u32, accesses: f64) {
        self.bytes_moved += accesses * (codes * bits as usize) as f64 / 8.0;
    }
}

/// Two disjoint `&mut` elements of one slice (the ring's send/recv pair).
fn pair_mut<'a, T>(s: &'a mut [T], i: usize, j: usize) -> (&'a mut T, &'a mut T) {
    assert_ne!(i, j);
    if i < j {
        let (a, b) = s.split_at_mut(j);
        (&mut a[i], &mut b[0])
    } else {
        let (a, b) = s.split_at_mut(i);
        (&mut b[0], &mut a[j])
    }
}

/// Chunk boundaries of the ring schedule over `n` codes and `m` ranks.
#[inline]
fn chunk_starts(n: usize, m: usize) -> Vec<usize> {
    (0..=m).map(|c| c * n / m).collect()
}

/// Ring all-reduce over per-worker packed **biased** code buffers covering
/// codes `[0, n_codes)` at width `bits`. Same schedule (and therefore the
/// same per-element reduction order) as [`super::ring_allreduce_sum_t`];
/// integer field sums are exact, so the result is bit-identical to reducing
/// the unpacked levels. On return every worker's buffer holds the biased
/// sum of all `m` contributions (bias = `m * per_contribution_bias`).
pub fn ring_allreduce_biased_range(
    bufs: &mut [&mut [u64]],
    bits: u32,
    n_codes: usize,
    traffic: &mut RingTraffic,
) {
    let m = bufs.len();
    if m <= 1 || n_codes == 0 {
        return;
    }
    let starts = chunk_starts(n_codes, m);

    // reduce-scatter: each hop adds the sender's packed segment into the
    // receiver's, field-wise, in place — no unpack, no repack.
    for step in 0..m - 1 {
        for r in 0..m {
            let c = (r + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (dst_words, src_words) = pair_mut(bufs, dst, r);
            bitpack::add_packed_codes(&mut **dst_words, &**src_words, bits, lo, hi);
            // read src + read dst + write dst
            traffic.seg(hi - lo, bits, 3.0);
            traffic.steps += 1;
        }
    }

    // all-gather: circulate the completed packed chunks.
    for step in 0..m - 1 {
        for r in 0..m {
            let c = (r + 1 + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (dst_words, src_words) = pair_mut(bufs, dst, r);
            bitpack::copy_packed_codes(&mut **dst_words, &**src_words, bits, lo, hi);
            // read src + write dst
            traffic.seg(hi - lo, bits, 2.0);
            traffic.steps += 1;
        }
    }
}

/// Pack-per-hop reference schedule: identical ring, but every reduce hop
/// unpacks both segments through the offset kernels, adds in the integer
/// domain, and repacks. Kept as the baseline the property tests pin
/// [`ring_allreduce_biased_range`] bit-identical to, and as the shape a
/// width-growing (wire-minimal) variant would take — see DESIGN.md
/// §Performance for the trade-off.
pub fn ring_allreduce_biased_range_reference(
    bufs: &mut [&mut [u64]],
    bits: u32,
    n_codes: usize,
) {
    let m = bufs.len();
    if m <= 1 || n_codes == 0 {
        return;
    }
    let starts = chunk_starts(n_codes, m);
    let max_chunk = (1..=m).map(|c| starts[c] - starts[c - 1]).max().unwrap_or(0);
    let mut a = vec![0u64; max_chunk];
    let mut b = vec![0u64; max_chunk];

    for step in 0..m - 1 {
        for r in 0..m {
            let c = (r + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let len = hi - lo;
            let (dst_words, src_words) = pair_mut(bufs, dst, r);
            bitpack::unpack_codes_at(&**src_words, bits, lo, &mut a[..len]);
            bitpack::unpack_codes_at(&**dst_words, bits, lo, &mut b[..len]);
            for (x, y) in b[..len].iter_mut().zip(&a[..len]) {
                *x += *y;
            }
            bitpack::pack_codes_at(&b[..len], bits, &mut **dst_words, lo);
        }
    }
    for step in 0..m - 1 {
        for r in 0..m {
            let c = (r + 1 + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (dst_words, src_words) = pair_mut(bufs, dst, r);
            bitpack::copy_packed_codes(&mut **dst_words, &**src_words, bits, lo, hi);
        }
    }
}

/// Convenience wrapper over whole [`Packed`] buffers (all at the same
/// resident width and length, biased codes). Used by the benches and tests;
/// the fused pipelined hot path drives [`ring_allreduce_biased_range`]
/// directly on per-chunk word views.
pub fn ring_allreduce_sum_packed(bufs: &mut [Packed], traffic: &mut RingTraffic) {
    let m = bufs.len();
    if m <= 1 {
        return;
    }
    let bits = bufs[0].bits;
    let len = bufs[0].len;
    assert!(
        bufs.iter().all(|p| p.bits == bits && p.len == len),
        "ragged packed buffers"
    );
    let mut views: Vec<&mut [u64]> = bufs.iter_mut().map(|p| p.words.as_mut_slice()).collect();
    ring_allreduce_biased_range(&mut views, bits, len, traffic);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::bitpack::{pack_biased_int, packed_sum_bits, unpack_biased_i64_at};
    use crate::util::quickcheck::{check, ensure};

    fn random_levels(
        g: &mut crate::util::quickcheck::Gen,
        lmax: usize,
        m: usize,
        n: usize,
    ) -> Vec<Vec<i32>> {
        (0..m)
            .map(|_| {
                (0..n)
                    .map(|_| g.rng().next_below(2 * lmax as u64 + 1) as i32 - lmax as i32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn prop_packed_ring_equals_integer_naive() {
        check("packed ring == naive integer sum", 120, |g| {
            let m = g.usize_in(1, 9);
            let lmax = *g.pick(&[1usize, 7, 127, 2047]);
            let n = g.size_scaled(0, 2500);
            let bits = packed_sum_bits(lmax, m);
            let levels = random_levels(g, lmax, m, n);
            let mut bufs: Vec<Packed> =
                levels.iter().map(|l| pack_biased_int(l, lmax as i64, bits)).collect();
            let mut traffic = RingTraffic::default();
            ring_allreduce_sum_packed(&mut bufs, &mut traffic);
            let want: Vec<i64> = (0..n)
                .map(|i| levels.iter().map(|l| l[i] as i64).sum::<i64>())
                .collect();
            let bias_total = (m as i64) * lmax as i64;
            let mut got = vec![0i64; n];
            for (r, p) in bufs.iter().enumerate() {
                unpack_biased_i64_at(&p.words, bits, 0, bias_total, &mut got);
                if got != want {
                    let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                    return Err(format!(
                        "rank {r} field {bad}: {} vs {} (m={m} lmax={lmax} bits={bits})",
                        got[bad], want[bad]
                    ));
                }
            }
            if m > 1 && n > 0 {
                ensure(traffic.bytes_moved > 0.0, "traffic counter must move")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fast_add_path_bit_identical_to_pack_per_hop_reference() {
        // the tentpole contract at the collective level: the in-place
        // add-with-carry hops produce the exact same packed words as the
        // unpack -> add -> repack reference schedule.
        check("adc ring == pack-per-hop reference", 120, |g| {
            let m = g.usize_in(2, 9);
            let lmax = *g.pick(&[1usize, 7, 127]);
            let n = g.size_scaled(1, 2000);
            let bits = packed_sum_bits(lmax, m);
            let levels = random_levels(g, lmax, m, n);
            let mut fast: Vec<Packed> =
                levels.iter().map(|l| pack_biased_int(l, lmax as i64, bits)).collect();
            let mut slow = fast.clone();
            let mut traffic = RingTraffic::default();
            ring_allreduce_sum_packed(&mut fast, &mut traffic);
            let mut views: Vec<&mut [u64]> =
                slow.iter_mut().map(|p| p.words.as_mut_slice()).collect();
            ring_allreduce_biased_range_reference(&mut views, bits, n);
            for r in 0..m {
                if fast[r] != slow[r] {
                    return Err(format!("rank {r} words differ (m={m} lmax={lmax} n={n})"));
                }
            }
            ensure(traffic.steps == 2 * m * (m - 1), "step count")
        });
    }

    #[test]
    fn traffic_scales_with_resident_width() {
        // same layout, twice the resident width -> twice the bytes moved
        let n = 4096;
        let m = 8;
        let levels: Vec<Vec<i32>> = (0..m).map(|r| vec![(r % 3) as i32; n]).collect();
        let run = |bits: u32| {
            let mut bufs: Vec<Packed> =
                levels.iter().map(|l| pack_biased_int(l, 4, bits)).collect();
            let mut t = RingTraffic::default();
            ring_allreduce_sum_packed(&mut bufs, &mut t);
            t.bytes_moved
        };
        let b8 = run(8);
        let b16 = run(16);
        assert!((b16 / b8 - 2.0).abs() < 1e-9, "width ratio: {b8} vs {b16}");
    }
}
