//! Schedule-generic packed data plane: every reduction schedule (ring, tree,
//! naive) reduces a *resident* operand of [`Packed`] biased codes through the
//! one [`PackedReduce`] trait — no widened `i16`/`i32` buffers anywhere on
//! the compressed hot path.
//!
//! The PR 1 data plane reduced widened integer buffers and only measured the
//! packed wire format on the side; PR 2 made the ring packed-resident but
//! left tree/naive on the widened plane (the paper-vs-deployed gap ScaleCom
//! documents). This module closes both gaps:
//!
//! * codes are **biased** (`code = level + lmax`, all non-negative), so a
//!   reduce hop is a field-wise *add* of two packed segments and biases
//!   accumulate linearly with the contribution count;
//! * the resident width ([`bitpack::packed_sum_bits`]) gives every field
//!   headroom for the full `m`-worker sum — the **carry-safety condition**:
//!   no per-field sum can overflow its field, so one big-integer
//!   add-with-carry per segment ([`bitpack::add_packed_codes`]) is exact
//!   field-wise addition, with zero unpack/repack work per hop. Tree and
//!   naive partial sums carry at most `m` contributions, so the same width
//!   is carry-safe for every schedule;
//! * [`RingGrowing`] additionally ships each reduce-scatter hop at the
//!   *minimal* width for the partial sum it carries — `bitlen(2*k*lmax)`
//!   for `k` accumulated contributions — re-packing between widths through
//!   the bit-offset kernels. Strictly never more wire bits than the fixed
//!   ring; extra pack compute (see `NetConfig::growing_ring_wins` for the
//!   analytic selector and DESIGN.md for the crossover);
//! * a pack-per-hop **reference** schedule (unpack → add → repack through
//!   the offset kernels) pins the fast path bit-identical.
//!
//! [`PlaneTraffic`] is the data-plane ledger every schedule reports through:
//! packed-buffer bytes read/written (`bytes_moved`) and total wire bits
//! shipped across the cluster (`wire_bits`), so the bench can gate the
//! packed plane against the i16 plane and growing against fixed.

use crate::compress::bitpack::{self, Packed};
use crate::netsim::{LinkLevel, NetConfig};

/// Data-plane ledger for a packed collective, generic over the schedule:
/// counts the packed-buffer bytes read and written by reduce/copy/repack
/// segments (field bits, not word slack) plus the wire payload every
/// transfer ships, byte-exact per segment.
///
/// Both books are **cluster totals** (summed over every rank's transfers);
/// the per-worker simulated ledgers live on [`crate::netsim::SimClock`] and
/// are charged analytically by [`super::StepCtx::charge_packed`].
#[derive(Clone, Copy, Debug, Default)]
pub struct PlaneTraffic {
    /// total packed bytes read + written by the data plane
    pub bytes_moved: f64,
    /// total wire bits shipped across the cluster (byte-exact per segment)
    pub wire_bits: f64,
    /// transfers executed (segment hops for the ring, pair transfers for
    /// tree/naive)
    pub steps: usize,
}

/// The pre-PR-3 name, kept so external readers of the bench JSON and older
/// call sites keep compiling; the ledger is schedule-generic now.
pub type RingTraffic = PlaneTraffic;

impl PlaneTraffic {
    #[inline]
    fn seg(&mut self, codes: usize, bits: u32, accesses: f64) {
        self.bytes_moved += accesses * (codes * bits as usize) as f64 / 8.0;
    }

    #[inline]
    fn wire(&mut self, codes: usize, bits: u32) {
        self.wire_bits += (8 * bitpack::wire_bytes_for(codes, bits)) as f64;
    }
}

// ---------------------------------------------------------------------------
// Hop-segment integrity (PR 7)
// ---------------------------------------------------------------------------

/// Wire bytes of the per-hop-segment checksum: one 64-bit word
/// (`8 * ceil(64 / 8)`), charged byte-exact on every checksummed hop.
pub const CHECKSUM_BYTES: usize = 8;

/// Integrity policy of the packed data plane: each hop segment ships a
/// [`xor_fold_checksum`] over its wire words; a mismatch (or an injected
/// loss) triggers a bounded retransmit ladder with exponential backoff,
/// charged to [`crate::netsim::SimClock::retrans_s`] /
/// [`crate::netsim::SimClock::retrans_bits`]. After `max_retries`
/// exhausted retransmits the peer escalates into the elastic partial-cohort
/// path ([`crate::control::elastic`]) instead of stalling the step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IntegrityConfig {
    /// Retransmits allowed per hop segment beyond the first attempt.
    pub max_retries: u32,
    /// Backoff before retransmit attempt `a` (1-based): `backoff_base_s *
    /// 2^(a-1)` — the classic exponential ladder, seeded at one TCP-ish
    /// stack latency.
    pub backoff_base_s: f64,
}

impl Default for IntegrityConfig {
    fn default() -> IntegrityConfig {
        IntegrityConfig { max_retries: 3, backoff_base_s: 50e-6 }
    }
}

/// Rotated xor-fold of a segment's wire words: word `i` contributes
/// `words[i].rotate_left(i % 64)`. Position-dependent rotation breaks the
/// plain-xor blind spot (two identical flips at the same bit of different
/// words cancel under plain xor; here they land on different bits unless the
/// words are 64 apart). Any **single**-bit corruption flips exactly one bit
/// of the fold and is always detected — the guarantee the injected-flip
/// recovery path relies on, pinned by `checksum_detects_every_single_bit_flip`.
#[inline]
pub fn xor_fold_checksum(words: &[u64]) -> u64 {
    let mut acc = 0u64;
    for (i, w) in words.iter().enumerate() {
        acc ^= w.rotate_left((i % 64) as u32);
    }
    acc
}

/// Apply a [`crate::netsim::HopFault::Flip`] corruption site to a wire
/// segment: flips bit `bit % 64` of word `word % len`. No-op on an empty
/// segment. Involution: applying the same site twice restores the words.
#[inline]
pub fn corrupt_word(words: &mut [u64], word: u64, bit: u32) {
    if words.is_empty() {
        return;
    }
    let i = (word % words.len() as u64) as usize;
    words[i] ^= 1u64 << (bit % 64);
}

/// One reduction schedule over packed-resident biased-code operands — the
/// schedule-generic seam of the compressed data plane. Implementations
/// really move the packed words (the integer sums are exact, so every
/// schedule is bit-identical to every other and to the unpacked integer
/// reduction), and expose the analytic per-hop wire shape the simulated
/// clock charges through [`super::StepCtx::charge_packed`].
pub trait PackedReduce: Sync {
    /// Schedule name for ledgers and benches.
    fn name(&self) -> &'static str;

    /// In-place sum all-reduce of per-worker packed **biased** code buffers
    /// covering codes `[0, n_codes)` at resident width `bits`. On return
    /// every worker's buffer holds the biased sum of all `m` contributions
    /// (bias = `m * per_contribution_bias`). Data-plane traffic accumulates
    /// into `traffic`.
    fn reduce(
        &self,
        bufs: &mut [&mut [u64]],
        bits: u32,
        n_codes: usize,
        traffic: &mut PlaneTraffic,
    );

    /// Synchronous per-worker hop count of the schedule across `m` ranks.
    fn hops(&self, m: usize) -> usize;

    /// Wire bytes one worker ships on hop `h` (`h < self.hops(m)`) for
    /// `elems` codes at resident width `bits` — the hop-accurate shape the
    /// uniform α–β model hides. Ring hops move one `ceil(elems/m)`-code
    /// segment; tree/naive hops move the full buffer.
    fn hop_wire_bytes(&self, h: usize, elems: usize, bits: u32, m: usize) -> f64;

    /// The [`LinkLevel`] hop `h` crosses, for topology-aware schedules
    /// (PR 8): [`Hierarchical`] tags its island hops `Intra` and its
    /// leader-ring hops `Inter`. `None` — the default every single-level
    /// schedule keeps — means "the flat bottleneck link", which the charger
    /// resolves through [`NetConfig::bottleneck_level`].
    fn hop_level(&self, _h: usize, _m: usize) -> Option<LinkLevel> {
        None
    }

    /// Simulated wire seconds of one full pass at resident width `bits`.
    /// Default: the sum of the schedule's hops, each over the link of its
    /// [`PackedReduce::hop_level`] (the flat bottleneck when untagged) —
    /// right for the rings, whose synchronous pipeline of segment hops
    /// spans nodes (this is what PR 2's `ring_steps_s` charged), and for
    /// the hierarchical schedule, whose hops carry their own level.
    /// Tree/naive override it with the **hierarchical** α–β model at the
    /// resident width, so multi-GPU-per-node clusters keep their NVLink
    /// advantage (the pre-PR-3 behaviour, now at the width actually
    /// shipped).
    fn comm_s(&self, net: &NetConfig, elems: usize, bits: u32) -> f64 {
        let m = net.workers.max(1);
        if m <= 1 || elems == 0 {
            return 0.0;
        }
        (0..self.hops(m)).map(|h| self.hop_time_s(net, h, elems, bits, m)).sum()
    }

    /// Analytic wire seconds of hop `h` alone — the flight recorder's
    /// per-hop weight when it partitions a schedule's `comm_s` charge into
    /// hop windows ([`super::StepCtx::charge_packed`]). For the rings these
    /// weights sum to exactly the default [`PackedReduce::comm_s`]; for
    /// tree/naive (which override `comm_s` with the hierarchical α–β model)
    /// the recorder normalizes, so only the *relative* weights matter.
    fn hop_time_s(&self, net: &NetConfig, h: usize, elems: usize, bits: u32, m: usize) -> f64 {
        net.hop_s_on(
            self.hop_level(h, m).unwrap_or(net.bottleneck_level()),
            self.hop_wire_bytes(h, elems, bits, m),
        )
    }
}

/// Two disjoint `&mut` elements of one slice (a schedule's send/recv pair).
fn pair_mut<'a, T>(s: &'a mut [T], i: usize, j: usize) -> (&'a mut T, &'a mut T) {
    assert_ne!(i, j);
    if i < j {
        let (a, b) = s.split_at_mut(j);
        (&mut a[i], &mut b[0])
    } else {
        let (a, b) = s.split_at_mut(i);
        (&mut b[0], &mut a[j])
    }
}

/// Chunk boundaries of the ring schedule over `n` codes and `m` ranks.
#[inline]
fn chunk_starts(n: usize, m: usize) -> Vec<usize> {
    (0..=m).map(|c| c * n / m).collect()
}

// ---------------------------------------------------------------------------
// Fixed-width ring (the PR 2 fast path)
// ---------------------------------------------------------------------------

/// Ring schedule at the fixed (final-sum) resident width: every hop is an
/// in-place big-integer add-with-carry over a packed segment — zero
/// unpack/repack work, but every hop ships the full resident width even
/// when the partial sum it carries is narrow.
#[derive(Clone, Copy, Debug, Default)]
pub struct RingFixed;

impl PackedReduce for RingFixed {
    fn name(&self) -> &'static str {
        "ring-fixed"
    }

    fn reduce(
        &self,
        bufs: &mut [&mut [u64]],
        bits: u32,
        n_codes: usize,
        traffic: &mut PlaneTraffic,
    ) {
        ring_allreduce_biased_range(bufs, bits, n_codes, traffic)
    }

    fn hops(&self, m: usize) -> usize {
        2 * m.saturating_sub(1)
    }

    fn hop_wire_bytes(&self, _h: usize, elems: usize, bits: u32, m: usize) -> f64 {
        bitpack::wire_bytes_for(elems.div_ceil(m), bits) as f64
    }
}

/// Ring all-reduce over per-worker packed **biased** code buffers covering
/// codes `[0, n_codes)` at width `bits`. Same schedule (and therefore the
/// same per-element reduction order) as [`super::ring_allreduce_sum_t`];
/// integer field sums are exact, so the result is bit-identical to reducing
/// the unpacked levels. On return every worker's buffer holds the biased
/// sum of all `m` contributions (bias = `m * per_contribution_bias`).
pub fn ring_allreduce_biased_range(
    bufs: &mut [&mut [u64]],
    bits: u32,
    n_codes: usize,
    traffic: &mut PlaneTraffic,
) {
    let m = bufs.len();
    if m <= 1 || n_codes == 0 {
        return;
    }
    let starts = chunk_starts(n_codes, m);

    // reduce-scatter: each hop adds the sender's packed segment into the
    // receiver's, field-wise, in place — no unpack, no repack.
    for step in 0..m - 1 {
        for r in 0..m {
            let c = (r + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (dst_words, src_words) = pair_mut(bufs, dst, r);
            bitpack::add_packed_codes(&mut **dst_words, &**src_words, bits, lo, hi);
            // read src + read dst + write dst
            traffic.seg(hi - lo, bits, 3.0);
            traffic.wire(hi - lo, bits);
            traffic.steps += 1;
        }
    }

    // all-gather: circulate the completed packed chunks.
    for step in 0..m - 1 {
        for r in 0..m {
            let c = (r + 1 + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (dst_words, src_words) = pair_mut(bufs, dst, r);
            bitpack::copy_packed_codes(&mut **dst_words, &**src_words, bits, lo, hi);
            // read src + write dst
            traffic.seg(hi - lo, bits, 2.0);
            traffic.wire(hi - lo, bits);
            traffic.steps += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Width-growing pack-per-hop ring
// ---------------------------------------------------------------------------

/// Wire width of a partial sum carrying `k` contributions bounded by
/// `lmax`: biased codes live in `[0, 2*k*lmax]`, so `bitlen(2*k*lmax)` —
/// the same formula as the resident width at `k = m`.
#[inline]
pub fn growing_hop_bits(lmax: usize, k: usize) -> u32 {
    bitpack::packed_sum_bits(lmax, k)
}

/// Ring schedule that ships every reduce-scatter hop at the **minimal**
/// width for the partial sum it carries: hop `step` moves segments holding
/// `k = step + 1` contributions, re-packed to [`growing_hop_bits`] codes on
/// the wire, then unpacked and accumulated into the receiver's resident
/// fields. All-gather hops carry completed `m`-contribution sums, which
/// already need the full resident width — no savings there.
///
/// Wire bits are never more than [`RingFixed`]'s (each hop's width is
/// `<= bits`, and [`bitpack::wire_bytes_for`] is monotone in the width);
/// the price is pack compute per hop instead of one add-with-carry pass.
/// Bit-identical to every other schedule: re-packing is lossless and the
/// integer sums are exact.
#[derive(Clone, Copy, Debug)]
pub struct RingGrowing {
    /// per-contribution level bound (= the per-contribution bias)
    pub lmax: usize,
}

impl PackedReduce for RingGrowing {
    fn name(&self) -> &'static str {
        "ring-growing"
    }

    fn reduce(
        &self,
        bufs: &mut [&mut [u64]],
        bits: u32,
        n_codes: usize,
        traffic: &mut PlaneTraffic,
    ) {
        let m = bufs.len();
        if m <= 1 || n_codes == 0 {
            return;
        }
        let starts = chunk_starts(n_codes, m);
        let max_chunk = (1..=m).map(|c| starts[c] - starts[c - 1]).max().unwrap_or(0);
        // pack-per-hop staging, reused across calls (the fused pipeline
        // calls reduce once per chunk per step — per-call Vecs here would
        // reintroduce exactly the steady-state allocation churn
        // PackedScratch exists to avoid). Thread-local is sound: reduce
        // runs on the pipeline's single consumer thread, and the contents
        // are fully overwritten before every read.
        thread_local! {
            static SCRATCH: std::cell::RefCell<(Vec<u64>, Vec<u64>, Vec<u64>)> =
                const { std::cell::RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
        }
        SCRATCH.with(|cell| {
            let mut guard = cell.borrow_mut();
            let (src_codes, dst_codes, wire) = &mut *guard;
            let wire_words = bitpack::words_for(max_chunk, bits);
            if src_codes.len() < max_chunk {
                src_codes.resize(max_chunk, 0);
                dst_codes.resize(max_chunk, 0);
            }
            if wire.len() < wire_words {
                wire.resize(wire_words, 0);
            }
            self.reduce_with_scratch(
                bufs, bits, &starts, src_codes, dst_codes, wire, traffic,
            );
        });
    }

    fn hops(&self, m: usize) -> usize {
        2 * m.saturating_sub(1)
    }

    fn hop_wire_bytes(&self, h: usize, elems: usize, bits: u32, m: usize) -> f64 {
        let seg = elems.div_ceil(m);
        // hops [0, m-1) are reduce-scatter at the growing width; the rest
        // are all-gather at the resident width
        let w = if h + 1 < m { growing_hop_bits(self.lmax, h + 1).min(bits) } else { bits };
        bitpack::wire_bytes_for(seg, w) as f64
    }
}

impl RingGrowing {
    #[allow(clippy::too_many_arguments)]
    fn reduce_with_scratch(
        &self,
        bufs: &mut [&mut [u64]],
        bits: u32,
        starts: &[usize],
        src_codes: &mut [u64],
        dst_codes: &mut [u64],
        wire: &mut [u64],
        traffic: &mut PlaneTraffic,
    ) {
        let m = bufs.len();
        // reduce-scatter: the shipped partial holds k = step + 1
        // contributions, so the wire segment is bitlen(2*k*lmax) wide.
        for step in 0..m - 1 {
            // capped at the resident width: with the flat ring's lmax the
            // cap never binds (k <= m), but the hierarchical leader ring
            // reuses this schedule with the island-sum bound g*lmax, where
            // a ragged last island can push bitlen(2*k*g*lmax) one past the
            // resident bitlen(2*m_total*lmax) — the values themselves
            // always fit the resident width, so shipping at it is exact
            let wbits = growing_hop_bits(self.lmax, step + 1).min(bits);
            for r in 0..m {
                let c = (r + m - step) % m;
                let dst = (r + 1) % m;
                let (lo, hi) = (starts[c], starts[c + 1]);
                let len = hi - lo;
                let (dst_words, src_words) = pair_mut(bufs, dst, r);
                // sender: re-pack its resident segment to the hop width
                bitpack::unpack_codes_at(&**src_words, bits, lo, &mut src_codes[..len]);
                bitpack::pack_codes_at(&src_codes[..len], wbits, &mut wire, 0);
                // receiver: unpack the wire segment, accumulate into its
                // resident fields at the full width
                bitpack::unpack_codes_at(&wire, wbits, 0, &mut src_codes[..len]);
                bitpack::unpack_codes_at(&**dst_words, bits, lo, &mut dst_codes[..len]);
                for (d, s) in dst_codes[..len].iter_mut().zip(&src_codes[..len]) {
                    *d += *s;
                }
                bitpack::pack_codes_at(&dst_codes[..len], bits, &mut **dst_words, lo);
                // resident read src + read dst + write dst, plus the wire
                // staging written once and read once at the hop width
                traffic.seg(len, bits, 3.0);
                traffic.seg(len, wbits, 2.0);
                traffic.wire(len, wbits);
                traffic.steps += 1;
            }
        }

        // all-gather at the full width: completed sums cannot ship narrower.
        for step in 0..m - 1 {
            for r in 0..m {
                let c = (r + 1 + m - step) % m;
                let dst = (r + 1) % m;
                let (lo, hi) = (starts[c], starts[c + 1]);
                let (dst_words, src_words) = pair_mut(bufs, dst, r);
                bitpack::copy_packed_codes(&mut **dst_words, &**src_words, bits, lo, hi);
                traffic.seg(hi - lo, bits, 2.0);
                traffic.wire(hi - lo, bits);
                traffic.steps += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tree and naive schedules, packed-resident
// ---------------------------------------------------------------------------

/// Binary-tree schedule over packed operands: gap-doubling pair adds up to
/// rank 0 (each a whole-range add-with-carry — partial sums hold at most
/// `m` contributions, so the resident width is carry-safe), then a packed
/// broadcast down. Mirrors [`super::tree_allreduce_sum_t`]'s reduction
/// order exactly.
#[derive(Clone, Copy, Debug, Default)]
pub struct TreeReduce;

impl PackedReduce for TreeReduce {
    fn name(&self) -> &'static str {
        "tree"
    }

    fn reduce(
        &self,
        bufs: &mut [&mut [u64]],
        bits: u32,
        n_codes: usize,
        traffic: &mut PlaneTraffic,
    ) {
        let m = bufs.len();
        if m <= 1 || n_codes == 0 {
            return;
        }
        let mut gap = 1;
        while gap < m {
            let mut r = 0;
            while r + gap < m {
                let (dst_words, src_words) = pair_mut(bufs, r, r + gap);
                bitpack::add_packed_codes(&mut **dst_words, &**src_words, bits, 0, n_codes);
                traffic.seg(n_codes, bits, 3.0);
                traffic.wire(n_codes, bits);
                traffic.steps += 1;
                r += gap * 2;
            }
            gap *= 2;
        }
        for r in 1..m {
            let (dst_words, src_words) = pair_mut(bufs, r, 0);
            bitpack::copy_packed_codes(&mut **dst_words, &**src_words, bits, 0, n_codes);
            traffic.seg(n_codes, bits, 2.0);
            traffic.wire(n_codes, bits);
            traffic.steps += 1;
        }
    }

    fn hops(&self, m: usize) -> usize {
        if m <= 1 {
            0
        } else {
            // ceil(log2 m) reduce rounds up + the same broadcast down,
            // each moving the full buffer (the latency-optimal shape
            // `NetConfig::tree_s` models)
            2 * (usize::BITS - (m - 1).leading_zeros()) as usize
        }
    }

    fn hop_wire_bytes(&self, _h: usize, elems: usize, bits: u32, _m: usize) -> f64 {
        bitpack::wire_bytes_for(elems, bits) as f64
    }

    fn comm_s(&self, net: &NetConfig, elems: usize, bits: u32) -> f64 {
        // hierarchical model (intra-node rounds on NVLink, inter-node on
        // Ethernet) at the resident width; `net.algo` is Tree whenever this
        // schedule is resolved from a step context
        if net.workers <= 1 || elems == 0 {
            return 0.0;
        }
        net.allreduce_s(bitpack::wire_bytes_for(elems, bits) as f64)
    }
}

/// Naive schedule over packed operands: accumulate every rank's buffer into
/// rank 0 with whole-range adds, then broadcast the packed sum. The wire
/// model matches [`crate::netsim::NetConfig`]'s naive cost: `m - 1`
/// full-buffer transfers per worker.
#[derive(Clone, Copy, Debug, Default)]
pub struct NaiveReduce;

impl PackedReduce for NaiveReduce {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn reduce(
        &self,
        bufs: &mut [&mut [u64]],
        bits: u32,
        n_codes: usize,
        traffic: &mut PlaneTraffic,
    ) {
        let m = bufs.len();
        if m <= 1 || n_codes == 0 {
            return;
        }
        for r in 1..m {
            let (dst_words, src_words) = pair_mut(bufs, 0, r);
            bitpack::add_packed_codes(&mut **dst_words, &**src_words, bits, 0, n_codes);
            traffic.seg(n_codes, bits, 3.0);
            traffic.wire(n_codes, bits);
            traffic.steps += 1;
        }
        for r in 1..m {
            let (dst_words, src_words) = pair_mut(bufs, r, 0);
            bitpack::copy_packed_codes(&mut **dst_words, &**src_words, bits, 0, n_codes);
            traffic.seg(n_codes, bits, 2.0);
            traffic.wire(n_codes, bits);
            traffic.steps += 1;
        }
    }

    fn hops(&self, m: usize) -> usize {
        m.saturating_sub(1)
    }

    fn hop_wire_bytes(&self, _h: usize, elems: usize, bits: u32, _m: usize) -> f64 {
        bitpack::wire_bytes_for(elems, bits) as f64
    }

    fn comm_s(&self, net: &NetConfig, elems: usize, bits: u32) -> f64 {
        // hierarchical model at the resident width (see TreeReduce::comm_s)
        if net.workers <= 1 || elems == 0 {
            return 0.0;
        }
        net.allreduce_s(bitpack::wire_bytes_for(elems, bits) as f64)
    }
}

// ---------------------------------------------------------------------------
// Hierarchical two-level schedule (PR 8)
// ---------------------------------------------------------------------------

/// Two-level packed schedule for multi-GPU-per-node clusters: a full-width
/// reduce-scatter + all-gather ring inside each contiguous NVLink island
/// (`gpus_per_node` ranks), then the compressed fixed-or-growing packed
/// ring **only across the island leaders** over the inter-node link, and
/// finally an intra-island broadcast of the global sum. Ranks are grouped
/// into islands by contiguous blocks (`island(w) = w / gpus_per_node`; the
/// last island may be ragged), matching how the elastic cohort compacts:
/// a leaving worker shrinks its island — the leader ring only loses a node
/// when an island empties.
///
/// **Payload parity.** Every phase is an exact integer reduction of biased
/// codes at a carry-safe width (island partials hold `<= g` contributions,
/// leader partials `<= m`, both within the resident headroom), and integer
/// addition is associative — so the final decoded payload is bit-identical
/// to every flat schedule's. Only timing and the per-level wire ledgers
/// differ, and those are pinned against closed forms.
///
/// **Per-level charge model** ([`PackedReduce::hop_level`] tags each hop):
/// * island all-reduce: `2(g−1)` Intra hops × `ceil(elems/g)`-code
///   segments at the resident width;
/// * leader ring: `2(nodes−1)` Inter hops × `ceil(elems/nodes)`-code
///   segments — resident width when fixed, `bitlen(2·k·g·lmax)` (capped at
///   resident) on reduce-scatter hop `k` when growing: an island sum is one
///   contribution bounded by `g·lmax`, so [`RingGrowing`]'s width law
///   composes with `lmax → g·lmax`;
/// * island broadcast: `2(g−1)` Intra hops × `ceil(elems/g)`-code segments
///   at the resident width (a scatter + all-gather pipelined broadcast —
///   the data plane performs the bit-identical simple copy, the wire model
///   charges the efficient schedule, the same convention tree/naive use).
///
/// Degenerate shapes collapse honestly: one island (`nodes == 1`) is
/// exactly the flat fixed ring on NVLink, one GPU per node (`g == 1`) is
/// exactly the flat ring on Ethernet.
#[derive(Clone, Copy, Debug)]
pub struct Hierarchical {
    /// island size (GPUs per NVLink island); islands are contiguous blocks
    pub gpus_per_node: usize,
    /// per-contribution level bound of the scheme (the per-rank bias)
    pub lmax: usize,
    /// leader-ring wire width: grow with the island-sum partial count?
    /// (the intra phases always run fixed — NVLink outruns the re-packer)
    pub growing: bool,
}

impl Hierarchical {
    /// `(g, nodes)` for an `m`-rank cohort: the island size clamped to the
    /// cohort and the leader-ring length `ceil(m/g)`.
    fn shape(&self, m: usize) -> (usize, usize) {
        let g = self.gpus_per_node.clamp(1, m.max(1));
        (g, m.div_ceil(g))
    }

    /// Wire width of leader-ring hop `h` (0-based within the inter phase):
    /// reduce-scatter hop `h` carries `k = h + 1` island sums, each bounded
    /// by `g·lmax`; all-gather hops carry completed sums at the resident
    /// width. Capped at the resident width (the values always fit it).
    fn leader_hop_width(&self, h: usize, g: usize, nodes: usize, bits: u32) -> u32 {
        if self.growing && h + 1 < nodes {
            growing_hop_bits(self.lmax.saturating_mul(g), h + 1).min(bits)
        } else {
            bits
        }
    }
}

impl PackedReduce for Hierarchical {
    fn name(&self) -> &'static str {
        if self.growing {
            "hier-growing"
        } else {
            "hier-fixed"
        }
    }

    fn reduce(
        &self,
        bufs: &mut [&mut [u64]],
        bits: u32,
        n_codes: usize,
        traffic: &mut PlaneTraffic,
    ) {
        let m = bufs.len();
        if m <= 1 || n_codes == 0 {
            return;
        }
        let (g, nodes) = self.shape(m);
        // phase A: island-local RS+AG all-reduce at the resident width —
        // every island member (the leader included) ends with the island sum
        if g > 1 {
            for island in bufs.chunks_mut(g) {
                ring_allreduce_biased_range(island, bits, n_codes, traffic);
            }
        }
        if nodes <= 1 {
            return; // single island: the island sum IS the global sum
        }
        // phase B: compressed ring across the island leaders only. An
        // island sum is one contribution bounded by g*lmax, so the growing
        // ring composes with the scaled bound (width capped at resident).
        {
            let mut leaders: Vec<&mut [u64]> = bufs
                .chunks_mut(g)
                .filter_map(|island| match island {
                    [first, ..] => Some(&mut **first),
                    [] => None,
                })
                .collect();
            if self.growing {
                RingGrowing { lmax: self.lmax.saturating_mul(g) }
                    .reduce(&mut leaders, bits, n_codes, traffic);
            } else {
                ring_allreduce_biased_range(&mut leaders, bits, n_codes, traffic);
            }
        }
        // phase C: broadcast the global sum back into each island (data
        // plane: a packed copy per member; wire model: scatter + all-gather)
        if g > 1 {
            for island in bufs.chunks_mut(g) {
                if let [leader, rest @ ..] = island {
                    for member in rest {
                        bitpack::copy_packed_codes(&mut **member, &**leader, bits, 0, n_codes);
                        traffic.seg(n_codes, bits, 2.0);
                        traffic.wire(n_codes, bits);
                        traffic.steps += 1;
                    }
                }
            }
        }
    }

    fn hops(&self, m: usize) -> usize {
        if m <= 1 {
            return 0;
        }
        let (g, nodes) = self.shape(m);
        if nodes <= 1 {
            // one island: plain intra ring (g == m here)
            2 * (g - 1)
        } else {
            // island all-reduce + leader ring + island broadcast
            4 * g.saturating_sub(1) + 2 * (nodes - 1)
        }
    }

    fn hop_wire_bytes(&self, h: usize, elems: usize, bits: u32, m: usize) -> f64 {
        let (g, nodes) = self.shape(m);
        let island_seg = bitpack::wire_bytes_for(elems.div_ceil(g), bits) as f64;
        if nodes <= 1 {
            return island_seg;
        }
        let intra_a = 2 * g.saturating_sub(1);
        let inter = 2 * (nodes - 1);
        if h >= intra_a && h < intra_a + inter {
            let hh = h - intra_a;
            let w = self.leader_hop_width(hh, g, nodes, bits);
            bitpack::wire_bytes_for(elems.div_ceil(nodes), w) as f64
        } else {
            island_seg
        }
    }

    fn hop_level(&self, h: usize, m: usize) -> Option<LinkLevel> {
        let (g, nodes) = self.shape(m);
        if nodes <= 1 {
            return Some(LinkLevel::Intra);
        }
        let intra_a = 2 * g.saturating_sub(1);
        let inter = 2 * (nodes - 1);
        Some(if h >= intra_a && h < intra_a + inter {
            LinkLevel::Inter
        } else {
            LinkLevel::Intra
        })
    }
}

/// The schedule for a [`crate::netsim::Algo`] + ring-width choice.
/// `lmax` is the per-contribution level bound (ignored off-ring and for the
/// fixed ring); `growing` selects [`RingGrowing`] on the ring.
pub fn schedule_for(algo: crate::netsim::Algo, growing: bool, lmax: usize) -> PackedSchedule {
    match algo {
        crate::netsim::Algo::Ring if growing => PackedSchedule::RingGrowing(RingGrowing { lmax }),
        crate::netsim::Algo::Ring => PackedSchedule::RingFixed(RingFixed),
        crate::netsim::Algo::Tree => PackedSchedule::Tree(TreeReduce),
        crate::netsim::Algo::Naive => PackedSchedule::Naive(NaiveReduce),
    }
}

/// Topology-aware schedule resolution (PR 8): [`Hierarchical`] when the
/// hierarchical policy is on, the algo is the ring, and the `m`-rank cohort
/// genuinely spans more than one multi-GPU island over `gpus_per_node`;
/// otherwise exactly [`schedule_for`]. `growing` picks the **leader ring's**
/// width on the hierarchical schedule (the island phases always run fixed).
pub fn schedule_for_topo(
    algo: crate::netsim::Algo,
    growing: bool,
    lmax: usize,
    hier: bool,
    gpus_per_node: usize,
    m: usize,
) -> PackedSchedule {
    if hier && matches!(algo, crate::netsim::Algo::Ring) {
        let g = gpus_per_node.clamp(1, m.max(1));
        if g > 1 && m.div_ceil(g) > 1 {
            return PackedSchedule::Hier(Hierarchical { gpus_per_node: g, lmax, growing });
        }
    }
    schedule_for(algo, growing, lmax)
}

/// Owned, allocation-free sum of the five schedules (so callers can select
/// per step without boxing); derefs to the trait via [`PackedSchedule::as_dyn`].
#[derive(Clone, Copy, Debug)]
pub enum PackedSchedule {
    RingFixed(RingFixed),
    RingGrowing(RingGrowing),
    Tree(TreeReduce),
    Naive(NaiveReduce),
    Hier(Hierarchical),
}

impl PackedSchedule {
    pub fn as_dyn(&self) -> &dyn PackedReduce {
        match self {
            PackedSchedule::RingFixed(s) => s,
            PackedSchedule::RingGrowing(s) => s,
            PackedSchedule::Tree(s) => s,
            PackedSchedule::Naive(s) => s,
            PackedSchedule::Hier(s) => s,
        }
    }
}

/// Analytic wire seconds of one schedule pass for the given net — the
/// comm_s [`super::StepCtx::charge_packed`] books ([`PackedReduce::comm_s`]),
/// exposed as a free fn so tests can pin the charge against the formula.
pub fn analytic_comm_s(
    sched: &dyn PackedReduce,
    net: &NetConfig,
    elems: usize,
    bits: u32,
) -> f64 {
    sched.comm_s(net, elems, bits)
}

/// Pack-per-hop reference schedule: identical ring, but every reduce hop
/// unpacks both segments through the offset kernels, adds in the integer
/// domain, and repacks — all at the fixed resident width. Kept as the
/// baseline the property tests pin [`ring_allreduce_biased_range`] and the
/// width-growing schedule bit-identical to.
pub fn ring_allreduce_biased_range_reference(
    bufs: &mut [&mut [u64]],
    bits: u32,
    n_codes: usize,
) {
    let m = bufs.len();
    if m <= 1 || n_codes == 0 {
        return;
    }
    let starts = chunk_starts(n_codes, m);
    let max_chunk = (1..=m).map(|c| starts[c] - starts[c - 1]).max().unwrap_or(0);
    let mut a = vec![0u64; max_chunk];
    let mut b = vec![0u64; max_chunk];

    for step in 0..m - 1 {
        for r in 0..m {
            let c = (r + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let len = hi - lo;
            let (dst_words, src_words) = pair_mut(bufs, dst, r);
            bitpack::unpack_codes_at(&**src_words, bits, lo, &mut a[..len]);
            bitpack::unpack_codes_at(&**dst_words, bits, lo, &mut b[..len]);
            for (x, y) in b[..len].iter_mut().zip(&a[..len]) {
                *x += *y;
            }
            bitpack::pack_codes_at(&b[..len], bits, &mut **dst_words, lo);
        }
    }
    for step in 0..m - 1 {
        for r in 0..m {
            let c = (r + 1 + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (dst_words, src_words) = pair_mut(bufs, dst, r);
            bitpack::copy_packed_codes(&mut **dst_words, &**src_words, bits, lo, hi);
        }
    }
}

/// Convenience wrapper over whole [`Packed`] buffers (all at the same
/// resident width and length, biased codes), reduced by `sched`. Used by
/// the benches and tests; the fused pipelined hot path drives
/// [`PackedReduce::reduce`] directly on per-chunk word views.
pub fn allreduce_sum_packed_sched(
    sched: &dyn PackedReduce,
    bufs: &mut [Packed],
    traffic: &mut PlaneTraffic,
) {
    let m = bufs.len();
    if m <= 1 {
        return;
    }
    let bits = bufs[0].bits;
    let len = bufs[0].len;
    assert!(
        bufs.iter().all(|p| p.bits == bits && p.len == len),
        "ragged packed buffers"
    );
    let mut views: Vec<&mut [u64]> = bufs.iter_mut().map(|p| p.words.as_mut_slice()).collect();
    sched.reduce(&mut views, bits, len, traffic);
}

/// [`allreduce_sum_packed_sched`] at the fixed-width ring (the historical
/// entry point the benches and StepCtx wrapper use).
pub fn ring_allreduce_sum_packed(bufs: &mut [Packed], traffic: &mut PlaneTraffic) {
    allreduce_sum_packed_sched(&RingFixed, bufs, traffic)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::bitpack::{pack_biased_int, packed_sum_bits, unpack_biased_i64_at};
    use crate::util::quickcheck::{check, ensure};

    fn random_levels(
        g: &mut crate::util::quickcheck::Gen,
        lmax: usize,
        m: usize,
        n: usize,
    ) -> Vec<Vec<i32>> {
        (0..m)
            .map(|_| {
                (0..n)
                    .map(|_| g.rng().next_below(2 * lmax as u64 + 1) as i32 - lmax as i32)
                    .collect()
            })
            .collect()
    }

    fn all_schedules(lmax: usize) -> Vec<PackedSchedule> {
        vec![
            PackedSchedule::RingFixed(RingFixed),
            PackedSchedule::RingGrowing(RingGrowing { lmax }),
            PackedSchedule::Tree(TreeReduce),
            PackedSchedule::Naive(NaiveReduce),
            // two-level shapes, exact and ragged islands, both leader widths
            PackedSchedule::Hier(Hierarchical { gpus_per_node: 2, lmax, growing: false }),
            PackedSchedule::Hier(Hierarchical { gpus_per_node: 3, lmax, growing: true }),
            PackedSchedule::Hier(Hierarchical { gpus_per_node: 4, lmax, growing: true }),
        ]
    }

    #[test]
    fn live_m_resident_width_rederives_and_stays_carry_safe() {
        // the elastic layer re-derives bitlen(2*M_live*lmax) per step from
        // the surviving cohort; the partial sum must stay carry-safe at
        // the narrower width even with worst-case level magnitudes
        let lmax = 7usize; // 4-bit levels
        let n = 301usize;
        for live in [2usize, 3, 4, 7] {
            let bits = packed_sum_bits(lmax, live);
            let levels: Vec<Vec<i32>> = (0..live)
                .map(|r| vec![if r % 2 == 0 { lmax as i32 } else { -(lmax as i32) }; n])
                .collect();
            let want: i64 = levels.iter().map(|l| l[0] as i64).sum();
            let mut bufs: Vec<Packed> =
                levels.iter().map(|l| pack_biased_int(l, lmax as i64, bits)).collect();
            let mut t = PlaneTraffic::default();
            allreduce_sum_packed_sched(&RingFixed, &mut bufs, &mut t);
            let mut got = vec![0i64; n];
            unpack_biased_i64_at(&bufs[0].words, bits, 0, (live as i64) * lmax as i64, &mut got);
            assert!(got.iter().all(|&x| x == want), "live={live} bits={bits}");
        }
        // the narrower width is not cosmetic: a 4-survivor cohort of a
        // 16-worker cluster ships strictly fewer wire bytes per segment
        assert!(packed_sum_bits(lmax, 4) < packed_sum_bits(lmax, 16));
        assert!(
            bitpack::wire_bytes_for(1000, packed_sum_bits(lmax, 4))
                < bitpack::wire_bytes_for(1000, packed_sum_bits(lmax, 16))
        );
    }

    #[test]
    fn prop_every_schedule_equals_integer_naive() {
        // the tentpole contract: ring (fixed + growing), tree, and naive
        // packed reducers all produce the exact integer sum on every rank.
        check("packed schedules == naive integer sum", 100, |g| {
            let m = g.usize_in(1, 9);
            let lmax = *g.pick(&[1usize, 7, 127, 2047]);
            let n = g.size_scaled(0, 2000);
            let bits = packed_sum_bits(lmax, m);
            let levels = random_levels(g, lmax, m, n);
            let want: Vec<i64> = (0..n)
                .map(|i| levels.iter().map(|l| l[i] as i64).sum::<i64>())
                .collect();
            let bias_total = (m as i64) * lmax as i64;
            for sched in all_schedules(lmax) {
                let mut bufs: Vec<Packed> =
                    levels.iter().map(|l| pack_biased_int(l, lmax as i64, bits)).collect();
                let mut traffic = PlaneTraffic::default();
                allreduce_sum_packed_sched(sched.as_dyn(), &mut bufs, &mut traffic);
                let mut got = vec![0i64; n];
                for (r, p) in bufs.iter().enumerate() {
                    unpack_biased_i64_at(&p.words, bits, 0, bias_total, &mut got);
                    if got != want {
                        let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
                        return Err(format!(
                            "{} rank {r} field {bad}: {} vs {} (m={m} lmax={lmax} bits={bits})",
                            sched.as_dyn().name(),
                            got[bad],
                            want[bad]
                        ));
                    }
                }
                if m > 1 && n > 0 {
                    ensure(traffic.bytes_moved > 0.0, "traffic counter must move")?;
                    ensure(traffic.wire_bits > 0.0, "wire counter must move")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fast_add_path_bit_identical_to_pack_per_hop_reference() {
        // the in-place add-with-carry hops and the width-growing hops both
        // produce the exact same packed words as the unpack -> add -> repack
        // reference schedule.
        check("adc + growing ring == pack-per-hop reference", 100, |g| {
            let m = g.usize_in(2, 9);
            let lmax = *g.pick(&[1usize, 7, 127]);
            let n = g.size_scaled(1, 1500);
            let bits = packed_sum_bits(lmax, m);
            let levels = random_levels(g, lmax, m, n);
            let mut fast: Vec<Packed> =
                levels.iter().map(|l| pack_biased_int(l, lmax as i64, bits)).collect();
            let mut grow = fast.clone();
            let mut slow = fast.clone();
            let mut traffic = PlaneTraffic::default();
            ring_allreduce_sum_packed(&mut fast, &mut traffic);
            let mut gt = PlaneTraffic::default();
            allreduce_sum_packed_sched(&RingGrowing { lmax }, &mut grow, &mut gt);
            let mut views: Vec<&mut [u64]> =
                slow.iter_mut().map(|p| p.words.as_mut_slice()).collect();
            ring_allreduce_biased_range_reference(&mut views, bits, n);
            for r in 0..m {
                if fast[r] != slow[r] {
                    return Err(format!("rank {r} adc words differ (m={m} lmax={lmax} n={n})"));
                }
                if grow[r] != slow[r] {
                    return Err(format!("rank {r} growing words differ (m={m} lmax={lmax} n={n})"));
                }
            }
            ensure(traffic.steps == 2 * m * (m - 1), "step count")?;
            // the growing schedule may never ship more wire bits
            ensure(
                gt.wire_bits <= traffic.wire_bits,
                &format!("growing wire {} > fixed wire {}", gt.wire_bits, traffic.wire_bits),
            )
        });
    }

    #[test]
    fn bytes_moved_matches_analytic_formula_per_schedule() {
        // satellite regression: the data-plane ledger equals the closed-form
        // per-schedule traffic. Adds touch 3 field passes, copies 2; chunks
        // partition [0, n), so fixed ring, tree, and naive all move
        // 5*(m-1)*n*bits/8 bytes; the growing ring adds 2 wire-staging
        // passes per reduce-scatter segment at the hop width.
        for &(m, lmax, n) in &[(2usize, 7usize, 257usize), (5, 1, 1000), (8, 127, 513)] {
            let bits = packed_sum_bits(lmax, m);
            let levels: Vec<Vec<i32>> =
                (0..m).map(|r| vec![(r % 3) as i32 - 1; n]).collect();
            let field_bytes = (n * bits as usize) as f64 / 8.0;
            let flat = 5.0 * (m - 1) as f64 * field_bytes;
            for sched in [
                PackedSchedule::RingFixed(RingFixed),
                PackedSchedule::Tree(TreeReduce),
                PackedSchedule::Naive(NaiveReduce),
            ] {
                let mut bufs: Vec<Packed> =
                    levels.iter().map(|l| pack_biased_int(l, lmax as i64, bits)).collect();
                let mut t = PlaneTraffic::default();
                allreduce_sum_packed_sched(sched.as_dyn(), &mut bufs, &mut t);
                assert!(
                    (t.bytes_moved - flat).abs() < 1e-6,
                    "{}: bytes_moved {} != analytic {flat} (m={m} bits={bits})",
                    sched.as_dyn().name(),
                    t.bytes_moved
                );
            }
            // growing ring: flat resident traffic + 2 wire passes per
            // reduce-scatter segment at that hop's width
            let starts = chunk_starts(n, m);
            let mut wire_extra = 0.0;
            for step in 0..m - 1 {
                let w = growing_hop_bits(lmax, step + 1) as usize;
                for c in 0..m {
                    wire_extra += 2.0 * ((starts[c + 1] - starts[c]) * w) as f64 / 8.0;
                }
            }
            let mut bufs: Vec<Packed> =
                levels.iter().map(|l| pack_biased_int(l, lmax as i64, bits)).collect();
            let mut t = PlaneTraffic::default();
            allreduce_sum_packed_sched(&RingGrowing { lmax }, &mut bufs, &mut t);
            assert!(
                (t.bytes_moved - (flat + wire_extra)).abs() < 1e-6,
                "growing: bytes_moved {} != analytic {} (m={m} bits={bits})",
                t.bytes_moved,
                flat + wire_extra
            );
        }
    }

    #[test]
    fn hop_models_match_netsim_shapes() {
        // per-worker hop counts and widths the clock charges: ring
        // 2(m-1) segments, tree 2*ceil(log2 m) full buffers, naive m-1
        // full buffers; growing reduce-scatter hops are narrow.
        let (elems, bits, m) = (1000usize, 8u32, 6usize);
        assert_eq!(RingFixed.hops(m), 10);
        assert_eq!(TreeReduce.hops(m), 6); // ceil(log2 6) = 3, up + down
        assert_eq!(NaiveReduce.hops(m), 5);
        assert_eq!(
            RingFixed.hop_wire_bytes(0, elems, bits, m),
            bitpack::wire_bytes_for(167, bits) as f64
        );
        assert_eq!(
            TreeReduce.hop_wire_bytes(0, elems, bits, m),
            bitpack::wire_bytes_for(elems, bits) as f64
        );
        let grow = RingGrowing { lmax: 7 };
        // first hop ships 1-contribution partials: bitlen(14) = 4 bits
        assert_eq!(
            grow.hop_wire_bytes(0, elems, bits, m),
            bitpack::wire_bytes_for(167, 4) as f64
        );
        // all-gather hops ship the full resident width
        assert_eq!(
            grow.hop_wire_bytes(m - 1, elems, bits, m),
            bitpack::wire_bytes_for(167, bits) as f64
        );
        // growing total never exceeds fixed total
        let total = |s: &dyn PackedReduce| -> f64 {
            (0..s.hops(m)).map(|h| s.hop_wire_bytes(h, elems, bits, m)).sum()
        };
        assert!(total(&grow) < total(&RingFixed));
    }

    #[test]
    fn hierarchical_hop_model_matches_closed_form() {
        // PR 8: hop count, per-hop bytes, per-hop level, and comm_s of the
        // two-level schedule, pinned against the hand-written closed form
        // on the paper topology (32 nodes x 4 GPUs).
        use crate::netsim::LinkLevel;
        let (elems, lmax, m, g, nodes) = (1_000_000usize, 7usize, 128usize, 4usize, 32usize);
        let bits = packed_sum_bits(lmax, m);
        let net = NetConfig::paper_cluster(10.0);
        let island_seg = bitpack::wire_bytes_for(elems.div_ceil(g), bits) as f64;
        let leader_seg = |w: u32| bitpack::wire_bytes_for(elems.div_ceil(nodes), w) as f64;

        for growing in [false, true] {
            let h = Hierarchical { gpus_per_node: g, lmax, growing };
            assert_eq!(h.hops(m), 4 * (g - 1) + 2 * (nodes - 1)); // 12 + 62
            let mut want_comm = 0.0;
            let mut want_intra_bytes = 0.0;
            let mut want_inter_bytes = 0.0;
            for hop in 0..h.hops(m) {
                let inter_hop = hop >= 2 * (g - 1) && hop < 2 * (g - 1) + 2 * (nodes - 1);
                let bytes = if inter_hop {
                    let hh = hop - 2 * (g - 1);
                    let w = if growing && hh + 1 < nodes {
                        growing_hop_bits(g * lmax, hh + 1).min(bits)
                    } else {
                        bits
                    };
                    leader_seg(w)
                } else {
                    island_seg
                };
                assert_eq!(
                    h.hop_wire_bytes(hop, elems, bits, m),
                    bytes,
                    "hop {hop} bytes (growing={growing})"
                );
                let level = if inter_hop { LinkLevel::Inter } else { LinkLevel::Intra };
                assert_eq!(h.hop_level(hop, m), Some(level), "hop {hop} level");
                want_comm += net.hop_s_on(level, bytes);
                if inter_hop {
                    want_inter_bytes += bytes;
                } else {
                    want_intra_bytes += bytes;
                }
            }
            let got = h.comm_s(&net, elems, bits);
            assert!(
                (got - want_comm).abs() <= 1e-12 * want_comm,
                "comm_s closed form (growing={growing}): {got} vs {want_comm}"
            );
            // the per-level split the clock ledgers see
            assert_eq!(want_intra_bytes, 4.0 * (g - 1) as f64 * island_seg);
            assert!(want_inter_bytes > 0.0);
            // the tentpole economics: the two-level schedule beats the flat
            // 128-rank Ethernet ring in simulated time (the bench gate)
            let flat = RingFixed.comm_s(&net, elems, bits);
            assert!(got < flat, "hier {got} must beat flat {flat} (growing={growing})");
        }
        // growing leader ring never ships more inter bytes than fixed
        let total_inter = |growing: bool| -> f64 {
            let h = Hierarchical { gpus_per_node: g, lmax, growing };
            (0..h.hops(m))
                .filter(|&hop| h.hop_level(hop, m) == Some(LinkLevel::Inter))
                .map(|hop| h.hop_wire_bytes(hop, elems, bits, m))
                .sum()
        };
        assert!(total_inter(true) < total_inter(false));
    }

    #[test]
    fn hierarchical_degenerates_to_flat_ring() {
        // one island (nodes == 1) or one GPU per node (g == 1): the
        // two-level schedule collapses to the flat fixed ring's hop shape,
        // and schedule_for_topo resolves it away entirely.
        use crate::netsim::{Algo, LinkLevel};
        let (elems, lmax) = (4096usize, 3usize);
        let m = 4usize;
        let bits = packed_sum_bits(lmax, m);

        // nodes == 1 on a single-node net: same hops, bytes, and comm as flat
        let one_island = Hierarchical { gpus_per_node: 4, lmax, growing: true };
        let net = NetConfig::single_node(m);
        assert_eq!(one_island.hops(m), RingFixed.hops(m));
        for h in 0..one_island.hops(m) {
            assert_eq!(
                one_island.hop_wire_bytes(h, elems, bits, m),
                RingFixed.hop_wire_bytes(h, elems, bits, m)
            );
            assert_eq!(one_island.hop_level(h, m), Some(LinkLevel::Intra));
        }
        assert_eq!(one_island.comm_s(&net, elems, bits), RingFixed.comm_s(&net, elems, bits));

        // g == 1 on a flat net: identical to the flat ring on Ethernet
        let flat_g1 = Hierarchical { gpus_per_node: 1, lmax, growing: false };
        let flat_net = NetConfig::flat(m, 10.0);
        assert_eq!(flat_g1.hops(m), RingFixed.hops(m));
        for h in 0..flat_g1.hops(m) {
            assert_eq!(
                flat_g1.hop_wire_bytes(h, elems, bits, m),
                RingFixed.hop_wire_bytes(h, elems, bits, m)
            );
            assert_eq!(flat_g1.hop_level(h, m), Some(LinkLevel::Inter));
        }
        assert_eq!(
            flat_g1.comm_s(&flat_net, elems, bits),
            RingFixed.comm_s(&flat_net, elems, bits)
        );

        // resolution: hier only materializes on true two-level shapes
        assert!(matches!(
            schedule_for_topo(Algo::Ring, false, lmax, true, 4, 128),
            PackedSchedule::Hier(_)
        ));
        assert!(matches!(
            schedule_for_topo(Algo::Ring, false, lmax, true, 4, 4),
            PackedSchedule::RingFixed(_)
        ));
        assert!(matches!(
            schedule_for_topo(Algo::Ring, true, lmax, true, 1, 128),
            PackedSchedule::RingGrowing(_)
        ));
        assert!(matches!(
            schedule_for_topo(Algo::Tree, false, lmax, true, 4, 128),
            PackedSchedule::Tree(_)
        ));
        assert!(matches!(
            schedule_for_topo(Algo::Ring, false, lmax, false, 4, 128),
            PackedSchedule::RingFixed(_)
        ));
    }

    #[test]
    fn hierarchical_traffic_matches_analytic() {
        // data-plane ledger closed form, exact islands, fixed leader ring:
        // phase A is one 5(g-1)-pass ring per island, phase B one
        // 5(nodes-1)-pass ring over the leaders, phase C (g-1) two-pass
        // full-buffer copies per island.
        let (m, g, lmax, n) = (8usize, 4usize, 7usize, 513usize);
        let nodes = m / g;
        let bits = packed_sum_bits(lmax, m);
        let field_bytes = (n * bits as usize) as f64 / 8.0;
        let want = nodes as f64 * 5.0 * (g - 1) as f64 * field_bytes // A
            + 5.0 * (nodes - 1) as f64 * field_bytes                 // B
            + nodes as f64 * 2.0 * (g - 1) as f64 * field_bytes;     // C
        let levels: Vec<Vec<i32>> = (0..m).map(|r| vec![(r % 3) as i32 - 1; n]).collect();
        let mut bufs: Vec<Packed> =
            levels.iter().map(|l| pack_biased_int(l, lmax as i64, bits)).collect();
        let mut t = PlaneTraffic::default();
        let sched = Hierarchical { gpus_per_node: g, lmax, growing: false };
        allreduce_sum_packed_sched(&sched, &mut bufs, &mut t);
        assert!(
            (t.bytes_moved - want).abs() < 1e-6,
            "hier bytes_moved {} != analytic {want}",
            t.bytes_moved
        );
    }

    #[test]
    fn tree_and_naive_comm_keep_the_hierarchy() {
        // regression: moving tree/naive onto the packed plane must not
        // flatten their wire model — a 32x4 NVLink cluster stays cheaper
        // than 128 flat-Ethernet workers (comm_s override), while the ring
        // keeps the PR 2 bottleneck-link hop charging.
        use crate::netsim::Algo;
        let (elems, bits) = (1 << 20, 8u32);
        for algo in [Algo::Tree, Algo::Naive] {
            let mut hier = NetConfig::paper_cluster(10.0);
            hier.algo = algo;
            let mut flat = NetConfig::flat(128, 10.0);
            flat.algo = algo;
            let sched: &dyn PackedReduce =
                if algo == Algo::Tree { &TreeReduce } else { &NaiveReduce };
            assert!(
                sched.comm_s(&hier, elems, bits) < sched.comm_s(&flat, elems, bits),
                "{}: NVLink hierarchy must beat flat ethernet",
                sched.name()
            );
        }
        // on a flat cluster the tree override equals the hop-sum shape
        let mut flat = NetConfig::flat(16, 10.0);
        flat.algo = Algo::Tree;
        let hop_sum: f64 = (0..TreeReduce.hops(16))
            .map(|h| flat.hop_s(TreeReduce.hop_wire_bytes(h, elems, bits, 16)))
            .sum();
        let got = TreeReduce.comm_s(&flat, elems, bits);
        assert!((got - hop_sum).abs() <= 1e-12 * hop_sum.max(1.0));
    }

    #[test]
    fn checksum_detects_every_single_bit_flip() {
        // the integrity guarantee: a single-bit corruption anywhere in the
        // segment always changes the rotated xor-fold (each word contributes
        // an invertible rotation, so one flipped input bit flips exactly one
        // fold bit). Exhaustive over every (word, bit) site of a random
        // 70-word segment — wider than one rotation period, so the i % 64
        // wraparound is covered too.
        let mut g = crate::util::rng::Rng::new(0x5EC5);
        let mut words: Vec<u64> = (0..70).map(|_| g.next_u64()).collect();
        let clean = xor_fold_checksum(&words);
        for w in 0..words.len() {
            for b in 0..64u32 {
                words[w] ^= 1u64 << b;
                assert_ne!(
                    xor_fold_checksum(&words),
                    clean,
                    "flip at word {w} bit {b} must change the checksum"
                );
                words[w] ^= 1u64 << b;
            }
        }
        assert_eq!(xor_fold_checksum(&words), clean);
        // ...and the rotation catches the plain-xor blind spot: the same
        // bit flipped in two adjacent words no longer cancels
        words[3] ^= 1 << 17;
        words[4] ^= 1 << 17;
        assert_ne!(xor_fold_checksum(&words), clean);
    }

    #[test]
    fn corrupt_word_is_a_detected_involution() {
        let mut g = crate::util::rng::Rng::new(0xC0DE);
        let mut words: Vec<u64> = (0..9).map(|_| g.next_u64()).collect();
        let orig = words.clone();
        let clean = xor_fold_checksum(&words);
        // arbitrary draw values reduce onto valid sites
        corrupt_word(&mut words, u64::MAX - 2, 77);
        assert_ne!(words, orig, "corruption must change the segment");
        assert_ne!(xor_fold_checksum(&words), clean, "and the checksum must see it");
        corrupt_word(&mut words, u64::MAX - 2, 77);
        assert_eq!(words, orig, "same site twice restores the segment");
        assert_eq!(xor_fold_checksum(&words), clean);
        // empty segment is a no-op
        corrupt_word(&mut [], 5, 5);
    }

    #[test]
    fn traffic_scales_with_resident_width() {
        // same layout, twice the resident width -> twice the bytes moved
        let n = 4096;
        let m = 8;
        let levels: Vec<Vec<i32>> = (0..m).map(|r| vec![(r % 3) as i32; n]).collect();
        let run = |bits: u32| {
            let mut bufs: Vec<Packed> =
                levels.iter().map(|l| pack_biased_int(l, 4, bits)).collect();
            let mut t = PlaneTraffic::default();
            ring_allreduce_sum_packed(&mut bufs, &mut t);
            t.bytes_moved
        };
        let b8 = run(8);
        let b16 = run(16);
        assert!((b16 / b8 - 2.0).abs() < 1e-9, "width ratio: {b8} vs {b16}");
    }
}
