//! Collective-communication substrate: the *data plane* of the simulated
//! cluster.
//!
//! These functions really move and reduce bytes between the logical workers'
//! buffers — the ring all-reduce below is the actual reduce-scatter +
//! all-gather schedule, not a shortcut `sum()` — so that reduction order,
//! chunking, and the compressed-domain aggregation invariant are exercised
//! for real. Simulated wire time is charged separately through
//! [`crate::netsim::NetConfig`] by [`StepCtx`].
//!
//! The compressed hot path's production data plane lives in [`packed`]:
//! every schedule (ring — fixed or width-growing wire — tree, naive)
//! reduces a *resident* operand of packed biased codes through the
//! [`packed::PackedReduce`] trait, charged hop-accurately at the widths the
//! schedule actually ships ([`StepCtx::charge_packed`]).

pub mod packed;

use crate::compress::bitpack::{self, Packed};
use crate::netsim::{FaultPlan, HopFault, LinkLevel, NetConfig, RingWidth, SimClock};
use crate::tensor::LevelInt;

pub use packed::{
    allreduce_sum_packed_sched, corrupt_word, ring_allreduce_sum_packed, schedule_for_topo,
    xor_fold_checksum, Hierarchical, IntegrityConfig, NaiveReduce, PackedReduce, PackedSchedule,
    PlaneTraffic, RingFixed, RingGrowing, RingTraffic, TreeReduce, CHECKSUM_BYTES,
};

/// Elementwise sum all-reduce via the ring schedule, generic over the
/// element type — the same schedule reduces `f32` gradients and the widened
/// integer level buffers of the compressed-domain hot path ([`LevelInt`]).
///
/// Reduction order per element equals the ring order starting at its chunk
/// owner — deterministic and identical across workers and element types,
/// which is what makes the compressed-domain sum bit-reproducible (and lets
/// the integer path be property-tested bit-identical to the f32 path).
///
/// Integer overflow is excluded by the aggregators' widening rule
/// (`workers * s <= T::MAX`); debug builds would panic on violation.
pub fn ring_allreduce_sum_t<T>(bufs: &mut [Vec<T>])
where
    T: Copy + Default + std::ops::AddAssign,
{
    let mut bytes = 0.0;
    ring_allreduce_sum_t_counted(bufs, &mut bytes);
}

/// [`ring_allreduce_sum_t`] with a bytes-moved ledger: accumulates the
/// element bytes the schedule reads and writes (stage copy = 2 accesses,
/// add = 3, all-gather copy-through = 4) into `bytes_moved`. The micro
/// bench compares this against the packed-resident plane's
/// [`packed::RingTraffic`].
pub fn ring_allreduce_sum_t_counted<T>(bufs: &mut [Vec<T>], bytes_moved: &mut f64)
where
    T: Copy + Default + std::ops::AddAssign,
{
    let elem = std::mem::size_of::<T>() as f64;
    let m = bufs.len();
    if m <= 1 {
        return;
    }
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "ragged buffers");
    if n == 0 {
        return;
    }

    // chunk c spans [starts[c], starts[c+1])
    let starts: Vec<usize> = (0..=m).map(|c| c * n / m).collect();
    // one reusable staging buffer for the "send" (perf pass: the per-step
    // to_vec allocations were ~2m² allocs per call)
    let max_chunk = (1..=m).map(|c| starts[c] - starts[c - 1]).max().unwrap_or(0);
    let mut seg = vec![T::default(); max_chunk];

    // reduce-scatter: after m-1 steps, worker r owns the full sum of chunk
    // (r+1) mod m.
    for step in 0..m - 1 {
        for r in 0..m {
            // worker r sends chunk (r - step) mod m to worker (r+1) mod m
            let c = (r + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let len = hi - lo;
            // split borrow: stage the segment (the "send"), add into dst
            seg[..len].copy_from_slice(&bufs[r][lo..hi]);
            let dst_seg = &mut bufs[dst][lo..hi];
            for (d, v) in dst_seg.iter_mut().zip(&seg[..len]) {
                *d += *v;
            }
            // stage copy (r+w) + add (r+r+w)
            *bytes_moved += 5.0 * len as f64 * elem;
        }
    }

    // all-gather: circulate the completed chunks
    for step in 0..m - 1 {
        for r in 0..m {
            let c = (r + 1 + m - step) % m;
            let dst = (r + 1) % m;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let len = hi - lo;
            seg[..len].copy_from_slice(&bufs[r][lo..hi]);
            bufs[dst][lo..hi].copy_from_slice(&seg[..len]);
            // copy-through the staging buffer: r+w, r+w
            *bytes_moved += 4.0 * len as f64 * elem;
        }
    }
}

/// Naive all-reduce, generic: rank 0 gathers + sums + broadcasts.
/// Reference implementation for equivalence tests.
pub fn naive_allreduce_sum_t<T>(bufs: &mut [Vec<T>])
where
    T: Copy + Default + std::ops::AddAssign,
{
    let m = bufs.len();
    if m <= 1 {
        return;
    }
    let n = bufs[0].len();
    let mut acc = vec![T::default(); n];
    for b in bufs.iter() {
        for (a, v) in acc.iter_mut().zip(b) {
            *a += *v;
        }
    }
    for b in bufs.iter_mut() {
        b.copy_from_slice(&acc);
    }
}

/// Binary-tree all-reduce, generic (reduce to rank 0 up the tree,
/// broadcast down).
pub fn tree_allreduce_sum_t<T>(bufs: &mut [Vec<T>])
where
    T: Copy + Default + std::ops::AddAssign,
{
    let m = bufs.len();
    if m <= 1 {
        return;
    }
    // reduce
    let mut gap = 1;
    while gap < m {
        let mut r = 0;
        while r + gap < m {
            let (left, right) = bufs.split_at_mut(r + gap);
            let (dst, src) = (&mut left[r], &right[0]);
            for (a, v) in dst.iter_mut().zip(src.iter()) {
                *a += *v;
            }
            r += gap * 2;
        }
        gap *= 2;
    }
    // broadcast
    let root = bufs[0].clone();
    for b in bufs.iter_mut().skip(1) {
        b.copy_from_slice(&root);
    }
}

/// f32 ring all-reduce (the dense-gradient data plane).
pub fn ring_allreduce_sum(bufs: &mut [Vec<f32>]) {
    ring_allreduce_sum_t(bufs)
}

/// f32 naive all-reduce.
pub fn naive_allreduce_sum(bufs: &mut [Vec<f32>]) {
    naive_allreduce_sum_t(bufs)
}

/// f32 tree all-reduce.
pub fn tree_allreduce_sum(bufs: &mut [Vec<f32>]) {
    tree_allreduce_sum_t(bufs)
}

/// Integer-domain ring all-reduce over i16 level buffers (the fused hot
/// path's narrow operand: half the memory traffic of the old f32 levels).
pub fn ring_allreduce_sum_i16(bufs: &mut [Vec<i16>]) {
    ring_allreduce_sum_t(bufs)
}

/// Integer-domain ring all-reduce over i32 level buffers (the widened
/// fallback for extreme `bits × workers` products).
pub fn ring_allreduce_sum_i32(bufs: &mut [Vec<i32>]) {
    ring_allreduce_sum_t(bufs)
}

/// Max all-reduce over one scalar per worker (the shared `||w||_2`).
pub fn max_allreduce_scalar(vals: &[f32]) -> f32 {
    vals.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b))
}

/// Elementwise min all-reduce over per-worker u8 vectors (scale sharing).
pub fn min_allreduce_u8(vecs: &[Vec<u8>]) -> Vec<u8> {
    let mut out = Vec::new();
    min_allreduce_u8_into(vecs, &mut out);
    out
}

/// [`min_allreduce_u8`] into a caller-provided buffer — the bucketed
/// control plane reduces one scale share per bucket per step, so it reuses
/// a single scratch vector instead of allocating per call.
pub fn min_allreduce_u8_into(vecs: &[Vec<u8>], out: &mut Vec<u8>) {
    let m = vecs.len();
    assert!(m > 0);
    let n = vecs[0].len();
    out.clear();
    out.extend_from_slice(&vecs[0]);
    for v in &vecs[1..] {
        assert_eq!(v.len(), n, "ragged scale vectors");
        for (o, x) in out.iter_mut().zip(v) {
            *o = (*o).min(*x);
        }
    }
}

/// Per-step context handed to aggregators: charges the simulated wire and
/// tracks the bits ledger + phase timings.
pub struct StepCtx<'a> {
    pub net: &'a NetConfig,
    pub clock: &'a mut SimClock,
    /// Wire floor (paper §6: frameworks only ship >=8-bit tensors). When
    /// set, payload bits per coordinate are rounded up to this.
    pub wire_floor_bits: Option<f64>,
    /// Wire-width policy for the packed ring schedule; `Auto` defers to the
    /// per-step analytic selector [`NetConfig::growing_ring_wins`].
    pub ring_width: RingWidth,
    /// Simulated backward-pass seconds of this step (the window gradient
    /// buckets stream out of, [`crate::perfmodel::BACKWARD_FRAC`] of the
    /// step compute). `Some` enables the bucketed control plane's overlap
    /// scheduler to hide bucket communication behind the remaining compute
    /// ([`SimClock::hidden_comm_s`]); `None` (the default) means no overlap
    /// information — every aggregator charges fully exposed comm, exactly
    /// the pre-PR-4 behaviour.
    pub backward_s: Option<f64>,
    /// Hop-segment integrity policy (PR 7). `Some` makes every packed hop
    /// ship a [`packed::xor_fold_checksum`] ([`packed::CHECKSUM_BYTES`]
    /// charged byte-exact per hop on both ledgers) and enables the
    /// retransmit walk against `wire_faults`. `None` (the default) keeps
    /// every charge bit-identical to the pre-integrity plane.
    pub integrity: Option<IntegrityConfig>,
    /// The fault plan and step the retransmit walk draws wire faults from.
    /// `None` (or a plan with `loss = flip = 0`) means a clean wire: no
    /// retransmit charges at all.
    pub wire_faults: Option<(&'a FaultPlan, usize)>,
    /// Topology-aware scheduling (PR 8): when true and the net spans more
    /// than one multi-GPU island, [`StepCtx::packed_schedule`] resolves the
    /// ring to the two-level [`packed::Hierarchical`] schedule (full-width
    /// island all-reduce over `intra`, compressed leader ring over `inter`)
    /// and `RingWidth::Auto` decides the leader ring's width per level
    /// ([`NetConfig::growing_ring_wins_on`] on the Inter link with the
    /// island-sum bound `g·lmax`). `false` (the default) keeps every
    /// resolution bit-identical to the flat planes.
    pub hier: bool,
    /// Step flight recorder (PR 9). `None` (the default) is the zero-cost
    /// off state: no instrumentation site allocates, branches on data, or
    /// touches a charge — the recorder only *reads* clock fields the charge
    /// just wrote, so trace-on runs are bit-identical to trace-off.
    pub tracer: Option<&'a mut crate::trace::Tracer>,
}

impl<'a> StepCtx<'a> {
    pub fn new(net: &'a NetConfig, clock: &'a mut SimClock) -> StepCtx<'a> {
        StepCtx {
            net,
            clock,
            wire_floor_bits: None,
            ring_width: RingWidth::Auto,
            backward_s: None,
            integrity: None,
            wire_faults: None,
            hier: false,
            tracer: None,
        }
    }

    /// The packed reduction schedule for this step: the configured algo,
    /// with the ring's wire width resolved through the policy + analytic
    /// selector. `lmax` is the per-contribution level bound of the scheme.
    pub fn packed_schedule(&self, lmax: usize, m: usize, elems: usize) -> PackedSchedule {
        // the resident width bitlen(2*m*lmax) and the wire's hop counts
        // must describe the same cohort: an elastic step builds its ctx
        // over net_for_step(live), so a mismatch here means a caller mixed
        // a partial cohort's levels with the full cohort's wire (or vice
        // versa) — the sum would still fit only by accident
        debug_assert_eq!(
            m, self.net.workers,
            "packed schedule for m={m} over a {}-worker wire",
            self.net.workers
        );
        let g = self.net.gpus_per_node.clamp(1, m.max(1));
        let nodes = m.div_ceil(g);
        let hier_active =
            self.hier && matches!(self.net.algo, crate::netsim::Algo::Ring) && g > 1 && nodes > 1;
        let growing = match self.ring_width {
            RingWidth::Fixed => false,
            RingWidth::Growing => true,
            // per-level decision (PR 8): on the two-level schedule only the
            // leader ring has a width choice, so Auto asks the selector about
            // the Inter link with the leader ring's shape — `nodes` ranks,
            // island-sum contribution bound `g·lmax`. Flat shapes keep the
            // bottleneck-link form, bit-identical to the pre-hier resolution.
            RingWidth::Auto if hier_active => self.net.growing_ring_wins_on(
                LinkLevel::Inter,
                lmax.saturating_mul(g),
                nodes,
                elems,
            ),
            RingWidth::Auto => self.net.growing_ring_wins(lmax, m, elems),
        };
        packed::schedule_for_topo(self.net.algo, growing, lmax, self.hier, g, m)
    }

    /// Byte-exact payload bits for `elems` coordinates at `bits_per_elem`:
    /// the wire floor (if set) rounds each coordinate up to whole bits, and
    /// the *total* is rounded up to whole bytes — exactly
    /// `8 * bitpack::wire_bytes_for(elems, bpe)`, so the simulated ledger
    /// and the packed wire format agree on every payload. (Previously the
    /// total kept fractional bits, so e.g. 97 coords at 3 bits charged
    /// 291 bits where the packed payload is 37 bytes = 296.)
    fn effective_bits(&self, elems: f64, bits_per_elem: f64) -> f64 {
        let bpe = match self.wire_floor_bits {
            Some(floor) => bits_per_elem.max(floor).ceil(),
            None => bits_per_elem,
        };
        ((elems * bpe) / 8.0).ceil() * 8.0
    }

    /// Sum all-reduce over per-worker equal-length vectors, charging
    /// `bits_per_elem` per coordinate on the wire. Returns the shared sum.
    pub fn allreduce_sum(&mut self, mut bufs: Vec<Vec<f32>>, bits_per_elem: f64) -> Vec<f32> {
        self.allreduce_sum_in_place(&mut bufs, bits_per_elem);
        bufs.into_iter().next().unwrap_or_default()
    }

    /// One body for every element width: charge the wire, then run the
    /// configured reduction schedule over the callers' buffers.
    fn allreduce_sum_in_place_impl<T>(&mut self, bufs: &mut [Vec<T>], bits_per_elem: f64)
    where
        T: Copy + Default + std::ops::AddAssign,
    {
        let elems = bufs.first().map(|b| b.len()).unwrap_or(0) as f64;
        let bits = self.effective_bits(elems, bits_per_elem);
        let c0 = self.clock.comm_s;
        self.clock.comm_s += self.net.allreduce_s(bits / 8.0);
        self.clock.bits_per_worker += bits;
        if let Some(t) = self.tracer.as_deref_mut() {
            let schedule = match self.net.algo {
                crate::netsim::Algo::Ring => "ring",
                crate::netsim::Algo::Tree => "tree",
                crate::netsim::Algo::Naive => "naive",
            };
            t.push(crate::trace::Span::new(
                crate::trace::Cat::Comm,
                crate::trace::SpanKind::Collective { schedule },
                c0,
                self.clock.comm_s,
                bits,
            ));
        }
        match self.net.algo {
            crate::netsim::Algo::Ring => ring_allreduce_sum_t(bufs),
            crate::netsim::Algo::Tree => tree_allreduce_sum_t(bufs),
            crate::netsim::Algo::Naive => naive_allreduce_sum_t(bufs),
        }
    }

    /// Zero-copy variant (perf pass): reduces into the callers' buffers —
    /// all of them end holding the sum, exactly like the real collective.
    pub fn allreduce_sum_in_place(&mut self, bufs: &mut [Vec<f32>], bits_per_elem: f64) {
        self.allreduce_sum_in_place_impl(bufs, bits_per_elem)
    }

    /// Integer-domain sum all-reduce over widened level buffers — the fused
    /// hot path's collective. Charges the same wire bits as the f32-level
    /// path (the wire format is the packed `bits_per_elem` codes either
    /// way); what changes is the *memory* the data plane moves: `i16` is
    /// half the f32 traffic. Overflow is excluded by the aggregators'
    /// widening rule (asserted at construction).
    pub fn allreduce_sum_in_place_int<T: LevelInt>(
        &mut self,
        bufs: &mut [Vec<T>],
        bits_per_elem: f64,
    ) {
        self.allreduce_sum_in_place_impl(bufs, bits_per_elem)
    }

    /// Scalar max all-reduce (`||w||_2` sharing): one 32-bit float.
    pub fn allreduce_max_scalar(&mut self, vals: &[f32]) -> f32 {
        let c0 = self.clock.comm_s;
        self.clock.comm_s += self.net.scalar_allreduce_s();
        self.clock.bits_per_worker += 32.0;
        if let Some(t) = self.tracer.as_deref_mut() {
            let bucket = t.bucket();
            t.push(crate::trace::Span::new(
                crate::trace::Cat::Comm,
                crate::trace::SpanKind::NormShare { bucket },
                c0,
                self.clock.comm_s,
                32.0,
            ));
        }
        max_allreduce_scalar(vals)
    }

    /// Elementwise min all-reduce of scale-index vectors, `bits_per_elem` =
    /// ceil(log2 N) per the paper's scale-sharing overhead.
    pub fn allreduce_min_u8(&mut self, vecs: &[Vec<u8>], bits_per_elem: f64) -> Vec<u8> {
        let mut out = Vec::new();
        self.allreduce_min_u8_into(vecs, bits_per_elem, &mut out);
        out
    }

    /// [`StepCtx::allreduce_min_u8`] into a caller-provided buffer (the
    /// bucketed control plane's per-bucket shares reuse one scratch).
    pub fn allreduce_min_u8_into(
        &mut self,
        vecs: &[Vec<u8>],
        bits_per_elem: f64,
        out: &mut Vec<u8>,
    ) {
        let elems = vecs.first().map(|v| v.len()).unwrap_or(0) as f64;
        let bits = self.effective_bits(elems, bits_per_elem);
        let c0 = self.clock.comm_s;
        self.clock.comm_s += self.net.allreduce_s(bits / 8.0);
        self.clock.bits_per_worker += bits;
        if let Some(t) = self.tracer.as_deref_mut() {
            let bucket = t.bucket();
            t.push(crate::trace::Span::new(
                crate::trace::Cat::Comm,
                crate::trace::SpanKind::ScaleShareReduce { bucket },
                c0,
                self.clock.comm_s,
                bits,
            ));
        }
        min_allreduce_u8_into(vecs, out);
    }

    /// Charge an all-gather where each rank contributes `elems` coordinates
    /// of `bits_per_elem` — byte-exact through [`StepCtx::effective_bits`],
    /// so the sparsified baselines (top-K, sign bits) charge
    /// `ceil(elems*bits/8)` wire bytes instead of fractional bits, matching
    /// the packed wire format. (Data is already centrally resident; only
    /// the wire is charged.)
    pub fn charge_allgather(&mut self, elems: f64, bits_per_elem: f64) {
        let bits_per_rank = self.effective_bits(elems, bits_per_elem);
        let c0 = self.clock.comm_s;
        self.clock.comm_s += self.net.allgather_s(bits_per_rank / 8.0);
        // each worker transmits its payload and receives M-1 others; the
        // ledger tracks *sent* bits per worker to match the paper's metric
        self.clock.bits_per_worker += bits_per_rank;
        if let Some(t) = self.tracer.as_deref_mut() {
            t.push(crate::trace::Span::new(
                crate::trace::Cat::Comm,
                crate::trace::SpanKind::Allgather,
                c0,
                self.clock.comm_s,
                bits_per_rank,
            ));
        }
    }

    /// Ledger + simulated-time charge for one packed-resident collective of
    /// `elems` coordinates reduced by `sched` at `resident_bits`. Two books
    /// are kept:
    ///
    /// * `bits_per_worker` — the paper's nominal accounting (byte-exact
    ///   `elems * payload_bits_per_elem`), identical for every data plane
    ///   and schedule so the ledgers stay comparable;
    /// * `comm_s` / `hop_bits_per_worker` — **hop-accurate**: the bits
    ///   ledger sums the schedule's synchronous hops at the bytes each
    ///   actually ships ([`PackedReduce::hop_wire_bytes`] — resident-width
    ///   ring segments, growing-width partials, full tree/naive buffers),
    ///   and the time charge is the schedule's own wire model
    ///   ([`PackedReduce::comm_s`]: per-level hop-sum for the rings —
    ///   each hop priced on its own link via [`PackedReduce::hop_level`] —
    ///   the hierarchical α–β model at the resident width for tree/naive)
    ///   — the deployment overhead the uniform model hides. The hop-bits
    ///   book is additionally split per link level into
    ///   [`SimClock::hop_bits_intra`] / [`SimClock::hop_bits_inter`]
    ///   (their sum always equals the `hop_bits_per_worker` increment).
    pub fn charge_packed(
        &mut self,
        sched: &dyn PackedReduce,
        elems: usize,
        resident_bits: u32,
        payload_bits_per_elem: f64,
    ) {
        let payload_bits = self.effective_bits(elems as f64, payload_bits_per_elem);
        self.clock.bits_per_worker += payload_bits;
        let m = self.net.workers.max(1);
        if m <= 1 || elems == 0 {
            if let Some(t) = self.tracer.as_deref_mut() {
                let bucket = t.bucket();
                let at = self.clock.comm_s;
                t.push(crate::trace::Span::new(
                    crate::trace::Cat::Comm,
                    crate::trace::SpanKind::Pack { bucket, payload_bits },
                    at,
                    at,
                    payload_bits,
                ));
            }
            return;
        }
        let c0 = self.clock.comm_s;
        self.clock.comm_s += sched.comm_s(self.net, elems, resident_bits);
        let c1 = self.clock.comm_s;
        let fallback = self.net.bottleneck_level();
        // Per-hop shape for the flight recorder: (wire bits, level, weight).
        // Collected only when tracing so the off path allocates nothing.
        let tracing = self.tracer.is_some();
        let mut hop_shape: Vec<(f64, LinkLevel, f64)> = Vec::new();
        for h in 0..sched.hops(m) {
            let bits = sched.hop_wire_bytes(h, elems, resident_bits, m) * 8.0;
            self.clock.hop_bits_per_worker += bits;
            // per-level split of the same book (flat schedules leave
            // hop_level at None and land wholly on the bottleneck level)
            match sched.hop_level(h, m).unwrap_or(fallback) {
                LinkLevel::Intra => self.clock.hop_bits_intra += bits,
                LinkLevel::Inter => self.clock.hop_bits_inter += bits,
            }
            if tracing {
                hop_shape.push((
                    bits,
                    sched.hop_level(h, m).unwrap_or(fallback),
                    sched.hop_time_s(self.net, h, elems, resident_bits, m),
                ));
            }
        }
        if let Some(t) = self.tracer.as_deref_mut() {
            let bucket = t.bucket();
            let name = sched.name();
            t.push(crate::trace::Span::new(
                crate::trace::Cat::Comm,
                crate::trace::SpanKind::Pack { bucket, payload_bits },
                c0,
                c0,
                payload_bits,
            ));
            if hop_shape.is_empty() {
                // A schedule with comm but no hops (cannot happen today:
                // m > 1 implies hops >= 1) still keeps the comm chain whole.
                t.push(crate::trace::Span::new(
                    crate::trace::Cat::Comm,
                    crate::trace::SpanKind::Collective { schedule: name },
                    c0,
                    c1,
                    0.0,
                ));
            } else {
                // Partition the schedule's one comm lump into per-hop
                // windows proportional to each hop's analytic wire time,
                // normalized so the last window ends exactly at the charged
                // snapshot (tree/naive override comm_s with the
                // hierarchical α–β model, so their weights only set shape).
                let w_total: f64 = hop_shape.iter().map(|&(_, _, w)| w).sum();
                let total = c1 - c0;
                let last = hop_shape.len() - 1;
                let mut cum = 0.0;
                let mut prev = c0;
                for (h, &(bits, level, w)) in hop_shape.iter().enumerate() {
                    cum += w;
                    let end = if h == last || w_total <= 0.0 {
                        c1
                    } else {
                        (c0 + total * (cum / w_total)).max(prev).min(c1)
                    };
                    t.push(crate::trace::Span::new(
                        crate::trace::Cat::Comm,
                        crate::trace::SpanKind::Hop {
                            schedule: name,
                            level,
                            hop_idx: h,
                            wire_bits: bits,
                        },
                        prev,
                        end,
                        0.0,
                    ));
                    prev = end;
                }
            }
        }
        self.charge_integrity(sched, elems, resident_bits);
    }

    /// Integrity + retransmit charge of one packed collective (PR 7);
    /// a strict no-op when [`StepCtx::integrity`] is `None`.
    ///
    /// **Checksum:** every hop segment carries [`packed::CHECKSUM_BYTES`]
    /// of [`packed::xor_fold_checksum`], charged on both bit ledgers and —
    /// since the checksum rides the hop's existing packet — as the
    /// bandwidth-only increment `hop_s(seg + 8) - hop_s(seg)` on `comm_s`
    /// (no extra α per hop). With a clean wire the whole charge is the
    /// closed form `64 * hops` bits the parity tests pin.
    ///
    /// **Retransmit walk:** with wire faults armed, each cohort slot's
    /// delivery of each hop draws its fate per attempt from the fault
    /// plan's pure `(seed, step, worker, hop, attempt)` stream. `f`
    /// leading failures trigger `min(f, max_retries)` retransmits, each
    /// charged its exponential-backoff rung plus the checksummed segment's
    /// full wire time (a retransmit is a fresh packet: α included) into
    /// `retrans_s` / `retrans_bits`. A slot that exhausts every retry here
    /// is still charged the full ladder but not dropped — membership is
    /// decided *before* aggregation by the cluster's escalation predicate
    /// ([`FaultPlan::unreachable_peers`], keyed by original worker id; this
    /// walk is keyed by cohort slot, which coincides on the identity
    /// cohort the closed-form tests use). `retrans_bits` is a cohort
    /// total, unlike per-worker `bits_per_worker`.
    fn charge_integrity(&mut self, sched: &dyn PackedReduce, elems: usize, resident_bits: u32) {
        let Some(cfg) = self.integrity else { return };
        let m = self.net.workers.max(1);
        if m <= 1 || elems == 0 {
            return;
        }
        let hops = sched.hops(m);
        let csum_bits = (8 * CHECKSUM_BYTES * hops) as f64;
        self.clock.bits_per_worker += csum_bits;
        self.clock.hop_bits_per_worker += csum_bits;
        let fallback = self.net.bottleneck_level();
        for h in 0..hops {
            // each hop's checksum rides that hop's link (PR 8: per-level)
            let level = sched.hop_level(h, m).unwrap_or(fallback);
            let per_hop_csum = (8 * CHECKSUM_BYTES) as f64;
            match level {
                LinkLevel::Intra => self.clock.hop_bits_intra += per_hop_csum,
                LinkLevel::Inter => self.clock.hop_bits_inter += per_hop_csum,
            }
            let seg = sched.hop_wire_bytes(h, elems, resident_bits, m);
            let c0 = self.clock.comm_s;
            self.clock.comm_s += self.net.hop_s_on(level, seg + CHECKSUM_BYTES as f64)
                - self.net.hop_s_on(level, seg);
            if let Some(t) = self.tracer.as_deref_mut() {
                t.push(crate::trace::Span::new(
                    crate::trace::Cat::Comm,
                    crate::trace::SpanKind::Checksum {
                        level,
                        hop_idx: h,
                        wire_bits: per_hop_csum,
                    },
                    c0,
                    self.clock.comm_s,
                    per_hop_csum,
                ));
            }
        }
        let Some((plan, step)) = self.wire_faults else { return };
        if plan.loss <= 0.0 && plan.flip <= 0.0 {
            return;
        }
        for h in 0..hops {
            let seg_bytes =
                sched.hop_wire_bytes(h, elems, resident_bits, m) + CHECKSUM_BYTES as f64;
            // a retransmit is a fresh packet on the hop's own link
            let level = sched.hop_level(h, m).unwrap_or(fallback);
            for w in 0..m {
                let mut failed = 0u32;
                while failed <= cfg.max_retries
                    && plan.hop_fault(step, w, h, failed) != HopFault::None
                {
                    failed += 1;
                }
                let sent = failed.min(cfg.max_retries);
                if sent > 0 {
                    let add_bits = sent as f64 * 8.0 * seg_bytes;
                    self.clock.retrans_bits += add_bits;
                    let r0 = self.clock.retrans_s;
                    self.clock.retrans_s += cfg.backoff_base_s
                        * (2f64.powi(sent as i32) - 1.0)
                        + sent as f64 * self.net.hop_s_on(level, seg_bytes);
                    if let Some(t) = self.tracer.as_deref_mut() {
                        t.push(crate::trace::Span::new(
                            crate::trace::Cat::Retrans,
                            crate::trace::SpanKind::Retransmit {
                                attempt: sent,
                                worker: w,
                                hop_idx: h,
                                level,
                                wire_bits: add_bits,
                            },
                            r0,
                            self.clock.retrans_s,
                            0.0,
                        ));
                    }
                }
            }
        }
    }

    /// [`StepCtx::charge_packed`] at the fixed-width ring (the historical
    /// entry point; kept for the benches and wire-ledger tests).
    pub fn charge_ring_packed(
        &mut self,
        elems: usize,
        resident_bits: u32,
        payload_bits_per_elem: f64,
    ) {
        self.charge_packed(&RingFixed, elems, resident_bits, payload_bits_per_elem)
    }

    /// Packed-resident sum all-reduce over per-worker biased [`Packed`]
    /// buffers through `sched`, with hop-accurate wire charging.
    /// `payload_bits_per_elem` is the nominal wire payload for the paper
    /// ledger. Returns the data-plane traffic.
    pub fn allreduce_sum_packed_sched(
        &mut self,
        sched: &dyn PackedReduce,
        bufs: &mut [Packed],
        payload_bits_per_elem: f64,
    ) -> PlaneTraffic {
        let mut traffic = PlaneTraffic::default();
        if let Some(first) = bufs.first() {
            let (elems, bits) = (first.len, first.bits);
            packed::allreduce_sum_packed_sched(sched, bufs, &mut traffic);
            self.charge_packed(sched, elems, bits, payload_bits_per_elem);
        }
        traffic
    }

    /// [`StepCtx::allreduce_sum_packed_sched`] at the fixed-width ring.
    pub fn allreduce_sum_packed(
        &mut self,
        bufs: &mut [Packed],
        payload_bits_per_elem: f64,
    ) -> PlaneTraffic {
        self.allreduce_sum_packed_sched(&RingFixed, bufs, payload_bits_per_elem)
    }

    /// Time a closure into the encode bucket.
    pub fn time_encode<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let e0 = self.clock.encode_s;
        let t0 = std::time::Instant::now();
        let r = f();
        self.clock.encode_s += t0.elapsed().as_secs_f64();
        if let Some(t) = self.tracer.as_deref_mut() {
            let bucket = t.bucket();
            t.push(crate::trace::Span::new(
                crate::trace::Cat::Encode,
                crate::trace::SpanKind::Encode { bucket },
                e0,
                self.clock.encode_s,
                0.0,
            ));
        }
        r
    }

    /// Time a closure into the decode bucket.
    pub fn time_decode<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let d0 = self.clock.decode_s;
        let t0 = std::time::Instant::now();
        let r = f();
        self.clock.decode_s += t0.elapsed().as_secs_f64();
        if let Some(t) = self.tracer.as_deref_mut() {
            let bucket = t.bucket();
            t.push(crate::trace::Span::new(
                crate::trace::Cat::Decode,
                crate::trace::SpanKind::Decode { bucket },
                d0,
                self.clock.decode_s,
                0.0,
            ));
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, ensure, ensure_slice_close};

    #[test]
    fn prop_ring_equals_naive() {
        check("ring allreduce == naive sum", 150, |g| {
            let m = g.usize_in(1, 9);
            let n = g.size_scaled(0, 3000);
            let bufs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let mut ring = bufs.clone();
            let mut naive = bufs.clone();
            ring_allreduce_sum(&mut ring);
            naive_allreduce_sum(&mut naive);
            for r in 0..m {
                ensure_slice_close(&ring[r], &naive[0], 1e-5, &format!("rank {r}"))?;
            }
            ensure(true, "")
        });
    }

    #[test]
    fn prop_tree_equals_naive() {
        check("tree allreduce == naive sum", 150, |g| {
            let m = g.usize_in(1, 12);
            let n = g.size_scaled(0, 2000);
            let bufs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let mut tree = bufs.clone();
            let mut naive = bufs;
            tree_allreduce_sum(&mut tree);
            naive_allreduce_sum(&mut naive);
            for r in 0..m {
                ensure_slice_close(&tree[r], &naive[0], 1e-5, &format!("rank {r}"))?;
            }
            ensure(true, "")
        });
    }

    #[test]
    fn prop_ring_all_ranks_identical() {
        check("ring leaves all ranks identical", 80, |g| {
            let m = g.usize_in(2, 8);
            let n = g.size_scaled(1, 2000);
            let mut bufs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 2.0)).collect();
            ring_allreduce_sum(&mut bufs);
            for r in 1..m {
                if bufs[r] != bufs[0] {
                    return Err(format!("rank {r} differs from rank 0"));
                }
            }
            ensure(true, "")
        });
    }

    #[test]
    fn ring_exact_on_integers() {
        // integer-valued f32 sums are exact => ring must equal naive exactly
        let mut bufs: Vec<Vec<f32>> =
            (0..5).map(|r| (0..97).map(|i| ((r * i) % 11) as f32).collect()).collect();
        let mut naive = bufs.clone();
        ring_allreduce_sum(&mut bufs);
        naive_allreduce_sum(&mut naive);
        assert_eq!(bufs[0], naive[0]);
    }

    #[test]
    fn prop_int_reducers_agree_exactly() {
        // integer sums are exact, so ring/tree/naive must agree with
        // assert_eq (no tolerance), on every rank, for i16 and i32.
        check("int ring == tree == naive (exact)", 120, |g| {
            let m = g.usize_in(1, 9);
            let n = g.size_scaled(0, 3000);
            // keep |level| <= 512 so m * level fits i16 comfortably
            let base: Vec<Vec<i32>> = (0..m)
                .map(|_| {
                    (0..n)
                        .map(|_| g.rng().next_below(1025) as i32 - 512)
                        .collect()
                })
                .collect();
            let mut ring32 = base.clone();
            let mut tree32 = base.clone();
            let mut naive32 = base.clone();
            ring_allreduce_sum_t(&mut ring32);
            tree_allreduce_sum_t(&mut tree32);
            naive_allreduce_sum_t(&mut naive32);
            let as16: Vec<Vec<i16>> =
                base.iter().map(|b| b.iter().map(|&x| x as i16).collect()).collect();
            let mut ring16 = as16.clone();
            ring_allreduce_sum_i16(&mut ring16);
            for r in 0..m {
                if ring32[r] != naive32[0] || tree32[r] != naive32[0] {
                    return Err(format!("rank {r}: int reducers disagree"));
                }
                let widened: Vec<i32> = ring16[r].iter().map(|&x| x as i32).collect();
                if widened != naive32[0] {
                    return Err(format!("rank {r}: i16 ring differs from i32 naive"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn step_ctx_int_allreduce_charges_same_wire_as_f32() {
        let net = NetConfig::flat(4, 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut bufs: Vec<Vec<i16>> = (0..4).map(|r| vec![r as i16; 1000]).collect();
        ctx.allreduce_sum_in_place_int(&mut bufs, 8.0);
        assert!(bufs.iter().all(|b| b.iter().all(|&x| x == 6))); // 0+1+2+3
        assert_eq!(clock.bits_per_worker, 8000.0);
    }

    #[test]
    fn min_u8_and_max_scalar() {
        let a = vec![3u8, 0, 7];
        let b = vec![1u8, 5, 7];
        assert_eq!(min_allreduce_u8(&[a, b]), vec![1, 0, 7]);
        assert_eq!(max_allreduce_scalar(&[1.0, 5.0, -2.0]), 5.0);
    }

    #[test]
    fn step_ctx_charges_wire() {
        let net = NetConfig::flat(4, 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let bufs: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32; 1000]).collect();
        let sum = ctx.allreduce_sum(bufs, 8.0);
        assert_eq!(sum[0], 0.0 + 1.0 + 2.0 + 3.0);
        assert!(clock.comm_s > 0.0);
        assert_eq!(clock.bits_per_worker, 8000.0);
    }

    #[test]
    fn wire_floor_rounds_up() {
        let net = NetConfig::flat(2, 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.wire_floor_bits = Some(8.0);
        let bufs: Vec<Vec<f32>> = vec![vec![1.0; 100], vec![2.0; 100]];
        ctx.allreduce_sum(bufs, 3.0); // 3-bit payload floors to 8
        assert_eq!(clock.bits_per_worker, 800.0);
    }

    #[test]
    fn effective_bits_is_byte_exact() {
        // 97 coords at 3 bits: the packed payload is ceil(291/8) = 37 bytes,
        // and the ledger must say the same — not fractional 291 bits.
        let net = NetConfig::flat(2, 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let bufs: Vec<Vec<f32>> = vec![vec![1.0; 97], vec![2.0; 97]];
        ctx.allreduce_sum(bufs, 3.0);
        assert_eq!(
            clock.bits_per_worker,
            (8 * bitpack::wire_bytes_for(97, 3)) as f64
        );
    }

    #[test]
    fn wire_floor_and_packed_path_agree_on_byte_totals() {
        // regression (satellite): the floor path and the packed wire format
        // must produce the same byte-exact totals, with and without floor.
        let net = NetConfig::flat(4, 10.0);

        // no floor: 13 sign bits -> 2 wire bytes -> 16 ledger bits
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.charge_allgather(13.0, 1.0);
        assert_eq!(clock.bits_per_worker, (8 * bitpack::wire_bytes_for(13, 1)) as f64);

        // floor 8: every coordinate widens to 8 bits -> 13 bytes -> 104
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.wire_floor_bits = Some(8.0);
        ctx.charge_allgather(13.0, 1.0);
        assert_eq!(clock.bits_per_worker, (8 * bitpack::wire_bytes_for(13, 8)) as f64);

        // and the packed-resident ring's nominal ledger uses the same rule
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.wire_floor_bits = Some(8.0);
        ctx.charge_ring_packed(13, 8, 1.0);
        assert_eq!(clock.bits_per_worker, (8 * bitpack::wire_bytes_for(13, 8)) as f64);
    }

    #[test]
    fn charge_packed_is_hop_accurate_per_schedule() {
        // every schedule books its own hop shape: ring 2(m-1) segments,
        // growing ring narrower reduce-scatter hops, tree 2*log2(m) full
        // buffers, naive m-1 full buffers — and comm_s equals the analytic
        // formula the trait exposes.
        let m = 4;
        let elems = 1000usize;
        let lmax = 7usize; // 4-bit payload
        let bits = bitpack::packed_sum_bits(lmax, m); // bitlen(56) = 6
        let net = NetConfig::flat(m, 10.0);
        let seg = bitpack::wire_bytes_for(elems.div_ceil(m), bits) as f64;
        let full = bitpack::wire_bytes_for(elems, bits) as f64;
        let cases: [(PackedSchedule, f64); 4] = [
            (PackedSchedule::RingFixed(RingFixed), 6.0 * seg),
            (
                PackedSchedule::RingGrowing(RingGrowing { lmax }),
                (1..m)
                    .map(|k| {
                        bitpack::wire_bytes_for(
                            elems.div_ceil(m),
                            bitpack::packed_sum_bits(lmax, k),
                        ) as f64
                    })
                    .sum::<f64>()
                    + 3.0 * seg,
            ),
            (PackedSchedule::Tree(TreeReduce), 4.0 * full),
            (PackedSchedule::Naive(NaiveReduce), 3.0 * full),
        ];
        for (sched, want_bytes) in cases {
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.charge_packed(sched.as_dyn(), elems, bits, 4.0);
            assert_eq!(
                clock.hop_bits_per_worker,
                want_bytes * 8.0,
                "{} hop bits",
                sched.as_dyn().name()
            );
            assert_eq!(
                clock.bits_per_worker,
                (8 * bitpack::wire_bytes_for(elems, 4)) as f64,
                "{} nominal ledger",
                sched.as_dyn().name()
            );
            assert_eq!(
                clock.comm_s,
                packed::analytic_comm_s(sched.as_dyn(), &net, elems, bits),
                "{} comm_s",
                sched.as_dyn().name()
            );
        }
        // growing never charges more hop bits than fixed
        let hop_bits = |sched: &dyn PackedReduce| {
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.charge_packed(sched, elems, bits, 4.0);
            clock.hop_bits_per_worker
        };
        assert!(hop_bits(&RingGrowing { lmax }) < hop_bits(&RingFixed));
    }

    #[test]
    fn integrity_checksum_charge_matches_closed_form_per_schedule() {
        // clean wire, integrity on: both bit ledgers gain exactly 64 bits
        // per hop, comm_s gains the bandwidth-only increment of 8 bytes per
        // hop, and nothing lands on the retransmit books.
        let m = 4;
        let elems = 1000usize;
        let bits = 6u32;
        let net = NetConfig::flat(m, 10.0);
        for sched in [
            PackedSchedule::RingFixed(RingFixed),
            PackedSchedule::RingGrowing(RingGrowing { lmax: 7 }),
            PackedSchedule::Tree(TreeReduce),
            PackedSchedule::Naive(NaiveReduce),
        ] {
            let s = sched.as_dyn();
            let mut off = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut off);
            ctx.charge_packed(s, elems, bits, 4.0);
            let mut on = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut on);
            ctx.integrity = Some(IntegrityConfig::default());
            ctx.charge_packed(s, elems, bits, 4.0);
            let hops = s.hops(m);
            let csum = (8 * CHECKSUM_BYTES * hops) as f64;
            assert_eq!(on.bits_per_worker, off.bits_per_worker + csum, "{}", s.name());
            assert_eq!(on.hop_bits_per_worker, off.hop_bits_per_worker + csum, "{}", s.name());
            let comm_delta: f64 = (0..hops)
                .map(|h| {
                    let seg = s.hop_wire_bytes(h, elems, bits, m);
                    net.hop_s(seg + CHECKSUM_BYTES as f64) - net.hop_s(seg)
                })
                .sum();
            assert_eq!(on.comm_s, off.comm_s + comm_delta, "{}", s.name());
            assert_eq!(on.retrans_s, 0.0);
            assert_eq!(on.retrans_bits, 0.0);
        }
    }

    #[test]
    fn retransmit_walk_charges_the_ladder_closed_form() {
        // Replay the exact fault draws the walk consumes and rebuild its
        // charge from the closed form: min(f, R) retransmits per (hop,
        // slot), each paying its backoff rung + the checksummed segment's
        // wire time.
        use crate::netsim::FaultPlan;
        let m = 4;
        let elems = 1000usize;
        let bits = 6u32;
        let net = NetConfig::flat(m, 10.0);
        let plan = FaultPlan::wire(0xF1, 0.15, 0.15);
        let step = 3usize;
        let cfg = IntegrityConfig::default();
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.integrity = Some(cfg);
        ctx.wire_faults = Some((&plan, step));
        let sched = RingFixed;
        ctx.charge_packed(&sched, elems, bits, 4.0);
        let (mut want_bits, mut want_s) = (0.0f64, 0.0f64);
        for h in 0..sched.hops(m) {
            let seg = sched.hop_wire_bytes(h, elems, bits, m) + CHECKSUM_BYTES as f64;
            for w in 0..m {
                let mut f = 0u32;
                while f <= cfg.max_retries
                    && plan.hop_fault(step, w, h, f) != crate::netsim::HopFault::None
                {
                    f += 1;
                }
                let sent = f.min(cfg.max_retries);
                want_bits += sent as f64 * 8.0 * seg;
                want_s += cfg.backoff_base_s * (2f64.powi(sent as i32) - 1.0)
                    + sent as f64 * net.hop_s(seg);
            }
        }
        assert!(want_bits > 0.0, "p=0.3 over 24 hop-slots should fault somewhere");
        assert_eq!(clock.retrans_bits, want_bits);
        assert_eq!(clock.retrans_s, want_s);
        // integrity off: the same faulty plan charges nothing — the wire
        // has no checksum to detect with, so the books stay clean
        let mut off = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut off);
        ctx.wire_faults = Some((&plan, step));
        ctx.charge_packed(&sched, elems, bits, 4.0);
        assert_eq!(off.retrans_bits, 0.0);
        assert_eq!(off.retrans_s, 0.0);
    }

    #[test]
    fn packed_schedule_resolution_follows_policy_and_algo() {
        let mut net = NetConfig::flat(8, 0.5); // slow wire: Auto picks growing
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        assert!(matches!(
            ctx.packed_schedule(1, 8, 1 << 20),
            PackedSchedule::RingGrowing(_)
        ));
        ctx.ring_width = crate::netsim::RingWidth::Fixed;
        assert!(matches!(ctx.packed_schedule(1, 8, 1 << 20), PackedSchedule::RingFixed(_)));
        net.algo = crate::netsim::Algo::Tree;
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        assert!(matches!(ctx.packed_schedule(1, 8, 1 << 20), PackedSchedule::Tree(_)));
        net.algo = crate::netsim::Algo::Naive;
        let mut clock = SimClock::default();
        let ctx = StepCtx::new(&net, &mut clock);
        assert!(matches!(ctx.packed_schedule(1, 8, 1 << 20), PackedSchedule::Naive(_)));
    }

    #[test]
    fn packed_allreduce_sums_and_charges_hop_accurately() {
        let m = 4;
        let net = NetConfig::flat(m, 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let n = 1000;
        let lmax = 7usize; // 4-bit payload levels
        let bits = bitpack::packed_sum_bits(lmax, m);
        let levels: Vec<Vec<i32>> = (0..m).map(|r| vec![r as i32 - 1; n]).collect();
        let mut bufs: Vec<Packed> = levels
            .iter()
            .map(|l| bitpack::pack_biased_int(l, lmax as i64, bits))
            .collect();
        let traffic = ctx.allreduce_sum_packed(&mut bufs, 4.0);
        // every rank holds the biased sum: (-1+0+1+2) + 4*7 = 30
        let mut out = vec![0i64; n];
        for p in &bufs {
            bitpack::unpack_biased_i64_at(&p.words, bits, 0, (m as i64) * lmax as i64, &mut out);
            assert!(out.iter().all(|&x| x == 2));
        }
        // nominal ledger: byte-exact 4-bit payload
        assert_eq!(clock.bits_per_worker, (8 * bitpack::wire_bytes_for(n, 4)) as f64);
        // hop-accurate ledger: 2(m-1) segments at the *resident* width,
        // strictly more than the nominal payload (the ScaleCom gap)
        let seg = bitpack::wire_bytes_for(n.div_ceil(m), bits) as f64;
        assert_eq!(clock.hop_bits_per_worker, 6.0 * seg * 8.0);
        assert!(clock.hop_bits_per_worker > clock.bits_per_worker);
        assert!(clock.comm_s > 0.0);
        assert!(traffic.bytes_moved > 0.0);
    }

    #[test]
    fn charge_packed_splits_hop_bits_per_level() {
        // PR 8: the hop-bits book gains a per-level split whose sum always
        // equals hop_bits_per_worker, with flat schedules landing wholly on
        // the bottleneck level and the hierarchical schedule splitting by
        // its hop tags — closed forms on the paper topology.
        let elems = 10_000usize;
        let lmax = 7usize;
        let net = NetConfig::paper_cluster(10.0);
        let m = net.workers;
        let (g, nodes) = (net.gpus_per_node, net.nodes());
        let bits = bitpack::packed_sum_bits(lmax, m);

        // flat ring on the multi-node net: everything is Inter
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.charge_packed(&RingFixed, elems, bits, 4.0);
        assert_eq!(clock.hop_bits_inter, clock.hop_bits_per_worker);
        assert_eq!(clock.hop_bits_intra, 0.0);

        // flat ring on a single-node net: everything is Intra
        let single = NetConfig::single_node(4);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&single, &mut clock);
        ctx.charge_packed(&RingFixed, elems, bitpack::packed_sum_bits(lmax, 4), 4.0);
        assert_eq!(clock.hop_bits_intra, clock.hop_bits_per_worker);
        assert_eq!(clock.hop_bits_inter, 0.0);

        // hierarchical: 4(g-1) Intra island-segment hops + 2(nodes-1) Inter
        // leader hops, each book pinned to its closed form
        let sched = Hierarchical { gpus_per_node: g, lmax, growing: false };
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        ctx.charge_packed(&sched, elems, bits, 4.0);
        let island_seg = bitpack::wire_bytes_for(elems.div_ceil(g), bits) as f64;
        let leader_seg = bitpack::wire_bytes_for(elems.div_ceil(nodes), bits) as f64;
        assert_eq!(clock.hop_bits_intra, 4.0 * (g - 1) as f64 * island_seg * 8.0);
        assert_eq!(clock.hop_bits_inter, 2.0 * (nodes - 1) as f64 * leader_seg * 8.0);
        assert_eq!(clock.hop_bits_intra + clock.hop_bits_inter, clock.hop_bits_per_worker);
        assert_eq!(clock.comm_s, packed::analytic_comm_s(&sched, &net, elems, bits));

        // integrity on: each hop's checksum lands on that hop's level and
        // the split invariant survives
        let mut on = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut on);
        ctx.integrity = Some(IntegrityConfig::default());
        ctx.charge_packed(&sched, elems, bits, 4.0);
        let csum = |hops: f64| hops * (8 * CHECKSUM_BYTES) as f64;
        assert_eq!(on.hop_bits_intra, clock.hop_bits_intra + csum(4.0 * (g - 1) as f64));
        assert_eq!(on.hop_bits_inter, clock.hop_bits_inter + csum(2.0 * (nodes - 1) as f64));
        assert_eq!(on.hop_bits_intra + on.hop_bits_inter, on.hop_bits_per_worker);
    }

    #[test]
    fn packed_schedule_resolution_is_topology_aware() {
        // hier on a genuinely two-level net resolves Hier; single-island,
        // single-GPU, off-ring, and hier=false shapes all stay flat.
        let elems = 1 << 20;
        let lmax = 7usize;
        let net = NetConfig::paper_cluster(10.0);
        let m = net.workers;
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        assert!(matches!(ctx.packed_schedule(lmax, m, elems), PackedSchedule::RingFixed(_)));
        ctx.hier = true;
        match ctx.packed_schedule(lmax, m, elems) {
            PackedSchedule::Hier(h) => assert_eq!(h.gpus_per_node, net.gpus_per_node),
            other => panic!("expected Hier, got {:?}", other),
        }
        // explicit width policy drives the leader ring
        ctx.ring_width = RingWidth::Growing;
        match ctx.packed_schedule(lmax, m, elems) {
            PackedSchedule::Hier(h) => assert!(h.growing),
            other => panic!("expected Hier, got {:?}", other),
        }
        // Auto on the hier shape asks the per-level selector about the
        // leader ring: slow Ethernet, 32 leaders, bound g*lmax -> growing
        let slow = NetConfig::paper_cluster(0.5);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&slow, &mut clock);
        ctx.hier = true;
        match ctx.packed_schedule(lmax, slow.workers, elems) {
            PackedSchedule::Hier(h) => {
                assert_eq!(
                    h.growing,
                    slow.growing_ring_wins_on(
                        LinkLevel::Inter,
                        lmax * slow.gpus_per_node,
                        slow.nodes(),
                        elems
                    )
                );
                assert!(h.growing, "32 leaders over 0.5 Gb/s should pick growing");
            }
            other => panic!("expected Hier, got {:?}", other),
        }
        // single island: hier requested but the topology is flat NVLink
        let single = NetConfig::single_node(4);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&single, &mut clock);
        ctx.hier = true;
        assert!(matches!(ctx.packed_schedule(lmax, 4, elems), PackedSchedule::RingFixed(_)));
        // off-ring algos ignore the hier flag entirely
        let mut tree = NetConfig::paper_cluster(10.0);
        tree.algo = crate::netsim::Algo::Tree;
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&tree, &mut clock);
        ctx.hier = true;
        assert!(matches!(ctx.packed_schedule(lmax, m, elems), PackedSchedule::Tree(_)));
    }

    #[test]
    fn growing_selector_matches_alpha_inclusive_times_at_crossover() {
        // The ISSUE-8 α satellite, pinned end-to-end: the selector's
        // bandwidth-only decision must equal the comparison of the two
        // candidates' FULL α-inclusive wire times (analytic_comm_s sums
        // α + bytes/β per hop) plus the repack tax — for every α, on both
        // sides of the elems crossover. Both rings make 2(m-1) hops, so α
        // is a common term and cannot flip the comparison.
        let m = 16usize;
        let lmax = 1usize; // 1-bit-ish codes: the regime where growing pays
        let bits = bitpack::packed_sum_bits(lmax, m);
        let mut flipped = false;
        for alpha in [0.0, 50e-6, 5e-3] {
            let mut net = NetConfig::flat(m, 2.0);
            net.inter.alpha_s = alpha;
            let mut last = None;
            for elems in [64usize, 512, 4 << 10, 64 << 10, 1 << 20, 8 << 20] {
                let seg_fixed =
                    bitpack::wire_bytes_for(elems.div_ceil(m), bits) as f64;
                // GROWING_EXTRA_PASSES (2.0) repack passes per RS hop
                let extra_s = (m - 1) as f64
                    * 2.0
                    * seg_fixed
                    * crate::netsim::REPACK_S_PER_BYTE;
                let fixed_s = packed::analytic_comm_s(&RingFixed, &net, elems, bits);
                let grow_s =
                    packed::analytic_comm_s(&RingGrowing { lmax }, &net, elems, bits);
                let want = fixed_s - grow_s > extra_s;
                let got = net.growing_ring_wins(lmax, m, elems);
                assert_eq!(
                    got, want,
                    "selector vs α-inclusive times at elems={elems}, α={alpha}"
                );
                if let Some(prev) = last {
                    flipped |= prev != got;
                }
                last = Some(got);
            }
        }
        assert!(flipped, "the sweep must straddle the crossover");
    }
}
