//! Per-worker error-feedback memory (EF-SGD, Karimireddy et al. 2019 /
//! ScaleCom's local memory): each worker accumulates the residual its
//! quantizer dropped and folds it into the next step's input.
//!
//! Per step and worker `w`: the control plane quantizes `x_w = g_w + e_w`;
//! afterwards `e_w <- x_w - dec(Q_w(x_w))`, where `dec(Q_w(x_w))` is that
//! worker's own decoded contribution (`level * wnorm / s`, the `m = 1`
//! decode). The quantizer stays the paper's unbiased QSGDMaxNorm — EF makes
//! the *step* biased but bounds the accumulated distortion, which is what
//! recovers accuracy at aggressive widths. The residual is recomputed from
//! the same uniform stream the data plane consumed, so it is exactly the
//! quantity the wire dropped — no second source of randomness.

use crate::compress::kernels;
use crate::util::threads;

/// Per-worker residual memory over the full flat gradient.
#[derive(Default)]
pub struct ErrorFeedback {
    mem: Vec<Vec<f32>>,
    /// per-worker f32 level scratch for the residual recompute
    lvl: Vec<Vec<f32>>,
}

impl ErrorFeedback {
    pub fn new() -> ErrorFeedback {
        ErrorFeedback::default()
    }

    fn ensure(&mut self, m: usize, n: usize) {
        self.mem.resize_with(m, Vec::new);
        self.lvl.resize_with(m, Vec::new);
        for e in self.mem.iter_mut() {
            e.resize(n, 0.0);
        }
    }

    /// `corrected[w] = grads[w] + e_w` into reusable scratch (pool-parallel).
    pub fn apply(&mut self, grads: &[&[f32]], corrected: &mut Vec<Vec<f32>>) {
        let m = grads.len();
        let n = grads[0].len();
        self.ensure(m, n);
        corrected.resize_with(m, Vec::new);
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
        for ((x, e), g) in corrected.iter_mut().zip(&self.mem).zip(grads) {
            tasks.push(Box::new(move || {
                x.resize(n, 0.0);
                for i in 0..n {
                    x[i] = g[i] + e[i];
                }
            }));
        }
        threads::pool().scope_run(tasks);
    }

    /// Update the residual of bucket `[lo, hi)` after it was quantized at
    /// `s` levels against `wnorm`, with per-worker inputs `corrected` and
    /// the same uniform draws `uni` the data plane encoded with.
    pub fn absorb_bucket(
        &mut self,
        corrected: &[Vec<f32>],
        uni: &[Vec<f32>],
        lo: usize,
        hi: usize,
        wnorm: f32,
        s: usize,
    ) {
        let m = corrected.len();
        debug_assert_eq!(self.mem.len(), m);
        let k = wnorm / s as f32; // the m = 1 decode constant
        let len = hi - lo;
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
        for ((e, lvl), (x, u)) in
            self.mem.iter_mut().zip(self.lvl.iter_mut()).zip(corrected.iter().zip(uni))
        {
            tasks.push(Box::new(move || {
                lvl.resize(len, 0.0);
                // deterministic re-encode: same inputs, norm, and uniforms
                // as the packed pipeline's producers
                kernels::qsgd_encode(&x[lo..hi], wnorm, &u[lo..hi], s, &mut lvl[..]);
                for i in 0..len {
                    e[lo + i] = x[lo + i] - lvl[i] * k;
                }
            }));
        }
        threads::pool().scope_run(tasks);
    }

    /// Multi-scale analogue of [`ErrorFeedback::absorb_bucket`]: the bucket
    /// `[lo, hi)` was quantized at the shared per-coordinate scales
    /// (`shared_idx` is the bucket-local share, `hi - lo` entries) against
    /// `wnorm`; the residual uses the per-coordinate `m = 1` decode
    /// `level * wnorm / s*` — recomputed from the same uniform stream the
    /// data plane consumed, so it is exactly what the wire dropped.
    #[allow(clippy::too_many_arguments)]
    pub fn absorb_bucket_multiscale(
        &mut self,
        corrected: &[Vec<f32>],
        uni: &[Vec<f32>],
        lo: usize,
        hi: usize,
        wnorm: f32,
        table: &kernels::ScaleTable,
        shared_idx: &[u8],
    ) {
        let m = corrected.len();
        debug_assert_eq!(self.mem.len(), m);
        let len = hi - lo;
        debug_assert_eq!(shared_idx.len(), len);
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
        for ((e, lvl), (x, u)) in
            self.mem.iter_mut().zip(self.lvl.iter_mut()).zip(corrected.iter().zip(uni))
        {
            tasks.push(Box::new(move || {
                lvl.resize(len, 0.0);
                kernels::multiscale_encode_t(
                    &x[lo..hi],
                    wnorm,
                    &u[lo..hi],
                    shared_idx,
                    table,
                    &mut lvl[..],
                );
                for i in 0..len {
                    // shared_idx crossed the wire: a poisoned share must
                    // panic here, not divide residuals by the 0.0 padding
                    // lane (satellite 2 decode-boundary guard).
                    let s_sel = table.select_checked(shared_idx[i] as u32);
                    e[lo + i] = x[lo + i] - lvl[i] * (wnorm / s_sel);
                }
            }));
        }
        threads::pool().scope_run(tasks);
    }

    /// Largest per-worker residual L2 norm (test/diagnostic hook).
    pub fn max_residual_norm(&self) -> f64 {
        self.mem.iter().map(|e| crate::tensor::norm2(e)).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn residual_is_exactly_what_the_quantizer_dropped() {
        let n = 257;
        let m = 3;
        let s = 7;
        let mut rng = Rng::new(11);
        let grads: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let wnorm = refs.iter().map(|v| kernels::l2_norm(v)).fold(0.0f32, f32::max);
        let mut uni: Vec<Vec<f32>> = Vec::new();
        crate::compress::fused::fill_uniforms_into(m, n, &mut uni, &Rng::new(5));

        let mut ef = ErrorFeedback::new();
        let mut corrected = Vec::new();
        ef.apply(&refs, &mut corrected); // first step: e = 0, x = g
        for w in 0..m {
            assert_eq!(corrected[w], grads[w]);
        }
        ef.absorb_bucket(&corrected, &uni, 0, n, wnorm, s);

        // manual check: e = x - Q(x)/1
        for w in 0..m {
            let mut lvl = vec![0.0f32; n];
            kernels::qsgd_encode(&grads[w], wnorm, &uni[w], s, &mut lvl);
            for i in 0..n {
                let want = grads[w][i] - lvl[i] * (wnorm / s as f32);
                assert_eq!(ef.mem[w][i], want, "worker {w} coord {i}");
            }
        }
        assert!(ef.max_residual_norm() > 0.0);

        // second apply folds the residual in
        let mut corrected2 = Vec::new();
        ef.apply(&refs, &mut corrected2);
        for w in 0..m {
            for i in 0..n {
                assert_eq!(corrected2[w][i], grads[w][i] + ef.mem[w][i]);
            }
        }
    }

    #[test]
    fn multiscale_residual_is_exactly_what_the_quantizer_dropped() {
        let n = 129;
        let m = 2;
        let scales = [7usize, 127];
        let table = kernels::ScaleTable::new(&scales);
        let mut rng = Rng::new(23);
        let grads: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let wnorm = refs.iter().map(|v| kernels::l2_norm(v)).fold(0.0f32, f32::max);
        let mut uni: Vec<Vec<f32>> = Vec::new();
        crate::compress::fused::fill_uniforms_into(m, n, &mut uni, &Rng::new(9));
        // the shared per-coordinate scales the data plane would have used
        let mut proposals: Vec<Vec<u8>> = Vec::new();
        for g in &grads {
            let mut prop = vec![0u8; n];
            kernels::multiscale_scale_index_t(g, wnorm, &table, &mut prop);
            proposals.push(prop);
        }
        let shared = crate::collectives::min_allreduce_u8(&proposals);

        let mut ef = ErrorFeedback::new();
        let mut corrected = Vec::new();
        ef.apply(&refs, &mut corrected);
        ef.absorb_bucket_multiscale(&corrected, &uni, 0, n, wnorm, &table, &shared);

        for w in 0..m {
            let mut lvl = vec![0.0f32; n];
            kernels::multiscale_encode_t(&grads[w], wnorm, &uni[w], &shared, &table, &mut lvl);
            for i in 0..n {
                let s_sel = table.select_checked(shared[i] as u32);
                let want = grads[w][i] - lvl[i] * (wnorm / s_sel);
                assert_eq!(ef.mem[w][i], want, "worker {w} coord {i}");
            }
        }
        assert!(ef.max_residual_norm() > 0.0);
    }

    #[test]
    fn zero_norm_bucket_accumulates_the_whole_input() {
        // wnorm = 0 -> all levels 0 -> residual equals the input
        let grads = vec![vec![0.25f32; 8], vec![-0.5f32; 8]];
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let uni = vec![vec![0.5f32; 8]; 2];
        let mut ef = ErrorFeedback::new();
        let mut corrected = Vec::new();
        ef.apply(&refs, &mut corrected);
        ef.absorb_bucket(&corrected, &uni, 0, 8, 0.0, 7);
        assert_eq!(ef.mem[0], grads[0]);
        assert_eq!(ef.mem[1], grads[1]);
    }
}
