//! Bucketed gradient control plane (PR 4): the layer between the cluster
//! step and the packed collectives.
//!
//! The monolithic path compresses the whole flattened gradient as one blob
//! at one global bit-width and only starts communicating after the entire
//! backward pass — the serialization Parallel-SGD analyses identify as the
//! scaling bottleneck. This subsystem splits the gradient into DDP-style
//! buckets along layer boundaries ([`bucket::BucketPlan`]), runs every
//! bucket through the packed pipeline independently at a per-bucket
//! bit-width ([`precision::PrecisionController`]: fixed, per-layer, or
//! variance-adaptive), optionally folds the quantization residual back in
//! via per-worker error feedback ([`feedback::ErrorFeedback`]), and hides
//! bucket communication behind the remaining backward compute
//! ([`overlap::schedule`]), reporting the hidden fraction through
//! [`crate::netsim::SimClock::hidden_comm_s`].
//!
//! Correctness pins (tests): with [`precision::FixedBits`] **and a global
//! norm** — i.e. whenever the overlap scheduler is inactive (no backward
//! window on the step context, or `overlap` off), or with a single bucket
//! — the bucketed path is **bit-identical** to the monolithic packed path
//! for *any* bucket plan: the control plane draws one full-length uniform
//! stream per worker (the monolithic `rng.derive([w])` draw) and shares
//! the global max norm, so per-bucket encode/reduce/decode reproduces the
//! monolithic numbers coordinate for coordinate. When overlap *is* active
//! with more than one bucket, norms are per-bucket (see [`NormScope`]) and
//! multi-bucket outputs legitimately diverge from the monolithic path —
//! pass `--no-overlap` to a cluster run to recover exact parity.
//! Per-bucket wire charging is byte-exact either way: the ledger over `N`
//! buckets is the sum of per-bucket `ceil(len_b * bits_b / 8)` payloads,
//! never a re-derivation from the whole-gradient length.

pub mod bucket;
pub mod feedback;
pub mod overlap;
pub mod precision;

use anyhow::{bail, Result};

use crate::collectives::StepCtx;
use crate::compress::{fused, kernels, Aggregator, Method};
use crate::runtime::Segment;
use crate::tensor;
use crate::util::rng::Rng;

pub use bucket::{Bucket, BucketPlan};
pub use feedback::ErrorFeedback;
pub use overlap::OverlapReport;
pub use precision::{BitsPolicy, BucketStats, FixedBits, PerLayerBits, PrecisionController, VarianceAdaptive};

/// How the shared quantizer norm is scoped.
///
/// `Global` (default) shares one max norm across all buckets — one 32-bit
/// scalar all-reduce, and the bucketed path stays bit-identical to the
/// monolithic one under fixed bits. `PerBucket` shares one norm per bucket
/// (32 bits each): the heterogeneous-scale variant a deployment would run
/// (each bucket's norm is available as soon as its backward completes),
/// at the cost of monolithic bit-parity.
///
/// A global norm needs the *full* gradient, which only exists after the
/// entire backward pass — so whenever the overlap scheduler is active
/// (`overlap` on and the step carries a backward window), the plane
/// switches to per-bucket norms regardless of this setting: crediting
/// hidden comm under a global norm would model a schedule no deployment
/// can realize. With a single bucket the two scopes coincide, so the
/// single-bucket bit-identity pin holds with or without overlap.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum NormScope {
    #[default]
    Global,
    PerBucket,
}

/// Configuration of the bucketed control plane (CLI `--buckets`,
/// `--bits`, `--error-feedback`, `--no-overlap`).
#[derive(Clone, Debug)]
pub struct ControlConfig {
    /// target bucket count (>= 1; the plan may merge small layers)
    pub buckets: usize,
    pub bits: BitsPolicy,
    pub error_feedback: bool,
    /// hide bucket comm behind backward compute when the step context
    /// carries a backward window
    pub overlap: bool,
    pub norm_scope: NormScope,
}

impl ControlConfig {
    pub fn new(buckets: usize) -> ControlConfig {
        ControlConfig {
            buckets,
            bits: BitsPolicy::Fixed(None),
            error_feedback: false,
            overlap: true,
            norm_scope: NormScope::Global,
        }
    }
}

/// Build the control plane for a parsed method. Only the single-scale
/// QSGD-MN family routes through the bucketed plane today; other methods
/// fail loudly rather than silently ignoring the bucket options.
pub fn build_plane(
    method: &Method,
    cfg: &ControlConfig,
    n: usize,
    segments: &[Segment],
) -> Result<GradientControlPlane> {
    match method {
        Method::Qsgd { bits } => GradientControlPlane::new(cfg.clone(), *bits, n, segments),
        other => bail!(
            "--buckets currently supports qsgd-mn-* methods only (got {})",
            other.label()
        ),
    }
}

/// The bucketed aggregator: partition -> per-bucket precision -> packed
/// pipeline per bucket -> optional error feedback -> overlap accounting.
pub struct GradientControlPlane {
    pub cfg: ControlConfig,
    pub plan: BucketPlan,
    /// the method's bit-width (the fixed default and the table label)
    base_bits: usize,
    ctrl: Box<dyn PrecisionController>,
    ef: Option<ErrorFeedback>,
    // ---- cross-step scratch (zero steady-state allocation once warm)
    packed: fused::PackedScratch,
    uniform: Vec<Vec<f32>>,
    corrected: Vec<Vec<f32>>,
    bucket_comm: Vec<f64>,
    // ---- last-step telemetry
    last_bits: Vec<usize>,
    last_payload_bits: f64,
    last_overlap: OverlapReport,
}

impl GradientControlPlane {
    pub fn new(
        cfg: ControlConfig,
        base_bits: usize,
        n: usize,
        segments: &[Segment],
    ) -> Result<GradientControlPlane> {
        anyhow::ensure!(cfg.buckets >= 1, "--buckets must be >= 1");
        anyhow::ensure!((2..=16).contains(&base_bits), "qsgd bits must be in 2..=16");
        fused::assert_widening_rule(kernels::s_for_bits(base_bits))?;
        let plan = BucketPlan::new(n, segments, cfg.buckets);
        let ctrl: Box<dyn PrecisionController> = match &cfg.bits {
            BitsPolicy::Fixed(explicit) => {
                let b = explicit.unwrap_or(base_bits);
                anyhow::ensure!((2..=16).contains(&b), "--bits fixed:{b} out of 2..=16");
                Box::new(FixedBits(b))
            }
            BitsPolicy::Auto => Box::new(VarianceAdaptive::default_policy()),
            BitsPolicy::PerLayer(per_layer) => Box::new(PerLayerBits::new(per_layer, &plan)?),
        };
        let ef = cfg.error_feedback.then(ErrorFeedback::new);
        Ok(GradientControlPlane {
            cfg,
            plan,
            base_bits,
            ctrl,
            ef,
            packed: fused::PackedScratch::new(),
            uniform: Vec::new(),
            corrected: Vec::new(),
            bucket_comm: Vec::new(),
            last_bits: Vec::new(),
            last_payload_bits: 0.0,
            last_overlap: OverlapReport::default(),
        })
    }

    /// Per-bucket bit-widths the last step used.
    pub fn last_bits(&self) -> &[usize] {
        &self.last_bits
    }

    /// Byte-exact payload bits per worker of the last step: the closed-form
    /// sum of per-bucket `8 * ceil(len_b * bits_b / 8)` terms.
    pub fn last_payload_bits(&self) -> f64 {
        self.last_payload_bits
    }

    /// Last step's overlap outcome.
    pub fn last_overlap(&self) -> OverlapReport {
        self.last_overlap
    }

    /// Largest per-worker error-feedback residual norm (0 with EF off).
    pub fn max_residual_norm(&self) -> f64 {
        self.ef.as_ref().map(|e| e.max_residual_norm()).unwrap_or(0.0)
    }
}

impl Aggregator for GradientControlPlane {
    fn name(&self) -> String {
        let mut name = format!(
            "QSGD-MN-{}-B{}[{}]",
            self.base_bits,
            self.plan.len(),
            self.ctrl.label()
        );
        if self.ef.is_some() {
            name.push_str("+EF");
        }
        name
    }

    fn allreduce_compatible(&self) -> bool {
        true
    }

    fn nominal_bits(&self) -> f64 {
        // length-weighted mean of the last step's widths (the method's
        // bit-width before the first step)
        if self.last_bits.len() == self.plan.len() && self.plan.n > 0 {
            self.plan
                .buckets
                .iter()
                .zip(&self.last_bits)
                .map(|(b, &bits)| (b.len() * bits) as f64)
                .sum::<f64>()
                / self.plan.n as f64
        } else {
            self.base_bits as f64
        }
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, rng: &mut Rng) -> Vec<f32> {
        let m = grads.len();
        let n = grads[0].len();
        assert!(m <= fused::MAX_WORKERS, "M={m} exceeds MAX_WORKERS");
        assert_eq!(n, self.plan.n, "gradient length does not match the bucket plan");

        // error feedback: fold the residual into this step's inputs
        let inputs: Vec<&[f32]> = match self.ef.as_mut() {
            Some(ef) => {
                let corrected = &mut self.corrected;
                ctx.time_encode(|| ef.apply(grads, corrected));
                self.corrected.iter().map(|v| v.as_slice()).collect()
            }
            None => grads.to_vec(),
        };

        // ONE full-length uniform stream per worker — the monolithic step's
        // exact draw (`rng.derive([w])`), sliced per bucket below. Together
        // with a globally shared norm this makes the bucketed output
        // bit-identical to the monolithic packed path for any bucket plan.
        let uniform = &mut self.uniform;
        ctx.time_encode(|| fused::fill_uniforms_into(m, n, uniform, rng));

        // shared norm (Algorithm 1 line 5). A GLOBAL norm needs the full
        // gradient — it only exists after the entire backward — so a step
        // that overlaps bucket comm with backward compute cannot use it:
        // when the overlap scheduler is active, norms are per-bucket (one
        // 32-bit share per bucket, available at the bucket's release and
        // charged inside its comm window), the deployment-realizable model.
        // Without overlap, Global shares one scalar like the monolithic
        // path — the FixedBits bit-identity pin.
        let overlap_active = self.cfg.overlap && ctx.backward_s.is_some();
        let per_bucket_norms =
            overlap_active || self.cfg.norm_scope == NormScope::PerBucket;
        let global_wnorm = if per_bucket_norms {
            None
        } else {
            let norms: Vec<f32> = inputs.iter().map(|g| kernels::l2_norm(g)).collect();
            Some(ctx.allreduce_max_scalar(&norms))
        };

        let nb = self.plan.len();
        self.bucket_comm.clear();
        self.bucket_comm.resize(nb, 0.0);
        self.last_bits.clear();
        self.last_payload_bits = 0.0;
        let mut out = vec![0.0f32; n];

        for b in 0..nb {
            let bk = self.plan.buckets[b];
            let (lo, hi) = (bk.lo, bk.hi);
            let g_slices: Vec<&[f32]> = inputs.iter().map(|g| &g[lo..hi]).collect();
            let u_slices: Vec<&[f32]> = self.uniform.iter().map(|u| &u[lo..hi]).collect();

            // everything charged from here on belongs to this bucket's comm
            // window — including its norm share, so the overlap scheduler
            // releases norm + payload together at the bucket's ready time
            let comm_before = ctx.clock.comm_s;

            let wnorm = match global_wnorm {
                Some(w) => w,
                None => {
                    let norms: Vec<f32> =
                        g_slices.iter().map(|g| kernels::l2_norm(g)).collect();
                    ctx.allreduce_max_scalar(&norms)
                }
            };

            // per-bucket precision; the O(m·n_b) moment pass runs only for
            // policies that read it, and is timed as encode work
            let grad_ms = if self.ctrl.needs_stats() {
                ctx.time_encode(|| {
                    g_slices.iter().map(|g| tensor::norm2_sq(g)).sum::<f64>() / m.max(1) as f64
                })
            } else {
                0.0
            };
            let stats = BucketStats { len: hi - lo, wnorm, grad_ms, workers: m };
            let bits = self.ctrl.bits_for(b, &stats);
            let s = kernels::s_for_bits(bits);
            let wire_bits = kernels::bits_for_s(s);

            fused::qsgd_step_packed_with_uniforms(
                &g_slices,
                &u_slices,
                wnorm,
                s,
                wire_bits,
                &mut self.packed,
                ctx,
                None,
                &mut out[lo..hi],
            );
            self.bucket_comm[b] = ctx.clock.comm_s - comm_before;
            self.last_bits.push(bits);
            self.last_payload_bits +=
                (8 * crate::compress::bitpack::wire_bytes_for(hi - lo, bits as u32)) as f64;

            if let Some(ef) = self.ef.as_mut() {
                let (corrected, uni) = (&self.corrected, &self.uniform);
                ctx.time_encode(|| ef.absorb_bucket(corrected, uni, lo, hi, wnorm, s));
            }
        }

        // overlap accounting: hide bucket comm inside the backward window
        self.last_overlap = match (self.cfg.overlap, ctx.backward_s) {
            (true, Some(backward_s)) => {
                let ready = self.plan.ready_times(backward_s);
                let report = overlap::schedule(&ready, &self.bucket_comm, backward_s);
                ctx.clock.hidden_comm_s += report.hidden_s;
                report
            }
            _ => OverlapReport::default(),
        };
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::bitpack;
    use crate::compress::qsgd_maxnorm::QsgdMaxNorm;
    use crate::netsim::{NetConfig, SimClock};

    use crate::runtime::contiguous_segments as segs;

    fn fixed_grads(seed: u64, m: usize, n: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect()
    }

    fn run(
        agg: &mut dyn Aggregator,
        grads: &[Vec<f32>],
        seed: u64,
        backward_s: Option<f64>,
    ) -> (Vec<f32>, SimClock) {
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let out = {
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.backward_s = backward_s;
            let mut rng = Rng::new(seed);
            agg.aggregate(&refs, &mut ctx, &mut rng)
        };
        (out, clock)
    }

    #[test]
    fn single_bucket_fixed_bits_reproduces_monolithic_ledger_and_output() {
        let (m, n) = (4usize, 997usize);
        let grads = fixed_grads(0xC0FFEE, m, n);
        let segments = segs(&[400, 400, 197]);

        let mut mono = QsgdMaxNorm::new(4).unwrap();
        let (want, clock_mono) = run(&mut mono, &grads, 77, None);

        let cfg = ControlConfig::new(1);
        let mut plane = GradientControlPlane::new(cfg, 4, n, &segments).unwrap();
        let (got, clock_b) = run(&mut plane, &grads, 77, None);

        assert_eq!(got, want);
        assert_eq!(clock_b.bits_per_worker, clock_mono.bits_per_worker);
        assert_eq!(clock_b.hop_bits_per_worker, clock_mono.hop_bits_per_worker);
        assert_eq!(clock_b.comm_s, clock_mono.comm_s);
        assert_eq!(plane.last_bits(), &[4]);
    }

    #[test]
    fn per_bucket_charging_is_byte_exact_never_rederived_from_whole_length() {
        // satellite bugfix pin: 3 ragged buckets at 2 bits — the per-bucket
        // byte ceilings sum to MORE than one whole-gradient ceiling, and the
        // ledger must show the per-bucket sum (a whole-length re-derivation
        // or a double byte-ceiling would both fail the equality).
        let (m, n) = (4usize, 97usize);
        let grads = fixed_grads(0xBEEF, m, n);
        let segments = segs(&[33, 33, 31]);
        let mut cfg = ControlConfig::new(3);
        cfg.bits = BitsPolicy::Fixed(Some(2));
        cfg.overlap = false;
        let mut plane = GradientControlPlane::new(cfg, 4, n, &segments).unwrap();
        assert_eq!(plane.plan.len(), 3);
        let (_, clock) = run(&mut plane, &grads, 5, None);

        let closed_form: f64 = [33usize, 33, 31]
            .iter()
            .map(|&l| (8 * bitpack::wire_bytes_for(l, 2)) as f64)
            .sum();
        assert_eq!(plane.last_payload_bits(), closed_form);
        // 32 norm bits + per-bucket byte-exact payloads
        assert_eq!(clock.bits_per_worker, 32.0 + closed_form);
        // and that differs from the whole-gradient ceiling (the bug shape)
        let whole = (8 * bitpack::wire_bytes_for(n, 2)) as f64;
        assert_ne!(closed_form, whole);
        assert_eq!(closed_form, 208.0);
        assert_eq!(whole, 200.0);
    }

    #[test]
    fn overlap_hides_comm_and_reports_positive_fraction() {
        // 1M coords keeps the per-hop cost bandwidth-dominated, so the
        // bucketed exposed tail (one bucket's hops) clears the monolithic
        // comm with a deterministic analytic margin
        let (m, n) = (16usize, 1 << 20);
        let grads = fixed_grads(0xABCD, m, n);
        let segments = segs(&[n / 4; 4]);

        let mut mono = QsgdMaxNorm::new(4).unwrap();
        let (_, clock_mono) = run(&mut mono, &grads, 3, Some(0.14));

        let cfg = ControlConfig::new(4);
        let mut plane = GradientControlPlane::new(cfg, 4, n, &segments).unwrap();
        let (_, clock_b) = run(&mut plane, &grads, 3, Some(0.14));

        // monolithic hides nothing
        assert_eq!(clock_mono.hidden_comm_s, 0.0);
        // bucketed hides a positive fraction and beats the monolithic
        // simulated step time (compute + exposed comm)
        assert!(clock_b.hidden_comm_s > 0.0);
        assert!(plane.last_overlap().overlap_frac > 0.0);
        let mono_step = 0.14 + clock_mono.comm_s;
        let buck_step = 0.14 + clock_b.comm_s - clock_b.hidden_comm_s;
        assert!(
            buck_step < mono_step,
            "bucketed-with-overlap {buck_step} must beat monolithic {mono_step}"
        );
        assert!(clock_b.hidden_comm_s <= clock_b.comm_s);
        assert!(clock_b.overlap_frac() > 0.0);
    }

    #[test]
    fn per_bucket_norm_scope_charges_one_scalar_per_bucket() {
        let (m, n) = (4usize, 512usize);
        let grads = fixed_grads(0x99, m, n);
        let segments = segs(&[128; 4]);
        let mut cfg = ControlConfig::new(4);
        cfg.norm_scope = NormScope::PerBucket;
        cfg.overlap = false;
        let mut plane = GradientControlPlane::new(cfg, 4, n, &segments).unwrap();
        let (out, clock) = run(&mut plane, &grads, 9, None);
        assert_eq!(out.len(), n);
        assert!(out.iter().all(|x| x.is_finite()));
        // 4 norm scalars instead of 1
        assert_eq!(
            clock.bits_per_worker,
            4.0 * 32.0 + plane.last_payload_bits()
        );
    }

    #[test]
    fn build_plane_rejects_incompatible_methods() {
        let cfg = ControlConfig::new(4);
        assert!(build_plane(&Method::SignSgd, &cfg, 100, &[]).is_err());
        assert!(build_plane(&Method::Qsgd { bits: 4 }, &cfg, 100, &[]).is_ok());
    }

    #[test]
    fn error_feedback_changes_the_step_but_stays_finite() {
        let (m, n) = (3usize, 300usize);
        let grads = fixed_grads(0x5A5A, m, n);
        let segments = segs(&[100; 3]);
        let mut cfg = ControlConfig::new(3);
        cfg.error_feedback = true;
        cfg.bits = BitsPolicy::Fixed(Some(8));
        let mut plane = GradientControlPlane::new(cfg, 8, n, &segments).unwrap();
        // first step: residual starts at zero, so outputs match the EF-less
        // plane; afterwards the residual is non-zero and folded in
        let mut plain =
            GradientControlPlane::new(ControlConfig::new(3), 8, n, &segments).unwrap();
        let (a, _) = run(&mut plane, &grads, 21, None);
        let (b, _) = run(&mut plain, &grads, 21, None);
        assert_eq!(a, b, "step 1 has zero residual");
        assert!(plane.max_residual_norm() > 0.0);
        let (c, _) = run(&mut plane, &grads, 22, None);
        let (d, _) = run(&mut plain, &grads, 22, None);
        assert_ne!(c, d, "step 2 folds the residual in");
        assert!(c.iter().all(|x| x.is_finite()));
    }
}
