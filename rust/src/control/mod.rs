//! Bucketed gradient control plane (PR 4, aggregator-generic since PR 5):
//! the layer between the cluster step and the packed collectives.
//!
//! The monolithic path compresses the whole flattened gradient as one blob
//! at one global bit-width and only starts communicating after the entire
//! backward pass — the serialization Parallel-SGD analyses identify as the
//! scaling bottleneck. This subsystem splits the gradient into DDP-style
//! buckets along layer boundaries ([`bucket::BucketPlan`]), runs every
//! bucket through the packed pipeline independently at a per-bucket
//! precision ([`precision::PrecisionController`]: fixed, per-layer, or
//! variance-adaptive — a bit-width for the single-scale quantizer, a whole
//! scale set for the multi-scale one), optionally folds the quantization
//! residual back in via per-worker error feedback
//! ([`feedback::ErrorFeedback`]), and hides bucket communication behind
//! the remaining backward compute ([`overlap::schedule`]), reporting the
//! hidden fraction through [`crate::netsim::SimClock::hidden_comm_s`].
//!
//! The plane covers the paper's whole all-reduce-compatible quantizer
//! family, factored as quantizer × domain:
//! * quantizer — QSGDMaxNorm (§4.1) or the multi-scale
//!   QSGDMaxNormMultiScale with per-bucket scale sharing (§4.2);
//! * domain — the dense flat gradient, or the GlobalRandK coordinate draw
//!   (§4.3/§4.4): the global sorted K-set is drawn once from the
//!   monolithic stream and routed to its owning buckets, so each bucket
//!   reduces a contiguous (possibly empty, ragged-`K_b`) slice of the
//!   gathered K-vector and charges its own byte-exact payload wire (the
//!   coordinate draw itself costs no wire — shared seed; only the TS
//!   variant adds a per-bucket scale-share term).
//!
//! Correctness pins (tests): with [`precision::FixedBits`] **and a global
//! norm** — i.e. whenever the overlap scheduler is inactive (no backward
//! window on the step context, or `overlap` off), or with a single bucket
//! — the bucketed path is **bit-identical** to the monolithic packed path
//! for *any* bucket plan: the control plane draws one uniform stream per
//! worker over the encode domain (the monolithic `rng.derive([w])` draw)
//! and shares the global max norm, so per-bucket encode/reduce/decode
//! reproduces the monolithic numbers coordinate for coordinate. The
//! multi-scale scale share is an *elementwise* min all-reduce, so the
//! per-bucket share derived from per-bucket proposals equals the slice of
//! the monolithic share whenever the proposals used the same norm —
//! per-bucket derivation costs no parity. When overlap *is* active with
//! more than one bucket, norms (and hence scale shares) are per-bucket
//! (see [`NormScope`]) and multi-bucket outputs legitimately diverge from
//! the monolithic path — pass `--no-overlap` to a cluster run to recover
//! exact parity. Per-bucket wire charging is byte-exact either way: the
//! ledger over `N` buckets is the sum of per-bucket
//! `ceil(len_b * bits_b / 8)` payload terms (plus per-bucket
//! `ceil(len_b * index_bits / 8)` scale-share terms for the multi-scale
//! quantizer), never a re-derivation from the whole-gradient length.
//!
//! The plane is schedule-agnostic by construction: every bucket resolves
//! its reduction through [`StepCtx::packed_schedule`], so the PR 8
//! hierarchical two-level schedule (`ctx.hier` on a multi-island net)
//! applies per bucket with zero parity cost — the payload pins above hold
//! for any schedule, and the hierarchical-vs-flat matrix in
//! `int_domain_equivalence.rs` exercises exactly this seam.

pub mod bucket;
pub mod elastic;
pub mod feedback;
pub mod guard;
pub mod overlap;
pub mod precision;

use anyhow::{bail, Result};

use crate::collectives::StepCtx;
use crate::compress::{bitpack, fused, kernels, randk, Aggregator, Method};
use crate::runtime::Segment;
use crate::tensor;
use crate::util::rng::Rng;

pub use bucket::{Bucket, BucketPlan};
pub use elastic::{CohortPolicy, ElasticCohort, ElasticConfig, StepPlan};
pub use feedback::ErrorFeedback;
pub use guard::{Anomaly, AnomalyPolicy};
pub use overlap::OverlapReport;
pub use precision::{
    shift_scale_bits, BitsPolicy, BucketStats, FixedBits, PerLayerBits, PrecisionController,
    VarianceAdaptive,
};

/// How the shared quantizer norm is scoped.
///
/// `Global` (default) shares one max norm across all buckets — one 32-bit
/// scalar all-reduce, and the bucketed path stays bit-identical to the
/// monolithic one under fixed bits. `PerBucket` shares one norm per bucket
/// (32 bits each): the heterogeneous-scale variant a deployment would run
/// (each bucket's norm is available as soon as its backward completes),
/// at the cost of monolithic bit-parity.
///
/// A global norm needs the *full* gradient, which only exists after the
/// entire backward pass — so whenever the overlap scheduler is active
/// (`overlap` on and the step carries a backward window), the plane
/// switches to per-bucket norms regardless of this setting: crediting
/// hidden comm under a global norm would model a schedule no deployment
/// can realize. With a single bucket the two scopes coincide, so the
/// single-bucket bit-identity pin holds with or without overlap.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum NormScope {
    #[default]
    Global,
    PerBucket,
}

/// Configuration of the bucketed control plane (CLI `--buckets`,
/// `--bits`, `--error-feedback`, `--no-overlap`).
#[derive(Clone, Debug)]
pub struct ControlConfig {
    /// target bucket count (>= 1; the plan may merge small layers)
    pub buckets: usize,
    pub bits: BitsPolicy,
    pub error_feedback: bool,
    /// hide bucket comm behind backward compute when the step context
    /// carries a backward window
    pub overlap: bool,
    pub norm_scope: NormScope,
}

impl ControlConfig {
    pub fn new(buckets: usize) -> ControlConfig {
        ControlConfig {
            buckets,
            bits: BitsPolicy::Fixed(None),
            error_feedback: false,
            overlap: true,
            norm_scope: NormScope::Global,
        }
    }
}

/// Build the control plane for a parsed method. The whole all-reduce-
/// compatible quantizer family routes through the bucketed plane
/// (`qsgd-mn-*`, `qsgd-mn-ts-*`, `grandk-mn-*`, `grandk-mn-ts-*`); the
/// all-gather baselines and PowerSGD fail loudly rather than silently
/// ignoring the bucket options — their compressed outputs do not commute
/// with per-bucket summation, so a bucketed wire model would be fiction.
pub fn build_plane(
    method: &Method,
    cfg: &ControlConfig,
    n: usize,
    segments: &[Segment],
) -> Result<GradientControlPlane> {
    match method {
        Method::Qsgd { bits } => GradientControlPlane::new(cfg.clone(), *bits, n, segments),
        Method::QsgdTs { bits } => {
            GradientControlPlane::new_multiscale(cfg.clone(), bits, n, segments)
        }
        Method::RandK { bits, k } => GradientControlPlane::new_randk(
            cfg.clone(),
            *bits,
            k.unwrap_or_else(|| Method::default_k(n)),
            n,
            segments,
        ),
        Method::RandKTs { bits, k } => GradientControlPlane::new_randk_ts(
            cfg.clone(),
            bits,
            k.unwrap_or_else(|| Method::default_k(n)),
            n,
            segments,
        ),
        other => bail!(
            "--buckets supports the all-reduce-compatible quantizer family \
             (qsgd-mn-*, qsgd-mn-ts-*, grandk-mn-*, grandk-mn-ts-*); {} is \
             not bucketable — drop --buckets to run it monolithically",
            other.label()
        ),
    }
}

/// Does `--bits auto` have room to adapt on this method? False only for
/// maximal-span TS sets, where the one legal small scale pins every
/// bucket and [`build_plane`] rejects Auto loudly — callers composing a
/// [`ControlConfig`] programmatically (the examples) pre-check this and
/// fall back to fixed bits instead of crashing.
pub fn auto_can_adapt(method: &Method) -> bool {
    let span = match method {
        Method::QsgdTs { bits } | Method::RandKTs { bits, .. } => {
            let lo = bits.iter().min().copied().unwrap_or(0);
            let hi = bits.iter().max().copied().unwrap_or(0);
            hi - lo
        }
        _ => 0,
    };
    auto_span_ok(span, VarianceAdaptive::default_policy().min_bits)
}

/// The single source of truth for "auto has headroom on a TS set of this
/// span": shared by [`auto_can_adapt`] and the `build` rejection.
fn auto_span_ok(span: usize, min_bits: usize) -> bool {
    16usize.saturating_sub(span) > min_bits
}

/// The no-silent-clamp rule for explicitly requested TS widths: a small
/// scale of `w` bits plus the set's refinement span must fit the 16-bit
/// quantizer cap — running at fewer bits than the flag claims would
/// misattribute the wire budget, so overflow is rejected loudly (the
/// clamp in [`precision::shift_scale_bits`] serves only the adaptive
/// best-effort path).
fn ensure_ts_width_fits(w: usize, span: usize, what: &str) -> Result<()> {
    anyhow::ensure!(
        w + span <= 16,
        "{what} width {w} overflows the multi-scale budget: the scale set \
         spans {span} bits, so widths can be at most {}",
        16 - span
    );
    Ok(())
}

/// Which quantizer every bucket runs (paper §4.1 vs §4.2).
enum Quantizer {
    /// QSGDMaxNorm at a per-bucket bit-width.
    Single { bits: usize },
    /// QSGDMaxNormMultiScale at a per-bucket scale set; `bits` is the
    /// resolved base set, sorted ascending (small scale first — the wire
    /// budget), which static policies keep and adaptive policies shift.
    Multi { bits: Vec<usize> },
}

/// Which coordinate domain the buckets' payloads cover (§4.3/§4.4).
#[derive(Clone, Copy)]
enum Domain {
    /// the full flat gradient
    Dense,
    /// GlobalRandK: the shared sorted K-coordinate draw, routed per bucket
    GlobalK { k: usize, rescale: bool },
}

/// The bucketed aggregator: partition -> per-bucket precision -> packed
/// pipeline per bucket -> optional error feedback -> overlap accounting.
pub struct GradientControlPlane {
    pub cfg: ControlConfig,
    pub plan: BucketPlan,
    quant: Quantizer,
    domain: Domain,
    ctrl: Box<dyn PrecisionController>,
    ef: Option<ErrorFeedback>,
    // ---- cross-step scratch (zero steady-state allocation once warm)
    packed: fused::PackedScratch,
    uniform: Vec<Vec<f32>>,
    corrected: Vec<Vec<f32>>,
    /// GlobalK: per-worker gathered K-vectors
    dense: Vec<Vec<f32>>,
    /// GlobalK: the decoded K-vector before the scatter
    sub: Vec<f32>,
    /// multi-scale: per-worker scale proposals of the current bucket
    idx_scratch: Vec<Vec<u8>>,
    /// multi-scale: the current bucket's reduced scale share
    shared_scratch: Vec<u8>,
    /// multi-scale: per-bucket `(bit set, table)` cache — rebuilt only when
    /// the controller changes the bucket's set, so static policies build
    /// each table exactly once
    ts_tables: Vec<Option<(Vec<usize>, kernels::ScaleTable)>>,
    bucket_comm: Vec<f64>,
    // ---- last-step telemetry
    last_bits: Vec<usize>,
    /// encoded coordinates per bucket (bucket length, or ragged `K_b`)
    last_lens: Vec<usize>,
    last_payload_bits: f64,
    last_overlap: OverlapReport,
}

impl GradientControlPlane {
    /// QSGD-MN (single-scale) over the dense gradient — the PR 4 plane.
    pub fn new(
        cfg: ControlConfig,
        base_bits: usize,
        n: usize,
        segments: &[Segment],
    ) -> Result<GradientControlPlane> {
        Self::build(cfg, Quantizer::Single { bits: base_bits }, Domain::Dense, n, segments)
    }

    /// QSGD-MN-TS (multi-scale, per-bucket scale sharing) over the dense
    /// gradient.
    pub fn new_multiscale(
        cfg: ControlConfig,
        bits: &[usize],
        n: usize,
        segments: &[Segment],
    ) -> Result<GradientControlPlane> {
        Self::build(cfg, Quantizer::Multi { bits: bits.to_vec() }, Domain::Dense, n, segments)
    }

    /// GRandK-MN: the global K-coordinate draw routed per bucket, each
    /// bucket's gathered sub-vector quantized single-scale.
    pub fn new_randk(
        cfg: ControlConfig,
        bits: usize,
        k: usize,
        n: usize,
        segments: &[Segment],
    ) -> Result<GradientControlPlane> {
        Self::build(
            cfg,
            Quantizer::Single { bits },
            Domain::GlobalK { k, rescale: false },
            n,
            segments,
        )
    }

    /// GRandK-MN-TS: the global K draw routed per bucket, each bucket's
    /// gathered sub-vector quantized multi-scale with per-bucket sharing.
    pub fn new_randk_ts(
        cfg: ControlConfig,
        bits: &[usize],
        k: usize,
        n: usize,
        segments: &[Segment],
    ) -> Result<GradientControlPlane> {
        Self::build(
            cfg,
            Quantizer::Multi { bits: bits.to_vec() },
            Domain::GlobalK { k, rescale: false },
            n,
            segments,
        )
    }

    fn build(
        cfg: ControlConfig,
        quant: Quantizer,
        domain: Domain,
        n: usize,
        segments: &[Segment],
    ) -> Result<GradientControlPlane> {
        anyhow::ensure!(cfg.buckets >= 1, "--buckets must be >= 1");
        if let Domain::GlobalK { k, .. } = domain {
            anyhow::ensure!(k >= 1 && k <= n, "K must be in 1..=n (K={k}, n={n})");
            anyhow::ensure!(
                !cfg.error_feedback,
                "--error-feedback needs a dense method: a GlobalRandK residual \
                 lives on the un-sampled coordinates the wire never carries"
            );
        }
        // normalize + validate the quantizer; `small_base` is the width the
        // default FixedBits policy inherits
        let (mut quant, small_base) = match quant {
            Quantizer::Single { bits } => {
                anyhow::ensure!((2..=16).contains(&bits), "qsgd bits must be in 2..=16");
                fused::assert_widening_rule(kernels::s_for_bits(bits))?;
                (Quantizer::Single { bits }, bits)
            }
            Quantizer::Multi { bits } => {
                // the SAME validation the monolithic TS aggregators run —
                // one shared helper, so the two paths (whose bit-identity
                // is test-pinned) can never drift on what a legal set is
                let bits = kernels::sorted_scale_bits(&bits)?;
                fused::assert_widening_rule(kernels::s_for_bits(bits[bits.len() - 1]))?;
                let small = bits[0];
                (Quantizer::Multi { bits }, small)
            }
        };
        let plan = BucketPlan::new(n, segments, cfg.buckets);
        let ctrl: Box<dyn PrecisionController> = match &cfg.bits {
            BitsPolicy::Fixed(explicit) => {
                let b = explicit.unwrap_or(small_base);
                anyhow::ensure!((2..=16).contains(&b), "--bits fixed:{b} out of 2..=16");
                // an explicit fixed width re-anchors a TS method's scale set
                // once, here, so FixedBits' default `scale_bits_for` (return
                // the base set) stays the static identity — the monolithic
                // bit-identity pin needs the resolved set to be THE set
                if let (Quantizer::Multi { bits }, Some(_)) = (&mut quant, explicit) {
                    let span = bits[bits.len() - 1] - bits[0];
                    ensure_ts_width_fits(b, span, "--bits fixed")?;
                    let shifted = precision::shift_scale_bits(bits, b);
                    *bits = shifted;
                }
                Box::new(FixedBits(b))
            }
            BitsPolicy::Auto => {
                let policy = VarianceAdaptive::default_policy();
                // an adaptive policy with no room to move is a silent lie:
                // a maximal-span TS set pins every bucket at the one legal
                // small scale, so "auto" would pay the per-bucket moment
                // pass while behaving exactly like fixed — reject it
                if let Quantizer::Multi { bits } = &quant {
                    let span = bits[bits.len() - 1] - bits[0];
                    anyhow::ensure!(
                        auto_span_ok(span, policy.min_bits),
                        "--bits auto cannot adapt this multi-scale set: it spans \
                         {span} bits, pinning every bucket at the {}-bit small \
                         scale — use --bits fixed instead",
                        16 - span
                    );
                }
                Box::new(policy)
            }
            BitsPolicy::PerLayer(per_layer) => {
                // same no-silent-clamp rule as fixed:N — every explicitly
                // requested per-layer width must fit the TS set's span
                if let Quantizer::Multi { bits } = &quant {
                    let span = bits[bits.len() - 1] - bits[0];
                    for &w in per_layer {
                        ensure_ts_width_fits(w, span, "--bits perlayer")?;
                    }
                }
                Box::new(PerLayerBits::new(per_layer, &plan)?)
            }
        };
        let ef = cfg.error_feedback.then(ErrorFeedback::new);
        Ok(GradientControlPlane {
            cfg,
            plan,
            quant,
            domain,
            ctrl,
            ef,
            packed: fused::PackedScratch::new(),
            uniform: Vec::new(),
            corrected: Vec::new(),
            dense: Vec::new(),
            sub: Vec::new(),
            idx_scratch: Vec::new(),
            shared_scratch: Vec::new(),
            ts_tables: Vec::new(),
            bucket_comm: Vec::new(),
            last_bits: Vec::new(),
            last_lens: Vec::new(),
            last_payload_bits: 0.0,
            last_overlap: OverlapReport::default(),
        })
    }

    /// Switch a GlobalRandK domain to the n/K-rescaled *unbiased* estimator
    /// (mirrors `GlobalRandK::rescale`; no-op for dense domains).
    pub fn set_rescale(&mut self, on: bool) {
        if let Domain::GlobalK { rescale, .. } = &mut self.domain {
            *rescale = on;
        }
    }

    /// Per-bucket small-scale bit-widths the last step used (0 marks a
    /// bucket the GlobalK draw left empty).
    pub fn last_bits(&self) -> &[usize] {
        &self.last_bits
    }

    /// Encoded coordinates per bucket of the last step: the bucket length
    /// for dense domains, the ragged per-bucket `K_b` for GlobalK.
    pub fn last_bucket_lens(&self) -> &[usize] {
        &self.last_lens
    }

    /// Byte-exact payload bits per worker of the last step: the closed-form
    /// sum of per-bucket `8 * ceil(len_b * bits_b / 8)` level terms, plus —
    /// for the multi-scale quantizer — per-bucket
    /// `8 * ceil(len_b * index_bits / 8)` scale-share terms. Norm scalars
    /// are charged separately (32 bits per share).
    pub fn last_payload_bits(&self) -> f64 {
        self.last_payload_bits
    }

    /// Last step's overlap outcome.
    pub fn last_overlap(&self) -> OverlapReport {
        self.last_overlap
    }

    /// Largest per-worker error-feedback residual norm (0 with EF off).
    pub fn max_residual_norm(&self) -> f64 {
        self.ef.as_ref().map(|e| e.max_residual_norm()).unwrap_or(0.0)
    }
}

impl Aggregator for GradientControlPlane {
    fn name(&self) -> String {
        let join = |bits: &[usize]| {
            bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",")
        };
        let scheme = match (&self.domain, &self.quant) {
            (Domain::Dense, Quantizer::Single { bits }) => format!("QSGD-MN-{bits}"),
            (Domain::Dense, Quantizer::Multi { bits }) => {
                format!("QSGD-MN-TS-({})", join(bits))
            }
            (Domain::GlobalK { .. }, Quantizer::Single { bits }) => {
                format!("GRandK-MN-{bits}")
            }
            (Domain::GlobalK { .. }, Quantizer::Multi { bits }) => {
                format!("GRandK-MN-TS-({})", join(bits))
            }
        };
        let mut name = format!("{scheme}-B{}[{}]", self.plan.len(), self.ctrl.label());
        if self.ef.is_some() {
            name.push_str("+EF");
        }
        name
    }

    fn allreduce_compatible(&self) -> bool {
        true
    }

    fn nominal_bits(&self) -> f64 {
        // per-coordinate nominal r of a bucket whose small-scale width is b:
        // the level payload, plus the scale-index share for multi-scale
        let r_of = |b: usize| match &self.quant {
            Quantizer::Single { .. } => b as f64,
            Quantizer::Multi { bits } => b as f64 + kernels::index_bits_for(bits.len()),
        };
        let base_small = match &self.quant {
            Quantizer::Single { bits } => *bits,
            Quantizer::Multi { bits } => bits[0],
        };
        let nb = self.plan.len();
        let warm = self.last_bits.len() == nb && self.last_lens.len() == nb && self.plan.n > 0;
        if warm {
            // length-weighted mean over what the last step actually shipped
            // (encoded coords per bucket: bucket length, or ragged K_b),
            // amortized over the n coordinates of the gradient
            self.last_lens
                .iter()
                .zip(&self.last_bits)
                .map(|(&l, &bits)| l as f64 * r_of(bits))
                .sum::<f64>()
                / self.plan.n as f64
        } else {
            match &self.domain {
                Domain::Dense => r_of(base_small),
                Domain::GlobalK { k, .. } => {
                    r_of(base_small) * *k as f64 / self.plan.n.max(1) as f64
                }
            }
        }
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, rng: &mut Rng) -> Vec<f32> {
        self.aggregate_inner(grads, None, ctx, rng)
    }

    fn aggregate_cohort(
        &mut self,
        grads: &[&[f32]],
        ids: &[usize],
        ctx: &mut StepCtx,
        rng: &mut Rng,
    ) -> Vec<f32> {
        self.aggregate_inner(grads, Some(ids), ctx, rng)
    }
}

impl GradientControlPlane {
    /// The one aggregation body behind both [`Aggregator::aggregate`]
    /// (`ids == None`: the full positional cohort) and
    /// [`Aggregator::aggregate_cohort`] (`ids == Some(survivors)`: slice
    /// `i` drawn against ORIGINAL worker `ids[i]`'s uniform stream). The
    /// live M is `grads.len()` throughout — the decode's `1/(s*m)` fold
    /// and the packed resident width `bitlen(2*M_live*lmax)` renormalize
    /// for the surviving cohort with no further bookkeeping, which is
    /// exactly the live-M renormalization the churn unbiasedness tier
    /// pins in `tests/paper_properties.rs`.
    fn aggregate_inner(
        &mut self,
        grads: &[&[f32]],
        ids: Option<&[usize]>,
        ctx: &mut StepCtx,
        rng: &mut Rng,
    ) -> Vec<f32> {
        let m = grads.len();
        let n = grads[0].len();
        assert!(m <= fused::MAX_WORKERS, "M={m} exceeds MAX_WORKERS");
        assert_eq!(n, self.plan.n, "gradient length does not match the bucket plan");
        if let Some(ids) = ids {
            assert_eq!(ids.len(), m, "one gradient slice per cohort id");
            debug_assert!(
                ids.windows(2).all(|w| w[0] < w[1]),
                "cohort ids must be strictly increasing, got {ids:?}"
            );
            // error-feedback residual memory is positional: folding a
            // partial cohort into it would misattribute residuals, so the
            // elastic layer only allows EF with a full, stable cohort
            assert!(
                self.ef.is_none() || ids.iter().enumerate().all(|(i, &w)| i == w),
                "error feedback requires the full cohort (positional residual memory)"
            );
        }

        // error feedback: fold the residual into this step's inputs
        // (dense domains only — construction rejects EF + GlobalK)
        let inputs: Vec<&[f32]> = match self.ef.as_mut() {
            Some(ef) => {
                let corrected = &mut self.corrected;
                ctx.time_encode(|| ef.apply(grads, corrected));
                self.corrected.iter().map(|v| v.as_slice()).collect()
            }
            None => grads.to_vec(),
        };

        // coordinate domain: the dense gradient itself, or the shared
        // global K-draw (the monolithic GlobalRandK derive) gathered into
        // per-worker K-vectors. The draw is sorted, so every bucket's
        // coordinates are one contiguous — possibly empty — slice of the
        // gathered vector, found below by binary search.
        let (coord_idx, enc_len, rescale) = match self.domain {
            Domain::Dense => (None, n, 1.0f32),
            Domain::GlobalK { k, rescale } => {
                let idx = randk::shared_indices(rng, n, k);
                let dense = &mut self.dense;
                ctx.time_encode(|| randk::gather_all(&inputs, &idx, dense));
                (Some(idx), k, if rescale { n as f32 / k as f32 } else { 1.0 })
            }
        };
        let work: Vec<&[f32]> = match &coord_idx {
            Some(_) => self.dense.iter().map(|d| d.as_slice()).collect(),
            None => inputs.clone(),
        };

        // ONE uniform stream per worker over the encode domain — the
        // monolithic step's exact draw (`rng.derive([w])`, full gradient
        // length for dense, K for GlobalK), sliced per bucket below.
        // Together with a globally shared norm this makes the bucketed
        // output bit-identical to the monolithic packed path for any
        // bucket plan. A partial cohort keys each slot by its ORIGINAL
        // worker id so survivors replay their own streams.
        let uniform = &mut self.uniform;
        ctx.time_encode(|| match ids {
            None => fused::fill_uniforms_into(m, enc_len, uniform, rng),
            Some(ids) => fused::fill_uniforms_masked_into(ids, enc_len, uniform, rng),
        });

        // shared norm (Algorithm 1/2 line 5). A GLOBAL norm needs the full
        // (gathered) gradient — it only exists after the entire backward —
        // so a step that overlaps bucket comm with backward compute cannot
        // use it: when the overlap scheduler is active, norms are
        // per-bucket (one 32-bit share per bucket, available at the
        // bucket's release and charged inside its comm window), the
        // deployment-realizable model. Without overlap, Global shares one
        // scalar like the monolithic path — the FixedBits bit-identity pin.
        // Multi-scale proposals derive from the norm, so the scale share
        // inherits the same scoping automatically.
        let overlap_active = self.cfg.overlap && ctx.backward_s.is_some();
        let per_bucket_norms =
            overlap_active || self.cfg.norm_scope == NormScope::PerBucket;
        let global_wnorm = if per_bucket_norms {
            None
        } else {
            let norms: Vec<f32> = work.iter().map(|g| kernels::l2_norm(g)).collect();
            Some(ctx.allreduce_max_scalar(&norms))
        };

        let nb = self.plan.len();
        self.bucket_comm.clear();
        self.bucket_comm.resize(nb, 0.0);
        self.last_bits.clear();
        self.last_lens.clear();
        self.last_payload_bits = 0.0;
        let mut out = vec![0.0f32; n];
        if coord_idx.is_some() {
            self.sub.resize(enc_len, 0.0);
        }

        for b in 0..nb {
            let bk = self.plan.buckets[b];
            // the flight recorder tags this bucket's inner collective spans
            if let Some(t) = ctx.tracer.as_deref_mut() {
                t.set_bucket(Some(b));
            }
            // encode-domain range of this bucket: its own coordinate range
            // (dense), or the sorted K-draw's sub-range inside it (GlobalK)
            let (elo, ehi) = match &coord_idx {
                None => (bk.lo, bk.hi),
                Some(idx) => (
                    idx.partition_point(|&i| i < bk.lo),
                    idx.partition_point(|&i| i < bk.hi),
                ),
            };
            self.last_lens.push(ehi - elo);
            if elo == ehi {
                // the draw left this bucket empty: nothing to share or ship
                self.last_bits.push(0);
                continue;
            }
            let g_slices: Vec<&[f32]> = work.iter().map(|g| &g[elo..ehi]).collect();
            let u_slices: Vec<&[f32]> = self.uniform.iter().map(|u| &u[elo..ehi]).collect();

            // everything charged from here on belongs to this bucket's comm
            // window — norm share, scale share, payload — so the overlap
            // scheduler releases them together at the bucket's ready time
            let comm_before = ctx.clock.comm_s;

            let wnorm = match global_wnorm {
                Some(w) => w,
                None => {
                    let norms: Vec<f32> =
                        g_slices.iter().map(|g| kernels::l2_norm(g)).collect();
                    ctx.allreduce_max_scalar(&norms)
                }
            };

            // per-bucket precision; the O(m·n_b) moment pass runs only for
            // policies that read it, and is timed as encode work
            let grad_ms = if self.ctrl.needs_stats() {
                ctx.time_encode(|| {
                    g_slices.iter().map(|g| tensor::norm2_sq(g)).sum::<f64>() / m.max(1) as f64
                })
            } else {
                0.0
            };
            let stats = BucketStats { len: ehi - elo, wnorm, grad_ms, workers: m };

            match &self.quant {
                Quantizer::Single { .. } => {
                    let bits = self.ctrl.bits_for(b, &stats);
                    let s = kernels::s_for_bits(bits);
                    let wire_bits = kernels::bits_for_s(s);
                    let dst = match &coord_idx {
                        None => &mut out[elo..ehi],
                        Some(_) => &mut self.sub[elo..ehi],
                    };
                    fused::qsgd_step_packed_with_uniforms(
                        &g_slices,
                        &u_slices,
                        wnorm,
                        s,
                        wire_bits,
                        &mut self.packed,
                        ctx,
                        None,
                        dst,
                    );
                    self.last_bits.push(bits);
                    self.last_payload_bits +=
                        (8 * bitpack::wire_bytes_for(ehi - elo, bits as u32)) as f64;
                    if let Some(ef) = self.ef.as_mut() {
                        let (corrected, uni) = (&self.corrected, &self.uniform);
                        ctx.time_encode(|| ef.absorb_bucket(corrected, uni, elo, ehi, wnorm, s));
                    }
                }
                Quantizer::Multi { bits: base } => {
                    let sb = self.ctrl.scale_bits_for(b, &stats, base);
                    // per-bucket table cache: rebuild only when the
                    // controller moved the bucket's set (static policies
                    // never do, so their tables are built exactly once)
                    if self.ts_tables.len() <= b {
                        self.ts_tables.resize_with(b + 1, || None);
                    }
                    let entry = &mut self.ts_tables[b];
                    if entry.as_ref().map_or(true, |(bits, _)| bits != &sb) {
                        let scales: Vec<usize> =
                            sb.iter().map(|&x| kernels::s_for_bits(x)).collect();
                        *entry = Some((sb.clone(), kernels::ScaleTable::new(&scales)));
                    }
                    let table = entry.as_ref().unwrap().1;
                    let index_bits = kernels::index_bits_for(sb.len());
                    // per-worker scale proposals on the bucket slice, then
                    // the bucket's share: the min all-reduce is elementwise,
                    // so with a global norm this share IS the slice of the
                    // monolithic share — per-bucket derivation costs no
                    // parity; under per-bucket norms it is the bucket's own
                    // independently derived share (ready at its release)
                    let idx_scratch = &mut self.idx_scratch;
                    ctx.time_encode(|| {
                        fused::scale_index_into(&g_slices, wnorm, &table, idx_scratch)
                    });
                    ctx.allreduce_min_u8_into(
                        &self.idx_scratch,
                        index_bits,
                        &mut self.shared_scratch,
                    );
                    let shared = &self.shared_scratch;
                    // bits_for_s(s_for_bits(w)) == w exactly for every legal
                    // width, so the small scale's wire payload is sb[0] bits
                    let payload_bits = sb[0] as f64;
                    let dst = match &coord_idx {
                        None => &mut out[elo..ehi],
                        Some(_) => &mut self.sub[elo..ehi],
                    };
                    fused::multiscale_step_packed_with_uniforms(
                        &g_slices,
                        &u_slices,
                        wnorm,
                        &table,
                        shared,
                        payload_bits,
                        &mut self.packed,
                        ctx,
                        None,
                        dst,
                    );
                    self.last_bits.push(sb[0]);
                    self.last_payload_bits += (8
                        * (bitpack::wire_bytes_for(ehi - elo, payload_bits as u32)
                            + bitpack::wire_bytes_for(ehi - elo, index_bits as u32)))
                        as f64;
                    if let Some(ef) = self.ef.as_mut() {
                        let (corrected, uni) = (&self.corrected, &self.uniform);
                        ctx.time_encode(|| {
                            ef.absorb_bucket_multiscale(
                                corrected, uni, elo, ehi, wnorm, &table, shared,
                            )
                        });
                    }
                }
            }
            self.bucket_comm[b] = ctx.clock.comm_s - comm_before;
        }
        if let Some(t) = ctx.tracer.as_deref_mut() {
            t.set_bucket(None);
        }

        // GlobalK: scatter the decoded K-vector back (+ optional n/K
        // unbiasedness rescale) — exactly the monolithic reconstruction
        if let Some(idx) = &coord_idx {
            let sub = &self.sub;
            ctx.time_decode(|| {
                for (j, &i) in idx.iter().enumerate() {
                    out[i] = sub[j] * rescale;
                }
            });
        }

        // overlap accounting: hide bucket comm inside the backward window
        let h0 = ctx.clock.hidden_comm_s;
        self.last_overlap = match (self.cfg.overlap, ctx.backward_s) {
            (true, Some(backward_s)) => {
                let ready = self.plan.ready_times(backward_s);
                let report = overlap::schedule(&ready, &self.bucket_comm, backward_s);
                ctx.clock.hidden_comm_s += report.hidden_s;
                if let Some(t) = ctx.tracer.as_deref_mut() {
                    t.push(crate::trace::Span::new(
                        crate::trace::Cat::HiddenComm,
                        crate::trace::SpanKind::Overlap {
                            hidden_s: report.hidden_s,
                            exposed_s: report.exposed_s,
                        },
                        h0,
                        ctx.clock.hidden_comm_s,
                        0.0,
                    ));
                }
                report
            }
            _ => OverlapReport::default(),
        };
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::bitpack;
    use crate::compress::qsgd_maxnorm::QsgdMaxNorm;
    use crate::netsim::{NetConfig, SimClock};

    use crate::runtime::contiguous_segments as segs;

    fn fixed_grads(seed: u64, m: usize, n: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal_f32(&mut v, 1.0);
                v
            })
            .collect()
    }

    fn run(
        agg: &mut dyn Aggregator,
        grads: &[Vec<f32>],
        seed: u64,
        backward_s: Option<f64>,
    ) -> (Vec<f32>, SimClock) {
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let out = {
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.backward_s = backward_s;
            let mut rng = Rng::new(seed);
            agg.aggregate(&refs, &mut ctx, &mut rng)
        };
        (out, clock)
    }

    #[test]
    fn single_bucket_fixed_bits_reproduces_monolithic_ledger_and_output() {
        let (m, n) = (4usize, 997usize);
        let grads = fixed_grads(0xC0FFEE, m, n);
        let segments = segs(&[400, 400, 197]);

        let mut mono = QsgdMaxNorm::new(4).unwrap();
        let (want, clock_mono) = run(&mut mono, &grads, 77, None);

        let cfg = ControlConfig::new(1);
        let mut plane = GradientControlPlane::new(cfg, 4, n, &segments).unwrap();
        let (got, clock_b) = run(&mut plane, &grads, 77, None);

        assert_eq!(got, want);
        assert_eq!(clock_b.bits_per_worker, clock_mono.bits_per_worker);
        assert_eq!(clock_b.hop_bits_per_worker, clock_mono.hop_bits_per_worker);
        assert_eq!(clock_b.comm_s, clock_mono.comm_s);
        assert_eq!(plane.last_bits(), &[4]);
    }

    fn run_cohort(
        plane: &mut GradientControlPlane,
        grads: &[&[f32]],
        ids: &[usize],
        seed: u64,
        backward_s: Option<f64>,
    ) -> (Vec<f32>, SimClock) {
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let out = {
            let mut ctx = StepCtx::new(&net, &mut clock);
            ctx.backward_s = backward_s;
            let mut rng = Rng::new(seed);
            plane.aggregate_cohort(grads, ids, &mut ctx, &mut rng)
        };
        (out, clock)
    }

    #[test]
    fn identity_cohort_is_bit_identical_to_aggregate() {
        let (m, n) = (4usize, 501usize);
        let grads = fixed_grads(0xE1A57, m, n);
        let segments = segs(&[200, 200, 101]);
        let ids: Vec<usize> = (0..m).collect();

        let cfg = ControlConfig::new(2);
        let mut a = GradientControlPlane::new(cfg.clone(), m, n, &segments).unwrap();
        let (want, clock_a) = run(&mut a, &grads, 11, Some(0.05));

        let mut b = GradientControlPlane::new(cfg, m, n, &segments).unwrap();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let (got, clock_b) = run_cohort(&mut b, &refs, &ids, 11, Some(0.05));

        assert_eq!(got, want);
        assert_eq!(clock_b.comm_s, clock_a.comm_s);
        assert_eq!(clock_b.bits_per_worker, clock_a.bits_per_worker);
        assert_eq!(clock_b.hidden_comm_s, clock_a.hidden_comm_s);
    }

    #[test]
    fn prefix_cohort_matches_a_monolithic_run_over_the_survivors() {
        // survivors {0, 1} of M=4: id-keyed streams coincide with
        // positional ones, so the partial all-reduce must be bit-identical
        // to a monolithic 2-worker run — live-M renormalization falls out
        // of the decode's 1/(s·m) fold with no extra bookkeeping
        let (m, n) = (4usize, 997usize);
        let grads = fixed_grads(0xD00D, m, n);
        let mut mono = QsgdMaxNorm::new(4).unwrap();
        let (want, clock_mono) = run(&mut mono, &grads[..2], 21, None);

        let segments = segs(&[n]);
        let mut plane =
            GradientControlPlane::new(ControlConfig::new(1), m, n, &segments).unwrap();
        let survivors: Vec<&[f32]> = grads[..2].iter().map(|v| v.as_slice()).collect();
        let (got, clock) = run_cohort(&mut plane, &survivors, &[0, 1], 21, None);

        assert_eq!(got, want);
        assert_eq!(clock.bits_per_worker, clock_mono.bits_per_worker);
        assert!(clock.hidden_comm_s <= clock.comm_s);
    }

    #[test]
    fn cohort_streams_are_keyed_by_original_worker_id() {
        // same two gradient slices, different surviving ids: only the
        // uniform streams differ, and the outputs must differ with them —
        // positional keying (the pre-elastic fill) would make these equal
        // and silently correlate a rejoined worker with its replacement
        let (m, n) = (4usize, 997usize);
        let grads = fixed_grads(0xF00D, m, n);
        let pair: Vec<&[f32]> = vec![grads[0].as_slice(), grads[1].as_slice()];
        let segments = segs(&[n]);

        let mut a = GradientControlPlane::new(ControlConfig::new(1), m, n, &segments).unwrap();
        let (low, _) = run_cohort(&mut a, &pair, &[0, 1], 9, None);
        let mut b = GradientControlPlane::new(ControlConfig::new(1), m, n, &segments).unwrap();
        let (high, _) = run_cohort(&mut b, &pair, &[0, 3], 9, None);
        assert_ne!(low, high);
    }

    #[test]
    fn default_aggregate_cohort_accepts_the_identity_and_rejects_subsets() {
        let (m, n) = (3usize, 64usize);
        let grads = fixed_grads(1, m, n);
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let mut mono = QsgdMaxNorm::new(4).unwrap();
        let (want, _) = run(&mut mono, &grads, 2, None);

        let net = NetConfig::flat(m, 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut fresh = QsgdMaxNorm::new(4).unwrap();
        let got = fresh.aggregate_cohort(&refs, &[0, 1, 2], &mut ctx, &mut Rng::new(2));
        assert_eq!(got, want);

        let partial = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let net = NetConfig::flat(2, 10.0);
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            let mut mono = QsgdMaxNorm::new(4).unwrap();
            mono.aggregate_cohort(&refs[..2], &[0, 2], &mut ctx, &mut Rng::new(2));
        }));
        assert!(partial.is_err(), "cohort-unaware aggregators must refuse subsets");
    }

    #[test]
    fn per_bucket_charging_is_byte_exact_never_rederived_from_whole_length() {
        // satellite bugfix pin: 3 ragged buckets at 2 bits — the per-bucket
        // byte ceilings sum to MORE than one whole-gradient ceiling, and the
        // ledger must show the per-bucket sum (a whole-length re-derivation
        // or a double byte-ceiling would both fail the equality).
        let (m, n) = (4usize, 97usize);
        let grads = fixed_grads(0xBEEF, m, n);
        let segments = segs(&[33, 33, 31]);
        let mut cfg = ControlConfig::new(3);
        cfg.bits = BitsPolicy::Fixed(Some(2));
        cfg.overlap = false;
        let mut plane = GradientControlPlane::new(cfg, 4, n, &segments).unwrap();
        assert_eq!(plane.plan.len(), 3);
        let (_, clock) = run(&mut plane, &grads, 5, None);

        let closed_form: f64 = [33usize, 33, 31]
            .iter()
            .map(|&l| (8 * bitpack::wire_bytes_for(l, 2)) as f64)
            .sum();
        assert_eq!(plane.last_payload_bits(), closed_form);
        // 32 norm bits + per-bucket byte-exact payloads
        assert_eq!(clock.bits_per_worker, 32.0 + closed_form);
        // and that differs from the whole-gradient ceiling (the bug shape)
        let whole = (8 * bitpack::wire_bytes_for(n, 2)) as f64;
        assert_ne!(closed_form, whole);
        assert_eq!(closed_form, 208.0);
        assert_eq!(whole, 200.0);
    }

    #[test]
    fn overlap_hides_comm_and_reports_positive_fraction() {
        // 1M coords keeps the per-hop cost bandwidth-dominated, so the
        // bucketed exposed tail (one bucket's hops) clears the monolithic
        // comm with a deterministic analytic margin
        let (m, n) = (16usize, 1 << 20);
        let grads = fixed_grads(0xABCD, m, n);
        let segments = segs(&[n / 4; 4]);

        let mut mono = QsgdMaxNorm::new(4).unwrap();
        let (_, clock_mono) = run(&mut mono, &grads, 3, Some(0.14));

        let cfg = ControlConfig::new(4);
        let mut plane = GradientControlPlane::new(cfg, 4, n, &segments).unwrap();
        let (_, clock_b) = run(&mut plane, &grads, 3, Some(0.14));

        // monolithic hides nothing
        assert_eq!(clock_mono.hidden_comm_s, 0.0);
        // bucketed hides a positive fraction and beats the monolithic
        // simulated step time (compute + exposed comm)
        assert!(clock_b.hidden_comm_s > 0.0);
        assert!(plane.last_overlap().overlap_frac > 0.0);
        let mono_step = 0.14 + clock_mono.comm_s;
        let buck_step = 0.14 + clock_b.comm_s - clock_b.hidden_comm_s;
        assert!(
            buck_step < mono_step,
            "bucketed-with-overlap {buck_step} must beat monolithic {mono_step}"
        );
        assert!(clock_b.hidden_comm_s <= clock_b.comm_s);
        assert!(clock_b.overlap_frac() > 0.0);
    }

    #[test]
    fn per_bucket_norm_scope_charges_one_scalar_per_bucket() {
        let (m, n) = (4usize, 512usize);
        let grads = fixed_grads(0x99, m, n);
        let segments = segs(&[128; 4]);
        let mut cfg = ControlConfig::new(4);
        cfg.norm_scope = NormScope::PerBucket;
        cfg.overlap = false;
        let mut plane = GradientControlPlane::new(cfg, 4, n, &segments).unwrap();
        let (out, clock) = run(&mut plane, &grads, 9, None);
        assert_eq!(out.len(), n);
        assert!(out.iter().all(|x| x.is_finite()));
        // 4 norm scalars instead of 1
        assert_eq!(
            clock.bits_per_worker,
            4.0 * 32.0 + plane.last_payload_bits()
        );
    }

    #[test]
    fn build_plane_rejects_incompatible_methods() {
        // satellite pin: the support matrix after PR 5 — every all-reduce-
        // compatible quantizer builds; the all-gather baselines and
        // PowerSGD are rejected loudly, with a message that names the
        // supported family instead of the stale "qsgd-mn-* only" claim.
        let cfg = ControlConfig::new(4);
        assert!(build_plane(&Method::Qsgd { bits: 4 }, &cfg, 100, &[]).is_ok());
        assert!(build_plane(&Method::QsgdTs { bits: vec![2, 6] }, &cfg, 100, &[]).is_ok());
        assert!(build_plane(&Method::RandK { bits: 4, k: Some(20) }, &cfg, 100, &[]).is_ok());
        assert!(
            build_plane(&Method::RandKTs { bits: vec![4, 8], k: None }, &cfg, 100, &[]).is_ok()
        );
        for bad in [
            Method::SignSgd,
            Method::TernGrad,
            Method::AllReduceSgd,
            Method::PowerSgd { rank: 2 },
            Method::TopK { k: Some(10) },
        ] {
            let err = build_plane(&bad, &cfg, 100, &[]).unwrap_err().to_string();
            assert!(
                err.contains("qsgd-mn-ts-*") && err.contains(&bad.label()),
                "rejection for {bad:?} must name the supported family: {err}"
            );
        }
    }

    #[test]
    fn build_plane_rejects_error_feedback_on_grandk() {
        let mut cfg = ControlConfig::new(4);
        cfg.error_feedback = true;
        let err = build_plane(&Method::RandK { bits: 4, k: Some(20) }, &cfg, 100, &[])
            .unwrap_err()
            .to_string();
        assert!(err.contains("error-feedback"), "{err}");
        // dense methods keep EF
        assert!(build_plane(&Method::QsgdTs { bits: vec![2, 6] }, &cfg, 100, &[]).is_ok());
    }

    #[test]
    fn single_bucket_multiscale_reproduces_monolithic_ledger_and_output() {
        use crate::compress::multiscale::QsgdMultiScale;
        let (m, n) = (4usize, 997usize);
        let grads = fixed_grads(0xC0FFEE, m, n);
        let segments = segs(&[400, 400, 197]);

        let mut mono = QsgdMultiScale::new(&[2, 6]).unwrap();
        let (want, clock_mono) = run(&mut mono, &grads, 77, None);

        let cfg = ControlConfig::new(1);
        let mut plane =
            GradientControlPlane::new_multiscale(cfg, &[2, 6], n, &segments).unwrap();
        let (got, clock_b) = run(&mut plane, &grads, 77, None);

        assert_eq!(got, want);
        assert_eq!(clock_b.bits_per_worker, clock_mono.bits_per_worker);
        assert_eq!(clock_b.comm_s, clock_mono.comm_s);
        assert_eq!(plane.last_bits(), &[2]);
        assert_eq!(plane.name(), "QSGD-MN-TS-(2,6)-B1[fixed:2]");
    }

    #[test]
    fn single_bucket_grandk_reproduces_monolithic_output_and_ledger() {
        use crate::compress::randk::GlobalRandK;
        let (m, n, k) = (4usize, 600usize, 48usize);
        let grads = fixed_grads(0xFACE, m, n);
        let segments = segs(&[200, 200, 200]);

        let mut mono = GlobalRandK::new(4, k, n).unwrap();
        let (want, clock_mono) = run(&mut mono, &grads, 31, None);

        let cfg = ControlConfig::new(1);
        let mut plane = GradientControlPlane::new_randk(cfg, 4, k, n, &segments).unwrap();
        let (got, clock_b) = run(&mut plane, &grads, 31, None);

        assert_eq!(got, want);
        assert_eq!(clock_b.bits_per_worker, clock_mono.bits_per_worker);
        assert_eq!(plane.last_bucket_lens().iter().sum::<usize>(), k);
    }

    #[test]
    fn grandk_routing_covers_the_draw_with_ragged_bucket_counts() {
        // the sorted K-draw partitions exactly across buckets: ragged K_b,
        // sum K_b = K, and the ledger is the per-bucket byte-exact sum
        let (m, n, k) = (4usize, 97usize, 31usize);
        let grads = fixed_grads(0xBEEF, m, n);
        let segments = segs(&[33, 33, 31]);
        let mut cfg = ControlConfig::new(3);
        cfg.bits = BitsPolicy::Fixed(Some(2));
        cfg.overlap = false;
        let mut plane = GradientControlPlane::new_randk(cfg, 4, k, n, &segments).unwrap();
        plane.set_rescale(true);
        assert_eq!(plane.plan.len(), 3);
        let (out, clock) = run(&mut plane, &grads, 5, None);
        assert!(out.iter().filter(|x| **x != 0.0).count() <= k);
        let lens = plane.last_bucket_lens().to_vec();
        assert_eq!(lens.iter().sum::<usize>(), k);
        assert_eq!(lens.len(), 3);
        let closed: f64 = lens
            .iter()
            .map(|&l| (8 * bitpack::wire_bytes_for(l, 2)) as f64)
            .sum();
        assert_eq!(plane.last_payload_bits(), closed);
        assert_eq!(clock.bits_per_worker, 32.0 + closed);
    }

    #[test]
    fn multiscale_per_bucket_charging_includes_the_scale_share() {
        // ragged buckets at scale set (2,6): per bucket the ledger carries
        // 8*ceil(len*2/8) level bits + 8*ceil(len*1/8) share bits — the
        // per-bucket sum, never a whole-gradient re-derivation
        let (m, n) = (4usize, 97usize);
        let grads = fixed_grads(0xBEEF, m, n);
        let segments = segs(&[33, 33, 31]);
        let mut cfg = ControlConfig::new(3);
        cfg.overlap = false;
        let mut plane =
            GradientControlPlane::new_multiscale(cfg, &[2, 6], n, &segments).unwrap();
        let (_, clock) = run(&mut plane, &grads, 5, None);
        let closed: f64 = [33usize, 33, 31]
            .iter()
            .map(|&l| {
                (8 * (bitpack::wire_bytes_for(l, 2) + bitpack::wire_bytes_for(l, 1))) as f64
            })
            .sum();
        assert_eq!(plane.last_payload_bits(), closed);
        assert_eq!(clock.bits_per_worker, 32.0 + closed);
        let whole = (8 * (bitpack::wire_bytes_for(n, 2) + bitpack::wire_bytes_for(n, 1))) as f64;
        assert_ne!(closed, whole, "ragged buckets must expose the per-bucket sum");
    }

    #[test]
    fn fixed_explicit_bits_reanchors_the_ts_scale_set() {
        let segments = segs(&[50, 50]);
        let mut cfg = ControlConfig::new(2);
        cfg.bits = BitsPolicy::Fixed(Some(4));
        let plane = GradientControlPlane::new_multiscale(cfg, &[2, 6], 100, &segments).unwrap();
        // (2,6) shifted so the small scale is 4 bits -> (4,8)
        assert_eq!(plane.name(), "QSGD-MN-TS-(4,8)-B2[fixed:4]");
    }

    #[test]
    fn explicit_widths_overflowing_the_ts_span_are_rejected_not_clamped() {
        // (2,6) spans 4 bits, so the small scale can be at most 12: a
        // requested fixed:14 would silently run at 12 if clamped — reject
        let segments = segs(&[50, 50]);
        let mut cfg = ControlConfig::new(2);
        cfg.bits = BitsPolicy::Fixed(Some(14));
        let err = GradientControlPlane::new_multiscale(cfg, &[2, 6], 100, &segments)
            .unwrap_err()
            .to_string();
        assert!(err.contains("at most 12"), "{err}");
        // same rule for per-layer widths
        let mut cfg = ControlConfig::new(2);
        cfg.bits = BitsPolicy::PerLayer(vec![4, 14]);
        assert!(
            GradientControlPlane::new_multiscale(cfg, &[2, 6], 100, &segments).is_err()
        );
        // the boundary width (12 + span 4 = 16) still builds
        let mut cfg = ControlConfig::new(2);
        cfg.bits = BitsPolicy::Fixed(Some(12));
        assert!(
            GradientControlPlane::new_multiscale(cfg, &[2, 6], 100, &segments).is_ok()
        );
        // and the single-scale plane is unaffected (no span constraint)
        let mut cfg = ControlConfig::new(2);
        cfg.bits = BitsPolicy::Fixed(Some(14));
        assert!(GradientControlPlane::new(cfg, 4, 100, &segments).is_ok());
    }

    #[test]
    fn auto_bits_rejected_when_the_ts_span_leaves_no_room_to_adapt() {
        // (2,16) spans 14 bits: the only legal small scale is 2, so an
        // "auto" controller could never move a width — reject rather than
        // silently running a fixed policy labeled [auto]
        let segments = segs(&[50, 50]);
        let mut cfg = ControlConfig::new(2);
        cfg.bits = BitsPolicy::Auto;
        let err = GradientControlPlane::new_multiscale(cfg, &[2, 16], 100, &segments)
            .unwrap_err()
            .to_string();
        assert!(err.contains("auto"), "{err}");
        // a set with adaptive headroom still builds under auto
        let mut cfg = ControlConfig::new(2);
        cfg.bits = BitsPolicy::Auto;
        assert!(
            GradientControlPlane::new_multiscale(cfg, &[2, 6], 100, &segments).is_ok()
        );
        // the pre-check callers use agrees with the build-time rejection
        assert!(!auto_can_adapt(&Method::QsgdTs { bits: vec![2, 16] }));
        assert!(auto_can_adapt(&Method::QsgdTs { bits: vec![2, 6] }));
        assert!(auto_can_adapt(&Method::Qsgd { bits: 4 }));
    }

    #[test]
    fn error_feedback_changes_the_step_but_stays_finite() {
        let (m, n) = (3usize, 300usize);
        let grads = fixed_grads(0x5A5A, m, n);
        let segments = segs(&[100; 3]);
        let mut cfg = ControlConfig::new(3);
        cfg.error_feedback = true;
        cfg.bits = BitsPolicy::Fixed(Some(8));
        let mut plane = GradientControlPlane::new(cfg, 8, n, &segments).unwrap();
        // first step: residual starts at zero, so outputs match the EF-less
        // plane; afterwards the residual is non-zero and folded in
        let mut plain =
            GradientControlPlane::new(ControlConfig::new(3), 8, n, &segments).unwrap();
        let (a, _) = run(&mut plane, &grads, 21, None);
        let (b, _) = run(&mut plain, &grads, 21, None);
        assert_eq!(a, b, "step 1 has zero residual");
        assert!(plane.max_residual_norm() > 0.0);
        let (c, _) = run(&mut plane, &grads, 22, None);
        let (d, _) = run(&mut plain, &grads, 22, None);
        assert_ne!(c, d, "step 2 folds the residual in");
        assert!(c.iter().all(|x| x.is_finite()));
    }
}
