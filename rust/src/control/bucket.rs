//! Bucket partitioner: DDP-style grouping of the flat gradient into
//! contiguous buckets along the model's [`Segment`] (layer) boundaries.
//!
//! Buckets are the unit the control plane compresses, reduces, charges, and
//! schedules independently: each flows through the packed pipeline with its
//! own bit-width and its own byte-exact wire payload, and is released to
//! the (simulated) wire as soon as its layers' backward pass completes.
//! Grouping whole layers keeps the partition aligned with where gradients
//! actually become available — exactly PyTorch DDP's bucketing rule —
//! while a capacity target bounds per-bucket latency overhead.

use crate::runtime::Segment;

/// One contiguous bucket of the flat gradient.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// coordinate range `[lo, hi)` of the flat gradient
    pub lo: usize,
    pub hi: usize,
    /// atom (segment) index range `[seg_lo, seg_hi)` the bucket covers
    pub seg_lo: usize,
    pub seg_hi: usize,
}

impl Bucket {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// Coordinate alignment of synthetic atom boundaries when the model carries
/// no segment metadata (mirrors DDP's byte alignment of bucket views).
const SYNTH_ALIGN: usize = 16;

/// A partition of `[0, n)` into contiguous buckets whose interior
/// boundaries all lie on atom (layer) boundaries.
#[derive(Clone, Debug)]
pub struct BucketPlan {
    pub n: usize,
    pub buckets: Vec<Bucket>,
    /// atom lengths the plan was built over (segment lengths, or synthetic
    /// aligned splits when the model has no segment metadata)
    pub atom_lens: Vec<usize>,
}

impl BucketPlan {
    /// Partition `n` coordinates into at most `target` buckets.
    ///
    /// When `segments` is non-empty and tiles `[0, n)` contiguously, whole
    /// segments are greedily grouped until each bucket reaches the
    /// `ceil(n/target)` capacity — so bucket boundaries always coincide
    /// with layer boundaries and the last bucket may be ragged. Without
    /// segment metadata the plan falls back to `target` near-even splits
    /// aligned down to [`SYNTH_ALIGN`] coordinates.
    pub fn new(n: usize, segments: &[Segment], target: usize) -> BucketPlan {
        let target = target.max(1);
        let atom_lens = if segments_tile(n, segments) {
            segments.iter().map(|s| s.len).collect()
        } else {
            synthetic_atoms(n, target)
        };
        let capacity = n.div_ceil(target).max(1);

        let mut buckets = Vec::new();
        let (mut lo, mut seg_lo, mut filled) = (0usize, 0usize, 0usize);
        for (i, &len) in atom_lens.iter().enumerate() {
            filled += len;
            if filled >= capacity || i + 1 == atom_lens.len() {
                let hi = lo + filled;
                buckets.push(Bucket { lo, hi, seg_lo, seg_hi: i + 1 });
                lo = hi;
                seg_lo = i + 1;
                filled = 0;
            }
        }
        if buckets.is_empty() {
            buckets.push(Bucket { lo: 0, hi: n, seg_lo: 0, seg_hi: atom_lens.len().max(1) });
        }
        debug_assert_eq!(buckets.last().unwrap().hi, n);
        let atom_lens = if atom_lens.is_empty() { vec![n] } else { atom_lens };
        BucketPlan { n, buckets, atom_lens }
    }

    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// Simulated time each bucket's gradient becomes available inside a
    /// backward window of `backward_s` seconds: a bucket is ready when its
    /// *earliest* atom finishes backward (backward runs last layer first,
    /// so that atom completes last among the bucket's).
    pub fn ready_times(&self, backward_s: f64) -> Vec<f64> {
        let seg_ready = crate::perfmodel::backward_ready_times(&self.atom_lens, backward_s);
        self.buckets
            .iter()
            .map(|b| if self.atom_lens.is_empty() { backward_s } else { seg_ready[b.seg_lo] })
            .collect()
    }
}

/// Do the segments contiguously tile `[0, n)`?
fn segments_tile(n: usize, segments: &[Segment]) -> bool {
    if segments.is_empty() {
        return false;
    }
    let mut off = 0usize;
    for s in segments {
        if s.offset != off {
            return false;
        }
        off += s.len;
    }
    off == n
}

/// Near-even aligned splits for models without segment metadata.
fn synthetic_atoms(n: usize, target: usize) -> Vec<usize> {
    if n == 0 {
        return vec![0];
    }
    let mut bounds = vec![0usize];
    for b in 1..target {
        let cut = (b * n / target) / SYNTH_ALIGN * SYNTH_ALIGN;
        if cut > *bounds.last().unwrap() && cut < n {
            bounds.push(cut);
        }
    }
    bounds.push(n);
    bounds.windows(2).map(|w| w[1] - w[0]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn seg(offset: usize, len: usize) -> Segment {
        Segment { name: format!("seg@{offset}"), shape: vec![len], offset, len }
    }

    use crate::runtime::contiguous_segments as segs;

    #[test]
    fn plan_covers_exactly_and_respects_segment_boundaries() {
        let lens = [256usize, 512, 128, 107];
        let n: usize = lens.iter().sum();
        let segments = segs(&lens);
        for target in [1usize, 2, 3, 4, 9] {
            let plan = BucketPlan::new(n, &segments, target);
            assert!(plan.len() <= target.max(1));
            // exact contiguous cover
            let mut off = 0;
            for b in &plan.buckets {
                assert_eq!(b.lo, off);
                off = b.hi;
                // interior boundaries are segment boundaries
                let seg_offsets: Vec<usize> = segments.iter().map(|s| s.offset).collect();
                if b.hi != n {
                    assert!(seg_offsets.contains(&b.hi), "boundary {} off-segment", b.hi);
                }
            }
            assert_eq!(off, n);
        }
        // target >= #segments: one bucket per segment, last ragged
        let plan = BucketPlan::new(n, &segments, 9);
        assert_eq!(plan.len(), 4);
        assert_eq!(plan.buckets[3].len(), 107);
    }

    #[test]
    fn single_bucket_plan_is_whole_gradient() {
        let plan = BucketPlan::new(1000, &segs(&[400, 600]), 1);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.buckets[0], Bucket { lo: 0, hi: 1000, seg_lo: 0, seg_hi: 2 });
    }

    #[test]
    fn no_segments_falls_back_to_aligned_splits() {
        let plan = BucketPlan::new(1003, &[], 3);
        assert_eq!(plan.buckets.last().unwrap().hi, 1003);
        for b in &plan.buckets {
            if b.hi != 1003 {
                assert_eq!(b.hi % SYNTH_ALIGN, 0, "unaligned synthetic boundary");
            }
        }
        // non-tiling segments (gap) also fall back
        let gappy = vec![seg(0, 100), seg(200, 100)];
        let plan = BucketPlan::new(300, &gappy, 2);
        assert_eq!(plan.buckets.last().unwrap().hi, 300);
    }

    #[test]
    fn ready_times_follow_backward_order() {
        let plan = BucketPlan::new(1000, &segs(&[250, 250, 250, 250]), 4);
        let ready = plan.ready_times(1.0);
        assert_eq!(ready.len(), 4);
        // later buckets (later layers) become ready earlier
        assert!(ready.windows(2).all(|w| w[0] >= w[1]));
        assert_eq!(ready[0], 1.0); // first bucket needs the full backward
        assert!((ready[3] - 0.25).abs() < 1e-12);
    }
}
