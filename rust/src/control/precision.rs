//! Per-bucket bit-width policies.
//!
//! The controller picks each bucket's quantizer bit-width per step. The
//! interesting policy is [`VarianceAdaptive`]: it tracks a running estimate
//! of every bucket's gradient second moment and picks the *cheapest* width
//! whose Lemma-5 quantization variance stays under a target fraction of it
//! — variance-based compression in the spirit of Tsuzuku et al. (2018) and
//! ScaleCom's per-chunk scaling, on top of the paper's QSGDMaxNorm
//! quantizer. [`FixedBits`] reproduces the monolithic path exactly (the
//! bit-identity pin); [`PerLayerBits`] opens heterogeneous per-layer
//! precision from an explicit spec.

use anyhow::{bail, Result};

use crate::compress::kernels;

use super::bucket::BucketPlan;

/// Per-step bucket statistics the controller decides from.
#[derive(Clone, Copy, Debug)]
pub struct BucketStats {
    /// coordinates in the bucket
    pub len: usize,
    /// the norm the bucket will be encoded against this step
    pub wnorm: f32,
    /// current mean over workers of `||g_bucket||^2`
    pub grad_ms: f64,
    /// worker count (the m-way average divides the quantizer variance)
    pub workers: usize,
}

/// Shift a sorted-ascending multi-scale bit set so its smallest scale
/// sits at `small` bits, preserving the gaps between scales (which keeps
/// the set distinct); the whole set is clamped into `2..=16`. This is how
/// every controller maps its per-bucket width decision onto a TS method's
/// scale *pair*: the small scale carries the wire budget (the payload is
/// `bits_for_s(s_min)` wide, eq. 10), so the small scale is the knob the
/// variance target turns, and the large scale rides along at the
/// configured refinement gap. The clamp exists for the *adaptive*
/// best-effort path only — explicitly requested widths (`fixed:N`,
/// `perlayer:`) are validated against the span at plane construction and
/// rejected rather than silently clamped.
pub fn shift_scale_bits(base: &[usize], small: usize) -> Vec<usize> {
    debug_assert!(!base.is_empty() && base.windows(2).all(|w| w[0] < w[1]));
    let span = base[base.len() - 1] - base[0];
    let lo = small.clamp(2, 16 - span);
    base.iter().map(|&b| b - base[0] + lo).collect()
}

/// A per-bucket bit-width policy. Stateful: exactly one of `bits_for`
/// (single-scale schemes) or `scale_bits_for` (multi-scale schemes) is
/// called once per bucket per step, in bucket order, so adaptive policies
/// can maintain running statistics.
pub trait PrecisionController: Send {
    /// Short label for run tables ("fixed:4", "auto", "perlayer").
    fn label(&self) -> String;

    /// Does this policy read `BucketStats::grad_ms`? Static policies return
    /// false so the control plane skips the O(m·n) per-bucket moment pass.
    fn needs_stats(&self) -> bool {
        true
    }

    /// Bit-width (in `2..=16`) for bucket `b` this step.
    fn bits_for(&mut self, b: usize, stats: &BucketStats) -> usize;

    /// Scale set (bit-widths, sorted ascending) for bucket `b` of a
    /// multi-scale (TS) method whose configured set is `base`. The default
    /// keeps the method's set — the static choice `FixedBits` relies on for
    /// the monolithic bit-identity pin. Adaptive policies shift the set
    /// ([`shift_scale_bits`]) so the small scale meets their variance
    /// target: Lemma 6 bounds the multi-scale variance by the single-scale
    /// Lemma-5 bound at `s_min`, so targeting the small scale is sound.
    fn scale_bits_for(&mut self, b: usize, stats: &BucketStats, base: &[usize]) -> Vec<usize> {
        let _ = (b, stats);
        base.to_vec()
    }
}

/// Every bucket at one width — with a single bucket this reproduces the
/// monolithic packed path bit for bit.
#[derive(Clone, Copy, Debug)]
pub struct FixedBits(pub usize);

impl PrecisionController for FixedBits {
    fn label(&self) -> String {
        format!("fixed:{}", self.0)
    }

    fn needs_stats(&self) -> bool {
        false
    }

    fn bits_for(&mut self, _b: usize, _stats: &BucketStats) -> usize {
        self.0
    }
}

/// Explicit per-bucket widths, resolved at construction from a per-layer
/// spec: a bucket spanning several layers takes the widest of them.
#[derive(Clone, Debug)]
pub struct PerLayerBits {
    per_bucket: Vec<usize>,
}

impl PerLayerBits {
    /// `per_layer[i]` is the width of atom (layer) `i` of `plan`; the spec
    /// must cover every atom.
    pub fn new(per_layer: &[usize], plan: &BucketPlan) -> Result<PerLayerBits> {
        anyhow::ensure!(
            per_layer.len() == plan.atom_lens.len(),
            "per-layer bits spec has {} entries for {} layers",
            per_layer.len(),
            plan.atom_lens.len()
        );
        for &b in per_layer {
            anyhow::ensure!((2..=16).contains(&b), "per-layer bits {b} not in 2..=16");
        }
        let per_bucket = plan
            .buckets
            .iter()
            .map(|bk| per_layer[bk.seg_lo..bk.seg_hi].iter().copied().max().unwrap_or(2))
            .collect();
        Ok(PerLayerBits { per_bucket })
    }
}

impl PrecisionController for PerLayerBits {
    fn label(&self) -> String {
        "perlayer".into()
    }

    fn needs_stats(&self) -> bool {
        false
    }

    fn bits_for(&mut self, b: usize, _stats: &BucketStats) -> usize {
        self.per_bucket[b]
    }

    fn scale_bits_for(&mut self, b: usize, _stats: &BucketStats, base: &[usize]) -> Vec<usize> {
        // per-layer spec names the bucket's small-scale width; the rest of
        // the set keeps the configured refinement gaps
        shift_scale_bits(base, self.per_bucket[b])
    }
}

/// Variance-targeting adaptive widths.
///
/// Per bucket it keeps an EMA of the gradient second moment `E||g_b||^2`
/// and each step picks the smallest `bits` whose Lemma-5 bound on the
/// m-averaged quantization variance,
/// `min(n_b/s^2, sqrt(n_b)/s) * wnorm^2 / m` with `s = 2^(bits-1) - 1`,
/// stays `<= target_frac * E||g_b||^2`. Falls back to `max_bits` (best
/// effort) when no width in range meets the target. With error feedback the
/// inputs (and hence `wnorm`) include the residual, so a growing residual
/// automatically buys more precision — the stabilizing loop.
#[derive(Clone, Debug)]
pub struct VarianceAdaptive {
    pub target_frac: f64,
    pub min_bits: usize,
    pub max_bits: usize,
    /// EMA decay of the per-bucket gradient second moment
    pub beta: f64,
    ema_ms: Vec<f64>,
    seen: Vec<bool>,
}

impl VarianceAdaptive {
    pub fn new(target_frac: f64, min_bits: usize, max_bits: usize) -> Result<VarianceAdaptive> {
        anyhow::ensure!(target_frac > 0.0, "target fraction must be positive");
        if !(2..=16).contains(&min_bits) || !(2..=16).contains(&max_bits) || min_bits > max_bits {
            bail!("adaptive bits range {min_bits}..={max_bits} invalid (need 2..=16)");
        }
        Ok(VarianceAdaptive {
            target_frac,
            min_bits,
            max_bits,
            beta: 0.9,
            ema_ms: Vec::new(),
            seen: Vec::new(),
        })
    }

    /// The defaults the `--bits auto` CLI spec resolves to: quantization
    /// variance within 10% of the gradient's, widths free in 2..=12.
    pub fn default_policy() -> VarianceAdaptive {
        VarianceAdaptive::new(0.1, 2, 12).unwrap()
    }

    /// Lemma-5 bound on the m-averaged quantization variance at `bits`.
    pub fn lemma5_var(len: usize, wnorm: f32, bits: usize, workers: usize) -> f64 {
        let s = kernels::s_for_bits(bits) as f64;
        let n = len as f64;
        let w2 = (wnorm as f64) * (wnorm as f64);
        (n / (s * s)).min(n.sqrt() / s) * w2 / workers.max(1) as f64
    }
}

impl PrecisionController for VarianceAdaptive {
    fn label(&self) -> String {
        "auto".into()
    }

    fn bits_for(&mut self, b: usize, stats: &BucketStats) -> usize {
        if self.ema_ms.len() <= b {
            self.ema_ms.resize(b + 1, 0.0);
            self.seen.resize(b + 1, false);
        }
        self.ema_ms[b] = if self.seen[b] {
            self.beta * self.ema_ms[b] + (1.0 - self.beta) * stats.grad_ms
        } else {
            self.seen[b] = true;
            stats.grad_ms
        };
        let target = self.target_frac * self.ema_ms[b];
        for bits in self.min_bits..=self.max_bits {
            if Self::lemma5_var(stats.len, stats.wnorm, bits, stats.workers) <= target {
                return bits;
            }
        }
        self.max_bits
    }

    fn scale_bits_for(&mut self, b: usize, stats: &BucketStats, base: &[usize]) -> Vec<usize> {
        // Lemma 6: the multi-scale variance is bounded by the single-scale
        // Lemma-5 bound at s_min, so the small-scale width is picked against
        // exactly the same per-bucket variance target as `bits_for` (one EMA
        // update per bucket per step either way), and the set shifts with it.
        let small = self.bits_for(b, stats);
        shift_scale_bits(base, small)
    }
}

/// Parsed `--bits` CLI spec.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BitsPolicy {
    /// `auto` — [`VarianceAdaptive::default_policy`]
    Auto,
    /// `fixed:<b>`; `None` inherits the method's bit-width
    Fixed(Option<usize>),
    /// `perlayer:<b1>,<b2>,...` — one width per model segment
    PerLayer(Vec<usize>),
}

impl BitsPolicy {
    pub fn parse(spec: &str) -> Result<BitsPolicy> {
        let s = spec.trim().to_ascii_lowercase();
        if s == "auto" {
            return Ok(BitsPolicy::Auto);
        }
        if s == "fixed" {
            return Ok(BitsPolicy::Fixed(None));
        }
        if let Some(b) = s.strip_prefix("fixed:") {
            return Ok(BitsPolicy::Fixed(Some(b.parse().map_err(|e| {
                anyhow::anyhow!("bad --bits spec '{spec}': {e}")
            })?)));
        }
        if let Some(list) = s.strip_prefix("perlayer:") {
            let bits: Result<Vec<usize>> = list
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad --bits entry '{p}': {e}"))
                })
                .collect();
            return Ok(BitsPolicy::PerLayer(bits?));
        }
        bail!("unknown --bits spec '{spec}' (expected auto | fixed[:N] | perlayer:a,b,...)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::bucket::BucketPlan;

    #[test]
    fn bits_policy_parses() {
        assert_eq!(BitsPolicy::parse("auto").unwrap(), BitsPolicy::Auto);
        assert_eq!(BitsPolicy::parse("fixed").unwrap(), BitsPolicy::Fixed(None));
        assert_eq!(BitsPolicy::parse("fixed:6").unwrap(), BitsPolicy::Fixed(Some(6)));
        assert_eq!(
            BitsPolicy::parse("perlayer:2,4,8").unwrap(),
            BitsPolicy::PerLayer(vec![2, 4, 8])
        );
        assert!(BitsPolicy::parse("nonsense").is_err());
        assert!(BitsPolicy::parse("fixed:x").is_err());
    }

    #[test]
    fn adaptive_spends_more_bits_when_variance_budget_is_tight() {
        let mut ctrl = VarianceAdaptive::new(0.1, 2, 12).unwrap();
        // big norm relative to the gradient moment -> needs a fine grid
        let fine = ctrl.bits_for(
            0,
            &BucketStats { len: 1024, wnorm: 10.0, grad_ms: 1.0, workers: 4 },
        );
        // same shape, generous budget -> coarse grid suffices
        let mut ctrl2 = VarianceAdaptive::new(0.1, 2, 12).unwrap();
        let coarse = ctrl2.bits_for(
            0,
            &BucketStats { len: 1024, wnorm: 10.0, grad_ms: 1e6, workers: 4 },
        );
        assert!(fine > coarse, "fine {fine} vs coarse {coarse}");
        assert!((2..=12).contains(&fine) && (2..=12).contains(&coarse));
        // the picked width actually meets the target (when not saturated)
        let target = 0.1 * 1.0;
        assert!(VarianceAdaptive::lemma5_var(1024, 10.0, fine, 4) <= target || fine == 12);
    }

    #[test]
    fn adaptive_ema_smooths_spikes() {
        let mut ctrl = VarianceAdaptive::new(0.1, 2, 12).unwrap();
        let calm = BucketStats { len: 256, wnorm: 1.0, grad_ms: 4.0, workers: 4 };
        let b0 = ctrl.bits_for(0, &calm);
        // one zero-moment spike must not instantly slam the width to max
        let spike = BucketStats { len: 256, wnorm: 1.0, grad_ms: 1e-12, workers: 4 };
        let b1 = ctrl.bits_for(0, &spike);
        assert!(b1 <= 12 && b1 >= b0, "ema keeps the width sane: {b0} -> {b1}");
    }

    #[test]
    fn per_layer_bits_take_bucket_max() {
        use crate::runtime::Segment;
        let segs: Vec<Segment> = [(0usize, 100usize), (100, 100), (200, 100)]
            .iter()
            .map(|&(offset, len)| Segment {
                name: format!("s{offset}"),
                shape: vec![len],
                offset,
                len,
            })
            .collect();
        let plan = BucketPlan::new(300, &segs, 2); // capacity 150: {[0,200), [200,300)}
        let mut ctrl = PerLayerBits::new(&[2, 8, 4], &plan).unwrap();
        let stats = BucketStats { len: 1, wnorm: 1.0, grad_ms: 1.0, workers: 1 };
        assert_eq!(ctrl.bits_for(0, &stats), 8); // max(2, 8)
        assert_eq!(ctrl.bits_for(1, &stats), 4);
        assert!(PerLayerBits::new(&[2, 8], &plan).is_err()); // wrong arity
        assert!(PerLayerBits::new(&[2, 8, 99], &plan).is_err()); // out of range
    }

    #[test]
    fn shift_scale_bits_preserves_gaps_and_clamps() {
        assert_eq!(shift_scale_bits(&[2, 6], 4), vec![4, 8]);
        assert_eq!(shift_scale_bits(&[2, 6], 2), vec![2, 6]); // identity
        assert_eq!(shift_scale_bits(&[2, 6, 10], 3), vec![3, 7, 11]);
        // clamp: the large scale may not exceed 16 bits
        assert_eq!(shift_scale_bits(&[2, 6], 14), vec![12, 16]);
        // floor: the small scale may not drop below 2
        assert_eq!(shift_scale_bits(&[4, 8], 1), vec![2, 6]);
    }

    #[test]
    fn static_policies_keep_or_anchor_the_scale_set() {
        let stats = BucketStats { len: 64, wnorm: 1.0, grad_ms: 1.0, workers: 2 };
        // FixedBits keeps the resolved base set untouched (the plane
        // re-anchors once at construction): the bit-identity pin
        let mut fixed = FixedBits(2);
        assert_eq!(fixed.scale_bits_for(0, &stats, &[2, 6]), vec![2, 6]);
        // PerLayerBits anchors per bucket at its small-scale width
        use crate::runtime::contiguous_segments as segs;
        let plan = BucketPlan::new(200, &segs(&[100, 100]), 2);
        let mut pl = PerLayerBits::new(&[4, 8], &plan).unwrap();
        assert_eq!(pl.scale_bits_for(0, &stats, &[2, 6]), vec![4, 8]);
        assert_eq!(pl.scale_bits_for(1, &stats, &[2, 6]), vec![8, 12]);
    }

    #[test]
    fn adaptive_scale_set_shifts_with_the_variance_budget() {
        // tight budget -> finer small scale than the generous budget's;
        // the gap between the scales is preserved either way
        let tight = VarianceAdaptive::new(0.1, 2, 12)
            .unwrap()
            .scale_bits_for(0, &BucketStats { len: 1024, wnorm: 10.0, grad_ms: 1.0, workers: 4 }, &[2, 6]);
        let loose = VarianceAdaptive::new(0.1, 2, 12)
            .unwrap()
            .scale_bits_for(0, &BucketStats { len: 1024, wnorm: 10.0, grad_ms: 1e6, workers: 4 }, &[2, 6]);
        assert!(tight[0] > loose[0], "tight {tight:?} vs loose {loose:?}");
        assert_eq!(tight[1] - tight[0], 4);
        assert_eq!(loose[1] - loose[0], 4);
    }

    #[test]
    fn fixed_bits_is_constant() {
        let mut ctrl = FixedBits(4);
        let stats = BucketStats { len: 10, wnorm: 5.0, grad_ms: 0.001, workers: 2 };
        assert_eq!(ctrl.bits_for(0, &stats), 4);
        assert_eq!(ctrl.bits_for(7, &stats), 4);
        assert_eq!(ctrl.label(), "fixed:4");
    }
}
