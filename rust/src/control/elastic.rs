//! Elastic-cohort policy layer (PR 6): who participates in each step's
//! collective, and what the coordination costs in simulated time.
//!
//! The data plane below this module is cohort-agnostic — the decode's
//! `1/(s·m)` fold and the packed resident width `bitlen(2·M_live·lmax)`
//! re-derive from however many gradient slices it is handed, so the
//! unbiased mean estimator renormalizes for the live M automatically
//! (pinned in `tests/paper_properties.rs`). What this module adds is the
//! *decision*: a [`CohortPolicy`] turns the per-worker step times of a
//! [`FaultPlan`] into a [`StepPlan`] — who is live, whether the step
//! synchronizes, how long the window is, and how much of it is straggler
//! wait — plus the local-accumulation state that carries non-synchronized
//! gradients to the next sync.
//!
//! Modeling choices (documented in DESIGN.md "Elastic cohort & fault
//! model"):
//! * A non-synchronizing step charges the profile compute time and zero
//!   wait — nobody coordinates, so nobody waits; per-worker jitter drift
//!   between syncs surfaces as straggler wait at the next sync.
//! * Periodic-sync is modeled as local gradient accumulation with a
//!   quantized all-reduce of the averaged accumulator every `period`
//!   steps (parameters stay replicated; the vmapped step function shares
//!   one parameter vector, so true per-worker parameter drift is out of
//!   scope until parameters shard).
//! * A rejoining worker pays a tree broadcast of the fp32 parameter
//!   vector ([`ElasticCohort::catch_up_s`]) and restarts with zero
//!   staleness and an empty accumulator.

use anyhow::{bail, ensure, Context, Result};

use crate::netsim::{EventKind, FaultPlan, NetConfig};

/// When a step's collective runs and over whom.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CohortPolicy {
    /// Every member joins every step's collective; the window is the
    /// slowest member's compute time. Under [`FaultPlan::none`] this is
    /// bit-identical to the pre-elastic plane (the parity matrix's pin).
    StrictSync,
    /// Members that finish within `base · (1 + timeout_frac)` synchronize;
    /// the rest are dropped from the step (not from the cluster) and the
    /// partial all-reduce renormalizes for the survivors. Dropped workers'
    /// gradients fold into their local accumulators for the next sync.
    TimeoutPartial { timeout_frac: f64 },
    /// Local accumulation with a synchronizing all-reduce every `period`
    /// steps — the bounded-staleness degradation mode (staleness is at
    /// most `period - 1`, pinned in `tests/training_convergence.rs`).
    PeriodicSync { period: usize },
}

impl CohortPolicy {
    /// Parse a CLI policy spec: `strict` | `partial[:FRAC]` |
    /// `periodic[:PERIOD]` (defaults: FRAC 0.25, PERIOD 4).
    pub fn parse(spec: &str) -> Result<CohortPolicy> {
        let (head, arg) = match spec.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (spec, None),
        };
        Ok(match head {
            "strict" => {
                ensure!(arg.is_none(), "'strict' takes no argument");
                CohortPolicy::StrictSync
            }
            "partial" => {
                let timeout_frac = match arg {
                    Some(a) => a
                        .parse()
                        .with_context(|| format!("bad timeout fraction '{a}'"))?,
                    None => 0.25,
                };
                ensure!(timeout_frac >= 0.0, "timeout fraction must be >= 0");
                CohortPolicy::TimeoutPartial { timeout_frac }
            }
            "periodic" => {
                let period = match arg {
                    Some(a) => a.parse().with_context(|| format!("bad period '{a}'"))?,
                    None => 4,
                };
                ensure!(period >= 1, "sync period must be >= 1");
                CohortPolicy::PeriodicSync { period }
            }
            other => bail!("unknown cohort policy '{other}' (strict|partial[:F]|periodic[:P])"),
        })
    }

    /// Short label for run names and reports.
    pub fn label(&self) -> String {
        match self {
            CohortPolicy::StrictSync => "strict".into(),
            CohortPolicy::TimeoutPartial { timeout_frac } => format!("partial:{timeout_frac}"),
            CohortPolicy::PeriodicSync { period } => format!("periodic:{period}"),
        }
    }
}

/// The elastic layer's configuration: policy, quorum, and fault schedule.
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    pub policy: CohortPolicy,
    /// Minimum cohort size for a synchronizing step; below it the step
    /// degrades to local accumulation (sync deferred, staleness grows).
    pub quorum: usize,
    pub faults: FaultPlan,
}

impl ElasticConfig {
    /// Strict sync under the identity fault plan — the configuration whose
    /// training trace is bit-identical to a non-elastic run.
    pub fn strict() -> ElasticConfig {
        ElasticConfig { policy: CohortPolicy::StrictSync, quorum: 1, faults: FaultPlan::none() }
    }
}

/// One step's coordination decision.
#[derive(Clone, Debug, PartialEq)]
pub struct StepPlan {
    /// Original worker ids participating in this step's collective (the
    /// surviving cohort), strictly increasing. On a non-sync step this is
    /// the full membership (everyone computes locally).
    pub live: Vec<usize>,
    /// Whether the collective runs this step.
    pub sync: bool,
    /// Simulated compute window of the step: how long the cluster's step
    /// takes before communication starts. At a sync this spans the
    /// slowest *participating* worker; a dropped straggler's overrun is
    /// not part of it.
    pub compute_window_s: f64,
    /// The coordination share of the window: `compute_window_s - base_s`.
    /// Attributed to [`SimClock::straggler_wait_s`], never to compute
    /// (the satellite-1 accounting fix).
    ///
    /// [`SimClock::straggler_wait_s`]: crate::netsim::SimClock
    pub straggler_wait_s: f64,
    /// Workers that rejoined at the start of this step (each owes a
    /// parameter catch-up broadcast).
    pub rejoined: Vec<usize>,
}

/// Membership, staleness, and local-accumulation state across steps.
pub struct ElasticCohort {
    cfg: ElasticConfig,
    m: usize,
    members: Vec<bool>,
    /// Steps since each worker last contributed to a synchronized update.
    staleness: Vec<usize>,
    /// Locally accumulated gradient sums of steps that did not sync.
    accum: Vec<Vec<f32>>,
    /// How many gradients each accumulator holds.
    count: Vec<usize>,
    /// Scratch for the averaged contributions at a sync step.
    contrib: Vec<Vec<f32>>,
}

impl ElasticCohort {
    pub fn new(cfg: ElasticConfig, m: usize) -> Result<ElasticCohort> {
        ensure!(m >= 1, "elastic cohort needs at least one worker");
        ensure!(
            (1..=m).contains(&cfg.quorum),
            "quorum {} outside 1..={m}",
            cfg.quorum
        );
        if let CohortPolicy::TimeoutPartial { timeout_frac } = cfg.policy {
            ensure!(timeout_frac >= 0.0, "timeout fraction must be >= 0");
        }
        if let CohortPolicy::PeriodicSync { period } = cfg.policy {
            ensure!(period >= 1, "sync period must be >= 1");
        }
        for e in &cfg.faults.events {
            ensure!(e.worker < m, "fault event for worker {} of {m}", e.worker);
        }
        for p in &cfg.faults.poisons {
            ensure!(p.worker < m, "poison event for worker {} of {m}", p.worker);
        }
        Ok(ElasticCohort {
            cfg,
            m,
            members: vec![true; m],
            staleness: vec![0; m],
            accum: vec![Vec::new(); m],
            count: vec![0; m],
            contrib: vec![Vec::new(); m],
        })
    }

    /// The configured policy.
    pub fn policy(&self) -> CohortPolicy {
        self.cfg.policy
    }

    /// The fault schedule this cohort runs under.
    pub fn faults(&self) -> &FaultPlan {
        &self.cfg.faults
    }

    /// Current members (original worker ids).
    pub fn members(&self) -> Vec<usize> {
        (0..self.m).filter(|&w| self.members[w]).collect()
    }

    /// Decide step `step`: apply membership events, time the cohort under
    /// the fault plan, and resolve the policy into a [`StepPlan`].
    /// `base_s` is the profile (jitter-free) compute time of one step.
    pub fn plan_step(&mut self, step: usize, base_s: f64) -> StepPlan {
        let mut rejoined = Vec::new();
        let events: Vec<_> = self.cfg.faults.events_at(step).copied().collect();
        for e in events {
            match e.kind {
                EventKind::Leave => self.members[e.worker] = false,
                EventKind::Join => {
                    if !self.members[e.worker] {
                        self.members[e.worker] = true;
                        self.staleness[e.worker] = 0;
                        self.accum[e.worker].clear();
                        self.count[e.worker] = 0;
                        rejoined.push(e.worker);
                    }
                }
            }
        }
        let members = self.members();
        let time_of =
            |w: usize| self.cfg.faults.worker_compute_s(base_s, step, w);
        let window_of = |ids: &[usize]| {
            ids.iter().map(|&w| time_of(w)).fold(base_s, f64::max)
        };

        // a step that does not synchronize charges the profile compute and
        // zero wait — nobody coordinates, so nobody waits
        let local = |members: Vec<usize>, rejoined: Vec<usize>| StepPlan {
            live: members,
            sync: false,
            compute_window_s: base_s,
            straggler_wait_s: 0.0,
            rejoined,
        };

        let (live, sync) = match self.cfg.policy {
            CohortPolicy::StrictSync => (members, true),
            CohortPolicy::TimeoutPartial { timeout_frac } => {
                let deadline = base_s * (1.0 + timeout_frac);
                let survivors: Vec<usize> =
                    members.iter().copied().filter(|&w| time_of(w) <= deadline).collect();
                if survivors.len() < members.len() {
                    // someone missed the deadline: the cohort waited the
                    // clock out to know, so the window IS the deadline
                    if survivors.len() >= self.cfg.quorum {
                        return StepPlan {
                            live: survivors,
                            sync: true,
                            compute_window_s: deadline,
                            straggler_wait_s: deadline - base_s,
                            rejoined,
                        };
                    }
                    return local(members, rejoined);
                }
                (survivors, true)
            }
            CohortPolicy::PeriodicSync { period } => {
                if (step + 1) % period != 0 {
                    return local(members, rejoined);
                }
                (members, true)
            }
        };
        if live.len() < self.cfg.quorum {
            return local(self.members(), rejoined);
        }
        let window = window_of(&live);
        StepPlan {
            live,
            sync,
            compute_window_s: window,
            straggler_wait_s: window - base_s,
            rejoined,
        }
    }

    /// Fold a non-synchronized step into the live workers' accumulators.
    /// `grads[w]` is ORIGINAL worker `w`'s gradient (full positional set).
    pub fn accumulate(&mut self, plan: &StepPlan, grads: &[&[f32]]) {
        debug_assert!(!plan.sync, "sync steps contribute, they don't accumulate");
        for &w in &plan.live {
            let acc = &mut self.accum[w];
            if acc.is_empty() {
                acc.extend_from_slice(grads[w]);
            } else {
                for (a, g) in acc.iter_mut().zip(grads[w]) {
                    *a += g;
                }
            }
            self.count[w] += 1;
        }
    }

    /// The surviving cohort's contributions at a sync step: worker `w`
    /// ships `(accum[w] + grads[w]) / (count[w] + 1)` — the mean of its
    /// local steps since the last sync. Returns `None` when no live
    /// worker holds pending accumulation, so the caller passes the raw
    /// gradient slices through untouched (the strict-sync f32-parity fast
    /// path: no scaling by 1.0 is ever applied).
    pub fn contributions(
        &mut self,
        plan: &StepPlan,
        grads: &[&[f32]],
    ) -> Option<Vec<&[f32]>> {
        debug_assert!(plan.sync);
        if plan.live.iter().all(|&w| self.count[w] == 0) {
            return None;
        }
        for (slot, &w) in plan.live.iter().enumerate() {
            let dst = &mut self.contrib[slot];
            dst.clear();
            dst.extend_from_slice(grads[w]);
            if self.count[w] > 0 {
                let inv = 1.0f32 / (self.count[w] as f32 + 1.0);
                let acc = &self.accum[w];
                for (d, a) in dst.iter_mut().zip(acc) {
                    *d = (*d + a) * inv;
                }
            }
        }
        Some(self.contrib[..plan.live.len()].iter().map(|v| v.as_slice()).collect())
    }

    /// Close the step's staleness and accumulator bookkeeping; returns the
    /// staleness to record: the maximum staleness *entering* a sync among
    /// its participants (how stale the oldest folded-in gradient was), or
    /// the maximum member staleness after a local step.
    pub fn commit(&mut self, plan: &StepPlan) -> usize {
        if plan.sync {
            let entering =
                plan.live.iter().map(|&w| self.staleness[w]).max().unwrap_or(0);
            for &w in &plan.live {
                self.staleness[w] = 0;
                self.accum[w].clear();
                self.count[w] = 0;
            }
            // members dropped from this sync keep aging
            for w in 0..self.m {
                if self.members[w] && !plan.live.contains(&w) {
                    self.staleness[w] += 1;
                }
            }
            entering
        } else {
            for &w in &plan.live {
                self.staleness[w] += 1;
            }
            plan.live.iter().map(|&w| self.staleness[w]).max().unwrap_or(0)
        }
    }

    /// Escalation seam of the self-healing data plane (PR 7): remove
    /// `dead` peers — workers whose hop deliveries exhausted every
    /// integrity retry this step ([`FaultPlan::unreachable_peers`], keyed
    /// by original id) — from an already-planned sync step. The survivors
    /// proceed through the same partial-cohort path a timeout drop takes
    /// (live-M renormalization via `aggregate_cohort` for free); if they
    /// fall below quorum the step degrades to a local step, exactly like a
    /// quorum failure at plan time. Dropped peers are NOT removed from the
    /// cluster — membership events stay the fault plan's business — so
    /// they age like any other skipped participant at [`Self::commit`].
    /// No-op on an empty `dead` set or a non-sync plan.
    pub fn drop_unreachable(&self, plan: &mut StepPlan, dead: &[usize]) {
        if dead.is_empty() || !plan.sync {
            return;
        }
        plan.live.retain(|w| !dead.contains(w));
        if plan.live.len() < self.cfg.quorum.max(1) {
            // below quorum: degrade to a local step over the full
            // membership, the same shape plan_step's quorum guard emits
            plan.live = self.members();
            plan.sync = false;
            plan.straggler_wait_s = 0.0;
        }
    }

    /// Simulated cost of a rejoining worker's parameter catch-up: a tree
    /// broadcast of the fp32 parameter vector over the current wire,
    /// `ceil(log2 m)` hops of `4n` bytes. Charged to comm time only — the
    /// bits ledgers stay gradient-payload accounting (DESIGN.md).
    pub fn catch_up_s(&self, net: &NetConfig, n: usize) -> f64 {
        if self.m <= 1 {
            return 0.0;
        }
        let hops = usize::BITS - (self.m - 1).leading_zeros();
        hops as f64 * net.hop_s(4.0 * n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strict_cohort(m: usize) -> ElasticCohort {
        ElasticCohort::new(ElasticConfig::strict(), m).unwrap()
    }

    #[test]
    fn parse_covers_policies_and_rejects_junk() {
        assert_eq!(CohortPolicy::parse("strict").unwrap(), CohortPolicy::StrictSync);
        assert_eq!(
            CohortPolicy::parse("partial:0.5").unwrap(),
            CohortPolicy::TimeoutPartial { timeout_frac: 0.5 }
        );
        assert_eq!(
            CohortPolicy::parse("partial").unwrap(),
            CohortPolicy::TimeoutPartial { timeout_frac: 0.25 }
        );
        assert_eq!(
            CohortPolicy::parse("periodic:8").unwrap(),
            CohortPolicy::PeriodicSync { period: 8 }
        );
        assert_eq!(
            CohortPolicy::parse("periodic").unwrap(),
            CohortPolicy::PeriodicSync { period: 4 }
        );
        for bad in ["strict:1", "partial:-1", "periodic:0", "async", "partial:x"] {
            assert!(CohortPolicy::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn drop_unreachable_respects_quorum_and_empty_sets() {
        let cfg = ElasticConfig {
            policy: CohortPolicy::StrictSync,
            quorum: 2,
            faults: FaultPlan::none(),
        };
        let mut c = ElasticCohort::new(cfg, 4).unwrap();

        // empty dead set: the plan is untouched
        let mut plan = c.plan_step(0, 0.2);
        let before = plan.clone();
        c.drop_unreachable(&mut plan, &[]);
        assert_eq!(plan, before);

        // above quorum: survivors keep syncing without the dead peers
        c.drop_unreachable(&mut plan, &[1, 3]);
        assert_eq!(plan.live, vec![0, 2]);
        assert!(plan.sync);

        // below quorum: degrade to a local step over the full membership
        let mut plan = c.plan_step(1, 0.2);
        c.drop_unreachable(&mut plan, &[0, 1, 2]);
        assert!(!plan.sync);
        assert_eq!(plan.live, vec![0, 1, 2, 3]);
        assert_eq!(plan.straggler_wait_s, 0.0);

        // a non-sync plan is left alone even with a dead list
        let mut local = plan.clone();
        c.drop_unreachable(&mut local, &[0, 1, 2, 3]);
        assert_eq!(local, plan);
    }

    #[test]
    fn strict_under_no_faults_is_the_identity_schedule() {
        let mut c = strict_cohort(4);
        for step in 0..5 {
            let plan = c.plan_step(step, 0.2);
            assert_eq!(plan.live, vec![0, 1, 2, 3]);
            assert!(plan.sync);
            assert_eq!(plan.compute_window_s, 0.2);
            assert_eq!(plan.straggler_wait_s, 0.0);
            assert!(plan.rejoined.is_empty());
            assert_eq!(c.commit(&plan), 0);
        }
    }

    #[test]
    fn strict_waits_for_the_slowest_member() {
        let cfg = ElasticConfig {
            policy: CohortPolicy::StrictSync,
            quorum: 1,
            faults: FaultPlan::jittered(7, 0.5),
        };
        let mut c = ElasticCohort::new(cfg.clone(), 4).unwrap();
        let plan = c.plan_step(0, 1.0);
        let slowest = (0..4)
            .map(|w| cfg.faults.worker_compute_s(1.0, 0, w))
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(plan.compute_window_s, slowest);
        assert_eq!(plan.straggler_wait_s, slowest - 1.0);
        assert!(plan.straggler_wait_s > 0.0);
    }

    #[test]
    fn timeout_drops_stragglers_and_caps_the_window_at_the_deadline() {
        // jitter 1.0 makes overruns likely; scan steps until one drops
        let cfg = ElasticConfig {
            policy: CohortPolicy::TimeoutPartial { timeout_frac: 0.2 },
            quorum: 1,
            faults: FaultPlan::jittered(3, 1.0),
        };
        let mut c = ElasticCohort::new(cfg.clone(), 8).unwrap();
        let mut dropped_some = false;
        for step in 0..50 {
            let plan = c.plan_step(step, 1.0);
            assert!(plan.compute_window_s <= 1.2 + 1e-12);
            if plan.live.len() < 8 {
                dropped_some = true;
                assert!(plan.sync);
                assert_eq!(plan.compute_window_s, 1.2);
                for &w in &plan.live {
                    assert!(cfg.faults.worker_compute_s(1.0, step, w) <= 1.2);
                }
            }
            c.commit(&plan);
        }
        assert!(dropped_some, "jitter 1.0 over 50x8 draws must drop someone");
    }

    #[test]
    fn quorum_failure_degrades_to_a_local_step() {
        // timeout 0 with heavy jitter: nearly everyone misses; quorum 7 of
        // 8 is all but unreachable, so steps degrade to local accumulation
        let cfg = ElasticConfig {
            policy: CohortPolicy::TimeoutPartial { timeout_frac: 0.0 },
            quorum: 7,
            faults: FaultPlan::jittered(11, 2.0),
        };
        let mut c = ElasticCohort::new(cfg, 8).unwrap();
        let mut degraded = false;
        for step in 0..20 {
            let plan = c.plan_step(step, 1.0);
            if !plan.sync {
                degraded = true;
                assert_eq!(plan.live, (0..8).collect::<Vec<_>>());
                assert_eq!(plan.compute_window_s, 1.0);
                assert_eq!(plan.straggler_wait_s, 0.0);
            }
            c.commit(&plan);
        }
        assert!(degraded, "quorum 7/8 at timeout 0 must degrade some step");
    }

    #[test]
    fn periodic_syncs_on_schedule_with_bounded_staleness() {
        let cfg = ElasticConfig {
            policy: CohortPolicy::PeriodicSync { period: 3 },
            quorum: 1,
            faults: FaultPlan::none(),
        };
        let mut c = ElasticCohort::new(cfg, 2).unwrap();
        for step in 0..9 {
            let plan = c.plan_step(step, 0.5);
            assert_eq!(plan.sync, (step + 1) % 3 == 0);
            let staleness = c.commit(&plan);
            assert!(staleness <= 2, "staleness {staleness} exceeds period-1 at {step}");
            if plan.sync {
                assert_eq!(staleness, 2, "sync folds in gradients 2 steps old");
            }
        }
    }

    #[test]
    fn accumulated_contributions_average_the_local_steps() {
        let cfg = ElasticConfig {
            policy: CohortPolicy::PeriodicSync { period: 2 },
            quorum: 1,
            faults: FaultPlan::none(),
        };
        let mut c = ElasticCohort::new(cfg, 2).unwrap();
        let g0: Vec<Vec<f32>> = vec![vec![1.0, 3.0], vec![2.0, 4.0]];
        let g1: Vec<Vec<f32>> = vec![vec![3.0, 5.0], vec![6.0, 0.0]];
        let r0: Vec<&[f32]> = g0.iter().map(|v| v.as_slice()).collect();
        let r1: Vec<&[f32]> = g1.iter().map(|v| v.as_slice()).collect();

        let p0 = c.plan_step(0, 0.1);
        assert!(!p0.sync);
        c.accumulate(&p0, &r0);
        c.commit(&p0);

        let p1 = c.plan_step(1, 0.1);
        assert!(p1.sync);
        let contrib = c.contributions(&p1, &r1).expect("pending accumulation");
        assert_eq!(contrib[0], &[2.0, 4.0][..]); // (1+3)/2, (3+5)/2
        assert_eq!(contrib[1], &[4.0, 2.0][..]); // (2+6)/2, (4+0)/2
        c.commit(&p1);

        // after the sync the accumulators are drained: the next sync with
        // no local steps pending takes the parity fast path
        let p2 = c.plan_step(2, 0.1);
        assert!(!p2.sync);
        let p3_probe = StepPlan { sync: true, ..p2.clone() };
        assert!(c.contributions(&p3_probe, &r1).is_none());
    }

    #[test]
    fn leave_then_rejoin_resets_staleness_and_owes_catch_up() {
        let cfg = ElasticConfig {
            policy: CohortPolicy::StrictSync,
            quorum: 1,
            faults: FaultPlan::parse("leave=1@2,join=1@4").unwrap(),
        };
        let mut c = ElasticCohort::new(cfg, 4).unwrap();
        for step in 0..2 {
            let p = c.plan_step(step, 0.1);
            assert_eq!(p.live, vec![0, 1, 2, 3]);
            c.commit(&p);
        }
        let p2 = c.plan_step(2, 0.1);
        assert_eq!(p2.live, vec![0, 2, 3], "worker 1 left at step 2");
        assert!(p2.rejoined.is_empty());
        c.commit(&p2);
        let p3 = c.plan_step(3, 0.1);
        c.commit(&p3);
        let p4 = c.plan_step(4, 0.1);
        assert_eq!(p4.live, vec![0, 1, 2, 3], "worker 1 rejoined at step 4");
        assert_eq!(p4.rejoined, vec![1]);
        assert_eq!(c.commit(&p4), 0, "a rejoined worker restarts fresh");

        let net = NetConfig::flat(4, 10.0);
        let catch_up = c.catch_up_s(&net, 1000);
        assert!(catch_up > 0.0);
        assert_eq!(catch_up, 2.0 * net.hop_s(4000.0), "ceil(log2 4) = 2 hops");
    }

    #[test]
    fn construction_rejects_bad_quorum_and_out_of_range_events() {
        assert!(ElasticCohort::new(
            ElasticConfig { quorum: 0, ..ElasticConfig::strict() },
            4
        )
        .is_err());
        assert!(ElasticCohort::new(
            ElasticConfig { quorum: 5, ..ElasticConfig::strict() },
            4
        )
        .is_err());
        let cfg = ElasticConfig {
            policy: CohortPolicy::StrictSync,
            quorum: 1,
            faults: FaultPlan::parse("leave=4@1").unwrap(),
        };
        assert!(ElasticCohort::new(cfg, 4).is_err(), "event for worker 4 of 4");
    }
}
