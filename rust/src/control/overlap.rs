//! Backward/communication overlap scheduler.
//!
//! Buckets become available in backward order (last layers first,
//! [`crate::perfmodel::backward_ready_times`]); the wire is a single
//! serialized resource, so bucket `b`'s collective starts at
//! `max(ready_b, previous finish)` and runs for its charged `comm_s`.
//! Communication that lands inside the backward window `[0, backward_s]`
//! is **hidden** — it does not extend the step's critical path — and is
//! credited to [`crate::netsim::SimClock::hidden_comm_s`]. The monolithic
//! path by contrast starts its single collective at `backward_s` and
//! exposes all of it: exactly the serialization Parallel-SGD identifies as
//! the scaling bottleneck.

/// One step's overlap outcome.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OverlapReport {
    /// total bucket communication seconds charged this step
    pub total_comm_s: f64,
    /// comm seconds hidden inside the backward window
    pub hidden_s: f64,
    /// comm seconds extending the critical path past the backward window
    pub exposed_s: f64,
    /// `hidden_s / total_comm_s` (0 when nothing was communicated)
    pub overlap_frac: f64,
}

/// Schedule bucket collectives against the backward window.
///
/// `ready[b]` is bucket `b`'s gradient-available time (ascending bucket =
/// earlier layer = ready *later*; all `ready <= backward_s`), `comm[b]` its
/// charged wire seconds. Buckets are issued in backward order (descending
/// index), serialized on the wire.
pub fn schedule(ready: &[f64], comm: &[f64], backward_s: f64) -> OverlapReport {
    debug_assert_eq!(ready.len(), comm.len());
    let total_comm_s: f64 = comm.iter().sum();
    if total_comm_s <= 0.0 {
        return OverlapReport::default();
    }
    let mut t = 0.0f64;
    for b in (0..comm.len()).rev() {
        t = t.max(ready[b]) + comm[b];
    }
    // every ready time is <= backward_s, so once the clock passes the
    // backward window the wire stays busy: the exposed tail is contiguous
    let exposed_s = (t - backward_s).clamp(0.0, total_comm_s);
    let hidden_s = total_comm_s - exposed_s;
    OverlapReport {
        total_comm_s,
        hidden_s,
        exposed_s,
        overlap_frac: hidden_s / total_comm_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_hidden_when_comm_fits_the_gaps() {
        // 4 buckets ready at .25/.5/.75/1.0 of a 1 s backward, 0.01 s each:
        // everything but the last bucket's tail past 1.0 s is hidden
        let ready = [1.0, 0.75, 0.5, 0.25];
        let comm = [0.01; 4];
        let r = schedule(&ready, &comm, 1.0);
        assert!((r.total_comm_s - 0.04).abs() < 1e-12);
        // last-issued bucket (index 0) starts at 1.0 -> 0.01 exposed
        assert!((r.exposed_s - 0.01).abs() < 1e-12);
        assert!((r.overlap_frac - 0.75).abs() < 1e-9);
    }

    #[test]
    fn serialization_pushes_comm_past_the_window() {
        // comm much longer than the window: almost everything exposed
        let ready = [1.0, 0.5];
        let comm = [2.0, 2.0];
        let r = schedule(&ready, &comm, 1.0);
        // issue order: bucket 1 at 0.5 -> 2.5, bucket 0 at 2.5 -> 4.5
        assert!((r.exposed_s - 3.5).abs() < 1e-12);
        assert!((r.hidden_s - 0.5).abs() < 1e-12);
        assert!(r.overlap_frac > 0.0 && r.overlap_frac < 1.0);
    }

    #[test]
    fn degenerate_cases() {
        assert_eq!(schedule(&[], &[], 1.0), OverlapReport::default());
        assert_eq!(schedule(&[1.0], &[0.0], 1.0), OverlapReport::default());
        // single bucket ready only at the window end: nothing hidden —
        // exactly the monolithic exposure
        let r = schedule(&[1.0], &[0.3], 1.0);
        assert_eq!(r.hidden_s, 0.0);
        assert!((r.exposed_s - 0.3).abs() < 1e-12);
        assert_eq!(r.overlap_frac, 0.0);
    }

    #[test]
    fn hidden_never_exceeds_total_and_zero_window_exposes_all() {
        let ready = [0.0, 0.0, 0.0];
        let comm = [0.1, 0.2, 0.3];
        let r = schedule(&ready, &comm, 0.0);
        assert_eq!(r.hidden_s, 0.0);
        assert!((r.exposed_s - 0.6).abs() < 1e-12);
    }
}
