//! Pre-encode numeric anomaly guard (PR 7): scan local gradients for
//! NaN/Inf *before* a single level is drawn, and gate the step by policy.
//!
//! The paper's quantizers normalize by the shared `||w||_2`; one non-finite
//! coordinate poisons that norm, and through it every worker's levels — the
//! packed plane would then ship garbage codes that decode to garbage on all
//! M ranks. The guard runs on the raw f32 gradients (a pure read: a clean
//! step is bit-identical with or without it) and the policy decides what a
//! dirty step does:
//!
//! * [`AnomalyPolicy::Skip`] — drop the step entirely: nothing is encoded,
//!   nothing is charged to the wire, the optimizer state is untouched, and
//!   the run ledger counts one skipped step;
//! * [`AnomalyPolicy::Clip`] — zero the non-finite coordinates and rescale
//!   each offending gradient to at most the configured L2 norm, then
//!   proceed normally (the TensorFlow-style "clip instead of crash"
//!   mitigation, cf. Tsuzuku et al., arXiv:1802.06058);
//! * [`AnomalyPolicy::Abort`] — fail the run loudly (CI / debugging).
//!
//! Widening-rule overflow — the third anomaly class — is structurally
//! excluded at aggregator construction (`sum_fits` asserts) and backstopped
//! by the encoder's finite-norm assert, so the scan here only needs the
//! float-domain checks.

use anyhow::{bail, Result};

/// What to do when the pre-encode scan finds a non-finite gradient.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AnomalyPolicy {
    /// Drop the step: no encode, no wire charge, no update.
    Skip,
    /// Zero non-finite coordinates, clip the gradient to this L2 norm,
    /// and continue the step.
    Clip(f32),
    /// Fail the run with an error naming the first offending coordinate.
    Abort,
}

impl AnomalyPolicy {
    /// Parse the CLI form: `skip` | `clip:C` | `abort`.
    pub fn parse(spec: &str) -> Result<AnomalyPolicy> {
        match spec.trim() {
            "skip" => Ok(AnomalyPolicy::Skip),
            "abort" => Ok(AnomalyPolicy::Abort),
            other => match other.strip_prefix("clip:") {
                Some(c) => {
                    let c: f32 = c
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad clip norm '{c}'"))?;
                    anyhow::ensure!(
                        c.is_finite() && c > 0.0,
                        "clip norm must be finite and > 0, got {c}"
                    );
                    Ok(AnomalyPolicy::Clip(c))
                }
                None => bail!("unknown anomaly policy '{other}' (expect skip|clip:C|abort)"),
            },
        }
    }

    /// Stable label for ledgers and summaries.
    pub fn label(&self) -> String {
        match self {
            AnomalyPolicy::Skip => "skip".to_string(),
            AnomalyPolicy::Clip(c) => format!("clip:{c}"),
            AnomalyPolicy::Abort => "abort".to_string(),
        }
    }
}

/// First non-finite coordinate found by [`scan`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Anomaly {
    /// Index into the scanned slice-of-workers (a cohort slot).
    pub worker: usize,
    /// Coordinate index within that worker's gradient.
    pub index: usize,
    /// The offending value (NaN or ±Inf).
    pub value: f32,
}

/// Scan the cohort's local gradients for the first non-finite coordinate.
/// Pure read — a clean cohort passes through with zero side effects, which
/// is what keeps the guard parity-free on every existing path.
pub fn scan(grads: &[&[f32]]) -> Option<Anomaly> {
    for (w, g) in grads.iter().enumerate() {
        if let Some(i) = g.iter().position(|x| !x.is_finite()) {
            return Some(Anomaly { worker: w, index: i, value: g[i] });
        }
    }
    None
}

/// Sanitize one gradient under [`AnomalyPolicy::Clip`]: zero every
/// non-finite coordinate, then rescale to L2 norm `c` if the cleaned norm
/// exceeds it. Returns true iff anything changed.
pub fn sanitize_clip(grad: &mut [f32], c: f32) -> bool {
    let mut changed = false;
    for x in grad.iter_mut() {
        if !x.is_finite() {
            *x = 0.0;
            changed = true;
        }
    }
    let norm = grad.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32;
    if norm > c {
        let scale = c / norm;
        for x in grad.iter_mut() {
            *x *= scale;
        }
        changed = true;
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_finds_the_first_anomaly_and_passes_clean_cohorts() {
        let a = vec![1.0f32, -2.0, 0.5];
        let b = vec![0.0f32, f32::NAN, 3.0];
        let c = vec![f32::INFINITY, 0.0, 0.0];
        assert_eq!(scan(&[&a, &a]), None);
        let hit = scan(&[&a, &b, &c]).expect("must find the NaN");
        assert_eq!((hit.worker, hit.index), (1, 1));
        assert!(hit.value.is_nan());
        let hit = scan(&[&c]).unwrap();
        assert_eq!((hit.worker, hit.index), (0, 0));
        assert_eq!(hit.value, f32::INFINITY);
        // empty cohorts and empty gradients are clean
        assert_eq!(scan(&[]), None);
        assert_eq!(scan(&[&[]]), None);
    }

    #[test]
    fn sanitize_clip_zeros_nonfinite_then_bounds_the_norm() {
        let mut g = vec![3.0f32, f32::NAN, 4.0, f32::NEG_INFINITY];
        assert!(sanitize_clip(&mut g, 1.0));
        // NaN/Inf zeroed, then [3,0,4,0] (norm 5) rescaled to norm 1
        let norm = g.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-6);
        assert!(g.iter().all(|x| x.is_finite()));
        assert_eq!(g[1], 0.0);
        assert_eq!(g[3], 0.0);
        // already-clean, already-small gradients pass through untouched
        let mut small = vec![0.1f32, -0.2];
        let before = small.clone();
        assert!(!sanitize_clip(&mut small, 10.0));
        assert_eq!(small, before);
        // clean but large: clipped without zeroing anything
        let mut big = vec![30.0f32, 40.0];
        assert!(sanitize_clip(&mut big, 5.0));
        assert!((big[0] - 3.0).abs() < 1e-5 && (big[1] - 4.0).abs() < 1e-5);
    }

    #[test]
    fn policy_parses_and_labels_round_trip() {
        assert_eq!(AnomalyPolicy::parse("skip").unwrap(), AnomalyPolicy::Skip);
        assert_eq!(AnomalyPolicy::parse("abort").unwrap(), AnomalyPolicy::Abort);
        assert_eq!(AnomalyPolicy::parse("clip:2.5").unwrap(), AnomalyPolicy::Clip(2.5));
        for p in ["skip", "abort", "clip:2.5"] {
            assert_eq!(AnomalyPolicy::parse(p).unwrap().label(), p);
        }
        for bad in ["", "clamp", "clip:", "clip:abc", "clip:-1", "clip:0", "clip:inf"] {
            assert!(AnomalyPolicy::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }
}
