//! Learning-rate schedules. The paper uses Cosine Annealing (SGDR [31])
//! over the full 150-epoch run; warmup and step schedules are provided for
//! the ablation benches.

#[derive(Clone, Debug)]
pub enum LrSchedule {
    Constant { lr: f64 },
    /// lr(t) = lr_min + 0.5 (lr0 - lr_min)(1 + cos(pi t / T))
    Cosine { lr0: f64, lr_min: f64, total_steps: usize },
    /// linear warmup into cosine
    WarmupCosine { lr0: f64, lr_min: f64, warmup: usize, total_steps: usize },
    /// multiply by gamma at each milestone
    Step { lr0: f64, gamma: f64, milestones: Vec<usize> },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f64 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::Cosine { lr0, lr_min, total_steps } => {
                let t = (step.min(*total_steps)) as f64 / (*total_steps).max(1) as f64;
                lr_min + 0.5 * (lr0 - lr_min) * (1.0 + (std::f64::consts::PI * t).cos())
            }
            LrSchedule::WarmupCosine { lr0, lr_min, warmup, total_steps } => {
                if step < *warmup {
                    lr0 * (step + 1) as f64 / *warmup as f64
                } else {
                    LrSchedule::Cosine {
                        lr0: *lr0,
                        lr_min: *lr_min,
                        total_steps: total_steps.saturating_sub(*warmup).max(1),
                    }
                    .at(step - warmup)
                }
            }
            LrSchedule::Step { lr0, gamma, milestones } => {
                let k = milestones.iter().filter(|&&m| step >= m).count();
                lr0 * gamma.powi(k as i32)
            }
        }
    }

    /// The paper's schedule for a run of `total_steps`.
    pub fn paper(lr0: f64, total_steps: usize) -> LrSchedule {
        LrSchedule::Cosine { lr0, lr_min: 0.0, total_steps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, ensure};

    #[test]
    fn cosine_endpoints() {
        let s = LrSchedule::Cosine { lr0: 1.0, lr_min: 0.1, total_steps: 100 };
        assert!((s.at(0) - 1.0).abs() < 1e-12);
        assert!((s.at(100) - 0.1).abs() < 1e-12);
        assert!((s.at(50) - 0.55).abs() < 1e-12);
        assert_eq!(s.at(1000), s.at(100)); // clamped past the horizon
    }

    #[test]
    fn prop_cosine_monotone_decreasing() {
        check("cosine is monotone", 30, |g| {
            let total = g.usize_in(2, 500);
            let s = LrSchedule::Cosine { lr0: g.f64_in(0.1, 2.0), lr_min: 0.0, total_steps: total };
            for t in 1..=total {
                if s.at(t) > s.at(t - 1) + 1e-12 {
                    return Err(format!("increase at {t}"));
                }
            }
            ensure(true, "")
        });
    }

    #[test]
    fn warmup_ramps_then_decays() {
        let s = LrSchedule::WarmupCosine { lr0: 1.0, lr_min: 0.0, warmup: 10, total_steps: 110 };
        assert!(s.at(0) < s.at(5));
        assert!(s.at(5) < s.at(9));
        assert!((s.at(10) - 1.0).abs() < 1e-9);
        assert!(s.at(60) < 1.0);
    }

    #[test]
    fn step_schedule() {
        let s = LrSchedule::Step { lr0: 1.0, gamma: 0.1, milestones: vec![10, 20] };
        assert_eq!(s.at(9), 1.0);
        assert!((s.at(10) - 0.1).abs() < 1e-12);
        assert!((s.at(25) - 0.01).abs() < 1e-12);
    }
}
