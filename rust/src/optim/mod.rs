//! Optimizers + LR schedules (the paper trains SGD, momentum 0.9, weight
//! decay 5e-4, cosine-annealing LR — §6).

pub mod lr;

pub use lr::LrSchedule;

/// SGD with (optionally Nesterov) momentum and decoupled-from-loss L2
/// weight decay, matching PyTorch `torch.optim.SGD` semantics:
/// `g += wd * theta; buf = mu * buf + g; theta -= lr * buf`.
pub struct Sgd {
    pub momentum: f32,
    pub weight_decay: f32,
    pub nesterov: bool,
    buf: Vec<f32>,
}

impl Sgd {
    pub fn new(n: usize, momentum: f32, weight_decay: f32) -> Sgd {
        Sgd { momentum, weight_decay, nesterov: false, buf: vec![0.0; n] }
    }

    /// The paper's configuration (§6): momentum 0.9, wd 5e-4.
    pub fn paper(n: usize) -> Sgd {
        Sgd::new(n, 0.9, 5e-4)
    }

    /// One update: `params -= lr * step(grad)`.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.buf.len());
        let (mu, wd) = (self.momentum, self.weight_decay);
        if mu == 0.0 {
            for i in 0..params.len() {
                let g = grad[i] + wd * params[i];
                params[i] -= lr * g;
            }
            return;
        }
        for i in 0..params.len() {
            let g = grad[i] + wd * params[i];
            self.buf[i] = mu * self.buf[i] + g;
            let d = if self.nesterov { g + mu * self.buf[i] } else { self.buf[i] };
            params[i] -= lr * d;
        }
    }

    pub fn reset(&mut self) {
        self.buf.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_matches_formula() {
        let mut opt = Sgd::new(2, 0.0, 0.0);
        let mut p = vec![1.0f32, -2.0];
        opt.step(&mut p, &[0.5, -0.5], 0.1);
        assert_eq!(p, vec![0.95, -1.95]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(1, 0.9, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 1.0);
        assert!((p[0] + 1.0).abs() < 1e-6); // buf=1, p=-1
        opt.step(&mut p, &[1.0], 1.0);
        assert!((p[0] + 1.0 + 1.9).abs() < 1e-6); // buf=1.9
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut opt = Sgd::new(1, 0.0, 0.1);
        let mut p = vec![10.0f32];
        for _ in 0..100 {
            opt.step(&mut p, &[0.0], 0.5);
        }
        assert!(p[0].abs() < 10.0 * 0.96f32.powi(100) * 1.1);
    }

    #[test]
    fn converges_on_quadratic() {
        // f(x) = 0.5 * ||x - a||^2, grad = x - a
        let a = [3.0f32, -1.0, 0.5];
        let mut p = vec![0.0f32; 3];
        let mut opt = Sgd::new(3, 0.9, 0.0);
        for _ in 0..200 {
            let g: Vec<f32> = p.iter().zip(&a).map(|(x, t)| x - t).collect();
            opt.step(&mut p, &g, 0.05);
        }
        for (x, t) in p.iter().zip(&a) {
            assert!((x - t).abs() < 1e-3, "{x} vs {t}");
        }
    }
}
