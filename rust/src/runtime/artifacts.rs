//! Artifact index: typed view over `artifacts/meta.json` + params.bin loading.
//!
//! `meta.json` is written by `python/compile/aot.py` (the only Python that
//! ever runs) and describes every lowered HLO: input/output shapes, the flat
//! parameter layout (segments), batch geometry and FLOP estimates.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One tensor's slice of the flat parameter vector.
#[derive(Clone, Debug)]
pub struct Segment {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// Build a contiguous segment list from per-layer lengths (offsets are the
/// running sum). Used by tests/benches to synthesize layer metadata and by
/// callers driving the bucketed control plane without lowered artifacts.
pub fn contiguous_segments(lens: &[usize]) -> Vec<Segment> {
    let mut off = 0usize;
    lens.iter()
        .enumerate()
        .map(|(i, &len)| {
            let s = Segment { name: format!("layer{i}"), shape: vec![len], offset: off, len };
            off += len;
            s
        })
        .collect()
}

/// Dtype carried on the wire between L3 and PJRT.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One input/output tensor of a lowered step.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub kind: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A lowered multi-worker gradient step.
#[derive(Clone, Debug)]
pub struct StepSpec {
    pub file: String,
    pub workers: usize,
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
    pub flops: f64,
}

/// A lowered eval step.
#[derive(Clone, Debug)]
pub struct EvalSpec {
    pub file: String,
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
}

/// One model's artifact family.
#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    pub name: String,
    pub param_count: usize,
    pub params_file: String,
    pub segments: Vec<Segment>,
    pub steps: BTreeMap<usize, StepSpec>,
    pub eval: EvalSpec,
    /// "image" or "tokens"
    pub input_kind: String,
    pub batch: usize,
    pub cfg: Json,
}

/// A parity-kernel artifact (Pallas graph lowered standalone).
#[derive(Clone, Debug)]
pub struct KernelArtifact {
    pub file: String,
    pub n: usize,
    pub extra: Json,
}

/// The whole artifact directory.
pub struct Artifacts {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelArtifacts>,
    pub kernels: BTreeMap<String, KernelArtifact>,
    /// paper's bits-per-coordinate -> number of levels s
    pub bits_to_s: BTreeMap<usize, usize>,
}

fn parse_dtype(s: &str) -> Result<Dtype> {
    match s {
        "f32" => Ok(Dtype::F32),
        "i32" => Ok(Dtype::I32),
        other => bail!("unknown dtype '{other}'"),
    }
}

fn parse_tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                kind: t.req("kind")?.as_str()?.to_string(),
                shape: t
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                dtype: parse_dtype(t.req("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

impl Artifacts {
    /// Locate the artifacts directory: `$REPRO_ARTIFACTS`, else `./artifacts`,
    /// else walk up from cwd (so tests/examples work from any subdir).
    pub fn locate() -> Result<PathBuf> {
        if let Ok(p) = std::env::var("REPRO_ARTIFACTS") {
            return Ok(PathBuf::from(p));
        }
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts").join("meta.json");
            if cand.exists() {
                return Ok(dir.join("artifacts"));
            }
            if !dir.pop() {
                bail!(
                    "artifacts/meta.json not found — run `make artifacts` \
                     (or set REPRO_ARTIFACTS)"
                );
            }
        }
    }

    pub fn load_default() -> Result<Artifacts> {
        Self::load(&Self::locate()?)
    }

    pub fn load(dir: &Path) -> Result<Artifacts> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?}"))?;
        let meta = Json::parse(&text).context("parsing meta.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in meta.req("models")?.as_obj()? {
            let segments = m
                .req("segments")?
                .as_arr()?
                .iter()
                .map(|s| {
                    Ok(Segment {
                        name: s.req("name")?.as_str()?.to_string(),
                        shape: s
                            .req("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<_>>()?,
                        offset: s.req("offset")?.as_usize()?,
                        len: s.req("len")?.as_usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;

            let mut steps = BTreeMap::new();
            for (mstr, st) in m.req("steps")?.as_obj()? {
                let spec = StepSpec {
                    file: st.req("file")?.as_str()?.to_string(),
                    workers: st.req("workers")?.as_usize()?,
                    batch: st.req("batch")?.as_usize()?,
                    inputs: parse_tensor_specs(st.req("inputs")?)?,
                    flops: st.req("flops")?.as_f64()?,
                };
                steps.insert(mstr.parse::<usize>()?, spec);
            }

            let ev = m.req("eval")?;
            let eval = EvalSpec {
                file: ev.req("file")?.as_str()?.to_string(),
                batch: ev.req("batch")?.as_usize()?,
                inputs: parse_tensor_specs(ev.req("inputs")?)?,
            };

            models.insert(
                name.clone(),
                ModelArtifacts {
                    name: name.clone(),
                    param_count: m.req("param_count")?.as_usize()?,
                    params_file: m.req("params_file")?.as_str()?.to_string(),
                    segments,
                    steps,
                    eval,
                    input_kind: m.req("input")?.as_str()?.to_string(),
                    batch: m.req("batch")?.as_usize()?,
                    cfg: m.req("cfg")?.clone(),
                },
            );
        }

        let mut kernels = BTreeMap::new();
        for (name, k) in meta.req("kernels")?.as_obj()? {
            kernels.insert(
                name.clone(),
                KernelArtifact {
                    file: k.req("file")?.as_str()?.to_string(),
                    n: k.req("n")?.as_usize()?,
                    extra: k.clone(),
                },
            );
        }

        let mut bits_to_s = BTreeMap::new();
        for (b, s) in meta.req("bits_to_s")?.as_obj()? {
            bits_to_s.insert(b.parse::<usize>()?, s.as_usize()?);
        }

        Ok(Artifacts { dir: dir.to_path_buf(), models, kernels, bits_to_s })
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in artifacts (have: {:?})", self.models.keys().collect::<Vec<_>>()))
    }

    pub fn kernel(&self, name: &str) -> Result<&KernelArtifact> {
        self.kernels
            .get(name)
            .with_context(|| format!("kernel '{name}' not in artifacts"))
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load a params.bin (little-endian f32) into a Vec.
    pub fn load_params(&self, model: &ModelArtifacts) -> Result<Vec<f32>> {
        let path = self.path_of(&model.params_file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != model.param_count * 4 {
            bail!(
                "{path:?}: expected {} bytes for {} params, got {}",
                model.param_count * 4,
                model.param_count,
                bytes.len()
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Paper bit-width -> quantization levels s (r = ceil(log s) + 1).
    pub fn s_for_bits(&self, bits: usize) -> Result<usize> {
        self.bits_to_s
            .get(&bits)
            .copied()
            .with_context(|| format!("no s for {bits}-bit (have {:?})", self.bits_to_s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(parse_dtype("f32").unwrap(), Dtype::F32);
        assert_eq!(parse_dtype("i32").unwrap(), Dtype::I32);
        assert!(parse_dtype("f64").is_err());
    }
}
