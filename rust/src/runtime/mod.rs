//! L3 <-> PJRT bridge: load AOT-compiled HLO text, compile once, execute on
//! the hot path.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Compiled executables are cached per file,
//! so each model variant compiles exactly once per process.
//!
//! NOTE: the `xla` crate's handles wrap raw PJRT pointers without Send/Sync,
//! so the runtime lives on the coordinator thread. Per-worker *compute* is
//! already parallel inside one call — the step HLO is vmapped over the
//! worker axis and XLA CPU multithreads it (DESIGN.md §2).

pub mod artifacts;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub use artifacts::{
    contiguous_segments, Artifacts, Dtype, ModelArtifacts, Segment, StepSpec, TensorSpec,
};

/// An input tensor for one execution.
pub enum Input<'a> {
    F32(&'a [f32], Vec<i64>),
    I32(&'a [i32], Vec<i64>),
}

impl<'a> Input<'a> {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Input::F32(data, dims) => {
                let lit = xla::Literal::vec1(data);
                if dims.len() == 1 {
                    lit
                } else {
                    lit.reshape(dims)?
                }
            }
            Input::I32(data, dims) => {
                let lit = xla::Literal::vec1(data);
                if dims.len() == 1 {
                    lit
                } else {
                    lit.reshape(dims)?
                }
            }
        })
    }

    fn len(&self) -> usize {
        match self {
            Input::F32(d, _) => d.len(),
            Input::I32(d, _) => d.len(),
        }
    }
}

/// One decoded output tensor.
#[derive(Debug)]
pub enum Output {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Output {
    pub fn f32(self) -> Result<Vec<f32>> {
        match self {
            Output::F32(v) => Ok(v),
            other => bail!("expected f32 output, got {other:?}"),
        }
    }
}

/// PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// cumulative time spent inside PJRT execute (compute profiling)
    exec_seconds: RefCell<f64>,
    exec_calls: RefCell<u64>,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: RefCell::new(HashMap::new()),
            exec_seconds: RefCell::new(0.0),
            exec_calls: RefCell::new(0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by absolute path).
    pub fn load(&self, path: &Path) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))?,
        );
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute a compiled artifact. The lowered functions return a tuple
    /// root (aot.py lowers with return_tuple=True); outputs come back
    /// decomposed in order.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[Input<'_>],
    ) -> Result<Vec<Output>> {
        let literals = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<Vec<_>>>()?;

        let t0 = std::time::Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?;
        let root = result[0][0].to_literal_sync()?;
        *self.exec_seconds.borrow_mut() += t0.elapsed().as_secs_f64();
        *self.exec_calls.borrow_mut() += 1;

        let parts = root.to_tuple()?;
        parts
            .into_iter()
            .map(|lit| {
                let ty = lit.ty()?;
                Ok(match ty {
                    xla::ElementType::F32 => Output::F32(lit.to_vec::<f32>()?),
                    xla::ElementType::S32 => Output::I32(lit.to_vec::<i32>()?),
                    other => bail!("unsupported output element type {other:?}"),
                })
            })
            .collect()
    }

    /// (total seconds inside execute, number of calls) — perf accounting.
    pub fn exec_stats(&self) -> (f64, u64) {
        (*self.exec_seconds.borrow(), *self.exec_calls.borrow())
    }
}

/// A model's training-step handle: validates shapes once, then executes.
pub struct StepFn {
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    pub spec: StepSpec,
    pub param_count: usize,
}

/// Output of one multi-worker gradient step.
pub struct StepOut {
    /// per-worker loss, len M
    pub losses: Vec<f32>,
    /// row-major [M, P] per-worker gradients
    pub grads: Vec<f32>,
}

impl StepFn {
    pub fn load(rt: &Runtime, arts: &Artifacts, model: &ModelArtifacts, workers: usize) -> Result<StepFn> {
        let spec = model
            .steps
            .get(&workers)
            .with_context(|| {
                format!(
                    "no lowered step for M={workers} (have {:?}) — re-run aot.py with --workers",
                    model.steps.keys().collect::<Vec<_>>()
                )
            })?
            .clone();
        let exe = rt.load(&arts.path_of(&spec.file))?;
        Ok(StepFn { exe, spec, param_count: model.param_count })
    }

    /// Classifier batch: x f32[M,B,...], y i32[M,B]. LM batch: tokens i32[M,B,T+1]
    /// passed through `x_i32`.
    pub fn run(
        &self,
        rt: &Runtime,
        params: &[f32],
        x_f32: Option<&[f32]>,
        x_i32: Option<&[i32]>,
        y_i32: Option<&[i32]>,
    ) -> Result<StepOut> {
        anyhow::ensure!(params.len() == self.param_count, "params length mismatch");
        let mut inputs: Vec<Input> = Vec::with_capacity(self.spec.inputs.len());
        for spec in &self.spec.inputs {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let input = match (spec.kind.as_str(), spec.dtype) {
                ("params", Dtype::F32) => Input::F32(params, dims),
                ("images", Dtype::F32) => {
                    Input::F32(x_f32.context("step needs images")?, dims)
                }
                ("labels", Dtype::I32) => Input::I32(y_i32.context("step needs labels")?, dims),
                ("tokens", Dtype::I32) => Input::I32(x_i32.context("step needs tokens")?, dims),
                (k, d) => bail!("unhandled step input kind={k} dtype={d:?}"),
            };
            anyhow::ensure!(
                input.len() == spec.elements(),
                "input '{}' length {} != expected {}",
                spec.kind,
                input.len(),
                spec.elements()
            );
            inputs.push(input);
        }
        let mut outs = rt.execute(&self.exe, &inputs)?;
        anyhow::ensure!(outs.len() == 2, "step should return (loss, grads), got {} outputs", outs.len());
        let grads = outs.pop().unwrap().f32()?;
        let losses = outs.pop().unwrap().f32()?;
        anyhow::ensure!(losses.len() == self.spec.workers, "loss vector length mismatch");
        anyhow::ensure!(
            grads.len() == self.spec.workers * self.param_count,
            "grads length mismatch"
        );
        Ok(StepOut { losses, grads })
    }
}

/// Eval-step handle: returns (mean loss, correct count).
pub struct EvalFn {
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    pub spec: artifacts::EvalSpec,
    param_count: usize,
}

impl EvalFn {
    pub fn load(rt: &Runtime, arts: &Artifacts, model: &ModelArtifacts) -> Result<EvalFn> {
        let exe = rt.load(&arts.path_of(&model.eval.file))?;
        Ok(EvalFn { exe, spec: model.eval.clone(), param_count: model.param_count })
    }

    pub fn run(
        &self,
        rt: &Runtime,
        params: &[f32],
        x_f32: Option<&[f32]>,
        x_i32: Option<&[i32]>,
        y_i32: Option<&[i32]>,
    ) -> Result<(f32, f32)> {
        anyhow::ensure!(params.len() == self.param_count, "params length mismatch");
        let mut inputs: Vec<Input> = Vec::new();
        for spec in &self.spec.inputs {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let input = match (spec.kind.as_str(), spec.dtype) {
                ("params", Dtype::F32) => Input::F32(params, dims),
                ("images", Dtype::F32) => Input::F32(x_f32.context("eval needs images")?, dims),
                ("labels", Dtype::I32) => Input::I32(y_i32.context("eval needs labels")?, dims),
                ("tokens", Dtype::I32) => Input::I32(x_i32.context("eval needs tokens")?, dims),
                (k, d) => bail!("unhandled eval input kind={k} dtype={d:?}"),
            };
            inputs.push(input);
        }
        let outs = rt.execute(&self.exe, &inputs)?;
        anyhow::ensure!(outs.len() == 2, "eval should return (loss, correct)");
        let mut it = outs.into_iter();
        let loss = it.next().unwrap().f32()?[0];
        let correct = it.next().unwrap().f32()?[0];
        Ok((loss, correct))
    }
}
