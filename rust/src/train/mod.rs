//! High-level training driver: runs method sweeps, logs CSV curves, prints
//! comparison tables. This is the engine behind `repro train`,
//! `repro figures` and the per-figure benches.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::cluster::{run_training, ClusterConfig};
use crate::collectives::IntegrityConfig;
use crate::compress::Method;
use crate::control::{AnomalyPolicy, ControlConfig, ElasticConfig};
use crate::metrics::{render_table, CsvWriter, RunSummary, StepRecord};
use crate::runtime::Artifacts;

/// One experiment: a model trained with a list of methods under identical
/// data/seed/schedule, logging loss curves per method.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub name: String,
    pub model: String,
    pub methods: Vec<Method>,
    pub workers: usize,
    pub steps: usize,
    pub lr0: f64,
    pub seed: u64,
    pub net_gbps: f64,
    /// GPUs per NVLink island (CLI `--topology NxG`); 1 = flat topology
    pub gpus_per_node: usize,
    /// hierarchical two-level packed schedule (CLI `--schedule hier`)
    pub hier_schedule: bool,
    pub eval_every: usize,
    pub out_dir: PathBuf,
    pub quiet: bool,
    /// bucketed control-plane options applied to every method of the sweep
    pub control: Option<ControlConfig>,
    /// elastic-cohort policy + fault schedule applied to every method
    pub elastic: Option<ElasticConfig>,
    /// hop-segment integrity (checksums + retransmit) applied to every
    /// method; `None` trusts the wire
    pub integrity: Option<IntegrityConfig>,
    /// policy for non-finite local gradients (pre-encode guard)
    pub on_anomaly: AnomalyPolicy,
    /// flight-recorder output path (CLI `--trace PATH`); multi-method
    /// sweeps suffix the method label before the extension
    pub trace: Option<PathBuf>,
}

impl Experiment {
    pub fn new(name: &str, model: &str, methods: Vec<Method>) -> Experiment {
        Experiment {
            name: name.to_string(),
            model: model.to_string(),
            methods,
            workers: 4,
            steps: 200,
            lr0: 0.05,
            seed: 42,
            net_gbps: 10.0,
            gpus_per_node: 1,
            hier_schedule: false,
            eval_every: 0,
            out_dir: PathBuf::from("results"),
            quiet: false,
            control: None,
            elastic: None,
            integrity: None,
            on_anomaly: AnomalyPolicy::Skip,
            trace: None,
        }
    }

    fn csv_path(&self, label: &str) -> PathBuf {
        let safe: String = label
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' { c } else { '_' })
            .collect();
        self.out_dir.join(format!("{}_{}.csv", self.name, safe))
    }

    /// Per-method trace path: the configured path as-is for a single-method
    /// run; sweeps get the sanitized method label spliced in before the
    /// extension so each method's trace survives.
    fn trace_path(&self, label: &str) -> Option<PathBuf> {
        let base = self.trace.as_ref()?;
        if self.methods.len() <= 1 {
            return Some(base.clone());
        }
        let safe: String = label
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' { c } else { '_' })
            .collect();
        let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
        let name = match base.extension().and_then(|e| e.to_str()) {
            Some(ext) => format!("{stem}_{safe}.{ext}"),
            None => format!("{stem}_{safe}"),
        };
        Some(base.with_file_name(name))
    }

    /// Run all methods; returns (per-method curves, summaries).
    pub fn run(&self, arts: &Artifacts) -> Result<Vec<(Vec<StepRecord>, RunSummary)>> {
        let mut results = Vec::new();
        for method in &self.methods {
            let mut cfg = ClusterConfig::new(&self.model, self.workers, method.clone());
            cfg.seed = self.seed;
            cfg.lr0 = self.lr0;
            cfg.total_steps = self.steps;
            cfg.net_gbps = self.net_gbps;
            cfg.gpus_per_node = self.gpus_per_node;
            cfg.hier_schedule = self.hier_schedule;
            cfg.control = self.control.clone();
            cfg.elastic = self.elastic.clone();
            cfg.integrity = self.integrity;
            cfg.on_anomaly = self.on_anomaly;

            let label = method.label();
            cfg.trace = self.trace_path(&label);
            if !self.quiet {
                eprintln!("[{}] {} on {} (M={}, {} steps)", self.name, label, self.model, self.workers, self.steps);
            }
            let mut csv = CsvWriter::create(
                &self.csv_path(&label),
                &["step", "loss", "lr", "t_compute", "t_encode", "t_decode", "t_comm_sim", "bits_per_worker", "overlap_frac", "live_workers", "straggler_wait_s", "staleness", "retrans_bits", "retrans_s", "skipped"],
            )?;
            let quiet = self.quiet;
            let steps = self.steps;
            let (records, summary) = run_training(arts, cfg, |rec| {
                let _ = csv.row(&[
                    rec.step as f64,
                    rec.loss,
                    rec.lr,
                    rec.t_compute,
                    rec.t_encode,
                    rec.t_decode,
                    rec.t_comm_sim,
                    rec.bits_per_worker,
                    rec.overlap_frac,
                    rec.live_workers as f64,
                    rec.straggler_wait_s,
                    rec.staleness as f64,
                    rec.retrans_bits,
                    rec.retrans_s,
                    rec.skipped as u8 as f64,
                ]);
                if !quiet && (rec.step % 20 == 0 || rec.step + 1 == steps) {
                    eprintln!("  step {:>5}  loss {:.4}  lr {:.4}", rec.step, rec.loss, rec.lr);
                }
            })?;
            if !self.quiet {
                eprintln!(
                    "  -> final loss {:.4}, eval loss {:.4}, eval acc {:.3}, sim {:.3}s",
                    summary.final_loss, summary.final_eval_loss, summary.final_eval_acc, summary.sim_time_s
                );
            }
            results.push((records, summary));
        }
        Ok(results)
    }
}

/// Render the standard comparison table for a finished experiment.
pub fn summary_table(summaries: &[RunSummary]) -> String {
    let rows: Vec<Vec<String>> = summaries
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{:.4}", r.final_loss),
                format!("{:.4}", r.final_eval_loss),
                format!("{:.3}", r.final_eval_acc),
                format!("{:.1}", r.mean_bits_per_step / 1e3),
                format!("{:.2}", r.overlap_frac),
                format!("{:.3}", r.t_straggler_wait),
                format!("{:.3}", r.t_retrans),
                format!("{:.3}", r.sim_time_s),
                format!("{:.1}", r.wall_time_s),
            ]
        })
        .collect();
    render_table(
        &["method", "train_loss", "eval_loss", "eval_acc", "kbits/step", "ovl", "wait_s", "rtx_s", "sim_s", "wall_s"],
        &rows,
    )
}

/// Write summaries as JSON next to the CSVs.
pub fn write_summaries(dir: &Path, name: &str, summaries: &[RunSummary]) -> Result<()> {
    crate::metrics::write_report(&dir.join(format!("{name}_summary.json")), summaries)
}
