//! α–β network cost model (the simulated wire).
//!
//! The paper's scalability argument is about *communication time*: all-reduce
//! scales O(log M) / O(1) in bandwidth terms while all-gather scales O(M).
//! We reproduce that with the standard latency–bandwidth (α–β) model over a
//! two-level hierarchy: GPUs within a node connected by NVLink, nodes
//! connected by Ethernet — the same topology §6.6 profiles (AWS p3.8xlarge,
//! 4×V100 + 10 Gbps).
//!
//! Every simulated collective charges this model; the physical data movement
//! happens in [`crate::collectives`] (real bytes through real encoders), so
//! simulated time and real numerics are decoupled but consistent.

pub mod fault;

pub use fault::{CohortEvent, EventKind, FaultPlan, HopFault, Outage, PoisonEvent};

/// One link class: latency (s) + inverse bandwidth (s/byte).
#[derive(Clone, Copy, Debug)]
pub struct Link {
    pub alpha_s: f64,
    pub bytes_per_s: f64,
}

impl Link {
    pub fn nvlink() -> Link {
        // NVLink2 ~25 GB/s effective per direction, ~2us launch latency
        Link { alpha_s: 2e-6, bytes_per_s: 25e9 }
    }

    pub fn ethernet_gbps(gbps: f64) -> Link {
        // TCP/IP stack latency ~50us
        Link { alpha_s: 50e-6, bytes_per_s: gbps * 1e9 / 8.0 }
    }

    fn xfer_s(&self, bytes: f64) -> f64 {
        self.alpha_s + bytes / self.bytes_per_s
    }
}

/// Wire-width policy for the packed ring schedule: ship every hop at the
/// fixed final-sum width (in-place add-with-carry hops, no repack), grow the
/// width hop-by-hop with the partial-sum contribution count (minimal wire,
/// pack-per-hop compute), or let [`NetConfig::growing_ring_wins`] decide
/// per step from the analytic cost model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RingWidth {
    Fixed,
    Growing,
    #[default]
    Auto,
}

/// One level of the two-level topology — the axis every per-level charge
/// (PR 8) is keyed by: `Intra` is the NVLink island fabric, `Inter` the
/// Ethernet between node leaders.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkLevel {
    Intra,
    Inter,
}

/// Modeled CPU cost of one byte of pack-per-hop re-pack work (unpack the
/// resident segment, repack at the hop width, unpack on receive, repack the
/// accumulated fields): ~2.5 GB/s of effective bit-twiddling throughput per
/// pass, on top of the add-with-carry pass the fixed ring already pays.
pub const REPACK_S_PER_BYTE: f64 = 4e-10;

/// Extra segment passes a width-growing reduce-scatter hop costs over the
/// fixed ring's single add-with-carry pass (sender repack + receiver
/// unpack/accumulate/repack, net of the adc pass).
const GROWING_EXTRA_PASSES: f64 = 2.0;

/// All-reduce algorithm the cost model assumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Bandwidth-optimal ring: reduce-scatter + all-gather.
    Ring,
    /// Latency-optimal binary tree (reduce + broadcast).
    Tree,
    /// Every rank sends its full buffer to every other rank.
    Naive,
}

impl std::str::FromStr for Algo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "ring" => Ok(Algo::Ring),
            "tree" => Ok(Algo::Tree),
            "naive" => Ok(Algo::Naive),
            other => Err(format!("unknown allreduce algo '{other}'")),
        }
    }
}

/// Cluster shape + links.
#[derive(Clone, Debug)]
pub struct NetConfig {
    pub workers: usize,
    pub gpus_per_node: usize,
    pub intra: Link,
    pub inter: Link,
    pub algo: Algo,
}

impl NetConfig {
    /// Single-node cluster over NVLink (the Fig 15 testbed uses Ethernet
    /// between single-GPU machines — see [`NetConfig::flat`]).
    pub fn single_node(workers: usize) -> NetConfig {
        NetConfig {
            workers,
            gpus_per_node: workers.max(1),
            intra: Link::nvlink(),
            inter: Link::ethernet_gbps(10.0),
            algo: Algo::Ring,
        }
    }

    /// Flat cluster: one GPU per node, everything over Ethernet.
    pub fn flat(workers: usize, gbps: f64) -> NetConfig {
        NetConfig {
            workers,
            gpus_per_node: 1,
            intra: Link::nvlink(),
            inter: Link::ethernet_gbps(gbps),
            algo: Algo::Ring,
        }
    }

    /// The paper's §6.6 projection target: 32 nodes × 4 V100 w/ NVLink.
    pub fn paper_cluster(gbps: f64) -> NetConfig {
        NetConfig {
            workers: 128,
            gpus_per_node: 4,
            intra: Link::nvlink(),
            inter: Link::ethernet_gbps(gbps),
            algo: Algo::Ring,
        }
    }

    pub fn nodes(&self) -> usize {
        self.workers.div_ceil(self.gpus_per_node)
    }

    /// Ring all-reduce of `bytes` over `n` ranks on `link`:
    /// 2(n−1) steps of α + (bytes/n)·β.
    fn ring_s(link: &Link, bytes: f64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        steps as f64 * link.alpha_s + (steps as f64 / n as f64) * bytes / link.bytes_per_s
    }

    /// Tree all-reduce: 2·log2(n) rounds of the full buffer.
    fn tree_s(link: &Link, bytes: f64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let rounds = 2.0 * (n as f64).log2().ceil();
        rounds * link.xfer_s(bytes)
    }

    /// Naive all-reduce == all-gather then local sum: (n−1) full buffers.
    fn naive_s(link: &Link, bytes: f64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * link.xfer_s(bytes)
    }

    fn one_level_allreduce_s(&self, link: &Link, bytes: f64, n: usize) -> f64 {
        match self.algo {
            Algo::Ring => Self::ring_s(link, bytes, n),
            Algo::Tree => Self::tree_s(link, bytes, n),
            Algo::Naive => Self::naive_s(link, bytes, n),
        }
    }

    /// Hierarchical all-reduce of a `bytes`-sized buffer across all workers:
    /// intra-node reduce-scatter/all-gather + inter-node ring (NCCL-style).
    pub fn allreduce_s(&self, bytes: f64) -> f64 {
        let g = self.gpus_per_node.min(self.workers).max(1);
        let nodes = self.nodes();
        let mut t = self.one_level_allreduce_s(&self.intra, bytes, g);
        if nodes > 1 {
            t += self.one_level_allreduce_s(&self.inter, bytes, nodes);
        }
        t
    }

    /// All-gather where every rank contributes `bytes_per_rank`:
    /// O(M) total bytes per rank — the scalability killer the paper plots.
    pub fn allgather_s(&self, bytes_per_rank: f64) -> f64 {
        let g = self.gpus_per_node.min(self.workers).max(1);
        let nodes = self.nodes();
        let mut t = if g > 1 {
            (g - 1) as f64 * self.intra.alpha_s
                + (g - 1) as f64 * bytes_per_rank / self.intra.bytes_per_s
        } else {
            0.0
        };
        if nodes > 1 {
            // after intra gather, each node forwards g×bytes_per_rank
            let node_bytes = g as f64 * bytes_per_rank;
            t += (nodes - 1) as f64 * self.inter.alpha_s
                + (nodes - 1) as f64 * node_bytes / self.inter.bytes_per_s;
            if g > 1 {
                // distribution leg (PR 8 bugfix): the inter-node gather lands
                // on each node's leader, but the other g−1 GPUs still need
                // the foreign nodes' (nodes−1)·g·bytes_per_rank over NVLink —
                // a pipelined intra broadcast: g−1 launch latencies plus the
                // foreign bytes once through the NVLink bandwidth
                let foreign_bytes = (nodes - 1) as f64 * node_bytes;
                t += (g - 1) as f64 * self.intra.alpha_s
                    + foreign_bytes / self.intra.bytes_per_s;
            }
        }
        t
    }

    /// A scalar max/min all-reduce (one f32): latency-dominated.
    pub fn scalar_allreduce_s(&self) -> f64 {
        self.allreduce_s(4.0)
    }

    /// The link class a [`LinkLevel`] names on this topology.
    fn link(&self, level: LinkLevel) -> &Link {
        match level {
            LinkLevel::Intra => &self.intra,
            LinkLevel::Inter => &self.inter,
        }
    }

    /// The level a flat (single-level) collective step bottlenecks on:
    /// inter-node when the cluster spans nodes, NVLink otherwise.
    pub fn bottleneck_level(&self) -> LinkLevel {
        if self.nodes() > 1 {
            LinkLevel::Inter
        } else {
            LinkLevel::Intra
        }
    }

    /// The link a synchronous collective step bottlenecks on.
    fn bottleneck(&self) -> &Link {
        self.link(self.bottleneck_level())
    }

    /// One synchronous hop moving `bytes` per rank over the bottleneck link
    /// — the unit every hop-accurate packed-schedule charge is built from.
    pub fn hop_s(&self, bytes: f64) -> f64 {
        self.hop_s_on(self.bottleneck_level(), bytes)
    }

    /// One synchronous hop moving `bytes` per rank over `level`'s link —
    /// the per-level unit the hierarchical packed schedule charges its
    /// intra-island and leader-ring hops from (PR 8).
    pub fn hop_s_on(&self, level: LinkLevel, bytes: f64) -> f64 {
        if self.workers <= 1 {
            return 0.0;
        }
        self.link(level).xfer_s(bytes)
    }

    /// Hop-accurate ring time: `steps` synchronous ring steps, each moving
    /// `bytes_per_step` per rank over the bottleneck link. Used by the
    /// packed-resident ring, whose per-hop segments are *wider* than the
    /// nominal payload (partial sums need headroom) — the deployment gap the
    /// uniform [`NetConfig::allreduce_s`] model hides (ScaleCom, Chen et
    /// al., 2020).
    pub fn ring_steps_s(&self, steps: usize, bytes_per_step: f64) -> f64 {
        if steps == 0 {
            return 0.0;
        }
        steps as f64 * self.hop_s(bytes_per_step)
    }

    /// Per-step analytic selector for the packed ring's wire width
    /// ([`RingWidth::Auto`]) on the flat (bottleneck-link) ring: does the
    /// width-growing pack-per-hop ring beat the fixed-width add-with-carry
    /// ring *in time* for this step? Delegates to the per-level form at the
    /// bottleneck level — the hierarchical schedule makes the same decision
    /// for its leader ring with [`LinkLevel::Inter`] and the island-sum
    /// contribution bound (PR 8).
    pub fn growing_ring_wins(&self, lmax: usize, m: usize, elems: usize) -> bool {
        self.growing_ring_wins_on(self.bottleneck_level(), lmax, m, elems)
    }

    /// Per-level form of the growing-ring selector: a ring of `m` ranks,
    /// each contributing biased codes bounded by `lmax`, shipped over
    /// `level`'s link.
    ///
    /// Wire seconds saved: each reduce-scatter hop `k` (of `m - 1`) ships
    /// its `ceil(elems/m)`-code segment at `bitlen(2*k*lmax)` instead of the
    /// fixed `bitlen(2*m*lmax)` (all-gather hops ship completed sums — no
    /// savings). Compute seconds added: [`GROWING_EXTRA_PASSES`] re-pack
    /// passes over the resident segment per reduce-scatter hop at
    /// [`REPACK_S_PER_BYTE`]. Growing wins on slow wires (the saved bytes
    /// buy more than the repack tax — low bits × high M over commodity
    /// Ethernet); fixed wins when the link outruns the re-packer. The
    /// observed data-plane crossover is recorded in DESIGN.md.
    ///
    /// The link's α term appears on **neither** side, deliberately: both
    /// rings make exactly `2(m−1)` synchronous hops, so the per-hop latency
    /// is a common term of both candidates' [`PackedReduce::comm_s`] sums
    /// and cancels in the comparison — including it would change nothing,
    /// omitting it cannot flip the selector even for tiny segments on
    /// high-α links. Pinned by `alpha_cancels_in_growing_selector` (here)
    /// and the crossover regression in the collectives tests.
    pub fn growing_ring_wins_on(
        &self,
        level: LinkLevel,
        lmax: usize,
        m: usize,
        elems: usize,
    ) -> bool {
        use crate::compress::bitpack::{packed_sum_bits, wire_bytes_for};
        if m <= 1 || elems == 0 {
            return false;
        }
        let seg = elems.div_ceil(m);
        let wfix = packed_sum_bits(lmax, m);
        let seg_fixed_bytes = wire_bytes_for(seg, wfix) as f64;
        let mut saved_bytes = 0.0;
        for k in 1..m {
            saved_bytes += seg_fixed_bytes - wire_bytes_for(seg, packed_sum_bits(lmax, k)) as f64;
        }
        let saved_s = saved_bytes / self.link(level).bytes_per_s;
        let extra_s =
            (m - 1) as f64 * GROWING_EXTRA_PASSES * seg_fixed_bytes * REPACK_S_PER_BYTE;
        saved_s > extra_s
    }
}

/// Accumulating simulated clock + wire ledger for one training run.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    pub comm_s: f64,
    pub compute_s: f64,
    pub encode_s: f64,
    pub decode_s: f64,
    /// payload bits sent per worker (the paper's 32 + d·r accounting)
    pub bits_per_worker: f64,
    /// hop-accurate bits sent per worker by the packed-resident ring: the
    /// sum over ring steps of the *actual* packed segment widths (partial
    /// sums ride wider codes than the nominal payload). Zero for paths that
    /// charge only the uniform model.
    pub hop_bits_per_worker: f64,
    /// the [`LinkLevel::Intra`] share of `hop_bits_per_worker` (PR 8): hop
    /// bits that crossed the NVLink island fabric. Flat schedules book
    /// everything on the bottleneck level, so on a multi-node flat wire
    /// this stays zero; the hierarchical schedule splits honestly.
    /// Invariant: `hop_bits_intra + hop_bits_inter == hop_bits_per_worker`.
    pub hop_bits_intra: f64,
    /// the [`LinkLevel::Inter`] share of `hop_bits_per_worker` (PR 8): hop
    /// bits that crossed the inter-node link.
    pub hop_bits_inter: f64,
    /// communication seconds hidden behind backward compute by the bucketed
    /// control plane's overlap scheduler ([`crate::control`]): this much of
    /// `comm_s` ran concurrently with `compute_s` and does not extend the
    /// step's critical path. Zero for the monolithic (non-overlapped) path.
    /// Invariant: `hidden_comm_s <= comm_s`.
    pub hidden_comm_s: f64,
    /// barrier seconds spent waiting for the slowest *surviving* worker
    /// beyond the nominal compute profile (straggler jitter under an
    /// elastic cohort policy, [`crate::control::elastic`]). Attributed
    /// separately from `comm_s` so the wire model stays honest, and from
    /// `compute_s` so the profile stays the intrinsic work. The overlap
    /// invariant extends across the new term: hidden comm is credited only
    /// against the surviving cohort's backward window — never against a
    /// dropped straggler's compute or the barrier wait — so
    /// `hidden_comm_s <= comm_s` still holds and the wait is always fully
    /// exposed on the critical path.
    pub straggler_wait_s: f64,
    /// recovery seconds spent on the self-healing data plane (PR 7):
    /// exponential backoff plus retransmitted-segment wire time after a
    /// checksum mismatch or injected loss, and the detection-timeout
    /// ladder for peers dropped after retry exhaustion. Attributed
    /// separately from `comm_s` (which stays the clean-wire charge) and
    /// always fully exposed on the critical path — a retransmit serializes
    /// behind the hop it repairs, so nothing overlaps it.
    pub retrans_s: f64,
    /// retransmitted wire bits, cohort-total (checksummed segment payload ×
    /// failed attempts). Unlike `bits_per_worker` this is *not* per-worker:
    /// a retransmit is one sender's repair, not a symmetric ring step.
    pub retrans_bits: f64,
}

impl SimClock {
    /// Critical-path seconds of the run: comm hidden behind compute by the
    /// overlap scheduler is subtracted — it ran during `compute_s` — while
    /// straggler barrier wait is added in full (nothing true runs under it
    /// that was not already charged: the overlap window is the *surviving*
    /// cohort's backward, which ends before the barrier resolves).
    pub fn total_s(&self) -> f64 {
        self.comm_s + self.compute_s + self.encode_s + self.decode_s + self.straggler_wait_s
            + self.retrans_s
            - self.hidden_comm_s
    }

    /// Fraction of the communication time the overlap scheduler hid behind
    /// compute (0 when nothing was charged or nothing overlapped).
    pub fn overlap_frac(&self) -> f64 {
        if self.comm_s > 0.0 {
            self.hidden_comm_s / self.comm_s
        } else {
            0.0
        }
    }

    /// Field-wise add of another clock (a per-step delta) into this one.
    pub fn accumulate(&mut self, d: &SimClock) {
        self.comm_s += d.comm_s;
        self.compute_s += d.compute_s;
        self.encode_s += d.encode_s;
        self.decode_s += d.decode_s;
        self.bits_per_worker += d.bits_per_worker;
        self.hop_bits_per_worker += d.hop_bits_per_worker;
        self.hop_bits_intra += d.hop_bits_intra;
        self.hop_bits_inter += d.hop_bits_inter;
        self.hidden_comm_s += d.hidden_comm_s;
        self.straggler_wait_s += d.straggler_wait_s;
        self.retrans_s += d.retrans_s;
        self.retrans_bits += d.retrans_bits;
    }

    /// Field-wise difference `self - before`: the ledger delta between two
    /// snapshots of the same accumulating clock (the flight recorder's
    /// per-step audit input, [`crate::trace::LedgerAudit`]).
    pub fn delta_since(&self, before: &SimClock) -> SimClock {
        SimClock {
            comm_s: self.comm_s - before.comm_s,
            compute_s: self.compute_s - before.compute_s,
            encode_s: self.encode_s - before.encode_s,
            decode_s: self.decode_s - before.decode_s,
            bits_per_worker: self.bits_per_worker - before.bits_per_worker,
            hop_bits_per_worker: self.hop_bits_per_worker - before.hop_bits_per_worker,
            hop_bits_intra: self.hop_bits_intra - before.hop_bits_intra,
            hop_bits_inter: self.hop_bits_inter - before.hop_bits_inter,
            hidden_comm_s: self.hidden_comm_s - before.hidden_comm_s,
            straggler_wait_s: self.straggler_wait_s - before.straggler_wait_s,
            retrans_s: self.retrans_s - before.retrans_s,
            retrans_bits: self.retrans_bits - before.retrans_bits,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_accumulate_and_delta_roundtrip() {
        let mut base = SimClock::default();
        base.comm_s = 1.5;
        base.compute_s = 2.0;
        base.bits_per_worker = 4096.0;
        base.hop_bits_intra = 1024.0;
        let mut d = SimClock::default();
        d.comm_s = 0.25;
        d.encode_s = 0.125;
        d.hop_bits_intra = 512.0;
        d.retrans_bits = 64.0;
        let before = base.clone();
        base.accumulate(&d);
        let got = base.delta_since(&before);
        assert_eq!(got.comm_s, d.comm_s);
        assert_eq!(got.compute_s, 0.0);
        assert_eq!(got.encode_s, d.encode_s);
        assert_eq!(got.hop_bits_intra, d.hop_bits_intra);
        assert_eq!(got.retrans_bits, d.retrans_bits);
        assert_eq!(got.bits_per_worker, 0.0);
    }

    #[test]
    fn ring_beats_naive_at_scale() {
        let bytes = 4.0 * 23_520_842.0; // ResNet50 fp32 gradient
        for workers in [8usize, 32, 128] {
            let mut ring = NetConfig::flat(workers, 10.0);
            ring.algo = Algo::Ring;
            let mut naive = ring.clone();
            naive.algo = Algo::Naive;
            assert!(
                ring.allreduce_s(bytes) < naive.allreduce_s(bytes),
                "ring must beat naive at M={workers}"
            );
        }
    }

    #[test]
    fn allreduce_bandwidth_term_is_size_invariant_in_m() {
        // Ring all-reduce total bytes per rank ~2·bytes regardless of M:
        // time grows only via latency terms.
        let bytes = 1e8;
        let t8 = NetConfig::flat(8, 10.0).allreduce_s(bytes);
        let t64 = NetConfig::flat(64, 10.0).allreduce_s(bytes);
        assert!(t64 < t8 * 1.5, "ring allreduce should scale gently: {t8} vs {t64}");
        // all-gather by contrast grows linearly
        let g8 = NetConfig::flat(8, 10.0).allgather_s(bytes);
        let g64 = NetConfig::flat(64, 10.0).allgather_s(bytes);
        assert!(g64 > g8 * 6.0, "allgather must scale ~linearly: {g8} vs {g64}");
    }

    #[test]
    fn hierarchy_uses_fast_intra_link() {
        let bytes = 1e8;
        let hier = NetConfig::paper_cluster(10.0); // 32 nodes × 4
        let flat = NetConfig::flat(128, 10.0);
        assert!(
            hier.allreduce_s(bytes) < flat.allreduce_s(bytes),
            "NVLink hierarchy should beat flat ethernet"
        );
    }

    #[test]
    fn single_worker_is_free() {
        let net = NetConfig::flat(1, 10.0);
        assert_eq!(net.allreduce_s(1e9), 0.0);
        assert_eq!(net.allgather_s(1e9), 0.0);
    }

    #[test]
    fn growing_selector_prefers_slow_wires() {
        // 2-bit quantizer (lmax=1), 8 workers: at 0.5 Gbps the saved
        // reduce-scatter bytes dominate the repack tax; on NVLink the link
        // outruns the re-packer. (The analytic crossover for this shape is
        // ~3 Gbps — see DESIGN.md.)
        let slow = NetConfig::flat(8, 0.5);
        let fast = NetConfig::single_node(8);
        assert!(slow.growing_ring_wins(1, 8, 1 << 20));
        assert!(!fast.growing_ring_wins(1, 8, 1 << 20));
        // degenerate shapes never pick growing
        assert!(!slow.growing_ring_wins(1, 1, 1 << 20));
        assert!(!slow.growing_ring_wins(1, 8, 0));
    }

    #[test]
    fn allgather_charges_the_intra_distribution_leg() {
        // PR 8 satellite regression: after the inter-node gather each node's
        // g GPUs still need the other nodes' (nodes−1)·g·bytes_per_rank over
        // NVLink. Pre-fix code stopped at the leader and this closed form
        // fails on it.
        let b = 1e6;
        let net = NetConfig::paper_cluster(10.0); // 32 nodes × 4 GPUs
        let (g, nodes) = (4f64, 32f64);
        let want = (g - 1.0) * net.intra.alpha_s + (g - 1.0) * b / net.intra.bytes_per_s
            + (nodes - 1.0) * net.inter.alpha_s
            + (nodes - 1.0) * g * b / net.inter.bytes_per_s
            + (g - 1.0) * net.intra.alpha_s
            + (nodes - 1.0) * g * b / net.intra.bytes_per_s;
        let got = net.allgather_s(b);
        assert!(
            (got - want).abs() <= 1e-12 * want,
            "allgather closed form: got {got}, want {want}"
        );
        // the leg only exists on true two-level topologies: flat (g = 1) and
        // single-node (nodes = 1) shapes are unchanged from the old model
        let flat = NetConfig::flat(8, 10.0);
        let flat_want = 7.0 * flat.inter.alpha_s + 7.0 * b / flat.inter.bytes_per_s;
        assert!((flat.allgather_s(b) - flat_want).abs() <= 1e-12 * flat_want);
        let single = NetConfig::single_node(8);
        let single_want = 7.0 * single.intra.alpha_s + 7.0 * b / single.intra.bytes_per_s;
        assert!((single.allgather_s(b) - single_want).abs() <= 1e-12 * single_want);
    }

    #[test]
    fn hop_s_on_levels_and_bottleneck_agree() {
        let hier = NetConfig::paper_cluster(10.0);
        assert_eq!(hier.bottleneck_level(), LinkLevel::Inter);
        assert_eq!(hier.hop_s(1e6), hier.hop_s_on(LinkLevel::Inter, 1e6));
        assert!(hier.hop_s_on(LinkLevel::Intra, 1e6) < hier.hop_s_on(LinkLevel::Inter, 1e6));
        let single = NetConfig::single_node(4);
        assert_eq!(single.bottleneck_level(), LinkLevel::Intra);
        assert_eq!(single.hop_s(1e6), single.hop_s_on(LinkLevel::Intra, 1e6));
        // single worker: every hop is free on every level
        let one = NetConfig::flat(1, 10.0);
        assert_eq!(one.hop_s_on(LinkLevel::Intra, 1e6), 0.0);
        assert_eq!(one.hop_s_on(LinkLevel::Inter, 1e6), 0.0);
    }

    #[test]
    fn alpha_cancels_in_growing_selector() {
        // PR 8 satellite regression: both ring widths make exactly 2(m−1)
        // hops, so the per-hop α is a common term and cannot flip the
        // selector — even for tiny segments on a very high-latency link.
        // Sweep α across six orders of magnitude at the bandwidth crossover
        // and at a tiny-segment shape; the decision must be α-invariant.
        let (lmax, m) = (1usize, 8usize);
        for &elems in &[64usize, 1 << 10, 1 << 20] {
            for &gbps in &[0.5f64, 3.0, 25.0, 200.0] {
                let mut reference = None;
                for &alpha in &[0.0f64, 1e-6, 1e-3, 1.0] {
                    let mut net = NetConfig::flat(m, gbps);
                    net.inter.alpha_s = alpha;
                    let wins = net.growing_ring_wins(lmax, m, elems);
                    match reference {
                        None => reference = Some(wins),
                        Some(r) => assert_eq!(
                            wins, r,
                            "α flipped the selector (elems={elems} gbps={gbps} α={alpha})"
                        ),
                    }
                }
            }
        }
    }

    #[test]
    fn growing_selector_is_per_level() {
        // the same step can want growing across nodes and fixed inside them:
        // on the paper cluster the inter link is slow Ethernet (growing
        // wins) while NVLink outruns the re-packer (fixed wins).
        let net = NetConfig::paper_cluster(0.5);
        assert!(net.growing_ring_wins_on(LinkLevel::Inter, 4, 32, 1 << 20));
        assert!(!net.growing_ring_wins_on(LinkLevel::Intra, 1, 128, 1 << 20));
        // the flat form is exactly the bottleneck-level per-level form
        assert_eq!(
            net.growing_ring_wins(1, 128, 1 << 20),
            net.growing_ring_wins_on(LinkLevel::Inter, 1, 128, 1 << 20)
        );
    }

    #[test]
    fn hop_s_matches_ring_steps() {
        let net = NetConfig::flat(4, 10.0);
        assert_eq!(net.ring_steps_s(6, 100.0), 6.0 * net.hop_s(100.0));
        assert_eq!(NetConfig::flat(1, 10.0).hop_s(100.0), 0.0);
    }

    #[test]
    fn straggler_wait_extends_total_and_never_shrinks_it() {
        // satellite regression (PR 6): barrier wait is a first-class
        // critical-path term — added in full, never offset by hidden comm
        // (hidden comm is bounded by comm_s, not by comm_s + wait).
        let mut clock = SimClock::default();
        clock.comm_s = 2.0;
        clock.compute_s = 3.0;
        clock.hidden_comm_s = 1.5;
        let base = clock.total_s();
        clock.straggler_wait_s = 0.7;
        assert_eq!(clock.total_s(), base + 0.7);
        // overlap_frac is about comm only: the wait does not dilute it
        assert_eq!(clock.overlap_frac(), 1.5 / 2.0);
        // the fully-hidden-comm extreme: total still includes the wait
        clock.hidden_comm_s = clock.comm_s;
        assert_eq!(clock.total_s(), 3.0 + 0.7);
    }

    #[test]
    fn retrans_time_extends_total_and_never_hides() {
        // PR 7: recovery time is a first-class critical-path term, added in
        // full on top of clean-wire comm — retransmits serialize behind the
        // hop they repair, so hidden comm never offsets them.
        let mut clock = SimClock::default();
        clock.comm_s = 2.0;
        clock.compute_s = 3.0;
        clock.hidden_comm_s = 2.0;
        let base = clock.total_s();
        clock.retrans_s = 0.3;
        clock.retrans_bits = 4096.0;
        assert_eq!(clock.total_s(), base + 0.3);
        // retransmitted bits are ledgered but do not change overlap_frac
        assert_eq!(clock.overlap_frac(), 1.0);
    }

    #[test]
    fn compressed_buffer_is_faster() {
        let net = NetConfig::flat(16, 1.0);
        let full = net.allreduce_s(4.0 * 14_728_266.0); // VGG16 fp32
        let q4 = net.allreduce_s(0.5 * 14_728_266.0); // 4-bit packed
        assert!(q4 < full / 4.0, "4-bit should be ~8x faster: {full} vs {q4}");
    }
}
