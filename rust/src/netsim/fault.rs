//! Deterministic fault & latency injection for the simulated cluster
//! (PR 6): per-worker step-time jitter, worker join/leave schedules, and
//! per-link degradation windows.
//!
//! Everything here is a pure function of `(plan seed, step, worker)` through
//! [`crate::util::rng::Rng::derive`], so a faulted run is exactly as
//! reproducible as a clean one — the determinism contract of DESIGN.md §5
//! extends to chaos. [`FaultPlan::none`] is the identity plan: no jitter, no
//! events, no outages, and [`FaultPlan::net_for_step`] returns the base
//! topology untouched (bit-identity pinned by the fault-plane parity matrix
//! in `tests/int_domain_equivalence.rs`).

use anyhow::{bail, Context, Result};

use super::NetConfig;
use crate::util::rng::Rng;

/// Label for the jitter stream derivation (`derive(&[FAULT_STREAM, step,
/// worker])`) — disjoint from the cluster's `0x5354` step stream and the
/// control plane's per-worker uniform streams.
const FAULT_STREAM: u64 = 0xFA17;

/// A membership change taking effect at the *start* of its step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// The worker leaves the cluster (crash, preemption, scale-down).
    Leave,
    /// The worker (re)joins and must catch up on the current parameters.
    Join,
}

/// One scheduled membership event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CohortEvent {
    pub step: usize,
    pub worker: usize,
    pub kind: EventKind,
}

/// An inter-node link degradation window: for steps in `[from, to)` the
/// inter-node bandwidth is multiplied by `factor` (0 < factor <= 1; a
/// near-zero factor models an outage the α–β model resolves to a stall).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Outage {
    pub from: usize,
    pub to: usize,
    pub factor: f64,
}

/// The deterministic fault schedule of one run.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the jitter stream (independent of the run seed so the same
    /// fault schedule can be replayed against different data orders).
    pub seed: u64,
    /// Relative per-worker step-time jitter: worker compute is scaled by
    /// `1 + jitter * |z|` with `z` standard normal (half-normal — a
    /// straggler only ever *slows down* relative to the profile).
    pub jitter: f64,
    /// Join/leave schedule, applied at the start of each step.
    pub events: Vec<CohortEvent>,
    /// Inter-node link degradation windows.
    pub outages: Vec<Outage>,
}

impl FaultPlan {
    /// The identity plan: no faults. Strict-sync under this plan is
    /// bit-identical to the pre-elastic data plane.
    pub fn none() -> FaultPlan {
        FaultPlan { seed: 0, jitter: 0.0, events: Vec::new(), outages: Vec::new() }
    }

    /// Jitter-only plan (the straggler scenario of `benches/micro_faults`).
    pub fn jittered(seed: u64, jitter: f64) -> FaultPlan {
        FaultPlan { seed, jitter, events: Vec::new(), outages: Vec::new() }
    }

    /// True iff this plan injects nothing.
    pub fn is_none(&self) -> bool {
        self.jitter == 0.0 && self.events.is_empty() && self.outages.is_empty()
    }

    /// Simulated compute seconds of `worker` at `step`: `base_s` scaled by
    /// the half-normal jitter multiplier of the derived `(seed, step,
    /// worker)` stream. With zero jitter no stream is drawn and `base_s`
    /// passes through exactly.
    pub fn worker_compute_s(&self, base_s: f64, step: usize, worker: usize) -> f64 {
        if self.jitter <= 0.0 {
            return base_s;
        }
        let mut r = Rng::new(self.seed).derive(&[FAULT_STREAM, step as u64, worker as u64]);
        base_s * (1.0 + self.jitter * r.next_normal().abs())
    }

    /// Membership events taking effect at the start of `step`.
    pub fn events_at(&self, step: usize) -> impl Iterator<Item = &CohortEvent> {
        self.events.iter().filter(move |e| e.step == step)
    }

    /// Bandwidth multiplier active on the inter-node link at `step`
    /// (overlapping windows compound; 1.0 when no window covers the step).
    pub fn link_factor(&self, step: usize) -> f64 {
        self.outages
            .iter()
            .filter(|o| o.from <= step && step < o.to)
            .map(|o| o.factor)
            .product()
    }

    /// The wire the cohort's collectives run over at `step`: the base
    /// topology with the *live* worker count substituted (so ring/tree hop
    /// counts, the packed resident width `bitlen(2*M_live*lmax)`, and every
    /// α–β charge re-derive from the surviving cohort) and any active
    /// degradation window applied to the inter-node link. For
    /// [`FaultPlan::none`] with a full cohort this is an exact clone of
    /// `base` — the bit-identity condition of the parity matrix.
    pub fn net_for_step(&self, base: &NetConfig, step: usize, live_workers: usize) -> NetConfig {
        let mut net = base.clone();
        net.workers = live_workers;
        let f = self.link_factor(step);
        // multiplying by the neutral 1.0 factor is exact in f64, so the
        // no-outage path stays bit-identical without a branch
        net.inter.bytes_per_s *= f;
        net
    }

    /// Parse a CLI fault spec: comma-separated clauses of
    /// `jitter=F` | `seed=N` | `leave=W@S` | `join=W@S` | `outage=A..B@F`,
    /// or the literal `none`. Example:
    /// `--faults jitter=0.1,seed=7,leave=3@10,join=3@20,outage=5..8@0.25`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::none();
        if spec.trim() == "none" {
            return Ok(plan);
        }
        for clause in spec.split(',') {
            let clause = clause.trim();
            let (key, val) = clause
                .split_once('=')
                .with_context(|| format!("fault clause '{clause}' is not key=value"))?;
            match key {
                "jitter" => {
                    plan.jitter = val
                        .parse()
                        .with_context(|| format!("bad jitter '{val}'"))?;
                    anyhow::ensure!(plan.jitter >= 0.0, "jitter must be >= 0");
                }
                "seed" => {
                    plan.seed = val.parse().with_context(|| format!("bad seed '{val}'"))?;
                }
                "leave" | "join" => {
                    let (w, s) = val
                        .split_once('@')
                        .with_context(|| format!("'{key}={val}' wants W@STEP"))?;
                    plan.events.push(CohortEvent {
                        worker: w.parse().with_context(|| format!("bad worker '{w}'"))?,
                        step: s.parse().with_context(|| format!("bad step '{s}'"))?,
                        kind: if key == "leave" { EventKind::Leave } else { EventKind::Join },
                    });
                }
                "outage" => {
                    let (range, f) = val
                        .split_once('@')
                        .with_context(|| format!("'outage={val}' wants A..B@FACTOR"))?;
                    let (a, b) = range
                        .split_once("..")
                        .with_context(|| format!("'outage={val}' wants A..B@FACTOR"))?;
                    let outage = Outage {
                        from: a.parse().with_context(|| format!("bad outage start '{a}'"))?,
                        to: b.parse().with_context(|| format!("bad outage end '{b}'"))?,
                        factor: f.parse().with_context(|| format!("bad outage factor '{f}'"))?,
                    };
                    anyhow::ensure!(
                        outage.from < outage.to,
                        "outage window {}..{} is empty",
                        outage.from,
                        outage.to
                    );
                    anyhow::ensure!(
                        outage.factor > 0.0 && outage.factor <= 1.0,
                        "outage factor must be in (0, 1], got {}",
                        outage.factor
                    );
                    plan.outages.push(outage);
                }
                other => bail!(
                    "unknown fault clause '{other}' \
                     (expect jitter|seed|leave|join|outage, or 'none')"
                ),
            }
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_is_identity() {
        let plan = FaultPlan::none();
        assert!(plan.is_none());
        assert_eq!(plan.worker_compute_s(0.25, 7, 3), 0.25);
        assert_eq!(plan.link_factor(0), 1.0);
        let base = NetConfig::flat(8, 10.0);
        let net = plan.net_for_step(&base, 5, 8);
        assert_eq!(net.workers, 8);
        assert_eq!(net.inter.bytes_per_s, base.inter.bytes_per_s);
        assert_eq!(net.inter.alpha_s, base.inter.alpha_s);
    }

    #[test]
    fn jitter_is_deterministic_and_only_slows_down() {
        let plan = FaultPlan::jittered(9, 0.5);
        let a = plan.worker_compute_s(1.0, 3, 1);
        let b = plan.worker_compute_s(1.0, 3, 1);
        assert_eq!(a, b, "same (seed, step, worker) must replay exactly");
        assert!(a >= 1.0, "half-normal jitter never speeds a worker up");
        // different workers and steps draw independent streams
        let c = plan.worker_compute_s(1.0, 3, 2);
        let d = plan.worker_compute_s(1.0, 4, 1);
        assert_ne!(a, c);
        assert_ne!(a, d);
        // a different plan seed reshuffles the stragglers
        let other = FaultPlan::jittered(10, 0.5);
        assert_ne!(a, other.worker_compute_s(1.0, 3, 1));
    }

    #[test]
    fn outage_windows_degrade_the_inter_link() {
        let mut plan = FaultPlan::none();
        plan.outages.push(Outage { from: 5, to: 8, factor: 0.25 });
        plan.outages.push(Outage { from: 7, to: 9, factor: 0.5 });
        assert_eq!(plan.link_factor(4), 1.0);
        assert_eq!(plan.link_factor(5), 0.25);
        assert_eq!(plan.link_factor(7), 0.125, "overlapping windows compound");
        assert_eq!(plan.link_factor(8), 0.5);
        assert_eq!(plan.link_factor(9), 1.0);
        let base = NetConfig::flat(8, 10.0);
        let net = plan.net_for_step(&base, 5, 8);
        assert_eq!(net.inter.bytes_per_s, base.inter.bytes_per_s * 0.25);
        // a degraded wire makes the same transfer strictly slower
        assert!(net.allreduce_s(1e6) > base.allreduce_s(1e6));
    }

    #[test]
    fn net_for_step_rederives_for_the_live_cohort() {
        let plan = FaultPlan::none();
        let base = NetConfig::flat(8, 10.0);
        let partial = plan.net_for_step(&base, 0, 5);
        assert_eq!(partial.workers, 5);
        // fewer ring participants -> fewer hops -> faster collective
        assert!(partial.allreduce_s(1e6) < base.allreduce_s(1e6));
    }

    #[test]
    fn parse_roundtrips_the_full_grammar() {
        let plan =
            FaultPlan::parse("jitter=0.1,seed=7,leave=3@10,join=3@20,outage=5..8@0.25").unwrap();
        assert_eq!(plan.jitter, 0.1);
        assert_eq!(plan.seed, 7);
        assert_eq!(
            plan.events,
            vec![
                CohortEvent { step: 10, worker: 3, kind: EventKind::Leave },
                CohortEvent { step: 20, worker: 3, kind: EventKind::Join },
            ]
        );
        assert_eq!(plan.outages, vec![Outage { from: 5, to: 8, factor: 0.25 }]);
        assert!(FaultPlan::parse("none").unwrap().is_none());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "jitter",            // no value
            "jitter=-0.5",       // negative
            "leave=3",           // missing @step
            "outage=5..5@0.5",   // empty window
            "outage=5..8@0.0",   // zero factor
            "outage=5..8@1.5",   // factor > 1
            "wobble=1",          // unknown clause
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn events_at_filters_by_step() {
        let plan = FaultPlan::parse("leave=1@3,leave=2@3,join=1@5").unwrap();
        assert_eq!(plan.events_at(3).count(), 2);
        assert_eq!(plan.events_at(5).count(), 1);
        assert_eq!(plan.events_at(4).count(), 0);
    }
}
