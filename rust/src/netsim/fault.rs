//! Deterministic fault & latency injection for the simulated cluster
//! (PR 6 + PR 7): per-worker step-time jitter, worker join/leave schedules,
//! per-link degradation windows, and — the PR 7 data-plane faults — per-hop
//! packet loss, per-hop word corruption (bit flips), and gradient-poison
//! events.
//!
//! Everything here is a pure function of `(plan seed, step, worker[, hop,
//! attempt])` through [`crate::util::rng::Rng::derive`], so a faulted run is
//! exactly as reproducible as a clean one — the determinism contract of
//! DESIGN.md §5 extends to chaos. [`FaultPlan::none`] is the identity plan:
//! no jitter, no events, no outages, no wire faults, no poison, and
//! [`FaultPlan::net_for_step`] returns the base topology untouched
//! (bit-identity pinned by the fault-plane parity matrix in
//! `tests/int_domain_equivalence.rs` and the wire-fault matrix in
//! `tests/self_healing.rs`).

use anyhow::{bail, Context, Result};

use super::NetConfig;
use crate::util::rng::Rng;

/// Label for the jitter stream derivation (`derive(&[FAULT_STREAM, step,
/// worker])`) — disjoint from the cluster's `0x5354` step stream and the
/// control plane's per-worker uniform streams.
const FAULT_STREAM: u64 = 0xFA17;

/// Label for the data-plane wire-fault stream
/// (`derive(&[WIRE_STREAM, step, worker, hop, attempt])`) — disjoint from
/// `FAULT_STREAM`, the cluster's `0x5354` step stream, and the `0xDA7A`
/// data seeds, so adding wire faults perturbs no existing draw.
const WIRE_STREAM: u64 = 0xC0DE;

/// A membership change taking effect at the *start* of its step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// The worker leaves the cluster (crash, preemption, scale-down).
    Leave,
    /// The worker (re)joins and must catch up on the current parameters.
    Join,
}

/// One scheduled membership event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CohortEvent {
    pub step: usize,
    pub worker: usize,
    pub kind: EventKind,
}

/// A scheduled gradient-poison event: at the start of `step`, worker
/// `worker`'s *local* gradient is corrupted with NaN/Inf before encode.
/// This is the end-to-end probe for the pre-encode `GradGuard` scan — a
/// poisoned gradient must be caught by the anomaly policy before a single
/// code reaches the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoisonEvent {
    pub step: usize,
    pub worker: usize,
}

/// Outcome of one delivery attempt of one hop segment on the wire, drawn
/// deterministically from `(seed, step, worker, hop, attempt)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HopFault {
    /// The segment arrives intact.
    None,
    /// The segment is lost in transit (receiver times out, retransmit).
    Lost,
    /// One bit of one wire word is flipped in transit; the checksum
    /// catches it and the segment is retransmitted. `word` is reduced
    /// modulo the segment's word count by the corruption site.
    Flip { word: u64, bit: u32 },
}

/// An inter-node link degradation window: for steps in `[from, to)` the
/// inter-node bandwidth is multiplied by `factor` (0 < factor <= 1; a
/// near-zero factor models an outage the α–β model resolves to a stall).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Outage {
    pub from: usize,
    pub to: usize,
    pub factor: f64,
}

/// The deterministic fault schedule of one run.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the jitter stream (independent of the run seed so the same
    /// fault schedule can be replayed against different data orders).
    pub seed: u64,
    /// Relative per-worker step-time jitter: worker compute is scaled by
    /// `1 + jitter * |z|` with `z` standard normal (half-normal — a
    /// straggler only ever *slows down* relative to the profile).
    pub jitter: f64,
    /// Join/leave schedule, applied at the start of each step.
    pub events: Vec<CohortEvent>,
    /// Inter-node link degradation windows.
    pub outages: Vec<Outage>,
    /// Per-hop-segment packet-loss probability in `[0, 1]`.
    pub loss: f64,
    /// Per-hop-segment single-bit corruption probability in `[0, 1]`
    /// (`loss + flip <= 1`: one uniform draw decides the attempt's fate).
    pub flip: f64,
    /// Scheduled gradient-poison events (NaN/Inf in a local gradient).
    pub poisons: Vec<PoisonEvent>,
}

impl FaultPlan {
    /// The identity plan: no faults. Strict-sync under this plan is
    /// bit-identical to the pre-elastic data plane.
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            jitter: 0.0,
            events: Vec::new(),
            outages: Vec::new(),
            loss: 0.0,
            flip: 0.0,
            poisons: Vec::new(),
        }
    }

    /// Jitter-only plan (the straggler scenario of `benches/micro_faults`).
    pub fn jittered(seed: u64, jitter: f64) -> FaultPlan {
        FaultPlan { seed, jitter, ..FaultPlan::none() }
    }

    /// Wire-fault-only plan (the corruption scenario of
    /// `benches/micro_integrity`).
    pub fn wire(seed: u64, loss: f64, flip: f64) -> FaultPlan {
        FaultPlan { seed, loss, flip, ..FaultPlan::none() }
    }

    /// True iff this plan injects nothing.
    pub fn is_none(&self) -> bool {
        self.jitter == 0.0
            && self.events.is_empty()
            && self.outages.is_empty()
            && self.loss == 0.0
            && self.flip == 0.0
            && self.poisons.is_empty()
    }

    /// Simulated compute seconds of `worker` at `step`: `base_s` scaled by
    /// the half-normal jitter multiplier of the derived `(seed, step,
    /// worker)` stream. With zero jitter no stream is drawn and `base_s`
    /// passes through exactly.
    pub fn worker_compute_s(&self, base_s: f64, step: usize, worker: usize) -> f64 {
        if self.jitter <= 0.0 {
            return base_s;
        }
        let mut r = Rng::new(self.seed).derive(&[FAULT_STREAM, step as u64, worker as u64]);
        base_s * (1.0 + self.jitter * r.next_normal().abs())
    }

    /// Fate of delivery `attempt` (0 = first transmission, 1.. =
    /// retransmits) of the hop segment sent by `worker` on hop `hop` of
    /// `step`. A pure function of `(seed, step, worker, hop, attempt)`:
    /// querying any attempt in any order, any number of times, replays the
    /// same outcome. One uniform draw partitions `[0, 1)` into
    /// `[0, loss) -> Lost`, `[loss, loss+flip) -> Flip`, rest intact; with
    /// both probabilities zero no stream is derived at all.
    pub fn hop_fault(&self, step: usize, worker: usize, hop: usize, attempt: u32) -> HopFault {
        if self.loss <= 0.0 && self.flip <= 0.0 {
            return HopFault::None;
        }
        let mut r = Rng::new(self.seed).derive(&[
            WIRE_STREAM,
            step as u64,
            worker as u64,
            hop as u64,
            attempt as u64,
        ]);
        let u = r.next_f64();
        if u < self.loss {
            HopFault::Lost
        } else if u < self.loss + self.flip {
            HopFault::Flip { word: r.next_u64(), bit: (r.next_u64() % 64) as u32 }
        } else {
            HopFault::None
        }
    }

    /// True iff `worker`'s local gradient is poisoned at `step`.
    pub fn poisoned(&self, step: usize, worker: usize) -> bool {
        self.poisons.iter().any(|p| p.step == step && p.worker == worker)
    }

    /// Workers (by *original id*, as in `ids`) that are unreachable at
    /// `step` even after `retries` retransmits: a peer is unreachable iff
    /// some hop in `0..hops` fails on every one of its `retries + 1`
    /// delivery attempts. This is the escalation predicate — the cluster
    /// drops these peers into the PR 6 elastic partial-cohort path instead
    /// of stalling the step.
    pub fn unreachable_peers(
        &self,
        step: usize,
        ids: &[usize],
        hops: usize,
        retries: u32,
    ) -> Vec<usize> {
        if self.loss <= 0.0 && self.flip <= 0.0 {
            return Vec::new();
        }
        ids.iter()
            .copied()
            .filter(|&w| {
                (0..hops).any(|h| {
                    (0..=retries).all(|a| self.hop_fault(step, w, h, a) != HopFault::None)
                })
            })
            .collect()
    }

    /// Membership events taking effect at the start of `step`.
    pub fn events_at(&self, step: usize) -> impl Iterator<Item = &CohortEvent> {
        self.events.iter().filter(move |e| e.step == step)
    }

    /// Bandwidth multiplier active on the inter-node link at `step`
    /// (overlapping windows compound; 1.0 when no window covers the step).
    pub fn link_factor(&self, step: usize) -> f64 {
        self.outages
            .iter()
            .filter(|o| o.from <= step && step < o.to)
            .map(|o| o.factor)
            .product()
    }

    /// The wire the cohort's collectives run over at `step`: the base
    /// topology with the *live* worker count substituted (so ring/tree hop
    /// counts, the packed resident width `bitlen(2*M_live*lmax)`, and every
    /// α–β charge re-derive from the surviving cohort) and any active
    /// degradation window applied to the link the shrunk cohort bottlenecks
    /// on. The island structure rides along untouched: `gpus_per_node` is
    /// cloned from `base`, so a leaving worker shrinks its (last,
    /// compacted) island while the leader ring keeps `ceil(live/g)` nodes —
    /// it only loses a node when an island empties entirely. For
    /// [`FaultPlan::none`] with a full cohort this is an exact clone of
    /// `base` — the bit-identity condition of the parity matrix.
    ///
    /// PR 8 satellite fix: the outage factor used to scale only `inter`,
    /// which silently no-ops on single-node topologies where every charge
    /// reads the `intra` bottleneck. It now degrades the bottleneck link of
    /// the live cohort — NVLink when `nodes() == 1`, Ethernet otherwise.
    pub fn net_for_step(&self, base: &NetConfig, step: usize, live_workers: usize) -> NetConfig {
        let mut net = base.clone();
        net.workers = live_workers;
        let f = self.link_factor(step);
        // multiplying by the neutral 1.0 factor is exact in f64, so the
        // no-outage path stays bit-identical without a branch
        match net.bottleneck_level() {
            crate::netsim::LinkLevel::Inter => net.inter.bytes_per_s *= f,
            crate::netsim::LinkLevel::Intra => net.intra.bytes_per_s *= f,
        }
        net
    }

    /// Parse a CLI fault spec: comma-separated clauses of
    /// `jitter=F` | `seed=N` | `leave=W@S` | `join=W@S` | `outage=A..B@F` |
    /// `loss=P` | `flip=P` | `poison=W@S`, or the literal `none`. Scalar
    /// keys (`jitter`, `seed`, `loss`, `flip`) may appear at most once;
    /// event-like clauses (`leave`, `join`, `outage`, `poison`) repeat.
    /// Example:
    /// `--faults jitter=0.1,seed=7,leave=3@10,loss=0.01,flip=0.001,poison=2@5`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::none();
        if spec.trim() == "none" {
            return Ok(plan);
        }
        let mut seen_scalar: Vec<&str> = Vec::new();
        let mut scalar_once = |key: &'static str| -> Result<()> {
            anyhow::ensure!(
                !seen_scalar.contains(&key),
                "duplicate fault clause '{key}' (scalar keys may appear once)"
            );
            seen_scalar.push(key);
            Ok(())
        };
        for clause in spec.split(',') {
            let clause = clause.trim();
            let (key, val) = clause
                .split_once('=')
                .with_context(|| format!("fault clause '{clause}' is not key=value"))?;
            match key {
                "jitter" => {
                    scalar_once("jitter")?;
                    plan.jitter = val
                        .parse()
                        .with_context(|| format!("bad jitter '{val}'"))?;
                    anyhow::ensure!(plan.jitter >= 0.0, "jitter must be >= 0");
                }
                "seed" => {
                    scalar_once("seed")?;
                    plan.seed = val.parse().with_context(|| format!("bad seed '{val}'"))?;
                }
                "loss" | "flip" => {
                    let p: f64 = val
                        .parse()
                        .with_context(|| format!("bad {key} probability '{val}'"))?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&p),
                        "{key} must be a probability in [0, 1], got {p}"
                    );
                    if key == "loss" {
                        scalar_once("loss")?;
                        plan.loss = p;
                    } else {
                        scalar_once("flip")?;
                        plan.flip = p;
                    }
                }
                "poison" => {
                    let (w, s) = val
                        .split_once('@')
                        .with_context(|| format!("'poison={val}' wants W@STEP"))?;
                    plan.poisons.push(PoisonEvent {
                        worker: w.parse().with_context(|| format!("bad worker '{w}'"))?,
                        step: s.parse().with_context(|| format!("bad step '{s}'"))?,
                    });
                }
                "leave" | "join" => {
                    let (w, s) = val
                        .split_once('@')
                        .with_context(|| format!("'{key}={val}' wants W@STEP"))?;
                    plan.events.push(CohortEvent {
                        worker: w.parse().with_context(|| format!("bad worker '{w}'"))?,
                        step: s.parse().with_context(|| format!("bad step '{s}'"))?,
                        kind: if key == "leave" { EventKind::Leave } else { EventKind::Join },
                    });
                }
                "outage" => {
                    let (range, f) = val
                        .split_once('@')
                        .with_context(|| format!("'outage={val}' wants A..B@FACTOR"))?;
                    let (a, b) = range
                        .split_once("..")
                        .with_context(|| format!("'outage={val}' wants A..B@FACTOR"))?;
                    let outage = Outage {
                        from: a.parse().with_context(|| format!("bad outage start '{a}'"))?,
                        to: b.parse().with_context(|| format!("bad outage end '{b}'"))?,
                        factor: f.parse().with_context(|| format!("bad outage factor '{f}'"))?,
                    };
                    anyhow::ensure!(
                        outage.from < outage.to,
                        "outage window {}..{} is empty",
                        outage.from,
                        outage.to
                    );
                    anyhow::ensure!(
                        outage.factor > 0.0 && outage.factor <= 1.0,
                        "outage factor must be in (0, 1], got {}",
                        outage.factor
                    );
                    plan.outages.push(outage);
                }
                other => bail!(
                    "unknown fault clause '{other}' \
                     (expect jitter|seed|leave|join|outage|loss|flip|poison, or 'none')"
                ),
            }
        }
        anyhow::ensure!(
            plan.loss + plan.flip <= 1.0,
            "loss + flip must be <= 1 (one draw decides an attempt's fate), got {} + {}",
            plan.loss,
            plan.flip
        );
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_is_identity() {
        let plan = FaultPlan::none();
        assert!(plan.is_none());
        assert_eq!(plan.worker_compute_s(0.25, 7, 3), 0.25);
        assert_eq!(plan.link_factor(0), 1.0);
        assert_eq!(plan.hop_fault(3, 1, 0, 0), HopFault::None);
        assert!(!plan.poisoned(0, 0));
        assert!(plan.unreachable_peers(0, &[0, 1, 2], 14, 3).is_empty());
        let base = NetConfig::flat(8, 10.0);
        let net = plan.net_for_step(&base, 5, 8);
        assert_eq!(net.workers, 8);
        assert_eq!(net.inter.bytes_per_s, base.inter.bytes_per_s);
        assert_eq!(net.inter.alpha_s, base.inter.alpha_s);
    }

    #[test]
    fn jitter_is_deterministic_and_only_slows_down() {
        let plan = FaultPlan::jittered(9, 0.5);
        let a = plan.worker_compute_s(1.0, 3, 1);
        let b = plan.worker_compute_s(1.0, 3, 1);
        assert_eq!(a, b, "same (seed, step, worker) must replay exactly");
        assert!(a >= 1.0, "half-normal jitter never speeds a worker up");
        // different workers and steps draw independent streams
        let c = plan.worker_compute_s(1.0, 3, 2);
        let d = plan.worker_compute_s(1.0, 4, 1);
        assert_ne!(a, c);
        assert_ne!(a, d);
        // a different plan seed reshuffles the stragglers
        let other = FaultPlan::jittered(10, 0.5);
        assert_ne!(a, other.worker_compute_s(1.0, 3, 1));
    }

    #[test]
    fn outage_windows_degrade_the_inter_link() {
        let mut plan = FaultPlan::none();
        plan.outages.push(Outage { from: 5, to: 8, factor: 0.25 });
        plan.outages.push(Outage { from: 7, to: 9, factor: 0.5 });
        assert_eq!(plan.link_factor(4), 1.0);
        assert_eq!(plan.link_factor(5), 0.25);
        assert_eq!(plan.link_factor(7), 0.125, "overlapping windows compound");
        assert_eq!(plan.link_factor(8), 0.5);
        assert_eq!(plan.link_factor(9), 1.0);
        let base = NetConfig::flat(8, 10.0);
        let net = plan.net_for_step(&base, 5, 8);
        assert_eq!(net.inter.bytes_per_s, base.inter.bytes_per_s * 0.25);
        // a degraded wire makes the same transfer strictly slower
        assert!(net.allreduce_s(1e6) > base.allreduce_s(1e6));
    }

    #[test]
    fn net_for_step_rederives_for_the_live_cohort() {
        let plan = FaultPlan::none();
        let base = NetConfig::flat(8, 10.0);
        let partial = plan.net_for_step(&base, 0, 5);
        assert_eq!(partial.workers, 5);
        // fewer ring participants -> fewer hops -> faster collective
        assert!(partial.allreduce_s(1e6) < base.allreduce_s(1e6));
    }

    #[test]
    fn parse_roundtrips_the_full_grammar() {
        let plan = FaultPlan::parse(
            "jitter=0.1,seed=7,leave=3@10,join=3@20,outage=5..8@0.25,\
             loss=0.01,flip=0.002,poison=2@5,poison=0@9",
        )
        .unwrap();
        assert_eq!(plan.jitter, 0.1);
        assert_eq!(plan.seed, 7);
        assert_eq!(
            plan.events,
            vec![
                CohortEvent { step: 10, worker: 3, kind: EventKind::Leave },
                CohortEvent { step: 20, worker: 3, kind: EventKind::Join },
            ]
        );
        assert_eq!(plan.outages, vec![Outage { from: 5, to: 8, factor: 0.25 }]);
        assert_eq!(plan.loss, 0.01);
        assert_eq!(plan.flip, 0.002);
        assert_eq!(
            plan.poisons,
            vec![PoisonEvent { step: 5, worker: 2 }, PoisonEvent { step: 9, worker: 0 }]
        );
        assert!(plan.poisoned(5, 2));
        assert!(!plan.poisoned(5, 3));
        assert!(FaultPlan::parse("none").unwrap().is_none());
        // every documented example round-trips
        for doc in [
            "jitter=0.1,seed=7,leave=3@10,join=3@20,outage=5..8@0.25",
            "jitter=0.1,seed=7,leave=3@10,loss=0.01,flip=0.001,poison=2@5",
            "leave=2@1,join=2@4",
            "loss=0.02",
            "flip=1.0",
        ] {
            assert!(FaultPlan::parse(doc).is_ok(), "documented example '{doc}' must parse");
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "jitter",                  // no value
            "jitter=-0.5",             // negative
            "jitter=0.1,jitter=0.2",   // duplicate scalar key
            "seed=1,seed=2",           // duplicate scalar key
            "leave=3",                 // missing @step
            "leave=@",                 // empty worker and step
            "leave=3@",                // empty step
            "outage=5..2@0.5",         // inverted window
            "outage=5..5@0.5",         // empty window
            "outage=5..8@0.0",         // zero factor
            "outage=5..8@1.5",         // factor > 1
            "loss=-0.1",               // negative probability
            "loss=1.5",                // probability > 1
            "loss=0.5,loss=0.5",       // duplicate scalar key
            "flip=-0.1",               // negative probability
            "flip=2",                  // probability > 1
            "loss=0.6,flip=0.5",       // loss + flip > 1
            "poison=2",                // missing @step
            "poison=@3",               // empty worker
            "wobble=1",                // unknown clause
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn wire_draws_are_pure_and_order_independent() {
        let plan = FaultPlan::parse("jitter=0.2,seed=11,loss=0.3,flip=0.3,poison=1@4").unwrap();
        // Query step 7 before step 3, then step 3 twice: every draw is a
        // pure function of its arguments, untouched by query order.
        let seven = plan.hop_fault(7, 2, 1, 0);
        let three_a = plan.hop_fault(3, 2, 1, 0);
        let three_b = plan.hop_fault(3, 2, 1, 0);
        assert_eq!(three_a, three_b, "same (step, worker, hop, attempt) must replay");
        assert_eq!(seven, plan.hop_fault(7, 2, 1, 0));
        let j7 = plan.worker_compute_s(1.0, 7, 0);
        let j3 = plan.worker_compute_s(1.0, 3, 0);
        assert_eq!(j3, plan.worker_compute_s(1.0, 3, 0));
        assert_eq!(j7, plan.worker_compute_s(1.0, 7, 0));
        assert_eq!(plan.link_factor(5), plan.link_factor(5));
        assert_eq!(plan.poisoned(4, 1), plan.poisoned(4, 1));
        let dead_a = plan.unreachable_peers(9, &[0, 1, 2, 3], 6, 1);
        let dead_b = plan.unreachable_peers(9, &[0, 1, 2, 3], 6, 1);
        assert_eq!(dead_a, dead_b);
        // Distinct attempts draw independent fates: over enough hops the
        // first and second attempts must disagree somewhere at p=0.6.
        let disagree = (0..64)
            .any(|h| plan.hop_fault(0, 0, h, 0) != plan.hop_fault(0, 0, h, 1));
        assert!(disagree, "retransmit attempts must re-draw, not replay the failure");
    }

    #[test]
    fn outage_degrades_single_node_topologies() {
        // PR 8 satellite regression: `outage=A..B@F` used to scale only the
        // inter link, a silent no-op on single-node topologies whose
        // bottleneck is NVLink. The degraded window must actually change
        // comm_s on `NetConfig::single_node` — this fails on pre-fix code.
        let plan = FaultPlan::parse("outage=2..5@0.25,seed=1").unwrap();
        let base = NetConfig::single_node(4);
        let clean = plan.net_for_step(&base, 0, 4);
        let degraded = plan.net_for_step(&base, 3, 4);
        assert_eq!(clean.intra.bytes_per_s, base.intra.bytes_per_s);
        assert_eq!(degraded.intra.bytes_per_s, 0.25 * base.intra.bytes_per_s);
        let bytes = 1e6;
        assert!(
            degraded.hop_s(bytes) > clean.hop_s(bytes),
            "degraded window must slow the single-node wire"
        );
        assert!(degraded.allreduce_s(bytes) > clean.allreduce_s(bytes));
        // multi-node topologies keep the inter-link semantics, and the
        // island structure (gpus_per_node) rides along for the hierarchical
        // schedule: a leaving worker shrinks its island, not the leader ring
        let hier = NetConfig::paper_cluster(10.0);
        let d = plan.net_for_step(&hier, 3, 127);
        assert_eq!(d.inter.bytes_per_s, 0.25 * hier.inter.bytes_per_s);
        assert_eq!(d.intra.bytes_per_s, hier.intra.bytes_per_s);
        assert_eq!(d.gpus_per_node, 4);
        assert_eq!(d.nodes(), 32, "127 live over g=4 still spans 32 islands");
    }

    #[test]
    fn hop_fault_rates_track_the_configured_probabilities() {
        let plan = FaultPlan::wire(42, 0.25, 0.25);
        let (mut lost, mut flipped, mut clean) = (0usize, 0usize, 0usize);
        let trials = 4000usize;
        for t in 0..trials {
            match plan.hop_fault(t, t % 7, t % 5, 0) {
                HopFault::Lost => lost += 1,
                HopFault::Flip { bit, .. } => {
                    assert!(bit < 64);
                    flipped += 1;
                }
                HopFault::None => clean += 1,
            }
        }
        let f = |c: usize| c as f64 / trials as f64;
        assert!((f(lost) - 0.25).abs() < 0.05, "loss rate {} far from 0.25", f(lost));
        assert!((f(flipped) - 0.25).abs() < 0.05, "flip rate {} far from 0.25", f(flipped));
        assert!((f(clean) - 0.5).abs() < 0.05, "clean rate {} far from 0.5", f(clean));
    }

    #[test]
    fn unreachable_peers_keys_by_original_id() {
        // loss=1 makes every attempt fail: everyone in `ids` is unreachable,
        // reported under the ids passed in (not cohort slots).
        let plan = FaultPlan::wire(3, 1.0, 0.0);
        assert_eq!(plan.unreachable_peers(2, &[0, 2, 5], 4, 3), vec![0, 2, 5]);
        // loss=0 makes no one unreachable even with zero retries
        let clean = FaultPlan::wire(3, 0.0, 0.0);
        assert!(clean.unreachable_peers(2, &[0, 2, 5], 4, 0).is_empty());
        // under a moderate rate, more retries can only shrink the dead set
        let mid = FaultPlan::wire(7, 0.4, 0.0);
        let ids: Vec<usize> = (0..16).collect();
        let dead0 = mid.unreachable_peers(1, &ids, 6, 0);
        let dead3 = mid.unreachable_peers(1, &ids, 6, 3);
        assert!(dead3.iter().all(|w| dead0.contains(w)));
        assert!(dead0.len() >= dead3.len());
    }

    #[test]
    fn events_at_filters_by_step() {
        let plan = FaultPlan::parse("leave=1@3,leave=2@3,join=1@5").unwrap();
        assert_eq!(plan.events_at(3).count(), 2);
        assert_eq!(plan.events_at(5).count(), 1);
        assert_eq!(plan.events_at(4).count(), 0);
    }
}
