//! §6.6 analytical performance model (after TernGrad [12]).
//!
//! Projects system throughput (images/s) for a cluster from:
//!   * a model profile — true parameter count + measured per-GPU step time
//!     (the paper profiled an AWS p3.8xlarge, 4×V100 NVLink; we encode the
//!     published/derived constants in [`ModelProfile`]);
//!   * the two-level α–β network model ([`crate::netsim::NetConfig`]);
//!   * a compression scheme's wire bits and encode/decode cost.
//!
//! `throughput = M·B / (t_compute + t_encode + t_comm + t_decode)`.
//!
//! Regenerates Figures 11–14 (`repro perfmodel`, bench `fig11_14_perfmodel`).

use crate::compress::kernels;
use crate::netsim::NetConfig;

/// Paper-scale model profiles (the *real* ResNet50/VGG16, not the lite
/// stand-ins used for the training curves — DESIGN.md §2).
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: &'static str,
    pub params: usize,
    /// per-GPU fwd+bwd seconds at `batch` on a V100 (fp32)
    pub compute_s: f64,
    pub batch: usize,
}

impl ModelProfile {
    /// ResNet50 on CIFAR10: 23 520 842 params (paper §6.7). The paper calls
    /// it *computation-intensive*: deep, many cheap layers — per-GPU step
    /// time dominated by kernel launches + compute. V100 batch-128 profile
    /// ≈ 610 img/s (0.21 s/step), params/compute ≈ 112 M/s.
    pub fn resnet50() -> ModelProfile {
        ModelProfile { name: "ResNet50", params: 23_520_842, compute_s: 0.21, batch: 128 }
    }

    /// VGG16 (CIFAR variant): 14 728 266 params (paper §6.7). The paper
    /// calls it *communication-intensive*: shallow and wide, so its
    /// params/compute ratio is ~2× ResNet50's. V100 batch-128 profile
    /// ≈ 1830 img/s (0.07 s/step), params/compute ≈ 210 M/s.
    pub fn vgg16() -> ModelProfile {
        ModelProfile { name: "VGG16", params: 14_728_266, compute_s: 0.07, batch: 128 }
    }
}

/// GPU-side processing rates for the compression stages (bytes/s through an
/// elementwise kernel ≈ HBM bandwidth-bound; V100 ≈ 900 GB/s theoretical,
/// ~300 GB/s effective for a read-modify-write quantizer chain).
const QUANTIZE_BYTES_PER_S: f64 = 300e9;
/// norm / scale-index extra pass
const REDUCE_BYTES_PER_S: f64 = 500e9;
/// low-rank matmul efficiency for PowerSGD (V100 fp32 ≈ 14 TFLOP/s, small
/// matrices reach ~20%)
const POWERSGD_FLOPS: f64 = 2.8e12;

/// A compression scheme as the performance model sees it.
#[derive(Clone, Debug)]
pub enum Scheme {
    AllReduceSgd,
    Qsgd { bits: usize },
    QsgdTs { bits_lo: usize, bits_hi: usize },
    RandK { bits: usize, k: usize },
    RandKTs { bits_lo: usize, bits_hi: usize, k: usize },
    PowerSgd { rank: usize },
}

impl Scheme {
    pub fn label(&self) -> String {
        match self {
            Scheme::AllReduceSgd => "AllReduce-SGD".into(),
            Scheme::Qsgd { bits } => format!("QSGD-MN-{bits}"),
            Scheme::QsgdTs { bits_lo, bits_hi } => format!("QSGD-MN-TS-({bits_lo},{bits_hi})"),
            Scheme::RandK { bits, .. } => format!("GRandK-MN-{bits}"),
            Scheme::RandKTs { bits_lo, bits_hi, .. } => {
                format!("GRandK-MN-TS-({bits_lo},{bits_hi})")
            }
            Scheme::PowerSgd { rank } => format!("PowerSGD-Rank-{rank}"),
        }
    }

    /// Payload bytes all-reduced per step for an n-coordinate gradient,
    /// plus a flag for schemes that need a second all-reduce round (the
    /// two-scale index share — the Fig 15 "two all-reduce ops" effect).
    fn wire(&self, n: usize, floor_bits: Option<f64>) -> WireCost {
        let f = |bits: f64| -> f64 {
            let b = match floor_bits {
                Some(fl) => bits.max(fl),
                None => bits,
            };
            b / 8.0
        };
        match self {
            Scheme::AllReduceSgd => WireCost { allreduce_bytes: 4.0 * n as f64, rounds: 1 },
            Scheme::Qsgd { bits } => WireCost {
                allreduce_bytes: f(*bits as f64) * n as f64,
                rounds: 1,
            },
            Scheme::QsgdTs { bits_lo, .. } => WireCost {
                // level payload at the small scale + 1-bit scale share
                allreduce_bytes: f(*bits_lo as f64) * n as f64 + f(1.0) * n as f64,
                rounds: 2,
            },
            Scheme::RandK { bits, k } => WireCost {
                allreduce_bytes: f(*bits as f64) * *k as f64,
                rounds: 1,
            },
            Scheme::RandKTs { bits_lo, k, .. } => WireCost {
                allreduce_bytes: (f(*bits_lo as f64) + f(1.0)) * *k as f64,
                rounds: 2,
            },
            Scheme::PowerSgd { rank } => {
                // P (sqrt-ish split) — use the paper's observed ~rank·(d1+d2)
                // with a generic 4:1 aspect: d1+d2 ≈ 2.24·sqrt(n)
                let d = 2.24 * (n as f64).sqrt();
                WireCost { allreduce_bytes: 4.0 * *rank as f64 * d, rounds: 2 }
            }
        }
    }

    /// Encode+decode seconds on the GPU for an n-coordinate gradient.
    fn codec_s(&self, n: usize) -> f64 {
        let nb = 4.0 * n as f64;
        match self {
            Scheme::AllReduceSgd => 0.0,
            Scheme::Qsgd { .. } => nb / QUANTIZE_BYTES_PER_S + nb / REDUCE_BYTES_PER_S,
            Scheme::QsgdTs { .. } => 2.0 * nb / QUANTIZE_BYTES_PER_S + nb / REDUCE_BYTES_PER_S,
            Scheme::RandK { k, .. } => {
                (4.0 * *k as f64) / QUANTIZE_BYTES_PER_S + nb / REDUCE_BYTES_PER_S * 0.1
            }
            Scheme::RandKTs { k, .. } => {
                (8.0 * *k as f64) / QUANTIZE_BYTES_PER_S + nb / REDUCE_BYTES_PER_S * 0.1
            }
            Scheme::PowerSgd { rank } => {
                // two n×rank GEMMs + orthogonalization
                (4.0 * n as f64 * *rank as f64) / POWERSGD_FLOPS * 3.0
            }
        }
    }
}

struct WireCost {
    allreduce_bytes: f64,
    rounds: usize,
}

/// Fraction of a fwd+bwd step spent in the backward pass — the window layer
/// gradients stream out of and bucket communication can hide behind. The
/// standard ~1:2 forward:backward FLOP ratio (each backward layer computes
/// both input and weight gradients) that DDP-style overlap analyses assume.
pub const BACKWARD_FRAC: f64 = 2.0 / 3.0;

/// Completion time of each segment's backward pass inside a backward window
/// of `backward_s` seconds, apportioned by parameter count.
///
/// Backward runs **last layer first**, so segment `i`'s gradient is ready
/// once every segment `j >= i` has been processed:
/// `ready[i] = backward_s * sum(len[i..]) / sum(len)`. The first segment's
/// gradient is therefore ready exactly at `backward_s` (the full backward),
/// the last segment's earliest — the release order the bucketed control
/// plane's overlap scheduler consumes ([`crate::control`]).
pub fn backward_ready_times(seg_lens: &[usize], backward_s: f64) -> Vec<f64> {
    let total: f64 = seg_lens.iter().map(|&l| l as f64).sum();
    if total <= 0.0 {
        return vec![backward_s; seg_lens.len()];
    }
    let mut suffix = 0.0f64;
    let mut ready = vec![0.0f64; seg_lens.len()];
    for i in (0..seg_lens.len()).rev() {
        suffix += seg_lens[i] as f64;
        ready[i] = backward_s * suffix / total;
    }
    ready
}

/// Throughput in images/s for `model` on `net` with `scheme`.
pub fn throughput(model: &ModelProfile, net: &NetConfig, scheme: &Scheme, floor_bits: Option<f64>) -> f64 {
    let wire = scheme.wire(model.params, floor_bits);
    let mut t_comm = net.allreduce_s(wire.allreduce_bytes);
    // extra latency per extra round (the scale-share all-reduce)
    if wire.rounds > 1 {
        t_comm += (wire.rounds - 1) as f64 * net.scalar_allreduce_s();
    }
    let t = model.compute_s + scheme.codec_s(model.params) + t_comm;
    net.workers as f64 * model.batch as f64 / t
}

/// The K used by the paper's sparsified schemes in §6: 10000.
pub const PAPER_K: usize = 10_000;

/// Build the scheme grid of Figures 11–14 for a bit-width.
pub fn paper_schemes(bits: usize) -> Vec<Scheme> {
    vec![
        Scheme::AllReduceSgd,
        Scheme::Qsgd { bits },
        Scheme::QsgdTs { bits_lo: bits, bits_hi: bits + 4 },
        Scheme::RandK { bits, k: PAPER_K },
        Scheme::RandKTs { bits_lo: bits, bits_hi: bits + 4, k: PAPER_K },
    ]
}

/// Sanity accessor used by tests: bits/coordinate the quantizer would claim.
pub fn nominal_bits(bits: usize) -> f64 {
    kernels::bits_for_s(kernels::s_for_bits(bits))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(gbps: f64) -> NetConfig {
        NetConfig::paper_cluster(gbps)
    }

    #[test]
    fn compression_helps_more_on_vgg_than_resnet() {
        // paper §6.6: VGG16 (communication-intensive) gains more
        let net = cluster(1.0);
        for (model, min_gain) in [(ModelProfile::vgg16(), 2.0), (ModelProfile::resnet50(), 1.05)] {
            let base = throughput(&model, &net, &Scheme::AllReduceSgd, None);
            let q2 = throughput(&model, &net, &Scheme::Qsgd { bits: 2 }, None);
            assert!(
                q2 / base > min_gain,
                "{}: gain {} < {min_gain}",
                model.name,
                q2 / base
            );
        }
        let vgg_gain = throughput(&ModelProfile::vgg16(), &net, &Scheme::Qsgd { bits: 2 }, None)
            / throughput(&ModelProfile::vgg16(), &net, &Scheme::AllReduceSgd, None);
        let res_gain =
            throughput(&ModelProfile::resnet50(), &net, &Scheme::Qsgd { bits: 2 }, None)
                / throughput(&ModelProfile::resnet50(), &net, &Scheme::AllReduceSgd, None);
        assert!(vgg_gain > res_gain, "VGG gain {vgg_gain} vs ResNet gain {res_gain}");
    }

    #[test]
    fn throughput_decreases_with_bits() {
        // paper §6.6: "throughput decreases with an increase in bits"
        let net = cluster(1.0);
        let model = ModelProfile::resnet50();
        let t2 = throughput(&model, &net, &Scheme::Qsgd { bits: 2 }, None);
        let t4 = throughput(&model, &net, &Scheme::Qsgd { bits: 4 }, None);
        let t8 = throughput(&model, &net, &Scheme::Qsgd { bits: 8 }, None);
        assert!(t2 > t4 && t4 > t8, "{t2} > {t4} > {t8} violated");
    }

    #[test]
    fn sparsified_wins_at_low_bandwidth() {
        // paper §6.6: under 1 Gbps, sparsified methods significantly win
        let net = cluster(1.0);
        let model = ModelProfile::vgg16();
        let q = throughput(&model, &net, &Scheme::Qsgd { bits: 4 }, None);
        let rk = throughput(&model, &net, &Scheme::RandK { bits: 4, k: PAPER_K }, None);
        assert!(rk > 1.5 * q, "sparsified {rk} should beat dense-quantized {q}");
    }

    #[test]
    fn ten_gbps_shrinks_the_gap() {
        let model = ModelProfile::resnet50();
        let gain_1g = throughput(&model, &cluster(1.0), &Scheme::Qsgd { bits: 4 }, None)
            / throughput(&model, &cluster(1.0), &Scheme::AllReduceSgd, None);
        let gain_10g = throughput(&model, &cluster(10.0), &Scheme::Qsgd { bits: 4 }, None)
            / throughput(&model, &cluster(10.0), &Scheme::AllReduceSgd, None);
        assert!(
            gain_1g > gain_10g,
            "compression gain must shrink with bandwidth: {gain_1g} vs {gain_10g}"
        );
    }

    #[test]
    fn backward_ready_times_release_last_layer_first() {
        let lens = [100usize, 300, 600];
        let ready = backward_ready_times(&lens, 1.0);
        // last segment ready first (0.6), first segment last (exactly 1.0)
        assert!((ready[2] - 0.6).abs() < 1e-12);
        assert!((ready[1] - 0.9).abs() < 1e-12);
        assert_eq!(ready[0], 1.0);
        assert!(ready.windows(2).all(|w| w[0] >= w[1]));
        // degenerate: zero-length segments all release at the window end
        assert_eq!(backward_ready_times(&[0, 0], 0.5), vec![0.5, 0.5]);
    }

    #[test]
    fn wire_floor_hurts_subbyte_schemes() {
        let net = cluster(1.0);
        let model = ModelProfile::vgg16();
        let free = throughput(&model, &net, &Scheme::Qsgd { bits: 2 }, None);
        let floored = throughput(&model, &net, &Scheme::Qsgd { bits: 2 }, Some(8.0));
        assert!(free > floored, "8-bit floor must cost throughput: {free} vs {floored}");
    }
}
