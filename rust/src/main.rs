//! `repro` — CLI for the distributed-quantization reproduction.
//!
//! Subcommands:
//!   train      train one model with one compression method
//!   figures    regenerate a paper figure family (1..15, or "scalability")
//!   perfmodel  print the §6.6 throughput projections (Figures 11-14)
//!   info       show artifact inventory
//!
//! Examples:
//!   repro train --model resnet_lite --method qsgd-mn-4 --steps 200 --workers 4
//!   repro train --model resnet_lite --method qsgd-mn-4 --buckets 8 --bits auto --error-feedback
//!   repro train --model resnet_lite --method qsgd-mn-ts-2-6 --buckets 8 --bits auto
//!   repro train --model vgg_lite --method grandk-mn-ts-4-8 --buckets 8
//!   repro train --model mlp --method qsgd-mn-4 --faults jitter=0.1,seed=7 \
//!       --cohort-policy partial:0.25 --quorum 2
//!   repro train --model mlp --method qsgd-mn-4 --faults loss=0.01,flip=0.001,seed=7 \
//!       --integrity --retries 3 --backoff-s 50e-6
//!   repro train --model mlp --method qsgd-mn-4 --faults poison=1@3 --on-anomaly clip:10
//!   repro train --model mlp --method qsgd-mn-4 --workers 128 --topology 32x4 --schedule hier
//!   repro train --model mlp --method qsgd-mn-4 --workers 16 --topology 4x4 \
//!       --schedule hier --trace results/train.trace.json
//!   repro figures --fig 3 --steps 150
//!   repro perfmodel --floor-bits 8

use anyhow::{bail, Result};

use repro::cli::Args;
use repro::collectives::IntegrityConfig;
use repro::compress::Method;
use repro::control::{AnomalyPolicy, BitsPolicy, CohortPolicy, ControlConfig, ElasticConfig};
use repro::netsim::FaultPlan;
use repro::figures::{self, FigureOpts};
use repro::runtime::Artifacts;
use repro::train::{summary_table, Experiment};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("figures") => cmd_figures(&args),
        Some("perfmodel") => cmd_perfmodel(&args),
        Some("info") => cmd_info(&args),
        Some(other) => bail!("unknown subcommand '{other}' (try train|figures|perfmodel|info)"),
        None => {
            eprintln!("usage: repro <train|figures|perfmodel|info> [options]");
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mlp").to_string();
    let method = Method::parse(args.get_or("method", "qsgd-mn-8"))?;
    let steps: usize = args.parse_or("steps", 100)?;
    let workers: usize = args.parse_or("workers", 4)?;
    let lr0: f64 = args.parse_or("lr", 0.05)?;
    let seed: u64 = args.parse_or("seed", 42)?;
    let out_dir = args.get_or("out-dir", "results").to_string();
    let (gpus_per_node, hier_schedule) = parse_topology(args, workers)?;
    let mut control = parse_control(args)?;
    let elastic = parse_elastic(args, workers)?;
    let integrity = parse_integrity(args)?;
    let on_anomaly = match args.get("on-anomaly") {
        Some(spec) => AnomalyPolicy::parse(spec)?,
        None => AnomalyPolicy::Skip,
    };
    if elastic.is_some() && control.is_none() {
        // the elastic layer runs on the bucketed control plane (the
        // monolithic aggregators are not cohort-aware): default to one
        // bucket, which is bit-identical to the monolithic path
        control = Some(ControlConfig::new(1));
    }
    // `--trace PATH` (PR 9) arms the step flight recorder and writes the
    // trace when the run finishes. The extension picks the format: `.jsonl`
    // emits compact per-step JSON lines; anything else emits Chrome
    // trace-event JSON loadable in chrome://tracing or ui.perfetto.dev
    // (one track per worker plus per-level wire tracks). Multi-method
    // sweeps suffix the sanitized method label before the extension.
    // Render either with `tools/trace_report.py PATH`.
    let trace = args.get("trace").map(std::path::PathBuf::from);
    args.reject_unknown()?;

    let arts = Artifacts::load_default()?;
    let mut exp = Experiment::new("train", &model, vec![method]);
    exp.steps = steps;
    exp.workers = workers;
    exp.lr0 = lr0;
    exp.seed = seed;
    exp.out_dir = out_dir.into();
    exp.gpus_per_node = gpus_per_node;
    exp.hier_schedule = hier_schedule;
    exp.control = control;
    exp.elastic = elastic;
    exp.integrity = integrity;
    exp.on_anomaly = on_anomaly;
    exp.trace = trace;
    let results = exp.run(&arts)?;
    let summaries: Vec<_> = results.into_iter().map(|(_, s)| s).collect();
    println!("{}", summary_table(&summaries));
    Ok(())
}

/// Simulated-wire topology options (PR 8): `--topology NxG` declares `N`
/// nodes of `G` GPUs each (`N*G` must equal `--workers`; e.g. `32x4` for
/// the paper's §6.6 cluster) and `--schedule hier|flat` picks the packed
/// collective schedule — `hier` runs the two-level island-then-leader-ring
/// schedule, `flat` (the default) the single-ring planes of PRs 1-7.
/// `--schedule hier` needs a `--topology` with `G > 1` and `N > 1`;
/// payloads are bit-identical either way, only timing and the per-level
/// wire ledgers differ.
fn parse_topology(args: &Args, workers: usize) -> Result<(usize, bool)> {
    let topo_spec = args.get("topology").map(str::to_string);
    let sched_spec = args.get("schedule").map(str::to_string);
    let gpus_per_node = match topo_spec {
        None => 1,
        Some(spec) => {
            let (n, g) = spec
                .split_once(|c| matches!(c, 'x' | 'X' | '×'))
                .ok_or_else(|| anyhow::anyhow!("--topology wants NxG (e.g. 32x4), got '{spec}'"))?;
            let nodes: usize = n.trim().parse()?;
            let gpus: usize = g.trim().parse()?;
            anyhow::ensure!(nodes >= 1 && gpus >= 1, "--topology needs N >= 1 and G >= 1");
            anyhow::ensure!(
                nodes * gpus == workers,
                "--topology {nodes}x{gpus} describes {} ranks but --workers is {workers}",
                nodes * gpus
            );
            gpus
        }
    };
    let hier = match sched_spec.as_deref() {
        None | Some("flat") => false,
        Some("hier") => {
            anyhow::ensure!(
                gpus_per_node > 1 && workers > gpus_per_node,
                "--schedule hier needs --topology NxG with N > 1 and G > 1 \
                 (got {} GPUs/node over {workers} workers)",
                gpus_per_node
            );
            true
        }
        Some(other) => bail!("unknown --schedule '{other}' (try hier|flat)"),
    };
    Ok((gpus_per_node, hier))
}

/// Bucketed control-plane options: `--buckets N` enables the plane for any
/// all-reduce-compatible quantizer (qsgd-mn-*, qsgd-mn-ts-*, grandk-mn-*,
/// grandk-mn-ts-*; other methods are rejected loudly by
/// `control::build_plane`), `--bits auto|fixed[:N]|perlayer:a,b,...` picks
/// the precision policy (for -ts- methods the chosen width re-anchors the
/// scale set's small scale, gaps preserved), `--error-feedback` turns on
/// per-worker residual memory (dense methods only), `--no-overlap`
/// disables hiding bucket comm behind backward compute.
fn parse_control(args: &Args) -> Result<Option<ControlConfig>> {
    let buckets: usize = args.parse_or("buckets", 0)?;
    let bits_spec = args.get("bits").map(str::to_string);
    let ef = args.flag("error-feedback");
    let no_overlap = args.flag("no-overlap");
    if buckets == 0 {
        anyhow::ensure!(
            bits_spec.is_none() && !ef && !no_overlap,
            "--bits/--error-feedback/--no-overlap need --buckets N"
        );
        return Ok(None);
    }
    let mut cfg = ControlConfig::new(buckets);
    if let Some(spec) = bits_spec {
        cfg.bits = BitsPolicy::parse(&spec)?;
    }
    cfg.error_feedback = ef;
    cfg.overlap = !no_overlap;
    Ok(Some(cfg))
}

/// Elastic-cohort options: `--faults SPEC` injects a deterministic fault
/// plan (`jitter=F,seed=N,leave=W@S,join=W@S,outage=A..B@F,loss=P,flip=P,
/// poison=W@S`, or `none`), `--cohort-policy
/// strict|partial[:FRAC]|periodic[:PERIOD]` picks how the cohort
/// synchronizes under it, `--quorum N` sets the minimum cohort for a
/// synchronizing step (below it the step degrades to local accumulation).
/// The PR 7 data-plane clauses: `loss=P` drops each hop delivery with
/// probability P, `flip=P` corrupts one bit of one packed word instead,
/// `poison=W@S` plants NaN/Inf in worker W's step-S gradient (repeatable).
/// `loss`/`flip` only have observable effect with `--integrity` on — a
/// trusting wire delivers the payload regardless. Any one of the three
/// flags enables the elastic layer; the defaults are strict sync, quorum
/// 1, no faults — bit-identical to a non-elastic run.
fn parse_elastic(args: &Args, workers: usize) -> Result<Option<ElasticConfig>> {
    let faults_spec = args.get("faults").map(str::to_string);
    let policy_spec = args.get("cohort-policy").map(str::to_string);
    let quorum_spec = args.get("quorum").map(str::to_string);
    if faults_spec.is_none() && policy_spec.is_none() && quorum_spec.is_none() {
        return Ok(None);
    }
    let faults = match faults_spec {
        Some(spec) => FaultPlan::parse(&spec)?,
        None => FaultPlan::none(),
    };
    let policy = match policy_spec {
        Some(spec) => CohortPolicy::parse(&spec)?,
        None => CohortPolicy::StrictSync,
    };
    let quorum: usize = match quorum_spec {
        Some(q) => q.parse()?,
        None => 1,
    };
    anyhow::ensure!(
        (1..=workers).contains(&quorum),
        "--quorum {quorum} outside 1..={workers}"
    );
    Ok(Some(ElasticConfig { policy, quorum, faults }))
}

/// Hop-segment integrity options: `--integrity` checksums every packed hop
/// segment (64-bit xor-fold, charged byte-exact) and retransmits
/// corrupted/lost hops, `--retries N` bounds the retransmit attempts per
/// hop (default 3; a peer that exhausts them is escalated into the elastic
/// partial-cohort path), `--backoff-s S` sets the exponential-backoff base
/// (default 50e-6). The knobs without `--integrity` are rejected loudly.
fn parse_integrity(args: &Args) -> Result<Option<IntegrityConfig>> {
    let on = args.flag("integrity");
    let retries_spec = args.get("retries").map(str::to_string);
    let backoff_spec = args.get("backoff-s").map(str::to_string);
    if !on {
        anyhow::ensure!(
            retries_spec.is_none() && backoff_spec.is_none(),
            "--retries/--backoff-s need --integrity"
        );
        return Ok(None);
    }
    let mut cfg = IntegrityConfig::default();
    if let Some(r) = retries_spec {
        cfg.max_retries = r.parse()?;
    }
    if let Some(b) = backoff_spec {
        cfg.backoff_base_s = b.parse()?;
        anyhow::ensure!(
            cfg.backoff_base_s.is_finite() && cfg.backoff_base_s >= 0.0,
            "--backoff-s must be finite and >= 0"
        );
    }
    Ok(Some(cfg))
}

fn cmd_figures(args: &Args) -> Result<()> {
    let fig = args.get_or("fig", "all").to_string();
    let mut opts = FigureOpts::default();
    opts.steps = args.parse_or("steps", 200)?;
    opts.workers = args.parse_or("workers", 4)?;
    opts.out_dir = args.get_or("out-dir", "results").to_string().into();
    if let Some(models) = args.get("models") {
        opts.models = models.split(',').map(|s| s.trim().to_string()).collect();
    }
    opts.quiet = args.flag("quiet");
    args.reject_unknown()?;

    let needs_artifacts = !matches!(fig.as_str(), "11" | "12" | "13" | "14" | "scalability");
    let arts = if needs_artifacts { Some(Artifacts::load_default()?) } else { None };

    match fig.as_str() {
        "1" | "2" | "1_2" => figures::fig1_2(arts.as_ref().unwrap(), &opts)?,
        "3" | "4" | "3_4" => figures::fig3_4(arts.as_ref().unwrap(), &opts)?,
        "5" | "6" | "5_6" => figures::fig5_6(arts.as_ref().unwrap(), &opts)?,
        "7" | "8" | "7_8" => figures::fig7_8(arts.as_ref().unwrap(), &opts)?,
        "9" | "10" | "9_10" => figures::fig9_10(arts.as_ref().unwrap(), &opts)?,
        "11" | "12" | "13" | "14" => println!("{}", figures::fig11_14(None)),
        "15" => println!("{}", figures::fig15(arts.as_ref().unwrap(), &opts)?),
        "scalability" => println!("{}", figures::scalability_table()),
        "all" => {
            let a = arts.as_ref().unwrap();
            figures::fig1_2(a, &opts)?;
            figures::fig3_4(a, &opts)?;
            figures::fig5_6(a, &opts)?;
            figures::fig7_8(a, &opts)?;
            figures::fig9_10(a, &opts)?;
            println!("{}", figures::fig11_14(None));
            println!("{}", figures::fig15(a, &opts)?);
            println!("{}", figures::scalability_table());
        }
        other => bail!("unknown figure '{other}'"),
    }
    Ok(())
}

fn cmd_perfmodel(args: &Args) -> Result<()> {
    let floor: Option<f64> = match args.get("floor-bits") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    args.reject_unknown()?;
    println!("{}", figures::fig11_14(floor));
    println!("{}", figures::scalability_table());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.reject_unknown()?;
    let arts = Artifacts::load_default()?;
    println!("artifacts dir: {:?}", arts.dir);
    println!("\nmodels:");
    for (name, m) in &arts.models {
        println!(
            "  {name:14} params={:>10}  input={:7} batch={}  steps for M={:?}",
            m.param_count,
            m.input_kind,
            m.batch,
            m.steps.keys().collect::<Vec<_>>()
        );
    }
    println!("\nkernels:");
    for (name, k) in &arts.kernels {
        println!("  {name:22} n={}  file={}", k.n, k.file);
    }
    Ok(())
}
