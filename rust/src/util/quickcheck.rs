//! Tiny property-based testing harness (the vendored set has no `proptest`).
//!
//! Usage:
//! ```ignore
//! check("ring allreduce == naive sum", 200, |g| {
//!     let n = g.usize_in(1, 4096);
//!     let v = g.vec_f32(n, -10.0, 10.0);
//!     /* ... */
//!     ensure(cond, "message")
//! });
//! ```
//! Each case runs with a seed derived from (global seed, case index); on
//! failure the harness panics with the failing seed so the case can be
//! replayed with `QC_SEED=<seed> QC_CASES=1`. No shrinking — generators are
//! encouraged to start small (case index scales sizes).

use super::rng::Rng;

/// Per-case generator handle.
pub struct Gen {
    rng: Rng,
    /// 0.0 at the first case, 1.0 at the last — generators can use this to
    /// grow sizes over the run (cheap stand-in for shrinking).
    pub progress: f64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        lo + self.rng.next_below((hi_inclusive - lo + 1) as u64) as usize
    }

    /// Size that grows with `progress` (small early cases catch trivial bugs
    /// fast, large late cases stress invariants).
    pub fn size_scaled(&mut self, lo: usize, hi: usize) -> usize {
        let hi_now = lo + ((hi - lo) as f64 * self.progress.max(0.05)) as usize;
        self.usize_in(lo, hi_now.max(lo))
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Gaussian vector — the natural gradient-like input.
    pub fn vec_normal(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal_f32(&mut v, sigma);
        v
    }

    /// Vector with adversarial structure: mixes zeros, tiny, huge, and
    /// denormal-ish values — edge-case fodder for quantizers.
    pub fn vec_adversarial(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| match self.usize_in(0, 5) {
                0 => 0.0,
                1 => self.f32_in(-1e-30, 1e-30),
                2 => self.f32_in(-1e6, 1e6),
                3 => self.f32_in(-1.0, 1.0),
                4 => -0.0,
                _ => self.f32_in(-1e-3, 1e-3),
            })
            .collect()
    }
}

/// Property outcome helper.
pub fn ensure(cond: bool, msg: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, msg: &str) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

pub fn ensure_slice_close(a: &[f32], b: &[f32], tol: f32, msg: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{msg}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("{msg}: idx {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Run `cases` seeded cases of the property `f`. Panics (test failure) with
/// a replayable seed on the first failing case.
pub fn check<F>(name: &str, cases: usize, f: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed: u64 = std::env::var("QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let cases = std::env::var("QC_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    let root = Rng::new(base_seed);
    for case in 0..cases {
        let mut g = Gen {
            rng: root.derive(&[0x9C, case as u64]),
            progress: case as f64 / cases.max(1) as f64,
        };
        if let Err(msg) = f(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases}: {msg}\n\
                 replay with QC_SEED={base_seed} (case index {case})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("tautology", 50, |g| {
            let n = g.usize_in(0, 10);
            ensure(n <= 10, "bounded")
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn failing_property_panics_with_seed() {
        check("falsum", 10, |g| {
            let x = g.f32_in(0.0, 1.0);
            ensure(x < 0.0, "impossible")
        });
    }

    #[test]
    fn adversarial_vec_has_zeros_and_magnitude_spread() {
        check("adversarial composition", 5, |g| {
            let v = g.vec_adversarial(1000);
            let zeros = v.iter().filter(|x| **x == 0.0).count();
            ensure(zeros > 0, "contains zeros")?;
            let max = v.iter().fold(0.0f32, |a, b| a.max(b.abs()));
            ensure(max > 1.0, "contains large values")
        });
    }
}
