//! Deterministic PRNG substrate: xoshiro256++ with SplitMix64 seeding.
//!
//! The vendored crate set has no `rand`, so the whole repo draws randomness
//! from this module. Streams are derived hierarchically with
//! [`Rng::derive`] so that (run, worker, step) tuples map to independent,
//! reproducible streams — the determinism contract of DESIGN.md §5.

/// SplitMix64: seeds the xoshiro state and derives sub-streams.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal
    spare_normal: Option<f64>,
}

impl Rng {
    /// Construct from a 64-bit seed (expanded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream keyed by a label tuple, e.g.
    /// `rng.derive(&[worker as u64, step as u64])`.
    pub fn derive(&self, labels: &[u64]) -> Rng {
        let mut h = self.s[0] ^ 0xD6E8FEB86659FD93;
        for &l in labels {
            let mut sm = h ^ l.wrapping_mul(0xA24BAED4963EE407);
            h = splitmix64(&mut sm);
        }
        Rng::new(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 24-bit mantissa resolution (f32-exact).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire rejection-free for our sizes).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply-shift; bias < 2^-64, irrelevant for simulation.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (caches the second value).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn next_normal_f32(&mut self) -> f32 {
        self.next_normal() as f32
    }

    /// Fill a slice with uniform [0,1) f32s.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_f32();
        }
    }

    /// Fill a slice with N(0, sigma^2) f32s.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.next_normal_f32() * sigma;
        }
    }

    /// Sample `k` distinct indices from `[0, n)` — Floyd's algorithm, then
    /// sorted for cache-friendly gathers. Used by GlobalRandK (all workers
    /// call this with the SAME derived stream => identical index sets).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below((j + 1) as u64) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out.sort_unstable();
        out
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_independent_streams() {
        let root = Rng::new(1);
        let mut a = root.derive(&[0, 5]);
        let mut b = root.derive(&[1, 5]);
        let mut same = 0;
        for _ in 0..64 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        assert_eq!(same, 0, "derived streams must differ");
        // and deriving with the same labels reproduces the stream
        let mut a2 = root.derive(&[0, 5]);
        let mut a1 = root.derive(&[0, 5]);
        for _ in 0..64 {
            assert_eq!(a1.next_u64(), a2.next_u64());
        }
    }

    #[test]
    fn uniform_f32_in_range_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0f64;
        const N: usize = 100_000;
        for _ in 0..N {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        const N: usize = 200_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..N {
            let z = r.next_normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / N as f64;
        let var = s2 / N as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(5);
        let idx = r.sample_distinct(10_000, 500);
        assert_eq!(idx.len(), 500);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 500, "indices must be distinct");
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "sorted");
        assert!(*idx.iter().max().unwrap() < 10_000);
    }

    #[test]
    fn sample_distinct_full_range() {
        let mut r = Rng::new(9);
        let idx = r.sample_distinct(16, 16);
        assert_eq!(idx, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::new(13);
        for n in [1u64, 2, 3, 17, 1 << 40] {
            for _ in 0..100 {
                assert!(r.next_below(n) < n);
            }
        }
    }
}
