//! Cross-cutting substrates: PRNG, JSON, property testing, thread helpers.
//!
//! Everything here exists because the vendored crate set ships only the
//! `xla` crate and its build dependencies — no rand/serde/rayon/proptest.
//! Each submodule is a from-scratch implementation sized to this repo's
//! needs, with its own unit tests.

pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod simd;
pub mod threads;

/// Wall-clock stopwatch with lap support — metrics plumbing.
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: std::time::Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since start, then reset.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = std::time::Instant::now();
        e
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(bytes: f64) -> String {
    if bytes >= 1e9 {
        format!("{:.2} GB", bytes / 1e9)
    } else if bytes >= 1e6 {
        format!("{:.2} MB", bytes / 1e6)
    } else if bytes >= 1e3 {
        format!("{:.2} KB", bytes / 1e3)
    } else {
        format!("{bytes:.0} B")
    }
}
