//! Runtime-dispatched SIMD kernels for the compress hot loops.
//!
//! The scalar loops in `compress/kernels.rs` and `compress/bitpack.rs` are
//! the repo's single-core ceiling (ROADMAP direction 1). This module holds
//! the vector bodies behind a tiny dispatch layer: AVX2 on x86_64 (runtime
//! `is_x86_feature_detected!`), NEON on aarch64 (baseline feature), and a
//! scalar fallback that is *always* compiled and stays the property-pinned
//! oracle. No new dependencies — everything is `std::arch`.
//!
//! ## The prefix contract
//!
//! Every kernel here processes a *prefix* of its input — a multiple of the
//! vector lane width, possibly shortened by buffer-bounds guards — and
//! returns the number of elements it handled. The caller finishes the tail
//! with the pinned scalar reference loop. `Backend::Scalar` always returns
//! 0 (the caller's scalar loop does everything), so forcing the fallback is
//! just a matter of handing kernels `Backend::Scalar` — which is exactly
//! what `REPRO_FORCE_SCALAR=1` makes [`active`] do. Tests and benches
//! instead pass an explicit [`Backend`] from [`available`] so both paths
//! are exercised in one process.
//!
//! ## The bit-exactness contract (DESIGN.md §5, "SIMD dispatch & tail
//! contract")
//!
//! SIMD output must be bit-identical to the scalar reference. That holds
//! because every float op the quantizer kernels use is exactly defined
//! per-lane by IEEE 754 and matched op-for-op, in the same order, by the
//! vector body: `|v|` is a sign-bit mask (scalar `f32::abs` is the same
//! bit-clear), `/`, `*`, `floor`, `-` and ordered `<`/`<=` compares are all
//! correctly rounded single operations, and the `1{u < p}` select is a mask
//! of exact `1.0`s. Rust never contracts `a*b + c` into an FMA, so the
//! scalar reference has no hidden double-rounding the vector body would
//! miss. Integer kernels (pack/unpack/add) are exact by construction.
//!
//! ## Saturation contract
//!
//! The SIMD paths never saturate silently: level→code conversion funnels
//! through the same loud release-mode range asserts as the scalar path
//! ([`biased_codes_i32`] accumulates a lane-wise violation mask per block
//! and panics *before* the caller publishes any packed word).

use std::sync::OnceLock;

/// A vector backend. `Scalar` is always available; the arch variants exist
/// on every platform (so `match`es stay portable) but their kernels return
/// 0 — "I processed nothing" — when invoked off their native arch.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Backend {
    Scalar,
    Avx2,
    Neon,
}

impl Backend {
    /// Short label for bench/report rows.
    pub fn label(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// f32 lanes per vector step (1 = scalar).
    pub fn lanes_f32(self) -> usize {
        match self {
            Backend::Scalar => 1,
            Backend::Avx2 => 8,
            Backend::Neon => 4,
        }
    }
}

fn detect() -> Backend {
    // Forced-scalar escape hatch: the CI fallback job and any machine where
    // the vector path misbehaves can pin the pinned-oracle path at runtime.
    if std::env::var_os("REPRO_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0") {
        return Backend::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Backend::Neon;
    }
    #[allow(unreachable_code)]
    Backend::Scalar
}

/// The process-wide active backend (detected once, `REPRO_FORCE_SCALAR`
/// wins). Hot-path entries in kernels/bitpack call this per buffer, not per
/// element.
pub fn active() -> Backend {
    static ACTIVE: OnceLock<Backend> = OnceLock::new();
    *ACTIVE.get_or_init(detect)
}

/// Every backend runnable on this machine (Scalar first). Tests and benches
/// iterate this to pin SIMD-vs-scalar equivalence and measure the multiple.
pub fn available() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            v.push(Backend::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        v.push(Backend::Neon);
    }
    v
}

// ---------------------------------------------------------------------------
// Quantizer kernels (f32 lanes)
// ---------------------------------------------------------------------------

/// QSGD level kernel over a lane-multiple prefix: `out[i]` gets the signed
/// f32 level of `v[i]` (the exact op sequence of `kernels::qsgd_level`).
/// Returns the prefix length processed (0 for `Scalar` / off-arch).
pub fn qsgd_levels(bk: Backend, v: &[f32], safe_w: f32, u: &[f32], s: f32, out: &mut [f32]) -> usize {
    debug_assert_eq!(v.len(), u.len());
    debug_assert!(out.len() >= v.len());
    match bk {
        Backend::Scalar => 0,
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: constructing Backend::Avx2 requires a positive
            // is_x86_feature_detected!("avx2") (see available()/detect()).
            unsafe {
                return avx2::qsgd_levels(v, safe_w, u, s, out);
            }
            #[allow(unreachable_code)]
            0
        }
        Backend::Neon => {
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is a baseline feature of aarch64.
            unsafe {
                return neon::qsgd_levels(v, safe_w, u, s, out);
            }
            #[allow(unreachable_code)]
            0
        }
    }
}

/// Multi-scale level kernel: per-lane branchless `ScaleTable::select` chain
/// (sum of `(idx==j)·sel[j]`, same accumulation order as the scalar loop)
/// followed by the QSGD level body at the selected scale. `sel` is the
/// padded table (`0.0` in padding lanes). Returns the prefix processed.
pub fn multiscale_levels(
    bk: Backend,
    v: &[f32],
    safe_w: f32,
    u: &[f32],
    idx: &[u8],
    sel: &[f32; 8],
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(v.len(), u.len());
    debug_assert_eq!(v.len(), idx.len());
    debug_assert!(out.len() >= v.len());
    match bk {
        Backend::Scalar => 0,
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: see qsgd_levels.
            unsafe {
                return avx2::multiscale_levels(v, safe_w, u, idx, sel, out);
            }
            #[allow(unreachable_code)]
            0
        }
        Backend::Neon => {
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is baseline on aarch64.
            unsafe {
                return neon::multiscale_levels(v, safe_w, u, idx, sel, out);
            }
            #[allow(unreachable_code)]
            0
        }
    }
}

/// eq. (10) scale-index kernel: `out[i] = (count of qualifying scales).max(1)
/// - 1` with the qualifying test `qual[j]·|v| <= thresh` (padding lanes hold
/// `+inf`, which never qualifies — `inf·0 = NaN` compares false, exactly as
/// in the scalar loop). Returns the prefix processed.
pub fn scale_index(bk: Backend, v: &[f32], thresh: f32, qual: &[f32; 8], out: &mut [u8]) -> usize {
    debug_assert!(out.len() >= v.len());
    match bk {
        Backend::Scalar => 0,
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: see qsgd_levels.
            unsafe {
                return avx2::scale_index(v, thresh, qual, out);
            }
            #[allow(unreachable_code)]
            0
        }
        Backend::Neon => {
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is baseline on aarch64.
            unsafe {
                return neon::scale_index(v, thresh, qual, out);
            }
            #[allow(unreachable_code)]
            0
        }
    }
}

// ---------------------------------------------------------------------------
// Bit-plane kernels (u64 lanes) — AVX2 only this PR; NEON falls back to the
// scalar staging loops (documented in DESIGN.md).
// ---------------------------------------------------------------------------

/// Gather-based field extraction: fills `out[k]` with the `bits`-wide code
/// at bit `start_bit + k*bits` of `words`. Arbitrary (unaligned) offsets and
/// widths up to 32 bits: each field is read as one unaligned 8-byte load at
/// `byte_off = bit/8`, shifted right by `bit%8` and masked — valid because
/// `bit%8 + bits <= 7 + 32 < 64`. The prefix stops early (scalar tail takes
/// over) when a field's 8-byte window would run past the buffer.
pub fn unpack_fields(bk: Backend, words: &[u64], start_bit: usize, bits: u32, out: &mut [u64]) -> usize {
    debug_assert!((2..=32).contains(&bits));
    match bk {
        Backend::Scalar | Backend::Neon => 0,
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: see qsgd_levels; in-bounds gather windows are enforced
            // by the n_safe guard inside.
            unsafe {
                return avx2::unpack_fields(words, start_bit, bits, out);
            }
            #[allow(unreachable_code)]
            0
        }
    }
}

/// Aligned-width pack: for `64 % bits == 0` and `per = 64/bits >= 4`, builds
/// `out[w]` from codes `[w*per, (w+1)*per)` via variable-shift + OR-reduce.
/// Returns the number of *whole words* built (codes consumed = words·per);
/// the caller packs the remaining codes with the scalar staging loop.
pub fn pack_aligned_words(bk: Backend, codes: &[u64], bits: u32, out: &mut [u64]) -> usize {
    debug_assert!(64 % bits == 0 && 64 / bits >= 4);
    match bk {
        Backend::Scalar | Backend::Neon => 0,
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: see qsgd_levels.
            unsafe {
                return avx2::pack_aligned_words(codes, bits, out);
            }
            #[allow(unreachable_code)]
            0
        }
    }
}

/// Biased-code materialization for the packed-resident encode: `out[i] =
/// (levels[i] as i64 + bias) as u64` over a lane-multiple prefix, with a
/// lane-wise range check accumulated per block — any code outside
/// `[0, max_code]` panics *before* the caller packs a single word (the SIMD
/// side of the satellite-1 "no silent saturation" contract).
pub fn biased_codes_i32(bk: Backend, levels: &[i32], bias: i64, max_code: u64, out: &mut [u64]) -> usize {
    debug_assert!(out.len() >= levels.len());
    match bk {
        Backend::Scalar => 0,
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: see qsgd_levels.
            unsafe {
                return avx2::biased_codes_i32(levels, bias, max_code, out);
            }
            #[allow(unreachable_code)]
            0
        }
        Backend::Neon => {
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is baseline on aarch64.
            unsafe {
                return neon::biased_codes_i32(levels, bias, max_code, out);
            }
            #[allow(unreachable_code)]
            0
        }
    }
}

/// Vectorized add-with-carry over full resident words (the ring-hop reduce
/// kernel's core). Processes a lane-multiple prefix of `dst[i] += src[i] +
/// carry_chain`, returns `(words_processed, carry_out_of_prefix)`.
///
/// Sound because under the carry-safety condition of `packed_sum_bits`
/// (every per-field sum < 2^bits) the carry OUT of a word is independent of
/// the carry IN: a carry-in can only ripple within the field straddling the
/// word's low boundary, whose in-word part has headroom, so it never reaches
/// bit 63. Each lane therefore computes its own carry-out from `dst+src`
/// alone, and the carry-ins are applied as a lane-shifted +1 afterwards —
/// breaking the loop-carried dependency the scalar adc chain serializes on.
pub fn add_words(bk: Backend, dst: &mut [u64], src: &[u64], carry_in: u64) -> (usize, u64) {
    debug_assert!(src.len() >= dst.len());
    debug_assert!(carry_in <= 1);
    match bk {
        Backend::Scalar | Backend::Neon => (0, carry_in),
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: see qsgd_levels.
            unsafe {
                return avx2::add_words(dst, src, carry_in);
            }
            #[allow(unreachable_code)]
            (0, carry_in)
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 bodies
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub unsafe fn qsgd_levels(v: &[f32], safe_w: f32, u: &[f32], s: f32, out: &mut [f32]) -> usize {
        let n = v.len() & !7;
        let w = _mm256_set1_ps(safe_w);
        let sv = _mm256_set1_ps(s);
        let one = _mm256_set1_ps(1.0);
        let zero = _mm256_setzero_ps();
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let mut i = 0usize;
        while i < n {
            let x = _mm256_loadu_ps(v.as_ptr().add(i));
            let uu = _mm256_loadu_ps(u.as_ptr().add(i));
            // exact scalar op order: a = |v|/w; scaled = a*s; l = floor;
            // p = scaled - l; level = l + 1{u < p}; sign-select.
            let a = _mm256_div_ps(_mm256_and_ps(x, absmask), w);
            let scaled = _mm256_mul_ps(a, sv);
            let l = _mm256_floor_ps(scaled);
            let p = _mm256_sub_ps(scaled, l);
            let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(uu, p);
            let level = _mm256_add_ps(l, _mm256_and_ps(lt, one));
            let pos = _mm256_and_ps(_mm256_cmp_ps::<_CMP_GT_OQ>(x, zero), one);
            let neg = _mm256_and_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(x, zero), one);
            let sg = _mm256_sub_ps(pos, neg);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(sg, level));
            i += 8;
        }
        n
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn multiscale_levels(
        v: &[f32],
        safe_w: f32,
        u: &[f32],
        idx: &[u8],
        sel: &[f32; 8],
        out: &mut [f32],
    ) -> usize {
        let n = v.len() & !7;
        let w = _mm256_set1_ps(safe_w);
        let one = _mm256_set1_ps(1.0);
        let zero = _mm256_setzero_ps();
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let sel_v: [__m256; 8] = [
            _mm256_set1_ps(sel[0]),
            _mm256_set1_ps(sel[1]),
            _mm256_set1_ps(sel[2]),
            _mm256_set1_ps(sel[3]),
            _mm256_set1_ps(sel[4]),
            _mm256_set1_ps(sel[5]),
            _mm256_set1_ps(sel[6]),
            _mm256_set1_ps(sel[7]),
        ];
        let mut i = 0usize;
        while i < n {
            // widen 8 u8 indices to 8 i32 lanes
            let id = _mm256_cvtepu8_epi32(_mm_loadl_epi64(idx.as_ptr().add(i) as *const __m128i));
            // branchless select chain, same j order and accumulation as the
            // scalar loop: all terms but (at most) one are +0.0.
            let mut s_eff = _mm256_setzero_ps();
            for (j, sj) in sel_v.iter().enumerate() {
                let eq = _mm256_castsi256_ps(_mm256_cmpeq_epi32(id, _mm256_set1_epi32(j as i32)));
                s_eff = _mm256_add_ps(s_eff, _mm256_and_ps(eq, *sj));
            }
            let x = _mm256_loadu_ps(v.as_ptr().add(i));
            let uu = _mm256_loadu_ps(u.as_ptr().add(i));
            let a = _mm256_div_ps(_mm256_and_ps(x, absmask), w);
            let scaled = _mm256_mul_ps(a, s_eff);
            let l = _mm256_floor_ps(scaled);
            let p = _mm256_sub_ps(scaled, l);
            let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(uu, p);
            let level = _mm256_add_ps(l, _mm256_and_ps(lt, one));
            let pos = _mm256_and_ps(_mm256_cmp_ps::<_CMP_GT_OQ>(x, zero), one);
            let neg = _mm256_and_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(x, zero), one);
            let sg = _mm256_sub_ps(pos, neg);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(sg, level));
            i += 8;
        }
        n
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_index(v: &[f32], thresh: f32, qual: &[f32; 8], out: &mut [u8]) -> usize {
        let n = v.len() & !7;
        let thr = _mm256_set1_ps(thresh);
        let one = _mm256_set1_epi32(1);
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let qual_v: [__m256; 8] = [
            _mm256_set1_ps(qual[0]),
            _mm256_set1_ps(qual[1]),
            _mm256_set1_ps(qual[2]),
            _mm256_set1_ps(qual[3]),
            _mm256_set1_ps(qual[4]),
            _mm256_set1_ps(qual[5]),
            _mm256_set1_ps(qual[6]),
            _mm256_set1_ps(qual[7]),
        ];
        let mut lanes = [0i32; 8];
        let mut i = 0usize;
        while i < n {
            let av = _mm256_and_ps(_mm256_loadu_ps(v.as_ptr().add(i)), absmask);
            // count += 1 per qualifying scale: subtract the all-ones mask.
            let mut count = _mm256_setzero_si256();
            for qj in qual_v.iter() {
                let le = _mm256_cmp_ps::<_CMP_LE_OQ>(_mm256_mul_ps(*qj, av), thr);
                count = _mm256_sub_epi32(count, _mm256_castps_si256(le));
            }
            let sel = _mm256_sub_epi32(_mm256_max_epi32(count, one), one);
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, sel);
            for (k, &c) in lanes.iter().enumerate() {
                *out.get_unchecked_mut(i + k) = c as u8;
            }
            i += 8;
        }
        n
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_fields(words: &[u64], start_bit: usize, bits: u32, out: &mut [u64]) -> usize {
        let total_bits = words.len() * 64;
        // every gathered 8-byte window [bit/8, bit/8 + 8) must stay inside
        // the buffer; bit <= total_bits - 64 is a (conservative) sufficient
        // condition since byte_off*8 <= bit.
        let max_gather_bit = match total_bits.checked_sub(64) {
            Some(m) => m,
            None => return 0,
        };
        if start_bit > max_gather_bit {
            return 0;
        }
        let n_safe = (max_gather_bit - start_bit) / bits as usize + 1;
        let n = out.len().min(n_safe) & !3;
        if n == 0 {
            return 0;
        }
        let base = words.as_ptr() as *const i64;
        let mask = _mm256_set1_epi64x(((1u64 << bits) - 1) as i64);
        let step = _mm256_set1_epi64x(4 * bits as i64);
        let seven = _mm256_set1_epi64x(7);
        let b = bits as usize;
        let mut bitpos = _mm256_set_epi64x(
            (start_bit + 3 * b) as i64,
            (start_bit + 2 * b) as i64,
            (start_bit + b) as i64,
            start_bit as i64,
        );
        let mut i = 0usize;
        while i < n {
            let byte_off = _mm256_srli_epi64::<3>(bitpos);
            let sh = _mm256_and_si256(bitpos, seven);
            let raw = _mm256_i64gather_epi64::<1>(base, byte_off);
            let val = _mm256_and_si256(_mm256_srlv_epi64(raw, sh), mask);
            _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, val);
            bitpos = _mm256_add_epi64(bitpos, step);
            i += 4;
        }
        n
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn pack_aligned_words(codes: &[u64], bits: u32, out: &mut [u64]) -> usize {
        let per = (64 / bits) as usize;
        let nw = (codes.len() / per).min(out.len());
        let base_shift =
            _mm256_set_epi64x(3 * bits as i64, 2 * bits as i64, bits as i64, 0);
        let step = _mm256_set1_epi64x(4 * bits as i64);
        for w in 0..nw {
            let mut acc = _mm256_setzero_si256();
            let mut sh = base_shift;
            let mut c = w * per;
            let end = c + per;
            while c < end {
                let cv = _mm256_loadu_si256(codes.as_ptr().add(c) as *const __m256i);
                acc = _mm256_or_si256(acc, _mm256_sllv_epi64(cv, sh));
                sh = _mm256_add_epi64(sh, step);
                c += 4;
            }
            // horizontal OR of the 4 lanes
            let hi = _mm256_extracti128_si256::<1>(acc);
            let lo = _mm256_castsi256_si128(acc);
            let x = _mm_or_si128(lo, hi);
            let y = _mm_or_si128(x, _mm_unpackhi_epi64(x, x));
            *out.get_unchecked_mut(w) = _mm_cvtsi128_si64(y) as u64;
        }
        nw
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn biased_codes_i32(levels: &[i32], bias: i64, max_code: u64, out: &mut [u64]) -> usize {
        let n = levels.len() & !3;
        let b = _mm256_set1_epi64x(bias);
        let zero = _mm256_setzero_si256();
        let maxv = _mm256_set1_epi64x(max_code as i64);
        let mut viol = _mm256_setzero_si256();
        let mut i = 0usize;
        while i < n {
            let l32 = _mm_loadu_si128(levels.as_ptr().add(i) as *const __m128i);
            let code = _mm256_add_epi64(_mm256_cvtepi32_epi64(l32), b);
            viol = _mm256_or_si256(viol, _mm256_cmpgt_epi64(zero, code));
            viol = _mm256_or_si256(viol, _mm256_cmpgt_epi64(code, maxv));
            _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, code);
            i += 4;
        }
        // loud in release, before any word is packed from this block
        assert!(
            _mm256_movemask_epi8(viol) == 0,
            "biased code out of range (level overflows its field) — corrupt level buffer"
        );
        n
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_words(dst: &mut [u64], src: &[u64], carry_in: u64) -> (usize, u64) {
        let n = dst.len().min(src.len()) & !3;
        if n == 0 {
            return (0, carry_in);
        }
        let sign = _mm256_set1_epi64x(i64::MIN);
        let mut carry = carry_in;
        let mut i = 0usize;
        while i < n {
            let d = _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i);
            let v = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let s = _mm256_add_epi64(d, v);
            // per-lane unsigned carry-out of d+v:  s <u v  <=>  signed
            // compare after flipping the sign bits. -1 where a carry exits.
            let cmask = _mm256_cmpgt_epi64(_mm256_xor_si256(v, sign), _mm256_xor_si256(s, sign));
            // carry-in to lane k is lane k-1's carry-out; lane 0 takes the
            // running chain carry. permute 0x90 -> lanes [0,0,1,2], then
            // blend the true chain carry into lane 0.
            let shifted = _mm256_permute4x64_epi64::<0x90>(cmask);
            let cin = _mm256_set_epi64x(0, 0, 0, if carry != 0 { -1 } else { 0 });
            let shifted = _mm256_blend_epi32::<0b0000_0011>(shifted, cin);
            // subtracting the -1 mask adds the carry; cannot overflow a lane
            // (carry-independence: the straddling field has headroom).
            let r = _mm256_sub_epi64(s, shifted);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, r);
            carry = (_mm256_extract_epi64::<3>(cmask) as u64) & 1;
            i += 4;
        }
        (n, carry)
    }
}

// ---------------------------------------------------------------------------
// NEON bodies (aarch64). The f32 quantizer kernels are 4-wide; the bit-plane
// kernels fall back to the scalar staging loops this PR (the dispatch layer
// returns 0 for them above).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn qsgd_levels(v: &[f32], safe_w: f32, u: &[f32], s: f32, out: &mut [f32]) -> usize {
        let n = v.len() & !3;
        let w = vdupq_n_f32(safe_w);
        let sv = vdupq_n_f32(s);
        let one = vdupq_n_f32(1.0);
        let zero = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i < n {
            let x = vld1q_f32(v.as_ptr().add(i));
            let uu = vld1q_f32(u.as_ptr().add(i));
            let a = vdivq_f32(vabsq_f32(x), w);
            let scaled = vmulq_f32(a, sv);
            let l = vrndmq_f32(scaled); // floor (round toward -inf)
            let p = vsubq_f32(scaled, l);
            let level = vaddq_f32(l, vbslq_f32(vcltq_f32(uu, p), one, zero));
            let pos = vbslq_f32(vcgtq_f32(x, zero), one, zero);
            let neg = vbslq_f32(vcltq_f32(x, zero), one, zero);
            let sg = vsubq_f32(pos, neg);
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(sg, level));
            i += 4;
        }
        n
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn multiscale_levels(
        v: &[f32],
        safe_w: f32,
        u: &[f32],
        idx: &[u8],
        sel: &[f32; 8],
        out: &mut [f32],
    ) -> usize {
        let n = v.len() & !3;
        let w = vdupq_n_f32(safe_w);
        let one = vdupq_n_f32(1.0);
        let zero = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i < n {
            let id_arr = [
                idx[i] as u32,
                idx[i + 1] as u32,
                idx[i + 2] as u32,
                idx[i + 3] as u32,
            ];
            let id = vld1q_u32(id_arr.as_ptr());
            let mut s_eff = vdupq_n_f32(0.0);
            for (j, &sj) in sel.iter().enumerate() {
                let eq = vceqq_u32(id, vdupq_n_u32(j as u32));
                s_eff = vaddq_f32(s_eff, vbslq_f32(eq, vdupq_n_f32(sj), zero));
            }
            let x = vld1q_f32(v.as_ptr().add(i));
            let uu = vld1q_f32(u.as_ptr().add(i));
            let a = vdivq_f32(vabsq_f32(x), w);
            let scaled = vmulq_f32(a, s_eff);
            let l = vrndmq_f32(scaled);
            let p = vsubq_f32(scaled, l);
            let level = vaddq_f32(l, vbslq_f32(vcltq_f32(uu, p), one, zero));
            let pos = vbslq_f32(vcgtq_f32(x, zero), one, zero);
            let neg = vbslq_f32(vcltq_f32(x, zero), one, zero);
            let sg = vsubq_f32(pos, neg);
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(sg, level));
            i += 4;
        }
        n
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale_index(v: &[f32], thresh: f32, qual: &[f32; 8], out: &mut [u8]) -> usize {
        let n = v.len() & !3;
        let thr = vdupq_n_f32(thresh);
        let one = vdupq_n_u32(1);
        let mut lanes = [0u32; 4];
        let mut i = 0usize;
        while i < n {
            let av = vabsq_f32(vld1q_f32(v.as_ptr().add(i)));
            let mut count = vdupq_n_u32(0);
            for &qj in qual.iter() {
                let le = vcleq_f32(vmulq_f32(vdupq_n_f32(qj), av), thr);
                count = vsubq_u32(count, le); // mask is all-ones = -1
            }
            let sel = vsubq_u32(vmaxq_u32(count, one), one);
            vst1q_u32(lanes.as_mut_ptr(), sel);
            for (k, &c) in lanes.iter().enumerate() {
                *out.get_unchecked_mut(i + k) = c as u8;
            }
            i += 4;
        }
        n
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn biased_codes_i32(levels: &[i32], bias: i64, max_code: u64, out: &mut [u64]) -> usize {
        let n = levels.len() & !3;
        let b = vdupq_n_s64(bias);
        let maxv = vdupq_n_s64(max_code as i64);
        let zero = vdupq_n_s64(0);
        let mut viol = vdupq_n_u64(0);
        let mut i = 0usize;
        while i < n {
            let l32 = vld1q_s32(levels.as_ptr().add(i));
            let lo = vaddq_s64(vmovl_s32(vget_low_s32(l32)), b);
            let hi = vaddq_s64(vmovl_s32(vget_high_s32(l32)), b);
            viol = vorrq_u64(viol, vcgtq_s64(zero, lo));
            viol = vorrq_u64(viol, vcgtq_s64(lo, maxv));
            viol = vorrq_u64(viol, vcgtq_s64(zero, hi));
            viol = vorrq_u64(viol, vcgtq_s64(hi, maxv));
            vst1q_u64(out.as_mut_ptr().add(i), vreinterpretq_u64_s64(lo));
            vst1q_u64(out.as_mut_ptr().add(i + 2), vreinterpretq_u64_s64(hi));
            i += 4;
        }
        let any = vgetq_lane_u64::<0>(viol) | vgetq_lane_u64::<1>(viol);
        assert!(
            any == 0,
            "biased code out of range (level overflows its field) — corrupt level buffer"
        );
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::kernels::qsgd_level;
    use crate::util::rng::Rng;

    fn adversarial_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| match i % 7 {
                0 => 0.0,
                1 => -0.0,
                2 => 1e-42,  // denormal
                3 => -1e-42, // negative denormal
                _ => {
                    let x = rng.next_f32() * 2.0 - 1.0;
                    if rng.next_u64() % 5 == 0 {
                        x * 1e-30
                    } else {
                        x
                    }
                }
            })
            .collect()
    }

    #[test]
    fn active_is_available() {
        let bk = active();
        assert!(available().contains(&bk), "active backend {bk:?} not in available set");
    }

    #[test]
    fn backend_labels_and_lanes() {
        assert_eq!(Backend::Scalar.label(), "scalar");
        assert_eq!(Backend::Scalar.lanes_f32(), 1);
        assert!(Backend::Avx2.lanes_f32() > Backend::Neon.lanes_f32());
    }

    #[test]
    fn qsgd_levels_prefix_bit_identical_to_scalar() {
        let mut rng = Rng::new(0x51D0_0001);
        for bk in available() {
            for n in [0usize, 1, 7, 8, 9, 64, 257, 1000] {
                let v = adversarial_f32s(&mut rng, n);
                let mut u = vec![0.0f32; n];
                rng.fill_uniform_f32(&mut u);
                // force u == p boundaries at a few coords: u = frac(|v|/w*s)
                let wnorm = 2.5f32;
                let s = 127.0f32;
                let mut u = u;
                for k in (0..n).step_by(5) {
                    let a = v[k].abs() / wnorm;
                    let scaled = a * s;
                    u[k] = scaled - scaled.floor(); // exactly p
                }
                let mut got = vec![9.0f32; n];
                let done = qsgd_levels(bk, &v, wnorm, &u, s, &mut got);
                assert!(done <= n);
                if bk == Backend::Scalar {
                    assert_eq!(done, 0);
                }
                for i in 0..done {
                    let want = qsgd_level(v[i], wnorm, u[i], s);
                    assert_eq!(
                        got[i].to_bits(),
                        want.to_bits(),
                        "{bk:?} lane {i}: {} vs {want}",
                        got[i]
                    );
                }
            }
        }
    }

    #[test]
    fn multiscale_levels_prefix_bit_identical_to_scalar() {
        let mut rng = Rng::new(0x51D0_0002);
        let sel = [7.0f32, 127.0, 2047.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        for bk in available() {
            for n in [0usize, 8, 63, 64, 500] {
                let v = adversarial_f32s(&mut rng, n);
                let mut u = vec![0.0f32; n];
                rng.fill_uniform_f32(&mut u);
                // include out-of-range indices: select must yield 0.0 there,
                // exactly like the scalar padded chain.
                let idx: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
                let wnorm = 1.75f32;
                let mut got = vec![9.0f32; n];
                let done = multiscale_levels(bk, &v, wnorm, &u, &idx, &sel, &mut got);
                for i in 0..done {
                    let mut s_eff = 0.0f32;
                    for (j, &sj) in sel.iter().enumerate() {
                        s_eff += (idx[i] == j as u8) as u32 as f32 * sj;
                    }
                    let want = qsgd_level(v[i], wnorm, u[i], s_eff);
                    assert_eq!(got[i].to_bits(), want.to_bits(), "{bk:?} lane {i}");
                }
            }
        }
    }

    #[test]
    fn scale_index_prefix_matches_scalar() {
        let mut rng = Rng::new(0x51D0_0003);
        let qual = [
            7.0f32,
            127.0,
            2047.0,
            f32::INFINITY,
            f32::INFINITY,
            f32::INFINITY,
            f32::INFINITY,
            f32::INFINITY,
        ];
        for bk in available() {
            for n in [0usize, 8, 129, 640] {
                let v = adversarial_f32s(&mut rng, n);
                let thresh = 1.3f32 * 7.0;
                let mut got = vec![0xEEu8; n];
                let done = scale_index(bk, &v, thresh, &qual, &mut got);
                for i in 0..done {
                    let av = v[i].abs();
                    let mut count = 0u32;
                    for &qj in qual.iter() {
                        count += (qj * av <= thresh) as u32;
                    }
                    let want = (count.max(1) - 1) as u8;
                    assert_eq!(got[i], want, "{bk:?} lane {i} (v={})", v[i]);
                }
            }
        }
    }

    #[test]
    fn unpack_fields_matches_scalar_extraction() {
        let mut rng = Rng::new(0x51D0_0004);
        for bk in available() {
            for bits in [2u32, 3, 5, 8, 11, 13, 16, 28, 32] {
                for start_bit in [0usize, 1, 7, 13, 63, 64, 100] {
                    let words: Vec<u64> = (0..32).map(|_| rng.next_u64()).collect();
                    let total_bits = words.len() * 64;
                    let cap = (total_bits - start_bit) / bits as usize;
                    let len = cap.min(100);
                    let mut out = vec![0u64; len];
                    let done = unpack_fields(bk, &words, start_bit, bits, &mut out);
                    assert!(done <= len);
                    let mask = if bits >= 64 { !0u64 } else { (1u64 << bits) - 1 };
                    for k in 0..done {
                        let bit = start_bit + k * bits as usize;
                        let w = bit / 64;
                        let off = (bit % 64) as u32;
                        let mut code = words[w] >> off;
                        if off + bits > 64 {
                            code |= words[w + 1] << (64 - off);
                        }
                        assert_eq!(out[k], code & mask, "{bk:?} bits={bits} start={start_bit} k={k}");
                    }
                }
            }
        }
    }

    #[test]
    fn pack_aligned_words_matches_scalar_shift_chain() {
        let mut rng = Rng::new(0x51D0_0005);
        for bk in available() {
            for bits in [2u32, 4, 8, 16] {
                let per = (64 / bits) as usize;
                let mask = (1u64 << bits) - 1;
                let n = per * 9 + 3;
                let codes: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
                let mut out = vec![0u64; n / per];
                let nw = pack_aligned_words(bk, &codes, bits, &mut out);
                assert!(nw <= out.len());
                for w in 0..nw {
                    let mut want = 0u64;
                    for j in 0..per {
                        want |= codes[w * per + j] << (j as u32 * bits);
                    }
                    assert_eq!(out[w], want, "{bk:?} bits={bits} word {w}");
                }
            }
        }
    }

    #[test]
    fn biased_codes_match_scalar_and_check_range() {
        let mut rng = Rng::new(0x51D0_0006);
        for bk in available() {
            let bias = 127i64;
            let max_code = 254u64;
            let n = 103;
            let levels: Vec<i32> =
                (0..n).map(|_| (rng.next_u64() % 255) as i32 - 127).collect();
            let mut out = vec![0u64; n];
            let done = biased_codes_i32(bk, &levels, bias, max_code, &mut out);
            for i in 0..done {
                assert_eq!(out[i], (levels[i] as i64 + bias) as u64, "{bk:?} lane {i}");
            }
        }
    }

    #[test]
    fn add_words_matches_scalar_adc() {
        let mut rng = Rng::new(0x51D0_0007);
        for bk in available() {
            for n in [0usize, 3, 4, 8, 33] {
                // carry-safe words: headroom in the top bit region so the
                // carry-independence precondition holds (as packed planes do)
                let a: Vec<u64> = (0..n).map(|_| rng.next_u64() >> 1).collect();
                let b: Vec<u64> = (0..n).map(|_| rng.next_u64() >> 1).collect();
                for carry_in in [0u64, 1] {
                    let mut dst = a.clone();
                    let (done, carry_out) = add_words(bk, &mut dst, &b, carry_in);
                    assert!(done <= n);
                    // scalar reference over the processed prefix
                    let mut carry = carry_in;
                    for i in 0..done {
                        let (s1, c1) = a[i].overflowing_add(b[i]);
                        let (s2, c2) = s1.overflowing_add(carry);
                        assert!(!c2, "test vectors must be carry-safe");
                        assert_eq!(dst[i], s2, "{bk:?} word {i} (carry_in={carry_in})");
                        carry = c1 as u64;
                    }
                    if done > 0 {
                        assert_eq!(carry_out, carry, "{bk:?} prefix carry (n={n})");
                    }
                    // untouched suffix
                    for i in done..n {
                        assert_eq!(dst[i], a[i]);
                    }
                }
            }
        }
    }

    #[test]
    fn add_words_carries_across_lane_and_vector_boundaries() {
        // lanes 0 and 3 overflow on d+s (c1 = 1), feeding +1 into lanes 1
        // and 4 — the latter crossing the 4-lane vector boundary via the
        // chain carry. Every lane RECEIVING a carry has headroom, so the
        // carry-safety precondition holds and the scalar adc is the oracle.
        for bk in available() {
            let a = vec![u64::MAX, 5u64, 9, u64::MAX, 20, 30, 40, 50];
            let b = vec![1u64, 7, 2, 3, 4, 5, 6, 7];
            for carry_in in [0u64, 1] {
                let mut dst = a.clone();
                let (done, carry_out) = add_words(bk, &mut dst, &b, carry_in);
                let mut carry = carry_in;
                for i in 0..done {
                    let (s1, c1) = a[i].overflowing_add(b[i]);
                    let (s2, c2) = s1.overflowing_add(carry);
                    assert!(!c2, "test vectors must be carry-safe");
                    assert_eq!(dst[i], s2, "{bk:?} word {i} (carry_in={carry_in})");
                    carry = c1 as u64;
                }
                if done > 0 {
                    assert_eq!(carry_out, carry, "{bk:?} (carry_in={carry_in})");
                }
            }
            // and a real ripple: d = MAX, s = 0, carry_in = 1 -> r = 0, but
            // carry OUT is c1(d+s) = 0 by carry-independence (the packed
            // planes guarantee this shape can only arise inside a field
            // with headroom; here we just pin the documented semantics).
            let mut dst2 = vec![u64::MAX, 5, 5, 5, 5, 5, 5, 5];
            let src2 = vec![0u64; 8];
            let (done2, _) = add_words(bk, &mut dst2, &src2, 1);
            if done2 > 0 {
                assert_eq!(dst2[0], 0, "{bk:?}: MAX + 0 + carry wraps the lane");
                assert_eq!(dst2[1], 5, "{bk:?}: carry-out taken from d+s, not the ripple");
            }
        }
    }

    #[test]
    fn forced_scalar_env_is_respected_by_detect() {
        // active() caches; test detect()'s env handling directly. Restore
        // the prior value so a forced-scalar CI run stays forced for any
        // test that races this one.
        let prior = std::env::var_os("REPRO_FORCE_SCALAR");
        std::env::set_var("REPRO_FORCE_SCALAR", "1");
        assert_eq!(super::detect(), Backend::Scalar);
        std::env::set_var("REPRO_FORCE_SCALAR", "0");
        let bk = super::detect();
        assert!(available().contains(&bk));
        match prior {
            Some(v) => std::env::set_var("REPRO_FORCE_SCALAR", v),
            None => std::env::remove_var("REPRO_FORCE_SCALAR"),
        }
    }
}
