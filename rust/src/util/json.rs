//! Minimal JSON substrate (parser + emitter).
//!
//! The vendored crate set has no `serde_json`, so the artifact index
//! (`artifacts/meta.json`) and run summaries go through this module. It
//! implements the full JSON grammar (RFC 8259) minus `\u` surrogate pairs
//! beyond the BMP, which the artifact index never contains.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic — handy for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    // -- emission -------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s);
        s
    }

    fn emit(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => emit_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.emit(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_str(k, out);
                    out.push(':');
                    x.emit(out);
                }
                out.push('}');
            }
        }
    }
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number '{txt}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        let v2 = Json::parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 42);
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.req("zz").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""café naïve""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café naïve");
    }

    #[test]
    fn integer_emission_is_plain() {
        assert_eq!(num(3.0).to_string(), "3");
        assert_eq!(num(3.5).to_string(), "3.5");
    }
}
