//! Persistent worker pool for the L3 hot path.
//!
//! The previous incarnation spawned OS threads per call (`std::thread::scope`
//! in every aggregator step) and funneled `par_map` results through a
//! `Mutex<Vec>` while claiming work from the *end* of the queue. This module
//! replaces both with one process-wide pool:
//!
//! * workers are spawned once ([`pool`]) and woken through a condvar — a
//!   per-step task costs a queue push, not a thread spawn;
//! * [`par_map`] / [`par_chunks_mut`] claim work FIFO via an atomic index and
//!   write results into disjoint slots — no result mutex, order preserved;
//! * callers *help*: the thread that submits a batch drains the queue until
//!   its batch completes, which keeps nested submissions deadlock-free and
//!   uses the caller's core instead of parking it.
//!
//! Keep granularity coarse (one task per simulated worker or per large
//! chunk) — a task still costs a queue round-trip.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Completion latch shared by one batch of submitted tasks.
struct Batch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Batch {
    fn new(n: usize) -> Arc<Batch> {
        Arc::new(Batch {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        })
    }

    fn job_done(&self) {
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.done.wait(rem).unwrap();
        }
    }
}

/// A queued unit of work. The closure is transmuted to `'static`; soundness
/// comes from [`ThreadPool::scope_run`] blocking until the batch completes,
/// so every borrow captured by the closure outlives its execution.
struct Job {
    run: Box<dyn FnOnce() + Send + 'static>,
    batch: Arc<Batch>,
}

fn run_job(job: Job) {
    let result = catch_unwind(AssertUnwindSafe(job.run));
    if result.is_err() {
        job.batch.panicked.store(true, Ordering::SeqCst);
    }
    job.batch.job_done();
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    work: Condvar,
}

/// Persistent worker pool. One global instance serves the whole process
/// ([`pool`]); dedicated instances are only built by tests.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        run_job(job);
    }
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("repro-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { shared, workers, threads }
    }

    /// Number of pool worker threads (the submitting thread helps too).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run a batch of borrowed closures to completion across the pool.
    ///
    /// Blocks until every task has finished — that blocking is what makes
    /// the internal lifetime transmute sound. The calling thread helps drain
    /// the queue, so nested `scope_run` from inside a task cannot deadlock.
    /// Panics (after the whole batch has settled) if any task panicked.
    pub fn scope_run<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        let batch = Batch::new(tasks.len());
        {
            let mut q = self.shared.queue.lock().unwrap();
            for t in tasks {
                // SAFETY: `batch.wait()` below does not return until this
                // closure has run to completion (or the pool worker running
                // it has counted it done after a panic), so the 'scope
                // borrows it captures are live throughout its execution.
                let run: Box<dyn FnOnce() + Send + 'static> = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(t)
                };
                q.jobs.push_back(Job { run, batch: batch.clone() });
            }
        }
        self.shared.work.notify_all();

        // Caller-helps loop: execute queued jobs (ours or another batch's)
        // until our batch is done; park only when the queue is drained.
        loop {
            if batch.is_done() {
                break;
            }
            let job = self.shared.queue.lock().unwrap().jobs.pop_front();
            match job {
                Some(j) => run_job(j),
                None => {
                    batch.wait();
                    break;
                }
            }
        }
        if batch.panicked.load(Ordering::SeqCst) {
            panic!("ThreadPool task panicked");
        }
    }
}

/// Chunk-ready handoff between pipeline producers (pool tasks) and the
/// consuming caller: a tiny SPSC queue of completed chunk indices plus the
/// settle/panic bookkeeping that makes the scope safe to unwind.
struct ChunkReady {
    state: Mutex<ChunkReadyState>,
    cv: Condvar,
}

struct ChunkReadyState {
    ready: VecDeque<usize>,
    /// producer tasks that have finished (successfully or by panicking)
    settled: usize,
    panicked: bool,
}

impl ThreadPool {
    /// Chunk-pipelined producer/consumer scope — the async step the
    /// caller-helps pool design was built for.
    ///
    /// Spawns one `produce(c)` task per chunk on the pool; the calling
    /// thread runs `consume(c)` for each chunk **as soon as it is
    /// produced**, in completion order (consumers must therefore be
    /// order-independent — the integer-domain reductions are, exactly
    /// because their sums are exact). While no chunk is ready the caller
    /// helps drain the pool queue, so the pipeline cannot deadlock even on
    /// a single-thread pool or under nested submissions.
    ///
    /// Blocks until every producer has settled and every produced chunk is
    /// consumed — that blocking is what makes the internal lifetime
    /// transmute sound (same contract as [`ThreadPool::scope_run`]).
    /// Panic-safe: a panicking producer marks the scope, the remaining
    /// chunks still settle, and the panic is re-raised here (no deadlock,
    /// no dangling borrows); a panicking consumer likewise waits for all
    /// producers before unwinding.
    pub fn pipeline_chunks<'scope, P, C>(&self, nchunks: usize, produce: P, mut consume: C)
    where
        P: Fn(usize) + Send + Sync + 'scope,
        C: FnMut(usize) + 'scope,
    {
        if nchunks == 0 {
            return;
        }
        let ready = Arc::new(ChunkReady {
            state: Mutex::new(ChunkReadyState {
                ready: VecDeque::new(),
                settled: 0,
                panicked: false,
            }),
            cv: Condvar::new(),
        });

        let pref = &produce;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..nchunks)
            .map(|c| {
                let ready = ready.clone();
                Box::new(move || {
                    let ok = catch_unwind(AssertUnwindSafe(|| pref(c))).is_ok();
                    let mut st = ready.state.lock().unwrap();
                    st.settled += 1;
                    if ok {
                        st.ready.push_back(c);
                    } else {
                        st.panicked = true;
                    }
                    ready.cv.notify_all();
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();

        // enqueue without waiting (scope_run would serialize the pipeline);
        // completion is tracked through `settled`, not the batch latch.
        let batch = Batch::new(tasks.len());
        {
            let mut q = self.shared.queue.lock().unwrap();
            for t in tasks {
                // SAFETY: this function does not return (or unwind) until
                // `settled == nchunks`, i.e. every closure has run to
                // completion, so the 'scope borrows stay live throughout.
                let run: Box<dyn FnOnce() + Send + 'static> = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + '_>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(t)
                };
                q.jobs.push_back(Job { run, batch: batch.clone() });
            }
        }
        self.shared.work.notify_all();

        let mut consumer_panic: Option<Box<dyn std::any::Any + Send>> = None;
        loop {
            // drain every chunk that is already ready
            loop {
                let next = {
                    let mut st = ready.state.lock().unwrap();
                    st.ready.pop_front()
                };
                match next {
                    Some(c) if consumer_panic.is_none() => {
                        if let Err(e) = catch_unwind(AssertUnwindSafe(|| consume(c))) {
                            consumer_panic = Some(e);
                        }
                    }
                    Some(_) => {} // consumer already failed: discard
                    None => break,
                }
            }
            {
                let st = ready.state.lock().unwrap();
                if st.settled == nchunks && st.ready.is_empty() {
                    break;
                }
            }
            // nothing ready: help the pool (our producers may be queued
            // behind other work), else park until a producer settles.
            let job = self.shared.queue.lock().unwrap().jobs.pop_front();
            match job {
                Some(j) => run_job(j),
                None => {
                    let st = ready.state.lock().unwrap();
                    if !(st.settled == nchunks || !st.ready.is_empty()) {
                        let _unused = ready.cv.wait(st).unwrap();
                    }
                }
            }
        }

        if let Some(e) = consumer_panic {
            std::panic::resume_unwind(e);
        }
        if ready.state.lock().unwrap().panicked {
            panic!("pipeline producer panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool, spawned on first use with
/// [`default_parallelism`] workers.
pub fn pool() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_parallelism()))
}

/// Raw-pointer wrapper for handing disjoint slots/slices to pool tasks.
/// `pub(crate)` so the fused pipelined hot path can hand per-chunk word
/// ranges of shared packed buffers to producer tasks (same disjointness
/// contract as the uses below).
pub(crate) struct SendPtr<P>(pub(crate) *mut P);
impl<P> Clone for SendPtr<P> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<P> Copy for SendPtr<P> {}
// SAFETY: every use partitions the pointee by index so no two tasks touch
// the same element; completion is ordered by the batch latch (scope_run)
// or the chunk-ready queue (pipeline_chunks). Sync is needed because a
// pipeline's single producer closure is shared by reference across tasks.
unsafe impl<P> Send for SendPtr<P> {}
unsafe impl<P> Sync for SendPtr<P> {}

/// Parallel map over `items`, at most `max_threads` concurrent workers.
/// Preserves input order in the output. Work is claimed FIFO through an
/// atomic index; each result is written to its own slot (no result mutex).
pub fn par_map<T, R, F>(items: Vec<T>, max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads.max(1).min(n).min(pool().threads() + 1);
    if threads == 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let mut slots: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let in_ptr = SendPtr(slots.as_mut_ptr());
    let out_ptr = SendPtr(out.as_mut_ptr());
    let fref = &f;
    let nref = &next;

    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    for _ in 0..threads {
        tasks.push(Box::new(move || loop {
            let i = nref.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            // SAFETY: the atomic fetch_add hands index i to exactly one
            // task, so the take/write below touch disjoint slots.
            let item = unsafe { (*in_ptr.0.add(i)).take().expect("item claimed twice") };
            let r = fref(i, item);
            unsafe {
                *out_ptr.0.add(i) = Some(r);
            }
        }));
    }
    pool().scope_run(tasks);

    out.into_iter().map(|r| r.expect("par_map: task not run")).collect()
}

/// Split `buf` into `parts` near-equal mutable chunks and run `f` on each in
/// parallel — the zero-copy path for elementwise kernels over big vectors.
/// Chunks are claimed through an atomic index on the persistent pool.
pub fn par_chunks_mut<F>(buf: &mut [f32], parts: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let n = buf.len();
    if n == 0 {
        return;
    }
    let parts = parts.max(1).min(n);
    let chunk = n.div_ceil(parts);
    let nchunks = n.div_ceil(chunk);
    let threads = (pool().threads() + 1).min(nchunks);
    if threads <= 1 || nchunks == 1 {
        for (i, piece) in buf.chunks_mut(chunk).enumerate() {
            f(i, i * chunk, piece);
        }
        return;
    }

    let base = SendPtr(buf.as_mut_ptr());
    let next = AtomicUsize::new(0);
    let fref = &f;
    let nref = &next;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    for _ in 0..threads {
        tasks.push(Box::new(move || loop {
            let c = nref.fetch_add(1, Ordering::Relaxed);
            if c >= nchunks {
                break;
            }
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            // SAFETY: chunk ranges [lo, hi) are disjoint across claimed
            // indices, so each task gets an exclusive subslice.
            let piece = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
            fref(c, lo, piece);
        }));
    }
    pool().scope_run(tasks);
}

/// Number of worker threads to use by default (leave one core for the OS).
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(items, 8, |_, x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_thread_and_empty() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |_, x| x + 1), vec![2, 3, 4]);
        assert_eq!(par_map(Vec::<i32>::new(), 4, |_, x| x), Vec::<i32>::new());
    }

    #[test]
    fn par_chunks_mut_covers_everything() {
        let mut buf = vec![0.0f32; 1003];
        par_chunks_mut(&mut buf, 7, |_, off, piece| {
            for (i, v) in piece.iter_mut().enumerate() {
                *v = (off + i) as f32;
            }
        });
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn pool_reused_across_many_batches() {
        // regression for the per-call spawn cost: the same pool instance
        // must serve many submissions (threads stay up between batches).
        let p = pool();
        let hits = AtomicUsize::new(0);
        for _ in 0..50 {
            let h = &hits;
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| Box::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>)
                .collect();
            p.scope_run(tasks);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn nested_scope_run_does_not_deadlock() {
        let total = AtomicUsize::new(0);
        let t = &total;
        let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| Box::new(move || {
                            t.fetch_add(1, Ordering::Relaxed);
                        }) as Box<dyn FnOnce() + Send + '_>)
                        .collect();
                    pool().scope_run(inner);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool().scope_run(outer);
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn dedicated_pool_shuts_down_cleanly() {
        let p = ThreadPool::new(2);
        let hits = AtomicUsize::new(0);
        let h = &hits;
        p.scope_run(
            (0..8)
                .map(|_| Box::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>)
                .collect(),
        );
        assert_eq!(hits.load(Ordering::Relaxed), 8);
        drop(p); // must join workers without hanging
    }

    #[test]
    #[should_panic(expected = "ThreadPool task panicked")]
    fn task_panic_propagates() {
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("boom")), Box::new(|| {})];
        pool().scope_run(tasks);
    }

    #[test]
    fn prop_pipeline_equals_sequential_for_any_chunk_count() {
        // the pipelining contract: for arbitrary chunk counts — including 1
        // and counts far beyond the pool width — produce-then-consume over
        // the pipeline touches every chunk exactly once and computes the
        // same result as the sequential loop (consumption order is
        // completion order, so we compare order-independent state).
        use crate::util::quickcheck::check;
        check("pipeline == sequential", 40, |g| {
            let nchunks = *g.pick(&[0usize, 1, 2, 3, 7, 16, 61, 4 * pool().threads() + 5]);
            let produced: Vec<AtomicUsize> = (0..nchunks).map(|_| AtomicUsize::new(0)).collect();
            let mut consumed = vec![0usize; nchunks];
            pool().pipeline_chunks(
                nchunks,
                |c| {
                    produced[c].fetch_add(1, Ordering::Relaxed);
                },
                |c| {
                    consumed[c] += c * c + 1;
                },
            );
            let want: Vec<usize> = (0..nchunks).map(|c| c * c + 1).collect();
            if consumed != want {
                return Err(format!("consumed {consumed:?} != {want:?}"));
            }
            for (c, p) in produced.iter().enumerate() {
                if p.load(Ordering::Relaxed) != 1 {
                    return Err(format!("chunk {c} produced {} times", p.load(Ordering::Relaxed)));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pipeline_consumer_sees_producer_writes() {
        // happens-before: the consumer must observe the producer's writes
        // to the chunk's slot (the fused path relies on this for the packed
        // words the producers fill).
        let n = 64;
        let mut slots = vec![0u64; n];
        let ptr = SendPtr(slots.as_mut_ptr());
        let mut sum = 0u64;
        pool().pipeline_chunks(
            n,
            |c| unsafe {
                *ptr.0.add(c) = (c as u64 + 1) * 3;
            },
            |c| {
                sum += slots_read(&ptr, c);
            },
        );
        fn slots_read(p: &SendPtr<u64>, c: usize) -> u64 {
            unsafe { *p.0.add(c) }
        }
        let want: u64 = (1..=n as u64).map(|x| x * 3).sum();
        assert_eq!(sum, want);
    }

    #[test]
    #[should_panic(expected = "pipeline producer panicked")]
    fn pipeline_producer_panic_does_not_deadlock() {
        // a panicking producer must not hang the scope: remaining chunks
        // settle, surviving chunks are consumed, and the panic re-raises.
        let hits = AtomicUsize::new(0);
        pool().pipeline_chunks(
            8,
            |c| {
                if c == 3 {
                    panic!("producer boom");
                }
            },
            |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            },
        );
    }

    #[test]
    fn pipeline_zero_and_one_chunks() {
        let mut seen = Vec::new();
        pool().pipeline_chunks(0, |_| {}, |c| seen.push(c));
        assert!(seen.is_empty());
        pool().pipeline_chunks(1, |_| {}, |c| seen.push(c));
        assert_eq!(seen, vec![0]);
    }
}
