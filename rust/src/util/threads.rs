//! Scoped-thread parallel helpers (no rayon in the vendored set).
//!
//! Used on the L3 hot path to parallelize per-worker encode/decode across
//! OS threads. Keep granularity coarse (one task per simulated worker or
//! per large chunk) — task spawn cost is a thread spawn.

/// Parallel map over `items`, at most `max_threads` concurrent threads.
/// Preserves input order in the output.
pub fn par_map<T, R, F>(items: Vec<T>, max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads.max(1).min(n);
    if threads == 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = std::sync::Mutex::new(work);
    let results = std::sync::Mutex::new(&mut out);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = { queue.lock().unwrap().pop() };
                match item {
                    Some((i, t)) => {
                        let r = f(i, t);
                        results.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });

    out.into_iter().map(|r| r.expect("par_map: task not run")).collect()
}

/// Split `buf` into `parts` near-equal mutable chunks and run `f` on each in
/// parallel — the zero-copy path for elementwise kernels over big vectors.
pub fn par_chunks_mut<F>(buf: &mut [f32], parts: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let n = buf.len();
    let parts = parts.max(1).min(n.max(1));
    let chunk = n.div_ceil(parts);
    std::thread::scope(|scope| {
        for (i, piece) in buf.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(i, i * chunk, piece));
        }
    });
}

/// Number of worker threads to use by default (leave one core for the OS).
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(items, 8, |_, x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_thread_and_empty() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |_, x| x + 1), vec![2, 3, 4]);
        assert_eq!(par_map(Vec::<i32>::new(), 4, |_, x| x), Vec::<i32>::new());
    }

    #[test]
    fn par_chunks_mut_covers_everything() {
        let mut buf = vec![0.0f32; 1003];
        par_chunks_mut(&mut buf, 7, |_, off, piece| {
            for (i, v) in piece.iter_mut().enumerate() {
                *v = (off + i) as f32;
            }
        });
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }
}
