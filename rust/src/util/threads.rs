//! Persistent worker pool for the L3 hot path.
//!
//! The previous incarnation spawned OS threads per call (`std::thread::scope`
//! in every aggregator step) and funneled `par_map` results through a
//! `Mutex<Vec>` while claiming work from the *end* of the queue. This module
//! replaces both with one process-wide pool:
//!
//! * workers are spawned once ([`pool`]) and woken through a condvar — a
//!   per-step task costs a queue push, not a thread spawn;
//! * [`par_map`] / [`par_chunks_mut`] claim work FIFO via an atomic index and
//!   write results into disjoint slots — no result mutex, order preserved;
//! * callers *help*: the thread that submits a batch drains the queue until
//!   its batch completes, which keeps nested submissions deadlock-free and
//!   uses the caller's core instead of parking it.
//!
//! Keep granularity coarse (one task per simulated worker or per large
//! chunk) — a task still costs a queue round-trip.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Completion latch shared by one batch of submitted tasks.
struct Batch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Batch {
    fn new(n: usize) -> Arc<Batch> {
        Arc::new(Batch {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        })
    }

    fn job_done(&self) {
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.done.wait(rem).unwrap();
        }
    }
}

/// A queued unit of work. The closure is transmuted to `'static`; soundness
/// comes from [`ThreadPool::scope_run`] blocking until the batch completes,
/// so every borrow captured by the closure outlives its execution.
struct Job {
    run: Box<dyn FnOnce() + Send + 'static>,
    batch: Arc<Batch>,
}

fn run_job(job: Job) {
    let result = catch_unwind(AssertUnwindSafe(job.run));
    if result.is_err() {
        job.batch.panicked.store(true, Ordering::SeqCst);
    }
    job.batch.job_done();
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    work: Condvar,
}

/// Persistent worker pool. One global instance serves the whole process
/// ([`pool`]); dedicated instances are only built by tests.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        run_job(job);
    }
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("repro-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { shared, workers, threads }
    }

    /// Number of pool worker threads (the submitting thread helps too).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run a batch of borrowed closures to completion across the pool.
    ///
    /// Blocks until every task has finished — that blocking is what makes
    /// the internal lifetime transmute sound. The calling thread helps drain
    /// the queue, so nested `scope_run` from inside a task cannot deadlock.
    /// Panics (after the whole batch has settled) if any task panicked.
    pub fn scope_run<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        let batch = Batch::new(tasks.len());
        {
            let mut q = self.shared.queue.lock().unwrap();
            for t in tasks {
                // SAFETY: `batch.wait()` below does not return until this
                // closure has run to completion (or the pool worker running
                // it has counted it done after a panic), so the 'scope
                // borrows it captures are live throughout its execution.
                let run: Box<dyn FnOnce() + Send + 'static> = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(t)
                };
                q.jobs.push_back(Job { run, batch: batch.clone() });
            }
        }
        self.shared.work.notify_all();

        // Caller-helps loop: execute queued jobs (ours or another batch's)
        // until our batch is done; park only when the queue is drained.
        loop {
            if batch.is_done() {
                break;
            }
            let job = self.shared.queue.lock().unwrap().jobs.pop_front();
            match job {
                Some(j) => run_job(j),
                None => {
                    batch.wait();
                    break;
                }
            }
        }
        if batch.panicked.load(Ordering::SeqCst) {
            panic!("ThreadPool task panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool, spawned on first use with
/// [`default_parallelism`] workers.
pub fn pool() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_parallelism()))
}

/// Raw-pointer wrapper for handing disjoint slots/slices to pool tasks.
struct SendPtr<P>(*mut P);
impl<P> Clone for SendPtr<P> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<P> Copy for SendPtr<P> {}
// SAFETY: every use partitions the pointee by index so no two tasks touch
// the same element; completion is ordered by the batch latch.
unsafe impl<P> Send for SendPtr<P> {}

/// Parallel map over `items`, at most `max_threads` concurrent workers.
/// Preserves input order in the output. Work is claimed FIFO through an
/// atomic index; each result is written to its own slot (no result mutex).
pub fn par_map<T, R, F>(items: Vec<T>, max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads.max(1).min(n).min(pool().threads() + 1);
    if threads == 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let mut slots: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let in_ptr = SendPtr(slots.as_mut_ptr());
    let out_ptr = SendPtr(out.as_mut_ptr());
    let fref = &f;
    let nref = &next;

    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    for _ in 0..threads {
        tasks.push(Box::new(move || loop {
            let i = nref.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            // SAFETY: the atomic fetch_add hands index i to exactly one
            // task, so the take/write below touch disjoint slots.
            let item = unsafe { (*in_ptr.0.add(i)).take().expect("item claimed twice") };
            let r = fref(i, item);
            unsafe {
                *out_ptr.0.add(i) = Some(r);
            }
        }));
    }
    pool().scope_run(tasks);

    out.into_iter().map(|r| r.expect("par_map: task not run")).collect()
}

/// Split `buf` into `parts` near-equal mutable chunks and run `f` on each in
/// parallel — the zero-copy path for elementwise kernels over big vectors.
/// Chunks are claimed through an atomic index on the persistent pool.
pub fn par_chunks_mut<F>(buf: &mut [f32], parts: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let n = buf.len();
    if n == 0 {
        return;
    }
    let parts = parts.max(1).min(n);
    let chunk = n.div_ceil(parts);
    let nchunks = n.div_ceil(chunk);
    let threads = (pool().threads() + 1).min(nchunks);
    if threads <= 1 || nchunks == 1 {
        for (i, piece) in buf.chunks_mut(chunk).enumerate() {
            f(i, i * chunk, piece);
        }
        return;
    }

    let base = SendPtr(buf.as_mut_ptr());
    let next = AtomicUsize::new(0);
    let fref = &f;
    let nref = &next;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    for _ in 0..threads {
        tasks.push(Box::new(move || loop {
            let c = nref.fetch_add(1, Ordering::Relaxed);
            if c >= nchunks {
                break;
            }
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            // SAFETY: chunk ranges [lo, hi) are disjoint across claimed
            // indices, so each task gets an exclusive subslice.
            let piece = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
            fref(c, lo, piece);
        }));
    }
    pool().scope_run(tasks);
}

/// Number of worker threads to use by default (leave one core for the OS).
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(items, 8, |_, x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_thread_and_empty() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |_, x| x + 1), vec![2, 3, 4]);
        assert_eq!(par_map(Vec::<i32>::new(), 4, |_, x| x), Vec::<i32>::new());
    }

    #[test]
    fn par_chunks_mut_covers_everything() {
        let mut buf = vec![0.0f32; 1003];
        par_chunks_mut(&mut buf, 7, |_, off, piece| {
            for (i, v) in piece.iter_mut().enumerate() {
                *v = (off + i) as f32;
            }
        });
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn pool_reused_across_many_batches() {
        // regression for the per-call spawn cost: the same pool instance
        // must serve many submissions (threads stay up between batches).
        let p = pool();
        let hits = AtomicUsize::new(0);
        for _ in 0..50 {
            let h = &hits;
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| Box::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>)
                .collect();
            p.scope_run(tasks);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn nested_scope_run_does_not_deadlock() {
        let total = AtomicUsize::new(0);
        let t = &total;
        let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| Box::new(move || {
                            t.fetch_add(1, Ordering::Relaxed);
                        }) as Box<dyn FnOnce() + Send + '_>)
                        .collect();
                    pool().scope_run(inner);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool().scope_run(outer);
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn dedicated_pool_shuts_down_cleanly() {
        let p = ThreadPool::new(2);
        let hits = AtomicUsize::new(0);
        let h = &hits;
        p.scope_run(
            (0..8)
                .map(|_| Box::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>)
                .collect(),
        );
        assert_eq!(hits.load(Ordering::Relaxed), 8);
        drop(p); // must join workers without hanging
    }

    #[test]
    #[should_panic(expected = "ThreadPool task panicked")]
    fn task_panic_propagates() {
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("boom")), Box::new(|| {})];
        pool().scope_run(tasks);
    }
}
