//! Figure regeneration: one entry point per figure family in the paper's
//! evaluation section (DESIGN.md experiment index). Each function prints
//! the same series the paper plots and writes CSVs under `results/`.
//!
//! Training figures use the lite models and step counts scaled to this CPU
//! testbed; the *shape* claims (method ordering, 2-bit gap, multi-scale
//! recovery, sparsified early advantage) are what EXPERIMENTS.md checks.

use std::path::PathBuf;

use anyhow::Result;

use crate::compress::Method;
use crate::metrics::render_table;
use crate::netsim::NetConfig;
use crate::perfmodel::{paper_schemes, throughput, ModelProfile};
use crate::runtime::Artifacts;
use crate::train::{summary_table, write_summaries, Experiment};

pub struct FigureOpts {
    pub steps: usize,
    pub workers: usize,
    pub out_dir: PathBuf,
    pub models: Vec<String>,
    pub quiet: bool,
}

impl Default for FigureOpts {
    fn default() -> Self {
        FigureOpts {
            steps: 200,
            workers: 4,
            out_dir: PathBuf::from("results"),
            models: vec!["resnet_lite".into(), "vgg_lite".into()],
            quiet: false,
        }
    }
}

fn parse_methods(specs: &[&str]) -> Vec<Method> {
    specs.iter().map(|s| Method::parse(s).expect("bad method spec")).collect()
}

fn run_experiment(arts: &Artifacts, name: &str, methods: Vec<Method>, opts: &FigureOpts) -> Result<()> {
    for model in &opts.models {
        let mut exp = Experiment::new(&format!("{name}_{model}"), model, methods.clone());
        exp.steps = opts.steps;
        exp.workers = opts.workers;
        exp.out_dir = opts.out_dir.clone();
        exp.quiet = opts.quiet;
        let results = exp.run(arts)?;
        let summaries: Vec<_> = results.into_iter().map(|(_, s)| s).collect();
        println!("\n=== {name} / {model} (loss & accuracy vs step -> results/) ===");
        println!("{}", summary_table(&summaries));
        write_summaries(&opts.out_dir, &format!("{name}_{model}"), &summaries)?;
    }
    Ok(())
}

/// Figures 1 & 2: benchmark all methods vs AllReduce-SGD and PowerSGD.
pub fn fig1_2(arts: &Artifacts, opts: &FigureOpts) -> Result<()> {
    run_experiment(
        arts,
        "fig1_2",
        parse_methods(&[
            "allreduce",
            "qsgd-mn-8",
            "qsgd-mn-ts-8-12",
            "grandk-mn-8",
            "grandk-mn-ts-8-12",
            "powersgd-1",
            "powersgd-2",
        ]),
        opts,
    )
}

/// Figures 3 & 4: QSGDMaxNorm precision sweep {8, 4, 2}.
pub fn fig3_4(arts: &Artifacts, opts: &FigureOpts) -> Result<()> {
    run_experiment(
        arts,
        "fig3_4",
        parse_methods(&["allreduce", "qsgd-mn-8", "qsgd-mn-4", "qsgd-mn-2"]),
        opts,
    )
}

/// Figures 5 & 6: GlobalRandKMaxNorm precision sweep {8, 4, 2}.
pub fn fig5_6(arts: &Artifacts, opts: &FigureOpts) -> Result<()> {
    run_experiment(
        arts,
        "fig5_6",
        parse_methods(&["allreduce", "grandk-mn-8", "grandk-mn-4", "grandk-mn-2"]),
        opts,
    )
}

/// Figures 7 & 8: two-scale sweep {(8,12),(6,10),(4,8),(2,6)}.
pub fn fig7_8(arts: &Artifacts, opts: &FigureOpts) -> Result<()> {
    run_experiment(
        arts,
        "fig7_8",
        parse_methods(&[
            "allreduce",
            "qsgd-mn-ts-8-12",
            "qsgd-mn-ts-6-10",
            "qsgd-mn-ts-4-8",
            "qsgd-mn-ts-2-6",
        ]),
        opts,
    )
}

/// Figures 9 & 10: sparsified two-scale sweep.
pub fn fig9_10(arts: &Artifacts, opts: &FigureOpts) -> Result<()> {
    run_experiment(
        arts,
        "fig9_10",
        parse_methods(&[
            "allreduce",
            "grandk-mn-ts-8-12",
            "grandk-mn-ts-6-10",
            "grandk-mn-ts-4-8",
            "grandk-mn-ts-2-6",
        ]),
        opts,
    )
}

/// Figures 11–14: analytical throughput projections (§6.6), 32 nodes × 4
/// V100, {1, 10} Gbps × {ResNet50, VGG16} × bits {2, 4, 8}.
pub fn fig11_14(floor_bits: Option<f64>) -> String {
    let mut out = String::new();
    for (fig, model, gbps) in [
        ("Figure 11", ModelProfile::resnet50(), 1.0),
        ("Figure 12", ModelProfile::resnet50(), 10.0),
        ("Figure 13", ModelProfile::vgg16(), 1.0),
        ("Figure 14", ModelProfile::vgg16(), 10.0),
    ] {
        let net = NetConfig::paper_cluster(gbps);
        out.push_str(&format!(
            "\n=== {fig}: {} @ {gbps} Gbps Ethernet, 32 nodes x 4 V100 (images/s) ===\n",
            model.name
        ));
        let mut rows = Vec::new();
        for bits in [2usize, 4, 8] {
            for scheme in paper_schemes(bits) {
                let tp = throughput(&model, &net, &scheme, floor_bits);
                rows.push(vec![format!("{bits}"), scheme.label(), format!("{tp:.0}")]);
            }
        }
        out.push_str(&render_table(&["bits", "method", "img/s"], &rows));
    }
    out
}

/// Figure 15: time breakdown per method on the 4-worker testbed.
/// Returns rows (method, compute_s, encode_s, comm_s, decode_s, total_s)
/// from an actual instrumented short run.
pub fn fig15(arts: &Artifacts, opts: &FigureOpts) -> Result<String> {
    let methods = parse_methods(&[
        "allreduce",
        "qsgd-mn-8",
        "qsgd-mn-ts-8-12",
        "grandk-mn-8",
        "grandk-mn-ts-8-12",
        "powersgd-1",
        "powersgd-2",
    ]);
    let mut out = String::new();
    for model in &opts.models {
        let mut exp = Experiment::new(&format!("fig15_{model}"), model, methods.clone());
        exp.steps = opts.steps.min(40);
        exp.workers = opts.workers;
        exp.out_dir = opts.out_dir.clone();
        exp.quiet = true;
        let results = exp.run(arts)?;
        out.push_str(&format!("\n=== Figure 15: time breakdown / {model} (s, {} steps) ===\n", exp.steps));
        let rows: Vec<Vec<String>> = results
            .iter()
            .map(|(_, s)| {
                vec![
                    s.label.clone(),
                    format!("{:.3}", s.t_compute),
                    format!("{:.3}", s.t_encode),
                    format!("{:.4}", s.t_comm_sim),
                    format!("{:.3}", s.t_decode),
                    format!("{:.3}", s.sim_time_s),
                ]
            })
            .collect();
        out.push_str(&render_table(
            &["method", "compute", "encode", "comm(sim)", "decode", "total"],
            &rows,
        ));
        write_summaries(
            &opts.out_dir,
            &format!("fig15_{model}"),
            &results.into_iter().map(|(_, s)| s).collect::<Vec<_>>(),
        )?;
    }
    Ok(out)
}

/// Scalability series (paper §1 / §6.6 discussion): simulated communication
/// time vs number of workers for all-reduce vs all-gather aggregation.
pub fn scalability_table() -> String {
    let n = 14_728_266usize; // VGG16 gradient
    let mut rows = Vec::new();
    for m in [2usize, 4, 8, 16, 32, 64, 128] {
        let net = NetConfig::flat(m, 10.0);
        let dense = net.allreduce_s(4.0 * n as f64);
        let q8 = net.allreduce_s(1.0 * n as f64);
        let gather = net.allgather_s(1.0 * n as f64);
        rows.push(vec![
            format!("{m}"),
            format!("{:.4}", dense),
            format!("{:.4}", q8),
            format!("{:.4}", gather),
            format!("{:.2}", gather / q8),
        ]);
    }
    render_table(
        &["workers", "fp32 allreduce (s)", "8-bit allreduce (s)", "8-bit allgather (s)", "gather/reduce"],
        &rows,
    )
}
