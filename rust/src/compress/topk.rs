//! Top-K sparsification baseline ([9]/[14]: Gradient Dropping / sparsified
//! SGD) with per-worker error accumulation.
//!
//! Each worker selects its own top-K coordinates by magnitude of
//! (gradient + accumulated residual). Indices differ per worker, so the
//! payloads cannot be summed in compressed form — the scheme is
//! all-reduce *incompatible* ([16]'s "non-linear" class) and pays the
//! all-gather: (32-bit index + 32-bit value) × K per worker, O(M) scaling.

use crate::collectives::StepCtx;
use crate::util::rng::Rng;

use super::Aggregator;

pub struct TopK {
    pub k: usize,
    n: usize,
    /// per-worker residual accumulation ([14]'s "gradient dropping" memory)
    residual: Vec<Vec<f32>>,
}

impl TopK {
    pub fn new(k: usize, n: usize) -> TopK {
        TopK { k: k.min(n), n, residual: Vec::new() }
    }
}

impl Aggregator for TopK {
    fn name(&self) -> String {
        "TopK".into()
    }

    fn allreduce_compatible(&self) -> bool {
        false
    }

    fn nominal_bits(&self) -> f64 {
        64.0 * self.k as f64 / self.n as f64
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, _rng: &mut Rng) -> Vec<f32> {
        let m = grads.len();
        let n = grads[0].len();
        if self.residual.len() != m {
            self.residual = vec![vec![0.0f32; n]; m];
        }

        // encode: per-worker corrected top-K sparse payloads
        let payloads: Vec<Vec<(usize, f32)>> = ctx.time_encode(|| {
            grads
                .iter()
                .zip(self.residual.iter_mut())
                .map(|(g, res)| {
                    for (r, &gi) in res.iter_mut().zip(g.iter()) {
                        *r += gi;
                    }
                    let idx = crate::tensor::top_k_abs_indices(res, self.k);
                    let payload: Vec<(usize, f32)> = idx.iter().map(|&i| (i, res[i])).collect();
                    for &(i, _) in &payload {
                        res[i] = 0.0;
                    }
                    payload
                })
                .collect()
        });

        // all-gather: each worker ships K (idx, val) pairs — byte-exact
        // through the shared packed-wire rule (ceil(k*64/8) bytes)
        ctx.charge_allgather(self.k as f64, 64.0);

        // decode: average the M sparse vectors
        ctx.time_decode(|| {
            let mut out = vec![0.0f32; n];
            for p in &payloads {
                for &(i, v) in p {
                    out[i] += v;
                }
            }
            crate::tensor::scale(1.0 / m as f32, &mut out);
            out
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, SimClock};
    use crate::util::quickcheck::{check, ensure};

    fn run(agg: &mut TopK, grads: &[Vec<f32>]) -> (Vec<f32>, f64) {
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut rng = Rng::new(0);
        let out = agg.aggregate(&refs, &mut ctx, &mut rng);
        (out, clock.bits_per_worker)
    }

    #[test]
    fn prop_support_bounded_by_mk() {
        check("topk support <= M*K", 60, |g| {
            let n = g.size_scaled(16, 2000);
            let k = g.usize_in(1, n / 4 + 1);
            let m = g.usize_in(1, 5);
            let grads: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(n, 1.0)).collect();
            let mut agg = TopK::new(k, n);
            let (out, _) = run(&mut agg, &grads);
            let nz = out.iter().filter(|x| **x != 0.0).count();
            ensure(nz <= m * k, &format!("support {nz} > M*K {}", m * k))
        });
    }

    #[test]
    fn residual_telescopes() {
        // after T steps, sum(decoded) + residual == sum(grads) per worker
        let n = 200;
        let k = 10;
        let mut agg = TopK::new(k, n);
        let mut rng = Rng::new(5);
        let mut g_sum = vec![0.0f32; n];
        let mut d_sum = vec![0.0f32; n];
        for _ in 0..50 {
            let mut g = vec![0.0f32; n];
            rng.fill_normal_f32(&mut g, 1.0);
            crate::tensor::add_assign(&mut g_sum, &g);
            let (out, _) = run(&mut agg, &[g]);
            crate::tensor::add_assign(&mut d_sum, &out);
        }
        crate::tensor::add_assign(&mut d_sum, &agg.residual[0]);
        let err = crate::tensor::max_rel_err(&d_sum, &g_sum);
        assert!(err < 1e-4, "telescoping identity violated: {err}");
    }

    #[test]
    fn picks_largest_coordinates_first_step() {
        let n = 8;
        let g = vec![0.1, -9.0, 0.2, 5.0, -0.1, 0.0, 7.0, 0.3];
        let mut agg = TopK::new(3, n);
        let (out, _) = run(&mut agg, &[g]);
        assert!(out[1] != 0.0 && out[3] != 0.0 && out[6] != 0.0);
        assert_eq!(out.iter().filter(|x| **x != 0.0).count(), 3);
    }

    #[test]
    fn allgather_wire_cost() {
        let n = 1000;
        let grads: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; n]).collect();
        let mut agg = TopK::new(50, n);
        let (_, bits) = run(&mut agg, &grads);
        assert_eq!(bits, 64.0 * 50.0);
    }
}
