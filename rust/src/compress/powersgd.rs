//! PowerSGD baseline (Vogels, Karimireddy, Jaggi 2020) — the all-reduce
//! compatible low-rank scheme the paper benchmarks against (Figs 1/2, 15).
//!
//! Per matrix-shaped layer M (d1×d2), one step of subspace/power iteration:
//!   P = M·Q          (all-reduce mean over workers)
//!   P̂ = orthonormalize(P)           (local, deterministic Gram-Schmidt)
//!   Q = Mᵀ·P̂         (all-reduce mean over workers)
//!   ĝ = P̂·Qᵀ
//! with per-worker error feedback e ← (g + e) − ĝ and warm-started Q.
//! 1-D segments (biases, norms) are aggregated uncompressed, as in the
//! reference implementation.
//!
//! The paper's observation that PowerSGD converges worse than QSGD-MN (its
//! one-step power iteration has large compression error) reproduces here —
//! see `rust/benches/fig1_2_benchmark.rs`.

use crate::collectives::StepCtx;
use crate::runtime::Segment;
use crate::util::rng::Rng;

use super::Aggregator;

struct Layer {
    offset: usize,
    rows: usize,
    cols: usize,
}

pub struct PowerSgd {
    pub rank: usize,
    n: usize,
    layers: Vec<Layer>,
    /// coordinates aggregated uncompressed (1-D segments)
    dense_coords: usize,
    /// per-worker error feedback, lazily sized to [M][n]
    errors: Vec<Vec<f32>>,
    /// warm-started Q per layer (shared across workers)
    qs: Vec<Vec<f32>>,
}

impl PowerSgd {
    pub fn new(rank: usize, n: usize, segments: &[Segment]) -> anyhow::Result<PowerSgd> {
        anyhow::ensure!(rank >= 1, "rank must be >= 1");
        let mut layers = Vec::new();
        let mut dense_coords = 0usize;
        if segments.is_empty() {
            // flat-vector fallback: treat as one square-ish matrix
            let rows = (n as f64).sqrt() as usize;
            if rows >= 2 {
                let cols = n / rows;
                layers.push(Layer { offset: 0, rows, cols });
                dense_coords = n - rows * cols;
            } else {
                dense_coords = n;
            }
        } else {
            for seg in segments {
                if seg.shape.len() >= 2 {
                    let rows = seg.shape[0];
                    let cols: usize = seg.shape[1..].iter().product();
                    layers.push(Layer { offset: seg.offset, rows, cols });
                } else {
                    dense_coords += seg.len;
                }
            }
        }
        // seed Q with a fixed shared gaussian
        let mut rng = Rng::new(0x50575253); // "PWRS"
        let qs = layers
            .iter()
            .map(|l| {
                let mut q = vec![0.0f32; l.cols * rank];
                rng.fill_normal_f32(&mut q, 1.0);
                q
            })
            .collect();
        Ok(PowerSgd { rank, n, layers, dense_coords, errors: Vec::new(), qs })
    }

    /// Modified Gram-Schmidt on the columns of a (rows×rank) column-major
    /// matrix stored row-major [rows][rank].
    fn orthonormalize(p: &mut [f32], rows: usize, rank: usize) {
        for c in 0..rank {
            // subtract projections on previous columns
            for prev in 0..c {
                let mut dot = 0.0f64;
                for r in 0..rows {
                    dot += p[r * rank + c] as f64 * p[r * rank + prev] as f64;
                }
                for r in 0..rows {
                    p[r * rank + c] -= dot as f32 * p[r * rank + prev];
                }
            }
            let mut norm = 0.0f64;
            for r in 0..rows {
                norm += (p[r * rank + c] as f64).powi(2);
            }
            let norm = norm.sqrt().max(1e-12) as f32;
            for r in 0..rows {
                p[r * rank + c] /= norm;
            }
        }
    }
}

impl Aggregator for PowerSgd {
    fn name(&self) -> String {
        format!("PowerSGD-Rank-{}", self.rank)
    }

    fn allreduce_compatible(&self) -> bool {
        // P and Q all-reduce (the scheme's selling point), even though the
        // operator itself is biased; error feedback compensates.
        true
    }

    fn nominal_bits(&self) -> f64 {
        let compressed: usize = self
            .layers
            .iter()
            .map(|l| (l.rows + l.cols) * self.rank)
            .sum();
        32.0 * (compressed + self.dense_coords) as f64 / self.n as f64
    }

    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, _rng: &mut Rng) -> Vec<f32> {
        let m = grads.len();
        let n = grads[0].len();
        debug_assert_eq!(n, self.n);
        let rank = self.rank;

        if self.errors.len() != m {
            self.errors = vec![vec![0.0f32; n]; m];
        }

        // corrected gradient per worker: c_w = g_w + e_w
        let corrected: Vec<Vec<f32>> = ctx.time_encode(|| {
            grads
                .iter()
                .zip(&self.errors)
                .map(|(g, e)| g.iter().zip(e).map(|(a, b)| a + b).collect())
                .collect()
        });

        let mut out = vec![0.0f32; n];

        for (li, layer) in self.layers.iter().enumerate() {
            let (rows, cols, off) = (layer.rows, layer.cols, layer.offset);
            let q0 = &self.qs[li];

            // P_w = M_w · Q  (rows×rank), then all-reduce mean
            let ps: Vec<Vec<f32>> = ctx.time_encode(|| {
                corrected
                    .iter()
                    .map(|c| {
                        let mat = &c[off..off + rows * cols];
                        let mut p = vec![0.0f32; rows * rank];
                        for r in 0..rows {
                            for k in 0..cols {
                                let mrk = mat[r * cols + k];
                                if mrk != 0.0 {
                                    for c2 in 0..rank {
                                        p[r * rank + c2] += mrk * q0[k * rank + c2];
                                    }
                                }
                            }
                        }
                        p
                    })
                    .collect()
            });
            let mut p_shared = ctx.allreduce_sum(ps, 32.0);
            crate::tensor::scale(1.0 / m as f32, &mut p_shared);
            Self::orthonormalize(&mut p_shared, rows, rank);

            // Q_w = M_wᵀ · P̂ (cols×rank), all-reduce mean
            let qs_new: Vec<Vec<f32>> = ctx.time_encode(|| {
                corrected
                    .iter()
                    .map(|c| {
                        let mat = &c[off..off + rows * cols];
                        let mut q = vec![0.0f32; cols * rank];
                        for r in 0..rows {
                            for k in 0..cols {
                                let mrk = mat[r * cols + k];
                                if mrk != 0.0 {
                                    for c2 in 0..rank {
                                        q[k * rank + c2] += mrk * p_shared[r * rank + c2];
                                    }
                                }
                            }
                        }
                        q
                    })
                    .collect()
            });
            let mut q_shared = ctx.allreduce_sum(qs_new, 32.0);
            crate::tensor::scale(1.0 / m as f32, &mut q_shared);

            // decode ĝ = P̂ · Qᵀ and update error feedback
            ctx.time_decode(|| {
                for r in 0..rows {
                    for k in 0..cols {
                        let mut acc = 0.0f32;
                        for c2 in 0..rank {
                            acc += p_shared[r * rank + c2] * q_shared[k * rank + c2];
                        }
                        out[off + r * cols + k] = acc;
                    }
                }
                for w in 0..m {
                    for r in 0..rows {
                        for k in 0..cols {
                            let i = off + r * cols + k;
                            self.errors[w][i] = corrected[w][i] - out[i];
                        }
                    }
                }
            });
            self.qs[li] = q_shared;
        }

        // 1-D segments: uncompressed mean all-reduce. Collect them into one
        // contiguous buffer to charge the wire once.
        let dense_idx: Vec<(usize, usize)> = {
            let mut covered = vec![false; n];
            for l in &self.layers {
                for i in l.offset..l.offset + l.rows * l.cols {
                    covered[i] = true;
                }
            }
            let mut spans = Vec::new();
            let mut i = 0;
            while i < n {
                if !covered[i] {
                    let start = i;
                    while i < n && !covered[i] {
                        i += 1;
                    }
                    spans.push((start, i));
                } else {
                    i += 1;
                }
            }
            spans
        };
        if !dense_idx.is_empty() {
            let bufs: Vec<Vec<f32>> = corrected
                .iter()
                .map(|c| {
                    dense_idx
                        .iter()
                        .flat_map(|&(a, b)| c[a..b].iter().copied())
                        .collect()
                })
                .collect();
            let mut sum = ctx.allreduce_sum(bufs, 32.0);
            crate::tensor::scale(1.0 / m as f32, &mut sum);
            let mut j = 0;
            for &(a, b) in &dense_idx {
                for i in a..b {
                    out[i] = sum[j];
                    j += 1;
                }
            }
            // dense coords carry no error
            for w in 0..m {
                for &(a, b) in &dense_idx {
                    for i in a..b {
                        self.errors[w][i] = 0.0;
                    }
                }
            }
        }

        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetConfig, SimClock};
    use crate::util::quickcheck::{check, ensure};

    fn seg(name: &str, shape: &[usize], offset: usize) -> Segment {
        Segment {
            name: name.into(),
            shape: shape.to_vec(),
            offset,
            len: shape.iter().product(),
        }
    }

    fn run(agg: &mut PowerSgd, grads: &[Vec<f32>]) -> Vec<f32> {
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let net = NetConfig::flat(grads.len(), 10.0);
        let mut clock = SimClock::default();
        let mut ctx = StepCtx::new(&net, &mut clock);
        let mut rng = Rng::new(0);
        agg.aggregate(&refs, &mut ctx, &mut rng)
    }

    #[test]
    fn exact_on_rank1_matrix() {
        // a rank-1 gradient is reproduced (almost) exactly by rank-1 PowerSGD
        let rows = 16;
        let cols = 24;
        let segs = vec![seg("w", &[rows, cols], 0)];
        let mut agg = PowerSgd::new(1, rows * cols, &segs).unwrap();
        let u: Vec<f32> = (0..rows).map(|i| (i as f32 * 0.37).sin()).collect();
        let v: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut g = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                g[r * cols + c] = u[r] * v[c];
            }
        }
        let grads = vec![g.clone(), g.clone()];
        // warm up the Q power iteration a few steps
        let mut out = Vec::new();
        for _ in 0..4 {
            out = run(&mut agg, &grads);
        }
        let err = crate::tensor::max_rel_err(&out, &g);
        assert!(err < 1e-3, "rank-1 should converge to exact: err={err}");
    }

    #[test]
    fn error_feedback_preserves_signal_over_time() {
        // sum over steps of decoded output approaches sum of true gradients
        // (the error-feedback telescoping property).
        let rows = 8;
        let cols = 8;
        let segs = vec![seg("w", &[rows, cols], 0)];
        let mut agg = PowerSgd::new(1, rows * cols, &segs).unwrap();
        let mut rng = Rng::new(3);
        let mut true_sum = vec![0.0f32; rows * cols];
        let mut dec_sum = vec![0.0f32; rows * cols];
        for _ in 0..60 {
            let mut g = vec![0.0f32; rows * cols];
            rng.fill_normal_f32(&mut g, 1.0);
            crate::tensor::add_assign(&mut true_sum, &g);
            let out = run(&mut agg, &[g.clone(), g]);
            crate::tensor::add_assign(&mut dec_sum, &out);
        }
        // residual = current error buffer; bounded, not growing
        let resid: f64 = true_sum
            .iter()
            .zip(&dec_sum)
            .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let total = crate::tensor::norm2(&true_sum);
        assert!(
            resid < total,
            "error feedback must keep residual bounded: resid={resid} total={total}"
        );
    }

    #[test]
    fn dense_1d_segments_pass_through_exactly() {
        let segs = vec![seg("w", &[4, 4], 0), seg("b", &[6], 16)];
        let n = 22;
        let mut agg = PowerSgd::new(2, n, &segs).unwrap();
        let mut g = vec![0.0f32; n];
        for (i, v) in g.iter_mut().enumerate() {
            *v = i as f32 * 0.1;
        }
        let out = run(&mut agg, &[g.clone(), g.clone()]);
        // bias segment must be exact
        for i in 16..22 {
            assert!((out[i] - g[i]).abs() < 1e-6, "bias coord {i}");
        }
    }

    #[test]
    fn prop_orthonormalize_produces_orthonormal_columns() {
        check("gram-schmidt orthonormality", 50, |g| {
            let rows = g.usize_in(2, 40);
            let rank = g.usize_in(1, rows.min(4));
            let mut p = g.vec_normal(rows * rank, 1.0);
            PowerSgd::orthonormalize(&mut p, rows, rank);
            for a in 0..rank {
                for b in 0..=a {
                    let mut dot = 0.0f64;
                    for r in 0..rows {
                        dot += p[r * rank + a] as f64 * p[r * rank + b] as f64;
                    }
                    let want = if a == b { 1.0 } else { 0.0 };
                    if (dot - want).abs() > 1e-3 {
                        return Err(format!("col {a}·col {b} = {dot}, want {want}"));
                    }
                }
            }
            ensure(true, "")
        });
    }

    #[test]
    fn wire_bits_scale_with_rank_not_size() {
        let rows = 64;
        let cols = 64;
        let segs = vec![seg("w", &[rows, cols], 0)];
        let n = rows * cols;
        let g: Vec<Vec<f32>> = (0..2).map(|_| vec![0.1f32; n]).collect();
        for rank in [1usize, 2] {
            let mut agg = PowerSgd::new(rank, n, &segs).unwrap();
            let refs: Vec<&[f32]> = g.iter().map(|v| v.as_slice()).collect();
            let net = NetConfig::flat(2, 10.0);
            let mut clock = SimClock::default();
            let mut ctx = StepCtx::new(&net, &mut clock);
            let mut rng = Rng::new(0);
            agg.aggregate(&refs, &mut ctx, &mut rng);
            let expect = 32.0 * ((rows + cols) * rank) as f64;
            assert_eq!(clock.bits_per_worker, expect, "rank {rank}");
        }
    }
}
