//! Gradient compression engine: the paper's contribution + every baseline.
//!
//! An [`Aggregator`] consumes per-worker gradients and produces the shared
//! averaged update, performing its communication through a
//! [`StepCtx`](crate::collectives::StepCtx) so that wire bits and simulated
//! time are charged faithfully. All-reduce-compatible schemes (the paper's)
//! aggregate *in the compressed domain*; incompatible baselines pay the
//! all-gather path — exactly the distinction the paper's scalability
//! analysis (§1, §6.6) turns on.
//!
//! Implementations:
//! * [`fused`]          — integer-domain fused hot path (widened level
//!   buffers, persistent-pool encode fan-out, overflow-safe widening rule)
//! * [`bitpack`]        — word-level b-bit wire format (pack/unpack)
//! * [`none`]           — AllReduce-SGD, dense fp32 (the PyTorch default)
//! * [`qsgd_maxnorm`]   — §4.1 QSGDMaxNorm (single-scale, unbiased)
//! * [`multiscale`]     — §4.2 QSGDMaxNormMultiScale + scale sharing
//! * [`randk`]          — §4.3/§4.4 GlobalRandK sparsified variants
//! * [`powersgd`]       — Vogels et al. low-rank baseline (rank-1/2)
//! * [`signsgd`]        — Bernstein et al. majority-vote baseline
//! * [`terngrad`]       — Wen et al. ternary baseline
//! * [`topk`]           — magnitude sparsification baseline (all-gather)

pub mod bitpack;
pub mod fused;
pub mod kernels;
pub mod multiscale;
pub mod none;
pub mod powersgd;
pub mod qsgd_maxnorm;
pub mod randk;
pub mod signsgd;
pub mod terngrad;
pub mod topk;

use anyhow::{bail, Result};

use crate::collectives::StepCtx;
use crate::runtime::Segment;
use crate::util::rng::Rng;

/// A gradient aggregation strategy (compression + collective protocol).
pub trait Aggregator {
    /// Display name matching the paper's plot legends (e.g. "QSGD-MN-8").
    fn name(&self) -> String;

    /// True iff the compressed outputs commute with summation (DESIGN.md §4).
    fn allreduce_compatible(&self) -> bool;

    /// Nominal payload bits per coordinate (the paper's r), for reporting.
    fn nominal_bits(&self) -> f64;

    /// Aggregate per-worker gradients into the shared averaged update.
    ///
    /// `grads[m]` is worker m's gradient (all equal length). `rng` is the
    /// step's shared randomness root; implementations derive worker/purpose
    /// sub-streams from it so runs are reproducible.
    fn aggregate(&mut self, grads: &[&[f32]], ctx: &mut StepCtx, rng: &mut Rng) -> Vec<f32>;

    /// Aggregate over a partial cohort: `grads[i]` belongs to ORIGINAL
    /// worker `ids[i]` (strictly increasing subset of the full cohort).
    /// Estimators keyed by worker position must draw `ids[i]`'s randomness
    /// stream so an elastic run stays replayable; the live M is simply
    /// `grads.len()` — unbiased mean estimators renormalize automatically.
    ///
    /// The default is only sound for the full identity cohort (the
    /// strict-sync path) and asserts so; cohort-aware aggregators
    /// (the bucketed control plane) override it.
    fn aggregate_cohort(
        &mut self,
        grads: &[&[f32]],
        ids: &[usize],
        ctx: &mut StepCtx,
        rng: &mut Rng,
    ) -> Vec<f32> {
        assert_eq!(grads.len(), ids.len());
        assert!(
            ids.iter().enumerate().all(|(i, &w)| i == w),
            "{} is not cohort-aware: partial cohort {ids:?} needs an \
             aggregate_cohort override",
            self.name()
        );
        self.aggregate(grads, ctx, rng)
    }
}

/// Parsed method specification (CLI `--method`).
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// `allreduce` — dense fp32 baseline
    AllReduceSgd,
    /// `qsgd-mn-<bits>` e.g. qsgd-mn-8
    Qsgd { bits: usize },
    /// `qsgd-mn-ts-<b1>-<b2>` e.g. qsgd-mn-ts-2-6 (two-scale)
    QsgdTs { bits: Vec<usize> },
    /// `grandk-mn-<bits>[-k<K>]`
    RandK { bits: usize, k: Option<usize> },
    /// `grandk-mn-ts-<b1>-<b2>[-k<K>]`
    RandKTs { bits: Vec<usize>, k: Option<usize> },
    /// `powersgd-<rank>`
    PowerSgd { rank: usize },
    /// `signsgd`
    SignSgd,
    /// `terngrad`
    TernGrad,
    /// `topk[-k<K>]`
    TopK { k: Option<usize> },
}

impl Method {
    pub fn parse(spec: &str) -> Result<Method> {
        let s = spec.to_ascii_lowercase();
        let parts: Vec<&str> = s.split('-').collect();
        let k_of = |p: &str| -> Result<usize> {
            p.strip_prefix('k')
                .and_then(|x| x.parse().ok())
                .ok_or_else(|| anyhow::anyhow!("bad K spec '{p}' in '{spec}'"))
        };
        Ok(match parts.as_slice() {
            ["allreduce"] | ["allreduce", "sgd"] | ["sgd"] | ["none"] => Method::AllReduceSgd,
            ["qsgd", "mn", b] => Method::Qsgd { bits: b.parse()? },
            ["qsgd", "mn", "ts", b1, b2] => {
                Method::QsgdTs { bits: vec![b1.parse()?, b2.parse()?] }
            }
            ["grandk", "mn", b] => Method::RandK { bits: b.parse()?, k: None },
            ["grandk", "mn", b, kk] => Method::RandK { bits: b.parse()?, k: Some(k_of(kk)?) },
            ["grandk", "mn", "ts", b1, b2] => {
                Method::RandKTs { bits: vec![b1.parse()?, b2.parse()?], k: None }
            }
            ["grandk", "mn", "ts", b1, b2, kk] => Method::RandKTs {
                bits: vec![b1.parse()?, b2.parse()?],
                k: Some(k_of(kk)?),
            },
            ["powersgd", r] => Method::PowerSgd { rank: r.parse()? },
            ["signsgd"] => Method::SignSgd,
            ["terngrad"] => Method::TernGrad,
            ["topk"] => Method::TopK { k: None },
            ["topk", kk] => Method::TopK { k: Some(k_of(kk)?) },
            _ => bail!("unknown method '{spec}'"),
        })
    }

    /// Paper legend label.
    pub fn label(&self) -> String {
        match self {
            Method::AllReduceSgd => "AllReduce-SGD".into(),
            Method::Qsgd { bits } => format!("QSGD-MN-{bits}"),
            Method::QsgdTs { bits } => format!("QSGD-MN-TS-({},{})", bits[0], bits[1]),
            Method::RandK { bits, .. } => format!("GRandK-MN-{bits}"),
            Method::RandKTs { bits, .. } => format!("GRandK-MN-TS-({},{})", bits[0], bits[1]),
            Method::PowerSgd { rank } => format!("PowerSGD-Rank-{rank}"),
            Method::SignSgd => "SignSGD-MV".into(),
            Method::TernGrad => "TernGrad".into(),
            Method::TopK { .. } => "TopK".into(),
        }
    }

    /// Default K for sparsified methods: the paper uses K=10000 at n≈23.5M /
    /// 14.7M; we keep the same coordinate *fraction* (~1/2000) on the lite
    /// models, floored so tiny models still communicate something.
    pub fn default_k(n: usize) -> usize {
        (n / 2000).clamp(256.min(n), n)
    }

    /// Instantiate the aggregator for a gradient of `n` coordinates.
    /// `segments` provides the per-layer structure (PowerSGD needs it).
    pub fn build(&self, n: usize, segments: &[Segment]) -> Result<Box<dyn Aggregator>> {
        Ok(match self {
            Method::AllReduceSgd => Box::new(none::DenseAllReduce::new()),
            Method::Qsgd { bits } => Box::new(qsgd_maxnorm::QsgdMaxNorm::new(*bits)?),
            Method::QsgdTs { bits } => Box::new(multiscale::QsgdMultiScale::new(bits)?),
            Method::RandK { bits, k } => Box::new(randk::GlobalRandK::new(
                *bits,
                k.unwrap_or_else(|| Self::default_k(n)),
                n,
            )?),
            Method::RandKTs { bits, k } => Box::new(randk::GlobalRandKMultiScale::new(
                bits,
                k.unwrap_or_else(|| Self::default_k(n)),
                n,
            )?),
            Method::PowerSgd { rank } => {
                Box::new(powersgd::PowerSgd::new(*rank, n, segments)?)
            }
            Method::SignSgd => Box::new(signsgd::SignSgdMajority::new()),
            Method::TernGrad => Box::new(terngrad::TernGrad::new()),
            Method::TopK { k } => {
                Box::new(topk::TopK::new(k.unwrap_or_else(|| Self::default_k(n)), n))
            }
        })
    }
}

/// The exact aggregation invariant of DESIGN.md §4, as a reusable test
/// helper: decode(allreduce_sum(encodes)) must equal mean(decode-one)s.
/// (Used by per-scheme property tests.)
#[cfg(test)]
pub(crate) fn assert_allreduce_invariant(
    agg: &mut dyn Aggregator,
    grads: &[Vec<f32>],
    tol: f32,
) {
    use crate::netsim::{NetConfig, SimClock};
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let net = NetConfig::flat(grads.len(), 10.0);
    let mut clock = SimClock::default();
    let mut ctx = StepCtx::new(&net, &mut clock);
    let mut rng = Rng::new(1234);
    let out = agg.aggregate(&refs, &mut ctx, &mut rng);
    assert_eq!(out.len(), grads[0].len());
    // unbiased schemes: E[out] = mean(grads); single-draw check is loose,
    // but the aggregation must at least produce finite values of the right
    // magnitude and zero where all inputs are zero.
    let mean = crate::tensor::mean_of(&refs);
    for i in 0..out.len() {
        assert!(out[i].is_finite(), "non-finite at {i}");
        if grads.iter().all(|g| g[i] == 0.0) && agg.allreduce_compatible() {
            assert_eq!(out[i], 0.0, "zero columns must stay zero at {i}");
        }
    }
    let _ = (mean, tol);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_specs() {
        assert_eq!(Method::parse("allreduce").unwrap(), Method::AllReduceSgd);
        assert_eq!(Method::parse("qsgd-mn-8").unwrap(), Method::Qsgd { bits: 8 });
        assert_eq!(
            Method::parse("qsgd-mn-ts-2-6").unwrap(),
            Method::QsgdTs { bits: vec![2, 6] }
        );
        assert_eq!(
            Method::parse("grandk-mn-4").unwrap(),
            Method::RandK { bits: 4, k: None }
        );
        assert_eq!(
            Method::parse("grandk-mn-4-k512").unwrap(),
            Method::RandK { bits: 4, k: Some(512) }
        );
        assert_eq!(
            Method::parse("grandk-mn-ts-4-8-k512").unwrap(),
            Method::RandKTs { bits: vec![4, 8], k: Some(512) }
        );
        assert_eq!(Method::parse("powersgd-2").unwrap(), Method::PowerSgd { rank: 2 });
        assert_eq!(Method::parse("signsgd").unwrap(), Method::SignSgd);
        assert_eq!(Method::parse("terngrad").unwrap(), Method::TernGrad);
        assert_eq!(Method::parse("topk-k100").unwrap(), Method::TopK { k: Some(100) });
        assert!(Method::parse("nonsense").is_err());
        assert!(Method::parse("qsgd-mn-x").is_err());
    }

    #[test]
    fn labels_match_paper_legends() {
        assert_eq!(Method::parse("qsgd-mn-8").unwrap().label(), "QSGD-MN-8");
        assert_eq!(
            Method::parse("qsgd-mn-ts-2-6").unwrap().label(),
            "QSGD-MN-TS-(2,6)"
        );
        assert_eq!(
            Method::parse("powersgd-1").unwrap().label(),
            "PowerSGD-Rank-1"
        );
    }

    #[test]
    fn default_k_fraction() {
        assert_eq!(Method::default_k(23_520_842), 11760);
        assert_eq!(Method::default_k(100), 100); // floors at n
        assert!(Method::default_k(1_000_000) >= 256);
    }
}
